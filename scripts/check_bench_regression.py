#!/usr/bin/env python3
"""Fail CI when benchmark throughput regresses against a committed baseline.

Usage:
    check_bench_regression.py CURRENT.json BASELINE.json [--tolerance 0.20]

Compares the `mib_per_s` of every result name present in BOTH files and
exits non-zero if any current number falls more than `tolerance` below
the baseline (default 20%, overridable via --tolerance or the
BENCH_TOLERANCE env var). Results without throughput (null `mib_per_s`)
and names missing from either side are reported but never fail the job.

Bootstrap: a baseline carrying `"provisional": true` (the committed
placeholder before the first real CI run) prints the comparison but
always exits 0 — replace it with a `BENCH_throughput.json` artifact from
a representative CI run and drop the flag to arm the gate. See
docs/OPERATIONS.md ("Throughput regression gate").
"""

import argparse
import json
import sys


def load_results(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    results = {}
    for r in doc.get("results", []):
        if r.get("mib_per_s") is not None:
            results[r["name"]] = float(r["mib_per_s"])
    return doc, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args()

    import os
    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.20"))

    cur_doc, current = load_results(args.current)
    base_doc, baseline = load_results(args.baseline)
    provisional = bool(base_doc.get("provisional"))

    regressions = []
    print(f"{'benchmark':<44} {'baseline':>12} {'current':>12} {'delta':>8}")
    for name in sorted(baseline):
        if name not in current:
            print(f"{name:<44} {baseline[name]:>10.1f} {'missing':>12} {'--':>8}")
            continue
        b, c = baseline[name], current[name]
        delta = (c - b) / b if b else 0.0
        flag = ""
        if c < b * (1.0 - tolerance):
            regressions.append((name, b, c, delta))
            flag = "  << REGRESSION"
        print(f"{name:<44} {b:>10.1f} {c:>10.1f} {delta:>+7.1%}{flag}")
    for name in sorted(set(current) - set(baseline)):
        print(f"{name:<44} {'--':>12} {current[name]:>10.1f}   (new, not gated)")

    if not baseline:
        print("\nbaseline carries no throughput results; nothing to gate")
    if provisional:
        print("\nbaseline is marked provisional: comparison is informational only")
        return 0
    if regressions:
        print(f"\n{len(regressions)} benchmark(s) regressed more than "
              f"{tolerance:.0%} vs {args.baseline}:")
        for name, b, c, delta in regressions:
            print(f"  {name}: {b:.1f} -> {c:.1f} MiB/s ({delta:+.1%})")
        return 1
    print(f"\nno regression beyond {tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
