#!/usr/bin/env python3
"""Fail CI when benchmark throughput regresses against a committed baseline.

Usage:
    check_bench_regression.py CURRENT.json BASELINE.json [--tolerance 0.20]
                              [--allow-provisional] [--ignore-tags]

Compares the `mib_per_s` of every result name present in BOTH files and
exits non-zero if any current number falls more than `tolerance` below
the baseline (default 20%, overridable via --tolerance or the
BENCH_TOLERANCE env var). Results without throughput (null `mib_per_s`)
and names missing from either side are reported but never fail the job.

The gate is ARMED by default — these are hard failures, not warnings:

  * exit 2 if the baseline file is missing or unparseable (a gate that
    silently skips is not a gate);
  * exit 2 if the baseline carries no throughput results;
  * exit 2 if the baseline is marked `"provisional": true` and
    --allow-provisional was not passed. The flag exists for the
    bootstrap window only: the first CI run on a new perf-relevant
    change has no real baseline yet, and the bless job
    (scripts/bless_bench_baseline.py) replaces the placeholder with
    that run's artifact on the next main push;
  * exit 2 if the two files disagree on an environment tag the gate
    knows about — `tags.isa` (comparing an AVX2 run against a scalar
    baseline measures the dispatch table, not the change under test),
    `tags.cache` (comparing a cache-on run against a cache-off baseline
    measures the hot-block cache tier, not the change under test), or
    `tags.integrity` (comparing runs with different integrity-mode arm
    sets measures checksum overhead, not the change under test) —
    unless --ignore-tags.

See docs/OPERATIONS.md ("Throughput regression gate").
"""

import argparse
import json
import os
import sys


def load_doc(path, role):
    if not os.path.exists(path):
        print(f"error: {role} file {path!r} does not exist", file=sys.stderr)
        sys.exit(2)
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot parse {role} file {path!r}: {e}", file=sys.stderr)
        sys.exit(2)
    results = {}
    for r in doc.get("results", []):
        if r.get("mib_per_s") is not None:
            results[r["name"]] = float(r["mib_per_s"])
    return doc, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--allow-provisional", action="store_true",
                    help="bootstrap only: tolerate a provisional baseline "
                         "(informational comparison, exit 0)")
    ap.add_argument("--ignore-tags", action="store_true",
                    help="skip the tags.* environment-match check "
                         "(isa/cache/persist/integrity)")
    args = ap.parse_args()

    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.20"))

    cur_doc, current = load_doc(args.current, "current")
    base_doc, baseline = load_doc(args.baseline, "baseline")
    provisional = bool(base_doc.get("provisional"))

    if provisional and not args.allow_provisional:
        print(f"error: baseline {args.baseline!r} is marked provisional; "
              "the gate refuses to run against a placeholder.\n"
              "Bless a real CI artifact (scripts/bless_bench_baseline.py) "
              "or pass --allow-provisional during bootstrap.",
              file=sys.stderr)
        return 2
    if not baseline and not provisional:
        print(f"error: baseline {args.baseline!r} carries no throughput "
              "results; refusing to gate against an empty baseline",
              file=sys.stderr)
        return 2

    if not args.ignore_tags:
        for tag in ("isa", "cache", "persist", "integrity"):
            cur_tag = (cur_doc.get("tags") or {}).get(tag)
            base_tag = (base_doc.get("tags") or {}).get(tag)
            if cur_tag and base_tag and cur_tag != base_tag:
                print(f"error: tags.{tag} mismatch: current run used "
                      f"{cur_tag!r}, baseline was recorded under "
                      f"{base_tag!r}. Re-bless the baseline under a "
                      "matching environment or pass --ignore-tags.",
                      file=sys.stderr)
                return 2

    regressions = []
    print(f"{'benchmark':<44} {'baseline':>12} {'current':>12} {'delta':>8}")
    for name in sorted(baseline):
        if name not in current:
            print(f"{name:<44} {baseline[name]:>10.1f} {'missing':>12} {'--':>8}")
            continue
        b, c = baseline[name], current[name]
        delta = (c - b) / b if b else 0.0
        flag = ""
        if c < b * (1.0 - tolerance):
            regressions.append((name, b, c, delta))
            flag = "  << REGRESSION"
        print(f"{name:<44} {b:>10.1f} {c:>10.1f} {delta:>+7.1%}{flag}")
    for name in sorted(set(current) - set(baseline)):
        print(f"{name:<44} {'--':>12} {current[name]:>10.1f}   (new, not gated)")

    if provisional:
        print("\nbaseline is provisional (--allow-provisional): "
              "comparison is informational only")
        return 0
    if regressions:
        print(f"\n{len(regressions)} benchmark(s) regressed more than "
              f"{tolerance:.0%} vs {args.baseline}:")
        for name, b, c, delta in regressions:
            print(f"  {name}: {b:.1f} -> {c:.1f} MiB/s ({delta:+.1%})")
        return 1
    print(f"\nno regression beyond {tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
