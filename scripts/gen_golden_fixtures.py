#!/usr/bin/env python3
"""Generate the golden wire-format fixtures under rust/tests/golden/.

This is a deliberate, minimal re-implementation of the crate's wire
format (bit stream, block codecs, container framing) used to produce the
checked-in fixtures that `rust/tests/golden_wire.rs` pins the Rust
implementation against. Two independent implementations agreeing
bit-for-bit is the point: a drift in either one fails the golden tests.

It also carries an independent encoder/decoder for the GBN1 network
protocol (`rust/src/server/protocol.rs`): the `gbn1_*.gbn` fixtures pin
the handshake and every request/response frame shape byte-for-byte
against `rust/tests/golden_protocol.rs`.

The GBDI fixture images are constructed so that every word fits at most
one table entry (asserted below), making the encoding independent of the
encoder's search order / MRU probe tie-breaks.

Normally you regenerate fixtures from the Rust side
(`GOLDEN_BLESS=1 cargo test --test golden_wire`); this script exists so
the fixtures can also be produced and cross-checked without a Rust
toolchain.
"""

import os
import sys
import zlib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "golden")

MASK32 = (1 << 32) - 1
MASK64 = (1 << 64) - 1


# ---- bit stream (LSB-first, matches util/bits.rs) -----------------------

class BitWriter:
    def __init__(self):
        self.bits = 0  # LSB = first bit of the stream
        self.n = 0

    def put(self, v, n):
        assert 0 <= n <= 64
        assert 0 <= v and (n == 64 or v < (1 << n)), f"{v} does not fit {n} bits"
        self.bits |= v << self.n
        self.n += n

    def put_bytes(self, bs):
        for b in bs:
            self.put(b, 8)

    def bit_len(self):
        return self.n

    def finish(self):
        return self.bits.to_bytes((self.n + 7) // 8, "little")


class BitReader:
    def __init__(self, data):
        self.v = int.from_bytes(data, "little")
        self.total = len(data) * 8
        self.pos = 0

    def get(self, n):
        if self.pos + n > self.total:
            raise EOFError(f"need {n} bits at {self.pos}, have {self.total}")
        out = (self.v >> self.pos) & ((1 << n) - 1)
        self.pos += n
        return out


def varint(v):
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def sext(v, bits):
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= (1 << (bits - 1)) else v


def signed_width(d):
    if d == 0:
        return 0
    n = 1
    while not (-(1 << (n - 1)) <= d < (1 << (n - 1))):
        n += 1
    return n


# ---- GBDI (gbdi/{table,encode}.rs) --------------------------------------

GBDI_CLASSES = [0, 4, 8, 12, 16, 20, 24]
NUM_BASES = 64
PTR_BITS = 7  # ceil(log2(64 + 1))
ESCAPE = NUM_BASES


def gbdi_config_bytes(block_bytes=64):
    out = block_bytes.to_bytes(4, "little") + bytes([4])
    out += NUM_BASES.to_bytes(2, "little") + bytes([len(GBDI_CLASSES)])
    out += bytes(GBDI_CLASSES)
    return out


def table_entries(pairs):
    """GlobalBaseTable::new: pin (0, 8), sort, dedup keeping max width."""
    pairs = list(pairs)
    if not any(b == 0 for b, _ in pairs):
        pairs.append((0, 8))
    pairs.sort()
    entries = []
    for base, width in pairs:
        if entries and entries[-1][0] == base:
            entries[-1] = (base, max(entries[-1][1], width))
        else:
            entries.append((base, width))
    return entries


def table_bytes(entries, version):
    out = b"GBT2" + version.to_bytes(8, "little") + bytes([4])
    out += len(entries).to_bytes(4, "little")
    for base, width in entries:
        out += base.to_bytes(4, "little") + bytes([width])
    return out


def gbdi_fits(entries, v):
    """All (idx, delta, width) encodings of word v; the fixtures assert
    at most one, so search-order tie-breaks cannot move the wire."""
    fits = []
    for idx, (base, width) in enumerate(entries):
        d = sext(v - base, 32)
        if signed_width(d) <= width:
            fits.append((idx, d, width))
    return fits


def gbdi_encode_block(entries, block, w):
    if len(block) != 64:
        w.put(0, 2)  # RAW tag
        w.put_bytes(block)
        return
    words = [int.from_bytes(block[i * 4:(i + 1) * 4], "little") for i in range(16)]
    if all(v == words[0] for v in words):
        if words[0] == 0:
            w.put(1, 2)  # ZERO
        else:
            w.put(2, 2)  # REP
            w.put(words[0], 32)
        return
    plan = []
    gbdi_bits = 2
    for v in words:
        fits = gbdi_fits(entries, v)
        assert len(fits) <= 1, f"word {v:#x} fits {len(fits)} bases; fixture must be unambiguous"
        if fits:
            idx, d, width = fits[0]
            gbdi_bits += PTR_BITS + width
            if width == 0:
                plan.append((idx, PTR_BITS))
            else:
                plan.append((idx | ((d + (1 << (width - 1))) << PTR_BITS), PTR_BITS + width))
        else:
            gbdi_bits += PTR_BITS + 32
            plan.append((ESCAPE | (v << PTR_BITS), PTR_BITS + 32))
    if gbdi_bits >= 2 + len(block) * 8:
        w.put(0, 2)
        w.put_bytes(block)
        return
    w.put(3, 2)  # GBDI
    for field, bits in plan:
        w.put(field, bits)


def gbdi_decode_block(entries, r, out_len):
    tag = r.get(2)
    if tag == 0:
        return bytes(r.get(8) for _ in range(out_len))
    if tag == 1:
        return bytes(out_len)
    if tag == 2:
        v = r.get(32)
        assert out_len % 4 == 0
        return v.to_bytes(4, "little") * (out_len // 4)
    assert out_len == 64
    out = bytearray()
    for _ in range(16):
        ptr = r.get(PTR_BITS)
        if ptr == ESCAPE:
            v = r.get(32)
        else:
            assert ptr < len(entries), "pointer beyond table"
            base, width = entries[ptr]
            if width == 0:
                v = base
            else:
                d = r.get(width) - (1 << (width - 1))
                v = (base + d) & MASK32
        out += v.to_bytes(4, "little")
    return bytes(out)


# ---- BDI (baselines/bdi.rs) ---------------------------------------------

# (enc id, base bytes, delta bytes) in the Rust selection-menu order
BDI_MENU = [(2, 8, 1), (5, 4, 1), (3, 8, 2), (7, 2, 1), (6, 4, 2), (4, 8, 4)]


def read_le(block, i, k):
    return int.from_bytes(block[i * k:(i + 1) * k], "little")


def bdi_sign_fits(delta, k, d):
    return -(1 << (8 * d - 1)) <= sext(delta, 8 * k) < (1 << (8 * d - 1))


def bdi_plan_fits(block, k, d):
    base = None
    for i in range(len(block) // k):
        v = read_le(block, i, k)
        if bdi_sign_fits(v, k, d):
            continue
        if base is None:
            base = v
        if not bdi_sign_fits((v - base) & ((1 << (8 * k)) - 1), k, d):
            return False
    return True


def bdi_plan_into(block, k, d):
    dmask = (1 << (8 * d)) - 1
    kmask = (1 << (8 * k)) - 1
    base = None
    plan = []
    for i in range(len(block) // k):
        v = read_le(block, i, k)
        if bdi_sign_fits(v, k, d):
            plan.append((True, v & dmask))
            continue
        if base is None:
            base = v
        delta = (v - base) & kmask
        assert bdi_sign_fits(delta, k, d)
        plan.append((False, delta & dmask))
    return (0 if base is None else base), plan


def bdi_encode_block(block, w, block_bytes=64):
    if len(block) == block_bytes:
        if all(b == 0 for b in block):
            w.put(0, 4)  # Zeros
            return
        if len(block) % 8 == 0:
            first = read_le(block, 0, 8)
            if all(read_le(block, i, 8) == first for i in range(1, len(block) // 8)):
                w.put(1, 4)  # Rep8
                w.put(first, 64)
                return
        best = None
        for enc_id, k, d in BDI_MENU:
            if len(block) % k != 0:
                continue
            n = len(block) // k
            bits = 4 + 8 * k + n + 8 * d * n
            if (best is None or bits < best[3]) and bdi_plan_fits(block, k, d):
                best = (enc_id, k, d, bits)
        if best is not None:
            enc_id, k, d, bits = best
            if bits < 4 + 8 * len(block):
                base, plan = bdi_plan_into(block, k, d)
                w.put(enc_id, 4)
                w.put(base & ((1 << (8 * k)) - 1), 8 * k)
                for zero, _ in plan:
                    w.put(1 if zero else 0, 1)
                for _, delta in plan:
                    w.put(delta, 8 * d)
                return
    w.put(8, 4)  # Raw
    w.put_bytes(block)


def bdi_decode_block(r, out_len):
    enc = r.get(4)
    if enc == 0:
        return bytes(out_len)
    if enc == 1:
        v = r.get(64)
        assert out_len % 8 == 0
        return v.to_bytes(8, "little") * (out_len // 8)
    if enc == 8:
        return bytes(r.get(8) for _ in range(out_len))
    kd = {2: (8, 1), 3: (8, 2), 4: (8, 4), 5: (4, 1), 6: (4, 2), 7: (2, 1)}[enc]
    k, d = kd
    assert out_len % k == 0
    n = out_len // k
    base = r.get(8 * k)
    mask = [r.get(1) for _ in range(n)]
    out = bytearray()
    for i in range(n):
        delta = r.get(8 * d)
        sd = sext(delta, 8 * d) & ((1 << (8 * k)) - 1)
        v = sd if mask[i] else (base + sd) & ((1 << (8 * k)) - 1)
        v &= (1 << (8 * k)) - 1
        out += v.to_bytes(k, "little")
    return bytes(out)


# ---- FPC (baselines/fpc.rs) ---------------------------------------------

def fpc_sext_fits(v, bits):
    s = sext(v, 32)
    return -(1 << (bits - 1)) <= s < (1 << (bits - 1))


def fpc_encode_word(w, v):
    if v == 0:
        w.put(0b000, 3)
    elif fpc_sext_fits(v, 4):
        w.put(0b001, 3)
        w.put(v & 0xF, 4)
    elif fpc_sext_fits(v, 8):
        w.put(0b010, 3)
        w.put(v & 0xFF, 8)
    elif fpc_sext_fits(v, 16):
        w.put(0b011, 3)
        w.put(v & 0xFFFF, 16)
    elif v & 0xFFFF == 0:
        w.put(0b100, 3)
        w.put(v >> 16, 16)
    elif -128 <= sext(v & 0xFFFF, 16) < 128 and -128 <= sext(v >> 16, 16) < 128:
        w.put(0b101, 3)
        w.put(v & 0xFF, 8)
        w.put((v >> 16) & 0xFF, 8)
    elif all(b == (v & 0xFF) for b in v.to_bytes(4, "little")):
        w.put(0b110, 3)
        w.put(v & 0xFF, 8)
    else:
        w.put(0b111, 3)
        w.put(v, 32)


def fpc_decode_word(r):
    p = r.get(3)
    if p == 0b000:
        return 0
    if p == 0b001:
        return sext(r.get(4), 4) & MASK32
    if p == 0b010:
        return sext(r.get(8), 8) & MASK32
    if p == 0b011:
        return sext(r.get(16), 16) & MASK32
    if p == 0b100:
        return r.get(16) << 16
    if p == 0b101:
        lo = sext(r.get(8), 8) & 0xFFFF
        hi = sext(r.get(8), 8) & 0xFFFF
        return lo | (hi << 16)
    if p == 0b110:
        b = r.get(8)
        return b | (b << 8) | (b << 16) | (b << 24)
    return r.get(32)


def fpc_encode_block(block, w):
    words = len(block) // 4
    for i in range(words):
        fpc_encode_word(w, read_le(block, i, 4))
    w.put_bytes(block[words * 4:])


def fpc_decode_block(r, out_len):
    words = out_len // 4
    out = bytearray()
    for _ in range(words):
        out += fpc_decode_word(r).to_bytes(4, "little")
    for _ in range(out_len - words * 4):
        out.append(r.get(8))
    return bytes(out)


# ---- container framing (container.rs) -----------------------------------

def compress_image(encode_block, image, block_bytes=64):
    w = BitWriter()
    block_bits = []
    for off in range(0, len(image), block_bytes):
        before = w.bit_len()
        encode_block(image[off:off + block_bytes], w)
        block_bits.append(w.bit_len() - before)
    return w.finish(), block_bits


def container_bytes(codec_id, config, table, image_len, block_bits, payload,
                    block_bytes=64):
    out = bytearray(b"GBC1")
    out.append(codec_id)
    out.append(1 if table is not None else 0)
    out += len(config).to_bytes(2, "little")
    out += config
    if table is not None:
        out += table
    out += image_len.to_bytes(8, "little")
    out += block_bytes.to_bytes(4, "little")
    out += (0).to_bytes(4, "little")  # chunk_blocks: serial stream
    out += len(block_bits).to_bytes(4, "little")
    for b in block_bits:
        out += varint(b)
    out += payload
    return bytes(out)


# ---- fixture images (mirrored in rust/tests/golden_wire.rs) -------------

def words_le(words):
    return b"".join((v & MASK32).to_bytes(4, "little") for v in words)


def gbdi_mixed_image():
    words = []
    words += [900 + 7 * i for i in range(16)]
    words += [0] * 16
    words += [0xDEADBEEF] * 16
    words += [(0x10000000 + i * 0x01234567) & MASK32 for i in range(16)]
    words += [(1 << 20) - 15000 + 1234 * i for i in range(16)]
    words += [1000 + i for i in range(12)] + [0xA0000000 + i for i in range(12, 16)]
    words += [[0, 1000, 1 << 20][i % 3] for i in range(16)]
    words += [1000 - i for i in range(16)]
    return words_le(words)


def gbdi_ragged_image():
    image = words_le([900 + 7 * i for i in range(16)])
    image += words_le([0] * 16)
    image += bytes((3 * j + 1) % 256 for j in range(21))
    return image


def gbdi_allraw_image():
    return bytes((37 * j + 11) % 256 for j in range(256))


def bdi_image():
    image = bytes(64)
    image += (0x0123456789ABCDEF).to_bytes(8, "little") * 8
    image += b"".join((0x7F3A00001000 + 3 * i).to_bytes(8, "little") for i in range(8))
    image += b"".join((0x00100000 + 200 * j).to_bytes(4, "little") for j in range(16))
    image += bytes((91 * j + 7) % 256 for j in range(64))
    image += b"".join((0x7FFF00000000 + 1000 * i).to_bytes(8, "little") for i in range(8))
    return image


FPC_WORDS = [
    0, 3, 0xFFFFFFFF, 100, 0xFFFFFF80, 30000, 0xFFFF8000, 0x12340000,
    0x00420017, 0xABABABAB, 0xDEADBEEF, 8, 127, 128, 0x7FFF0000, 0xFFFFFFF8,
    0x00010001, 0, 0x00000005, 0x0000FF00, 0x00320000, 0x11111111,
    0x80000000, 0x0000ABCD, 0xFFFF0001, 42, 0xFFFFFF01, 0x00008000,
    0x7F7F7F7F, 1, 0xC0C0C0C0, 0x00FF00FF,
]


def fpc_image():
    return words_le(FPC_WORDS) + bytes([9, 8, 7, 6, 5, 4, 3])


# ---- GBN1 network protocol (rust/src/server/protocol.rs) ----------------
#
# Everything below mirrors the Rust encoders byte-for-byte: little-endian
# fixed-width integers, u32 length prefixes, one op byte per request and
# one status byte + echoed op byte per response. The decoders exist so
# the fixtures are cross-checked (decode -> re-encode -> identical) by an
# implementation that shares no code with the encoder's call sites.

GBN_MAGIC = b"GBN1"
GBN_VERSION = 1
GBN_STATS_VERSION = 1
GBN_MIN_REQUEST_PAYLOAD = 9
MASK32 = (1 << 32) - 1
MASK64 = (1 << 64) - 1

GBN_OPS = {
    "put_pages": 1, "get_block": 2, "get_blocks": 3, "put_block": 4,
    "read_range": 5, "flush": 6, "stats": 7, "reanalyze": 8, "shutdown": 9,
}
GBN_STATUS = {
    "ok": 0, "not_found": 1, "bad_request": 2, "retry_after": 3,
    "server_error": 4, "shutting_down": 5,
}
GBN_OP_NAMES = {v: k for k, v in GBN_OPS.items()}
GBN_STATUS_NAMES = {v: k for k, v in GBN_STATUS.items()}


def u32le(v):
    return (v & MASK32).to_bytes(4, "little")


def u64le(v):
    return (v & MASK64).to_bytes(8, "little")


def gbn_frame(payload):
    return u32le(len(payload)) + payload


def gbn_server_hello(block_bytes):
    return GBN_MAGIC + bytes([GBN_VERSION, 0]) + block_bytes.to_bytes(2, "little")


def gbn_request(req_id, op, body):
    """Encode one request payload (no length prefix)."""
    out = bytearray(u64le(req_id))
    out.append(GBN_OPS[op])
    if op == "put_pages":
        out += u32le(len(body))
        for page_id, data in body:
            out += u64le(page_id) + u32le(len(data)) + bytes(data)
    elif op == "get_block":
        page_id, block = body
        out += u64le(page_id) + u32le(block)
    elif op == "get_blocks":
        out += u32le(len(body))
        for page_id, block in body:
            out += u64le(page_id) + u32le(block)
    elif op == "put_block":
        page_id, block, data = body
        out += u64le(page_id) + u32le(block) + u32le(len(data)) + bytes(data)
    elif op == "read_range":
        page_id, first, count = body
        out += u64le(page_id) + u32le(first) + u32le(count)
    else:
        assert op in ("flush", "stats", "reanalyze", "shutdown") and body == ()
    return bytes(out)


def gbn_response(req_id, status, op, body):
    """Encode one response payload. For non-ok statuses `op` is the raw
    echoed op byte and `body` is `(retry_ms, message)`."""
    out = bytearray(u64le(req_id))
    out.append(GBN_STATUS[status])
    if status != "ok":
        out.append(op)
        retry_ms, message = body
        msg = message.encode("utf-8")
        out += u32le(retry_ms) + u32le(len(msg)) + msg
        return bytes(out)
    out.append(GBN_OPS[op])
    if op == "put_pages":
        out += u32le(body)
    elif op in ("get_block", "read_range"):
        out += u32le(len(body)) + bytes(body)
    elif op == "get_blocks":
        out += u32le(len(body))
        for item in body:
            if item is None:
                out.append(0)
            else:
                out.append(1)
                out += u32le(len(item)) + bytes(item)
    elif op == "flush":
        out += u64le(body)
    elif op == "stats":
        out.append(GBN_STATS_VERSION)
        out += u32le(len(body))
        for field in body:
            out += u64le(field)
    elif op == "reanalyze":
        out += u64le(body)
    else:
        assert op in ("put_block", "shutdown") and body == ()
    return bytes(out)


class GbnCursor:
    """Bounds-checked little-endian reader (mirror of protocol.rs `Rd`)."""

    def __init__(self, buf):
        self.buf = buf
        self.pos = 0

    def take(self, n):
        assert self.pos + n <= len(self.buf), "truncated GBN1 payload"
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u8(self):
        return self.take(1)[0]

    def u32(self):
        return int.from_bytes(self.take(4), "little")

    def u64(self):
        return int.from_bytes(self.take(8), "little")

    def finish(self):
        assert self.pos == len(self.buf), "trailing bytes after GBN1 payload"


def gbn_decode_request(payload):
    c = GbnCursor(payload)
    req_id = c.u64()
    op = GBN_OP_NAMES[c.u8()]
    if op == "put_pages":
        body = [(c.u64(), c.take(c.u32())) for _ in range(c.u32())]
    elif op == "get_block":
        body = (c.u64(), c.u32())
    elif op == "get_blocks":
        body = [(c.u64(), c.u32()) for _ in range(c.u32())]
    elif op == "put_block":
        body = (c.u64(), c.u32(), c.take(c.u32()))
    elif op == "read_range":
        body = (c.u64(), c.u32(), c.u32())
    else:
        body = ()
    c.finish()
    return req_id, op, body


def gbn_decode_response(payload):
    c = GbnCursor(payload)
    req_id = c.u64()
    status = GBN_STATUS_NAMES[c.u8()]
    op_byte = c.u8()
    if status != "ok":
        body = (c.u32(), c.take(c.u32()).decode("utf-8"))
        c.finish()
        return req_id, status, op_byte, body
    op = GBN_OP_NAMES[op_byte]
    if op == "put_pages":
        body = c.u32()
    elif op in ("get_block", "read_range"):
        body = c.take(c.u32())
    elif op == "get_blocks":
        body = [c.take(c.u32()) if c.u8() else None for _ in range(c.u32())]
    elif op == "flush":
        body = c.u64()
    elif op == "stats":
        assert c.u8() == GBN_STATS_VERSION, "stats reply version moved"
        body = [c.u64() for _ in range(c.u32())]
    elif op == "reanalyze":
        body = c.u64()
    else:
        body = ()
    c.finish()
    return req_id, status, op, body


# The frozen frame sequences. Touch ONLY with a protocol version bump:
# rust/tests/golden_protocol.rs builds the identical lists in Rust and
# the checked-in bytes must match both.
GBN_REQUESTS = [
    (1, "put_pages", [
        (0x1122334455667788, bytes((i * 7 + 3) & 0xFF for i in range(16))),
        (7, b"\xAB" * 5),
    ]),
    (2, "get_block", (3, 9)),
    (3, "get_blocks", [(1, 2), (MASK64, MASK32)]),
    (4, "put_block", (5, 0, b"\xC3" * 64)),
    (5, "read_range", (9, 2, 3)),
    (6, "flush", ()),
    (7, "stats", ()),
    (MASK64, "reanalyze", ()),
    (0, "shutdown", ()),
]

GBN_RESPONSES = [
    (1, "ok", "put_pages", 2),
    (2, "ok", "get_block", bytes(range(64))),
    (3, "ok", "get_blocks", [bytes(range(1, 9)), None]),
    (4, "ok", "put_block", ()),
    (5, "ok", "read_range", bytes(255 - i for i in range(12))),
    (6, "ok", "flush", 7),
    (7, "ok", "stats", [1000 + i for i in range(29)]),
    (8, "ok", "reanalyze", 3),
    (9, "ok", "shutdown", ()),
    (2, "not_found", 2, (0, "page 3 not found")),
    (10, "bad_request", 0x2A, (0, "unknown op 0x2a")),
    (1, "retry_after", 1, (50, "ingest backlog")),
    (11, "shutting_down", 4, (0, "")),
    (12, "server_error", 6, (0, "internal")),
]


def gbn_split_frames(stream):
    """Split a concatenation of length-prefixed frames back into payloads."""
    out = []
    pos = 0
    while pos < len(stream):
        assert pos + 4 <= len(stream), "truncated frame header"
        n = int.from_bytes(stream[pos:pos + 4], "little")
        assert n >= GBN_MIN_REQUEST_PAYLOAD, f"frame length {n} under minimum"
        payload = stream[pos + 4:pos + 4 + n]
        assert len(payload) == n, "truncated frame body"
        out.append(payload)
        pos += 4 + n
    return out


def build_gbn1_fixtures():
    hello = GBN_MAGIC + gbn_server_hello(64)

    requests = bytearray()
    for req_id, op, body in GBN_REQUESTS:
        payload = gbn_request(req_id, op, body)
        rid, rop, rbody = gbn_decode_request(payload)
        assert gbn_request(rid, rop, rbody) == payload, \
            f"GBN1 request {req_id}/{op} decode/re-encode drift"
        requests += gbn_frame(payload)

    responses = bytearray()
    for req_id, status, op, body in GBN_RESPONSES:
        payload = gbn_response(req_id, status, op, body)
        decoded = gbn_decode_response(payload)
        assert gbn_response(*decoded) == payload, \
            f"GBN1 response {req_id}/{status} decode/re-encode drift"
        responses += gbn_frame(payload)

    assert len(gbn_split_frames(bytes(requests))) == len(GBN_REQUESTS)
    assert len(gbn_split_frames(bytes(responses))) == len(GBN_RESPONSES)
    return [
        ("gbn1_hello.gbn", hello),
        ("gbn1_requests.gbn", bytes(requests)),
        ("gbn1_responses.gbn", bytes(responses)),
    ]


# ---- persistence formats (rust/src/persist/{wal,segment}.rs) ------------
#
# The durability layer's three on-disk formats, mirrored independently:
# WAL records (GBW1), checkpoint segments (GBS1), and the manifest
# (GBM1). Their CRC-32 is the zlib polynomial (0xEDB88320, reflected,
# init/xorout 0xFFFFFFFF), so zlib.crc32 is the reference here — if the
# Rust table drifts, every persist fixture mismatches at once.

WAL_MAGIC = b"GBW1"
SEGMENT_MAGIC = b"GBS1"
MANIFEST_MAGIC = b"GBM1"
MANIFEST_VERSION = 1

WAL_TAGS = {"put_page": 1, "write_block": 2, "remove_page": 3,
            "publish_codec": 4, "resize": 5}
WAL_TAG_NAMES = {v: k for k, v in WAL_TAGS.items()}


def crc32(data):
    return zlib.crc32(bytes(data)) & MASK32


def wal_record(kind, body):
    """Encode one WAL payload (tag + body, no framing)."""
    out = bytearray([WAL_TAGS[kind]])
    if kind == "put_page":
        page_id, container = body
        out += u64le(page_id) + bytes(container)
    elif kind == "write_block":
        page_id, block, data = body
        out += u64le(page_id) + u32le(block) + bytes(data)
    elif kind == "remove_page":
        out += u64le(body)
    elif kind == "publish_codec":
        out += bytes(body)
    else:
        assert kind == "resize"
        out += u32le(body)
    return bytes(out)


def wal_decode_record(payload):
    kind = WAL_TAG_NAMES[payload[0]]
    body = payload[1:]
    if kind == "put_page":
        assert len(body) >= 8
        return kind, (int.from_bytes(body[:8], "little"), body[8:])
    if kind == "write_block":
        assert len(body) >= 12
        return kind, (int.from_bytes(body[:8], "little"),
                      int.from_bytes(body[8:12], "little"), body[12:])
    if kind == "remove_page":
        assert len(body) == 8
        return kind, int.from_bytes(body, "little")
    if kind == "publish_codec":
        return kind, body
    assert len(body) == 4
    return kind, int.from_bytes(body, "little")


def wal_file(records):
    """Frame records (`len u32 | crc u32 | payload`) behind the magic."""
    out = bytearray(WAL_MAGIC)
    for kind, body in records:
        payload = wal_record(kind, body)
        assert wal_record(*wal_decode_record(payload)) == payload, \
            f"WAL {kind} decode/re-encode drift"
        out += u32le(len(payload)) + u32le(crc32(payload)) + payload
    return bytes(out)


def wal_split(stream):
    assert stream[:4] == WAL_MAGIC, "WAL magic missing"
    out, pos = [], 4
    while pos < len(stream):
        n = int.from_bytes(stream[pos:pos + 4], "little")
        crc = int.from_bytes(stream[pos + 4:pos + 8], "little")
        payload = stream[pos + 8:pos + 8 + n]
        assert len(payload) == n, "torn WAL record"
        assert crc32(payload) == crc, "WAL record CRC mismatch"
        out.append(wal_decode_record(payload))
        pos += 8 + n
    return out


def segment_file(entries):
    out = bytearray(SEGMENT_MAGIC)
    for page_id, container in entries:
        out += u64le(page_id) + u32le(len(container)) + u32le(crc32(container))
        out += bytes(container)
    return bytes(out)


def segment_split(stream):
    assert stream[:4] == SEGMENT_MAGIC, "segment magic missing"
    out, pos = [], 4
    while pos < len(stream):
        page_id = int.from_bytes(stream[pos:pos + 8], "little")
        n = int.from_bytes(stream[pos + 8:pos + 12], "little")
        crc = int.from_bytes(stream[pos + 12:pos + 16], "little")
        container = stream[pos + 16:pos + 16 + n]
        assert len(container) == n, "torn segment entry"
        assert crc32(container) == crc, "segment entry CRC mismatch"
        out.append((page_id, container))
        pos += 16 + n
    return out


def manifest_file(epoch, shard_count, codecs):
    out = bytearray(MANIFEST_MAGIC)
    out.append(MANIFEST_VERSION)
    out += u64le(epoch) + u32le(shard_count) + u32le(len(codecs))
    for snapshot in codecs:
        out += u32le(len(snapshot)) + bytes(snapshot)
    out += u32le(crc32(out))
    return bytes(out)


def manifest_decode(data):
    body, crc = data[:-4], int.from_bytes(data[-4:], "little")
    assert crc32(body) == crc, "manifest CRC mismatch"
    assert body[:4] == MANIFEST_MAGIC and body[4] == MANIFEST_VERSION
    epoch = int.from_bytes(body[5:13], "little")
    shard_count = int.from_bytes(body[13:17], "little")
    n = int.from_bytes(body[17:21], "little")
    codecs, at = [], 21
    for _ in range(n):
        ln = int.from_bytes(body[at:at + 4], "little")
        at += 4
        codecs.append(body[at:at + ln])
        at += ln
    assert at == len(body), "trailing bytes in manifest"
    return epoch, shard_count, codecs


def build_persist_fixtures():
    # a real page container + the zero-image codec snapshot form, built
    # by the same independent GBDI encoder the .gbc fixtures use
    entries = table_entries([(1000, 8), (1 << 20, 16)])
    image = gbdi_mixed_image()
    payload, block_bits = compress_image(
        lambda b, w: gbdi_encode_block(entries, b, w), image)
    verify(lambda r, n: gbdi_decode_block(entries, r, n), payload, block_bits, image)
    page = container_bytes(1, gbdi_config_bytes(), table_bytes(entries, 7),
                           len(image), block_bits, payload)
    snapshot = container_bytes(1, gbdi_config_bytes(), table_bytes(entries, 7),
                               0, [], b"")

    # frozen record sequence: one of each tag, in tag order. Touch ONLY
    # with a new WAL magic — rust/tests/golden_persist.rs builds the
    # identical list in Rust and the checked-in bytes must match both.
    records = [
        ("put_page", (0x0102030405060708, page)),
        ("write_block", (0x0102030405060708, 5,
                         bytes((3 * i + 1) & 0xFF for i in range(64)))),
        ("remove_page", 42),
        ("publish_codec", snapshot),
        ("resize", 6),
    ]
    wal = wal_file(records)
    assert len(wal_split(wal)) == len(records)

    seg_entries = [(0x0102030405060708, page), (7, snapshot), (MASK64, b"")]
    seg = segment_file(seg_entries)
    assert segment_split(seg) == [(i, bytes(c)) for i, c in seg_entries]

    man = manifest_file(9, 4, [snapshot])
    assert manifest_decode(man) == (9, 4, [snapshot])

    return [
        ("persist_wal.gbw", wal),
        ("persist_segment.gbs", seg),
        ("persist_manifest.gbm", man),
    ]


# ---- assembly + self-verification ---------------------------------------

def verify(decode_block, payload, block_bits, image, block_bytes=64):
    """Decode the payload per block and check bytes + per-block framing."""
    r = BitReader(payload)
    off = 0
    for i, bits in enumerate(block_bits):
        before = r.pos
        out_len = min(block_bytes, len(image) - off)
        got = decode_block(r, out_len)
        assert got == image[off:off + out_len], f"block {i} decode mismatch"
        assert r.pos - before == bits, f"block {i}: consumed {r.pos - before}, framed {bits}"
        off += out_len
    assert off == len(image)
    assert len(payload) == (sum(block_bits) + 7) // 8, "payload length vs framing"


def build_gbdi(name, pairs, version, image):
    entries = table_entries(pairs)
    payload, block_bits = compress_image(
        lambda b, w: gbdi_encode_block(entries, b, w), image)
    verify(lambda r, n: gbdi_decode_block(entries, r, n), payload, block_bits, image)
    return name, container_bytes(
        1, gbdi_config_bytes(), table_bytes(entries, version),
        len(image), block_bits, payload)


def main():
    import argparse

    ap = argparse.ArgumentParser(
        description="Generate + cross-verify the golden wire fixtures "
                    "under rust/tests/golden/ (overwrites them).")
    ap.add_argument("--check", action="store_true",
                    help="verify the checked-in fixtures match instead of rewriting them")
    args = ap.parse_args()

    fixtures = [
        build_gbdi("gbdi_mixed.gbc", [(1000, 8), (1 << 20, 16)], 7, gbdi_mixed_image()),
        build_gbdi("gbdi_ragged.gbc", [(1000, 8), (1 << 20, 16)], 7, gbdi_ragged_image()),
        build_gbdi("gbdi_allraw.gbc", [(0, 8)], 3, gbdi_allraw_image()),
    ]
    # the all-raw case's premise: every block fell back to RAW
    image = gbdi_allraw_image()
    entries = table_entries([(0, 8)])
    _, bits = compress_image(lambda b, w: gbdi_encode_block(entries, b, w), image)
    assert all(b == 2 + 512 for b in bits), f"all-raw fixture not all raw: {bits}"

    image = bdi_image()
    payload, block_bits = compress_image(bdi_encode_block, image)
    verify(bdi_decode_block, payload, block_bits, image)
    fixtures.append(("bdi.gbc", container_bytes(
        2, (64).to_bytes(4, "little"), None, len(image), block_bits, payload)))
    # coverage premise: the six intended encodings, in order
    r = BitReader(payload)
    seen = []
    for b in block_bits:
        at = r.pos
        seen.append(r.get(4))
        r.pos = at + b
    assert seen == [0, 1, 2, 6, 8, 3], f"bdi block encodings moved: {seen}"

    image = fpc_image()
    payload, block_bits = compress_image(fpc_encode_block, image)
    verify(fpc_decode_block, payload, block_bits, image)
    fixtures.append(("fpc.gbc", container_bytes(
        3, (64).to_bytes(4, "little"), None, len(image), block_bits, payload)))

    fixtures.extend(build_gbn1_fixtures())
    fixtures.extend(build_persist_fixtures())

    if args.check:
        bad = 0
        for name, data in fixtures:
            path = os.path.join(OUT_DIR, name)
            try:
                with open(path, "rb") as f:
                    on_disk = f.read()
            except FileNotFoundError:
                print(f"MISSING {path}")
                bad += 1
                continue
            if on_disk == data:
                print(f"ok {path} ({len(data)} bytes)")
            else:
                print(f"MISMATCH {path}: {len(on_disk)} bytes on disk, {len(data)} generated")
                bad += 1
        return 1 if bad else 0

    os.makedirs(OUT_DIR, exist_ok=True)
    for name, data in fixtures:
        path = os.path.join(OUT_DIR, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"wrote {path} ({len(data)} bytes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
