#!/usr/bin/env python3
"""Promote a CI bench artifact to the committed regression baseline.

Usage:
    bless_bench_baseline.py ARTIFACT.json BASELINE.json [--if-needed]

Copies ARTIFACT.json (a `BENCH_<name>.json` produced by a real bench
run) over BASELINE.json, stripping any `provisional` marker so the
regression gate (scripts/check_bench_regression.py) arms itself. The
`bless-baseline` CI job runs this with --if-needed on every main push:
it promotes the fresh artifact only while the committed baseline is
still the provisional bootstrap placeholder, so an armed baseline is
never silently overwritten by a faster/slower runner.

Refuses to bless artifacts that would leave the gate toothless:

  * no throughput results (an empty baseline gates nothing);
  * no `tags.isa` (the gate needs the environment tag to refuse
    cross-ISA comparisons).
"""

import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact")
    ap.add_argument("baseline")
    ap.add_argument("--if-needed", action="store_true",
                    help="only bless when the existing baseline is missing "
                         "or provisional; exit 0 without writing otherwise")
    args = ap.parse_args()

    try:
        with open(args.artifact, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read artifact {args.artifact!r}: {e}",
              file=sys.stderr)
        return 2

    throughput = [r for r in doc.get("results", [])
                  if r.get("mib_per_s") is not None]
    if not throughput:
        print("error: artifact carries no throughput results; refusing to "
              "bless an empty baseline", file=sys.stderr)
        return 2
    if not (doc.get("tags") or {}).get("isa"):
        print("error: artifact has no tags.isa environment tag; run a bench "
              "build that records it before blessing", file=sys.stderr)
        return 2

    if args.if_needed and os.path.exists(args.baseline):
        try:
            with open(args.baseline, encoding="utf-8") as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = None  # unreadable baseline: re-bless
        if existing is not None and not existing.get("provisional"):
            print(f"baseline {args.baseline!r} is already armed; "
                  "nothing to do (--if-needed)")
            return 0

    doc.pop("provisional", None)
    doc.pop("note", None)
    os.makedirs(os.path.dirname(os.path.abspath(args.baseline)), exist_ok=True)
    with open(args.baseline, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"blessed {args.artifact} -> {args.baseline} "
          f"({len(throughput)} gated results, "
          f"isa={doc['tags']['isa']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
