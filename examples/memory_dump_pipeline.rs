//! End-to-end driver (DESIGN.md deliverable): the paper's full
//! methodology on all nine workloads —
//!
//!   synthesize memory dump → write ELF core file → parse it back →
//!   background analysis → compress → decompress → verify bit-exactness →
//!   report per-workload ratios and the paper's group means (Figure 1).
//!
//! ```bash
//! cargo run --release --example memory_dump_pipeline
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E1.

use gbdi::baselines::{ratio_of, Codec, GbdiWholeImage};
use gbdi::report::{bar_chart, fmt_bytes, fmt_ratio, Table};
use gbdi::{elf, workloads};
use std::time::Instant;

const IMAGE_BYTES: usize = 8 << 20; // 8 MiB per workload dump
const SEED: u64 = 7;

fn main() {
    let tmp = std::env::temp_dir().join("gbdi_dumps");
    std::fs::create_dir_all(&tmp).expect("mkdir");
    let gbdi = GbdiWholeImage::default();

    let mut chart = Vec::new();
    let mut c_ratios = Vec::new();
    let mut j_ratios = Vec::new();
    let mut table = Table::new(&[
        "workload", "group", "dump size", "ratio", "compress MiB/s", "decompress MiB/s", "exact",
    ]);

    for w in workloads::all() {
        // 1. synthesize + write an ELF core dump (the paper's input format)
        let image = w.generate(IMAGE_BYTES, SEED);
        let path = tmp.join(format!("{}.dump", w.name()));
        let file = elf::write_core(&[elf::Segment { vaddr: 0x7F00_0000_0000, flags: 6, data: image }]);
        std::fs::write(&path, &file).expect("write dump");

        // 2. parse it back like the paper's pipeline
        let raw = std::fs::read(&path).expect("read dump");
        let dump = elf::parse(&raw).expect("parse ELF");
        let image = dump.flatten();

        // 3. compress / 4. decompress / 5. verify
        let t0 = Instant::now();
        let comp = gbdi.compress(&image);
        let t_c = t0.elapsed();
        let t0 = Instant::now();
        let restored = gbdi.decompress(&comp, image.len()).expect("decompress");
        let t_d = t0.elapsed();
        let exact = restored == image;
        assert!(exact, "{}: reconstruction mismatch", w.name());

        let ratio = image.len() as f64 / comp.len() as f64;
        let mibs = image.len() as f64 / (1 << 20) as f64;
        table.row(&[
            w.name().to_string(),
            w.group().label().to_string(),
            fmt_bytes(file.len() as u64),
            fmt_ratio(ratio),
            format!("{:.0}", mibs / t_c.as_secs_f64()),
            format!("{:.0}", mibs / t_d.as_secs_f64()),
            "yes".into(),
        ]);
        chart.push((w.name().to_string(), ratio));
        if w.group().is_c_family() {
            c_ratios.push(ratio);
        } else {
            j_ratios.push(ratio);
        }

        // sanity cross-check against whole-image API
        debug_assert!((ratio_of(&gbdi, &image) - ratio).abs() < 1e-9);
    }

    print!("{}", table.render());
    println!();
    println!("{}", bar_chart("Figure 1 — GBDI compression ratio per workload", &chart, 48));
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let all: Vec<f64> = chart.iter().map(|(_, r)| *r).collect();
    println!(
        "C-workloads mean {} (paper: 1.4x) | Java mean {} (paper: 1.55x) | overall {} (paper: 1.45x)",
        fmt_ratio(mean(&c_ratios)),
        fmt_ratio(mean(&j_ratios)),
        fmt_ratio(mean(&all)),
    );
    assert!(mean(&j_ratios) > mean(&c_ratios), "paper's Java > C ordering must hold");
    println!("\nend-to-end pipeline: all nine workloads BIT-EXACT");
}
