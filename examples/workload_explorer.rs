//! Workload explorer: per-workload memory-structure statistics and the
//! full codec comparison table (E3) — GBDI vs BDI vs FPC vs LZSS vs
//! Huffman vs gzip vs zstd.
//!
//! ```bash
//! cargo run --release --example workload_explorer
//! ```

use gbdi::baselines::{all_codecs, ratio_of};
use gbdi::report::Table;
use gbdi::util::stats::byte_entropy;
use gbdi::value::{words, WordSize};
use gbdi::workloads;
use std::collections::BTreeSet;

const IMAGE_BYTES: usize = 2 << 20;

fn main() {
    // --- structure table -------------------------------------------------
    let mut t = Table::new(&["workload", "entropy b/B", "zero words %", "distinct hi16 %"]);
    for w in workloads::all() {
        let img = w.generate(IMAGE_BYTES, 7);
        let total = img.len() / 4;
        let zeros = words(&img, WordSize::W32).filter(|&v| v == 0).count();
        let his: BTreeSet<u16> = words(&img, WordSize::W32).map(|v| (v >> 16) as u16).collect();
        t.row(&[
            w.name().to_string(),
            format!("{:.2}", byte_entropy(&img)),
            format!("{:.1}", 100.0 * zeros as f64 / total as f64),
            format!("{:.2}", 100.0 * his.len() as f64 / total as f64),
        ]);
    }
    println!("memory-structure profile ({} per workload):", IMAGE_BYTES >> 20);
    print!("{}", t.render());

    // --- codec comparison (E3) -------------------------------------------
    let codecs = all_codecs();
    let mut header: Vec<&str> = vec!["workload"];
    let names: Vec<&'static str> = codecs.iter().map(|c| c.name()).collect();
    header.extend(names.iter());
    let mut t = Table::new(&header);
    let mut sums = vec![0.0; codecs.len()];
    for w in workloads::all() {
        let img = w.generate(IMAGE_BYTES, 7);
        let mut row = vec![w.name().to_string()];
        for (i, c) in codecs.iter().enumerate() {
            let r = ratio_of(c.as_ref(), &img);
            sums[i] += r;
            row.push(format!("{r:.3}"));
        }
        t.row(&row);
    }
    let mut mean_row = vec!["MEAN".to_string()];
    for s in &sums {
        mean_row.push(format!("{:.3}", s / 9.0));
    }
    t.row(&mean_row);
    println!("\ncompression ratios, all codecs (E3):");
    print!("{}", t.render());
    println!("\nnote: gzip/zstd buy ratio with orders-of-magnitude more latency —");
    println!("see `cargo bench --bench throughput` for the speed column.");
}
