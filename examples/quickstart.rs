//! Quickstart: the five-line GBDI story — generate a workload image, run
//! background analysis, compress, decompress, check bit-exactness.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::report::fmt_ratio;
use gbdi::workloads;

fn main() {
    // 4 MiB of mcf-like memory content (pointer graph + small ints).
    let image = workloads::by_name("mcf").unwrap().generate(4 << 20, 7);

    // 1. Background data analysis: sample, cluster (modified k-means),
    //    pair each global base with a max-delta width class.
    let config = GbdiConfig::default();
    let table = analyze::analyze_image(&image, &config);
    println!("analysis found {} global bases:", table.len());
    for e in table.entries().iter().take(8) {
        println!("  base {:#010x}  max-delta class {:>2} bits", e.base, e.width);
    }

    // 2. Compress.
    let codec = GbdiCodec::new(table, config);
    let (compressed, stats) = codec.compress_image_stats(&image);
    println!(
        "\ncompressed {} KiB -> {} KiB  ratio {}",
        image.len() / 1024,
        compressed.total_len() / 1024,
        fmt_ratio(compressed.ratio())
    );
    println!(
        "blocks: {} gbdi, {} zero, {} rep, {} raw; outliers {:.2}%",
        stats.gbdi_blocks,
        stats.zero_blocks,
        stats.rep_blocks,
        stats.raw_blocks,
        stats.outlier_frac() * 100.0
    );

    // 3. Decompress and verify (always bit-exact).
    let restored = gbdi::gbdi::decode::decompress_image(&compressed).expect("decode");
    assert_eq!(restored, image);
    println!("\nreconstruction: BIT-EXACT");
}
