//! Quickstart: the GBDI story on the random-access surface — generate a
//! workload image, run background analysis, stream it through a
//! compression session, then serve single cache-line reads and writes
//! straight out of the compressed frame (no whole-image decode).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gbdi::report::fmt_ratio;
use gbdi::{workloads, BlockCodec, CodecKind, Compressor, GbdiConfig, Scratch};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    // 4 MiB of mcf-like memory content (pointer graph + small ints).
    let image = workloads::by_name("mcf").unwrap().generate(4 << 20, 7);

    // 1. Background data analysis: sample, cluster (modified k-means),
    //    pair each global base with a max-delta width class. CodecKind
    //    wraps that into a ready codec.
    let codec: Arc<dyn BlockCodec> =
        Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));

    // 2. Compress through a streaming session: chunked input, bounded
    //    memory (only one partial block is ever buffered).
    let mut session = Compressor::new(Arc::clone(&codec));
    for chunk in image.chunks(64 << 10) {
        session.write(chunk);
    }
    let mut frame = session.finish();
    println!(
        "compressed {} KiB -> {} KiB  ratio {}  ({} blocks indexed)",
        image.len() / 1024,
        frame.compressed_len() / 1024,
        fmt_ratio(image.len() as f64 / frame.compressed_len() as f64),
        frame.n_blocks()
    );

    // 3. Random access: single cache-line reads out of the compressed
    //    image — O(1) in the image size, zero allocations per read.
    let mut line = [0u8; 64];
    let t0 = Instant::now();
    let reads = 100_000usize;
    let mut checksum = 0u64;
    for i in 0..reads {
        let blk = (i * 2654435761) % frame.n_blocks(); // scattered probe
        frame.read_block(blk, &mut line).expect("read");
        checksum = checksum.wrapping_add(line[0] as u64);
    }
    let per_read = t0.elapsed().as_nanos() as f64 / reads as f64;
    let t0 = Instant::now();
    let full = frame.decompress().expect("decode");
    let full_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(full, image);
    println!(
        "read_block: {per_read:.0} ns/line (checksum {checksum}) vs whole-image decode {full_ms:.1} ms"
    );

    // 4. Writes recompress one line in place; growth spills to the
    //    frame's patch region instead of rewriting the image.
    let mut scratch = Scratch::new();
    let hot_line = [0xA5u8; 64];
    let wr = frame.write_block(123, &hot_line, &mut scratch).expect("write");
    println!(
        "write_block: {} bits re-encoded {}",
        wr.bits,
        if wr.spilled { "(spilled to patch region)" } else { "(in place)" }
    );
    frame.read_block(123, &mut line).expect("read back");
    assert_eq!(line, hot_line);

    // 5. Ship it: compaction folds the patch region back into the
    //    canonical container format, bit-exact.
    let container = frame.to_container();
    let restored = container.decompress().expect("decode");
    assert_eq!(&restored[123 * 64..124 * 64], &hot_line[..]);
    println!("\nreconstruction after random writes: BIT-EXACT");
}
