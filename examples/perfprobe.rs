//! Perf probe: best-of-5 codec throughput on three representative
//! workloads — the §Perf measurement tool (EXPERIMENTS.md). Best-of-N
//! approximates the unloaded machine on a noisy shared testbed.
//!
//! ```bash
//! cargo run --release --example perfprobe
//! ```

use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::workloads;
use std::time::Instant;
fn main() {
    let cfg = GbdiConfig::default();
    for name in ["mcf", "triangle_count", "deepsjeng"] {
        let img = workloads::by_name(name).unwrap().generate(4 << 20, 7);
        let table = analyze::analyze_image(&img, &cfg);
        let codec = GbdiCodec::new(table, cfg.clone());
        // best-of-5: the shared testbed is noisy; best approximates the
        // unloaded machine
        let mut c_best = f64::MAX;
        let mut comp = None;
        for _ in 0..5 {
            let t0 = Instant::now();
            comp = Some(codec.compress_image(&img));
            c_best = c_best.min(t0.elapsed().as_secs_f64());
        }
        let c_mibs = 4.0 / c_best;
        let comp = comp.unwrap();
        let mut d_best = f64::MAX;
        for _ in 0..5 {
            let t0 = Instant::now();
            gbdi::gbdi::decode::decompress_image(&comp).unwrap();
            d_best = d_best.min(t0.elapsed().as_secs_f64());
        }
        let d_mibs = 4.0 / d_best;
        println!("{name:<16} compress {c_mibs:7.1} MiB/s  decompress {d_mibs:7.1} MiB/s  (best of 5)");
    }
}
