//! Coordinator demo: stream pages from a mixed workload through the
//! compression service while the background analyzer re-derives the
//! global base table from sampled traffic (through the AOT JAX/Pallas
//! k-means artifact when `artifacts/` exists, else the mini-batch
//! warm-start selector), migrate old pages forward — then serve
//! **single cache-line GETs and PUTs straight out of the compressed
//! frames** (no whole-page decode) and report per-request latency, the
//! access pattern a CXL-expansion deployment actually sees.
//!
//! ```bash
//! make artifacts && cargo run --release --example compression_server
//! ```

use gbdi::cluster::{ArtifactSelector, BaseSelector, MiniBatchSelector};
use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::report::{fmt_bytes, fmt_ratio};
use gbdi::runtime::ArtifactRuntime;
use gbdi::util::prng::Rng;
use gbdi::workloads;
use std::sync::Arc;

const PAGES: u64 = 768;

/// Wait (bounded) for the analyzer to publish at least `version`.
fn wait_for_version(svc: &CompressionService, version: u64) {
    for _ in 0..600 {
        if svc.current_version() >= version {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

fn main() {
    let selector: Box<dyn BaseSelector> = match ArtifactRuntime::new(ArtifactRuntime::default_dir())
    {
        Ok(rt) if rt.has_artifact("kmeans_k64") => {
            println!("analyzer selector: AOT JAX/Pallas artifact via PJRT ({})", rt.platform());
            Box::new(ArtifactSelector::new(Arc::new(rt)))
        }
        _ => {
            println!("analyzer selector: mini-batch warm start (run `make artifacts` for PJRT)");
            Box::new(MiniBatchSelector)
        }
    };

    let svc = CompressionService::start_with_selector(
        ServiceConfig { workers: 4, analyze_every: 96, ..Default::default() },
        selector,
    )
    .expect("service start");

    // phase 1: pointer-heavy C workloads
    let mut rng = Rng::new(42);
    let phase1 = ["mcf", "perlbench", "omnetpp"];
    for i in 0..PAGES / 2 {
        let w = workloads::by_name(phase1[rng.below(3) as usize]).unwrap();
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    svc.request_analysis();
    wait_for_version(&svc, 1);
    let snap = svc.metrics();
    println!(
        "phase 1 (C mix):    {:>4} pages  ratio {}  table v{}  analyses {}",
        snap.pages_in,
        fmt_ratio(snap.ratio()),
        svc.current_version(),
        snap.analyses
    );

    // phase 2: traffic shifts to JVM workloads — the analyzer should
    // re-cluster and swap the table
    let phase2 = ["triangle_count", "svm", "matrix_factorization"];
    for i in PAGES / 2..PAGES {
        let w = workloads::by_name(phase2[rng.below(3) as usize]).unwrap();
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    let v = svc.current_version();
    svc.request_analysis();
    wait_for_version(&svc, v + 1);

    // migrate lagging pages to the newest table
    let mut migrated = 0;
    loop {
        let n = svc.recompress_step().expect("recompress");
        migrated += n;
        if n == 0 {
            break;
        }
    }

    // verify a sample of pages decompress bit-exactly after all of that
    let mut checked = 0;
    for i in (0..PAGES).step_by(37) {
        let data = svc.read_page(i).expect("read");
        assert_eq!(data.len(), 4096);
        checked += 1;
    }

    // block-granular serving: random single-line GETs hit the frames'
    // O(1) index (no page decode), PUTs recompress one line in place
    let mut line = [0u8; 64];
    for _ in 0..20_000 {
        let pid = rng.below(PAGES);
        let blk = rng.below(64) as usize;
        svc.read_block(pid, blk, &mut line).expect("block GET");
    }
    for i in 0..256u64 {
        let pid = rng.below(PAGES);
        svc.write_block(pid, (i % 64) as usize, &line).expect("block PUT");
    }

    let (logical, stored, ratio) = svc.storage_ratio();
    let snap = svc.shutdown();
    println!(
        "phase 2 (JVM mix):  {:>4} pages  ratio {}  analyses {}  swaps {}",
        snap.pages_in,
        fmt_ratio(snap.ratio()),
        snap.analyses,
        snap.table_swaps
    );
    println!(
        "store: {} logical -> {} stored ({})  migrated {}  spot-checked {} pages OK",
        fmt_bytes(logical as u64),
        fmt_bytes(stored as u64),
        fmt_ratio(ratio),
        migrated,
        checked
    );
    println!(
        "throughput: {:.0} MiB/s across workers  ({} reads failed)",
        snap.compress_mib_s(),
        snap.read_errors
    );
    println!(
        "block serving: {} GETs @ {:.0} ns mean  {} PUTs @ {:.0} ns mean (straight from compressed frames)",
        snap.block_reads,
        snap.block_read_mean_ns(),
        snap.block_writes,
        snap.block_write_mean_ns()
    );
}
