"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps vary shapes, magnitudes, and centroid placement —
the CORE correctness signal for the analysis plane (DESIGN.md deliverable
c): if these pass, the AOT artifacts compute what the Rust fallback
computes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kmeans_pallas, ref, size_pallas

TN = kmeans_pallas.TN


def _values(rng, n, spread):
    """Memory-word-like f32 values: clustered mixture + uniform noise."""
    centers = rng.uniform(0, 2**31, size=4)
    vals = np.where(
        rng.uniform(size=n) < 0.8,
        rng.choice(centers, size=n) + rng.uniform(-spread, spread, size=n),
        rng.uniform(0, 2**32 - 1, size=n),
    )
    return jnp.asarray(np.clip(vals, 0, 2**32 - 1), dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    k=st.sampled_from([4, 8, 16, 64]),
    spread=st.sampled_from([10.0, 1e4, 1e7]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_assign_matches_ref(n_tiles, k, spread, seed):
    rng = np.random.RandomState(seed)
    x = _values(rng, n_tiles * TN, spread)
    c = jnp.asarray(rng.uniform(0, 2**31, size=k), dtype=jnp.float32)
    onehot, cost = kmeans_pallas.assign(x, c)
    onehot_r, cost_r = ref.assign_ref(x, c)
    np.testing.assert_allclose(onehot, onehot_r)
    np.testing.assert_allclose(cost, cost_r)
    # invariants: exactly one base per sample; costs from the class menu
    np.testing.assert_allclose(np.asarray(onehot).sum(axis=1), 1.0)
    menu = set(float(c) for c in ref.DEFAULT_CLASSES) | {ref.OUTLIER_BITS}
    assert set(np.unique(np.asarray(cost))) <= menu


@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    k=st.sampled_from([4, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_update_matches_ref(n_tiles, k, seed):
    rng = np.random.RandomState(seed)
    n = n_tiles * TN
    x = jnp.asarray(rng.uniform(0, 2**31, size=n), dtype=jnp.float32)
    best = rng.randint(0, k, size=n)
    onehot = jnp.asarray(np.eye(k, dtype=np.float32)[best])
    sums, counts = kmeans_pallas.update(x, onehot)
    sums_r, counts_r = ref.update_ref(x, onehot)
    np.testing.assert_allclose(sums, sums_r, rtol=1e-6)
    np.testing.assert_allclose(counts, counts_r)
    assert float(jnp.sum(counts)) == n


@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    k=st.sampled_from([4, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_size_estimate_matches_ref(n_tiles, k, seed):
    rng = np.random.RandomState(seed)
    x = _values(rng, n_tiles * TN, 1e5)
    bases = jnp.asarray(rng.uniform(0, 2**31, size=k), dtype=jnp.float32)
    widths = jnp.asarray(rng.choice([0, 4, 8, 12, 16, 20, 24], size=k), dtype=jnp.float32)
    total, per_value = size_pallas.size_estimate(x, bases, widths)
    total_r, per_value_r = ref.size_estimate_ref(x, bases, widths)
    np.testing.assert_allclose(per_value, per_value_r)
    np.testing.assert_allclose(total, total_r, rtol=1e-6)


def test_assign_exact_hits_cost_zero():
    c = jnp.asarray([100.0, 5e8], dtype=jnp.float32)
    x = jnp.asarray([100.0] * TN, dtype=jnp.float32)
    onehot, cost = kmeans_pallas.assign(x, c)
    np.testing.assert_allclose(cost, 0.0)
    np.testing.assert_allclose(np.asarray(onehot)[:, 0], 1.0)


def test_assign_outliers_cost_outlier_bits():
    c = jnp.asarray([0.0], dtype=jnp.float32)
    x = jnp.asarray([2**31 * 1.0] * TN, dtype=jnp.float32)
    _, cost = kmeans_pallas.assign(x, c)
    np.testing.assert_allclose(cost, ref.OUTLIER_BITS)


def test_cost_class_boundaries():
    """Deltas at width-class edges land in the right class."""
    c = jnp.asarray([0.0], dtype=jnp.float32)
    # delta 7 needs 4 bits (class 4); delta 9 needs 5 (class 8);
    # delta 2047 needs 12; delta 2049 needs 13 -> class 16
    x = jnp.asarray([7.0, 9.0, 2047.0, 2049.0] * (TN // 4), dtype=jnp.float32)
    _, cost = kmeans_pallas.assign(x, c)
    got = np.asarray(cost[:4])
    np.testing.assert_allclose(got, [4.0, 8.0, 12.0, 16.0])


def test_assign_rejects_ragged_n():
    with pytest.raises(AssertionError):
        kmeans_pallas.assign(
            jnp.zeros(TN + 1, jnp.float32), jnp.zeros(4, jnp.float32)
        )
