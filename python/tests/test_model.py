"""L2 correctness: the full k-means analysis graph (model.kmeans_fit)
against the unrolled oracle, plus convergence behaviour on synthetic
mixtures — what the Rust coordinator relies on when it runs the artifact.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

N = 4096


def _mixture(rng, centers, spread, n=N):
    c = rng.choice(centers, size=n)
    return jnp.asarray(c + rng.uniform(-spread, spread, size=n), dtype=jnp.float32)


def test_kmeans_fit_matches_unrolled_ref():
    rng = np.random.RandomState(0)
    x = _mixture(rng, [1e4, 5e7, 3e9], 50.0)
    init = jnp.asarray(rng.uniform(0, 2**31, size=16), dtype=jnp.float32)
    c, counts, inertia = model.kmeans_fit(x, init, iters=4)
    c_r, counts_r, inertia_r = ref.kmeans_ref(x, init, iters=4)
    np.testing.assert_allclose(c, c_r, rtol=1e-5)
    np.testing.assert_allclose(counts, counts_r)
    np.testing.assert_allclose(inertia, inertia_r[None], rtol=1e-5)


def test_kmeans_recovers_separated_centers():
    rng = np.random.RandomState(1)
    true_centers = np.array([1e5, 8e7, 2.5e9])
    x = _mixture(rng, true_centers, 30.0)
    # init from data samples — the contract: the Rust coordinator seeds
    # centroids (k-means++ over its sample) before invoking the artifact
    init = jnp.asarray(rng.choice(np.asarray(x), size=16), dtype=jnp.float32)
    c, counts, _ = model.kmeans_fit(x, init)
    c = np.asarray(c)
    counts = np.asarray(counts)
    for t in true_centers:
        # some centroid with meaningful mass should sit near each center
        near = np.abs(c - t) < max(1e-4 * t, 200.0)
        assert (counts[near] > 100).any(), f"no populated centroid near {t}: {c}"


def test_kmeans_inertia_nonincreasing_with_iters():
    rng = np.random.RandomState(2)
    x = _mixture(rng, [3e3, 9e8], 1e4)
    init = jnp.asarray(rng.uniform(0, 2**31, size=16), dtype=jnp.float32)
    inertias = [float(model.kmeans_fit(x, init, iters=t)[2][0]) for t in (1, 4, 16)]
    assert inertias[0] >= inertias[1] - 1e-3, inertias
    assert inertias[1] >= inertias[2] - 1e-3, inertias


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), k=st.sampled_from([16, 64]))
def test_kmeans_counts_conserve_samples(seed, k):
    rng = np.random.RandomState(seed)
    x = _mixture(rng, rng.uniform(0, 2**31, size=5), 1e5)
    init = jnp.asarray(rng.uniform(0, 2**31, size=k), dtype=jnp.float32)
    _, counts, _ = model.kmeans_fit(x, init, iters=3)
    assert float(jnp.sum(counts)) == N


def test_size_fit_matches_ref():
    rng = np.random.RandomState(3)
    x = _mixture(rng, [5e6, 1e9], 1e3)
    bases = jnp.asarray(rng.uniform(0, 2**31, size=64), dtype=jnp.float32)
    widths = jnp.asarray(rng.choice([0, 4, 8, 16, 24], size=64), dtype=jnp.float32)
    total, per_value = model.size_fit(x, bases, widths)
    total_r, per_value_r = ref.size_estimate_ref(x, bases, widths)
    np.testing.assert_allclose(per_value, per_value_r)
    np.testing.assert_allclose(total, total_r[None], rtol=1e-6)


def test_size_fit_better_table_scores_lower():
    rng = np.random.RandomState(4)
    x = _mixture(rng, [7e5], 100.0)
    good = (jnp.asarray([7e5] + [0.0] * 7, jnp.float32), jnp.asarray([12.0] * 8, jnp.float32))
    bad = (jnp.asarray(rng.uniform(0, 2**31, size=8), jnp.float32), jnp.asarray([4.0] * 8, jnp.float32))
    t_good = float(model.size_fit(x, *good)[0][0])
    t_bad = float(model.size_fit(x, *bad)[0][0])
    assert t_good < t_bad
