"""AOT path: HLO-text emission is parseable-shaped, deterministic, and the
lowered computation executes (via jax) to the same numbers as the eager
path — the contract the Rust PJRT loader depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_hlo_text_emission_structure():
    text = aot.lower_kmeans(16)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # while-loop from fori_loop must be present (single fused loop)
    assert "while" in text
    assert "f32[4096,16]" in text  # the (N, K) one-hot tile
    t64 = aot.lower_kmeans(64)
    assert "f32[4096,64]" in t64


def test_hlo_emission_deterministic():
    assert aot.lower_sizeest(64) == aot.lower_sizeest(64)


def test_lowered_kmeans_executes_like_eager():
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.uniform(0, 2**31, size=aot.N_SAMPLES), dtype=jnp.float32)
    init = jnp.asarray(rng.uniform(0, 2**31, size=16), dtype=jnp.float32)
    lowered = jax.jit(lambda a, b: model.kmeans_fit(a, b)).lower(
        jax.ShapeDtypeStruct(x.shape, x.dtype), jax.ShapeDtypeStruct(init.shape, init.dtype)
    )
    compiled = lowered.compile()
    c_aot, counts_aot, inertia_aot = compiled(x, init)
    c, counts, inertia = model.kmeans_fit(x, init)
    np.testing.assert_allclose(c_aot, c, rtol=1e-6)
    np.testing.assert_allclose(counts_aot, counts)
    np.testing.assert_allclose(inertia_aot, inertia, rtol=1e-6)


def test_manifest_mentions_all_artifacts(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=str(aot.__file__).rsplit("/compile/", 1)[0],
    )
    names = {p.name for p in out.iterdir()}
    assert {"kmeans_k16.hlo.txt", "kmeans_k64.hlo.txt", "sizeest_k64.hlo.txt", "manifest.txt"} <= names
    manifest = (out / "manifest.txt").read_text()
    for n in ("kmeans_k16", "kmeans_k64", "sizeest_k64"):
        assert n in manifest
