"""AOT lowering: JAX/Pallas analysis graphs → HLO *text* artifacts the
Rust runtime loads through the PJRT C API.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids, which the image's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
Emits:
  kmeans_k16.hlo.txt   kmeans_fit for N=4096, K=16
  kmeans_k64.hlo.txt   kmeans_fit for N=4096, K=64
  sizeest_k64.hlo.txt  size_fit  for N=4096, K=64
  manifest.txt         shapes + seeds for the Rust loader to validate
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

N_SAMPLES = 4096
KMEANS_KS = (16, 64)
SIZEEST_KS = (64,)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (0.5.1-parseable)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kmeans(k: int) -> str:
    spec_x = jax.ShapeDtypeStruct((N_SAMPLES,), jnp.float32)
    spec_c = jax.ShapeDtypeStruct((k,), jnp.float32)
    lowered = jax.jit(lambda x, c: model.kmeans_fit(x, c)).lower(spec_x, spec_c)
    return to_hlo_text(lowered)


def lower_sizeest(k: int) -> str:
    spec_x = jax.ShapeDtypeStruct((N_SAMPLES,), jnp.float32)
    spec_k = jax.ShapeDtypeStruct((k,), jnp.float32)
    lowered = jax.jit(model.size_fit).lower(spec_x, spec_k, spec_k)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = [f"n_samples={N_SAMPLES}", f"iters={model.ITERS}"]
    for k in KMEANS_KS:
        path = os.path.join(args.out_dir, f"kmeans_k{k}.hlo.txt")
        text = lower_kmeans(k)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"kmeans_k{k}.hlo.txt k={k} inputs=x[{N_SAMPLES}]f32,c[{k}]f32 "
                        f"outputs=centroids[{k}],counts[{k}],inertia[1]")
        print(f"wrote {path} ({len(text)} chars)")
    for k in SIZEEST_KS:
        path = os.path.join(args.out_dir, f"sizeest_k{k}.hlo.txt")
        text = lower_sizeest(k)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"sizeest_k{k}.hlo.txt k={k} inputs=x[{N_SAMPLES}]f32,b[{k}]f32,w[{k}]f32 "
                        f"outputs=total[1],per_value[{N_SAMPLES}]")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("manifest written")


if __name__ == "__main__":
    main()
