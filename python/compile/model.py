"""Layer-2 JAX analysis graphs: the full background-data-analysis loop
(the paper's §II.B "establishing global base values"), built on the L1
Pallas kernels and AOT-lowered by ``aot.py``.

Exports two jit-able functions with fixed shapes per artifact:

* ``kmeans_fit(samples f32[N], init f32[K]) -> (centroids f32[K],
  counts f32[K], inertia f32[1])`` — T iterations of bit-cost Lloyd.
* ``size_fit(samples f32[N], bases f32[K], widths f32[K]) ->
  (total_bits f32[1], per_value f32[N])``.

The iteration loop is a ``lax.fori_loop`` whose carry is only the (K,)
centroid vector — no per-iteration recomputation is kept live, so the
lowered HLO has a single while-loop with the two kernels fused inside
(L2 perf requirement from DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import kmeans_pallas, size_pallas
from .kernels.ref import DEFAULT_CLASSES

ITERS = 16


@functools.partial(jax.jit, static_argnames=("iters",))
def kmeans_fit(samples, init_centroids, iters=ITERS):
    """T iterations of modified (bit-cost) k-means over the samples."""

    def body(_, c):
        onehot, _cost = kmeans_pallas.assign(samples, c, DEFAULT_CLASSES)
        sums, counts = kmeans_pallas.update(samples, onehot)
        return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c)

    c = jax.lax.fori_loop(0, iters, body, init_centroids)
    onehot, cost = kmeans_pallas.assign(samples, c, DEFAULT_CLASSES)
    _, counts = kmeans_pallas.update(samples, onehot)
    return c, counts, cost.sum()[None]


@jax.jit
def size_fit(samples, bases, widths):
    """Compressed-size estimate of ``samples`` under a candidate table."""
    total, per_value = size_pallas.size_estimate(samples, bases, widths)
    return total[None], per_value
