"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness ground
truth). Everything here is straight-line jax.numpy with no pallas — the
pytest suite asserts the kernels match these bit-for-bit (same dtype, same
reduction order up to allclose tolerance).

Value domain: memory words are brought into f32 (the TPU-side analysis
works on approximate magnitudes; the Rust L3 snaps centroids back to exact
integers and re-derives exact width classes, so f32 rounding here cannot
affect codec correctness — see DESIGN.md §5).
"""

from __future__ import annotations

import jax.numpy as jnp

# The codec's delta width-class menu (must match GbdiConfig::width_classes)
DEFAULT_CLASSES = (0, 4, 8, 12, 16, 20, 24)
# Cost charged when no class fits (word bits + escape overhead)
OUTLIER_BITS = 40.0


def needed_bits(delta):
    """Approximate signed offset-binary width of ``delta`` in f32 math.

    Mirrors rust ``signed_width``: 0 for 0; otherwise ~log2(|d|) + 2
    (exact for non-powers-of-two; ±1 bit near boundaries is acceptable —
    the L3 refit uses exact integer widths).
    """
    d = jnp.abs(delta)
    bits = jnp.floor(jnp.log2(jnp.maximum(d, 0.5))) + 2.0
    return jnp.where(d < 0.5, 0.0, bits)


def class_cost(delta, classes=DEFAULT_CLASSES):
    """Encoded-delta bits: the smallest width class that covers ``delta``,
    or OUTLIER_BITS when none does (the modified-k-means metric)."""
    need = needed_bits(delta)
    cost = jnp.full_like(need, OUTLIER_BITS)
    for c in reversed(classes):
        cost = jnp.where(need <= float(c), float(c), cost)
    return cost


def assign_ref(x, centroids, classes=DEFAULT_CLASSES):
    """Assignment step oracle.

    Args:
      x: f32[N] sample values.
      centroids: f32[K].
    Returns:
      (onehot f32[N, K], cost f32[N]) — the chosen-base one-hot matrix and
      the per-sample encoded-bit cost, with ties broken by |delta| then by
      lower index (matching the kernel).
    """
    delta = x[:, None] - centroids[None, :]  # (N, K)
    cost = class_cost(delta, classes)
    # two-stage tie-break (cost, then |delta|, then index), kept as separate
    # exact comparisons: a fused `cost*BIG + |delta|` key rounds differently
    # under XLA fusion (FMA) and flips argmin on near-ties
    min_cost = cost.min(axis=1, keepdims=True)
    key = jnp.where(cost == min_cost, jnp.abs(delta), jnp.inf)
    best = jnp.argmin(key, axis=1)
    onehot = (jnp.arange(centroids.shape[0])[None, :] == best[:, None]).astype(jnp.float32)
    return onehot, jnp.take_along_axis(cost, best[:, None], axis=1)[:, 0]


def update_ref(x, onehot):
    """Centroid update oracle: masked means via the one-hot matrix.

    Returns (sums f32[K], counts f32[K]).
    """
    sums = onehot.T @ x
    counts = onehot.sum(axis=0)
    return sums, counts


def size_estimate_ref(x, bases, widths, ptr_bits=7.0, word_bits=32.0):
    """Compressed-size estimator oracle.

    Each value pays ``ptr_bits`` plus the width of the cheapest base whose
    class covers its delta, or ``word_bits`` if none does (outlier).

    Returns (total_bits f32 scalar, per_value_bits f32[N]).
    """
    delta = x[:, None] - bases[None, :]
    need = needed_bits(delta)
    fits = need <= widths[None, :]
    delta_bits = jnp.where(fits, widths[None, :], jnp.inf).min(axis=1)
    per_value = ptr_bits + jnp.where(jnp.isinf(delta_bits), word_bits, delta_bits)
    return per_value.sum(), per_value


def kmeans_ref(x, init_centroids, iters=16, classes=DEFAULT_CLASSES):
    """Full Lloyd loop oracle (bit-cost metric, mean update).

    Returns (centroids f32[K], counts f32[K], inertia f32 scalar).
    """
    c = init_centroids
    for _ in range(iters):
        onehot, _ = assign_ref(x, c, classes)
        sums, counts = update_ref(x, onehot)
        c = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c)
    onehot, cost = assign_ref(x, c, classes)
    _, counts = update_ref(x, onehot)
    return c, counts, cost.sum()
