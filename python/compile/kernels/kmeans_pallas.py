"""Layer-1 Pallas kernels for GBDI background analysis.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the HPCA'22 design
does its data analysis in dedicated hardware next to the memory
controller; here the same computation is re-thought for a TPU core:

* ``assign`` — the (N, K) delta/cost tile lives in VMEM. The grid walks N
  in ``TN``-row tiles; K (≤ 64 bases) stays resident, so each grid step
  streams one sample tile HBM→VMEM and writes one one-hot tile back. The
  cost function is branch-free f32 select chains (VPU-friendly), not the
  scalar loop a CPU would use.
* ``update`` — centroid accumulation is expressed as ``onehot.T @ x``:
  a (K, N) × (N, 1) matmul that lands on the MXU systolic array instead
  of scatter-adds (which TPUs do badly). Counts ride along as
  ``onehot.T @ 1``.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness is what the AOT path needs
(see /opt/xla-example/README.md). Real-TPU tile-size/VMEM estimates are
recorded in DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_CLASSES, OUTLIER_BITS

# Rows of samples processed per grid step (fits (TN, K) f32 in VMEM with
# room for double-buffering: 512 × 64 × 4 B = 128 KiB per tile).
TN = 512


def _cost_from_delta(delta, classes):
    """Branch-free encoded-bits cost of a delta tile (f32)."""
    d = jnp.abs(delta)
    bits = jnp.floor(jnp.log2(jnp.maximum(d, 0.5))) + 2.0
    need = jnp.where(d < 0.5, 0.0, bits)
    cost = jnp.full_like(need, OUTLIER_BITS)
    for c in reversed(classes):
        cost = jnp.where(need <= float(c), float(c), cost)
    return cost


def _assign_kernel(x_ref, c_ref, onehot_ref, cost_ref, *, classes):
    """One grid step: (TN,) samples × (K,) centroids → one-hot + cost."""
    x = x_ref[...]  # (TN, 1)
    c = c_ref[...]  # (1, K)
    delta = x - c  # (TN, K) broadcast in VMEM
    cost = _cost_from_delta(delta, classes)
    # two-stage tie-break matching ref.assign_ref: exact comparisons only
    # (a fused arithmetic key is FMA/fusion-sensitive and flips near-ties)
    min_cost = jnp.min(cost, axis=1, keepdims=True)
    key = jnp.where(cost == min_cost, jnp.abs(delta), jnp.inf)
    best = jnp.argmin(key, axis=1)  # (TN,)
    k = c.shape[1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1) == best[:, None])
    onehot_ref[...] = onehot.astype(jnp.float32)
    cost_ref[...] = jnp.min(cost, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("classes",))
def assign(x, centroids, classes=DEFAULT_CLASSES):
    """Pallas assignment step.

    Args:
      x: f32[N] (N must be a multiple of TN).
      centroids: f32[K].
    Returns:
      (onehot f32[N, K], cost f32[N]).
    """
    n = x.shape[0]
    k = centroids.shape[0]
    assert n % TN == 0, f"N={n} must be a multiple of {TN}"
    onehot, cost = pl.pallas_call(
        functools.partial(_assign_kernel, classes=classes),
        grid=(n // TN,),
        in_specs=[
            pl.BlockSpec((TN, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TN, k), lambda i: (i, 0)),
            pl.BlockSpec((TN, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=True,
    )(x[:, None], centroids[None, :])
    return onehot, cost[:, 0]


def _update_kernel(onehot_ref, x_ref, sums_ref, counts_ref):
    """Single-block MXU step: sums = onehotᵀ @ x, counts = onehotᵀ @ 1."""
    onehot = onehot_ref[...]  # (N, K)
    x = x_ref[...]  # (N, 1)
    sums_ref[...] = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    ones = jnp.ones_like(x)
    counts_ref[...] = jnp.dot(onehot.T, ones, preferred_element_type=jnp.float32)


@jax.jit
def update(x, onehot):
    """Pallas centroid-update step (one MXU-shaped block).

    Args:
      x: f32[N]; onehot: f32[N, K].
    Returns:
      (sums f32[K], counts f32[K]).
    """
    n, k = onehot.shape
    sums, counts = pl.pallas_call(
        _update_kernel,
        in_specs=[
            pl.BlockSpec((n, k), lambda: (0, 0)),
            pl.BlockSpec((n, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, 1), lambda: (0, 0)),
            pl.BlockSpec((k, 1), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=True,
    )(onehot, x[:, None])
    return sums[:, 0], counts[:, 0]
