"""Layer-1 Pallas kernel: compressed-size estimation.

Given sampled word values and a candidate global-base table (bases +
per-base width classes), estimate the encoded bits per value — the
coordinator uses this (through the AOT artifact) to score a candidate
table against live traffic before swapping it in.

Same VMEM tiling story as the assignment kernel: (TN, K) delta tile per
grid step, K resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 512


def _size_kernel(x_ref, b_ref, w_ref, bits_ref, *, ptr_bits, word_bits):
    x = x_ref[...]  # (TN, 1)
    b = b_ref[...]  # (1, K)
    w = w_ref[...]  # (1, K)
    delta = jnp.abs(x - b)
    need = jnp.where(delta < 0.5, 0.0, jnp.floor(jnp.log2(jnp.maximum(delta, 0.5))) + 2.0)
    fits = need <= w
    delta_bits = jnp.min(jnp.where(fits, w, jnp.inf), axis=1, keepdims=True)
    per_value = ptr_bits + jnp.where(jnp.isinf(delta_bits), word_bits, delta_bits)
    bits_ref[...] = per_value


@functools.partial(jax.jit, static_argnames=("ptr_bits", "word_bits"))
def size_estimate(x, bases, widths, ptr_bits=7.0, word_bits=32.0):
    """Per-value and total encoded bits under a candidate table.

    Args:
      x: f32[N] (N multiple of TN); bases: f32[K]; widths: f32[K].
    Returns:
      (total_bits f32 scalar, per_value f32[N]).
    """
    n = x.shape[0]
    k = bases.shape[0]
    assert n % TN == 0, f"N={n} must be a multiple of {TN}"
    per_value = pl.pallas_call(
        functools.partial(_size_kernel, ptr_bits=ptr_bits, word_bits=word_bits),
        grid=(n // TN,),
        in_specs=[
            pl.BlockSpec((TN, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TN, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(x[:, None], bases[None, :], widths[None, :])
    per_value = per_value[:, 0]
    return per_value.sum(), per_value
