//! Durability overhead and recovery speed: what the WAL costs on the
//! ingest path as the group-commit window grows, and how fast a data
//! directory comes back.
//!
//! * **Ingest sweep** — pages ingested (parse GBC1 + `put`) into a plain
//!   in-memory store (`persist=off`, the PR-8 baseline) and into a
//!   `DurableStore` at `fsync_batch` ∈ {1, 8, 64}. Every durable put
//!   appends a `PutPage` WAL record; batch 1 fsyncs each append (full
//!   durability), larger batches amortize the sync (group commit). The
//!   WAL rolls over through `maybe_checkpoint`, so checkpoint cost is
//!   amortized into the numbers exactly as in production.
//! * **Recovery metrics** — wall time of `recover()` over the same page
//!   population held (a) entirely in the WAL and (b) folded into
//!   checkpoint segments, reported as `recover_*_ms` metrics.
//!
//! Emits `BENCH_durability.json` (tags: `isa`, `persist`) for
//! `scripts/check_bench_regression.py`; honours `GBDI_BENCH_FAST=1`.
//! Works in a private directory under the system temp dir and removes
//! it on exit.
//!
//! `cargo bench --bench durability`

use gbdi::container::Container;
use gbdi::coordinator::{ShardedPageStore, StoredPage};
use gbdi::persist::recover::recover;
use gbdi::persist::{DurableStore, PersistConfig, RealFs};
use gbdi::simd;
use gbdi::util::bench::Bencher;
use gbdi::{workloads, BlockCodec, CodecKind, Frame, GbdiConfig};
use std::sync::Arc;
use std::time::Instant;

const PAGE_BYTES: u64 = 4096;
const ID_SPACE: u64 = 512;
const SHARDS: usize = 4;

fn parse_page(bytes: &[u8]) -> StoredPage {
    let frame = Frame::from_container(Container::from_bytes(bytes).expect("bench container"))
        .expect("bench frame");
    StoredPage { frame }
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let root = std::env::temp_dir().join(format!("gbdi-bench-durability-{}", std::process::id()));
    let root = root.to_string_lossy().into_owned();

    let cfg = GbdiConfig::default();
    let image = workloads::by_name("mcf").unwrap().generate(PAGE_BYTES as usize, 42);
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));
    // one pre-serialized page: every arm pays the identical parse + put,
    // so the durable arms' delta is purely WAL append + fsync cadence
    let page_bytes = gbdi::container::compress(codec.as_ref(), &image).to_bytes();

    let mut b = Bencher::new();
    println!(
        "== durable ingest: {PAGE_BYTES}-byte pages over {ID_SPACE} ids, {SHARDS} shards ==\n"
    );

    // baseline: persistence off — the exact ingest path PR 8 shipped
    {
        let store = ShardedPageStore::new(SHARDS);
        store.publish_codec(Arc::clone(&codec));
        let mut i = 0u64;
        b.bench("ingest/persist=off", Some(PAGE_BYTES), || {
            store.put(i % ID_SPACE, parse_page(&page_bytes));
            i += 1;
        });
    }

    for &batch in &[1usize, 8, 64] {
        let dir = format!("{root}/batch{batch}");
        let pc = PersistConfig { fsync_batch: batch, wal_limit_bytes: 32 << 20 };
        let (ds, _) = DurableStore::open(Arc::new(RealFs), &dir, pc, SHARDS, 0)
            .expect("bench data dir must open");
        ds.publish_codec(Arc::clone(&codec)).expect("publish");
        let mut i = 0u64;
        b.bench(&format!("ingest/fsync_batch={batch}"), Some(PAGE_BYTES), || {
            ds.put(i % ID_SPACE, parse_page(&page_bytes)).expect("durable put");
            ds.maybe_checkpoint().expect("wal rollover");
            i += 1;
        });
        assert_eq!(ds.store().len(), ID_SPACE.min(i) as usize);
    }

    // recovery: the same population once WAL-resident, once checkpointed
    let n_pages: u64 = if fast { 256 } else { 2048 };
    let dir = format!("{root}/recover");
    {
        let pc = PersistConfig { fsync_batch: 64, wal_limit_bytes: u64::MAX };
        let (ds, _) = DurableStore::open(Arc::new(RealFs), &dir, pc, SHARDS, 0)
            .expect("recover data dir must open");
        ds.publish_codec(Arc::clone(&codec)).expect("publish");
        for id in 0..n_pages {
            ds.put(id, parse_page(&page_bytes)).expect("durable put");
        }
        // dropped here: all n_pages stay in the WAL behind an empty
        // checkpoint, so the next recovery is a pure WAL replay
    }
    let t0 = Instant::now();
    let (store, report) = recover(&RealFs, &dir, None, 0).expect("recover");
    let wal_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(store.len(), n_pages as usize);
    assert!(!report.saw_damage(), "bench directory must be clean");
    println!("\nrecover (WAL replay):      {n_pages} pages in {wal_ms:>8.2} ms");
    b.metric(&format!("recover_wal_ms/pages={n_pages}"), wal_ms);

    {
        // reopening folds the WAL into fresh segments + manifest
        let pc = PersistConfig::default();
        let (_ds, report) = DurableStore::open(Arc::new(RealFs), &dir, pc, SHARDS, 0)
            .expect("checkpointing reopen");
        assert!(!report.saw_damage());
    }
    let t0 = Instant::now();
    let (store, report) = recover(&RealFs, &dir, None, 0).expect("recover");
    let ckpt_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(store.len(), n_pages as usize);
    assert!(!report.saw_damage(), "checkpointed directory must be clean");
    println!("recover (checkpoint load): {n_pages} pages in {ckpt_ms:>8.2} ms");
    b.metric(&format!("recover_checkpoint_ms/pages={n_pages}"), ckpt_ms);

    // the fsync cadence and storage stack are part of the measurement
    // environment: never compare against a baseline from another setup
    b.tag("isa", simd::active().isa.name());
    b.tag("persist", "wal-fsync-sweep-1-8-64");

    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all("target").ok();
    b.write_csv("target/durability.csv").ok();
    println!("\ncsv: target/durability.csv");
    match b.write_bench_json("durability") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
