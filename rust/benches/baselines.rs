//! E3 — GBDI vs the baseline codecs the paper discusses: BDI (the
//! algorithm it extends), FPC, LZSS ("LZ compression"), Huffman coding,
//! gzip and zstd. Ratio per workload + speed on a representative image.
//!
//! `cargo bench --bench baselines`

use gbdi::baselines::{all_codecs, ratio_of};
use gbdi::report::Table;
use gbdi::util::bench::Bencher;
use gbdi::workloads;

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    let codecs = all_codecs();

    // --- ratio grid -------------------------------------------------------
    println!("== E3: compression ratio, all codecs x all workloads ({} KiB) ==\n", size >> 10);
    let mut header: Vec<&str> = vec!["workload"];
    let names: Vec<&'static str> = codecs.iter().map(|c| c.name()).collect();
    header.extend(names.iter());
    let mut t = Table::new(&header);
    let mut sums = vec![0.0; codecs.len()];
    let mut gbdi_wins_vs_bdi = 0;
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let mut row = vec![w.name().to_string()];
        let mut ratios = Vec::new();
        for (i, c) in codecs.iter().enumerate() {
            let r = ratio_of(c.as_ref(), &img);
            sums[i] += r;
            ratios.push(r);
            row.push(format!("{r:.3}"));
        }
        if ratios[0] > ratios[1] {
            gbdi_wins_vs_bdi += 1;
        }
        t.row(&row);
    }
    let mut mean_row = vec!["MEAN".to_string()];
    for s in &sums {
        mean_row.push(format!("{:.3}", s / 9.0));
    }
    t.row(&mean_row);
    print!("{}", t.render());
    println!(
        "\nGBDI beats BDI on {gbdi_wins_vs_bdi}/9 workloads; mean {:.3} vs {:.3} (HPCA'22 shape: GBDI > BDI)",
        sums[0] / 9.0,
        sums[1] / 9.0
    );

    // --- speed column -----------------------------------------------------
    println!("\n== E3b: codec speed on triangle_count ==\n");
    let img = workloads::by_name("triangle_count").unwrap().generate(size, 7);
    let mut b = Bencher::new();
    for codec in &codecs {
        b.bench(&format!("compress/{}", codec.name()), Some(img.len() as u64), || {
            codec.compress(&img)
        });
        let comp = codec.compress(&img);
        b.bench(&format!("decompress/{}", codec.name()), Some(img.len() as u64), || {
            codec.decompress(&comp, img.len()).unwrap()
        });
    }
    std::fs::create_dir_all("target").ok();
    b.write_csv("target/baselines_speed.csv").ok();
    println!("\ncsv: target/baselines_speed.csv");
    match b.write_bench_json("baselines") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
