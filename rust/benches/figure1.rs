//! E1 + E2 — the paper's Figure 1 (per-workload GBDI compression ratio)
//! and its in-text aggregate claims (1.55× Java / 1.4× C / 1.45× overall,
//! vs the literature's 1.9× upper bound).
//!
//! `cargo bench --bench figure1` — writes `target/figure1.csv`.

use gbdi::baselines::{ratio_of, Codec, GbdiWholeImage};
use gbdi::report::{bar_chart, fmt_ratio, Table};
use gbdi::util::bench::Bencher;
use gbdi::util::prng::Rng;
use gbdi::workloads;

fn image_bytes() -> usize {
    if std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1") {
        1 << 20
    } else {
        8 << 20
    }
}

fn main() {
    let size = image_bytes();
    let gbdi = GbdiWholeImage::default();
    let mut bencher = Bencher::new();

    println!("== E1 / Figure 1: GBDI compression ratio, {} MiB per workload ==\n", size >> 20);
    let mut chart = Vec::new();
    let mut c_ratios = Vec::new();
    let mut j_ratios = Vec::new();
    let mut table = Table::new(&["workload", "group", "ratio"]);
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let r = ratio_of(&gbdi, &img);
        table.row(&[w.name().into(), w.group().label().into(), format!("{r:.4}")]);
        chart.push((w.name().to_string(), r));
        if w.group().is_c_family() {
            c_ratios.push(r)
        } else {
            j_ratios.push(r)
        }
    }
    print!("{}", table.render());
    println!();
    println!("{}", bar_chart("Figure 1", &chart, 48));

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let all: Vec<f64> = chart.iter().map(|(_, r)| *r).collect();
    println!("== E2: aggregate claims ==");
    let mut t = Table::new(&["aggregate", "paper", "measured"]);
    t.row(&["C-workloads mean".into(), "1.40x".into(), fmt_ratio(mean(&c_ratios))]);
    t.row(&["Java mean".into(), "1.55x".into(), fmt_ratio(mean(&j_ratios))]);
    t.row(&["overall mean".into(), "1.45x".into(), fmt_ratio(mean(&all))]);
    // the literature's 1.9x: an ideally clusterable population (a few tight
    // value clusters, zero slack) — GBDI's best case
    let ideal = {
        let mut rng = Rng::new(3);
        let mut img = vec![0u8; size.min(4 << 20)];
        for c in img.chunks_mut(4) {
            let base = [0x0000_1000u32, 0x4000_0000, 0x8000_0000, 0xC000_0000][rng.below(4) as usize];
            let v = base + rng.below(128) as u32;
            let n = c.len();
            c.copy_from_slice(&v.to_le_bytes()[..n]);
        }
        ratio_of(&gbdi, &img)
    };
    t.row(&["ideal clusterable (lit. bound)".into(), "1.90x".into(), fmt_ratio(ideal)]);
    print!("{}", t.render());

    // end-to-end timing of the figure's pipeline on one representative
    let img = workloads::by_name("mcf").unwrap().generate(size.min(2 << 20), 7);
    bencher.bench("figure1/compress-mcf", Some(img.len() as u64), || gbdi.compress(&img));
    let comp = gbdi.compress(&img);
    bencher.bench("figure1/decompress-mcf", Some(img.len() as u64), || {
        gbdi.decompress(&comp, img.len()).unwrap()
    });
    let mut csv = String::from("workload,ratio\n");
    for (n, r) in &chart {
        csv.push_str(&format!("{n},{r:.4}\n"));
        bencher.metric(&format!("ratio/{n}"), *r);
    }
    bencher.metric("mean_ratio/c_workloads", mean(&c_ratios));
    bencher.metric("mean_ratio/java", mean(&j_ratios));
    bencher.metric("mean_ratio/overall", mean(&all));
    bencher.metric("ratio/ideal_clusterable", ideal);
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/figure1.csv", csv).ok();
    println!("\ncsv: target/figure1.csv");
    match bencher.write_bench_json("figure1") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
