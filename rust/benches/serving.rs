//! Network serving: trace-driven multi-connection load against the GBN1
//! TCP front end ([`gbdi::server::Server`]) over loopback — the
//! experiment the pipelined binary protocol exists for.
//!
//! Reports, per connection count (1/2/4/8 clients against 8 shards):
//! aggregate op throughput (ops/s) and client-observed p50/p99/p999
//! latency, plus two gateable single-connection byte-throughput probes
//! (single-block GET round-trips and 4 KiB RANGE reads). The last arm
//! forces a live codec-table swap while 8 connections are in flight and
//! counts failed client ops. Emits `BENCH_serving.json` at the repo
//! root.
//!
//! Acceptance bars this bench guards (asserted whenever the machine has
//! ≥ 4 hardware threads, fast mode included):
//!
//! * 8 pipelined connections must deliver ≥ 2x the aggregate throughput
//!   of 1 connection at 8 shards;
//! * a codec-table swap forced under live 8-connection load must
//!   complete with zero failed client ops.
//!
//! `cargo bench --bench serving`

use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::server::{self, protocol::stats_field, Client, LoadGenConfig, Server, ServerConfig};
use gbdi::simd;
use gbdi::util::bench::Bencher;
use std::time::{Duration, Instant};

/// Adaptive 8-shard service behind a GBN1 server on an ephemeral
/// loopback port. Automatic analysis is parked (`analyze_every: MAX`)
/// so table swaps happen exactly when an arm forces them.
fn start_server(shards: usize) -> Server {
    let svc = CompressionService::start(ServiceConfig {
        workers: 2,
        shards,
        analyze_every: u64::MAX,
        ingest_batch: 32,
        ..Default::default()
    })
    .expect("service start");
    let scfg = ServerConfig { listen: "127.0.0.1:0".to_string(), ..Default::default() };
    Server::bind(svc, scfg).expect("server bind")
}

/// One scaling arm: `conns` pipelined connections replaying the mixed
/// deterministic trace. Returns (ops_per_s, p50_ns, p99_ns, p999_ns).
fn run_conn_arm(addr: &str, conns: usize, ops_per_conn: usize, pages: u64) -> (f64, u64, u64, u64) {
    let cfg = LoadGenConfig {
        addr: addr.to_string(),
        conns,
        ops_per_conn,
        pages,
        ..Default::default()
    };
    let rep = server::run_loadgen(&cfg).expect("loadgen");
    assert_eq!(rep.ops_err, 0, "load generator saw failed ops at {conns} conns");
    let mut lat = rep.lat_ns.clone();
    lat.sort_unstable();
    let p50 = server::percentile(&lat, 0.50);
    let p99 = server::percentile(&lat, 0.99);
    let p999 = server::percentile(&lat, 0.999);
    println!(
        "{conns:>2} conn(s): {:>10.0} ops/s   p50 {:>7} ns  p99 {:>8} ns  p999 {:>8} ns  \
         ({} ok, {} shed)",
        rep.ops_per_s(), p50, p99, p999, rep.ops_ok, rep.sheds
    );
    (rep.ops_per_s(), p50, p99, p999)
}

/// Live codec-table swap under 8-connection load: a control client
/// forces analysis rounds while the trace is in flight. Tables start
/// trivial and the preloaded pages seed the sample reservoir, so the
/// first forced round adopts a real table. Returns
/// (table swaps observed, failed client ops).
fn run_swap_arm(pages: u64, ops_per_conn: usize) -> (u64, u64) {
    let server = start_server(8);
    let addr = server.local_addr().to_string();
    let cfg = LoadGenConfig {
        addr: addr.clone(),
        conns: 8,
        ops_per_conn,
        pages,
        ..Default::default()
    };
    server::preload(&cfg).expect("preload");

    let ctl = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).expect("control connect");
        let v0 = c.stats().expect("stats").get(stats_field::CODEC_VERSION);
        // let the load connections come up so the swap lands mid-traffic
        std::thread::sleep(Duration::from_millis(30));
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            c.reanalyze().expect("reanalyze");
            std::thread::sleep(Duration::from_millis(20));
            let v = c.stats().expect("stats").get(stats_field::CODEC_VERSION);
            if v > v0 || Instant::now() >= deadline {
                return v.saturating_sub(v0);
            }
        }
    });
    let rep = server::run_loadgen(&cfg).expect("loadgen");
    let swaps = ctl.join().expect("control thread");
    let (svc, _, _) = server.stop();
    svc.shutdown();
    (swaps, rep.ops_err)
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let pages: u64 = if fast { 192 } else { 512 };
    let ops_per_conn: usize = if fast { 2_500 } else { 20_000 };
    println!("== GBN1 network serving: 8 shards, {pages} pages, pipelined mixed ops ==\n");

    let server = start_server(8);
    let addr = server.local_addr().to_string();
    let pre_cfg = LoadGenConfig { addr: addr.clone(), pages, ..Default::default() };
    let preloaded = server::preload(&pre_cfg).expect("preload");
    assert_eq!(preloaded, pages, "preload accepted fewer pages than requested");

    let mut b = Bencher::new();

    // gateable byte-throughput probes: one synchronous connection, one
    // request per iteration (protocol + service + loopback round-trip)
    let mut probe = Client::connect(&addr).expect("probe connect");
    let block = probe.block_bytes() as u64;
    b.bench("net_get_block_roundtrip", Some(block), || {
        probe.get_block(3, 9).expect("get_block").len()
    });
    b.bench("net_range_read_4k", Some(4096), || {
        probe.read_range(5, 0, 64).expect("read_range").len()
    });
    drop(probe);
    println!();

    let mut ops_at_1 = 0.0f64;
    let mut ops_at_8 = 0.0f64;
    for conns in [1usize, 2, 4, 8] {
        let (ops, p50, p99, p999) = run_conn_arm(&addr, conns, ops_per_conn, pages);
        b.metric(&format!("ops_per_s/conns={conns}"), ops);
        b.metric(&format!("p50_ns/conns={conns}"), p50 as f64);
        b.metric(&format!("p99_ns/conns={conns}"), p99 as f64);
        b.metric(&format!("p999_ns/conns={conns}"), p999 as f64);
        if conns == 1 {
            ops_at_1 = ops;
        }
        if conns == 8 {
            ops_at_8 = ops;
        }
    }
    let speedup = ops_at_8 / ops_at_1.max(1e-9);
    b.metric("speedup/8_conns_vs_1", speedup);
    println!("\n8 conns vs 1 conn: {speedup:.2}x aggregate throughput");
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if cores >= 4 {
        assert!(
            speedup >= 2.0,
            "8 connections must at least double 1-connection throughput \
             (got {speedup:.2}x on {cores} cores)"
        );
    } else {
        println!("(scaling assertion skipped: {cores} hardware threads)");
    }

    // drain the scaling server and record its side of the ledger
    let (svc, stats, flushed) = server.stop();
    let m = svc.shutdown();
    let (ok, err, shed) = (stats.ops_ok, stats.ops_err, stats.shed_ops);
    println!(
        "server: {} conns, {ok} ops ok / {err} err / {shed} shed, {} protocol errors, \
         {} pages in, {flushed} deferred blocks flushed",
        stats.accepted_conns, stats.protocol_errors, m.pages_in
    );

    println!("\n== live codec-table swap under 8-connection load ==\n");
    let (swaps, failed) = run_swap_arm(pages, ops_per_conn);
    b.metric("swap/table_swaps", swaps as f64);
    b.metric("swap/failed_ops", failed as f64);
    println!("table swaps under load: {swaps}, failed client ops: {failed}");
    assert!(swaps >= 1, "no codec-table swap completed under live load");
    assert_eq!(failed, 0, "client ops failed during a live codec-table swap");

    // the regression gate must only ever compare runs of the same ISA
    // dispatch and protocol revision
    b.tag("isa", simd::active().isa.name());
    b.tag("proto", "gbn1");
    std::fs::create_dir_all("target").ok();
    b.write_csv("target/serving.csv").ok();
    match b.write_bench_json("serving") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
