//! E4 — the paper's modified-k-means claim (§II.A): GBDI's bit-cost
//! clustering "achieves higher compression ratios than unmodified
//! Kmeans". Three arms, everything else fixed:
//!
//! * modified — bit-cost assignment metric (the paper's algorithm)
//! * unmodified — Euclidean assignment metric
//! * uniform — K bases evenly spaced over the value range (no clustering)
//!
//! `cargo bench --bench kmeans_ablation`

use gbdi::cluster::Metric;
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::report::Table;
use gbdi::util::bench::Bencher;
use gbdi::workloads;

fn ratio_with_table(img: &[u8], table: gbdi::gbdi::GlobalBaseTable, cfg: &GbdiConfig) -> f64 {
    let codec = GbdiCodec::new(table, cfg.clone());
    codec.compress_image(img).ratio()
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    let cfg = GbdiConfig::default();

    println!("== E4: clustering ablation ({} KiB per workload) ==\n", size >> 10);
    let mut t = Table::new(&["workload", "modified", "unmodified", "uniform bases"]);
    let mut wins_mod = 0;
    let mut sums = [0.0f64; 3];
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let samples = analyze::sample_image(&img, &cfg);
        let modified = ratio_with_table(
            &img,
            analyze::analyze_samples_metric(&samples, &cfg, Metric::BitCost),
            &cfg,
        );
        let unmodified = ratio_with_table(
            &img,
            analyze::analyze_samples_metric(&samples, &cfg, Metric::Euclidean),
            &cfg,
        );
        let uniform = {
            let k = cfg.num_bases as u64;
            let centroids: Vec<u64> = (0..k).map(|i| i * (u32::MAX as u64 / k)).collect();
            ratio_with_table(
                &img,
                analyze::table_from_centroids(&samples, &centroids, &cfg, 0),
                &cfg,
            )
        };
        if modified >= unmodified {
            wins_mod += 1;
        }
        sums[0] += modified;
        sums[1] += unmodified;
        sums[2] += uniform;
        t.row(&[
            w.name().into(),
            format!("{modified:.3}"),
            format!("{unmodified:.3}"),
            format!("{uniform:.3}"),
        ]);
    }
    t.row(&[
        "MEAN".into(),
        format!("{:.3}", sums[0] / 9.0),
        format!("{:.3}", sums[1] / 9.0),
        format!("{:.3}", sums[2] / 9.0),
    ]);
    print!("{}", t.render());
    println!(
        "\nmodified >= unmodified on {wins_mod}/9 workloads (paper claim: modified wins)"
    );

    // analysis-time cost of each arm
    println!();
    let img = workloads::by_name("mcf").unwrap().generate(size, 7);
    let samples = analyze::sample_image(&img, &cfg);
    let mut b = Bencher::new();
    b.bench("analysis/modified-kmeans", None, || {
        analyze::analyze_samples_metric(&samples, &cfg, Metric::BitCost)
    });
    b.bench("analysis/unmodified-kmeans", None, || {
        analyze::analyze_samples_metric(&samples, &cfg, Metric::Euclidean)
    });
}
