//! E4 + E9 — clustering ablation, now across the whole base-selector
//! engine.
//!
//! Arms (everything but the selector fixed):
//!
//! * lloyd — full bit-cost Lloyd k-means (the paper's modified
//!   algorithm; the reference arm)
//! * unmodified — Euclidean-metric Lloyd (the paper's ablation)
//! * minibatch-warm — mini-batch k-means **warm-started from a table fit
//!   on the previous epoch's sample** (the production configuration)
//! * minibatch-cold — the same selector without an incumbent
//! * histogram — frequency top-K bucket selector
//! * uniform — K evenly spaced bases (no clustering at all)
//!
//! Each arm is scored on compression ratio over the nine paper workloads
//! and on wall time per analysis pass (selector + width fitting — what
//! the coordinator pays when drift detection fires). A phase-change
//! experiment (fluidanimate traffic shifting to mcf) exercises the warm
//! start under the adaptation scenario it exists for.
//!
//! Headline targets (reported in `BENCH_kmeans_ablation.json`):
//! minibatch-warm >= 5x faster per pass than lloyd at <= 2% mean ratio
//! loss.
//!
//! `cargo bench --bench kmeans_ablation`

use gbdi::cluster::{BaseSelector, Metric, SelectorConfig, SelectorKind};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::report::Table;
use gbdi::util::bench::Bencher;
use gbdi::workloads;
use std::time::Instant;

fn ratio_with_table(img: &[u8], table: GlobalBaseTable, cfg: &GbdiConfig) -> f64 {
    let codec = GbdiCodec::new(table, cfg.clone());
    codec.compress_image(img).ratio()
}

/// Run one analysis pass `runs` times (selectors are deterministic for
/// fixed inputs); returns the produced table and the best-of-runs wall
/// time in milliseconds.
fn timed(runs: usize, mut f: impl FnMut() -> GlobalBaseTable) -> (GlobalBaseTable, f64) {
    let mut best = f64::INFINITY;
    let mut table = None;
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        let t = f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        table = Some(t);
    }
    (table.expect("runs >= 1"), best)
}

/// One analysis pass: selector + width fitting (the swap scoring is the
/// same O(n) for every arm and is excluded).
fn analysis_pass(
    selector: &mut dyn BaseSelector,
    samples: &[u64],
    incumbent: Option<&GlobalBaseTable>,
    cfg: &GbdiConfig,
    sel_cfg: &SelectorConfig,
) -> GlobalBaseTable {
    let selection = selector.select(samples, incumbent, sel_cfg).expect("native selector");
    GlobalBaseTable::from_selection(samples, &selection, cfg, 0)
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    let runs = if fast { 2 } else { 3 };
    let cfg = GbdiConfig::default();
    let sel_cfg = SelectorConfig::from_gbdi(&cfg);
    let mut b = Bencher::new();

    println!("== E4/E9: base-selector ablation ({} KiB per workload) ==\n", size >> 10);
    const ARMS: [&str; 6] =
        ["lloyd", "unmodified", "minibatch-warm", "minibatch-cold", "histogram", "uniform"];
    let mut header: Vec<String> = vec!["workload".into()];
    header.extend(ARMS.iter().map(|a| a.to_string()));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    let mut ratio_sums = [0.0f64; 6];
    let mut ms_sums = [0.0f64; 6];
    let mut n_workloads = 0usize;

    for w in workloads::all() {
        let img = w.generate(size, 7);
        let samples = analyze::sample_image(&img, &cfg);
        // the warm arm's incumbent: a lloyd table fit on the previous
        // epoch's sample of the same workload (steady-state serving)
        let prev_img = w.generate(size, 11);
        let prev_samples = analyze::sample_image(&prev_img, &cfg);
        let incumbent = analysis_pass(
            &mut *SelectorKind::Lloyd.build(),
            &prev_samples,
            None,
            &cfg,
            &sel_cfg,
        );

        let mut ratios = [0.0f64; 6];
        for (i, &arm) in ARMS.iter().enumerate() {
            let (table, ms) = match arm {
                "lloyd" => {
                    let mut s = SelectorKind::Lloyd.build();
                    timed(runs, || analysis_pass(&mut *s, &samples, None, &cfg, &sel_cfg))
                }
                "unmodified" => {
                    let euc = SelectorConfig { metric: Metric::Euclidean, ..sel_cfg.clone() };
                    let mut s = SelectorKind::Lloyd.build();
                    timed(runs, || analysis_pass(&mut *s, &samples, None, &cfg, &euc))
                }
                "minibatch-warm" => {
                    let mut s = SelectorKind::MiniBatch.build();
                    timed(runs, || {
                        analysis_pass(&mut *s, &samples, Some(&incumbent), &cfg, &sel_cfg)
                    })
                }
                "minibatch-cold" => {
                    let mut s = SelectorKind::MiniBatch.build();
                    timed(runs, || analysis_pass(&mut *s, &samples, None, &cfg, &sel_cfg))
                }
                "histogram" => {
                    let mut s = SelectorKind::Histogram.build();
                    timed(runs, || analysis_pass(&mut *s, &samples, None, &cfg, &sel_cfg))
                }
                _ => {
                    // uniform: K evenly spaced bases, no clustering
                    let k = cfg.num_bases as u64;
                    let centroids: Vec<u64> = (0..k).map(|i| i * (u32::MAX as u64 / k)).collect();
                    timed(runs, || GlobalBaseTable::fit_from_centroids(&samples, &centroids, &cfg, 0))
                }
            };
            ratios[i] = ratio_with_table(&img, table, &cfg);
            ratio_sums[i] += ratios[i];
            ms_sums[i] += ms;
            b.metric(&format!("ratio/{}/{arm}", w.name()), ratios[i]);
            b.metric(&format!("analysis_ms/{}/{arm}", w.name()), ms);
        }
        n_workloads += 1;
        let mut row = vec![w.name().to_string()];
        row.extend(ratios.iter().map(|r| format!("{r:.3}")));
        t.row(&row);
    }
    let mut mean_row = vec!["MEAN ratio".to_string()];
    mean_row.extend(ratio_sums.iter().map(|s| format!("{:.3}", s / n_workloads as f64)));
    t.row(&mean_row);
    let mut ms_row = vec!["MEAN pass ms".to_string()];
    ms_row.extend(ms_sums.iter().map(|s| format!("{:.2}", s / n_workloads as f64)));
    t.row(&ms_row);
    print!("{}", t.render());

    let mean_ratio = |i: usize| ratio_sums[i] / n_workloads as f64;
    let mean_ms = |i: usize| ms_sums[i] / n_workloads as f64;
    for (i, &arm) in ARMS.iter().enumerate() {
        b.metric(&format!("mean_ratio/{arm}"), mean_ratio(i));
        b.metric(&format!("mean_analysis_ms/{arm}"), mean_ms(i));
    }
    let speedup = mean_ms(0) / mean_ms(2).max(1e-9);
    let loss_pct = (1.0 - mean_ratio(2) / mean_ratio(0)) * 100.0;
    b.metric("speedup/minibatch_warm_vs_lloyd", speedup);
    b.metric("ratio_loss_pct/minibatch_warm_vs_lloyd", loss_pct);
    println!(
        "\nmodified (lloyd) >= unmodified on ratio: {} (paper claim: modified wins)",
        if mean_ratio(0) >= mean_ratio(1) { "yes" } else { "NO" }
    );
    println!(
        "minibatch-warm vs lloyd: {speedup:.1}x faster per pass, {loss_pct:.2}% mean ratio loss \
         (targets: >=5x, <=2%) -> {}",
        if speedup >= 5.0 && loss_pct <= 2.0 { "PASS" } else { "MISS" }
    );

    // phase change: incumbent fit on fluidanimate traffic, traffic is
    // now mcf — the adaptation scenario the warm start exists for
    println!("\n== phase change (fluidanimate -> mcf) ==");
    let img_a = workloads::by_name("fluidanimate").unwrap().generate(size, 7);
    let img_b = workloads::by_name("mcf").unwrap().generate(size, 7);
    let samples_a = analyze::sample_image(&img_a, &cfg);
    let samples_b = analyze::sample_image(&img_b, &cfg);
    let stale =
        analysis_pass(&mut *SelectorKind::Lloyd.build(), &samples_a, None, &cfg, &sel_cfg);
    let stale_ratio = ratio_with_table(&img_b, stale.clone(), &cfg);
    let mut warm_sel = SelectorKind::MiniBatch.build();
    let (warm_table, warm_ms) =
        timed(runs, || analysis_pass(&mut *warm_sel, &samples_b, Some(&stale), &cfg, &sel_cfg));
    let warm_ratio = ratio_with_table(&img_b, warm_table, &cfg);
    let mut lloyd_sel = SelectorKind::Lloyd.build();
    let (lloyd_table, lloyd_ms) =
        timed(runs, || analysis_pass(&mut *lloyd_sel, &samples_b, None, &cfg, &sel_cfg));
    let lloyd_ratio = ratio_with_table(&img_b, lloyd_table, &cfg);
    println!(
        "stale table on new phase: {stale_ratio:.3}  |  warm re-analysis: {warm_ratio:.3} \
         ({warm_ms:.2} ms)  |  full lloyd: {lloyd_ratio:.3} ({lloyd_ms:.2} ms)"
    );
    b.metric("phase_change/stale_ratio", stale_ratio);
    b.metric("phase_change/minibatch_warm_ratio", warm_ratio);
    b.metric("phase_change/minibatch_warm_ms", warm_ms);
    b.metric("phase_change/lloyd_ratio", lloyd_ratio);
    b.metric("phase_change/lloyd_ms", lloyd_ms);

    // steady timing rows for the JSON results array (one workload)
    println!();
    let img = workloads::by_name("mcf").unwrap().generate(size, 7);
    let samples = analyze::sample_image(&img, &cfg);
    let incumbent =
        analysis_pass(&mut *SelectorKind::Lloyd.build(), &samples, None, &cfg, &sel_cfg);
    let mut s = SelectorKind::Lloyd.build();
    b.bench("analysis/lloyd/mcf", None, || {
        analysis_pass(&mut *s, &samples, None, &cfg, &sel_cfg)
    });
    let mut s = SelectorKind::MiniBatch.build();
    b.bench("analysis/minibatch-warm/mcf", None, || {
        analysis_pass(&mut *s, &samples, Some(&incumbent), &cfg, &sel_cfg)
    });
    let mut s = SelectorKind::Histogram.build();
    b.bench("analysis/histogram/mcf", None, || {
        analysis_pass(&mut *s, &samples, None, &cfg, &sel_cfg)
    });

    match b.write_bench_json("kmeans_ablation") {
        Ok(p) => println!("\njson: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
