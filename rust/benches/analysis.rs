//! E8 — background-analysis cost: the PJRT-artifact k-means (AOT
//! JAX/Pallas) vs the native Rust k-means, across sample budgets. This is
//! the coordinator's control-plane latency — it bounds how fast the
//! service can react to traffic phase changes.
//!
//! `cargo bench --bench analysis` (artifact rows skip if `make artifacts`
//! has not run).

use gbdi::cluster::{kmeans, KmeansConfig, Metric, SelectorConfig, SelectorKind};
use gbdi::gbdi::{analyze, GbdiConfig, GlobalBaseTable};
use gbdi::runtime::{shape_samples, ArtifactRuntime, N_SAMPLES};
use gbdi::util::bench::Bencher;
use gbdi::util::prng::Rng;
use gbdi::workloads;
use std::sync::Arc;

fn main() {
    let img = workloads::by_name("triangle_count").unwrap().generate(2 << 20, 7);
    let cfg = GbdiConfig::default();
    let mut b = Bencher::new();

    println!("== E8: background-analysis latency ==\n");
    // native k-means across sample budgets
    for n in [1024usize, 4096, 16384] {
        let samples = gbdi::util::stats::stride_sample(
            &gbdi::value::words(&img, cfg.word_size).collect::<Vec<_>>(),
            n,
        );
        let kcfg = KmeansConfig { k: 63, iters: 16, ..Default::default() };
        b.bench(&format!("native-kmeans/n={n}"), None, || kmeans(&samples, &kcfg));
    }
    // full analysis (sampling + clustering + width fitting)
    b.bench("native-full-analysis/n=4096", None, || analyze::analyze_image(&img, &cfg));

    // artifact path
    match ArtifactRuntime::new(ArtifactRuntime::default_dir()) {
        Ok(rt) if rt.has_artifact("kmeans_k64") => {
            let rt = Arc::new(rt);
            let samples: Vec<u64> =
                gbdi::value::words(&img, cfg.word_size).take(N_SAMPLES * 4).collect();
            let x = shape_samples(&samples);
            let mut rng = Rng::new(5);
            let init64: Vec<f32> =
                (0..64).map(|_| samples[rng.below(samples.len() as u64) as usize] as f32).collect();
            let init16: Vec<f32> = init64[..16].to_vec();
            b.bench("artifact-kmeans/k=16", None, || rt.kmeans(&x, &init16).unwrap());
            b.bench("artifact-kmeans/k=64", None, || rt.kmeans(&x, &init64).unwrap());
            let bases = vec![0.0f32; 64];
            let widths = vec![16.0f32; 64];
            b.bench("artifact-sizeest/k=64", None, || {
                rt.size_estimate(&x, &bases, &widths).unwrap()
            });
        }
        _ => println!("(artifact rows skipped: run `make artifacts`)"),
    }

    // Euclidean-vs-bitcost clustering cost (the modification's price)
    let samples = analyze::sample_image(&img, &cfg);
    let bit = KmeansConfig { k: 63, iters: 16, metric: Metric::BitCost, ..Default::default() };
    let euc = KmeansConfig { k: 63, iters: 16, metric: Metric::Euclidean, ..Default::default() };
    b.bench("native-kmeans/bitcost-metric", None, || kmeans(&samples, &bit));
    b.bench("native-kmeans/euclidean-metric", None, || kmeans(&samples, &euc));

    // the selector engine: per-pass latency of every registered selector
    // (cold), plus the mini-batch warm start against a serving table —
    // the number drift-triggered re-analysis actually pays
    println!();
    let sel_cfg = SelectorConfig::from_gbdi(&cfg);
    for &kind in SelectorKind::all() {
        let mut sel = kind.build();
        b.bench(&format!("selector/{}/cold", kind.name()), None, || {
            sel.select(&samples, None, &sel_cfg).unwrap()
        });
    }
    let incumbent = {
        let selection =
            SelectorKind::Lloyd.build().select(&samples, None, &sel_cfg).unwrap();
        GlobalBaseTable::from_selection(&samples, &selection, &cfg, 1)
    };
    let mut warm = SelectorKind::MiniBatch.build();
    b.bench("selector/minibatch/warm", None, || {
        warm.select(&samples, Some(&incumbent), &sel_cfg).unwrap()
    });

    std::fs::create_dir_all("target").ok();
    b.write_csv("target/analysis.csv").ok();
    println!("\ncsv: target/analysis.csv");
    match b.write_bench_json("analysis") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
