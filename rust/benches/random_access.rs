//! Random-access latency: single-block reads through the
//! [`gbdi::Frame`] index vs the whole-image decode every consumer paid
//! before the Frame API existed — across the paper's nine workloads on
//! 4 MiB images, for all three block codecs on the reference workload.
//!
//! The acceptance bar this bench guards: a single-block read must be
//! ≥ 10x faster than a full decode on a 4 MiB image, with **zero heap
//! allocations** per `read_block` and per `estimate_block_bits_with`
//! call at steady state (measured by the crate's counting allocator,
//! registered as this binary's global allocator).
//!
//! `cargo bench --bench random_access`

use gbdi::util::alloc::CountingAlloc;
use gbdi::util::bench::Bencher;
use gbdi::{workloads, BlockCodec, CodecKind, Frame, GbdiConfig, Scratch};
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size: usize = if fast { 1 << 20 } else { 4 << 20 };
    println!(
        "== random access: Frame::read_block vs whole-image decode ({} MiB images) ==\n",
        size >> 20
    );
    let cfg = GbdiConfig::default();
    let mut b = Bencher::new();

    // all nine workloads under GBDI (the paper's codec)
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&img, &cfg));
        let container = gbdi::container::compress(codec.as_ref(), &img);
        let frame = Frame::with_codec(container.clone(), Arc::clone(&codec)).expect("frame");
        let n = frame.n_blocks();
        let mut line = vec![0u8; frame.block_bytes()];
        let mut i = 0usize;
        let read = b
            .bench(&format!("read_block/{}", w.name()), Some(64), || {
                i = (i.wrapping_mul(2654435761).wrapping_add(12345)) % n; // scattered
                frame.read_block(i, &mut line).unwrap();
                line[0]
            })
            .mean;
        let full = b
            .bench(&format!("decompress/{}", w.name()), Some(img.len() as u64), || {
                container.decompress().unwrap()
            })
            .mean;
        let speedup = full.as_nanos() as f64 / (read.as_nanos() as f64).max(1.0);
        b.metric(&format!("speedup/{}", w.name()), speedup);
        assert!(
            speedup >= 10.0,
            "{}: single-block read only {speedup:.1}x faster than full decode",
            w.name()
        );

        // allocation budget: steady-state reads and estimates are free.
        // (warmed above: the scratch writer and line buffer exist)
        let mut scratch = Scratch::new();
        let block = &img[0..64];
        codec.estimate_block_bits_with(block, &mut scratch); // warm scratch
        let before = CountingAlloc::allocations();
        let mut sink = 0u64;
        for k in 0..4096usize {
            let idx = (k * 997) % n;
            frame.read_block(idx, &mut line).unwrap();
            sink = sink.wrapping_add(line[0] as u64);
            sink = sink.wrapping_add(
                codec.estimate_block_bits_with(&img[idx * 64..(idx + 1) * 64], &mut scratch),
            );
        }
        let allocs = CountingAlloc::allocations() - before;
        std::hint::black_box(sink);
        b.metric(&format!("allocs_per_read/{}", w.name()), allocs as f64 / 4096.0);
        assert_eq!(allocs, 0, "{}: hot loop allocated {allocs} times", w.name());
    }

    // codec sweep on the reference workload: the index is codec-agnostic
    println!("\n-- per-codec single-block latency (mcf) --");
    let img = workloads::by_name("mcf").unwrap().generate(size, 7);
    for &kind in CodecKind::all() {
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&img, &cfg));
        let frame = Frame::compress(Arc::clone(&codec), &img);
        let n = frame.n_blocks();
        let mut line = vec![0u8; frame.block_bytes()];
        let mut i = 0usize;
        b.bench(&format!("read_block/codec/{}", kind.name()), Some(64), || {
            i = (i.wrapping_mul(2654435761).wrapping_add(12345)) % n;
            frame.read_block(i, &mut line).unwrap();
            line[0]
        });
    }

    std::fs::create_dir_all("target").ok();
    b.write_csv("target/random_access.csv").ok();
    println!("\ncsv: target/random_access.csv");
    match b.write_bench_json("random_access") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
