//! E5 — the paper's methodology metrics (§V): decompression time and
//! reconstruction accuracy, plus compression throughput. One row per
//! workload, GBDI end-to-end, with block-granular decode latency (the
//! number a memory controller cares about).
//!
//! `cargo bench --bench throughput`

use gbdi::gbdi::{analyze, decode, GbdiCodec, GbdiConfig};
use gbdi::util::bench::Bencher;
use gbdi::util::bits::BitReader;
use gbdi::workloads;

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    println!("== E5: GBDI compress/decompress throughput ({} KiB images) ==\n", size >> 10);
    let cfg = GbdiConfig::default();
    let mut b = Bencher::new();
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let table = analyze::analyze_image(&img, &cfg);
        let codec = GbdiCodec::new(table, cfg.clone());
        b.bench(&format!("compress/{}", w.name()), Some(img.len() as u64), || {
            codec.compress_image(&img)
        });
        let comp = codec.compress_image(&img);
        // reconstruction accuracy: always verified inside the run
        let restored = decode::decompress_image(&comp).expect("decode");
        assert_eq!(restored, img, "{} reconstruction", w.name());
        b.bench(&format!("decompress/{}", w.name()), Some(img.len() as u64), || {
            decode::decompress_image(&comp).unwrap()
        });
    }

    // block-granular decode latency (single 64B block, hot path)
    println!("\n-- single-block decode latency --");
    let img = workloads::by_name("triangle_count").unwrap().generate(size, 7);
    let table = analyze::analyze_image(&img, &cfg);
    let codec = GbdiCodec::new(table.clone(), cfg.clone());
    let comp = codec.compress_image(&img);
    // pick the first GBDI-coded block's payload
    let payload = &comp.payload;
    let mut out = vec![0u8; cfg.block_bytes];
    b.bench("decode/single-block", Some(64), || {
        let mut r = BitReader::new(payload);
        decode::decompress_block(&mut r, &table, &cfg, &mut out).unwrap();
        out[0]
    });
    std::fs::create_dir_all("target").ok();
    b.write_csv("target/throughput.csv").ok();
    println!("\ncsv: target/throughput.csv");
    match b.write_bench_json("throughput") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
