//! E5 — the paper's methodology metrics (§V): decompression time and
//! reconstruction accuracy, plus compression throughput. One row per
//! workload, GBDI end-to-end, with block-granular decode latency (the
//! number a memory controller cares about).
//!
//! The single-block probe locates a **real GBDI-mode block** through the
//! container's per-block bit index and the 2-bit mode tag — the block at
//! payload offset 0 need not be GBDI-coded (it is frequently ZERO or
//! REP, which would make the "latency" number fiction). Both the fused
//! LUT kernel (the codec's hot path) and the scalar reference decoder
//! are timed on that block, so the JSON records the kernel speedup.
//!
//! A per-ISA ablation then re-decodes one image under **every SIMD
//! backend this host supports** (forced through the dispatch override)
//! and emits `speedup/<isa>-vs-scalar` metrics; the JSON is tagged with
//! the ISA that served the main measurements, which the regression gate
//! checks before comparing runs.
//!
//! `cargo bench --bench throughput`

use gbdi::gbdi::{analyze, decode, BlockMode, GbdiCodec, GbdiConfig};
use gbdi::simd::{self, Isa};
use gbdi::util::bench::Bencher;
use gbdi::util::bits::BitReader;
use gbdi::workloads;
use gbdi::BlockCodec;

/// Bit offset of the first GBDI-mode block in a serially-compressed
/// container, via the block-bits index + each block's mode tag. The
/// plain prefix-sum walk is only valid without parallel-chunk byte
/// realignment (a chunked payload would need `Frame`'s offset index).
fn find_gbdi_block(comp: &gbdi::Container) -> Option<u64> {
    assert_eq!(comp.chunk_blocks, 0, "offset walk requires a serial payload");
    let mut off = 0u64;
    for &bits in &comp.block_bits {
        let mut r = BitReader::new(&comp.payload[(off / 8) as usize..]);
        if off % 8 != 0 {
            r.get((off % 8) as u32).ok()?;
        }
        let tag = r.get(2).ok()?;
        if BlockMode::from_tag(tag) == BlockMode::Gbdi {
            return Some(off);
        }
        off += bits as u64;
    }
    None
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    println!("== E5: GBDI compress/decompress throughput ({} KiB images) ==\n", size >> 10);
    let cfg = GbdiConfig::default();
    let mut b = Bencher::new();
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let table = analyze::analyze_image(&img, &cfg);
        let codec = GbdiCodec::new(table, cfg.clone());
        b.bench(&format!("compress/{}", w.name()), Some(img.len() as u64), || {
            codec.compress_image(&img)
        });
        let comp = codec.compress_image(&img);
        // reconstruction accuracy: always verified inside the run
        let restored = decode::decompress_image(&comp).expect("decode");
        assert_eq!(restored, img, "{} reconstruction", w.name());
        b.bench(&format!("decompress/{}", w.name()), Some(img.len() as u64), || {
            decode::decompress_image(&comp).unwrap()
        });
    }

    // block-granular decode latency (single 64B block, hot path)
    println!("\n-- single-block decode latency --");
    let img = workloads::by_name("triangle_count").unwrap().generate(size, 7);
    let table = analyze::analyze_image(&img, &cfg);
    let codec = GbdiCodec::new(table.clone(), cfg.clone());
    let comp = codec.compress_image(&img);
    let off = find_gbdi_block(&comp).expect("workload produced no GBDI-mode block");
    let byte = (off / 8) as usize;
    let sub = off % 8;
    let mut out = vec![0u8; cfg.block_bytes];
    b.bench("decode/single-block", Some(64), || {
        let mut r = BitReader::new(&comp.payload[byte..]);
        if sub != 0 {
            r.get(sub as u32).unwrap();
        }
        codec.decompress_block(&mut r, &mut out).unwrap();
        out[0]
    });
    // the scalar reference decoder on the same block: the LUT-kernel
    // ablation, recorded so the JSON carries the kernel speedup
    b.bench("decode/single-block-reference", Some(64), || {
        let mut r = BitReader::new(&comp.payload[byte..]);
        if sub != 0 {
            r.get(sub as u32).unwrap();
        }
        decode::decompress_block(&mut r, &table, &cfg, &mut out).unwrap();
        out[0]
    });
    // -- per-ISA ablation: the same image decoded under every backend
    // this host supports, forced through the dispatch override. Records
    // absolute rates per ISA plus speedup-vs-forced-scalar ratios (the
    // number ISSUE acceptance gates on).
    println!("\n-- per-ISA decode ablation --");
    let mut rates: Vec<(Isa, f64)> = Vec::new();
    for &isa in Isa::all() {
        if !isa.supported() {
            continue;
        }
        simd::force(Some(isa)).expect("forcing a supported ISA cannot fail");
        let restored = decode::decompress_image(&comp).expect("decode under forced ISA");
        assert_eq!(restored, img, "reconstruction under {}", isa.name());
        let r = b.bench(
            &format!("decompress/isa/{}", isa.name()),
            Some(img.len() as u64),
            || decode::decompress_image(&comp).unwrap(),
        );
        rates.push((isa, r.mib_per_s().unwrap()));
    }
    simd::force(None).expect("clearing the ISA override cannot fail");
    let scalar_rate = rates
        .iter()
        .find(|(i, _)| *i == Isa::Scalar)
        .map(|&(_, r)| r)
        .expect("scalar backend always runs");
    let mut best = (Isa::Scalar, scalar_rate);
    for &(isa, rate) in &rates {
        b.metric(&format!("speedup/{}-vs-scalar", isa.name()), rate / scalar_rate);
        if rate > best.1 {
            best = (isa, rate);
        }
    }
    b.metric("speedup/best-vs-scalar", best.1 / scalar_rate);
    println!(
        "best backend: {} ({:.1} MiB/s, {:.2}x scalar)",
        best.0.name(),
        best.1,
        best.1 / scalar_rate
    );
    // which ISA served the (un-forced) measurements above — the
    // regression gate refuses to compare runs tagged differently
    b.tag("isa", simd::active().isa.name());
    b.tag("isa_best", Isa::detect_best().name());

    std::fs::create_dir_all("target").ok();
    b.write_csv("target/throughput.csv").ok();
    println!("\ncsv: target/throughput.csv");
    match b.write_bench_json("throughput") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
