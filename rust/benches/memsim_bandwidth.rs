//! E7 — the bandwidth/performance shape the paper quotes from HPCA'22
//! (§III: "1.5× higher bandwidth and 1.1× higher performance ... when
//! medium-high memory [intensity] is required"): replay access traces
//! against the compressed-memory simulator and report bandwidth
//! amplification + the memory-bound speedup proxy.
//!
//! `cargo bench --bench memsim_bandwidth`

use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::memsim::{replay, trace, CompressedMemory, DramModel, TraceKind};
use gbdi::report::Table;
use gbdi::util::bench::Bencher;
use gbdi::workloads;

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    let accesses = if fast { 8192 } else { 65536 };
    let model = DramModel { burst_bytes: 16, meta_miss: 0.05 };
    let kinds = [
        TraceKind::Streaming,
        TraceKind::Uniform,
        TraceKind::Zipf { exponent_milli: 1000 },
    ];

    println!(
        "== E7: bandwidth amplification (16 B bursts, {} accesses, {} KiB images) ==\n",
        accesses,
        size >> 10
    );
    let mut t = Table::new(&[
        "workload",
        "capacity",
        "streaming amp",
        "uniform amp",
        "zipf amp",
        "speedup@0.6 (stream)",
    ]);
    let cfg = GbdiConfig::default();
    let mut stream_amps = Vec::new();
    for w in workloads::all() {
        let img = w.generate(size, 7);
        let table = analyze::analyze_image(&img, &cfg);
        let mut mem = CompressedMemory::new(GbdiCodec::new(table, cfg.clone()));
        mem.store_image(&img);
        let mut amps = Vec::new();
        let mut speedup06 = 0.0;
        for kind in kinds {
            let tr = trace::generate(kind, mem.total_blocks(), accesses, 0.1, 9);
            let rep = replay(&mut mem, &tr, &model).unwrap();
            if kind == TraceKind::Streaming {
                speedup06 = rep.speedup(0.6);
                stream_amps.push(rep.amplification);
            }
            amps.push(rep.amplification);
        }
        t.row(&[
            w.name().into(),
            format!("{:.3}", mem.capacity_ratio()),
            format!("{:.3}", amps[0]),
            format!("{:.3}", amps[1]),
            format!("{:.3}", amps[2]),
            format!("{:.3}x", speedup06),
        ]);
    }
    print!("{}", t.render());
    let mean = stream_amps.iter().sum::<f64>() / stream_amps.len() as f64;
    println!(
        "\nmean streaming amplification {:.3}x (HPCA'22 claim shape: 1.5x bandwidth);",
        mean
    );
    println!(
        "speedup at 60% memory-bound {:.3}x (claim shape: 1.1x performance)",
        1.0 / ((1.0 - 0.6) + 0.6 / mean)
    );
    let mut b = Bencher::new();
    b.metric("mean_streaming_amplification", mean);
    b.metric("speedup_at_0.6_memory_bound", 1.0 / ((1.0 - 0.6) + 0.6 / mean));
    match b.write_bench_json("memsim_bandwidth") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
