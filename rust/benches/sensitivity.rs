//! E6 — design-space sensitivity: the "high degree of freedom over
//! customizing the algorithm" the paper's abstract motivates. Sweeps the
//! number of global bases, the block size, and the width-class menu.
//!
//! `cargo bench --bench sensitivity`

use gbdi::baselines::ratio_of;
use gbdi::baselines::GbdiWholeImage;
use gbdi::gbdi::GbdiConfig;
use gbdi::report::Table;
use gbdi::util::bench::Bencher;
use gbdi::workloads;

fn ratio(img: &[u8], cfg: GbdiConfig) -> f64 {
    ratio_of(&GbdiWholeImage { config: cfg }, img)
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let size = if fast { 1 << 19 } else { 2 << 20 };
    let loads = ["mcf", "triangle_count", "fluidanimate"];
    let mut bencher = Bencher::new();

    // --- K sweep ------------------------------------------------------
    println!("== E6a: number of global bases (K), {} KiB ==\n", size >> 10);
    let ks = [4usize, 8, 16, 32, 64, 128, 256];
    let mut header = vec!["workload".to_string()];
    header.extend(ks.iter().map(|k| format!("K={k}")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for name in loads {
        let img = workloads::by_name(name).unwrap().generate(size, 7);
        let mut row = vec![name.to_string()];
        for &k in &ks {
            let r = ratio(&img, GbdiConfig { num_bases: k, ..Default::default() });
            bencher.metric(&format!("ratio/{name}/K={k}"), r);
            row.push(format!("{r:.3}"));
        }
        t.row(&row);
    }
    print!("{}", t.render());

    // --- block size sweep ----------------------------------------------
    println!("\n== E6b: block size ==\n");
    let blocks = [32usize, 64, 128, 256];
    let mut header = vec!["workload".to_string()];
    header.extend(blocks.iter().map(|b| format!("{b} B")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for name in loads {
        let img = workloads::by_name(name).unwrap().generate(size, 7);
        let mut row = vec![name.to_string()];
        for &bb in &blocks {
            row.push(format!(
                "{:.3}",
                ratio(&img, GbdiConfig { block_bytes: bb, ..Default::default() })
            ));
        }
        t.row(&row);
    }
    print!("{}", t.render());

    // --- width-class menu sweep -----------------------------------------
    println!("\n== E6c: width-class menu ==\n");
    let menus: [(&str, Vec<u32>); 4] = [
        ("coarse {0,8,16,24}", vec![0, 8, 16, 24]),
        ("default {0,4,8,12,16,20,24}", vec![0, 4, 8, 12, 16, 20, 24]),
        ("fine {0,2,4,..,24}", (0..=24).step_by(2).collect()),
        ("narrow-only {0,4,8}", vec![0, 4, 8]),
    ];
    let mut t = Table::new(&["workload", "coarse", "default", "fine", "narrow-only"]);
    for name in loads {
        let img = workloads::by_name(name).unwrap().generate(size, 7);
        let mut row = vec![name.to_string()];
        for (_, menu) in &menus {
            row.push(format!(
                "{:.3}",
                ratio(&img, GbdiConfig { width_classes: menu.clone(), ..Default::default() })
            ));
        }
        t.row(&row);
    }
    print!("{}", t.render());

    // --- analysis sample count ------------------------------------------
    println!("\n== E6d: analysis sample budget ==\n");
    let samples = [256usize, 1024, 4096, 16384];
    let mut header = vec!["workload".to_string()];
    header.extend(samples.iter().map(|s| format!("{s}")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for name in loads {
        let img = workloads::by_name(name).unwrap().generate(size, 7);
        let mut row = vec![name.to_string()];
        for &s in &samples {
            row.push(format!(
                "{:.3}",
                ratio(&img, GbdiConfig { analysis_samples: s, ..Default::default() })
            ));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    match bencher.write_bench_json("sensitivity") {
        Ok(p) => println!("\njson: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
