//! Concurrent serving throughput: M client threads of mixed single-block
//! reads and writes against the [`gbdi::coordinator::CompressionService`]
//! as the page store scales from 1 shard (the old global-lock behavior)
//! to N shards — the experiment the sharded store exists for.
//!
//! Reports, per shard count: aggregate block-op throughput (ops/s) and
//! client-observed p50/p99 latency, plus the per-shard lock-hold means.
//! Emits `BENCH_concurrent_serving.json` at the repo root.
//!
//! The acceptance bar this bench guards: with 8 client threads, 8 shards
//! must deliver ≥ 2x the aggregate block-op throughput of 1 shard on the
//! same workload (asserted when the host has ≥ 4 hardware threads; on
//! smaller machines the numbers are still emitted for inspection).
//!
//! `cargo bench --bench concurrent_serving`

use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::util::bench::Bencher;
use gbdi::util::prng::Rng;
use gbdi::{workloads, BlockCodec, CodecKind, GbdiConfig};
use std::sync::Arc;
use std::time::Instant;

/// One arm: start a static-codec service with `shards` shards, ingest
/// `pages` pages in batches, then hammer it with `threads` clients doing
/// `ops_per_thread` mixed block ops (50% GET / 50% PUT). Returns
/// (ops_per_s, p50_ns, p99_ns).
fn run_arm(
    shards: usize,
    threads: usize,
    pages: u64,
    ops_per_thread: usize,
    image: &[u8],
) -> (f64, u64, u64) {
    let cfg = GbdiConfig::default();
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(image, &cfg));
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards, ..Default::default() },
        codec,
    )
    .expect("service start");
    let w = workloads::by_name("mcf").unwrap();
    let ingest_batch = svc.shard_count().max(8) * 4;
    let mut batch: Vec<(u64, Vec<u8>)> = Vec::with_capacity(ingest_batch);
    for i in 0..pages {
        batch.push((i, w.generate(4096, i)));
        if batch.len() >= ingest_batch {
            svc.submit_batch(std::mem::take(&mut batch));
        }
    }
    svc.submit_batch(batch);
    svc.flush();

    // warmup: touch every page once so first-access effects are paid
    // before the measured window
    let mut line = [0u8; 64];
    for i in 0..pages {
        svc.read_block(i, (i % 64) as usize, &mut line).unwrap();
    }

    let t0 = Instant::now();
    let mut lats: Vec<u64> = Vec::with_capacity(threads * ops_per_thread);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let svc = &svc;
                s.spawn(move || {
                    let mut rng = Rng::new(0xBEEF ^ (t as u64).wrapping_mul(0x9E3779B9));
                    let mut line = [0u8; 64];
                    let mut lat = Vec::with_capacity(ops_per_thread);
                    for _ in 0..ops_per_thread {
                        let pid = rng.below(pages);
                        let blk = rng.below(64) as usize;
                        let op0 = Instant::now();
                        if rng.below(2) == 0 {
                            svc.read_block(pid, blk, &mut line).unwrap();
                        } else {
                            svc.write_block(pid, blk, &line).unwrap();
                        }
                        lat.push(op0.elapsed().as_nanos() as u64);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            lats.extend(h.join().expect("client thread"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let total_ops = (threads * ops_per_thread) as f64;
    let ops_per_s = total_ops / wall.max(1e-9);

    lats.sort_unstable();
    let p50 = lats[lats.len() / 2];
    let p99 = lats[(lats.len() * 99 / 100).min(lats.len() - 1)];

    // cross-check: per-shard counters must sum exactly to the global
    // totals (the invariant the stress tests also pin)
    let shard_snaps = svc.shard_metrics();
    let hold_mean = shard_snaps.iter().map(|s| s.lock_hold_mean_ns()).sum::<f64>()
        / shard_snaps.len() as f64;
    let m = svc.shutdown();
    let sum_reads: u64 = shard_snaps.iter().map(|s| s.block_reads).sum();
    let sum_writes: u64 = shard_snaps.iter().map(|s| s.block_writes).sum();
    assert_eq!(sum_reads, m.block_reads, "per-shard reads must sum to the global total");
    assert_eq!(sum_writes, m.block_writes, "per-shard writes must sum to the global total");

    println!(
        "{:>3} shard(s) x {threads} clients: {:>10.0} ops/s   p50 {:>7} ns  p99 {:>7} ns  \
         (mean lock hold {:.0} ns)",
        shards, ops_per_s, p50, p99, hold_mean
    );
    (ops_per_s, p50, p99)
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let threads = 8usize;
    let pages: u64 = if fast { 192 } else { 512 };
    let ops_per_thread: usize = if fast { 8_000 } else { 50_000 };
    let shard_counts: &[usize] = if fast { &[1, 2, 8] } else { &[1, 2, 4, 8, 16] };
    println!(
        "== concurrent serving: {threads} clients, {pages} pages, 50/50 block GET/PUT ==\n"
    );
    let image = workloads::by_name("mcf").unwrap().generate(1 << 20, 7);

    let mut b = Bencher::new();
    let mut ops_at_1 = 0.0f64;
    let mut ops_at_8 = 0.0f64;
    for &shards in shard_counts {
        let (ops_per_s, p50, p99) = run_arm(shards, threads, pages, ops_per_thread, &image);
        b.metric(&format!("ops_per_s/shards={shards}"), ops_per_s);
        b.metric(&format!("p50_ns/shards={shards}"), p50 as f64);
        b.metric(&format!("p99_ns/shards={shards}"), p99 as f64);
        if shards == 1 {
            ops_at_1 = ops_per_s;
        }
        if shards == 8 {
            ops_at_8 = ops_per_s;
        }
    }
    let speedup = ops_at_8 / ops_at_1.max(1e-9);
    b.metric("speedup/8_shards_vs_1", speedup);
    println!("\n8 shards vs 1 shard at {threads} clients: {speedup:.2}x aggregate throughput");
    // enforce the bar only on full runs with real parallelism: the fast
    // CI smoke (one short trial on a shared runner) emits the numbers
    // for inspection but must not turn scheduler noise into a red build
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if !fast && cores >= 4 {
        assert!(
            speedup >= 2.0,
            "8 shards must at least double 1-shard throughput (got {speedup:.2}x on {cores} cores)"
        );
    } else {
        println!("(assertion skipped: fast={fast}, {cores} hardware threads)");
    }

    std::fs::create_dir_all("target").ok();
    b.write_csv("target/concurrent_serving.csv").ok();
    println!("csv: target/concurrent_serving.csv");
    match b.write_bench_json("concurrent_serving") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
