//! Concurrent serving throughput: M client threads of mixed single-block
//! reads and writes against the [`gbdi::coordinator::CompressionService`]
//! as the page store scales from 1 shard (the old global-lock behavior)
//! to N shards — the experiment the sharded store exists for.
//!
//! Reports, per shard count: aggregate block-op throughput (ops/s) and
//! client-observed p50/p99 latency, plus the per-shard lock-hold means.
//! Emits `BENCH_concurrent_serving.json` at the repo root.
//!
//! The second experiment is the hot-block cache sweep: Zipfian (s = 1.0)
//! GET/PUT traffic against 8 shards while the cache tier grows from 0%
//! to 20% of the logical footprint. Per cache size it reports the hit
//! rate, client-observed p99, the p99 of re-reads of the Zipf head (the
//! guaranteed-resident blocks), and the footprint savings vs raw
//! uncompressed memory — the hit-rate/latency curve the cache tier
//! exists for.
//!
//! The third experiment prices the integrity plane: the same mixed
//! traffic at 8 shards with integrity off, digest-maintenance only,
//! verified reads, and verified reads plus an aggressive scrubber —
//! the overhead curve `IntegrityConfig::verify_reads` documents.
//!
//! Acceptance bars this bench guards (asserted on full runs with ≥ 4
//! hardware threads; the fast CI smoke only emits the numbers):
//!
//! * with 8 client threads, 8 shards must deliver ≥ 2x the aggregate
//!   block-op throughput of 1 shard on the same workload;
//! * at cache = 10% of the logical footprint, the hot-probe p99 must be
//!   ≤ 2x an identically timed raw-memcpy probe, with ≥ 5x footprint
//!   savings over uncompressed memory;
//! * full integrity (verify + 256 MiB/s scrub) must retain ≥ 20% of
//!   unchecked throughput — a catastrophic-regression guard, not a
//!   performance promise.
//!
//! `cargo bench --bench concurrent_serving`

use gbdi::coordinator::{CompressionService, IntegrityConfig, ServiceConfig};
use gbdi::util::bench::Bencher;
use gbdi::util::prng::Rng;
use gbdi::{workloads, BlockCodec, CodecKind, GbdiConfig};
use std::sync::Arc;
use std::time::Instant;

/// One arm: start a static-codec service with `shards` shards, ingest
/// `pages` pages in batches, then hammer it with `threads` clients doing
/// `ops_per_thread` mixed block ops (50% GET / 50% PUT). The integrity
/// plane runs as configured, so the same harness measures both the
/// shard sweep (integrity off) and the integrity-overhead arms. Returns
/// (ops_per_s, p50_ns, p99_ns).
fn run_arm(
    shards: usize,
    threads: usize,
    pages: u64,
    ops_per_thread: usize,
    image: &[u8],
    integrity: IntegrityConfig,
) -> (f64, u64, u64) {
    let cfg = GbdiConfig::default();
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(image, &cfg));
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards, integrity, ..Default::default() },
        codec,
    )
    .expect("service start");
    let w = workloads::by_name("mcf").unwrap();
    let ingest_batch = svc.shard_count().max(8) * 4;
    let mut batch: Vec<(u64, Vec<u8>)> = Vec::with_capacity(ingest_batch);
    for i in 0..pages {
        batch.push((i, w.generate(4096, i)));
        if batch.len() >= ingest_batch {
            svc.submit_batch(std::mem::take(&mut batch));
        }
    }
    svc.submit_batch(batch);
    svc.flush();

    // warmup: touch every page once so first-access effects are paid
    // before the measured window
    let mut line = [0u8; 64];
    for i in 0..pages {
        svc.read_block(i, (i % 64) as usize, &mut line).unwrap();
    }

    let t0 = Instant::now();
    let mut lats: Vec<u64> = Vec::with_capacity(threads * ops_per_thread);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let svc = &svc;
                s.spawn(move || {
                    let mut rng = Rng::new(0xBEEF ^ (t as u64).wrapping_mul(0x9E3779B9));
                    let mut line = [0u8; 64];
                    let mut lat = Vec::with_capacity(ops_per_thread);
                    for _ in 0..ops_per_thread {
                        let pid = rng.below(pages);
                        let blk = rng.below(64) as usize;
                        let op0 = Instant::now();
                        if rng.below(2) == 0 {
                            svc.read_block(pid, blk, &mut line).unwrap();
                        } else {
                            svc.write_block(pid, blk, &line).unwrap();
                        }
                        lat.push(op0.elapsed().as_nanos() as u64);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            lats.extend(h.join().expect("client thread"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let total_ops = (threads * ops_per_thread) as f64;
    let ops_per_s = total_ops / wall.max(1e-9);

    lats.sort_unstable();
    let p50 = lats[lats.len() / 2];
    let p99 = lats[(lats.len() * 99 / 100).min(lats.len() - 1)];

    // cross-check: per-shard counters must sum exactly to the global
    // totals (the invariant the stress tests also pin)
    let shard_snaps = svc.shard_metrics();
    let hold_mean = shard_snaps.iter().map(|s| s.lock_hold_mean_ns()).sum::<f64>()
        / shard_snaps.len() as f64;
    let m = svc.shutdown();
    let sum_reads: u64 = shard_snaps.iter().map(|s| s.block_reads).sum();
    let sum_writes: u64 = shard_snaps.iter().map(|s| s.block_writes).sum();
    assert_eq!(sum_reads, m.block_reads, "per-shard reads must sum to the global total");
    assert_eq!(sum_writes, m.block_writes, "per-shard writes must sum to the global total");

    println!(
        "{:>3} shard(s) x {threads} clients: {:>10.0} ops/s   p50 {:>7} ns  p99 {:>7} ns  \
         (mean lock hold {:.0} ns)",
        shards, ops_per_s, p50, p99, hold_mean
    );
    (ops_per_s, p50, p99)
}

/// p99 of an unsorted latency sample (sorts in place).
fn p99_ns(lats: &mut [u64]) -> u64 {
    lats.sort_unstable();
    lats[(lats.len() * 99 / 100).min(lats.len() - 1)]
}

/// Map a Zipf rank onto a (page, block) address so the hot head spreads
/// across pages — and therefore shards — instead of piling into page 0.
fn rank_to_block(rank: u64, pages: u64) -> (u64, usize) {
    (rank % pages, ((rank / pages) % 64) as usize)
}

/// Client-observed latency of one 64-byte copy out of resident
/// uncompressed memory — the floor the cached read path is held to.
/// Timed exactly like the cached hot probe in [`run_zipf_arm`] (rank
/// draw inside the window) so the two are comparable.
fn raw_probe_p99(ops: usize, hot_ranks: u64) -> u64 {
    let src = vec![7u8; 64 * hot_ranks as usize];
    let mut dst = [0u8; 64];
    let mut rng = Rng::new(0xD15C0);
    let mut lats = Vec::with_capacity(ops);
    for _ in 0..ops {
        let t0 = Instant::now();
        let off = rng.below(hot_ranks) as usize * 64;
        dst.copy_from_slice(&src[off..off + 64]);
        std::hint::black_box(&dst);
        lats.push(t0.elapsed().as_nanos() as u64);
    }
    p99_ns(&mut lats)
}

/// One Zipfian arm: 8 shards, a hot-block cache sized to `cache_pct`%
/// of the logical footprint, `threads` clients of skewed GET/PUT
/// traffic (Zipf s = 1.0 over block addresses). Near-constant pages
/// keep the compressed frames tiny, so the uncompressed cache tier is
/// the dominant footprint cost — the trade the sweep exposes. Returns
/// (hit_rate, p99_ns, hot_p99_ns, footprint_savings).
fn run_zipf_arm(
    cache_pct: usize,
    threads: usize,
    pages: u64,
    ops_per_thread: usize,
) -> (f64, u64, u64, f64) {
    let logical = pages as usize * 4096;
    let cache_bytes = logical * cache_pct / 100;
    let cfg = GbdiConfig::default();
    let image = vec![0u8; 1 << 16];
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards: 8, cache_bytes, ..Default::default() },
        codec,
    )
    .expect("service start");
    svc.submit_batch((0..pages).map(|i| (i, vec![0u8; 4096])).collect());
    svc.flush();
    let total_blocks = pages * 64;

    // mixed skewed traffic: drives admissions, promotions, and deferred
    // writes while we record client-observed per-op latency
    let mut lats: Vec<u64> = Vec::with_capacity(threads * ops_per_thread);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let svc = &svc;
                s.spawn(move || {
                    let mut rng = Rng::new(0xF00D ^ (t as u64).wrapping_mul(0x9E3779B9));
                    let mut line = [0u8; 64];
                    let mut lat = Vec::with_capacity(ops_per_thread);
                    for _ in 0..ops_per_thread {
                        let op0 = Instant::now();
                        let (pid, blk) = rank_to_block(rng.zipf(total_blocks, 1.0), pages);
                        if rng.below(2) == 0 {
                            svc.read_block(pid, blk, &mut line).unwrap();
                        } else {
                            svc.write_block(pid, blk, &line).unwrap();
                        }
                        lat.push(op0.elapsed().as_nanos() as u64);
                    }
                    lat
                })
            })
            .collect();
        for h in handles {
            lats.extend(h.join().expect("client thread"));
        }
    });
    let p99 = p99_ns(&mut lats);

    // hot probe: the head of the Zipf distribution is resident at any
    // nonzero cache size — re-read it, timing each op exactly like
    // raw_probe_p99 does
    let hot_ranks = 64u64.min(total_blocks);
    let mut line = [0u8; 64];
    for r in 0..hot_ranks {
        // two touches: admit the block if it was evicted, then set its
        // reference bit so the probe window cannot push it out
        let (pid, blk) = rank_to_block(r, pages);
        svc.read_block(pid, blk, &mut line).unwrap();
        svc.read_block(pid, blk, &mut line).unwrap();
    }
    let probe_ops = (threads * ops_per_thread / 4).clamp(4_096, 20_000);
    let mut rng = Rng::new(0xCAFE);
    let mut hot_lats = Vec::with_capacity(probe_ops);
    for _ in 0..probe_ops {
        let t0 = Instant::now();
        let (pid, blk) = rank_to_block(rng.below(hot_ranks), pages);
        svc.read_block(pid, blk, &mut line).unwrap();
        std::hint::black_box(&line);
        hot_lats.push(t0.elapsed().as_nanos() as u64);
    }
    let hot_p99 = p99_ns(&mut hot_lats);

    let totals = svc.cache_totals();
    let (logical_b, stored_b, _) = svc.storage_ratio();
    let savings = logical_b as f64 / stored_b.max(1) as f64;
    svc.shutdown();
    println!(
        "cache {:>3}%: hit rate {:>5.1}%   p99 {:>7} ns   hot p99 {:>6} ns   \
         footprint savings {:>6.2}x",
        cache_pct,
        totals.hit_rate() * 100.0,
        p99,
        hot_p99,
        savings
    );
    (totals.hit_rate(), p99, hot_p99, savings)
}

fn main() {
    let fast = std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1");
    let threads = 8usize;
    let pages: u64 = if fast { 192 } else { 512 };
    let ops_per_thread: usize = if fast { 8_000 } else { 50_000 };
    let shard_counts: &[usize] = if fast { &[1, 2, 8] } else { &[1, 2, 4, 8, 16] };
    println!(
        "== concurrent serving: {threads} clients, {pages} pages, 50/50 block GET/PUT ==\n"
    );
    let image = workloads::by_name("mcf").unwrap().generate(1 << 20, 7);

    let mut b = Bencher::new();
    let mut ops_at_1 = 0.0f64;
    let mut ops_at_8 = 0.0f64;
    for &shards in shard_counts {
        let (ops_per_s, p50, p99) =
            run_arm(shards, threads, pages, ops_per_thread, &image, IntegrityConfig::default());
        b.metric(&format!("ops_per_s/shards={shards}"), ops_per_s);
        b.metric(&format!("p50_ns/shards={shards}"), p50 as f64);
        b.metric(&format!("p99_ns/shards={shards}"), p99 as f64);
        if shards == 1 {
            ops_at_1 = ops_per_s;
        }
        if shards == 8 {
            ops_at_8 = ops_per_s;
        }
    }
    let speedup = ops_at_8 / ops_at_1.max(1e-9);
    b.metric("speedup/8_shards_vs_1", speedup);
    println!("\n8 shards vs 1 shard at {threads} clients: {speedup:.2}x aggregate throughput");
    // enforce the bar only on full runs with real parallelism: the fast
    // CI smoke (one short trial on a shared runner) emits the numbers
    // for inspection but must not turn scheduler noise into a red build
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if !fast && cores >= 4 {
        assert!(
            speedup >= 2.0,
            "8 shards must at least double 1-shard throughput (got {speedup:.2}x on {cores} cores)"
        );
    } else {
        println!("(assertion skipped: fast={fast}, {cores} hardware threads)");
    }

    // ---- hot-block cache sweep: Zipfian traffic, 8 shards ----
    let zipf_ops: usize = if fast { 4_000 } else { 25_000 };
    println!(
        "\n== Zipfian hot-set serving: 8 shards, {threads} clients, s=1.0, \
         cache 0-20% of footprint ==\n"
    );
    let raw_p99 = raw_probe_p99(20_000, 64);
    println!("raw-memcpy probe p99: {raw_p99} ns (the uncompressed floor)\n");
    b.metric("zipf_raw_probe_p99_ns", raw_p99 as f64);
    let mut at_10pct = (0.0f64, 0u64, 0u64, 0.0f64);
    for &pct in &[0usize, 5, 10, 20] {
        let arm = run_zipf_arm(pct, threads, pages, zipf_ops);
        b.metric(&format!("zipf_hit_rate/cache_pct={pct}"), arm.0);
        b.metric(&format!("zipf_p99_ns/cache_pct={pct}"), arm.1 as f64);
        b.metric(&format!("zipf_hot_p99_ns/cache_pct={pct}"), arm.2 as f64);
        b.metric(&format!("zipf_footprint_savings/cache_pct={pct}"), arm.3);
        if pct == 10 {
            at_10pct = arm;
        }
    }
    // the cache sweep configuration is part of the measurement
    // environment: the regression gate must never compare this run
    // against a baseline captured under a different cache setup
    b.tag("cache", "zipf-sweep-0-5-10-20pct");
    if !fast && cores >= 4 {
        let (hit, _, hot_p99, savings) = at_10pct;
        assert!(
            hot_p99 as f64 <= 2.0 * raw_p99 as f64,
            "hot-probe p99 at 10% cache must stay within 2x of raw memcpy \
             (got {hot_p99} ns vs raw {raw_p99} ns, hit rate {hit:.2})"
        );
        assert!(
            savings >= 5.0,
            "footprint savings at 10% cache must stay >= 5x (got {savings:.2}x)"
        );
    } else {
        println!("(cache assertions skipped: fast={fast}, {cores} hardware threads)");
    }

    // ---- integrity plane overhead: 8 shards, same mixed traffic ----
    // Four arms isolate where the cycles go: `off` is the baseline the
    // shard sweep also measures; `digest` pays only the incremental
    // per-page CRC maintenance on writes; `verify` adds the O(page)
    // hash on every frame decode (the strong never-serve-wrong mode);
    // `verify+scrub` piles an aggressive background scrubber on top.
    println!("\n== integrity plane overhead: 8 shards, {threads} clients ==\n");
    let modes: [(&str, IntegrityConfig); 4] = [
        ("off", IntegrityConfig::default()),
        ("digest", IntegrityConfig { enabled: true, verify_reads: false, scrub_mib_s: 0 }),
        ("verify", IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 0 }),
        ("verify+scrub", IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 256 }),
    ];
    let mut ops_int_off = 0.0f64;
    let mut ops_int_full = 0.0f64;
    for (mode, icfg) in modes {
        println!("mode {mode}:");
        let (ops_per_s, p50, p99) = run_arm(8, threads, pages, ops_per_thread, &image, icfg);
        b.metric(&format!("integrity_ops_per_s/mode={mode}"), ops_per_s);
        b.metric(&format!("integrity_p50_ns/mode={mode}"), p50 as f64);
        b.metric(&format!("integrity_p99_ns/mode={mode}"), p99 as f64);
        match mode {
            "off" => ops_int_off = ops_per_s,
            "verify+scrub" => ops_int_full = ops_per_s,
            _ => {}
        }
    }
    let retained = ops_int_full / ops_int_off.max(1e-9);
    b.metric("integrity_throughput_retained/full_vs_off", retained);
    println!(
        "\nfull integrity (verify + 256 MiB/s scrub) retains {:.0}% of unchecked throughput",
        retained * 100.0
    );
    // the mode set is part of the measurement environment, like the
    // cache sweep's: never diff against a baseline with different arms
    b.tag("integrity", "off-digest-verify-scrub256");
    // catastrophic-regression guard only: the plane is allowed to cost,
    // but an order-of-magnitude collapse means a hot-path accident
    if !fast && cores >= 4 {
        assert!(
            retained >= 0.2,
            "full integrity must retain >= 20% of unchecked throughput (got {retained:.2})"
        );
    } else {
        println!("(integrity assertion skipped: fast={fast}, {cores} hardware threads)");
    }

    std::fs::create_dir_all("target").ok();
    b.write_csv("target/concurrent_serving.csv").ok();
    println!("csv: target/concurrent_serving.csv");
    match b.write_bench_json("concurrent_serving") {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("json write failed: {e}"),
    }
}
