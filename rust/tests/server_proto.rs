//! End-to-end tests for the GBN1 network front end: a real
//! [`gbdi::server::Server`] on an ephemeral loopback port, driven
//! through [`gbdi::server::Client`] and through raw sockets.
//!
//! Covers the handshake and every op round-trip, the malformed-frame
//! contract (framing violations close the connection, decodable frames
//! with bad bodies answer `BadRequest` and keep it), a fuzz sweep that
//! must never kill the server, deterministic `RetryAfter` admission
//! sheds, the drain semantics of the SHUTDOWN op, the counter ledger
//! (client tallies == server stats == service metrics == per-shard
//! sums), and the shutdown-flushes-absorbed-writes guarantee the cache
//! tier owes its callers.

use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::server::protocol::{self, stats_field, Reply, Request, Status};
use gbdi::server::{Client, Server, ServerConfig};
use gbdi::util::prng::Rng;
use gbdi::{workloads, BlockCodec, CodecKind, GbdiConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A static-codec service (analysis-free, deterministic) behind a GBN1
/// server on an ephemeral loopback port.
fn server_with(shards: usize, cache_bytes: usize, max_inflight_pages: u64) -> Server {
    let image = workloads::by_name("mcf").unwrap().generate(1 << 16, 7);
    let codec: Arc<dyn BlockCodec> =
        Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards, cache_bytes, ..Default::default() },
        codec,
    )
    .expect("service start");
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_inflight_pages,
        ..Default::default()
    };
    Server::bind(svc, cfg).expect("server bind")
}

/// Raw-socket handshake: send the magic, swallow the hello.
fn handshake(server: &Server) -> TcpStream {
    let mut s = TcpStream::connect(server.local_addr()).expect("connect");
    s.write_all(&protocol::MAGIC).unwrap();
    let mut hello = [0u8; 8];
    s.read_exact(&mut hello).unwrap();
    protocol::parse_server_hello(&hello).unwrap();
    s
}

fn read_response(s: &mut TcpStream) -> protocol::Response {
    let payload = protocol::read_frame(s, 8 << 20).unwrap().expect("response frame");
    protocol::decode_response(&payload).unwrap()
}

/// Read until EOF (or timeout); returns total bytes drained.
fn drain(s: &mut TcpStream) -> usize {
    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
    let mut total = 0;
    let mut buf = [0u8; 256];
    loop {
        match s.read(&mut buf) {
            Ok(0) => return total,
            Ok(n) => total += n,
            Err(_) => return total,
        }
    }
}

fn mcf_pages(n: u64) -> Vec<(u64, Vec<u8>)> {
    let w = workloads::by_name("mcf").unwrap();
    (0..n).map(|i| (i, w.generate(4096, i))).collect()
}

#[test]
fn handshake_and_all_ops_roundtrip() {
    let server = server_with(4, 0, 0);
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    assert_eq!(c.block_bytes(), 64, "hello must carry the service block size");

    let pages = mcf_pages(8);
    assert_eq!(c.put_pages(&pages).unwrap(), 8);
    c.flush().unwrap();

    // single-block GET matches the source bytes
    assert_eq!(c.get_block(3, 9).unwrap(), &pages[3].1[9 * 64..10 * 64]);

    // batched GET: two hits plus a missing-page slot
    let reply = c.request(&Request::GetBlocks(vec![(0, 0), (7, 63), (999, 0)])).unwrap();
    match reply.body {
        Reply::Blocks { items } => {
            assert_eq!(items.len(), 3);
            assert_eq!(items[0].as_deref().unwrap(), &pages[0].1[..64]);
            assert_eq!(items[1].as_deref().unwrap(), &pages[7].1[63 * 64..]);
            assert!(items[2].is_none(), "a missing page must come back as a miss slot");
        }
        other => panic!("unexpected batched-GET reply {other:?}"),
    }

    // single-block PUT, re-read through a two-block RANGE
    let line = vec![0x5A; 64];
    c.put_block(1, 2, line.clone()).unwrap();
    let range = c.read_range(1, 2, 2).unwrap();
    assert_eq!(&range[..64], &line[..]);
    assert_eq!(&range[64..], &pages[1].1[3 * 64..4 * 64]);

    // STATS reflects the traffic; Reanalyze is a no-op on a static codec
    let stats = c.stats().unwrap();
    assert_eq!(stats.get(stats_field::PAGES_IN), 8);
    assert_eq!(stats.get(stats_field::SHARDS), 4);
    assert_eq!(stats.get(stats_field::OPS_ERR), 0);
    assert_eq!(c.reanalyze().unwrap(), 0);

    // pipelined sends drain strictly in request order
    let mut ids = Vec::new();
    for i in 0..16u64 {
        ids.push(c.send(&Request::GetBlock { page_id: i % 8, block: 0 }).unwrap());
    }
    for id in ids {
        assert_eq!(c.recv().unwrap().req_id, id, "responses must drain in request order");
    }
    drop(c);

    let (svc, snap, _) = server.stop();
    assert!(snap.accepted_conns >= 1);
    assert_eq!(snap.protocol_errors, 0);
    svc.shutdown();
}

#[test]
fn bad_magic_closes_without_a_hello() {
    let server = server_with(1, 0, 0);
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    s.write_all(b"HTTP").unwrap();
    assert_eq!(drain(&mut s), 0, "a bad-magic connection must be closed hello-free");
    // the server is still alive for well-behaved clients
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    assert!(c.stats().unwrap().get(stats_field::PROTOCOL_ERRORS) >= 1);
    drop(c);
    let (svc, _, _) = server.stop();
    svc.shutdown();
}

#[test]
fn framing_violations_close_the_connection() {
    let server = server_with(1, 0, 0);
    for bad_len in [0u32, 1, 8, u32::MAX] {
        let mut s = handshake(&server);
        s.write_all(&bad_len.to_le_bytes()).unwrap();
        // the server may already have closed on the bad header, so the
        // trailing junk write is allowed to fail
        let _ = s.write_all(&[0u8; 8]);
        assert_eq!(drain(&mut s), 0, "frame length {bad_len} must close the connection");
    }
    // truncation mid-frame: a valid header whose body never arrives
    let s = handshake(&server);
    let mut s2 = s.try_clone().unwrap();
    s2.write_all(&100u32.to_le_bytes()).unwrap();
    s2.write_all(&[0u8; 10]).unwrap();
    drop(s2);
    drop(s);
    // a healthy second connection is unaffected
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    assert!(c.stats().unwrap().get(stats_field::PROTOCOL_ERRORS) >= 4);
    drop(c);
    let (svc, _, _) = server.stop();
    svc.shutdown();
}

#[test]
fn bad_bodies_get_bad_request_and_the_connection_survives() {
    let server = server_with(1, 0, 0);
    let mut s = handshake(&server);

    // unknown op byte: decodable framing, undecodable body
    let mut payload = 77u64.to_le_bytes().to_vec();
    payload.push(0x2A);
    protocol::write_frame(&mut s, &payload).unwrap();
    let resp = read_response(&mut s);
    assert_eq!(resp.req_id, 77, "req id must be salvaged from the bad frame");
    match resp.body {
        Reply::Error { status, op, .. } => {
            assert_eq!(status, Status::BadRequest);
            assert_eq!(op, 0x2A, "the offending op byte must be echoed");
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }

    // truncated GetBlock body: same outcome, same still-open connection
    let mut payload = 78u64.to_le_bytes().to_vec();
    payload.push(2);
    payload.extend_from_slice(&[1, 2]);
    protocol::write_frame(&mut s, &payload).unwrap();
    let resp = read_response(&mut s);
    assert_eq!(resp.req_id, 78);
    assert!(matches!(resp.body, Reply::Error { status: Status::BadRequest, .. }));

    // the connection still serves valid requests afterwards
    protocol::write_frame(&mut s, &protocol::encode_request(79, &Request::Stats)).unwrap();
    let resp = read_response(&mut s);
    assert_eq!(resp.req_id, 79);
    match resp.body {
        Reply::Stats(stats) => assert_eq!(stats.get(stats_field::OPS_ERR), 2),
        other => panic!("expected a stats reply, got {other:?}"),
    }
    drop(s);
    let (svc, snap, _) = server.stop();
    assert_eq!(snap.protocol_errors, 0, "bad bodies are not framing violations");
    svc.shutdown();
}

#[test]
fn fuzzed_frames_never_kill_the_server() {
    let server = server_with(2, 0, 0);
    let mut rng = Rng::new(0xF0_2221);
    for round in 0..100u64 {
        let mut s = handshake(&server);
        let req = protocol::arbitrary_request(&mut rng);
        let mut payload = protocol::encode_request(round, &req);
        match rng.below(4) {
            0 => payload.truncate(rng.below(payload.len() as u64 + 1) as usize),
            1 => {
                let i = rng.below(payload.len() as u64) as usize;
                payload[i] ^= 1 << rng.below(8);
            }
            2 => {
                for _ in 0..=rng.below(16) {
                    payload.push(rng.next_u64() as u8);
                }
            }
            _ => {}
        }
        // under-length payloads go out with their (invalid) real length
        let _ = s.write_all(&(payload.len() as u32).to_le_bytes());
        let _ = s.write_all(&payload);
        let _ = s.flush();
        drop(s); // never read: exercises writer-side broken pipes too
    }
    // the server survived every round and still serves a clean client
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.get(stats_field::ACCEPTED_CONNS) >= 100);
    drop(c);
    let (svc, _, _) = server.stop();
    svc.shutdown();
}

#[test]
fn fuzzed_replies_never_kill_the_client_decoder() {
    // The client-side twin of `fuzzed_frames_never_kill_the_server`:
    // a chaos proxy can hand the client truncated, bit-flipped, or
    // garbage-extended reply payloads, and `decode_response` must
    // return a clean error (or happen to decode) — never panic, never
    // allocate absurdly, never loop. Pure in-memory, no server needed.
    let mut rng = Rng::new(0xC11E_27);
    let mut decoded_ok = 0u32;
    let mut rejected = 0u32;
    for _ in 0..2000u32 {
        let resp = protocol::arbitrary_response(&mut rng);
        let mut payload = protocol::encode_response(&resp);
        match rng.below(4) {
            0 => payload.truncate(rng.below(payload.len() as u64 + 1) as usize),
            1 => {
                let i = rng.below(payload.len() as u64) as usize;
                payload[i] ^= 1 << rng.below(8);
            }
            2 => {
                for _ in 0..=rng.below(16) {
                    payload.push(rng.next_u64() as u8);
                }
            }
            _ => {}
        }
        match protocol::decode_response(&payload) {
            Ok(_) => decoded_ok += 1,
            Err(msg) => {
                assert!(!msg.is_empty(), "decode errors must say what broke");
                rejected += 1;
            }
        }
    }
    // both outcomes must actually occur or the sweep proves nothing
    assert!(decoded_ok > 0, "no mutation left a decodable payload");
    assert!(rejected > 0, "no mutation was ever rejected");
    // and untouched encodings always round-trip
    for _ in 0..200u32 {
        let resp = protocol::arbitrary_response(&mut rng);
        let payload = protocol::encode_response(&resp);
        assert_eq!(protocol::decode_response(&payload).unwrap(), resp);
    }
}

#[test]
fn admission_control_sheds_with_retry_after() {
    // inflight cap of 4 pages: an 8-page batch must shed, deterministically
    let server = server_with(1, 0, 4);
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    let pages: Vec<(u64, Vec<u8>)> = (0..8u64).map(|i| (i, vec![i as u8; 4096])).collect();
    let reply = c.request(&Request::PutPages(pages)).unwrap();
    match reply.body {
        Reply::Error { status, retry_ms, .. } => {
            assert_eq!(status, Status::RetryAfter);
            assert!(retry_ms > 0, "a shed must tell the client when to come back");
        }
        other => panic!("a batch over the inflight cap must shed, got {other:?}"),
    }
    assert_eq!(c.stats().unwrap().get(stats_field::SHED_OPS), 1);
    drop(c);
    let (svc, snap, _) = server.stop();
    assert_eq!(snap.shed_ops, 1);
    svc.shutdown();
}

#[test]
fn shutdown_op_drains_then_refuses_work() {
    let server = server_with(1, 0, 0);
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    assert!(!server.shutdown_requested());
    c.shutdown().unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    while !server.shutdown_requested() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(server.shutdown_requested(), "the SHUTDOWN op must set the drain flag");

    // draining: new work is refused, STATS still answers
    let reply = c.request(&Request::Flush).unwrap();
    assert!(matches!(reply.body, Reply::Error { status: Status::ShuttingDown, .. }));
    assert!(c.stats().is_ok(), "STATS must still answer while draining");
    drop(c);
    let (svc, _, _) = server.stop();
    svc.shutdown();
}

#[test]
fn stats_counters_stay_consistent() {
    let server = server_with(4, 0, 0);
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    let pages = mcf_pages(6);
    assert_eq!(c.put_pages(&pages).unwrap(), 6);
    c.flush().unwrap();

    let mut reads = 0u64;
    let mut writes = 0u64;
    for i in 0..30u64 {
        if i % 3 == 0 {
            c.put_block(i % 6, (i % 64) as u32, vec![i as u8; 64]).unwrap();
            writes += 1;
        } else {
            c.get_block(i % 6, (i % 64) as u32).unwrap();
            reads += 1;
        }
    }

    // client-side ledger: put_pages + flush + 30 block ops + this STATS
    // op (which counts itself before executing)
    let stats = c.stats().unwrap();
    assert_eq!(stats.get(stats_field::OPS_OK), 1 + 1 + 30 + 1);
    assert_eq!(stats.get(stats_field::OPS_ERR), 0);
    assert_eq!(stats.get(stats_field::BLOCK_READS), reads);
    assert_eq!(stats.get(stats_field::BLOCK_WRITES), writes);
    assert_eq!(stats.get(stats_field::PAGES_IN), 6);
    drop(c);

    let (svc, snap, _) = server.stop();
    assert_eq!(snap.ops_ok, 33);
    assert_eq!(snap.ops_err, 0);
    assert_eq!(snap.frames_in, snap.frames_out, "every request frame must get one response");

    // server-side ledger: per-shard sums == service totals == client tallies
    let shard_reads: u64 = svc.shard_metrics().iter().map(|s| s.block_reads).sum();
    let shard_writes: u64 = svc.shard_metrics().iter().map(|s| s.block_writes).sum();
    let m = svc.shutdown();
    assert_eq!(shard_reads, m.block_reads);
    assert_eq!(shard_writes, m.block_writes);
    assert_eq!(m.block_reads, reads);
    assert_eq!(m.block_writes, writes);
    assert_eq!(m.pages_in, 6);
}

#[test]
fn server_stop_flushes_absorbed_writes() {
    let server = server_with(2, 1 << 20, 0);
    let mut c = Client::connect(&server.local_addr().to_string()).unwrap();
    let pages = mcf_pages(4);
    c.put_pages(&pages).unwrap();
    c.flush().unwrap();

    // the first write admits the block into the cache; the second is
    // absorbed: the cached copy goes dirty and the frame keeps its
    // stale encoding until a flush
    let line_b = vec![0x22u8; 64];
    c.put_block(1, 5, vec![0x11u8; 64]).unwrap();
    c.put_block(1, 5, line_b.clone()).unwrap();
    let stats = c.stats().unwrap();
    assert!(
        stats.get(stats_field::DIRTY_BLOCKS) >= 1,
        "the second write must defer, not recompress"
    );
    drop(c);

    // kill the server right after the absorb: stop() must drain the
    // connections and flush the deferred write before handing the
    // service back
    let (svc, _, flushed) = server.stop();
    assert!(flushed >= 1, "stop() must flush deferred dirty blocks");
    assert_eq!(svc.cache_totals().dirty_blocks, 0);
    let mut expect = pages[1].1.clone();
    expect[5 * 64..6 * 64].copy_from_slice(&line_b);
    assert_eq!(svc.read_page(1).unwrap(), expect, "absorbed write lost on shutdown");
    svc.shutdown();
}
