//! Property-based integration tests (self-built testkit; proptest is
//! unavailable offline): codec roundtrips over adversarial generated
//! inputs, bitstream invariants, and coordinator-facing table invariants.

use gbdi::baselines::{all_codecs, Codec};
use gbdi::cluster::{apply_delta, wrapping_delta};
use gbdi::gbdi::table::GlobalBaseTable;
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::util::bits::{signed_width, BitReader, BitWriter};
use gbdi::util::testkit::{check, BytesGen, Gen, PairGen, RangeGen, WordsGen};
use gbdi::value::WordSize;

#[test]
fn prop_gbdi_roundtrips_arbitrary_bytes() {
    let gen = BytesGen { max_len: 4096 };
    check(0xA11CE, 60, &gen, |data| {
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(data, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(data);
        gbdi::gbdi::decode::decompress_image(&comp).map(|d| d == *data).unwrap_or(false)
    });
}

#[test]
fn prop_gbdi_never_expands_much() {
    // bounded expansion: tag bits + table + framing only
    let gen = BytesGen { max_len: 8192 };
    check(0xB0B, 60, &gen, |data| {
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(data, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(data);
        comp.total_len() <= data.len() + data.len() / 32 + 600
    });
}

#[test]
fn prop_all_baselines_roundtrip() {
    let gen = BytesGen { max_len: 2048 };
    for codec in all_codecs() {
        check(0xC0DEC ^ codec.name().len() as u64, 30, &gen, |data| {
            let comp = codec.compress(data);
            codec.decompress(&comp, data.len()).map(|d| d == *data).unwrap_or(false)
        });
    }
}

#[test]
fn prop_gbdi_roundtrips_clustered_words() {
    let gen = WordsGen { max_words: 2048, centers: 5 };
    check(0x60D, 60, &gen, |words| {
        let data: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(&data, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(&data);
        gbdi::gbdi::decode::decompress_image(&comp).map(|d| d == data).unwrap_or(false)
    });
}

#[test]
fn prop_bitstream_roundtrips_any_field_sequence() {
    struct FieldsGen;
    impl Gen for FieldsGen {
        type Item = Vec<(u64, u32)>;
        fn gen(&self, rng: &mut gbdi::util::prng::Rng) -> Self::Item {
            (0..rng.below(200))
                .map(|_| {
                    let n = rng.range(1, 65) as u32;
                    let v = if n == 64 { rng.next_u64() } else { rng.next_u64() & ((1 << n) - 1) };
                    (v, n)
                })
                .collect()
        }
        fn shrink(&self, v: &Self::Item) -> Vec<Self::Item> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_vec(), v[1..].to_vec()]
            }
        }
    }
    check(0xB175, 200, &FieldsGen, |fields| {
        let mut w = BitWriter::new();
        for &(v, n) in fields {
            w.put(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        fields.iter().all(|&(v, n)| r.get(n) == Ok(v))
    });
}

#[test]
fn prop_wrapping_delta_inverts() {
    let gen = PairGen(RangeGen { lo: 0, hi: u32::MAX as u64 + 1 }, RangeGen { lo: 0, hi: u32::MAX as u64 + 1 });
    check(0xDE17A, 500, &gen, |&(v, c)| {
        let d = wrapping_delta(v, c, WordSize::W32);
        apply_delta(c, d, WordSize::W32) == v && signed_width(d) <= 33
    });
}

#[test]
fn prop_table_serialization_roundtrips() {
    struct TableGen;
    impl Gen for TableGen {
        type Item = Vec<(u64, u32)>;
        fn gen(&self, rng: &mut gbdi::util::prng::Rng) -> Self::Item {
            (0..rng.range(1, 100))
                .map(|_| (rng.next_u32() as u64, rng.below(25) as u32))
                .collect()
        }
    }
    check(0x7AB1E, 200, &TableGen, |pairs| {
        let t = GlobalBaseTable::new(pairs.clone(), WordSize::W32, 9);
        let bytes = t.serialize();
        match GlobalBaseTable::deserialize(&bytes) {
            Ok((t2, n)) => t2 == t && n == bytes.len(),
            Err(_) => false,
        }
    });
}

#[test]
fn prop_best_base_result_is_always_encodable() {
    struct QueryGen;
    impl Gen for QueryGen {
        type Item = (Vec<(u64, u32)>, Vec<u64>);
        fn gen(&self, rng: &mut gbdi::util::prng::Rng) -> Self::Item {
            let pairs: Vec<(u64, u32)> = (0..rng.range(1, 64))
                .map(|_| (rng.next_u32() as u64, [0u32, 4, 8, 12, 16, 20, 24][rng.below(7) as usize]))
                .collect();
            let queries: Vec<u64> = (0..64).map(|_| rng.next_u32() as u64).collect();
            (pairs, queries)
        }
    }
    check(0xBE57, 200, &QueryGen, |(pairs, queries)| {
        let t = GlobalBaseTable::new(pairs.clone(), WordSize::W32, 0);
        queries.iter().all(|&v| match t.best_base(v) {
            Some((idx, d, w)) => {
                let e = t.get(idx);
                // the contract the encoder depends on: delta fits the
                // entry's class, the width is the entry's class, and the
                // decoder's reconstruction inverts exactly
                e.width == w && e.fits(d) && apply_delta(e.base, d, WordSize::W32) == v
            }
            None => t.best_base_exhaustive(v).is_none(),
        })
    });
}

#[test]
fn prop_w64_scan_matches_exhaustive() {
    struct W64TableGen;
    impl Gen for W64TableGen {
        type Item = (Vec<(u64, u32)>, Vec<u64>);
        fn gen(&self, rng: &mut gbdi::util::prng::Rng) -> Self::Item {
            let pairs: Vec<(u64, u32)> = (0..rng.range(1, 48))
                .map(|_| (rng.next_u64(), [0u32, 4, 8, 16, 24, 32][rng.below(6) as usize]))
                .collect();
            let mut queries: Vec<u64> = (0..32).map(|_| rng.next_u64()).collect();
            // bias half the queries near bases so fits actually occur
            for i in 0..16.min(pairs.len()) {
                queries[i] = pairs[i].0.wrapping_add(rng.range_i64(-1000, 1000) as u64);
            }
            (pairs, queries)
        }
    }
    check(0x64B17, 150, &W64TableGen, |(pairs, queries)| {
        let t = GlobalBaseTable::new(pairs.clone(), WordSize::W64, 0);
        queries.iter().all(|&v| {
            let fast = t.best_base(v);
            let slow = t.best_base_exhaustive(v);
            match (fast, slow) {
                (None, None) => true,
                (Some((i, d, w)), Some((_, _, sw))) => {
                    let e = t.get(i);
                    w == sw && e.width == w && e.fits(d)
                        && apply_delta(e.base, d, WordSize::W64) == v
                }
                _ => false,
            }
        })
    });
}

#[test]
fn prop_parallel_stream_decodes_after_corruption_attempts() {
    // chunked (parallel) streams must be as corruption-safe as serial ones
    let gen = WordsGen { max_words: 8192, centers: 4 };
    check(0xC4A9, 10, &gen, |words| {
        // tile up past one 256 KiB chunk so the chunked path actually runs
        let one: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        if one.len() < 1024 {
            return true; // too small to exercise chunking
        }
        let mut data = Vec::new();
        while data.len() <= 4096 * 64 {
            data.extend_from_slice(&one);
            data.push(data.len() as u8); // avoid degenerate all-identical tiles
        }
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(&data, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let (comp, _) = codec.compress_image_parallel(&data, 4);
        // exact decode
        if gbdi::gbdi::decode::decompress_image(&comp).map(|d| d == data).unwrap_or(false) {
            // and corrupting the frame must never panic
            let mut bad = comp.clone();
            if !bad.payload.is_empty() {
                bad.payload[0] ^= 0xFF;
                let _ = gbdi::gbdi::decode::decompress_image(&bad);
            }
            let mut bad = comp;
            bad.chunk_blocks = 7; // wrong chunking
            let _ = gbdi::gbdi::decode::decompress_image(&bad);
            true
        } else {
            false
        }
    });
}
