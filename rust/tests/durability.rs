//! Crash-safety proof for the durability layer: a deterministic
//! crash-injection sweep over every filesystem boundary of a randomized
//! schedule, a clean-restart exactness check, and a torn-write /
//! bitflip / truncation corruption fuzz.
//!
//! The oracle is a pure in-memory model (page id → plaintext content)
//! advanced op-by-op next to a reference [`PageStore`]: after a crash
//! at *any* write / fsync / create / rename / remove boundary
//! ([`FaultFs`] counts them all), the recovered store's contents must
//! equal the model's state after some prefix of the schedule — the
//! formal statement of "no acknowledged state is half-applied and
//! nothing recovers to a state that never existed". The schedule mixes
//! puts, in-place block writes (including ones absorbed by the
//! hot-block cache tier, which the WAL captures at absorb time),
//! removes, codec publishes, online shard resizes, and checkpoints.

use gbdi::container::{self, Container};
use gbdi::coordinator::{PageStore, ShardedPageStore, StoredPage};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::persist::recover::recover;
use gbdi::persist::{DurableStore, FaultFs, PersistConfig, Vfs, MANIFEST_FILE, WAL_FILE};
use gbdi::util::prng::Rng;
use gbdi::workloads;
use gbdi::{BlockCodec, Frame};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

const DIR: &str = "data";
const ID_SPACE: u64 = 16;
const PAGE_BYTES: usize = 1024;
const BLOCKS: usize = PAGE_BYTES / 64;

/// One logical schedule step. Every mutation the durable facade logs,
/// plus the two purely-operational ops (resize reroutes pages and
/// rewrites the checkpoint, checkpoint folds the WAL) that add the
/// juiciest crash boundaries without changing observable content.
enum Op {
    Put { id: u64, img: usize, codec: usize },
    Write { id: u64, block: usize, data: Vec<u8> },
    Remove { id: u64 },
    Publish { codec: usize },
    Resize { shards: usize },
    Checkpoint,
}

/// Page images, versioned codecs, and pre-serialized GBC1 containers
/// (`containers[img][codec]`) so schedule replay parses instead of
/// recompressing.
struct Fixtures {
    imgs: Vec<Vec<u8>>,
    codecs: Vec<Arc<dyn BlockCodec>>,
    containers: Vec<Vec<Vec<u8>>>,
}

fn fixtures() -> Fixtures {
    let cfg = GbdiConfig::default();
    let imgs: Vec<Vec<u8>> = ["mcf", "fluidanimate", "perlbench"]
        .iter()
        .enumerate()
        .map(|(i, n)| workloads::by_name(n).unwrap().generate(PAGE_BYTES, i as u64 + 9))
        .collect();
    let codecs: Vec<Arc<dyn BlockCodec>> = ["svm", "mcf"]
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let img = workloads::by_name(n).unwrap().generate(4096, i as u64 + 1);
            let mut t = analyze::analyze_image(&img, &cfg);
            t.version = i as u64 + 1;
            Arc::new(GbdiCodec::new(t, cfg.clone())) as Arc<dyn BlockCodec>
        })
        .collect();
    let containers = imgs
        .iter()
        .map(|img| {
            codecs.iter().map(|c| container::compress(c.as_ref(), img).to_bytes()).collect()
        })
        .collect();
    Fixtures { imgs, codecs, containers }
}

fn build_schedule(seed: u64, fx: &Fixtures) -> Vec<Op> {
    let n_imgs = fx.imgs.len() as u64;
    let n_codecs = fx.codecs.len() as u64;
    let mut rng = Rng::new(seed);
    let mut ops = Vec::new();
    for _ in 0..60 {
        let id = rng.below(ID_SPACE);
        ops.push(match rng.below(12) {
            0..=4 => Op::Put {
                id,
                img: rng.below(n_imgs) as usize,
                codec: rng.below(n_codecs) as usize,
            },
            5..=8 => {
                let mut data = vec![0u8; 64];
                if rng.below(4) != 0 {
                    rng.fill_bytes(&mut data);
                }
                Op::Write { id, block: rng.below(BLOCKS as u64) as usize, data }
            }
            9 => Op::Remove { id },
            10 => Op::Publish { codec: rng.below(n_codecs) as usize },
            _ => Op::Resize { shards: 1 + rng.below(5) as usize },
        });
    }
    // pin the interesting boundaries regardless of the dice: an early
    // checkpoint, a split, a late checkpoint, and a merge
    ops[10] = Op::Checkpoint;
    ops[25] = Op::Resize { shards: 5 };
    ops[40] = Op::Checkpoint;
    ops[50] = Op::Resize { shards: 2 };
    ops
}

/// Advance the pure model: what page contents *should* be after the op.
fn apply_model(model: &mut BTreeMap<u64, Vec<u8>>, fx: &Fixtures, op: &Op) {
    match op {
        Op::Put { id, img, .. } => {
            model.insert(*id, fx.imgs[*img].clone());
        }
        Op::Write { id, block, data } => {
            if let Some(content) = model.get_mut(id) {
                content[block * 64..block * 64 + 64].copy_from_slice(data);
            }
        }
        Op::Remove { id } => {
            model.remove(id);
        }
        Op::Publish { .. } | Op::Resize { .. } | Op::Checkpoint => {}
    }
}

/// Advance the reference in-memory store (the satellite oracle for the
/// clean-restart arm, which also pins codec versions).
fn apply_reference(store: &mut PageStore, fx: &Fixtures, op: &Op) {
    match op {
        Op::Put { id, img, codec } => store.put(
            *id,
            StoredPage { frame: Frame::compress(Arc::clone(&fx.codecs[*codec]), &fx.imgs[*img]) },
        ),
        Op::Write { id, block, data } => {
            let _ = store.write_block(*id, *block, data);
        }
        Op::Remove { id } => {
            store.remove(*id);
        }
        Op::Publish { codec } => store.publish_codec(Arc::clone(&fx.codecs[*codec])),
        Op::Resize { .. } | Op::Checkpoint => {}
    }
}

fn apply_durable(ds: &DurableStore, fx: &Fixtures, op: &Op) -> gbdi::Result<()> {
    match op {
        Op::Put { id, img, codec } => {
            let frame =
                Frame::from_container(Container::from_bytes(&fx.containers[*img][*codec])?)?;
            ds.put(*id, StoredPage { frame })
        }
        Op::Write { id, block, data } => ds.write_block(*id, *block, data).map(|_| ()),
        Op::Remove { id } => ds.remove(*id).map(|_| ()),
        Op::Publish { codec } => ds.publish_codec(Arc::clone(&fx.codecs[*codec])),
        Op::Resize { shards } => ds.resize_shards(*shards).map(|_| ()),
        Op::Checkpoint => ds.checkpoint().map(|_| ()),
    }
}

/// Open the durable store over `fs` and replay the schedule. Returns
/// `None` if the injected crash fired (mid-open or mid-op); logical
/// rejections (e.g. a block write to a missing page) are part of the
/// schedule and do not stop the run.
fn run_schedule(
    fs: &FaultFs,
    ops: &[Op],
    fx: &Fixtures,
    cfg: &PersistConfig,
    shards: usize,
    cache_bytes: usize,
) -> Option<DurableStore> {
    let opened = DurableStore::open(Arc::new(fs.clone()), DIR, cfg.clone(), shards, cache_bytes);
    let Ok((ds, _)) = opened else {
        assert!(fs.crashed(), "open may only fail by injected crash");
        return None;
    };
    for op in ops {
        if apply_durable(&ds, fx, op).is_err() && fs.crashed() {
            return None;
        }
    }
    Some(ds)
}

/// Every page's plaintext content, via the production read path.
fn store_contents(store: &ShardedPageStore) -> BTreeMap<u64, Vec<u8>> {
    store
        .lagging_pages(u64::MAX)
        .into_iter()
        .map(|id| (id, store.read(id).expect("recovered page must decode")))
        .collect()
}

/// All model states along the schedule, `states[i]` = after `i` ops.
fn prefix_states(ops: &[Op], fx: &Fixtures) -> Vec<BTreeMap<u64, Vec<u8>>> {
    let mut model = BTreeMap::new();
    let mut states = vec![model.clone()];
    for op in ops {
        apply_model(&mut model, fx, op);
        states.push(model.clone());
    }
    states
}

/// The tentpole proof: arm the crash fuse at every single mutating-op
/// boundary the full schedule crosses, crash there, remount, recover,
/// and require the recovered contents to be *some* prefix state of the
/// model. Runs twice: strict WAL without the cache tier, then group
/// commit with a deliberately tiny cache so absorbed (deferred dirty)
/// writes sit in volatile cache memory at crash time and only their WAL
/// records survive.
#[test]
fn crash_at_every_boundary_recovers_a_prefix_state() {
    let fx = fixtures();
    for (batch, cache_bytes) in [(1usize, 0usize), (3, 2048)] {
        let cfg = PersistConfig { fsync_batch: batch, ..PersistConfig::default() };
        let ops = build_schedule(0xB007 ^ batch as u64, &fx);
        let states = prefix_states(&ops, &fx);
        let state_set: HashSet<_> = states.iter().cloned().collect();

        // dry run: count the boundaries and pin the happy path
        let fs = FaultFs::new();
        let ds = run_schedule(&fs, &ops, &fx, &cfg, 3, cache_bytes)
            .expect("no fuse armed, nothing may crash");
        assert_eq!(
            store_contents(ds.store()),
            *states.last().unwrap(),
            "durable replay diverged from the model (batch {batch}, cache {cache_bytes})"
        );
        assert!(ds.durability().checkpoints() >= 4, "schedule must actually checkpoint");
        let boundaries = fs.op_count();
        drop(ds);
        assert!(boundaries > 100, "schedule too small: only {boundaries} crash boundaries");

        for k in 0..boundaries {
            let fs = FaultFs::new();
            fs.set_fuse(k);
            let ds = run_schedule(&fs, &ops, &fx, &cfg, 3, cache_bytes);
            assert!(fs.crashed(), "boundary {k}/{boundaries}: fuse must fire");
            // ds may be Some if the crash landed in best-effort stale-
            // segment cleanup on the very last op — still a crash
            drop(ds);
            fs.revive();
            let (store, report) =
                recover(&fs, DIR, None, 0).expect("recovery after a crash must not error");
            let got = store_contents(&store);
            assert!(
                state_set.contains(&got),
                "boundary {k}/{boundaries} (batch {batch}, cache {cache_bytes}): recovered \
                 {} page(s) into a state that never existed; {report}",
                got.len(),
            );
        }
    }
}

/// Clean shutdown + reopen is *exact*: contents, page count, per-page
/// codec versions, and shard topology all survive, and the recovery
/// report counts zero damage.
#[test]
fn clean_restart_restores_the_exact_state() {
    let fx = fixtures();
    for (batch, cache_bytes) in [(1usize, 0usize), (4, 4096)] {
        let cfg = PersistConfig { fsync_batch: batch, ..PersistConfig::default() };
        let ops = build_schedule(0x5EED ^ batch as u64, &fx);
        let mut reference = PageStore::new();
        for op in &ops {
            apply_reference(&mut reference, &fx, op);
        }
        let finals = prefix_states(&ops, &fx).pop().unwrap();

        let fs = FaultFs::new();
        let ds = run_schedule(&fs, &ops, &fx, &cfg, 3, cache_bytes).expect("clean run");
        let shards_now = ds.store().shard_count();
        drop(ds);

        let (ds, report) =
            DurableStore::open(Arc::new(fs.clone()), DIR, cfg.clone(), shards_now, cache_bytes)
                .expect("clean reopen");
        assert!(!report.saw_damage(), "clean restart counted damage: {report}");
        let store = ds.store();
        assert_eq!(store.shard_count(), shards_now);
        assert_eq!(store.len(), reference.len(), "page count (batch {batch})");
        assert_eq!(store_contents(store), finals, "contents (batch {batch})");
        for (id, want) in &finals {
            assert_eq!(&reference.read(*id).unwrap(), want, "reference arm diverged on {id}");
            let ref_version = reference.get(*id).unwrap().codec_version();
            assert_eq!(
                store.with_page(*id, |p| p.codec_version()),
                Some(ref_version),
                "page {id} codec version (batch {batch})"
            );
        }
    }
}

/// What a corruption is allowed to do: lose suffixes/pages (counted, or
/// an exact record-boundary truncation) — but never fabricate content.
/// Every recovered page must hold bytes that id actually had at some
/// point of the schedule, and recovery must never panic or error.
#[test]
fn corrupted_files_recover_without_panics_or_fabricated_data() {
    let fx = fixtures();
    let cfg = PersistConfig::default(); // strict WAL
    let ops = build_schedule(0xF022, &fx);
    let states = prefix_states(&ops, &fx);
    let state_set: HashSet<_> = states.iter().cloned().collect();
    let mut history: HashMap<u64, HashSet<Vec<u8>>> = HashMap::new();
    for st in &states {
        for (id, content) in st {
            history.entry(*id).or_default().insert(content.clone());
        }
    }
    let final_state = states.last().unwrap();

    let fs = FaultFs::new();
    let ds = run_schedule(&fs, &ops, &fx, &cfg, 3, 0).expect("clean run");
    drop(ds);
    let pristine = fs.snapshot();
    // sanity: the uncorrupted image recovers exactly
    let (store, report) = recover(&pristine.snapshot(), DIR, None, 0).unwrap();
    assert!(!report.saw_damage());
    assert_eq!(store_contents(&store), *final_state);

    enum Hurt {
        Truncate(usize),
        Flip(usize),
        Append(usize),
    }
    let mut rng = Rng::new(0xBAD_C0DE);
    let mut damage_seen = 0u32;
    for path in pristine.paths() {
        let len = pristine.len_of(&path).unwrap();
        let mut hurts = vec![Hurt::Append(13), Hurt::Truncate(0)];
        for _ in 0..4 {
            hurts.push(Hurt::Flip(rng.below(len as u64) as usize));
            hurts.push(Hurt::Truncate(rng.below(len as u64) as usize));
        }
        for (case, hurt) in hurts.into_iter().enumerate() {
            let fsx = pristine.snapshot();
            fsx.corrupt(&path, |v| match hurt {
                Hurt::Truncate(n) => v.truncate(n),
                Hurt::Flip(i) => v[i] ^= 0x20,
                Hurt::Append(n) => v.extend(std::iter::repeat(0xA5).take(n)),
            });
            let (store, report) = recover(&fsx, DIR, None, 0)
                .unwrap_or_else(|e| panic!("{path} case {case}: recovery must not error: {e:?}"));
            let got = store_contents(&store);
            for (id, content) in &got {
                assert!(
                    history.get(id).is_some_and(|h| h.contains(content)),
                    "{path} case {case}: page {id} recovered with fabricated content"
                );
            }
            if got != *final_state {
                damage_seen += 1;
                // losing state is only acceptable as *counted* damage or
                // as a clean record-boundary cut back to a prefix state
                assert!(
                    report.saw_damage() || state_set.contains(&got),
                    "{path} case {case}: silent uncounted state loss; {report}"
                );
            }
            if report.saw_damage() {
                damage_seen += 1;
            }
        }
    }
    assert!(damage_seen > 10, "fuzz corpus too weak: only {damage_seen} damaging cases");

    // targeted: a mid-WAL bitflip is *counted* in the recovery metrics
    let fsx = pristine.snapshot();
    let wal_path = format!("{DIR}/{WAL_FILE}");
    let wal_len = pristine.len_of(&wal_path).unwrap();
    assert!(wal_len > 8, "schedule must leave WAL records behind its last checkpoint");
    fsx.corrupt(&wal_path, |v| {
        let mid = v.len() / 2;
        v[mid] ^= 0x01;
    });
    let (_, report) = recover(&fsx, DIR, None, 0).unwrap();
    assert!(
        report.wal_corrupt_records + report.wal_truncated_bytes > 0,
        "mid-WAL bitflip must show up in the WAL damage counters: {report}"
    );

    // targeted: a deleted manifest falls back to WAL-only recovery
    let fsx = pristine.snapshot();
    fsx.remove(&format!("{DIR}/{MANIFEST_FILE}")).unwrap();
    let (store, report) = recover(&fsx, DIR, None, 0).unwrap();
    assert!(!report.manifest_found);
    for (id, content) in &store_contents(&store) {
        assert!(
            history.get(id).is_some_and(|h| h.contains(content)),
            "WAL-only recovery fabricated content for page {id}"
        );
    }

    // targeted: a deleted segment is counted as missing
    let seg = pristine
        .paths()
        .into_iter()
        .find(|p| p.contains("/seg-"))
        .expect("a checkpoint segment must exist");
    let fsx = pristine.snapshot();
    fsx.remove(&seg).unwrap();
    let (_, report) = recover(&fsx, DIR, None, 0).unwrap();
    assert!(report.segments_missing > 0, "deleted {seg} must be counted: {report}");
    assert!(report.saw_damage());
}
