//! Integration: the E3 comparison shape — GBDI vs the baselines across
//! the paper's workloads. Asserts orderings, not absolute numbers.

use gbdi::baselines::{all_codecs, bdi::Bdi, ratio_of, Codec, GbdiWholeImage};
use gbdi::workloads;

const SIZE: usize = 1 << 20;

#[test]
fn every_codec_roundtrips_every_workload() {
    for w in workloads::all() {
        let img = w.generate(1 << 17, 13);
        for codec in all_codecs() {
            let comp = codec.compress(&img);
            let back = codec.decompress(&comp, img.len()).unwrap_or_else(|e| {
                panic!("{} failed on {}: {e}", codec.name(), w.name())
            });
            assert_eq!(back, img, "{} lossy on {}", codec.name(), w.name());
        }
    }
}

#[test]
fn gbdi_beats_bdi_on_average() {
    // the HPCA'22 claim the paper re-states: global bases beat
    // per-block bases on aggregate
    let gbdi = GbdiWholeImage::default();
    let bdi = Bdi::default();
    let mut g_sum = 0.0;
    let mut b_sum = 0.0;
    let mut g_wins = 0;
    for w in workloads::all() {
        let img = w.generate(SIZE, 7);
        let g = ratio_of(&gbdi, &img);
        let b = ratio_of(&bdi as &dyn Codec, &img);
        g_sum += g;
        b_sum += b;
        if g > b {
            g_wins += 1;
        }
    }
    assert!(g_sum > b_sum, "gbdi mean {} <= bdi mean {}", g_sum / 9.0, b_sum / 9.0);
    assert!(g_wins >= 4, "gbdi should win several workloads, won {g_wins}");
}

#[test]
fn java_group_compresses_better_than_c_group() {
    // the paper's headline: 1.55x Java vs 1.4x C-workloads
    let gbdi = GbdiWholeImage::default();
    let mut c = Vec::new();
    let mut j = Vec::new();
    for w in workloads::all() {
        let r = ratio_of(&gbdi, &w.generate(SIZE, 7));
        if w.group().is_c_family() {
            c.push(r);
        } else {
            j.push(r);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&j) > mean(&c),
        "Java mean {} must beat C mean {}",
        mean(&j),
        mean(&c)
    );
    // and the overall average lands in the paper's band (1.3 - 1.7)
    let overall = (mean(&j) * j.len() as f64 + mean(&c) * c.len() as f64) / 9.0;
    assert!((1.25..1.75).contains(&overall), "overall {overall}");
}

#[test]
fn heavyweight_codecs_win_ratio_but_not_blocks() {
    // zstd/gzip operate on whole images with unbounded context, so they
    // should beat block codecs on ratio for text-like data — that's the
    // tradeoff the paper's intro discusses
    let img = workloads::by_name("perlbench").unwrap().generate(SIZE, 7);
    let gbdi = ratio_of(&GbdiWholeImage::default(), &img);
    let zstd = ratio_of(&gbdi::baselines::external::Zstd::default(), &img);
    assert!(zstd > gbdi, "zstd {zstd} should beat gbdi {gbdi} on text");
}

#[test]
fn deepsjeng_is_the_hardest_workload() {
    let gbdi = GbdiWholeImage::default();
    let mut ratios: Vec<(String, f64)> = workloads::all()
        .iter()
        .map(|w| (w.name().to_string(), ratio_of(&gbdi, &w.generate(SIZE, 7))))
        .collect();
    ratios.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    assert_eq!(ratios[0].0, "deepsjeng", "expected deepsjeng hardest: {ratios:?}");
}
