//! Integration: the unified block-codec layer. Every registered
//! [`BlockCodec`] must roundtrip byte-identically through the shared
//! container — across all workloads, word sizes, block sizes, and the
//! serial vs parallel chunked pipelines — and the serialized container
//! must survive a bytes roundtrip. Includes the regression for the old
//! `GbdiWholeImage` format's u16 per-block bit lengths, which silently
//! truncated blocks larger than 64 B.

use gbdi::cluster::{SelectorConfig, SelectorKind};
use gbdi::codec::{BlockCodec, CodecId, CodecKind};
use gbdi::container::{self, Container};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::util::prng::Rng;
use gbdi::util::testkit::{check, BytesGen};
use gbdi::value::WordSize;
use gbdi::workloads;

#[test]
fn every_codec_roundtrips_every_workload_serial_and_parallel() {
    for w in workloads::all() {
        // 512 KiB: two 256 KiB chunks, so compress_parallel really chunks
        let img = w.generate(1 << 19, 13);
        for &kind in CodecKind::all() {
            let codec = kind.build_for_image(&img, &GbdiConfig::default());
            let serial = container::compress(codec.as_ref(), &img);
            assert_eq!(
                serial.decompress().unwrap(),
                img,
                "{} serial lossy on {}",
                kind.name(),
                w.name()
            );
            for threads in [2usize, 4] {
                let par = container::compress_parallel(codec.as_ref(), &img, threads);
                assert_eq!(
                    par.block_bits,
                    serial.block_bits,
                    "{} parallel framing differs on {} ({threads} threads)",
                    kind.name(),
                    w.name()
                );
                assert_eq!(
                    par.decompress().unwrap(),
                    img,
                    "{} parallel lossy on {} ({threads} threads)",
                    kind.name(),
                    w.name()
                );
            }
        }
    }
}

#[test]
fn container_bytes_roundtrip_every_codec() {
    let img = workloads::by_name("mcf").unwrap().generate(1 << 19, 5);
    for &kind in CodecKind::all() {
        let codec = kind.build_for_image(&img, &GbdiConfig::default());
        let comp = container::compress_parallel(codec.as_ref(), &img, 4);
        let bytes = comp.to_bytes();
        assert_eq!(bytes.len(), comp.total_len(), "{} total_len", kind.name());
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back.codec_id, comp.codec_id);
        assert_eq!(back.block_bits, comp.block_bits);
        assert_eq!(back.chunk_blocks, comp.chunk_blocks);
        // self-contained decode: the container alone rebuilds its decoder
        assert_eq!(back.decompress().unwrap(), img, "{}", kind.name());
    }
}

#[test]
fn word_sizes_and_block_sizes_roundtrip_through_container() {
    let img = workloads::by_name("omnetpp").unwrap().generate(1 << 17, 9);
    for (ws, classes) in [
        (WordSize::W32, vec![0u32, 4, 8, 12, 16, 20, 24]),
        (WordSize::W64, vec![0u32, 4, 8, 16, 24, 32]),
    ] {
        for block_bytes in [32usize, 64, 128] {
            let cfg = GbdiConfig {
                word_size: ws,
                width_classes: classes.clone(),
                block_bytes,
                ..Default::default()
            };
            let table = analyze::analyze_image(&img, &cfg);
            let codec = GbdiCodec::new(table, cfg);
            let comp = container::compress_parallel(&codec, &img, 4);
            let back = Container::from_bytes(&comp.to_bytes()).unwrap();
            assert_eq!(
                back.decompress().unwrap(),
                img,
                "gbdi {ws:?} block={block_bytes}"
            );
        }
    }
}

#[test]
fn prop_every_codec_roundtrips_arbitrary_bytes() {
    let gen = BytesGen { max_len: 4096 };
    for &kind in CodecKind::all() {
        check(0xB10C ^ kind.name().len() as u64, 40, &gen, |data| {
            let codec = kind.build_for_image(data, &GbdiConfig::default());
            let comp = container::compress(codec.as_ref(), data);
            match Container::from_bytes(&comp.to_bytes()) {
                Ok(back) => back.decompress().map(|d| d == *data).unwrap_or(false),
                Err(_) => false,
            }
        });
    }
}

#[test]
fn prop_every_selector_table_roundtrips_arbitrary_bytes() {
    // tables proposed by any base selector must decode bit-exactly, on
    // workload images and on adversarial byte strings alike
    let gen = BytesGen { max_len: 4096 };
    for &kind in SelectorKind::all() {
        check(0x5E1 ^ kind.name().len() as u64, 30, &gen, |data| {
            let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
            let samples = analyze::sample_image(data, &cfg);
            let selection = kind
                .build()
                .select(&samples, None, &SelectorConfig::from_gbdi(&cfg))
                .expect("native selectors never fail");
            let table = GlobalBaseTable::from_selection(&samples, &selection, &cfg, 0);
            let codec = GbdiCodec::new(table, cfg);
            let comp = container::compress(&codec, data);
            match Container::from_bytes(&comp.to_bytes()) {
                Ok(back) => back.decompress().map(|d| d == *data).unwrap_or(false),
                Err(_) => false,
            }
        });
    }
}

#[test]
fn selector_tables_roundtrip_workloads_serial_and_parallel() {
    for w in workloads::all() {
        let img = w.generate(1 << 18, 17);
        let cfg = GbdiConfig::default();
        let samples = analyze::sample_image(&img, &cfg);
        for &kind in SelectorKind::all() {
            let selection = kind
                .build()
                .select(&samples, None, &SelectorConfig::from_gbdi(&cfg))
                .unwrap();
            let table = GlobalBaseTable::from_selection(&samples, &selection, &cfg, 0);
            let codec = GbdiCodec::new(table, cfg.clone());
            let serial = container::compress(&codec, &img);
            assert_eq!(
                serial.decompress().unwrap(),
                img,
                "{} serial lossy on {}",
                kind.name(),
                w.name()
            );
            let par = container::compress_parallel(&codec, &img, 4);
            assert_eq!(par.block_bits, serial.block_bits, "{} on {}", kind.name(), w.name());
            assert_eq!(par.decompress().unwrap(), img, "{} on {}", kind.name(), w.name());
        }
    }
}

#[test]
fn u16_block_bits_regression_oversized_blocks() {
    // The retired GbdiWholeImage container stored per-block bit lengths as
    // u16: any block compressing to more than 65535 bits (e.g. a raw
    // 16 KiB block = 131074 bits) truncated silently and corrupted the
    // stream. The unified container's u32 varints must carry them exactly.
    let mut rng = Rng::new(0xB16);
    let mut image = vec![0u8; 96 * 1024];
    rng.fill_bytes(&mut image); // incompressible -> raw blocks
    let cfg = GbdiConfig { block_bytes: 16384, ..Default::default() };
    let table = analyze::analyze_image(&image, &cfg);
    let codec = GbdiCodec::new(table, cfg);
    let comp = container::compress(&codec, &image);
    let max_bits = *comp.block_bits.iter().max().unwrap();
    assert!(
        max_bits > u16::MAX as u32,
        "test must exercise >u16 block bits, got {max_bits}"
    );
    assert_eq!(max_bits as u64, 2 + 16384 * 8, "raw 16 KiB block");
    let back = Container::from_bytes(&comp.to_bytes()).unwrap();
    assert_eq!(back.block_bits, comp.block_bits, "bit lengths must survive exactly");
    assert_eq!(back.decompress().unwrap(), image);
}

#[test]
fn varint_boundaries_roundtrip_and_overflow_is_rejected() {
    // the container's framing index is u32 LEB128; every boundary value
    // must roundtrip exactly and oversized encodings must be corruption,
    // not silent truncation (a truncated length mis-frames every later
    // block)
    let boundaries = [
        0u32,
        1,
        0x7F,
        0x80,
        0x3FFF,
        0x4000,
        0x1F_FFFF,
        0x20_0000,
        0xFFF_FFFF,
        0x1000_0000,
        u32::MAX - 1,
        u32::MAX,
    ];
    let mut buf = Vec::new();
    for &v in &boundaries {
        buf.clear();
        container::put_varint(&mut buf, v);
        assert_eq!(buf.len(), container::varint_len(v), "len for {v:#x}");
        let mut off = 0;
        assert_eq!(container::read_varint(&buf, &mut off).unwrap(), v, "{v:#x}");
        assert_eq!(off, buf.len());
    }
    // a fifth byte with payload past bit 31, or still continuing, is corrupt
    for bad in [[0xFF, 0xFF, 0xFF, 0xFF, 0x10], [0xFF, 0xFF, 0xFF, 0xFF, 0x80]] {
        let mut off = 0;
        assert!(container::read_varint(&bad, &mut off).is_err(), "{bad:?}");
    }
    // truncated stream
    let mut off = 0;
    assert!(container::read_varint(&[0x80], &mut off).is_err());
}

#[test]
fn frame_index_math_survives_boundaries() {
    use gbdi::frame::Frame;
    use std::sync::Arc;
    let cfg = GbdiConfig::default();
    // zero-block image: a frame over nothing reads nothing and errors
    // out-of-range instead of panicking
    for &kind in CodecKind::all() {
        let codec = kind.build_for_image(&[], &cfg);
        let c = container::compress(codec.as_ref(), &[]);
        let frame = Frame::from_container(c).unwrap();
        assert_eq!(frame.n_blocks(), 0);
        assert!(frame.read_block(0, &mut [0u8; 64]).is_err());
        assert_eq!(frame.decompress().unwrap(), Vec::<u8>::new());
    }
    // ragged tails at every offset within a block boundary
    let base = workloads::by_name("perlbench").unwrap().generate(4096, 55);
    for cut in [1usize, 63, 64, 65, 4095] {
        let img = &base[..cut];
        let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Fpc.build_for_image(img, &cfg));
        let frame = Frame::compress(Arc::clone(&codec), img);
        let mut buf = [0u8; 64];
        let last = frame.n_blocks() - 1;
        let n = frame.read_block(last, &mut buf).unwrap();
        assert_eq!(n, if cut % 64 == 0 { 64 } else { cut % 64 }, "cut {cut}");
        assert_eq!(frame.decompress().unwrap(), img, "cut {cut}");
    }
    // u32::MAX-scale bit lengths in a forged index must be rejected at
    // frame construction (the offsets would run past the payload)
    let img = base;
    let codec = CodecKind::Bdi.build_for_image(&img, &cfg);
    let mut c = container::compress(codec.as_ref(), &img);
    c.block_bits[0] = u32::MAX;
    assert!(Frame::from_container(c).is_err());
}

#[test]
fn containers_distinguish_codecs_on_decode() {
    // compress with one codec; the container remembers which, and a
    // mismatched decoder is rejected instead of producing garbage
    let img = workloads::by_name("svm").unwrap().generate(1 << 15, 3);
    let cfg = GbdiConfig::default();
    let bdi = CodecKind::Bdi.build_for_image(&img, &cfg);
    let comp = container::compress(bdi.as_ref(), &img);
    assert_eq!(comp.codec_id, CodecId::Bdi);
    let fpc = CodecKind::Fpc.build_for_image(&img, &cfg);
    assert!(container::decompress_with(&comp, fpc.as_ref()).is_err());
    assert_eq!(container::decompress_with(&comp, bdi.as_ref()).unwrap(), img);
    // and gbdi's legacy entry point refuses non-gbdi containers
    assert!(gbdi::gbdi::decode::decompress_image(&comp).is_err());
}

#[test]
fn estimate_matches_emitted_bits_for_every_codec() {
    let img = workloads::by_name("fluidanimate").unwrap().generate(1 << 14, 21);
    let cfg = GbdiConfig::default();
    for &kind in CodecKind::all() {
        let codec = kind.build_for_image(&img, &cfg);
        let comp = container::compress(codec.as_ref(), &img);
        for (i, block) in img.chunks(codec.block_bytes()).enumerate() {
            assert_eq!(
                codec.estimate_block_bits(block),
                comp.block_bits[i] as u64,
                "{} block {i}",
                kind.name()
            );
        }
    }
}
