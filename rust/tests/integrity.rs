//! End-to-end integrity plane: chaos property tests.
//!
//! Four arms, one property: **no client-visible read is ever silently
//! wrong**. Under injected faults every read must come back as the
//! correct bytes, a clean typed error ([`gbdi::Error::DataLoss`]), or
//! healed-correct content — and never a panic.
//!
//! * storage bitflips with no durable copy → exact quarantine
//!   accounting, `DATA_LOSS` on every touched path, re-ingest lifts
//!   the fence;
//! * storage bitflips **with** a durable copy → reads self-heal to the
//!   original bytes and quarantine drains;
//! * wire chaos (mid-frame cuts + stalls through [`ChaosProxy`]) →
//!   the resilient client reconnects and replays, content-checked
//!   GETs stay correct, and wire faults never masquerade as storage
//!   corruption;
//! * integrity off (the default) → bit-identical reads to an
//!   integrity-enabled build on a clean store and zero plane activity,
//!   pinning the "off ⇒ unchanged" contract.

use gbdi::coordinator::{CompressionService, IntegrityConfig, ServiceConfig};
use gbdi::persist::{Durability, FaultFs, PersistConfig, Vfs};
use gbdi::server::protocol::stats_field;
use gbdi::server::{self, ChaosProxy, Client, FaultPlan, LoadGenConfig, Server, ServerConfig};
use gbdi::util::prng::Rng;
use gbdi::workloads::{self, Workload};
use gbdi::{BlockCodec, CodecKind, Error, GbdiConfig};
use std::sync::Arc;

const PAGE_BYTES: usize = 4096;
const BLOCK_BYTES: usize = 64;
const BLOCKS: usize = PAGE_BYTES / BLOCK_BYTES;

/// Deterministic analysis-free codec so reads depend on nothing but
/// the stored frames (same recipe as `tests/server_proto.rs`).
fn static_codec() -> Arc<dyn BlockCodec> {
    let image = workloads::by_name("mcf").unwrap().generate(1 << 16, 7);
    Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()))
}

fn mcf() -> Box<dyn Workload> {
    workloads::by_name("mcf").unwrap()
}

/// Flip exactly one stored bit of `page`, starting the block probe at
/// a seeded offset so different victims corrupt different blocks.
fn flip_one_bit(svc: &CompressionService, page: u64, rng: &mut Rng) -> bool {
    let start = rng.below(BLOCKS as u64) as usize;
    let bit = rng.below(8);
    (0..BLOCKS).any(|off| svc.corrupt_page_block(page, (start + off) % BLOCKS, bit))
}

#[test]
fn bitflip_storm_reads_are_correct_or_clean_data_loss() {
    const PAGES: u64 = 24;
    let svc = CompressionService::start_static(
        ServiceConfig {
            workers: 2,
            shards: 3,
            integrity: IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 64 },
            ..Default::default()
        },
        static_codec(),
    )
    .unwrap();
    let w = mcf();
    let oracle: Vec<Vec<u8>> = (0..PAGES).map(|i| w.generate(PAGE_BYTES, i)).collect();
    for (i, img) in oracle.iter().enumerate() {
        svc.submit(i as u64, img.clone());
    }
    svc.flush();

    // randomized corruption schedule: distinct victims, one bit each
    let mut rng = Rng::new(0xB17_F11A);
    let mut victims: Vec<u64> = Vec::new();
    while victims.len() < 6 {
        let p = rng.below(PAGES);
        if victims.contains(&p) {
            continue;
        }
        assert!(flip_one_bit(&svc, p, &mut rng), "page {p}: no stored bit to flip");
        victims.push(p);
    }

    // every read: correct bytes or a clean typed error — whichever
    // detector fences first (scrubber or verified read), never garbage
    for p in 0..PAGES {
        let r = svc.read_page(p);
        if victims.contains(&p) {
            match r {
                Err(Error::DataLoss(msg)) => {
                    assert!(!msg.is_empty(), "DATA_LOSS must say which page")
                }
                other => panic!("corrupted page {p} served without a fence: {other:?}"),
            }
        } else {
            assert_eq!(r.unwrap(), oracle[p as usize], "untouched page {p} drifted");
        }
    }
    // the block paths honor the same fence
    let v = victims[0];
    let mut buf = vec![0u8; BLOCK_BYTES];
    assert!(matches!(svc.read_block(v, 0, &mut buf), Err(Error::DataLoss(_))));
    assert!(matches!(svc.write_block(v, 0, &buf), Err(Error::DataLoss(_))));

    // accounting is exact: one detection + one quarantine per injected
    // corruption, zero heals without a durable copy
    let t = svc.integrity_totals();
    assert_eq!(t.corrupt_detected, victims.len() as u64, "detections != injected corruptions");
    assert_eq!(t.quarantined, victims.len() as u64);
    assert_eq!(t.healed, 0, "nothing durable to heal from");
    let mut fenced = svc.quarantined_pages();
    fenced.sort_unstable();
    let mut want = victims.clone();
    want.sort_unstable();
    assert_eq!(fenced, want);

    // a full-page overwrite supersedes the lost content: fence lifts,
    // and the overwrite is NOT counted as a heal
    for &p in &victims {
        svc.submit(p, w.generate(PAGE_BYTES, p ^ 0xFEED));
    }
    svc.flush();
    for &p in &victims {
        assert_eq!(svc.read_page(p).unwrap(), w.generate(PAGE_BYTES, p ^ 0xFEED));
    }
    assert!(svc.quarantined_pages().is_empty());
    let t = svc.integrity_totals();
    assert_eq!(t.corrupt_detected, victims.len() as u64);
    assert_eq!(t.healed, 0);
    svc.shutdown();
}

#[test]
fn quarantine_self_heals_from_durable_state() {
    const PAGES: u64 = 12;
    let vfs: Arc<dyn Vfs> = Arc::new(FaultFs::new());
    let (d, _) = Durability::open(Arc::clone(&vfs), "data", PersistConfig::default(), 2, 0).unwrap();
    let svc = CompressionService::start_static(
        ServiceConfig {
            workers: 2,
            shards: 2,
            persist: Some(d),
            integrity: IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 64 },
            ..Default::default()
        },
        static_codec(),
    )
    .unwrap();
    let w = mcf();
    for i in 0..PAGES {
        svc.submit(i, w.generate(PAGE_BYTES, i));
    }
    svc.flush();

    let mut rng = Rng::new(0x5E1F_4EA1);
    let victims = [1u64, 5, 9];
    for &p in &victims {
        assert!(flip_one_bit(&svc, p, &mut rng), "page {p}: no stored bit to flip");
    }
    // with persistence attached the fence is invisible to callers:
    // every read serves the WAL-backed original, not an error
    for p in 0..PAGES {
        assert_eq!(svc.read_page(p).unwrap(), w.generate(PAGE_BYTES, p), "page {p}");
    }
    let t = svc.integrity_totals();
    assert_eq!(t.corrupt_detected, victims.len() as u64);
    assert_eq!(t.quarantined, victims.len() as u64);
    assert_eq!(t.healed, victims.len() as u64, "every quarantined page must heal");
    assert!(svc.quarantined_pages().is_empty(), "healed pages must leave quarantine");

    // healed pages take writes again and stay coherent
    let block = vec![0xA5u8; BLOCK_BYTES];
    svc.write_block(victims[0], 0, &block).unwrap();
    let mut out = vec![0u8; BLOCK_BYTES];
    svc.read_block(victims[0], 0, &mut out).unwrap();
    assert_eq!(out, block);
    svc.shutdown();
}

#[test]
fn wire_chaos_survives_cuts_without_silent_wrong_reads() {
    let svc = CompressionService::start_static(
        ServiceConfig {
            workers: 2,
            shards: 2,
            integrity: IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 32 },
            ..Default::default()
        },
        static_codec(),
    )
    .unwrap();
    let server = Server::bind(
        svc,
        ServerConfig { listen: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let upstream = server.local_addr().to_string();

    let mut cfg = LoadGenConfig {
        addr: upstream.clone(),
        conns: 2,
        ops_per_conn: 600,
        pipeline: 4,
        pages: 16,
        page_bytes: PAGE_BYTES,
        read_fraction: 0.7,
        batch_read_every: 16,
        put_pages_every: 64,
        check_content: true,
        max_reconnects: 100,
        seed: 0xC4A0_5,
        ..Default::default()
    };
    // preload over the clean path; only the measured run goes through
    // the proxy (mirrors `gbdi client --op load --chaos-cut`)
    server::preload(&cfg).unwrap();

    // ~8 cuts per connection per direction at this traffic volume, so
    // mid-stream disconnects are certain; stalls fire a few times
    let plan = FaultPlan {
        seed: 0xFA_017,
        cut_every_bytes: 8 * 1024,
        stall_every_bytes: 32 * 1024,
        stall_ms: 1,
        ..Default::default()
    };
    let mut proxy = ChaosProxy::start(&upstream, plan).unwrap();
    cfg.addr = proxy.addr();
    let rep = server::run_loadgen(&cfg).expect("loadgen must survive wire chaos");
    proxy.stop();

    assert!(proxy.cuts() >= 1, "chaos never fired: raise the fault rate");
    assert!(proxy.conns() >= 2, "each loadgen connection dials through the proxy");
    assert!(rep.reconnects >= 1, "no reconnects despite {} injected cuts", proxy.cuts());
    assert_eq!(
        rep.check_failures, 0,
        "{} silently-wrong GET payloads under chaos",
        rep.check_failures
    );
    assert!(rep.ops_ok > 0, "no op completed: {rep:?}");

    // the appended STATS fields decode end to end over the clean path
    let mut c = Client::connect(&upstream).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.fields.len(), stats_field::COUNT);
    drop(c);

    let (svc, _stats, _conns) = server.stop();
    let t = svc.integrity_totals();
    assert_eq!(t.corrupt_detected, 0, "wire chaos must never look like storage corruption");
    assert_eq!(t.quarantined, 0);
    svc.shutdown();
}

#[test]
fn integrity_off_matches_the_unchecked_build_bit_for_bit() {
    assert!(!IntegrityConfig::default().enabled, "integrity must be opt-in");
    let start = |integrity| {
        CompressionService::start_static(
            ServiceConfig { workers: 2, shards: 2, integrity, ..Default::default() },
            static_codec(),
        )
        .unwrap()
    };
    let on = start(IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 64 });
    let off = start(IntegrityConfig::default());

    let w = mcf();
    for i in 0..10u64 {
        let img = w.generate(PAGE_BYTES, i);
        on.submit(i, img.clone());
        off.submit(i, img);
    }
    on.flush();
    off.flush();
    // a clean store reads identically with the plane on or off — the
    // CRCs only ever *reject*, never transform
    let mut a = vec![0u8; BLOCK_BYTES];
    let mut b = vec![0u8; BLOCK_BYTES];
    for i in 0..10u64 {
        let want = w.generate(PAGE_BYTES, i);
        assert_eq!(on.read_page(i).unwrap(), want);
        assert_eq!(off.read_page(i).unwrap(), want);
        on.read_block(i, 3, &mut a).unwrap();
        off.read_block(i, 3, &mut b).unwrap();
        assert_eq!(a, b);
    }
    // off = zero plane activity: no scrubber, no detections, even
    // after giving a would-be scrubber time to run
    std::thread::sleep(std::time::Duration::from_millis(50));
    let t = off.integrity_totals();
    assert_eq!(
        (t.scrubbed, t.corrupt_detected, t.healed, t.quarantined),
        (0, 0, 0, 0),
        "disabled plane did work: {t:?}"
    );
    assert!(off.quarantined_pages().is_empty());
    off.shutdown();
    on.shutdown();
}
