//! Integration: the random-access Frame API. Decoding every block
//! individually through `Frame::read_block` must be byte-identical to
//! whole-image `decompress` for every registered codec — across all
//! workloads, ragged tails, parallel-compressed containers, and after
//! in-place writes under table swaps. Property-tested against
//! adversarial byte strings too.

use gbdi::codec::{BlockCodec, Scratch};
use gbdi::container;
use gbdi::frame::{Compressor, Decompressor, Frame};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::util::prng::Rng;
use gbdi::util::testkit::{check, BytesGen};
use gbdi::workloads;
use gbdi::CodecKind;
use std::sync::Arc;

fn build(kind: CodecKind, img: &[u8]) -> Arc<dyn BlockCodec> {
    Arc::from(kind.build_for_image(img, &GbdiConfig::default()))
}

#[test]
fn every_codec_every_workload_block_reads_match_whole_decode() {
    for w in workloads::all() {
        let mut img = w.generate(1 << 17, 41);
        img.truncate(img.len() - 7); // every workload gets a ragged tail
        for &kind in CodecKind::all() {
            let codec = build(kind, &img);
            let container = container::compress(codec.as_ref(), &img);
            let whole = container.decompress().unwrap();
            let frame = Frame::from_container(container).unwrap();
            let mut buf = vec![0u8; frame.block_bytes()];
            for i in 0..frame.n_blocks() {
                let n = frame.read_block(i, &mut buf).unwrap();
                assert_eq!(
                    &buf[..n],
                    &whole[i * 64..i * 64 + n],
                    "{} block {i} on {}",
                    kind.name(),
                    w.name()
                );
            }
            assert_eq!(frame.decompress().unwrap(), img, "{} on {}", kind.name(), w.name());
        }
    }
}

#[test]
fn parallel_containers_serve_block_reads_across_chunk_seams() {
    // chunked-parallel compression byte-aligns every 4096th block; the
    // frame index must reproduce that realignment
    let img = workloads::by_name("omnetpp").unwrap().generate(1 << 19, 43);
    for &kind in CodecKind::all() {
        let codec = build(kind, &img);
        let par = container::compress_parallel(codec.as_ref(), &img, 4);
        assert!(par.chunk_blocks > 0);
        let frame = Frame::with_codec(par, Arc::clone(&codec)).unwrap();
        let mut buf = [0u8; 64];
        let n = frame.n_blocks();
        let mut rng = Rng::new(45);
        for _ in 0..512 {
            let i = rng.below(n as u64) as usize;
            frame.read_block(i, &mut buf).unwrap();
            assert_eq!(&buf[..], &img[i * 64..(i + 1) * 64], "{} block {i}", kind.name());
        }
    }
}

#[test]
fn prop_frame_roundtrips_arbitrary_bytes_blockwise() {
    let gen = BytesGen { max_len: 4096 };
    for &kind in CodecKind::all() {
        check(0xF4A3 ^ kind.name().len() as u64, 40, &gen, |data| {
            let codec = build(kind, data);
            let frame = Frame::compress(Arc::clone(&codec), data);
            let mut buf = vec![0u8; frame.block_bytes()];
            for i in 0..frame.n_blocks() {
                let n = match frame.read_block(i, &mut buf) {
                    Ok(n) => n,
                    Err(_) => return false,
                };
                if &buf[..n] != &data[i * 64..i * 64 + n] {
                    return false;
                }
            }
            frame.decompress().map(|d| d == *data).unwrap_or(false)
        });
    }
}

#[test]
fn prop_write_then_read_roundtrips_arbitrary_bytes() {
    // overwrite a pseudo-random block with a pseudo-random line, then
    // demand bit-exactness from block reads, whole decodes, and the
    // compacted container
    let gen = BytesGen { max_len: 4096 };
    for &kind in CodecKind::all() {
        check(0x33E1 ^ kind.name().len() as u64, 25, &gen, |data| {
            let codec = build(kind, data);
            let mut frame = Frame::compress(Arc::clone(&codec), data);
            if frame.n_blocks() == 0 {
                return frame.decompress().map(|d| d.is_empty()).unwrap_or(false);
            }
            let mut scratch = Scratch::new();
            let mut rng = Rng::new(data.len() as u64 + 1);
            let mut expect = data.clone();
            for _ in 0..4 {
                let i = rng.below(frame.n_blocks() as u64) as usize;
                let blen = frame.block_len(i);
                let mut line = vec![0u8; blen];
                if rng.chance(0.5) {
                    rng.fill_bytes(&mut line);
                }
                if frame.write_block(i, &line, &mut scratch).is_err() {
                    return false;
                }
                expect[i * 64..i * 64 + blen].copy_from_slice(&line);
            }
            let direct = frame.decompress().map(|d| d == expect).unwrap_or(false);
            let compacted =
                frame.to_container().decompress().map(|d| d == expect).unwrap_or(false);
            direct && compacted
        });
    }
}

#[test]
fn writes_under_table_swaps_stay_bit_exact() {
    // two GBDI tables (a phase change away from each other): pages
    // framed under v1 keep decoding and accepting writes with their own
    // codec after v2 is adopted elsewhere — and a v2-framed copy of the
    // same content serves identical bytes
    let cfg = GbdiConfig::default();
    let img_a = workloads::by_name("mcf").unwrap().generate(1 << 14, 3);
    let img_b = workloads::by_name("svm").unwrap().generate(1 << 14, 3);
    let mut t1 = analyze::analyze_image(&img_a, &cfg);
    t1.version = 1;
    let mut t2 = analyze::analyze_image(&img_b, &cfg);
    t2.version = 2;
    let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
    let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg));
    let mut old_frame = Frame::compress(Arc::clone(&c1), &img_a);
    let mut new_frame = Frame::compress(Arc::clone(&c2), &img_a);
    let mut scratch = Scratch::new();
    let mut expect = img_a.clone();
    let mut rng = Rng::new(8);
    for k in 0..32 {
        let i = rng.below(old_frame.n_blocks() as u64) as usize;
        let mut line = [0u8; 64];
        if k % 2 == 0 {
            rng.fill_bytes(&mut line);
        } else {
            line[..64].copy_from_slice(&img_b[i * 64..(i + 1) * 64]);
        }
        old_frame.write_block(i, &line, &mut scratch).unwrap();
        new_frame.write_block(i, &line, &mut scratch).unwrap();
        expect[i * 64..(i + 1) * 64].copy_from_slice(&line);
    }
    assert_eq!(old_frame.decompress().unwrap(), expect, "old-table frame");
    assert_eq!(new_frame.decompress().unwrap(), expect, "new-table frame");
    // both serialize to self-contained containers that decode anywhere
    assert_eq!(old_frame.to_container().decompress().unwrap(), expect);
    assert_eq!(new_frame.to_container().decompress().unwrap(), expect);
}

#[test]
fn sessions_roundtrip_every_workload() {
    for w in workloads::all() {
        let mut img = w.generate(1 << 16, 47);
        img.truncate(img.len() - 11);
        let codec = build(CodecKind::Gbdi, &img);
        let mut c = Compressor::new(Arc::clone(&codec));
        for chunk in img.chunks(777) {
            c.write(chunk);
        }
        let frame = c.finish();
        let mut d = Decompressor::new(&frame);
        let mut out = Vec::with_capacity(img.len());
        let mut buf = [0u8; 4096];
        loop {
            let n = d.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            out.extend_from_slice(&buf[..n]);
        }
        assert_eq!(out, img, "{}", w.name());
    }
}

#[test]
fn read_range_and_append_cover_boundaries() {
    let img = workloads::by_name("fluidanimate").unwrap().generate(1 << 15, 49);
    let codec = build(CodecKind::Bdi, &img);
    let mut frame = Frame::compress(Arc::clone(&codec), &img);
    let mut scratch = Scratch::new();
    // ranges straddling block seams
    for (off, len) in [(0usize, 1usize), (63, 2), (64, 64), (100, 1000), (img.len() - 5, 5)] {
        let mut out = vec![0u8; len];
        frame.read_range(off, &mut out, &mut scratch).unwrap();
        assert_eq!(out, &img[off..off + len], "range {off}+{len}");
    }
    // append then read across the old/new boundary
    let extra = workloads::by_name("mcf").unwrap().generate(4096, 50);
    frame.append_blocks(&extra, &mut scratch).unwrap();
    let mut out = vec![0u8; 256];
    frame.read_range(img.len() - 128, &mut out, &mut scratch).unwrap();
    assert_eq!(&out[..128], &img[img.len() - 128..]);
    assert_eq!(&out[128..], &extra[..128]);
}
