//! Integration: GBDI lossless roundtrip across every workload, config
//! sweep, and word size — the paper's "reconstruction accuracy" metric
//! (§V) must be exact everywhere.

use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::value::WordSize;
use gbdi::workloads;

#[test]
fn all_workloads_roundtrip_bit_exact() {
    for w in workloads::all() {
        let image = w.generate(1 << 19, 11);
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(&image, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(&image);
        let restored = gbdi::gbdi::decode::decompress_image(&comp).unwrap();
        assert_eq!(restored, image, "{} not bit-exact", w.name());
        assert!(comp.ratio() > 1.0, "{} ratio {}", w.name(), comp.ratio());
    }
}

#[test]
fn config_sweep_roundtrips() {
    let image = workloads::by_name("freqmine").unwrap().generate(1 << 18, 3);
    for num_bases in [2usize, 8, 16, 64, 128, 256] {
        for block_bytes in [32usize, 64, 128] {
            let cfg = GbdiConfig { num_bases, block_bytes, ..Default::default() };
            let table = analyze::analyze_image(&image, &cfg);
            let codec = GbdiCodec::new(table, cfg);
            let comp = codec.compress_image(&image);
            let restored = gbdi::gbdi::decode::decompress_image(&comp).unwrap();
            assert_eq!(restored, image, "K={num_bases} block={block_bytes}");
        }
    }
}

#[test]
fn w64_mode_roundtrips() {
    let image = workloads::by_name("omnetpp").unwrap().generate(1 << 18, 5);
    let cfg = GbdiConfig {
        word_size: WordSize::W64,
        width_classes: vec![0, 4, 8, 16, 24, 32],
        ..Default::default()
    };
    let table = analyze::analyze_image(&image, &cfg);
    let codec = GbdiCodec::new(table, cfg);
    let comp = codec.compress_image(&image);
    assert_eq!(gbdi::gbdi::decode::decompress_image(&comp).unwrap(), image);
}

#[test]
fn narrow_width_class_menus_roundtrip() {
    let image = workloads::by_name("svm").unwrap().generate(1 << 17, 9);
    for classes in [vec![0u32], vec![8], vec![0, 16], vec![4, 8, 12, 16, 20, 24]] {
        let cfg = GbdiConfig { width_classes: classes.clone(), ..Default::default() };
        let table = analyze::analyze_image(&image, &cfg);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(&image);
        let restored = gbdi::gbdi::decode::decompress_image(&comp).unwrap();
        assert_eq!(restored, image, "classes {classes:?}");
    }
}

#[test]
fn pathological_images_roundtrip() {
    let cfg = GbdiConfig::default();
    let mut rng = gbdi::util::prng::Rng::new(1);
    let mut noise = vec![0u8; 1 << 16];
    rng.fill_bytes(&mut noise);
    let cases: Vec<Vec<u8>> = vec![
        vec![],
        vec![0u8; 63],           // less than one block
        vec![0u8; 64],           // exactly one block
        vec![0u8; 65],           // one block + ragged tail
        vec![0xFF; 1 << 16],     // repeated
        noise,                   // incompressible
        (0..=255u8).cycle().take(12345).collect(),
    ];
    for (i, image) in cases.iter().enumerate() {
        let table = analyze::analyze_image(image, &cfg);
        let codec = GbdiCodec::new(table, cfg.clone());
        let comp = codec.compress_image(image);
        assert_eq!(&gbdi::gbdi::decode::decompress_image(&comp).unwrap(), image, "case {i}");
    }
}

#[test]
fn parallel_compression_matches_serial() {
    let image = workloads::by_name("triangle_count").unwrap().generate(2 << 20, 17);
    let cfg = GbdiConfig::default();
    let table = analyze::analyze_image(&image, &cfg);
    let codec = GbdiCodec::new(table, cfg);
    let serial = codec.compress_image(&image);
    for threads in [2usize, 4, 8] {
        let (par, stats) = codec.compress_image_parallel(&image, threads);
        assert_eq!(par.block_bits, serial.block_bits, "{threads} threads: same per-block sizes");
        // padding cost: < 1 byte per 4096-block chunk
        let chunks = (serial.payload.len() / (4096 * 64)).max(1);
        assert!(par.payload.len() <= serial.payload.len() + chunks + 1);
        assert_eq!(gbdi::gbdi::decode::decompress_image(&par).unwrap(), image);
        assert!(stats.gbdi_blocks > 0);
    }
}
