//! Golden GBN1 protocol fixtures: checked-in frame bytes that pin the
//! network wire format byte-for-byte.
//!
//! Every case asserts two things against its fixture file under
//! `tests/golden/`:
//!
//! 1. **byte-identical encoding** — encoding the frozen request/response
//!    lists with [`gbdi::server::protocol`] reproduces the checked-in
//!    bytes exactly (length prefixes, op/status bytes, field order,
//!    little-endian layout);
//! 2. **exact decode** — splitting and decoding the checked-in frames
//!    reproduces the frozen value lists structurally.
//!
//! The fixtures are independently produced (and `--check`-verified) by
//! the Python mirror in `scripts/gen_golden_fixtures.py`; the two
//! implementations share no code, so agreement pins the protocol. The
//! frozen lists below MUST stay in sync with `GBN_REQUESTS` /
//! `GBN_RESPONSES` in that script.
//!
//! Regenerate after an *intentional* protocol change (which needs a
//! version bump) with `GOLDEN_BLESS=1 cargo test --test golden_protocol`
//! or `python3 scripts/gen_golden_fixtures.py`, then commit the new
//! fixtures and explain the break in the PR.

use gbdi::server::protocol::{
    self, stats_field, Reply, Request, Response, StatsReply, Status, MAGIC, PROTOCOL_VERSION,
};
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden").join(name)
}

/// Bless (under `GOLDEN_BLESS=1`) or compare, then return the
/// checked-in bytes for the decode leg.
fn check_golden(name: &str, generated: &[u8]) -> Vec<u8> {
    let path = fixture_path(name);
    if std::env::var("GOLDEN_BLESS").is_ok_and(|v| v == "1") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, generated).unwrap();
        eprintln!("blessed {name}: {} bytes", generated.len());
        return generated.to_vec();
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("missing fixture {path:?} ({e}); regenerate with GOLDEN_BLESS=1")
    });
    assert_eq!(golden, generated, "{name}: checked-in fixture != Rust encoding");
    golden
}

/// The frozen request sequence. Touch ONLY with a protocol version bump,
/// in lockstep with `GBN_REQUESTS` in `scripts/gen_golden_fixtures.py`.
fn golden_requests() -> Vec<(u64, Request)> {
    let pages = vec![
        (0x1122_3344_5566_7788, (0..16u32).map(|i| (i * 7 + 3) as u8).collect()),
        (7, vec![0xAB; 5]),
    ];
    vec![
        (1, Request::PutPages(pages)),
        (2, Request::GetBlock { page_id: 3, block: 9 }),
        (3, Request::GetBlocks(vec![(1, 2), (u64::MAX, u32::MAX)])),
        (4, Request::PutBlock { page_id: 5, block: 0, data: vec![0xC3; 64] }),
        (5, Request::ReadRange { page_id: 9, first: 2, count: 3 }),
        (6, Request::Flush),
        (7, Request::Stats),
        (u64::MAX, Request::Reanalyze),
        (0, Request::Shutdown),
    ]
}

fn resp(req_id: u64, body: Reply) -> Response {
    Response { req_id, body }
}

fn err(status: Status, op: u8, retry_ms: u32, message: &str) -> Reply {
    Reply::Error { status, op, retry_ms, message: message.to_string() }
}

/// The frozen response sequence, one OK body per reply shape plus one
/// error body per non-OK status. Kept in lockstep with `GBN_RESPONSES`
/// in `scripts/gen_golden_fixtures.py`.
fn golden_responses() -> Vec<Response> {
    vec![
        resp(1, Reply::PutPages { accepted: 2 }),
        resp(2, Reply::Block { data: (0..64).collect() }),
        resp(3, Reply::Blocks { items: vec![Some((1..=8).collect()), None] }),
        resp(4, Reply::PutBlock),
        resp(5, Reply::Range { data: (0..12u8).map(|i| 255 - i).collect() }),
        resp(6, Reply::Flushed { blocks: 7 }),
        resp(7, Reply::Stats(StatsReply { fields: (0..29u64).map(|i| 1000 + i).collect() })),
        resp(8, Reply::Version { version: 3 }),
        resp(9, Reply::ShutdownAck),
        resp(2, err(Status::NotFound, 2, 0, "page 3 not found")),
        resp(10, err(Status::BadRequest, 0x2A, 0, "unknown op 0x2a")),
        resp(1, err(Status::RetryAfter, 1, 50, "ingest backlog")),
        resp(11, err(Status::ShuttingDown, 4, 0, "")),
        resp(12, err(Status::ServerError, 6, 0, "internal")),
    ]
}

#[test]
fn golden_hello() {
    let mut generated = Vec::new();
    generated.extend_from_slice(&MAGIC);
    generated.extend_from_slice(&protocol::server_hello(64));
    let bytes = check_golden("gbn1_hello.gbn", &generated);

    assert_eq!(bytes.len(), 12, "handshake fixture is client magic + 8-byte server hello");
    assert_eq!(&bytes[..4], &MAGIC, "client handshake magic moved");
    let mut hello = [0u8; 8];
    hello.copy_from_slice(&bytes[4..]);
    let (version, block_bytes) = protocol::parse_server_hello(&hello).unwrap();
    assert_eq!(version, PROTOCOL_VERSION);
    assert_eq!(block_bytes, 64);
}

#[test]
fn golden_request_frames() {
    let reqs = golden_requests();
    let mut generated = Vec::new();
    for (req_id, req) in &reqs {
        generated.extend_from_slice(&protocol::frame(&protocol::encode_request(*req_id, req)));
    }
    let bytes = check_golden("gbn1_requests.gbn", &generated);

    let mut stream = &bytes[..];
    let mut decoded = Vec::new();
    while let Some(payload) = protocol::read_frame(&mut stream, 8 << 20).unwrap() {
        decoded.push(protocol::decode_request(&payload).unwrap());
    }
    assert_eq!(decoded, reqs, "decoding the checked-in request frames drifted");
}

#[test]
fn golden_response_frames() {
    let resps = golden_responses();
    let mut generated = Vec::new();
    for r in &resps {
        generated.extend_from_slice(&protocol::frame(&protocol::encode_response(r)));
    }
    let bytes = check_golden("gbn1_responses.gbn", &generated);

    let mut stream = &bytes[..];
    let mut decoded = Vec::new();
    while let Some(payload) = protocol::read_frame(&mut stream, 8 << 20).unwrap() {
        decoded.push(protocol::decode_response(&payload).unwrap());
    }
    assert_eq!(decoded, resps, "decoding the checked-in response frames drifted");
}

#[test]
fn stats_layout_is_frozen() {
    // Growing the field set is append-only and must rev this assert:
    // 29 fields through the durability release, +4 integrity counters
    // (scrubbed_pages/corrupt_detected/healed/quarantined) at indices
    // 29..33. The golden stats reply deliberately still carries 29
    // words — StatsReply is length-prefixed, so an old-length vector
    // must keep decoding (that IS the append-only guarantee).
    assert_eq!(stats_field::COUNT, 33, "stats_field grew: rev STATS fixtures + docs");
    assert_eq!(stats_field::NAMES.len(), stats_field::COUNT);
    assert_eq!(stats_field::SCRUBBED_PAGES, 29);
    assert_eq!(stats_field::CORRUPT_DETECTED, 30);
    assert_eq!(stats_field::HEALED, 31);
    assert_eq!(stats_field::QUARANTINED, 32);
}
