//! Golden fixtures for the durability layer's on-disk formats: WAL
//! records (`GBW1`), checkpoint segments (`GBS1`), and the manifest
//! (`GBM1`).
//!
//! The checked-in bytes under `tests/golden/persist_*` are produced by
//! an independent Python implementation (`scripts/gen_golden_fixtures.py`,
//! `build_persist_fixtures`) and each case here asserts both directions
//! against them:
//!
//! 1. **exact decode** — scanning the checked-in bytes yields the
//!    expected records/entries with zero damage counted;
//! 2. **byte-identical re-encode** — building the same logical content
//!    through the Rust encoders reproduces the fixture exactly.
//!
//! The embedded page container is the `gbdi_mixed.gbc` image compressed
//! with the same explicit-table codec `golden_wire.rs` pins, so the WAL
//! and segment fixtures also transitively freeze the GBC1 reuse.
//!
//! Regenerate after an *intentional* format change with
//! `GOLDEN_BLESS=1 cargo test --test golden_persist` (or the Python
//! script) — and bump the magic, never reinterpret bytes in place.

use gbdi::container;
use gbdi::gbdi::{GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::persist::segment::{
    decode_manifest, encode_manifest, encode_segment, scan_segment, Manifest, MANIFEST_VERSION,
};
use gbdi::persist::wal::{scan_wal, WalRecord, WAL_MAGIC};
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden").join(name)
}

/// Same codec + image as `golden_wire.rs`'s mixed case (table version 7).
fn fixture_codec() -> GbdiCodec {
    let cfg = GbdiConfig::default();
    let table = GlobalBaseTable::new(vec![(1000, 8), (1 << 20, 16)], cfg.word_size, 7);
    GbdiCodec::new(table, cfg)
}

fn gbdi_mixed_image() -> Vec<u8> {
    let mut words: Vec<u32> = Vec::new();
    words.extend((0..16u32).map(|i| 900 + 7 * i));
    words.extend([0u32; 16]);
    words.extend([0xDEAD_BEEFu32; 16]);
    words.extend((0..16u32).map(|i| 0x1000_0000u32.wrapping_add(i.wrapping_mul(0x0123_4567))));
    words.extend((0..16u32).map(|i| (1u32 << 20) - 15000 + 1234 * i));
    words.extend((0..12u32).map(|i| 1000 + i));
    words.extend((12..16u32).map(|i| 0xA000_0000 + i));
    words.extend((0..16usize).map(|i| [0u32, 1000, 1 << 20][i % 3]));
    words.extend((0..16u32).map(|i| 1000 - i));
    words.iter().flat_map(|w| w.to_le_bytes()).collect()
}

/// The embedded containers: a real compressed page and the zero-image
/// codec snapshot form the WAL/manifest use for table publication.
fn page_and_snapshot() -> (Vec<u8>, Vec<u8>) {
    let codec = fixture_codec();
    let page = container::compress(&codec, &gbdi_mixed_image()).to_bytes();
    let snapshot = container::compress(&codec, &[]).to_bytes();
    (page, snapshot)
}

const PAGE_ID: u64 = 0x0102_0304_0506_0708;

/// The frozen record sequence, one of each tag, mirrored verbatim in
/// `build_persist_fixtures` on the Python side.
fn wal_records() -> Vec<WalRecord> {
    let (page, snapshot) = page_and_snapshot();
    vec![
        WalRecord::PutPage { page_id: PAGE_ID, container: page },
        WalRecord::WriteBlock {
            page_id: PAGE_ID,
            block: 5,
            data: (0..64u32).map(|i| ((3 * i + 1) & 0xFF) as u8).collect(),
        },
        WalRecord::RemovePage { page_id: 42 },
        WalRecord::PublishCodec { container: snapshot },
        WalRecord::Resize { shards: 6 },
    ]
}

/// Shared assertion: bless or compare, with a first-diff report.
fn check_golden(name: &str, built: &[u8]) {
    let path = fixture_path(name);
    if std::env::var("GOLDEN_BLESS").is_ok_and(|v| v == "1") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, built).unwrap();
        eprintln!("blessed {name}: {} bytes", built.len());
        return;
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("missing fixture {path:?} ({e}); regenerate with GOLDEN_BLESS=1")
    });
    if built != golden {
        let first_diff = built
            .iter()
            .zip(golden.iter())
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| built.len().min(golden.len()));
        panic!(
            "{name}: persist format moved: {} bytes now vs {} in fixture, first diff at byte \
             {} (got {:#04x?}, fixture {:#04x?})",
            built.len(),
            golden.len(),
            first_diff,
            built.get(first_diff),
            golden.get(first_diff),
        );
    }
}

#[test]
fn golden_wal_records() {
    let records = wal_records();
    let mut built = WAL_MAGIC.to_vec();
    for rec in &records {
        rec.encode_into(&mut built);
    }
    check_golden("persist_wal.gbw", &built);

    // exact decode of the checked-in bytes, with zero damage counted
    let golden = std::fs::read(fixture_path("persist_wal.gbw")).unwrap();
    let scan = scan_wal(&golden);
    assert_eq!(scan.records, records, "WAL fixture no longer decodes to the frozen records");
    assert_eq!(scan.corrupt_records, 0);
    assert_eq!(scan.truncated_bytes, 0);
    assert!(!scan.missing_magic);
    assert_eq!(scan.valid_bytes, golden.len() as u64);
}

#[test]
fn golden_segment() {
    let (page, snapshot) = page_and_snapshot();
    let entries = vec![(PAGE_ID, page), (7, snapshot), (u64::MAX, Vec::new())];
    let built = encode_segment(&entries);
    check_golden("persist_segment.gbs", &built);

    let golden = std::fs::read(fixture_path("persist_segment.gbs")).unwrap();
    let scan = scan_segment(&golden);
    assert_eq!(scan.entries, entries, "segment fixture no longer decodes to the frozen pages");
    assert_eq!(scan.crc_failures, 0);
    assert_eq!(scan.truncated_bytes, 0);
    assert!(!scan.missing_magic);
}

#[test]
fn golden_manifest() {
    // the version byte is frozen at 1: changing the layout means a new
    // version (or magic), never a silent re-interpretation
    assert_eq!(MANIFEST_VERSION, 1, "bump requires a migration story, not just this test");

    let (_, snapshot) = page_and_snapshot();
    let manifest = Manifest { epoch: 9, shard_count: 4, codecs: vec![snapshot] };
    let built = encode_manifest(&manifest);
    check_golden("persist_manifest.gbm", &built);
    assert_eq!(built[4], 1, "version byte must sit right after the magic");

    let golden = std::fs::read(fixture_path("persist_manifest.gbm")).unwrap();
    assert_eq!(decode_manifest(&golden), Some(manifest));
}

#[test]
fn golden_wal_embedded_container_still_parses() {
    // the PutPage container in the fixture is a real GBC1 page: decode
    // it through the production parser and check the image round-trips
    let golden = std::fs::read(fixture_path("persist_wal.gbw")).unwrap();
    let scan = scan_wal(&golden);
    let Some(WalRecord::PutPage { container: bytes, .. }) = scan.records.first() else {
        panic!("first WAL record must be the PutPage");
    };
    let parsed = gbdi::container::Container::from_bytes(bytes).unwrap();
    assert_eq!(parsed.decompress().unwrap(), gbdi_mixed_image());
}
