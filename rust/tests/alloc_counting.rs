//! Allocation-budget regression: the steady-state random-access paths —
//! `Frame::read_block`, `Frame::read_range`, in-place `write_block`,
//! `BlockCodec::estimate_block_bits_with`, the stores' `read_into` page
//! sweeps, the hot-block cache tier's hit/absorb paths, and reads from
//! a crash-recovered store — must not touch the heap once scratch
//! buffers are warm. This binary registers
//! the crate's counting allocator globally and diffs its counter around
//! the hot loops, for all three block codecs.
//!
//! The allocator counter is process-global, so the tests serialize
//! through a gate mutex: no sibling test can allocate inside another's
//! measured window.

use gbdi::coordinator::{PageStore, ShardedPageStore, StoredPage};
use gbdi::persist::recover::recover;
use gbdi::persist::{DurableStore, FaultFs, PersistConfig};
use gbdi::util::alloc::CountingAlloc;
use gbdi::util::prng::Rng;
use gbdi::{BlockCodec, CodecKind, Frame, GbdiConfig, Scratch};
use std::sync::{Arc, Mutex};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Serializes whole test bodies (see module docs).
static GATE: Mutex<()> = Mutex::new(());

/// Run `f` in a measured window and return the allocation events it
/// caused.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = CountingAlloc::allocations();
    f();
    CountingAlloc::allocations() - before
}

fn clustered_image(len_words: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..len_words)
        .flat_map(|_| {
            let v: u32 = match rng.below(4) {
                0 => 4000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                1 => (1u32 << 23).wrapping_add(rng.range_i64(-300, 300) as u32),
                2 => 0,
                _ => rng.next_u32(),
            };
            v.to_le_bytes()
        })
        .collect()
}

#[test]
fn read_and_estimate_paths_do_not_allocate() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let image = clustered_image(16 * 1024, 61); // 64 KiB
    let cfg = GbdiConfig::default();
    for &kind in CodecKind::all() {
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&image, &cfg));
        let frame = Frame::compress(Arc::clone(&codec), &image);
        let n = frame.n_blocks();
        let mut line = vec![0u8; frame.block_bytes()];
        let mut scratch = Scratch::new();
        let mut sink = 0u64;
        let mut pass = |sink: &mut u64, scratch: &mut Scratch| {
            for k in 0..2000usize {
                let i = (k * 131) % n;
                frame.read_block(i, &mut line).unwrap();
                *sink = sink.wrapping_add(line[0] as u64);
                *sink = sink.wrapping_add(
                    codec.estimate_block_bits_with(&image[i * 64..(i + 1) * 64], scratch),
                );
            }
        };
        // warm pass: scratch buffers grow to their steady-state size
        pass(&mut sink, &mut scratch);
        let allocs = allocs_during(|| pass(&mut sink, &mut scratch));
        std::hint::black_box(sink);
        assert_eq!(allocs, 0, "{}: read/estimate hot loop allocated", kind.name());
    }
}

#[test]
fn decode_hot_paths_do_not_allocate() {
    // the decode side of the kernel rewrite: raw `decompress_block`
    // straight off a packed payload (GBDI through its decode LUT) and
    // `Frame::read_block` must stay at 0 allocs/op
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let image = clustered_image(16 * 1024, 65); // 64 KiB, whole blocks only
    let cfg = GbdiConfig::default();
    for &kind in CodecKind::all() {
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&image, &cfg));
        let container = gbdi::container::compress(codec.as_ref(), &image);
        let frame = Frame::compress(Arc::clone(&codec), &image);
        let n = frame.n_blocks();
        // bit offset of every block in the serial payload (the plain
        // prefix-sum walk needs chunk_blocks == 0: no chunk realignment)
        assert_eq!(container.chunk_blocks, 0);
        let mut offsets = Vec::with_capacity(n);
        let mut off = 0u64;
        for &bits in &container.block_bits {
            offsets.push(off);
            off += bits as u64;
        }
        let payload = &container.payload;
        let mut out = vec![0u8; codec.block_bytes()];
        let mut sink = 0u64;
        let mut pass = |sink: &mut u64| {
            for k in 0..2000usize {
                let i = (k * 131) % n;
                let byte = (offsets[i] / 8) as usize;
                let sub = (offsets[i] % 8) as u32;
                let mut r = gbdi::util::bits::BitReader::new(&payload[byte..]);
                if sub != 0 {
                    r.get(sub).unwrap();
                }
                codec.decompress_block(&mut r, &mut out).unwrap();
                *sink = sink.wrapping_add(out[0] as u64);
                frame.read_block(i, &mut out).unwrap();
                *sink = sink.wrapping_add(out[0] as u64);
            }
        };
        // warm pass (nothing to warm on these paths, but keep symmetry)
        pass(&mut sink);
        let allocs = allocs_during(|| pass(&mut sink));
        std::hint::black_box(sink);
        assert_eq!(allocs, 0, "{}: decode hot loop allocated", kind.name());
    }
}

#[test]
fn range_reads_do_not_allocate_once_warm() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let image = clustered_image(16 * 1024, 62);
    let cfg = GbdiConfig::default();
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));
    let frame = Frame::compress(Arc::clone(&codec), &image);
    let mut scratch = Scratch::new();
    let mut out = vec![0u8; 300];
    // warm: the partial-block scratch buffer allocates exactly once
    frame.read_range(13, &mut out, &mut scratch).unwrap();
    let allocs = allocs_during(|| {
        for k in 0..1000usize {
            let off = (k * 77) % (image.len() - out.len());
            frame.read_range(off, &mut out, &mut scratch).unwrap();
        }
    });
    assert_eq!(allocs, 0, "read_range hot loop allocated");
}

#[test]
fn in_place_writes_do_not_allocate_once_warm() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    // rewriting blocks with same-shaped content stays inside each
    // block's span: no patch growth, no writer growth, no allocations
    let image = clustered_image(16 * 1024, 63);
    let cfg = GbdiConfig::default();
    for &kind in CodecKind::all() {
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&image, &cfg));
        let mut frame = Frame::compress(Arc::clone(&codec), &image);
        let n = frame.n_blocks();
        let mut scratch = Scratch::new();
        let mut line = vec![0u8; frame.block_bytes()];
        let mut pass = |frame: &mut Frame, scratch: &mut Scratch| {
            for k in 0..500usize {
                let i = (k * 37) % n;
                // read the block and write the same bytes back: the
                // re-encoding is identical, so it always fits in place
                frame.read_block(i, &mut line).unwrap();
                frame.write_block(i, &line, scratch).unwrap();
            }
        };
        // warm pass: scratch writer + plan buffers reach steady state
        pass(&mut frame, &mut scratch);
        let allocs = allocs_during(|| pass(&mut frame, &mut scratch));
        assert_eq!(allocs, 0, "{}: in-place write hot loop allocated", kind.name());
    }
}

#[test]
fn store_read_into_and_cache_hot_paths_do_not_allocate() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let image = clustered_image(1024, 64); // 4 KiB: one 64-block page
    let cfg = GbdiConfig::default();
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));

    // `read_into` reuses the caller's buffer: after the first sweep
    // grows it, repeat sweeps stay off the heap entirely
    let mut plain = PageStore::new();
    plain.publish_codec(Arc::clone(&codec));
    plain.put(7, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) });
    let mut page = Vec::new();
    plain.read_into(7, &mut page).unwrap();
    let allocs = allocs_during(|| {
        for _ in 0..200 {
            plain.read_into(7, &mut page).unwrap();
        }
    });
    assert_eq!(allocs, 0, "PageStore::read_into hot loop allocated");

    // the cache tier: one shard, a cache big enough that the page's 64
    // blocks all stay resident once admitted
    let store = ShardedPageStore::new(1).with_cache(1 << 20);
    store.publish_codec(Arc::clone(&codec));
    store.put(7, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) });
    let mut line = [0u8; 64];
    for blk in 0..64 {
        store.read_block(7, blk, &mut line).unwrap(); // warm: admit every block
    }
    let t0 = store.cache_totals();
    let allocs = allocs_during(|| {
        for k in 0..2000usize {
            store.read_block(7, k % 64, &mut line).unwrap();
        }
    });
    let t1 = store.cache_totals();
    assert_eq!(allocs, 0, "cache-hit read_block hot loop allocated");
    assert_eq!(t1.hits - t0.hits, 2000, "every measured read must be a cache hit");

    // a fully clean cache overlays nothing into the page sweep, so the
    // sharded `read_into` matches the reference store at zero allocs
    store.read_into(7, &mut page).unwrap();
    let allocs = allocs_during(|| {
        for _ in 0..200 {
            store.read_into(7, &mut page).unwrap();
        }
    });
    assert_eq!(allocs, 0, "ShardedPageStore::read_into hot loop allocated");

    // absorbed writes update the resident copy in place — recompression
    // is deferred, so the hot write path never touches the heap either
    let allocs = allocs_during(|| {
        for k in 0..2000usize {
            store.write_block(7, k % 64, &line).unwrap();
        }
    });
    let t2 = store.cache_totals();
    assert_eq!(allocs, 0, "absorbed write hot loop allocated");
    assert_eq!(t2.hits - t1.hits, 2000, "every measured write must be absorbed");
}

#[test]
fn recovered_store_read_paths_do_not_allocate() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let image = clustered_image(1024, 66); // 4 KiB: one 64-block page
    let cfg = GbdiConfig::default();
    let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));

    // build a data directory in the in-memory fault filesystem: one page
    // folded into a checkpoint segment, one WAL-only, one with a WAL
    // block patch on top — so recovery rebuilds frames from every source
    let fs = FaultFs::default();
    let (ds, _) =
        DurableStore::open(Arc::new(fs.clone()), "data", PersistConfig::default(), 1, 0).unwrap();
    ds.publish_codec(Arc::clone(&codec)).unwrap();
    ds.put(1, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) }).unwrap();
    ds.checkpoint().unwrap(); // page 1 now lives in a segment
    ds.put(2, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) }).unwrap();
    ds.write_block(2, 3, &[7u8; 64]).unwrap(); // replayed onto the frame
    drop(ds);
    let (store, report) = recover(&fs, "data", None, 0).unwrap();
    assert!(!report.saw_damage(), "clean directory must recover without damage");
    assert_eq!(store.len(), 2);

    // recovered frames must be as hot as freshly compressed ones: block
    // reads and warmed page sweeps stay off the heap
    let mut line = [0u8; 64];
    let mut page = Vec::new();
    for id in [1u64, 2] {
        store.read_block(id, 0, &mut line).unwrap(); // symmetry with the warm passes
        let allocs = allocs_during(|| {
            for k in 0..2000usize {
                store.read_block(id, k % 64, &mut line).unwrap();
            }
        });
        assert_eq!(allocs, 0, "recovered page {id}: read_block hot loop allocated");
        store.read_into(id, &mut page).unwrap(); // warm: grows the buffer once
        let allocs = allocs_during(|| {
            for _ in 0..200 {
                store.read_into(id, &mut page).unwrap();
            }
        });
        assert_eq!(allocs, 0, "recovered page {id}: read_into hot loop allocated");
    }
}
