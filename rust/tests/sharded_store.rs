//! Shard correctness: the sharded page store must be observationally
//! identical to the single-lock reference store under any interleaving
//! of operations, and concurrent mixed traffic must lose no writes
//! while per-shard metrics sum exactly to the global totals.
//!
//! * `sharded_store_equivalent_to_reference_store` — a randomized
//!   single-threaded interleaving of put / get / read_block /
//!   write_block / table-swap / shard-migration / remove applied to
//!   three arms at once — the reference store, the sharded store, and
//!   a sharded store with the hot-block cache tier on (deliberately
//!   tiny, so admission, eviction, and deferred recompression all fire
//!   mid-schedule) — compared op-by-op and in a final sweep, for
//!   N ∈ {1, 2, 7} shards. A forced phase then migrates shards while
//!   dirty deferred writes are outstanding, and a final `flush_cache`
//!   must drain clean without changing any observable content.
//! * `concurrent_mixed_ops_lose_no_writes` — M threads × mixed ops on
//!   the sharded store (each thread owns a disjoint page set for
//!   writes), then a full content verification plus the metrics-sum
//!   invariant.
//! * `online_resize_under_concurrent_traffic_loses_no_writes` — writer
//!   threads stream block writes (disjoint ownership) and reads while a
//!   resizer thread walks the shard count through splits and merges;
//!   afterwards every block holds its final pattern, per-shard metrics
//!   still sum to the issued totals, and the topology is the last one
//!   requested.
//! * `service_under_concurrent_clients_stays_consistent` — the same
//!   shape through the full `CompressionService`.

use gbdi::coordinator::{
    CompressionService, PageStore, ServiceConfig, ShardedPageStore, StoredPage,
};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::util::prng::Rng;
use gbdi::workloads;
use gbdi::{BlockCodec, Frame};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Three GBDI codec versions derived from three different value
/// populations — enough to exercise the codec ring and lagging-page
/// bookkeeping.
fn versioned_codecs(cfg: &GbdiConfig) -> (Vec<Vec<u8>>, Vec<Arc<dyn BlockCodec>>) {
    let imgs: Vec<Vec<u8>> = ["mcf", "svm", "fluidanimate"]
        .iter()
        .enumerate()
        .map(|(i, n)| workloads::by_name(n).unwrap().generate(4096, i as u64 + 1))
        .collect();
    let codecs = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| {
            let mut t = analyze::analyze_image(img, cfg);
            t.version = i as u64 + 1;
            Arc::new(GbdiCodec::new(t, cfg.clone())) as Arc<dyn BlockCodec>
        })
        .collect();
    (imgs, codecs)
}

/// Drive `migrate_shard` on both sharded arms and emulate it on the
/// reference store: `migrate_shard` re-encodes the lowest-id pages of
/// shard `idx` lagging behind `codec`, up to `budget` of them, so the
/// emulation re-encodes exactly that set. Works because the lagging set
/// (ids + codec versions) is observationally identical across the arms
/// — deferred cached writes never change a page's codec version.
fn migrate_all_arms(
    reference: &mut PageStore,
    sharded: &ShardedPageStore,
    cached: &ShardedPageStore,
    idx: usize,
    codec: &Arc<dyn BlockCodec>,
    budget: usize,
    step: u32,
) {
    let mut lagging: Vec<u64> = reference
        .lagging_pages(codec.version())
        .into_iter()
        .filter(|&p| sharded.shard_of(p) == idx)
        .collect();
    lagging.truncate(budget);
    for &id in &lagging {
        let data = reference.read(id).unwrap();
        reference.put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), &data) });
    }
    let a = sharded.migrate_shard(idx, codec, budget).unwrap();
    let b = cached.migrate_shard(idx, codec, budget).unwrap();
    assert_eq!(a, lagging.len(), "step {step} migrate shard {idx}");
    assert_eq!(b, lagging.len(), "step {step} migrate shard {idx} (cached)");
}

#[test]
fn sharded_store_equivalent_to_reference_store() {
    let cfg = GbdiConfig::default();
    let (imgs, codecs) = versioned_codecs(&cfg);
    for &shards in &[1usize, 2, 7] {
        let mut reference = PageStore::new();
        let sharded = ShardedPageStore::new(shards);
        // third arm: the same schedule through the hot-block cache
        // tier. 4 KiB across the shards is deliberately tiny so
        // admission, eviction, and deferred recompression all fire
        // mid-schedule rather than only in the final sweep.
        let cached = ShardedPageStore::new(shards).with_cache(4 * 1024);
        reference.publish_codec(Arc::clone(&codecs[0]));
        sharded.publish_codec(Arc::clone(&codecs[0]));
        cached.publish_codec(Arc::clone(&codecs[0]));
        let mut active = 0usize; // index of the currently published codec
        let mut rng = Rng::new(0xD1CE ^ shards as u64);
        let id_space = 96u64;
        for step in 0..1500u32 {
            let id = rng.below(id_space);
            match rng.below(10) {
                // put (insert or overwrite) under the active codec
                0..=2 => {
                    let img = &imgs[(id % 3) as usize];
                    let codec = &codecs[active];
                    reference
                        .put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), img) });
                    sharded
                        .put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), img) });
                    cached
                        .put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), img) });
                }
                // whole-page read (the cached arm overlays deferred writes)
                3..=4 => {
                    let a = reference.read(id);
                    let b = sharded.read(id);
                    let c = cached.read(id);
                    assert_eq!(a.is_ok(), b.is_ok(), "step {step} page {id}");
                    assert_eq!(a.is_ok(), c.is_ok(), "step {step} page {id} (cached)");
                    if let Ok(a) = &a {
                        assert_eq!(a, b.as_ref().unwrap(), "step {step} page {id}");
                        assert_eq!(a, c.as_ref().unwrap(), "step {step} page {id} (cached)");
                    }
                }
                // single-block read
                5..=6 => {
                    let blk = rng.below(64) as usize;
                    let mut buf_a = [0u8; 64];
                    let mut buf_b = [0u8; 64];
                    let mut buf_c = [0u8; 64];
                    let a = reference.read_block(id, blk, &mut buf_a);
                    let b = sharded.read_block(id, blk, &mut buf_b);
                    let c = cached.read_block(id, blk, &mut buf_c);
                    assert_eq!(a.is_ok(), b.is_ok(), "step {step} page {id} block {blk}");
                    assert_eq!(a.is_ok(), c.is_ok(), "step {step} block {blk} (cached)");
                    if let Ok(n) = a {
                        assert_eq!(n, b.unwrap());
                        assert_eq!(n, c.unwrap());
                        assert_eq!(buf_a, buf_b, "step {step} page {id} block {blk}");
                        assert_eq!(buf_a, buf_c, "step {step} block {blk} (cached)");
                    }
                }
                // single-block write of identical random data
                7..=8 => {
                    let blk = rng.below(64) as usize;
                    let mut data = [0u8; 64];
                    if rng.below(3) == 0 {
                        // compressible content exercises the in-place path
                        data.fill(0);
                    } else {
                        rng.fill_bytes(&mut data);
                    }
                    let a = reference.write_block(id, blk, &data);
                    let b = sharded.write_block(id, blk, &data);
                    let c = cached.write_block(id, blk, &data);
                    assert_eq!(a.is_ok(), b.is_ok(), "step {step} page {id} block {blk}");
                    // an absorbed (deferred) write reports the frame's
                    // stale bits by design, so the cached arm is only
                    // comparable on success/failure here — content
                    // equality is pinned by every read and the sweep
                    assert_eq!(a.is_ok(), c.is_ok(), "step {step} block {blk} (cached)");
                    if let (Ok(a), Ok(b)) = (a, b) {
                        assert_eq!(a, b, "step {step}: BlockWrite outcome must match");
                    }
                }
                // table swap, shard migration, or removal
                _ => match rng.below(3) {
                    0 if active + 1 < codecs.len() => {
                        active += 1;
                        reference.publish_codec(Arc::clone(&codecs[active]));
                        sharded.publish_codec(Arc::clone(&codecs[active]));
                        cached.publish_codec(Arc::clone(&codecs[active]));
                    }
                    1 => {
                        let idx = rng.below(shards as u64) as usize;
                        let budget = 1 + rng.below(3) as usize;
                        let codec = &codecs[active];
                        migrate_all_arms(
                            &mut reference, &sharded, &cached, idx, codec, budget, step,
                        );
                    }
                    _ => {
                        let a = reference.remove(id);
                        let b = sharded.remove(id);
                        let c = cached.remove(id);
                        assert_eq!(a.is_some(), b.is_some(), "step {step} remove {id}");
                        assert_eq!(a.is_some(), c.is_some(), "step {step} remove {id} (cached)");
                    }
                },
            }
        }
        // forced phase: migrate shards while dirty deferred writes are
        // outstanding. Plant pages encoded under the oldest codec (ids
        // outside the random range, so lagging pages are guaranteed to
        // exist), publish the newest table everywhere, absorb a write
        // into a resident cached block of each still-lagging page, then
        // migrate its whole shard with the dirty copy still cached —
        // at least one such round fires per shard that holds laggards.
        let newest = codecs.last().unwrap();
        for id in [id_space, id_space + 1] {
            let frame = || Frame::compress(Arc::clone(&codecs[0]), &imgs[0]);
            reference.put(id, StoredPage { frame: frame() });
            sharded.put(id, StoredPage { frame: frame() });
            cached.put(id, StoredPage { frame: frame() });
        }
        for c in &codecs[active..] {
            reference.publish_codec(Arc::clone(c));
            sharded.publish_codec(Arc::clone(c));
            cached.publish_codec(Arc::clone(c));
        }
        let before = cached.cache_totals();
        let mut forced = 0u64;
        for id in 0..id_space + 2 {
            let lags = reference.get(id).is_some_and(|p| p.codec_version() < newest.version());
            if !lags {
                continue;
            }
            forced += 1;
            let mut line = [0u8; 64];
            // two reads pin block 0 resident: the first admits it on a
            // miss, and the second either hits (a hit only sets the ref
            // bit, it cannot evict) or re-admits into a queue whose ref
            // bits the first admission's eviction pass already cleared,
            // so the freshly admitted block cannot be its own victim
            cached.read_block(id, 0, &mut line).unwrap();
            cached.read_block(id, 0, &mut line).unwrap();
            let hits_before = cached.cache_totals().hits;
            let mut data = [0u8; 64];
            rng.fill_bytes(&mut data);
            let a = reference.write_block(id, 0, &data).unwrap();
            let b = sharded.write_block(id, 0, &data).unwrap();
            assert_eq!(a, b, "forced write {id}: BlockWrite outcome must match");
            cached.write_block(id, 0, &data).unwrap(); // absorbed: deferred, dirty
            assert!(
                cached.cache_totals().hits > hits_before,
                "forced write {id} must be absorbed by the cache"
            );
            let idx = sharded.shard_of(id);
            migrate_all_arms(&mut reference, &sharded, &cached, idx, newest, usize::MAX, 9999);
            assert_eq!(
                reference.read(id).unwrap(),
                cached.read(id).unwrap(),
                "page {id} after migrating with a dirty deferred block outstanding"
            );
        }
        assert!(forced >= 1, "{shards} shards: planted lagging pages must exist");
        let after = cached.cache_totals();
        assert!(
            after.deferred_flushes >= before.deferred_flushes + forced,
            "{shards} shards: each forced migration must fold its dirty deferred block"
        );
        // final sweep: aggregates and every page byte-identical. The
        // cached arm's stored_bytes additionally counts cache-resident
        // bytes and reflects deferred-write patch history, so only the
        // cacheless pair is footprint-comparable.
        assert_eq!(reference.len(), sharded.len(), "{shards} shards");
        assert_eq!(reference.len(), cached.len(), "{shards} shards (cached)");
        assert_eq!(reference.logical_bytes(), sharded.logical_bytes(), "{shards} shards");
        assert_eq!(reference.logical_bytes(), cached.logical_bytes(), "{shards} (cached)");
        assert_eq!(reference.stored_bytes(), sharded.stored_bytes(), "{shards} shards");
        assert_eq!(reference.codec_count(), sharded.codec_count(), "{shards} shards");
        assert_eq!(reference.codec_count(), cached.codec_count(), "{shards} shards (cached)");
        let newest_v = newest.version();
        assert_eq!(
            reference.lagging_pages(newest_v),
            sharded.lagging_pages(newest_v),
            "{shards} shards"
        );
        assert_eq!(
            reference.lagging_pages(newest_v),
            cached.lagging_pages(newest_v),
            "{shards} shards (cached)"
        );
        for id in 0..id_space + 2 {
            match reference.get(id) {
                Some(p) => {
                    assert_eq!(
                        Some(p.codec_version()),
                        sharded.with_page(id, |q| q.codec_version()),
                        "page {id} version"
                    );
                    assert_eq!(
                        Some(p.codec_version()),
                        cached.with_page(id, |q| q.codec_version()),
                        "page {id} version (cached)"
                    );
                    assert_eq!(
                        Some(p.stored_len()),
                        sharded.with_page(id, |q| q.stored_len()),
                        "page {id} footprint"
                    );
                    let want = reference.read(id).unwrap();
                    assert_eq!(want, sharded.read(id).unwrap(), "page {id} content");
                    assert_eq!(want, cached.read(id).unwrap(), "page {id} content (cached)");
                }
                None => {
                    assert!(!sharded.contains(id), "page {id} must be absent");
                    assert!(!cached.contains(id), "page {id} must be absent (cached)");
                }
            }
        }
        // the cache demonstrably engaged during the schedule, and
        // flushing it drains every deferred write without changing any
        // observable content
        let t = cached.cache_totals();
        assert!(t.admissions > 0, "{shards} shards: cache never admitted");
        assert!(t.hits > 0, "{shards} shards: cache never hit");
        assert!(t.evictions > 0, "{shards} shards: cache never evicted");
        let flushed = cached.flush_cache();
        let t2 = cached.cache_totals();
        assert_eq!(t2.dirty_blocks, 0, "{shards} shards: flush_cache left dirty blocks");
        assert_eq!(
            t2.deferred_flushes,
            t.deferred_flushes + flushed as u64,
            "{shards} shards: flush_cache must count every deferred write"
        );
        for id in 0..id_space + 2 {
            if reference.get(id).is_some() {
                assert_eq!(
                    reference.read(id).unwrap(),
                    cached.read(id).unwrap(),
                    "page {id} content after flush_cache"
                );
            }
        }
    }
}

#[test]
fn concurrent_mixed_ops_lose_no_writes() {
    let cfg = GbdiConfig::default();
    let img = workloads::by_name("mcf").unwrap().generate(4096, 42);
    let codec: Arc<dyn BlockCodec> =
        Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
    let store = ShardedPageStore::new(7);
    store.publish_codec(Arc::clone(&codec));
    let n_pages = 48u64;
    let threads = 8u64;
    for id in 0..n_pages {
        store.put(id, StoredPage { frame: Frame::compress(Arc::clone(&codec), &img) });
    }
    // deterministic per-(page, block) content, so repeated writes are
    // idempotent and the final state is known regardless of scheduling
    let pattern = |id: u64, blk: usize| [(id as u8).wrapping_mul(37) ^ (blk as u8); 64];
    let total_reads: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let store = &store;
                let img = &img;
                s.spawn(move || {
                    let mut rng = Rng::new(1000 + t);
                    let mut line = [0u8; 64];
                    let mut reads = 0u64;
                    // write every block of every page this thread owns,
                    // interleaving reads of random (possibly foreign,
                    // possibly mid-write) pages
                    for id in (t..n_pages).step_by(threads as usize) {
                        for blk in 0..64usize {
                            store.write_block(id, blk, &pattern(id, blk)).unwrap();
                            // immediately visible to the writer
                            store.read_block(id, blk, &mut line).unwrap();
                            assert_eq!(line, pattern(id, blk), "read-own-write {id}/{blk}");
                            reads += 1;
                            // a read of someone else's page sees either
                            // the original image or their pattern, never
                            // torn data (read_block verifies framing)
                            let other = rng.below(n_pages);
                            let oblk = rng.below(64) as usize;
                            store.read_block(other, oblk, &mut line).unwrap();
                            assert!(
                                line == pattern(other, oblk)
                                    || line[..] == img[oblk * 64..(oblk + 1) * 64],
                                "torn read on {other}/{oblk}"
                            );
                            reads += 1;
                        }
                    }
                    reads
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("stress thread")).sum()
    });
    // no lost writes: every block of every page holds its final pattern
    for id in 0..n_pages {
        let page = store.read(id).unwrap();
        for blk in 0..64usize {
            assert_eq!(
                page[blk * 64..(blk + 1) * 64],
                pattern(id, blk),
                "lost write on {id}/{blk}"
            );
        }
    }
    // per-shard metrics sum to the totals we actually issued
    let total_writes = n_pages * 64;
    let snaps = store.shard_metrics();
    assert_eq!(snaps.len(), 7);
    assert_eq!(snaps.iter().map(|s| s.block_writes).sum::<u64>(), total_writes);
    assert_eq!(snaps.iter().map(|s| s.block_reads).sum::<u64>(), total_reads);
    assert_eq!(snaps.iter().map(|s| s.pages).sum::<u64>(), store.len() as u64);
    assert_eq!(
        snaps.iter().map(|s| s.logical_bytes).sum::<u64>(),
        store.logical_bytes() as u64
    );
    assert_eq!(
        snaps.iter().map(|s| s.stored_bytes).sum::<u64>(),
        store.stored_bytes() as u64
    );
    // exclusive acquisitions happened on every shard that holds pages
    for s in &snaps {
        if s.pages > 0 {
            assert!(s.lock_holds > 0, "shard {} never took its write lock", s.shard);
        }
    }
}

#[test]
fn online_resize_under_concurrent_traffic_loses_no_writes() {
    let cfg = GbdiConfig::default();
    let img = workloads::by_name("fluidanimate").unwrap().generate(4096, 11);
    let codec: Arc<dyn BlockCodec> =
        Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
    let store = ShardedPageStore::new(2);
    store.publish_codec(Arc::clone(&codec));
    let n_pages = 48u64;
    let threads = 4u64;
    for id in 0..n_pages {
        store.put(id, StoredPage { frame: Frame::compress(Arc::clone(&codec), &img) });
    }
    let pattern = |id: u64, blk: usize| [(id as u8).wrapping_mul(29) ^ (blk as u8); 64];
    let done = AtomicBool::new(false);
    let (total_reads, rounds, moved) = std::thread::scope(|s| {
        let writers: Vec<_> = (0..threads)
            .map(|t| {
                let store = &store;
                let img = &img;
                s.spawn(move || {
                    let mut rng = Rng::new(0x2E51 + t);
                    let mut line = [0u8; 64];
                    let mut reads = 0u64;
                    for id in (t..n_pages).step_by(threads as usize) {
                        for blk in 0..64usize {
                            store.write_block(id, blk, &pattern(id, blk)).unwrap();
                            // a resize between the write and this read
                            // must carry the block to its new shard
                            store.read_block(id, blk, &mut line).unwrap();
                            assert_eq!(line, pattern(id, blk), "read-own-write {id}/{blk}");
                            reads += 1;
                            let other = rng.below(n_pages);
                            let oblk = rng.below(64) as usize;
                            store.read_block(other, oblk, &mut line).unwrap();
                            assert!(
                                line == pattern(other, oblk)
                                    || line[..] == img[oblk * 64..(oblk + 1) * 64],
                                "torn read on {other}/{oblk} during resize"
                            );
                            reads += 1;
                        }
                    }
                    reads
                })
            })
            .collect();
        // the resizer walks splits and merges until every writer is
        // done, then lands on the final topology — the coprime counts
        // guarantee reroutes in both directions
        let resizer = {
            let store = &store;
            let done = &done;
            s.spawn(move || {
                let plan = [5usize, 1, 7, 3];
                let mut rounds = 0u64;
                let mut moved = 0usize;
                loop {
                    let n = plan[(rounds % plan.len() as u64) as usize];
                    moved += store.resize_shards(n);
                    assert_eq!(store.shard_count(), n, "round {rounds}");
                    rounds += 1;
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                moved += store.resize_shards(3);
                (rounds, moved)
            })
        };
        let reads: u64 = writers.into_iter().map(|h| h.join().expect("writer thread")).sum();
        done.store(true, Ordering::Release);
        let (rounds, moved) = resizer.join().expect("resizer thread");
        (reads, rounds, moved)
    });
    assert!(rounds >= 1, "the resizer must complete at least one resize");
    assert!(moved > 0, "resizing between coprime shard counts must reroute pages");
    assert_eq!(store.shard_count(), 3, "the last requested topology must stick");
    // no lost writes across any number of splits and merges
    for id in 0..n_pages {
        let page = store.read(id).unwrap();
        for blk in 0..64usize {
            assert_eq!(
                page[blk * 64..(blk + 1) * 64],
                pattern(id, blk),
                "lost write on {id}/{blk}"
            );
        }
    }
    // counters moved with their shard indices (retired ones folded into
    // shard 0), so per-shard metrics still sum to the issued traffic and
    // the live gauges to the store totals
    let snaps = store.shard_metrics();
    assert_eq!(snaps.len(), 3);
    assert_eq!(snaps.iter().map(|s| s.block_writes).sum::<u64>(), n_pages * 64);
    assert_eq!(snaps.iter().map(|s| s.block_reads).sum::<u64>(), total_reads);
    assert_eq!(snaps.iter().map(|s| s.pages).sum::<u64>(), store.len() as u64);
    assert_eq!(
        snaps.iter().map(|s| s.logical_bytes).sum::<u64>(),
        store.logical_bytes() as u64
    );
    assert_eq!(
        snaps.iter().map(|s| s.stored_bytes).sum::<u64>(),
        store.stored_bytes() as u64
    );
}

#[test]
fn service_under_concurrent_clients_stays_consistent() {
    let img = workloads::by_name("triangle_count").unwrap().generate(4096, 7);
    let codec: Arc<dyn BlockCodec> = {
        let cfg = GbdiConfig::default();
        Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg))
    };
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards: 7, ..Default::default() },
        codec,
    )
    .unwrap();
    let n_pages = 40u64;
    let threads = 4u64;
    svc.submit_batch((0..n_pages).map(|i| (i, img.clone())).collect());
    svc.flush();
    let pattern = |id: u64, blk: usize| [(id as u8) ^ (blk as u8).wrapping_mul(11); 64];
    std::thread::scope(|s| {
        for t in 0..threads {
            let svc = &svc;
            s.spawn(move || {
                let mut line = [0u8; 64];
                let mut rng = Rng::new(7 + t);
                for id in (t..n_pages).step_by(threads as usize) {
                    for blk in 0..64usize {
                        svc.write_block(id, blk, &pattern(id, blk)).unwrap();
                        let other = rng.below(n_pages);
                        svc.read_block(other, rng.below(64) as usize, &mut line).unwrap();
                    }
                }
            });
        }
    });
    for id in 0..n_pages {
        let page = svc.read_page(id).unwrap();
        for blk in 0..64usize {
            assert_eq!(
                page[blk * 64..(blk + 1) * 64],
                pattern(id, blk),
                "lost write on {id}/{blk}"
            );
        }
    }
    let shards = svc.shard_metrics();
    let m = svc.metrics();
    assert_eq!(shards.iter().map(|s| s.block_reads).sum::<u64>(), m.block_reads);
    assert_eq!(shards.iter().map(|s| s.block_writes).sum::<u64>(), m.block_writes);
    assert_eq!(m.block_writes, n_pages * 64);
    assert_eq!(m.write_errors, 0);
    assert_eq!(m.read_errors, 0);
    svc.shutdown();
}
