//! Shard correctness: the sharded page store must be observationally
//! identical to the single-lock reference store under any interleaving
//! of operations, and concurrent mixed traffic must lose no writes
//! while per-shard metrics sum exactly to the global totals.
//!
//! * `sharded_store_equivalent_to_reference_store` — a randomized
//!   single-threaded interleaving of put / get / read_block /
//!   write_block / table-swap / remove applied to both stores, compared
//!   op-by-op and in a final sweep, for N ∈ {1, 2, 7} shards.
//! * `concurrent_mixed_ops_lose_no_writes` — M threads × mixed ops on
//!   the sharded store (each thread owns a disjoint page set for
//!   writes), then a full content verification plus the metrics-sum
//!   invariant.
//! * `service_under_concurrent_clients_stays_consistent` — the same
//!   shape through the full `CompressionService`.

use gbdi::coordinator::{
    CompressionService, PageStore, ServiceConfig, ShardedPageStore, StoredPage,
};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::util::prng::Rng;
use gbdi::workloads;
use gbdi::{BlockCodec, Frame};
use std::sync::Arc;

/// Three GBDI codec versions derived from three different value
/// populations — enough to exercise the codec ring and lagging-page
/// bookkeeping.
fn versioned_codecs(cfg: &GbdiConfig) -> (Vec<Vec<u8>>, Vec<Arc<dyn BlockCodec>>) {
    let imgs: Vec<Vec<u8>> = ["mcf", "svm", "fluidanimate"]
        .iter()
        .enumerate()
        .map(|(i, n)| workloads::by_name(n).unwrap().generate(4096, i as u64 + 1))
        .collect();
    let codecs = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| {
            let mut t = analyze::analyze_image(img, cfg);
            t.version = i as u64 + 1;
            Arc::new(GbdiCodec::new(t, cfg.clone())) as Arc<dyn BlockCodec>
        })
        .collect();
    (imgs, codecs)
}

#[test]
fn sharded_store_equivalent_to_reference_store() {
    let cfg = GbdiConfig::default();
    let (imgs, codecs) = versioned_codecs(&cfg);
    for &shards in &[1usize, 2, 7] {
        let mut reference = PageStore::new();
        let sharded = ShardedPageStore::new(shards);
        reference.publish_codec(Arc::clone(&codecs[0]));
        sharded.publish_codec(Arc::clone(&codecs[0]));
        let mut active = 0usize; // index of the currently published codec
        let mut rng = Rng::new(0xD1CE ^ shards as u64);
        let id_space = 96u64;
        for step in 0..1500u32 {
            let id = rng.below(id_space);
            match rng.below(10) {
                // put (insert or overwrite) under the active codec
                0..=2 => {
                    let img = &imgs[(id % 3) as usize];
                    let codec = &codecs[active];
                    reference
                        .put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), img) });
                    sharded
                        .put(id, StoredPage { frame: Frame::compress(Arc::clone(codec), img) });
                }
                // whole-page read
                3..=4 => {
                    let a = reference.read(id);
                    let b = sharded.read(id);
                    match (a, b) {
                        (Ok(a), Ok(b)) => assert_eq!(a, b, "step {step} page {id}"),
                        (a, b) => assert_eq!(a.is_err(), b.is_err(), "step {step} page {id}"),
                    }
                }
                // single-block read
                5..=6 => {
                    let blk = rng.below(64) as usize;
                    let mut buf_a = [0u8; 64];
                    let mut buf_b = [0u8; 64];
                    let a = reference.read_block(id, blk, &mut buf_a);
                    let b = sharded.read_block(id, blk, &mut buf_b);
                    assert_eq!(a.is_ok(), b.is_ok(), "step {step} page {id} block {blk}");
                    if a.is_ok() {
                        assert_eq!(a.unwrap(), b.unwrap());
                        assert_eq!(buf_a, buf_b, "step {step} page {id} block {blk}");
                    }
                }
                // single-block write of identical random data
                7..=8 => {
                    let blk = rng.below(64) as usize;
                    let mut data = [0u8; 64];
                    if rng.below(3) == 0 {
                        // compressible content exercises the in-place path
                        data.fill(0);
                    } else {
                        rng.fill_bytes(&mut data);
                    }
                    let a = reference.write_block(id, blk, &data);
                    let b = sharded.write_block(id, blk, &data);
                    assert_eq!(a.is_ok(), b.is_ok(), "step {step} page {id} block {blk}");
                    if let (Ok(a), Ok(b)) = (a, b) {
                        assert_eq!(a, b, "step {step}: BlockWrite outcome must match");
                    }
                }
                // table swap or removal
                _ => {
                    if active + 1 < codecs.len() && rng.below(2) == 0 {
                        active += 1;
                        reference.publish_codec(Arc::clone(&codecs[active]));
                        sharded.publish_codec(Arc::clone(&codecs[active]));
                    } else {
                        let a = reference.remove(id);
                        let b = sharded.remove(id);
                        assert_eq!(a.is_some(), b.is_some(), "step {step} remove {id}");
                    }
                }
            }
        }
        // final sweep: aggregates and every page byte-identical
        assert_eq!(reference.len(), sharded.len(), "{shards} shards");
        assert_eq!(reference.logical_bytes(), sharded.logical_bytes(), "{shards} shards");
        assert_eq!(reference.stored_bytes(), sharded.stored_bytes(), "{shards} shards");
        assert_eq!(reference.codec_count(), sharded.codec_count(), "{shards} shards");
        let newest = codecs.last().unwrap().version();
        assert_eq!(
            reference.lagging_pages(newest),
            sharded.lagging_pages(newest),
            "{shards} shards"
        );
        for id in 0..id_space {
            match reference.get(id) {
                Some(p) => {
                    assert_eq!(
                        Some(p.codec_version()),
                        sharded.with_page(id, |q| q.codec_version()),
                        "page {id} version"
                    );
                    assert_eq!(
                        Some(p.stored_len()),
                        sharded.with_page(id, |q| q.stored_len()),
                        "page {id} footprint"
                    );
                    assert_eq!(
                        reference.read(id).unwrap(),
                        sharded.read(id).unwrap(),
                        "page {id} content"
                    );
                }
                None => assert!(!sharded.contains(id), "page {id} must be absent"),
            }
        }
    }
}

#[test]
fn concurrent_mixed_ops_lose_no_writes() {
    let cfg = GbdiConfig::default();
    let img = workloads::by_name("mcf").unwrap().generate(4096, 42);
    let codec: Arc<dyn BlockCodec> =
        Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
    let store = ShardedPageStore::new(7);
    store.publish_codec(Arc::clone(&codec));
    let n_pages = 48u64;
    let threads = 8u64;
    for id in 0..n_pages {
        store.put(id, StoredPage { frame: Frame::compress(Arc::clone(&codec), &img) });
    }
    // deterministic per-(page, block) content, so repeated writes are
    // idempotent and the final state is known regardless of scheduling
    let pattern = |id: u64, blk: usize| [(id as u8).wrapping_mul(37) ^ (blk as u8); 64];
    let total_reads: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let store = &store;
                let img = &img;
                s.spawn(move || {
                    let mut rng = Rng::new(1000 + t);
                    let mut line = [0u8; 64];
                    let mut reads = 0u64;
                    // write every block of every page this thread owns,
                    // interleaving reads of random (possibly foreign,
                    // possibly mid-write) pages
                    for id in (t..n_pages).step_by(threads as usize) {
                        for blk in 0..64usize {
                            store.write_block(id, blk, &pattern(id, blk)).unwrap();
                            // immediately visible to the writer
                            store.read_block(id, blk, &mut line).unwrap();
                            assert_eq!(line, pattern(id, blk), "read-own-write {id}/{blk}");
                            reads += 1;
                            // a read of someone else's page sees either
                            // the original image or their pattern, never
                            // torn data (read_block verifies framing)
                            let other = rng.below(n_pages);
                            let oblk = rng.below(64) as usize;
                            store.read_block(other, oblk, &mut line).unwrap();
                            assert!(
                                line == pattern(other, oblk)
                                    || line[..] == img[oblk * 64..(oblk + 1) * 64],
                                "torn read on {other}/{oblk}"
                            );
                            reads += 1;
                        }
                    }
                    reads
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("stress thread")).sum()
    });
    // no lost writes: every block of every page holds its final pattern
    for id in 0..n_pages {
        let page = store.read(id).unwrap();
        for blk in 0..64usize {
            assert_eq!(
                page[blk * 64..(blk + 1) * 64],
                pattern(id, blk),
                "lost write on {id}/{blk}"
            );
        }
    }
    // per-shard metrics sum to the totals we actually issued
    let total_writes = n_pages * 64;
    let snaps = store.shard_metrics();
    assert_eq!(snaps.len(), 7);
    assert_eq!(snaps.iter().map(|s| s.block_writes).sum::<u64>(), total_writes);
    assert_eq!(snaps.iter().map(|s| s.block_reads).sum::<u64>(), total_reads);
    assert_eq!(snaps.iter().map(|s| s.pages).sum::<u64>(), store.len() as u64);
    assert_eq!(
        snaps.iter().map(|s| s.logical_bytes).sum::<u64>(),
        store.logical_bytes() as u64
    );
    assert_eq!(
        snaps.iter().map(|s| s.stored_bytes).sum::<u64>(),
        store.stored_bytes() as u64
    );
    // exclusive acquisitions happened on every shard that holds pages
    for s in &snaps {
        if s.pages > 0 {
            assert!(s.lock_holds > 0, "shard {} never took its write lock", s.shard);
        }
    }
}

#[test]
fn service_under_concurrent_clients_stays_consistent() {
    let img = workloads::by_name("triangle_count").unwrap().generate(4096, 7);
    let codec: Arc<dyn BlockCodec> = {
        let cfg = GbdiConfig::default();
        Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg))
    };
    let svc = CompressionService::start_static(
        ServiceConfig { workers: 2, shards: 7, ..Default::default() },
        codec,
    )
    .unwrap();
    let n_pages = 40u64;
    let threads = 4u64;
    svc.submit_batch((0..n_pages).map(|i| (i, img.clone())).collect());
    svc.flush();
    let pattern = |id: u64, blk: usize| [(id as u8) ^ (blk as u8).wrapping_mul(11); 64];
    std::thread::scope(|s| {
        for t in 0..threads {
            let svc = &svc;
            s.spawn(move || {
                let mut line = [0u8; 64];
                let mut rng = Rng::new(7 + t);
                for id in (t..n_pages).step_by(threads as usize) {
                    for blk in 0..64usize {
                        svc.write_block(id, blk, &pattern(id, blk)).unwrap();
                        let other = rng.below(n_pages);
                        svc.read_block(other, rng.below(64) as usize, &mut line).unwrap();
                    }
                }
            });
        }
    });
    for id in 0..n_pages {
        let page = svc.read_page(id).unwrap();
        for blk in 0..64usize {
            assert_eq!(
                page[blk * 64..(blk + 1) * 64],
                pattern(id, blk),
                "lost write on {id}/{blk}"
            );
        }
    }
    let shards = svc.shard_metrics();
    let m = svc.metrics();
    assert_eq!(shards.iter().map(|s| s.block_reads).sum::<u64>(), m.block_reads);
    assert_eq!(shards.iter().map(|s| s.block_writes).sum::<u64>(), m.block_writes);
    assert_eq!(m.block_writes, n_pages * 64);
    assert_eq!(m.write_errors, 0);
    assert_eq!(m.read_errors, 0);
    svc.shutdown();
}
