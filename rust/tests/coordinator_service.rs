//! Integration: the full coordinator service under concurrent load, table
//! churn across traffic phase changes (per base selector), and (when
//! artifacts exist) the PJRT-artifact selector end-to-end.

use gbdi::cluster::{ArtifactSelector, SelectorKind};
use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::runtime::ArtifactRuntime;
use gbdi::util::prng::Rng;
use gbdi::workloads;
use std::sync::Arc;

fn native_service(workers: usize, analyze_every: u64) -> CompressionService {
    CompressionService::start(ServiceConfig { workers, analyze_every, ..Default::default() })
        .unwrap()
}

/// Force analyses until the published version exceeds `above` (bounded);
/// returns the version reached.
fn wait_for_version_above(svc: &CompressionService, above: u64) -> u64 {
    for round in 0..10 {
        svc.request_analysis();
        for _ in 0..200 {
            if svc.current_version() > above {
                return svc.current_version();
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // reservoir may still be dominated by the old phase; let more
        // traffic arrive between forced rounds
        let _ = round;
    }
    svc.current_version()
}

#[test]
fn heavy_mixed_load_stays_bit_exact() {
    let svc = native_service(4, 64);
    let names = ["mcf", "perlbench", "fluidanimate", "svm", "deepsjeng"];
    let mut rng = Rng::new(5);
    let mut expected = Vec::new();
    for i in 0..400u64 {
        let w = workloads::by_name(names[rng.below(5) as usize]).unwrap();
        let page = w.generate(4096, i);
        expected.push(page.clone());
        svc.submit(i, page);
    }
    svc.flush();
    for (i, page) in expected.iter().enumerate() {
        assert_eq!(&svc.read_page(i as u64).unwrap(), page, "page {i}");
    }
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 400);
    assert!(m.analyses >= 1, "analyzer ran");
    assert!(m.ratio() > 1.0);
}

#[test]
fn phase_change_triggers_reclustering() {
    let svc = native_service(2, 48);
    // phase 1: zero-heavy
    for i in 0..96u64 {
        svc.submit(i, vec![0u8; 4096]);
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..400 {
        if svc.current_version() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let v1 = svc.current_version();
    // phase 2: pointer-heavy traffic — table should move again
    let w = workloads::by_name("mcf").unwrap();
    for i in 96..256u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..400 {
        if svc.current_version() > v1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let m = svc.metrics();
    assert!(m.analyses >= 2, "analyses {}", m.analyses);
    // all pages from both phases still decode
    assert_eq!(svc.read_page(0).unwrap(), vec![0u8; 4096]);
    assert_eq!(svc.read_page(200).unwrap(), w.generate(4096, 200));
    svc.shutdown();
}

#[test]
fn flush_is_a_complete_barrier() {
    let svc = native_service(4, 1_000_000);
    for round in 0..10u64 {
        for i in 0..50u64 {
            svc.submit(round * 50 + i, vec![round as u8; 4096]);
        }
        svc.flush();
        // every page of this round must be readable immediately
        for i in 0..50u64 {
            assert_eq!(svc.read_page(round * 50 + i).unwrap(), vec![round as u8; 4096]);
        }
    }
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 500);
}

#[test]
fn every_selector_serves_phase_change_bit_exact() {
    // The adaptive-service contract per selector: ingest workload A,
    // shift to workload B, and require (1) monotonically increasing
    // published table versions and (2) every stored page — old phase,
    // new phase, and recompressed — decoding bit-exactly.
    for &kind in SelectorKind::all() {
        let svc = CompressionService::start(ServiceConfig {
            workers: 2,
            analyze_every: 48,
            selector: kind,
            ..Default::default()
        })
        .unwrap();
        let a = workloads::by_name("fluidanimate").unwrap();
        let b = workloads::by_name("mcf").unwrap();
        // phase A
        for i in 0..96u64 {
            svc.submit(i, a.generate(4096, i));
        }
        svc.flush();
        let v1 = wait_for_version_above(&svc, 0);
        assert!(v1 > 0, "{}: analyzer never published a table", kind.name());
        // phase B: traffic shifts — the analyzer must publish a NEW
        // (strictly higher) table version for the shifted population
        for i in 96..224u64 {
            svc.submit(i, b.generate(4096, i));
        }
        svc.flush();
        let v2 = wait_for_version_above(&svc, v1);
        assert!(
            v2 > v1,
            "{}: phase change must publish a newer table (v1={v1}, v2={v2})",
            kind.name()
        );
        // migrate everything to the newest version, then verify all pages
        while svc.recompress_step().unwrap() > 0 {}
        for i in 0..224u64 {
            let expect = if i < 96 { a.generate(4096, i) } else { b.generate(4096, i) };
            assert_eq!(
                svc.read_page(i).unwrap(),
                expect,
                "{}: page {i} corrupt after phase change",
                kind.name()
            );
        }
        let m = svc.shutdown();
        assert!(m.analyses >= 1, "{}", kind.name());
        assert_eq!(m.read_errors, 0, "{}", kind.name());
    }
}

#[test]
fn drift_detection_skips_when_traffic_is_stable() {
    // steady single-workload traffic: after the first adoption, periodic
    // analysis rounds should be skipped by drift detection, not re-run
    let svc = CompressionService::start(ServiceConfig {
        workers: 2,
        analyze_every: 16,
        selector: SelectorKind::MiniBatch,
        // generous margin: we are testing the skip mechanism, not the
        // exact threshold
        drift_margin: 1.25,
        ..Default::default()
    })
    .unwrap();
    let w = workloads::by_name("mcf").unwrap();
    for i in 0..64u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    let v1 = wait_for_version_above(&svc, 0);
    assert!(v1 > 0, "first adoption must happen");
    // keep streaming the same distribution; give the analyzer time to
    // hit its periodic trigger several times
    for i in 64..256u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    for _ in 0..100 {
        if svc.metrics().analyses_skipped > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let m = svc.metrics();
    assert!(
        m.analyses_skipped > 0,
        "stable traffic must skip re-clustering (analyses {}, skipped {})",
        m.analyses,
        m.analyses_skipped
    );
    for i in 0..256u64 {
        assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
    }
    svc.shutdown();
}

#[test]
fn artifact_backend_end_to_end_if_built() {
    let Ok(rt) = ArtifactRuntime::new(ArtifactRuntime::default_dir()) else { return };
    if !rt.has_artifact("kmeans_k64") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let svc = CompressionService::start_with_selector(
        ServiceConfig { workers: 2, analyze_every: 32, ..Default::default() },
        Box::new(ArtifactSelector::new(Arc::new(rt))),
    )
    .unwrap();
    let w = workloads::by_name("triangle_count").unwrap();
    for i in 0..96u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..600 {
        if svc.current_version() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(svc.current_version() > 0, "PJRT analyzer never published a table");
    for i in 0..96u64 {
        assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
    }
    let m = svc.shutdown();
    assert!(m.table_swaps >= 1);
}

#[test]
fn shutdown_drains_pending_pages() {
    let svc = native_service(2, 1_000_000);
    for i in 0..100u64 {
        svc.submit(i, vec![i as u8; 4096]);
    }
    // no flush: shutdown must drain everything itself
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 100);
}

#[test]
fn storage_ratio_accounts_logical_and_stored() {
    let svc = native_service(2, 64);
    for i in 0..64u64 {
        svc.submit(i, vec![0u8; 4096]); // zeros: tiny stored size
    }
    svc.flush();
    let (logical, stored, ratio) = svc.storage_ratio();
    assert_eq!(logical, 64 * 4096);
    assert!(stored < logical / 10, "zeros stored {stored}");
    assert!(ratio > 10.0);
    svc.shutdown();
}
