//! Integration: the full coordinator service under concurrent load, table
//! churn across traffic phase changes, and (when artifacts exist) the
//! PJRT-artifact analyzer end-to-end.

use gbdi::coordinator::{AnalyzerBackend, CompressionService, ServiceConfig};
use gbdi::runtime::ArtifactRuntime;
use gbdi::util::prng::Rng;
use gbdi::workloads;
use std::sync::Arc;

fn native_service(workers: usize, analyze_every: u64) -> CompressionService {
    CompressionService::start(
        ServiceConfig { workers, analyze_every, ..Default::default() },
        AnalyzerBackend::Native,
    )
    .unwrap()
}

#[test]
fn heavy_mixed_load_stays_bit_exact() {
    let svc = native_service(4, 64);
    let names = ["mcf", "perlbench", "fluidanimate", "svm", "deepsjeng"];
    let mut rng = Rng::new(5);
    let mut expected = Vec::new();
    for i in 0..400u64 {
        let w = workloads::by_name(names[rng.below(5) as usize]).unwrap();
        let page = w.generate(4096, i);
        expected.push(page.clone());
        svc.submit(i, page);
    }
    svc.flush();
    for (i, page) in expected.iter().enumerate() {
        assert_eq!(&svc.read_page(i as u64).unwrap(), page, "page {i}");
    }
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 400);
    assert!(m.analyses >= 1, "analyzer ran");
    assert!(m.ratio() > 1.0);
}

#[test]
fn phase_change_triggers_reclustering() {
    let svc = native_service(2, 48);
    // phase 1: zero-heavy
    for i in 0..96u64 {
        svc.submit(i, vec![0u8; 4096]);
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..400 {
        if svc.current_version() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let v1 = svc.current_version();
    // phase 2: pointer-heavy traffic — table should move again
    let w = workloads::by_name("mcf").unwrap();
    for i in 96..256u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..400 {
        if svc.current_version() > v1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let m = svc.metrics();
    assert!(m.analyses >= 2, "analyses {}", m.analyses);
    // all pages from both phases still decode
    assert_eq!(svc.read_page(0).unwrap(), vec![0u8; 4096]);
    assert_eq!(svc.read_page(200).unwrap(), w.generate(4096, 200));
    svc.shutdown();
}

#[test]
fn flush_is_a_complete_barrier() {
    let svc = native_service(4, 1_000_000);
    for round in 0..10u64 {
        for i in 0..50u64 {
            svc.submit(round * 50 + i, vec![round as u8; 4096]);
        }
        svc.flush();
        // every page of this round must be readable immediately
        for i in 0..50u64 {
            assert_eq!(svc.read_page(round * 50 + i).unwrap(), vec![round as u8; 4096]);
        }
    }
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 500);
}

#[test]
fn artifact_backend_end_to_end_if_built() {
    let Ok(rt) = ArtifactRuntime::new(ArtifactRuntime::default_dir()) else { return };
    if !rt.has_artifact("kmeans_k64") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let svc = CompressionService::start(
        ServiceConfig { workers: 2, analyze_every: 32, ..Default::default() },
        AnalyzerBackend::Artifact(Arc::new(rt)),
    )
    .unwrap();
    let w = workloads::by_name("triangle_count").unwrap();
    for i in 0..96u64 {
        svc.submit(i, w.generate(4096, i));
    }
    svc.flush();
    svc.request_analysis();
    for _ in 0..600 {
        if svc.current_version() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(svc.current_version() > 0, "PJRT analyzer never published a table");
    for i in 0..96u64 {
        assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
    }
    let m = svc.shutdown();
    assert!(m.table_swaps >= 1);
}

#[test]
fn shutdown_drains_pending_pages() {
    let svc = native_service(2, 1_000_000);
    for i in 0..100u64 {
        svc.submit(i, vec![i as u8; 4096]);
    }
    // no flush: shutdown must drain everything itself
    let m = svc.shutdown();
    assert_eq!(m.pages_in, 100);
}

#[test]
fn storage_ratio_accounts_logical_and_stored() {
    let svc = native_service(2, 64);
    for i in 0..64u64 {
        svc.submit(i, vec![0u8; 4096]); // zeros: tiny stored size
    }
    svc.flush();
    let (logical, stored, ratio) = svc.storage_ratio();
    assert_eq!(logical, 64 * 4096);
    assert!(stored < logical / 10, "zeros stored {stored}");
    assert!(ratio > 10.0);
    svc.shutdown();
}
