//! Differential tests for the SIMD kernel backends (DESIGN.md §10).
//!
//! Every vector kernel must be **observationally identical** to the
//! scalar reference on every backend the host supports — same booleans,
//! same first-fit index (it goes on the wire as the base pointer), same
//! decoded bytes, same Ok/Err classification on corrupt input. Tests
//! iterate `Isa::all()` filtered by `Isa::supported()` and fetch
//! vtables through `kernels_for`, so they exercise whatever silicon CI
//! provides (SSE2 everywhere on x86_64, AVX2 where detected, NEON under
//! the QEMU aarch64 job) without racing on the process-global dispatch.

use gbdi::baselines::bdi::Bdi;
use gbdi::baselines::Codec;
use gbdi::gbdi::decode::{decompress_block, decompress_block_lut_with, DecodeLut};
use gbdi::gbdi::{GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::simd::{self, kernels_for, Isa, Kernels};
use gbdi::util::bits::BitReader;
use gbdi::util::prng::Rng;
use gbdi::value::WordSize;

/// The scalar oracle plus every vector backend this host can run.
fn backends() -> Vec<&'static Kernels> {
    Isa::all().iter().filter(|i| i.supported()).map(|&i| kernels_for(i)).collect()
}

fn scalar() -> &'static Kernels {
    kernels_for(Isa::Scalar)
}

// ---------------------------------------------------------------- block scans

#[test]
fn all_zero_matches_scalar_at_every_flip_position() {
    // ragged lengths straddle the 16/32-byte vector chunks, and a single
    // set byte at *every* position catches lane/tail classification bugs
    for len in [1usize, 4, 15, 16, 17, 31, 32, 33, 63, 64, 65, 256] {
        let zeros = vec![0u8; len];
        for k in backends() {
            assert!((k.all_zero)(&zeros), "{} len {}", k.isa.name(), len);
        }
        for pos in 0..len {
            let mut b = zeros.clone();
            b[pos] = 1;
            for k in backends() {
                assert!(!(k.all_zero)(&b), "{} len {} flip {}", k.isa.name(), len, pos);
            }
        }
    }
}

#[test]
fn rep_words_matches_scalar_at_every_flip_position() {
    let mut rng = Rng::new(41);
    // strides 2/4/8 take the vector paths; 3 and 16 take each backend's
    // scalar fallback (still must agree)
    for stride in [2usize, 3, 4, 8, 16] {
        for blocks in [1usize, 2, 5, 8, 9] {
            let len = stride * blocks;
            let mut pat = vec![0u8; stride];
            rng.fill_bytes(&mut pat);
            let rep: Vec<u8> = pat.iter().copied().cycle().take(len).collect();
            for k in backends() {
                let ok = (k.rep_words)(&rep, stride);
                assert!(ok, "{} stride {} len {}", k.isa.name(), stride, len);
            }
            // breaking any byte outside the leading pattern must flip the
            // verdict on every backend
            for pos in stride..len {
                let mut b = rep.clone();
                b[pos] ^= 0x5A;
                for k in backends() {
                    assert!(
                        !(k.rep_words)(&b, stride),
                        "{} stride {} len {} flip {}",
                        k.isa.name(),
                        stride,
                        len,
                        pos
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- first_fit

#[test]
fn first_fit_matches_scalar_on_random_intervals() {
    let mut rng = Rng::new(43);
    for trial in 0..400 {
        let n = rng.below(21) as usize; // 0..=20 candidates (ragged tails)
        let mut lo = Vec::with_capacity(n);
        let mut span = Vec::with_capacity(n);
        for _ in 0..n {
            lo.push(rng.next_u32());
            // mix of tight and huge (wrapping) intervals
            span.push(match rng.below(3) {
                0 => rng.below(16) as u32,
                1 => rng.next_u32() >> 16,
                _ => rng.next_u32(), // may wrap past u32::MAX
            });
        }
        for _ in 0..32 {
            let v = if rng.chance(0.5) && n > 0 {
                // land near a candidate boundary
                let i = rng.below(n as u64) as usize;
                lo[i].wrapping_add(span[i]).wrapping_add(rng.below(3) as u32).wrapping_sub(1)
            } else {
                rng.next_u32()
            };
            let want = (scalar().first_fit)(v, &lo, &span);
            for k in backends() {
                assert_eq!(
                    (k.first_fit)(v, &lo, &span),
                    want,
                    "{} trial {} v {}",
                    k.isa.name(),
                    trial,
                    v
                );
            }
        }
    }
}

#[test]
fn first_fit_returns_first_index_not_any_index() {
    // three overlapping candidates all containing v: index 0 must win on
    // every backend (candidate order is the on-wire base pointer)
    let lo = [100u32, 90, 0];
    let span = [50u32, 100, u32::MAX];
    for k in backends() {
        assert_eq!((k.first_fit)(120, &lo, &span), Some(0), "{}", k.isa.name());
        // only the later ones contain 95
        assert_eq!((k.first_fit)(95, &lo, &span), Some(1), "{}", k.isa.name());
        // wrapped interval: lo + span wraps past u32::MAX
        let wlo = [u32::MAX - 2u32];
        let wspan = [10u32];
        assert_eq!((k.first_fit)(5, &wlo, &wspan), Some(0), "{} wrap", k.isa.name());
        assert_eq!((k.first_fit)(9, &wlo, &wspan), None, "{} wrap miss", k.isa.name());
        assert_eq!((k.first_fit)(1, &[], &[]), None, "{} empty", k.isa.name());
    }
}

// ---------------------------------------------------------------- bdi_fits

/// The BDI encoding menu `encode_block_with` sweeps.
const BDI_MENU: [(usize, usize); 6] = [(8, 1), (4, 1), (8, 2), (2, 1), (4, 2), (8, 4)];

fn bdi_block(rng: &mut Rng, k: usize, flavor: u32) -> Vec<u8> {
    let n = 64 / k;
    let mut out = Vec::with_capacity(64);
    let base: u64 = rng.next_u64();
    for _ in 0..n {
        let v: u64 = match flavor {
            // clustered near the block base with near-boundary deltas:
            // |delta| hovers around every d's sign boundary
            0 => {
                let d = [127i64, 128, 129, -128, -129, 32767, 32768, -32768, -32769]
                    [rng.below(9) as usize];
                base.wrapping_add(d as u64)
            }
            // small values that zero-fit for most d
            1 => rng.below(200),
            // mix of zero-fitting and base-clustered
            2 => {
                if rng.chance(0.5) {
                    rng.below(100)
                } else {
                    base.wrapping_add(rng.range_i64(-120, 120) as u64)
                }
            }
            // adversarial: random full-width words
            _ => rng.next_u64(),
        };
        for b in 0..k {
            out.push((v >> (8 * b)) as u8);
        }
    }
    out
}

#[test]
fn bdi_fits_matches_scalar_across_menu() {
    let mut rng = Rng::new(47);
    for trial in 0u32..300 {
        for &(k, d) in &BDI_MENU {
            let block = bdi_block(&mut rng, k, trial % 4);
            let want = (scalar().bdi_fits)(&block, k, d);
            for ker in backends() {
                assert_eq!(
                    (ker.bdi_fits)(&block, k, d),
                    want,
                    "{} trial {} k {} d {}",
                    ker.isa.name(),
                    trial,
                    k,
                    d
                );
            }
        }
    }
}

#[test]
fn bdi_fits_boundary_deltas_classify_identically() {
    // hand-built blocks sitting exactly on the d-byte sign boundary:
    // base, then base + (2^(8d-1) - 1) [fits] vs base + 2^(8d-1) [misses]
    for &(k, d) in &BDI_MENU {
        let bias = 1u64 << (8 * d - 1);
        let base = 0x1111_2222_3333_4444u64 & ((1u64 << (8 * k as u32 - 1)) - 1);
        for (delta, _should_fit_base) in [(bias - 1, true), (bias, false)] {
            let n = 64 / k;
            let mut block = Vec::with_capacity(64);
            for i in 0..n {
                let v = if i == 0 { base } else { base.wrapping_add(delta) };
                for b in 0..k {
                    block.push((v >> (8 * b)) as u8);
                }
            }
            let want = (scalar().bdi_fits)(&block, k, d);
            for ker in backends() {
                assert_eq!(
                    (ker.bdi_fits)(&block, k, d),
                    want,
                    "{} k {} d {} delta {}",
                    ker.isa.name(),
                    k,
                    d,
                    delta
                );
            }
        }
    }
}

#[test]
fn bdi_wire_bytes_identical_under_every_forced_isa() {
    // whole-image BDI compression must emit bit-identical streams no
    // matter which backend served the feasibility scans. force() is
    // process-global, but ISA choice never changes emitted bytes — which
    // is exactly the invariant under test.
    let mut rng = Rng::new(53);
    let mut image = Vec::new();
    for k in [2usize, 4, 8] {
        for flavor in 0u32..4 {
            image.extend(bdi_block(&mut rng, k, flavor));
        }
    }
    image.extend_from_slice(&[0u8; 128]); // zeros + rep tails
    image.extend_from_slice(&[0xABu8; 64]);
    let bdi = Bdi::default();
    simd::force(Some(Isa::Scalar)).unwrap();
    let reference = bdi.compress(&image);
    for &isa in Isa::all() {
        if !isa.supported() {
            continue;
        }
        simd::force(Some(isa)).unwrap();
        assert_eq!(bdi.compress(&image), reference, "{}", isa.name());
    }
    simd::force(None).unwrap();
    assert_eq!(bdi.decompress(&reference, image.len()).unwrap(), image);
}

// ---------------------------------------------------------------- gbdi apply

#[test]
fn gbdi_apply_matches_scalar_including_wrapping() {
    let mut rng = Rng::new(59);
    for trial in 0..200 {
        let table = 1 + rng.below(64) as usize;
        let adj: Vec<u32> = (0..table).map(|_| rng.next_u32()).collect();
        let n = rng.below(33) as usize; // 0..=32 words: full chunks + tails
        let ptrs: Vec<u32> = (0..n).map(|_| rng.below(table as u64) as u32).collect();
        let raws: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut want = vec![0u8; 4 * n];
        (scalar().gbdi_apply_w32)(&adj, &ptrs, &raws, &mut want);
        for k in backends() {
            let mut got = vec![0xEEu8; 4 * n];
            (k.gbdi_apply_w32)(&adj, &ptrs, &raws, &mut got);
            assert_eq!(got, want, "{} trial {}", k.isa.name(), trial);
        }
    }
}

// ------------------------------------------------------- end-to-end decode

fn codec() -> GbdiCodec {
    let cfg = GbdiConfig::default();
    let table = GlobalBaseTable::new(
        vec![(1000, 8), (1 << 20, 16), (3_000_000_000, 8)],
        cfg.word_size,
        1,
    );
    GbdiCodec::new(table, cfg)
}

fn mixed_image(len_words: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..len_words)
        .flat_map(|_| {
            let v: u32 = match rng.below(5) {
                0 => 1000u32.wrapping_add(rng.range_i64(-127, 127) as u32),
                1 => (1u32 << 20).wrapping_add(rng.range_i64(-30_000, 30_000) as u32),
                2 => 3_000_000_000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                3 => 0,
                _ => rng.next_u32(),
            };
            v.to_le_bytes()
        })
        .collect()
}

#[test]
fn simd_decode_matches_scalar_per_block() {
    // every block of a mixed image, decoded under every backend: same
    // bytes, same bits consumed (framing is wire-visible)
    let image = mixed_image(2048, 61);
    let c = codec();
    let comp = c.compress_image(&image);
    let lut = DecodeLut::new(c.table(), c.config());
    let mut want = vec![0u8; c.config().block_bytes];
    let mut got = vec![0u8; c.config().block_bytes];
    let mut off = 0u64;
    for (i, &bits) in comp.block_bits.iter().enumerate() {
        let byte = (off / 8) as usize;
        let sub = (off % 8) as u32;
        let mut rs = BitReader::new(&comp.payload[byte..]);
        if sub != 0 {
            rs.get(sub).unwrap();
        }
        decompress_block_lut_with(&mut rs, &lut, &mut want, scalar()).unwrap();
        for k in backends() {
            let mut r = BitReader::new(&comp.payload[byte..]);
            if sub != 0 {
                r.get(sub).unwrap();
            }
            decompress_block_lut_with(&mut r, &lut, &mut got, k).unwrap();
            assert_eq!(got, want, "{} block {}", k.isa.name(), i);
            assert_eq!(r.bit_pos(), rs.bit_pos(), "{} block {} framing", k.isa.name(), i);
        }
        off += bits as u64;
    }
}

#[test]
fn simd_decode_corruption_classification_matches_reference() {
    // bit flips + truncation: each backend must classify Ok/Err exactly
    // like the scalar reference decoder, and agree on bytes when Ok
    let image = mixed_image(512, 67);
    let c = codec();
    let comp = c.compress_image(&image);
    let lut = DecodeLut::new(c.table(), c.config());
    let mut rng = Rng::new(71);
    let mut a = vec![0u8; c.config().block_bytes];
    let mut b = vec![0u8; c.config().block_bytes];
    for trial in 0..200 {
        let mut bad = comp.payload.clone();
        let i = rng.below(bad.len() as u64) as usize;
        bad[i] ^= 1 << rng.below(8);
        if rng.chance(0.3) {
            bad.truncate(rng.below(bad.len() as u64 + 1) as usize);
        }
        let mut rb = BitReader::new(&bad);
        let reference = decompress_block(&mut rb, c.table(), c.config(), &mut b);
        for k in backends() {
            let mut ra = BitReader::new(&bad);
            let res = decompress_block_lut_with(&mut ra, &lut, &mut a, k);
            assert_eq!(
                res.is_ok(),
                reference.is_ok(),
                "{} trial {} classification",
                k.isa.name(),
                trial
            );
            if reference.is_ok() {
                assert_eq!(a, b, "{} trial {}", k.isa.name(), trial);
                assert_eq!(ra.bit_pos(), rb.bit_pos(), "{} trial {}", k.isa.name(), trial);
            }
        }
    }
}

#[test]
fn w64_tables_fall_back_and_still_agree() {
    // W64 has no fused SIMD tables; vector backends must take the
    // reference loop and still decode identically
    let cfg = GbdiConfig {
        word_size: WordSize::W64,
        width_classes: vec![0, 4, 8, 16, 24, 32],
        ..Default::default()
    };
    let table = GlobalBaseTable::new(vec![(0x7F3A_0000_0000, 24), (5_000, 8)], cfg.word_size, 1);
    let c = GbdiCodec::new(table, cfg.clone());
    let mut rng = Rng::new(73);
    let image: Vec<u8> = (0..512)
        .flat_map(|_| {
            let v: u64 = match rng.below(3) {
                0 => 0x7F3A_0000_0000u64.wrapping_add(rng.range_i64(-400_000, 400_000) as u64),
                1 => 5_000u64.wrapping_add(rng.range_i64(-100, 100) as u64),
                _ => rng.next_u64(),
            };
            v.to_le_bytes()
        })
        .collect();
    let comp = c.compress_image(&image);
    let lut = DecodeLut::new(c.table(), c.config());
    let mut want = vec![0u8; cfg.block_bytes];
    let mut got = vec![0u8; cfg.block_bytes];
    let mut off = 0u64;
    for &bits in &comp.block_bits {
        let byte = (off / 8) as usize;
        let sub = (off % 8) as u32;
        let mut rs = BitReader::new(&comp.payload[byte..]);
        if sub != 0 {
            rs.get(sub).unwrap();
        }
        decompress_block_lut_with(&mut rs, &lut, &mut want, scalar()).unwrap();
        for k in backends() {
            let mut r = BitReader::new(&comp.payload[byte..]);
            if sub != 0 {
                r.get(sub).unwrap();
            }
            decompress_block_lut_with(&mut r, &lut, &mut got, k).unwrap();
            assert_eq!(got, want, "{}", k.isa.name());
        }
        off += bits as u64;
    }
}

#[test]
fn gbdi_wire_bytes_identical_under_every_forced_isa() {
    // the whole GBDI pipeline — ZERO/REP scans, hinted base search,
    // emission — must produce bit-identical containers under every
    // backend (the encoder's first-fit index is wire-visible)
    let image = mixed_image(4096, 79);
    let c = codec();
    simd::force(Some(Isa::Scalar)).unwrap();
    let reference = c.compress_image(&image);
    for &isa in Isa::all() {
        if !isa.supported() {
            continue;
        }
        simd::force(Some(isa)).unwrap();
        let comp = c.compress_image(&image);
        assert_eq!(comp.payload, reference.payload, "{} payload", isa.name());
        assert_eq!(comp.block_bits, reference.block_bits, "{} framing", isa.name());
        // and the image survives the roundtrip under this backend
        assert_eq!(
            gbdi::gbdi::decode::decompress_image(&comp).unwrap(),
            image,
            "{} roundtrip",
            isa.name()
        );
    }
    simd::force(None).unwrap();
}
