//! Integration: the PJRT runtime executing the AOT JAX/Pallas artifacts,
//! cross-checked against the native Rust clustering. Skips (with a loud
//! message) when `artifacts/` has not been built — run `make artifacts`.

use gbdi::cluster::{apply_delta, ArtifactSelector};
use gbdi::coordinator::Analyzer;
use gbdi::gbdi::GbdiConfig;
use gbdi::runtime::{shape_samples, ArtifactRuntime, N_SAMPLES};
use gbdi::util::prng::Rng;
use gbdi::value::WordSize;
use std::sync::Arc;

fn runtime() -> Option<Arc<ArtifactRuntime>> {
    // tests run from the crate root, so ./artifacts is right; also honour
    // GBDI_ARTIFACTS
    let rt = ArtifactRuntime::new(ArtifactRuntime::default_dir()).ok()?;
    if !rt.has_artifact("kmeans_k64") {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(rt))
}

fn mixture(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let c = [60_000u64, 12_000_000, 2_800_000_000][rng.below(3) as usize];
            apply_delta(c, rng.range_i64(-200, 200), WordSize::W32)
        })
        .collect()
}

#[test]
fn artifact_kmeans_recovers_centers() {
    let Some(rt) = runtime() else { return };
    let samples = mixture(1, N_SAMPLES);
    let x = shape_samples(&samples);
    let mut rng = Rng::new(2);
    let init: Vec<f32> =
        (0..64).map(|_| samples[rng.below(samples.len() as u64) as usize] as f32).collect();
    let fit = rt.kmeans(&x, &init).expect("artifact kmeans");
    assert_eq!(fit.centroids.len(), 64);
    assert_eq!(fit.counts.len(), 64);
    let total: f32 = fit.counts.iter().sum();
    assert_eq!(total as usize, N_SAMPLES, "counts conserve samples");
    // the sample mass must concentrate around the true centers (with
    // K=64, each cluster's mass spreads over ~20 nearby centroids)
    for target in [60_000.0f32, 12_000_000.0, 2_800_000_000.0] {
        let mass: f32 = fit
            .centroids
            .iter()
            .zip(&fit.counts)
            .filter(|&(&c, _)| (c - target).abs() / target.max(1.0) < 0.01)
            .map(|(_, &n)| n)
            .sum();
        assert!(mass > 500.0, "only {mass} samples near {target}: {:?}", fit.centroids);
    }
    assert!(fit.inertia >= 0.0);
}

#[test]
fn artifact_analyzer_builds_compressive_table() {
    let Some(rt) = runtime() else { return };
    let cfg = GbdiConfig::default();
    let mut artifact = Analyzer::new(Box::new(ArtifactSelector::new(rt)), cfg.clone());
    let mut native = Analyzer::native(cfg);
    let samples = mixture(3, N_SAMPLES);
    let t_a = artifact.analyze(&samples, 1).expect("artifact analyze");
    let t_n = native.analyze(&samples, 1).expect("native analyze");
    let bits_a = artifact.estimate_bits(&samples, &t_a);
    let bits_n = native.estimate_bits(&samples, &t_n);
    let raw = samples.len() as u64 * 32;
    // f32 ulp at 2.8e9 is 256, so snapped bases sit a few hundred off the
    // integer centroids and deltas need a wider class than the native
    // (exact-integer) path — still far below raw
    assert!(bits_a < raw * 2 / 3, "artifact table compresses: {bits_a} vs raw {raw}");
    // the two backends should land in the same quality ballpark
    let ratio = bits_a as f64 / bits_n as f64;
    assert!((0.6..1.6).contains(&ratio), "artifact {bits_a} vs native {bits_n}");
}

#[test]
fn artifact_size_estimate_tracks_table_quality() {
    let Some(rt) = runtime() else { return };
    let samples = mixture(5, N_SAMPLES);
    let x = shape_samples(&samples);
    let good_bases: Vec<f32> = {
        let mut b = vec![0.0f32; 64];
        b[0] = 60_000.0;
        b[1] = 12_000_000.0;
        b[2] = 2_800_000_000.0;
        b
    };
    let good_widths = vec![12.0f32; 64];
    let bad_bases: Vec<f32> = (0..64).map(|i| i as f32 * 1000.0).collect();
    let bad_widths = vec![4.0f32; 64];
    let good = rt.size_estimate(&x, &good_bases, &good_widths).expect("sizeest");
    let bad = rt.size_estimate(&x, &bad_bases, &bad_widths).expect("sizeest");
    assert!(good < bad, "good table {good} should score below bad {bad}");
}

#[test]
fn artifact_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    assert!(rt.kmeans(&[0.0; 10], &[0.0; 64]).is_err());
    assert!(rt.kmeans(&vec![0.0; N_SAMPLES], &[0.0; 13]).is_err());
    assert!(rt.size_estimate(&vec![0.0; N_SAMPLES], &[0.0; 10], &[0.0; 10]).is_err());
}

#[test]
fn artifact_execution_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let samples = mixture(7, N_SAMPLES);
    let x = shape_samples(&samples);
    let init: Vec<f32> = (0..16).map(|i| (i * 1000) as f32).collect();
    let a = rt.kmeans(&x, &init).unwrap();
    let b = rt.kmeans(&x, &init).unwrap();
    assert_eq!(a.centroids, b.centroids);
    assert_eq!(a.counts, b.counts);
}
