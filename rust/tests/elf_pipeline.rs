//! Integration: the paper's full dump pipeline — generate ELF cores,
//! parse, compress, decompress, verify — including multi-segment dumps
//! and the CLI's container format.

use gbdi::baselines::{Codec, GbdiWholeImage};
use gbdi::elf;
use gbdi::workloads;

#[test]
fn elf_dump_pipeline_end_to_end() {
    for name in ["mcf", "svm"] {
        let w = workloads::by_name(name).unwrap();
        let image = w.generate(1 << 18, 21);
        let file = elf::write_core(&[elf::Segment { vaddr: 0x10000, flags: 6, data: image.clone() }]);
        let dump = elf::parse(&file).unwrap();
        assert_eq!(dump.flatten(), image);
        let codec = GbdiWholeImage::default();
        let comp = codec.compress(&dump.flatten());
        assert_eq!(codec.decompress(&comp, image.len()).unwrap(), image);
    }
}

#[test]
fn multi_segment_dump_flattens_and_compresses() {
    let text = workloads::by_name("perlbench").unwrap().generate(1 << 16, 1);
    let heap = workloads::by_name("triangle_count").unwrap().generate(1 << 17, 2);
    let stack = vec![0u8; 1 << 14];
    let file = elf::write_core(&[
        elf::Segment { vaddr: 0x400000, flags: 5, data: text.clone() },
        elf::Segment { vaddr: 0x7F00_0000_0000, flags: 6, data: heap.clone() },
        elf::Segment { vaddr: 0x7FFF_FF00_0000, flags: 6, data: stack.clone() },
    ]);
    let dump = elf::parse(&file).unwrap();
    assert_eq!(dump.segments.len(), 3);
    let image = dump.flatten();
    assert_eq!(image.len(), text.len() + heap.len() + stack.len());
    let codec = GbdiWholeImage::default();
    let comp = codec.compress(&image);
    assert_eq!(codec.decompress(&comp, image.len()).unwrap(), image);
}

#[test]
fn container_records_length() {
    let image = workloads::by_name("fluidanimate").unwrap().generate(100_000, 3);
    let codec = GbdiWholeImage::default();
    let comp = codec.compress(&image);
    assert_eq!(GbdiWholeImage::container_len(&comp).unwrap(), 100_000);
}

#[test]
fn container_roundtrips_through_files() {
    let dir = std::env::temp_dir().join("gbdi_elf_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    let image = workloads::by_name("deepsjeng").unwrap().generate(1 << 16, 4);
    let codec = GbdiWholeImage::default();
    let comp_path = dir.join("x.gbdi");
    std::fs::write(&comp_path, codec.compress(&image)).unwrap();
    let comp = std::fs::read(&comp_path).unwrap();
    let len = GbdiWholeImage::container_len(&comp).unwrap();
    assert_eq!(codec.decompress(&comp, len).unwrap(), image);
    std::fs::remove_dir_all(&dir).ok();
}
