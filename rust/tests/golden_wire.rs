//! Golden wire-format fixtures: checked-in compressed images that pin
//! the on-disk format bit-for-bit.
//!
//! Every case asserts two things against its fixture file under
//! `tests/golden/`:
//!
//! 1. **exact decode** — parsing the checked-in bytes and decompressing
//!    them reproduces the (deterministically reconstructed) source image
//!    byte-identically, whole-image and per-block through a [`Frame`];
//! 2. **byte-identical recompression** — compressing the source image
//!    with an identically-constructed codec reproduces the checked-in
//!    file exactly, down to the last bit of the last varint.
//!
//! Together these freeze the stream layout (LSB-first fields, block tags,
//! fused ptr+delta fields, container framing, table serialization): any
//! kernel rewrite that moves a single bit fails here before it can ship.
//! The cases cover GBDI (mixed ZERO/REP/RAW/GBDI blocks with outliers),
//! a ragged-tail image, an all-raw (incompressible) image, and the BDI
//! and FPC baselines.
//!
//! Regenerate after an *intentional* format change with:
//! `GOLDEN_BLESS=1 cargo test --test golden_wire` (then commit the new
//! fixtures and explain the break in the PR).

use gbdi::baselines::bdi::Bdi;
use gbdi::baselines::fpc::FpcBlock;
use gbdi::container::{self, Container};
use gbdi::gbdi::{GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::BlockCodec;
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden").join(name)
}

fn words_le(words: &[u32]) -> Vec<u8> {
    words.iter().flat_map(|w| w.to_le_bytes()).collect()
}

/// GBDI codec shared by the mixed and ragged cases: explicit table
/// (analysis-free, so the fixture does not depend on any selector), the
/// default config.
fn gbdi_fixture_codec() -> GbdiCodec {
    let cfg = GbdiConfig::default();
    let table = GlobalBaseTable::new(vec![(1000, 8), (1 << 20, 16)], cfg.word_size, 7);
    GbdiCodec::new(table, cfg)
}

/// Eight 64-byte blocks exercising every GBDI block mode: near-base
/// deltas, ZERO, REP, all-outlier RAW, wide deltas, mixed outliers,
/// exact base hits, and descending runs. Every word fits at most one
/// table entry, so the encoding is independent of search order.
fn gbdi_mixed_image() -> Vec<u8> {
    let mut words: Vec<u32> = Vec::new();
    words.extend((0..16u32).map(|i| 900 + 7 * i)); // deltas around base 1000
    words.extend([0u32; 16]); // ZERO block
    words.extend([0xDEAD_BEEFu32; 16]); // REP block
    // all outliers -> RAW beats GBDI
    words.extend((0..16u32).map(|i| 0x1000_0000u32.wrapping_add(i.wrapping_mul(0x0123_4567))));
    // wide deltas around base 1<<20
    words.extend((0..16u32).map(|i| (1u32 << 20) - 15000 + 1234 * i));
    // mixed: 12 near-base words + 4 outliers, GBDI still wins
    words.extend((0..12u32).map(|i| 1000 + i));
    words.extend((12..16u32).map(|i| 0xA000_0000 + i));
    // exact base hits
    words.extend((0..16usize).map(|i| [0u32, 1000, 1 << 20][i % 3]));
    words.extend((0..16u32).map(|i| 1000 - i)); // descending run
    words_le(&words)
}

/// Two full blocks plus a 21-byte ragged tail (stored raw).
fn gbdi_ragged_image() -> Vec<u8> {
    let mut image = Vec::new();
    image.extend(words_le(&(0..16u32).map(|i| 900 + 7 * i).collect::<Vec<_>>()));
    image.extend(words_le(&[0u32; 16]));
    image.extend((0..21u32).map(|j| (3 * j + 1) as u8));
    image
}

/// GBDI with only the pinned zero base; every word is an outlier, every
/// block falls back to RAW.
fn gbdi_allraw_codec() -> GbdiCodec {
    let cfg = GbdiConfig::default();
    let table = GlobalBaseTable::new(vec![(0, 8)], cfg.word_size, 3);
    GbdiCodec::new(table, cfg)
}

fn gbdi_allraw_image() -> Vec<u8> {
    (0..256u32).map(|j| ((37 * j + 11) % 256) as u8).collect()
}

/// Six BDI blocks: Zeros, Rep8, B8D1, B4D2, raw, B8D2.
fn bdi_image() -> Vec<u8> {
    let mut image = vec![0u8; 64]; // Zeros
    for _ in 0..8 {
        image.extend_from_slice(&0x0123_4567_89AB_CDEFu64.to_le_bytes()); // Rep8
    }
    for i in 0..8u64 {
        image.extend_from_slice(&(0x7F3A_0000_1000u64 + 3 * i).to_le_bytes()); // B8D1
    }
    for j in 0..16u32 {
        image.extend_from_slice(&(0x0010_0000u32 + 200 * j).to_le_bytes()); // B4D2
    }
    image.extend((0..64u32).map(|j| ((91 * j + 7) % 256) as u8)); // raw
    for i in 0..8u64 {
        image.extend_from_slice(&(0x7FFF_0000_0000u64 + 1000 * i).to_le_bytes()); // B8D2
    }
    image
}

/// Two FPC blocks hitting every word pattern, plus a 7-byte ragged tail.
fn fpc_image() -> Vec<u8> {
    let words: [u32; 32] = [
        0,
        3,
        0xFFFF_FFFF,
        100,
        0xFFFF_FF80,
        30000,
        0xFFFF_8000,
        0x1234_0000,
        0x0042_0017,
        0xABAB_ABAB,
        0xDEAD_BEEF,
        8,
        127,
        128,
        0x7FFF_0000,
        0xFFFF_FFF8,
        0x0001_0001,
        0,
        0x0000_0005,
        0x0000_FF00,
        0x0032_0000,
        0x1111_1111,
        0x8000_0000,
        0x0000_ABCD,
        0xFFFF_0001,
        42,
        0xFFFF_FF01,
        0x0000_8000,
        0x7F7F_7F7F,
        1,
        0xC0C0_C0C0,
        0x00FF_00FF,
    ];
    let mut image = words_le(&words);
    image.extend_from_slice(&[9, 8, 7, 6, 5, 4, 3]);
    image
}

/// The shared assertion: fixture decodes to `image` exactly, and
/// recompressing `image` reproduces the fixture byte-for-byte.
fn check_golden(name: &str, codec: &dyn BlockCodec, image: &[u8]) {
    let path = fixture_path(name);
    let recompressed = container::compress(codec, image).to_bytes();
    if std::env::var("GOLDEN_BLESS").is_ok_and(|v| v == "1") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &recompressed).unwrap();
        eprintln!("blessed {name}: {} bytes", recompressed.len());
        return;
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("missing fixture {path:?} ({e}); regenerate with GOLDEN_BLESS=1")
    });

    // 1. exact decode of the checked-in bytes
    let parsed = Container::from_bytes(&golden).unwrap_or_else(|e| {
        panic!("{name}: fixture no longer parses: {e:?}")
    });
    assert_eq!(parsed.decompress().unwrap(), image, "{name}: whole-image decode diverged");
    // ...including per-block through the random-access path
    let frame = Container::from_bytes(&golden).unwrap().into_frame().unwrap();
    let mut buf = vec![0u8; frame.block_bytes()];
    for i in 0..frame.n_blocks() {
        let n = frame.read_block(i, &mut buf).unwrap();
        let bb = frame.block_bytes();
        assert_eq!(&buf[..n], &image[i * bb..i * bb + n], "{name}: block {i} decode diverged");
    }

    // 2. byte-identical recompression
    if recompressed != golden {
        let first_diff = recompressed
            .iter()
            .zip(golden.iter())
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| recompressed.len().min(golden.len()));
        panic!(
            "{name}: wire format moved: {} bytes now vs {} in fixture, first diff at byte {} \
             (got {:#04x?}, fixture {:#04x?})",
            recompressed.len(),
            golden.len(),
            first_diff,
            recompressed.get(first_diff),
            golden.get(first_diff),
        );
    }
}

#[test]
fn golden_gbdi_mixed() {
    check_golden("gbdi_mixed.gbc", &gbdi_fixture_codec(), &gbdi_mixed_image());
}

#[test]
fn golden_gbdi_ragged_tail() {
    check_golden("gbdi_ragged.gbc", &gbdi_fixture_codec(), &gbdi_ragged_image());
}

#[test]
fn golden_gbdi_all_raw() {
    let codec = gbdi_allraw_codec();
    let image = gbdi_allraw_image();
    check_golden("gbdi_allraw.gbc", &codec, &image);
    // the case's premise: every block really did fall back to RAW
    let comp = codec.compress_image(&image);
    for (i, &bits) in comp.block_bits.iter().enumerate() {
        assert_eq!(bits, 2 + 64 * 8, "block {i} was not stored raw");
    }
}

#[test]
fn golden_bdi() {
    check_golden("bdi.gbc", &Bdi { block_bytes: 64 }, &bdi_image());
}

#[test]
fn golden_fpc() {
    check_golden("fpc.gbc", &FpcBlock { block_bytes: 64 }, &fpc_image());
}
