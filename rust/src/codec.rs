//! The unified block-codec layer: one trait every cache-line-granular
//! compressor in this repo implements, so the memory simulator, the
//! coordinator service, the CLI, and the benches sweep GBDI and the
//! baselines through a single seam.
//!
//! A [`BlockCodec`] compresses and decompresses fixed-size blocks over the
//! shared bit-packed stream ([`crate::util::bits`]). Whole-image framing —
//! per-block bit lengths, chunked parallel compression, serialization —
//! lives one layer up in [`crate::container`] and is codec-agnostic.
//!
//! Registered codecs:
//!
//! | id | name | notes |
//! |----|------|-------|
//! | 1  | gbdi | global-base delta-immediate; carries a [`GlobalBaseTable`] |
//! | 2  | bdi  | per-block base-delta-immediate (PACT'12) |
//! | 3  | fpc  | frequent-pattern compression (word significance) |

use crate::gbdi::table::GlobalBaseTable;
use crate::gbdi::GbdiConfig;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// Stable on-wire codec identifier (one byte in the container header).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// Global-Base Delta-Immediate.
    Gbdi = 1,
    /// Base-Delta-Immediate.
    Bdi = 2,
    /// Frequent Pattern Compression.
    Fpc = 3,
}

impl CodecId {
    /// Decode the container-header byte.
    pub fn from_u8(b: u8) -> Option<CodecId> {
        match b {
            1 => Some(CodecId::Gbdi),
            2 => Some(CodecId::Bdi),
            3 => Some(CodecId::Fpc),
            _ => None,
        }
    }

    /// Short name used in reports and `--codec` values.
    pub fn name(self) -> &'static str {
        match self {
            CodecId::Gbdi => "gbdi",
            CodecId::Bdi => "bdi",
            CodecId::Fpc => "fpc",
        }
    }
}

/// Caller-owned reusable buffers for the allocation-free hot paths:
/// [`BlockCodec::compress_block_with`],
/// [`BlockCodec::estimate_block_bits_with`], and the random-access
/// [`crate::frame::Frame`] write/range operations all borrow one of
/// these instead of allocating per call.
///
/// A `Scratch` is plain state — create one per thread (they are cheap
/// and start empty; buffers grow to their steady-state size on first
/// use and are then reused). It is deliberately *not* `Sync`-shared:
/// ownership stays with the caller, which is what lets the per-request
/// paths in the coordinator and the memory simulator run without a
/// single heap allocation. Each shard of the coordinator's page store
/// owns one, so block writes on different shards never share buffers.
///
/// ```
/// use gbdi::{BlockCodec, CodecKind, GbdiConfig, Scratch};
///
/// let cfg = GbdiConfig::default();
/// let codec = CodecKind::Bdi.build_for_image(&[], &cfg);
/// let mut scratch = Scratch::new();
/// // hold the scratch across a loop: after the first call these paths
/// // are allocation-free (pinned by tests/alloc_counting.rs)
/// let block = [7u8; 64];
/// let bits = codec.estimate_block_bits_with(&block, &mut scratch);
/// assert!(bits > 0);
/// assert_eq!(codec.estimate_block_bits(&block), bits);
/// ```
#[derive(Debug, Default)]
pub struct Scratch {
    /// Reusable bit writer (estimate + in-place write paths).
    pub(crate) w: BitWriter,
    /// One decoded block (partial-block edges of range reads).
    pub(crate) block: Vec<u8>,
    /// GBDI per-word emission plan, u64-packed: each entry is one fused
    /// `(field value, field bits)` writer `put` (base pointer and
    /// offset-binary delta pre-merged; wide W64 fields split in two).
    pub(crate) gbdi_plan: Vec<(u64, u32)>,
    /// BDI per-word (zero-base?, delta) plan.
    pub(crate) bdi_plan: Vec<(bool, u64)>,
}

impl Scratch {
    /// Fresh, empty scratch space.
    pub fn new() -> Self {
        Scratch::default()
    }
}

/// A block-granular lossless codec: the one interface the simulator, the
/// coordinator, the container layer, and the CLI sweep all consume.
///
/// Contract:
///
/// * [`compress_block`](Self::compress_block) writes one block to the bit
///   stream and returns exactly the bits it appended; feeding those bits
///   back through [`decompress_block`](Self::decompress_block) must
///   reconstruct the block byte-identically and consume exactly the same
///   bit count (the container layer verifies this per block).
/// * Blocks shorter than [`block_bytes`](Self::block_bytes) (the image's
///   ragged tail) must roundtrip too.
/// * Implementations are immutable and thread-safe: the container layer
///   compresses chunks of blocks on multiple threads against one `&self`.
pub trait BlockCodec: Send + Sync {
    /// Short identifier used in reports (e.g. `"bdi"`).
    fn name(&self) -> &'static str;

    /// Wire id recorded in container headers.
    fn codec_id(&self) -> CodecId;

    /// Block granularity in bytes (a cache line in the papers).
    fn block_bytes(&self) -> usize;

    /// Compress one block into `w`; returns the bits written.
    fn compress_block(&self, block: &[u8], w: &mut BitWriter) -> u32;

    /// [`Self::compress_block`] with caller-owned [`Scratch`] buffers —
    /// the hot-path variant: codecs that need per-block temporaries (the
    /// GBDI word plan, BDI's per-word mask plan) take them from `scratch`
    /// instead of allocating. The default ignores the scratch and
    /// delegates; stateless codecs need nothing more.
    fn compress_block_with(&self, block: &[u8], w: &mut BitWriter, scratch: &mut Scratch) -> u32 {
        let _ = scratch;
        self.compress_block(block, w)
    }

    /// Decode one block from `r` into `out` (exactly `out.len()` bytes;
    /// pass a short slice for ragged tail blocks). Implementations must
    /// not allocate: this is the per-request path of
    /// [`crate::frame::Frame::read_block`].
    fn decompress_block(&self, r: &mut BitReader<'_>, out: &mut [u8]) -> Result<()>;

    /// Compressed bit size of `block` without emitting anything.
    /// Convenience wrapper that builds a throwaway [`Scratch`] per call —
    /// fine for one-offs, wrong for loops: analysis loops must hold a
    /// `Scratch` and call [`Self::estimate_block_bits_with`], which is
    /// allocation-free at steady state.
    fn estimate_block_bits(&self, block: &[u8]) -> u64 {
        self.estimate_block_bits_with(block, &mut Scratch::new())
    }

    /// Exact compressed bit size of `block` using caller-owned scratch
    /// buffers. The default encodes into the scratch writer (reused
    /// across calls, so zero allocations once warm); codecs with a cheap
    /// closed form override it.
    fn estimate_block_bits_with(&self, block: &[u8], scratch: &mut Scratch) -> u64 {
        let mut w = std::mem::take(&mut scratch.w);
        w.clear();
        let bits = self.compress_block_with(block, &mut w, scratch) as u64;
        scratch.w = w;
        bits
    }

    /// Codec-specific configuration blob embedded in containers, parsed
    /// back by [`build_codec`]. Must be enough to reconstruct a decoder
    /// (together with [`global_table`](Self::global_table)).
    fn config_bytes(&self) -> Vec<u8>;

    /// The shared dictionary this codec decodes against, if any (GBDI's
    /// global base table). Charged to the compressed size by the
    /// container and the simulator's capacity accounting.
    fn global_table(&self) -> Option<&GlobalBaseTable> {
        None
    }

    /// Version of the codec's shared state (GBDI table version). The
    /// coordinator keys its codec ring on this; stateless codecs are 0.
    fn version(&self) -> u64 {
        0
    }
}

/// Reconstruct a decoder from container metadata: codec id, config blob,
/// and the optional global table.
pub fn build_codec(
    id: CodecId,
    config: &[u8],
    table: Option<GlobalBaseTable>,
) -> Result<Box<dyn BlockCodec>> {
    match id {
        CodecId::Gbdi => {
            let cfg = GbdiConfig::from_bytes(config)?;
            let table = table
                .ok_or_else(|| Error::Corrupt("gbdi container without a global table".into()))?;
            Ok(Box::new(crate::gbdi::GbdiCodec::try_new(table, cfg)?))
        }
        CodecId::Bdi => {
            let bb = read_block_bytes(config)?;
            Ok(Box::new(crate::baselines::bdi::Bdi { block_bytes: bb }))
        }
        CodecId::Fpc => {
            let bb = read_block_bytes(config)?;
            Ok(Box::new(crate::baselines::fpc::FpcBlock { block_bytes: bb }))
        }
    }
}

/// Shared config-blob shape for the stateless codecs: `u32 block_bytes`.
pub(crate) fn block_bytes_config(block_bytes: usize) -> Vec<u8> {
    (block_bytes as u32).to_le_bytes().to_vec()
}

fn read_block_bytes(config: &[u8]) -> Result<usize> {
    if config.len() < 4 {
        return Err(Error::Corrupt("truncated codec config".into()));
    }
    let bb = u32::from_le_bytes(config[0..4].try_into().unwrap()) as usize;
    if bb == 0 {
        return Err(Error::Corrupt("codec config with zero block size".into()));
    }
    Ok(bb)
}

/// A registered codec family the CLI and sweeps can instantiate by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// GBDI (runs background analysis over the target image).
    Gbdi,
    /// BDI baseline.
    Bdi,
    /// FPC baseline.
    Fpc,
}

impl CodecKind {
    /// All registered kinds, in report order.
    pub fn all() -> &'static [CodecKind] {
        &[CodecKind::Gbdi, CodecKind::Bdi, CodecKind::Fpc]
    }

    /// Parse a `--codec` value (case-insensitive, by registered name).
    pub fn parse(s: &str) -> Option<CodecKind> {
        let s = s.to_ascii_lowercase();
        CodecKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// The wire id this kind builds codecs for — the single source of
    /// truth tying the CLI registry to the container format.
    pub fn id(self) -> CodecId {
        match self {
            CodecKind::Gbdi => CodecId::Gbdi,
            CodecKind::Bdi => CodecId::Bdi,
            CodecKind::Fpc => CodecId::Fpc,
        }
    }

    /// The kind's name (matches [`BlockCodec::name`]).
    pub fn name(self) -> &'static str {
        self.id().name()
    }

    /// Build a codec for `image`. GBDI runs background analysis on the
    /// image itself; the stateless baselines only take the block size
    /// from `cfg`.
    pub fn build_for_image(self, image: &[u8], cfg: &GbdiConfig) -> Box<dyn BlockCodec> {
        match self {
            CodecKind::Gbdi => {
                let table = crate::gbdi::analyze::analyze_image(image, cfg);
                Box::new(crate::gbdi::GbdiCodec::new(table, cfg.clone()))
            }
            CodecKind::Bdi => Box::new(crate::baselines::bdi::Bdi { block_bytes: cfg.block_bytes }),
            CodecKind::Fpc => {
                Box::new(crate::baselines::fpc::FpcBlock { block_bytes: cfg.block_bytes })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_ids_roundtrip() {
        for id in [CodecId::Gbdi, CodecId::Bdi, CodecId::Fpc] {
            assert_eq!(CodecId::from_u8(id as u8), Some(id));
        }
        assert_eq!(CodecId::from_u8(0), None);
        assert_eq!(CodecId::from_u8(99), None);
    }

    #[test]
    fn kind_parse_matches_names() {
        for &k in CodecKind::all() {
            assert_eq!(CodecKind::parse(k.name()), Some(k));
        }
        assert_eq!(CodecKind::parse("GBDI"), Some(CodecKind::Gbdi));
        assert_eq!(CodecKind::parse("nope"), None);
    }

    #[test]
    fn build_for_image_honors_names_and_block_size() {
        let cfg = GbdiConfig { block_bytes: 128, ..Default::default() };
        let img = vec![0u8; 4096];
        for &k in CodecKind::all() {
            let c = k.build_for_image(&img, &cfg);
            assert_eq!(c.name(), k.name());
            assert_eq!(c.codec_id(), k.id(), "registry/wire id must agree");
            assert_eq!(c.block_bytes(), 128);
        }
    }

    #[test]
    fn scratch_paths_agree_with_plain_paths() {
        // compress_block_with / estimate_block_bits_with must be
        // bit-identical to the allocating entry points for every codec
        let mut rng = crate::util::prng::Rng::new(77);
        let mut img = vec![0u8; 64 * 64];
        for c in img.chunks_mut(16) {
            let v = 9_000u32.wrapping_add(rng.range_i64(-500, 500) as u32);
            c[..4].copy_from_slice(&v.to_le_bytes());
        }
        let cfg = GbdiConfig::default();
        let mut scratch = Scratch::new();
        for &k in CodecKind::all() {
            let codec = k.build_for_image(&img, &cfg);
            for block in img.chunks(64) {
                let mut a = BitWriter::new();
                let bits_a = codec.compress_block(block, &mut a);
                let mut b = BitWriter::new();
                let bits_b = codec.compress_block_with(block, &mut b, &mut scratch);
                assert_eq!(bits_a, bits_b, "{}", k.name());
                assert_eq!(a.finish(), b.finish(), "{} stream", k.name());
                assert_eq!(
                    codec.estimate_block_bits(block),
                    bits_a as u64,
                    "{} estimate",
                    k.name()
                );
                assert_eq!(
                    codec.estimate_block_bits_with(block, &mut scratch),
                    bits_a as u64,
                    "{} estimate_with",
                    k.name()
                );
            }
        }
    }

    #[test]
    fn build_codec_rejects_bad_config() {
        assert!(build_codec(CodecId::Bdi, &[], None).is_err());
        assert!(build_codec(CodecId::Fpc, &0u32.to_le_bytes(), None).is_err());
        // gbdi without a table is corrupt
        let cfg = GbdiConfig::default();
        assert!(build_codec(CodecId::Gbdi, &cfg.to_bytes(), None).is_err());
    }
}
