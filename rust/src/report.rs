//! Result presentation: aligned tables, ASCII bar charts (for the paper's
//! Figure 1), and CSV emission for the experiment logs.

use std::fmt::Write as _;

/// An aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for c in 0..ncol {
            width[c] = self.header[c].chars().count();
            for r in &self.rows {
                width[c] = width[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c == 0 {
                    let _ = write!(line, "{:<w$}", cell, w = width[c]);
                } else {
                    let _ = write!(line, "  {:>w$}", cell, w = width[c]);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Horizontal ASCII bar chart — the shape of the paper's Figure 1.
///
/// Bars are scaled to `max_width` characters; each row shows the label,
/// the bar, and the numeric value.
pub fn bar_chart(title: &str, items: &[(String, f64)], max_width: usize) -> String {
    let mut out = format!("{title}\n");
    if items.is_empty() {
        return out;
    }
    let vmax = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let lw = items.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    for (label, v) in items {
        let n = ((v / vmax) * max_width as f64).round().max(0.0) as usize;
        let _ = writeln!(out, "{label:<lw$}  {:<max_width$}  {v:.3}", "#".repeat(n));
    }
    out
}

/// Format a byte count human-readably.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a ratio as `1.45x`.
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.3}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment_and_csv() {
        let mut t = Table::new(&["workload", "ratio"]);
        t.row(&["mcf".into(), "1.40".into()]);
        t.row(&["matrixfactor".into(), "1.62".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("workload"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[2].len(), lines[3].len());
        let csv = t.csv();
        assert!(csv.starts_with("workload,ratio\n"));
        assert!(csv.contains("mcf,1.40"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only".into()]);
    }

    #[test]
    fn bar_chart_scales() {
        let items = vec![("a".to_string(), 1.0), ("bb".to_string(), 2.0)];
        let s = bar_chart("T", &items, 10);
        assert!(s.contains("##########"), "{s}"); // max bar full width
        assert!(s.contains("#####"), "{s}");
        assert!(s.starts_with("T\n"));
        assert!(bar_chart("E", &[], 10) == "E\n");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
        assert_eq!(fmt_ratio(1.4499), "1.450x");
    }
}
