//! Configuration files for the service and codec (a TOML-subset parser —
//! serde/toml are unavailable offline, and the deployment story needs a
//! real config system, not only CLI flags).
//!
//! Supported syntax: `[section]` headers, `key = value` pairs with
//! integers (incl. `0x` hex and `k/m/g` suffixes), floats, booleans,
//! quoted strings, and `[a, b, c]` integer arrays; `#` comments.
//!
//! ```text
//! # gbdi.toml
//! [codec]
//! block_bytes = 64
//! word_size = 32
//! num_bases = 64
//! width_classes = [0, 4, 8, 12, 16, 20, 24]
//! delta_quantile = 0.95
//!
//! [service]
//! workers = 4
//! shards = 8                 # independently locked page-store shards
//! ingest_batch = 32          # pages grouped per submit_batch call
//! analyze_every = 256
//! sample_words = 8192
//!
//! [analyzer]
//! selector = "minibatch"     # lloyd | minibatch | histogram
//! drift_margin = 1.02
//! swap_margin = 0.98
//!
//! [cache]
//! bytes = 4m                 # hot-block cache budget; 0 (default) = off
//!
//! [persist]
//! data_dir = "data"          # durability on; gbdi serve --data-dir overrides
//! fsync_batch = 1            # WAL group commit: fsync every N appends
//! wal_limit = 8m             # checkpoint once the WAL outgrows this
//!
//! [integrity]
//! enabled = true             # per-page CRC digests + scrubber; default off
//! verify_reads = true        # re-verify the digest on every frame decode
//! scrub_mib_s = 8            # background scrub budget, MiB/s of stored bytes
//!
//! [server]
//! listen = "127.0.0.1:7070"  # gbdi serve --listen overrides
//! max_conns = 64
//! write_queue_frames = 256   # per-connection response queue (backpressure)
//! write_queue_bytes = 4m
//! max_inflight_pages = 0     # admission cap; 0 = shards * ingest_batch * 4
//! retry_after_ms = 50
//! handshake_timeout_ms = 5000   # drop connections silent before their magic
//! write_timeout_ms = 10000      # drop peers that stop reading responses
//! ```

use crate::cli::parse_u64;
use crate::cluster::SelectorKind;
use crate::coordinator::{IntegrityConfig, ServiceConfig};
use crate::gbdi::GbdiConfig;
use crate::persist::PersistConfig;
use crate::server::ServerConfig;
use crate::value::WordSize;
use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Integer (accepts hex / size suffixes in the source).
    Int(u64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Quoted string.
    Str(String),
    /// Integer array.
    IntArray(Vec<u64>),
}

/// Parsed file: section -> key -> value.
#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl ConfigFile {
    /// Parse config text; returns line-numbered errors.
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut cfg = ConfigFile::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let value = Self::parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    fn parse_value(s: &str) -> Result<Value, String> {
        if s == "true" {
            return Ok(Value::Bool(true));
        }
        if s == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(body) = s.strip_prefix('"') {
            let body = body.strip_suffix('"').ok_or("unterminated string")?;
            return Ok(Value::Str(body.to_string()));
        }
        if let Some(body) = s.strip_prefix('[') {
            let body = body.strip_suffix(']').ok_or("unterminated array")?;
            let mut out = Vec::new();
            for part in body.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                out.push(Self::parse_int(part)?);
            }
            return Ok(Value::IntArray(out));
        }
        if s.contains('.') || s.contains('e') || s.contains('E') {
            if let Ok(f) = s.parse::<f64>() {
                return Ok(Value::Float(f));
            }
        }
        Self::parse_int(s).map(Value::Int)
    }

    fn parse_int(s: &str) -> Result<u64, String> {
        if let Some(hex) = s.strip_prefix("0x") {
            return u64::from_str_radix(&hex.replace('_', ""), 16)
                .map_err(|_| format!("bad hex '{s}'"));
        }
        parse_u64(s)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    fn get_u64(&self, section: &str, key: &str, default: u64) -> Result<u64, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Int(v)) => Ok(*v),
            Some(v) => Err(format!("{section}.{key}: expected integer, got {v:?}")),
        }
    }

    fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Bool(v)) => Ok(*v),
            Some(v) => Err(format!("{section}.{key}: expected bool, got {v:?}")),
        }
    }

    fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Float(v)) => Ok(*v),
            Some(Value::Int(v)) => Ok(*v as f64),
            Some(v) => Err(format!("{section}.{key}: expected float, got {v:?}")),
        }
    }

    /// Build a [`GbdiConfig`] from the `[codec]` section (missing keys
    /// keep their defaults); validates the result.
    pub fn codec_config(&self) -> Result<GbdiConfig, String> {
        let d = GbdiConfig::default();
        let word_size = match self.get_u64("codec", "word_size", d.word_size.bits() as u64)? {
            32 => WordSize::W32,
            64 => WordSize::W64,
            v => return Err(format!("codec.word_size: {v} not 32/64")),
        };
        let width_classes = match self.get("codec", "width_classes") {
            None => d.width_classes.clone(),
            Some(Value::IntArray(v)) => v.iter().map(|&x| x as u32).collect(),
            Some(v) => return Err(format!("codec.width_classes: expected array, got {v:?}")),
        };
        let cfg = GbdiConfig {
            block_bytes: self.get_u64("codec", "block_bytes", d.block_bytes as u64)? as usize,
            word_size,
            num_bases: self.get_u64("codec", "num_bases", d.num_bases as u64)? as usize,
            width_classes,
            analysis_samples: self
                .get_u64("codec", "analysis_samples", d.analysis_samples as u64)?
                as usize,
            analysis_iters: self.get_u64("codec", "analysis_iters", d.analysis_iters as u64)?
                as usize,
            delta_quantile: self.get_f64("codec", "delta_quantile", d.delta_quantile)?,
            seed: self.get_u64("codec", "seed", d.seed)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build a [`ServiceConfig`] from `[service]` + `[analyzer]` (+ the
    /// `[codec]` section for the embedded codec config).
    pub fn service_config(&self) -> Result<ServiceConfig, String> {
        let d = ServiceConfig::default();
        let selector = match self.get("analyzer", "selector") {
            None => d.selector,
            Some(Value::Str(s)) => SelectorKind::parse(s)
                .ok_or_else(|| format!("analyzer.selector: unknown selector '{s}'"))?,
            Some(v) => return Err(format!("analyzer.selector: expected string, got {v:?}")),
        };
        let drift_margin = self.get_f64("analyzer", "drift_margin", d.drift_margin)?;
        if drift_margin < 1.0 {
            return Err(format!("analyzer.drift_margin: {drift_margin} must be >= 1.0"));
        }
        let swap_margin = self.get_f64("analyzer", "swap_margin", d.swap_margin)?;
        if !(0.0..=1.0).contains(&swap_margin) {
            return Err(format!("analyzer.swap_margin: {swap_margin} must be in [0, 1]"));
        }
        let shards = self.get_u64("service", "shards", d.shards as u64)? as usize;
        if shards == 0 {
            return Err("service.shards: must be >= 1".into());
        }
        let ingest_batch = self.get_u64("service", "ingest_batch", d.ingest_batch as u64)? as usize;
        if ingest_batch == 0 {
            return Err("service.ingest_batch: must be >= 1".into());
        }
        Ok(ServiceConfig {
            codec: self.codec_config()?,
            workers: self.get_u64("service", "workers", d.workers as u64)? as usize,
            analyze_every: self.get_u64("service", "analyze_every", d.analyze_every)?,
            sample_words: self.get_u64("service", "sample_words", d.sample_words as u64)? as usize,
            recompress_batch: self
                .get_u64("service", "recompress_batch", d.recompress_batch as u64)?
                as usize,
            selector,
            drift_margin,
            swap_margin,
            shards,
            ingest_batch,
            cache_bytes: self.get_u64("cache", "bytes", d.cache_bytes as u64)? as usize,
            // the durability engine is a runtime object: the caller
            // (gbdi serve) builds it from persist_config() and injects
            persist: None,
            integrity: self.integrity_config()?,
        })
    }

    /// Build an [`IntegrityConfig`] from the `[integrity]` section
    /// (missing section or keys keep the defaults — integrity off,
    /// verify-on-read on when enabled, 8 MiB/s scrub budget); validates
    /// the result.
    pub fn integrity_config(&self) -> Result<IntegrityConfig, String> {
        let d = IntegrityConfig::default();
        let cfg = IntegrityConfig {
            enabled: self.get_bool("integrity", "enabled", d.enabled)?,
            verify_reads: self.get_bool("integrity", "verify_reads", d.verify_reads)?,
            scrub_mib_s: self.get_u64("integrity", "scrub_mib_s", d.scrub_mib_s)?,
        };
        if cfg.scrub_mib_s == 0 {
            return Err("integrity.scrub_mib_s: must be >= 1".into());
        }
        Ok(cfg)
    }

    /// Build a [`ServerConfig`] from the `[server]` section (missing
    /// keys keep their defaults); validates the result. The listen
    /// address here is overridden by `gbdi serve --listen` when both
    /// are given.
    pub fn server_config(&self) -> Result<ServerConfig, String> {
        let d = ServerConfig::default();
        let listen = match self.get("server", "listen") {
            None => d.listen,
            Some(Value::Str(s)) => s.clone(),
            Some(v) => return Err(format!("server.listen: expected string, got {v:?}")),
        };
        let cfg = ServerConfig {
            listen,
            max_conns: self.get_u64("server", "max_conns", d.max_conns as u64)? as usize,
            max_frame_bytes: self
                .get_u64("server", "max_frame_bytes", d.max_frame_bytes as u64)?
                as usize,
            write_queue_frames: self
                .get_u64("server", "write_queue_frames", d.write_queue_frames as u64)?
                as usize,
            write_queue_bytes: self
                .get_u64("server", "write_queue_bytes", d.write_queue_bytes as u64)?
                as usize,
            max_inflight_pages: self.get_u64("server", "max_inflight_pages", d.max_inflight_pages)?,
            retry_after_ms: self.get_u64("server", "retry_after_ms", d.retry_after_ms as u64)?
                as u32,
            poll_interval_ms: self.get_u64("server", "poll_interval_ms", d.poll_interval_ms)?,
            handshake_timeout_ms: self
                .get_u64("server", "handshake_timeout_ms", d.handshake_timeout_ms)?,
            write_timeout_ms: self.get_u64("server", "write_timeout_ms", d.write_timeout_ms)?,
        };
        if cfg.max_conns == 0 {
            return Err("server.max_conns: must be >= 1".into());
        }
        if cfg.max_frame_bytes < 64 << 10 {
            return Err("server.max_frame_bytes: must be >= 64k".into());
        }
        if cfg.write_queue_frames == 0 {
            return Err("server.write_queue_frames: must be >= 1".into());
        }
        if cfg.write_queue_bytes < 64 << 10 {
            return Err("server.write_queue_bytes: must be >= 64k".into());
        }
        if cfg.poll_interval_ms == 0 {
            return Err("server.poll_interval_ms: must be >= 1".into());
        }
        if cfg.handshake_timeout_ms == 0 {
            return Err("server.handshake_timeout_ms: must be >= 1".into());
        }
        if cfg.write_timeout_ms == 0 {
            return Err("server.write_timeout_ms: must be >= 1".into());
        }
        Ok(cfg)
    }

    /// Build the durability settings from the `[persist]` section:
    /// `Ok(None)` when the section is absent or has no `data_dir`
    /// (persistence off, the default), otherwise the data directory and
    /// a validated [`PersistConfig`]. `gbdi serve --data-dir` overrides
    /// the directory.
    pub fn persist_config(&self) -> Result<Option<(String, PersistConfig)>, String> {
        let dir = match self.get("persist", "data_dir") {
            None => return Ok(None),
            Some(Value::Str(s)) if s.is_empty() => return Ok(None),
            Some(Value::Str(s)) => s.clone(),
            Some(v) => return Err(format!("persist.data_dir: expected string, got {v:?}")),
        };
        let d = PersistConfig::default();
        let cfg = PersistConfig {
            fsync_batch: self.get_u64("persist", "fsync_batch", d.fsync_batch as u64)? as usize,
            wal_limit_bytes: self.get_u64("persist", "wal_limit", d.wal_limit_bytes)?,
        };
        if cfg.fsync_batch == 0 {
            return Err("persist.fsync_batch: must be >= 1".into());
        }
        if cfg.wal_limit_bytes < 4 << 10 {
            return Err("persist.wal_limit: must be >= 4k".into());
        }
        Ok(Some((dir, cfg)))
    }

    /// Load + parse a file.
    pub fn load(path: &str) -> Result<ConfigFile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
[codec]
block_bytes = 128          # inline comment
word_size = 32
num_bases = 32
width_classes = [0, 8, 16]
delta_quantile = 0.9
seed = 0xDEAD_BEEF

[service]
workers = 8
shards = 4
ingest_batch = 16
analyze_every = 1k

[analyzer]
selector = "minibatch"
drift_margin = 1.05

[cache]
bytes = 4m
"#;

    #[test]
    fn parses_sample() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get("codec", "block_bytes"), Some(&Value::Int(128)));
        assert_eq!(cfg.get("codec", "delta_quantile"), Some(&Value::Float(0.9)));
        assert_eq!(cfg.get("codec", "seed"), Some(&Value::Int(0xDEAD_BEEF)));
        assert_eq!(
            cfg.get("codec", "width_classes"),
            Some(&Value::IntArray(vec![0, 8, 16]))
        );
        assert_eq!(cfg.get("service", "analyze_every"), Some(&Value::Int(1024)));
        assert_eq!(cfg.get("nope", "x"), None);
    }

    #[test]
    fn builds_codec_config() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap().codec_config().unwrap();
        assert_eq!(cfg.block_bytes, 128);
        assert_eq!(cfg.num_bases, 32);
        assert_eq!(cfg.width_classes, vec![0, 8, 16]);
        assert!((cfg.delta_quantile - 0.9).abs() < 1e-12);
        // unspecified keys keep defaults
        assert_eq!(cfg.analysis_samples, GbdiConfig::default().analysis_samples);
    }

    #[test]
    fn builds_service_config() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap().service_config().unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.ingest_batch, 16);
        assert_eq!(cfg.analyze_every, 1024);
        assert_eq!(cfg.codec.block_bytes, 128);
        assert_eq!(cfg.selector, SelectorKind::MiniBatch);
        assert!((cfg.drift_margin - 1.05).abs() < 1e-12);
        assert_eq!(cfg.cache_bytes, 4 << 20);
        // unspecified analyzer keys keep their defaults
        assert!((cfg.swap_margin - ServiceConfig::default().swap_margin).abs() < 1e-12);
    }

    #[test]
    fn cache_section_defaults_off_and_validates() {
        // no [cache] section: the cache stays disabled
        let c = ConfigFile::parse("").unwrap().service_config().unwrap();
        assert_eq!(c.cache_bytes, 0);
        assert_eq!(ServiceConfig::default().cache_bytes, 0);
        // explicit zero is also off; suffixed sizes parse
        let c = ConfigFile::parse("[cache]\nbytes = 0").unwrap().service_config().unwrap();
        assert_eq!(c.cache_bytes, 0);
        let c = ConfigFile::parse("[cache]\nbytes = 64k").unwrap().service_config().unwrap();
        assert_eq!(c.cache_bytes, 64 << 10);
        // type errors are caught
        let c = ConfigFile::parse("[cache]\nbytes = \"lots\"").unwrap();
        assert!(c.service_config().is_err());
    }

    #[test]
    fn sharding_keys_validate() {
        let c = ConfigFile::parse("[service]\nshards = 0").unwrap();
        assert!(c.service_config().is_err());
        let c = ConfigFile::parse("[service]\ningest_batch = 0").unwrap();
        assert!(c.service_config().is_err());
        // defaults when the keys are absent
        let c = ConfigFile::parse("").unwrap().service_config().unwrap();
        assert_eq!(c.shards, ServiceConfig::default().shards);
        assert_eq!(c.ingest_batch, ServiceConfig::default().ingest_batch);
    }

    #[test]
    fn analyzer_section_validates() {
        let c = ConfigFile::parse("[analyzer]\nselector = \"bogus\"").unwrap();
        assert!(c.service_config().is_err());
        let c = ConfigFile::parse("[analyzer]\nselector = 3").unwrap();
        assert!(c.service_config().is_err());
        let c = ConfigFile::parse("[analyzer]\ndrift_margin = 0.5").unwrap();
        assert!(c.service_config().is_err());
        let c = ConfigFile::parse("[analyzer]\nswap_margin = 1.5").unwrap();
        assert!(c.service_config().is_err());
        // defaults when the section is absent
        let c = ConfigFile::parse("").unwrap().service_config().unwrap();
        assert_eq!(c.selector, ServiceConfig::default().selector);
    }

    #[test]
    fn persist_section_builds_and_validates() {
        // absent section or absent data_dir: persistence off
        assert_eq!(ConfigFile::parse("").unwrap().persist_config().unwrap(), None);
        let c = ConfigFile::parse("[persist]\nfsync_batch = 4").unwrap();
        assert_eq!(c.persist_config().unwrap(), None);
        let c = ConfigFile::parse("[persist]\ndata_dir = \"\"").unwrap();
        assert_eq!(c.persist_config().unwrap(), None);
        // full section
        let text = "[persist]\ndata_dir = \"data\"\nfsync_batch = 8\nwal_limit = 1m";
        let (dir, cfg) = ConfigFile::parse(text).unwrap().persist_config().unwrap().unwrap();
        assert_eq!(dir, "data");
        assert_eq!(cfg.fsync_batch, 8);
        assert_eq!(cfg.wal_limit_bytes, 1 << 20);
        // defaults for unspecified keys
        let c = ConfigFile::parse("[persist]\ndata_dir = \"d\"").unwrap();
        let (_, cfg) = c.persist_config().unwrap().unwrap();
        assert_eq!(cfg.fsync_batch, PersistConfig::default().fsync_batch);
        assert_eq!(cfg.wal_limit_bytes, PersistConfig::default().wal_limit_bytes);
        // validation
        for bad in [
            "[persist]\ndata_dir = \"d\"\nfsync_batch = 0",
            "[persist]\ndata_dir = \"d\"\nwal_limit = 1k",
            "[persist]\ndata_dir = 7",
        ] {
            let c = ConfigFile::parse(bad).unwrap();
            assert!(c.persist_config().is_err(), "{bad:?} should fail validation");
        }
    }

    #[test]
    fn builds_server_config() {
        let text = "[server]\nlisten = \"0.0.0.0:9999\"\nmax_conns = 8\n\
                    write_queue_bytes = 1m\nmax_inflight_pages = 512\nretry_after_ms = 10\n\
                    handshake_timeout_ms = 250\nwrite_timeout_ms = 2000";
        let cfg = ConfigFile::parse(text).unwrap().server_config().unwrap();
        assert_eq!(cfg.listen, "0.0.0.0:9999");
        assert_eq!(cfg.max_conns, 8);
        assert_eq!(cfg.write_queue_bytes, 1 << 20);
        assert_eq!(cfg.max_inflight_pages, 512);
        assert_eq!(cfg.retry_after_ms, 10);
        assert_eq!(cfg.handshake_timeout_ms, 250);
        assert_eq!(cfg.write_timeout_ms, 2000);
        // unspecified keys keep defaults
        let d = ServerConfig::default();
        assert_eq!(cfg.max_frame_bytes, d.max_frame_bytes);
        assert_eq!(cfg.write_queue_frames, d.write_queue_frames);
        assert_eq!(cfg.poll_interval_ms, d.poll_interval_ms);
        // no [server] section: all defaults
        assert_eq!(ConfigFile::parse("").unwrap().server_config().unwrap(), d);
    }

    #[test]
    fn server_section_validates() {
        for bad in [
            "[server]\nmax_conns = 0",
            "[server]\nmax_frame_bytes = 1k",
            "[server]\nwrite_queue_frames = 0",
            "[server]\nwrite_queue_bytes = 1k",
            "[server]\npoll_interval_ms = 0",
            "[server]\nlisten = 7070",
            "[server]\nhandshake_timeout_ms = 0",
            "[server]\nwrite_timeout_ms = 0",
        ] {
            let c = ConfigFile::parse(bad).unwrap();
            assert!(c.server_config().is_err(), "{bad:?} should fail validation");
        }
    }

    #[test]
    fn integrity_section_builds_and_validates() {
        // absent section: integrity off, defaults intact
        let c = ConfigFile::parse("").unwrap();
        assert_eq!(c.integrity_config().unwrap(), IntegrityConfig::default());
        assert!(!c.integrity_config().unwrap().enabled);
        // full section, wired through service_config too
        let text = "[integrity]\nenabled = true\nverify_reads = false\nscrub_mib_s = 32";
        let c = ConfigFile::parse(text).unwrap();
        let i = c.integrity_config().unwrap();
        assert!(i.enabled);
        assert!(!i.verify_reads);
        assert_eq!(i.scrub_mib_s, 32);
        assert_eq!(c.service_config().unwrap().integrity, i);
        // enabling alone keeps verify_reads on and the default budget
        let c = ConfigFile::parse("[integrity]\nenabled = true").unwrap();
        let i = c.integrity_config().unwrap();
        assert!(i.enabled && i.verify_reads);
        assert_eq!(i.scrub_mib_s, IntegrityConfig::default().scrub_mib_s);
        // validation
        for bad in [
            "[integrity]\nenabled = 1",
            "[integrity]\nverify_reads = \"yes\"",
            "[integrity]\nscrub_mib_s = 0",
        ] {
            let c = ConfigFile::parse(bad).unwrap();
            assert!(c.integrity_config().is_err(), "{bad:?} should fail validation");
        }
    }

    #[test]
    fn empty_file_gives_defaults() {
        let cfg = ConfigFile::parse("").unwrap();
        assert_eq!(cfg.codec_config().unwrap(), GbdiConfig::default());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ConfigFile::parse("[unterminated").is_err());
        assert!(ConfigFile::parse("keynovalue").is_err());
        assert!(ConfigFile::parse("[s]\nx = \"open").is_err());
        assert!(ConfigFile::parse("[s]\nx = [1, 2").is_err());
        // bad semantic values
        let c = ConfigFile::parse("[codec]\nword_size = 16").unwrap();
        assert!(c.codec_config().is_err());
        let c = ConfigFile::parse("[codec]\nblock_bytes = 30").unwrap();
        assert!(c.codec_config().is_err(), "validation runs");
        let c = ConfigFile::parse("[codec]\nnum_bases = 0.5").unwrap();
        assert!(c.codec_config().is_err());
    }

    #[test]
    fn strings_and_bools() {
        let c = ConfigFile::parse("[x]\na = true\nb = false\nc = \"hi\"").unwrap();
        assert_eq!(c.get("x", "a"), Some(&Value::Bool(true)));
        assert_eq!(c.get("x", "b"), Some(&Value::Bool(false)));
        assert_eq!(c.get("x", "c"), Some(&Value::Str("hi".into())));
    }
}
