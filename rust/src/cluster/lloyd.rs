//! Full Lloyd k-means over memory word values — the paper's algorithm
//! and the selector engine's reference arm ([`LloydSelector`]).
//!
//! Runs cold every pass: k-means++ seeding, then `iters` full
//! assignment/update sweeps under the configured [`Metric`]. The
//! mini-batch selector (`super::minibatch`) trades a little quality for
//! an order of magnitude less work; this implementation is the
//! correctness oracle and the quality ceiling the benches compare
//! against. The same algorithm also ships as an AOT-compiled JAX/Pallas
//! artifact executed through [`crate::runtime`] (`super::artifact`).

use super::{
    point_cost as cost, wrapping_delta, BaseSelector, Metric, Selection, SelectorConfig,
};
use crate::util::prng::Rng;
use crate::value::WordSize;

/// Clustering configuration.
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    /// Number of clusters (global bases to find).
    pub k: usize,
    /// Maximum Lloyd iterations.
    pub iters: usize,
    /// Assignment metric.
    pub metric: Metric,
    /// Sorted delta width classes (bits) used by [`Metric::BitCost`];
    /// must match the codec's [`crate::gbdi::GbdiConfig::width_classes`].
    pub width_classes: Vec<u32>,
    /// Word granularity (wrapping-delta semantics).
    pub word_size: WordSize,
    /// PRNG seed (k-means++ init).
    pub seed: u64,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig {
            k: 64,
            iters: 16,
            metric: Metric::BitCost,
            width_classes: vec![0, 4, 8, 12, 16, 20, 24],
            word_size: WordSize::W32,
            seed: 0x6BD1_5EED,
        }
    }
}

/// Clustering output.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Final centroids (cluster means snapped to word values), sorted
    /// ascending. Length <= k (duplicate/empty centers are dropped).
    pub centroids: Vec<u64>,
    /// Samples assigned to each centroid in the final assignment.
    pub counts: Vec<u64>,
    /// Sum of final per-sample costs (metric units: bits for BitCost,
    /// |delta| for Euclidean).
    pub inertia: f64,
    /// Iterations actually run (stops early on convergence).
    pub iters_run: usize,
}

/// k-means++ seeding: first center uniform, subsequent centers sampled
/// proportionally to the current assignment cost.
fn seed_centers(samples: &[u64], cfg: &KmeansConfig, rng: &mut Rng, outlier_bits: u32) -> Vec<u64> {
    let mut centers = Vec::with_capacity(cfg.k);
    centers.push(samples[rng.below(samples.len() as u64) as usize]);
    let mut best_cost: Vec<f64> = samples
        .iter()
        .map(|&v| cost(v, centers[0], cfg.metric, &cfg.width_classes, cfg.word_size, outlier_bits))
        .collect();
    while centers.len() < cfg.k {
        let total: f64 = best_cost.iter().sum();
        let next = if total <= 0.0 {
            // All samples already at zero cost: any extra center is moot;
            // pick uniformly to keep K stable.
            samples[rng.below(samples.len() as u64) as usize]
        } else {
            let mut x = rng.f64() * total;
            let mut pick = samples.len() - 1;
            for (i, &c) in best_cost.iter().enumerate() {
                x -= c;
                if x < 0.0 {
                    pick = i;
                    break;
                }
            }
            samples[pick]
        };
        centers.push(next);
        for (bc, &v) in best_cost.iter_mut().zip(samples) {
            let c = cost(v, next, cfg.metric, &cfg.width_classes, cfg.word_size, outlier_bits);
            if c < *bc {
                *bc = c;
            }
        }
    }
    centers
}

/// Run k-means over `samples` (word values). Deterministic for a given
/// config. Empty or tiny inputs yield a degenerate (but valid) result.
pub fn kmeans(samples: &[u64], cfg: &KmeansConfig) -> KmeansResult {
    assert!(cfg.k >= 1, "k must be >= 1");
    assert!(!cfg.width_classes.is_empty());
    debug_assert!(cfg.width_classes.windows(2).all(|w| w[0] < w[1]), "classes sorted");
    if samples.is_empty() {
        return KmeansResult { centroids: vec![0], counts: vec![0], inertia: 0.0, iters_run: 0 };
    }
    let outlier_bits = super::outlier_bits(cfg.word_size);
    let mut rng = Rng::new(cfg.seed);
    let mut centers = seed_centers(samples, cfg, &mut rng, outlier_bits);
    let mut assign = vec![0u32; samples.len()];
    let mut iters_run = 0;
    let mut inertia = 0.0;

    for _iter in 0..cfg.iters {
        iters_run += 1;
        // --- assignment step ---
        inertia = 0.0;
        let mut changed = false;
        for (i, &v) in samples.iter().enumerate() {
            let mut best = 0u32;
            let mut best_cost = f64::INFINITY;
            let mut best_abs = i64::MAX;
            for (j, &c) in centers.iter().enumerate() {
                let cst = cost(v, c, cfg.metric, &cfg.width_classes, cfg.word_size, outlier_bits);
                let abs = wrapping_delta(v, c, cfg.word_size).unsigned_abs() as i64;
                if cst < best_cost || (cst == best_cost && abs < best_abs) {
                    best_cost = cst;
                    best_abs = abs;
                    best = j as u32;
                }
            }
            inertia += best_cost;
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        if !changed && _iter > 0 {
            break;
        }
        // --- update step: mean of assigned values ---
        let mut sums = vec![0u128; centers.len()];
        let mut counts = vec![0u64; centers.len()];
        for (&v, &a) in samples.iter().zip(&assign) {
            sums[a as usize] += v as u128;
            counts[a as usize] += 1;
        }
        for j in 0..centers.len() {
            if counts[j] > 0 {
                centers[j] = (sums[j] / counts[j] as u128) as u64;
            } else {
                // Re-seed empty clusters on the sample with the worst cost.
                let (worst, _) = samples
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        (i, cost(v, centers[assign[i] as usize], cfg.metric, &cfg.width_classes, cfg.word_size, outlier_bits))
                    })
                    .fold((0, f64::MIN), |acc, (i, c)| if c > acc.1 { (i, c) } else { acc });
                centers[j] = samples[worst];
            }
        }
    }

    // Final pass: recount with the last centers, dedup, sort.
    let mut counts = vec![0u64; centers.len()];
    for &v in samples {
        let mut best = 0usize;
        let mut best_cost = f64::INFINITY;
        for (j, &c) in centers.iter().enumerate() {
            let cst = cost(v, c, cfg.metric, &cfg.width_classes, cfg.word_size, outlier_bits);
            if cst < best_cost {
                best_cost = cst;
                best = j;
            }
        }
        counts[best] += 1;
    }
    let mut pairs: Vec<(u64, u64)> = centers.into_iter().zip(counts).collect();
    pairs.sort_unstable();
    pairs.dedup_by(|a, b| {
        if a.0 == b.0 {
            b.1 += a.1; // merge duplicate centers' counts
            true
        } else {
            false
        }
    });
    let (centroids, counts): (Vec<u64>, Vec<u64>) = pairs.into_iter().unzip();
    KmeansResult { centroids, counts, inertia, iters_run }
}

/// The reference [`BaseSelector`]: full Lloyd k-means, re-seeded cold on
/// every pass (the incumbent is ignored). Highest quality, highest cost.
pub struct LloydSelector;

impl BaseSelector for LloydSelector {
    fn name(&self) -> &'static str {
        "lloyd"
    }

    fn select(
        &mut self,
        samples: &[u64],
        _incumbent: Option<&crate::gbdi::table::GlobalBaseTable>,
        cfg: &SelectorConfig,
    ) -> crate::Result<Selection> {
        let kcfg = KmeansConfig {
            k: cfg.k,
            iters: cfg.iters,
            metric: cfg.metric,
            width_classes: cfg.width_classes.clone(),
            word_size: cfg.word_size,
            seed: cfg.seed,
        };
        let r = kmeans(samples, &kcfg);
        Ok(Selection {
            centroids: r.centroids,
            cost: r.inertia,
            iters_run: r.iters_run,
            warm_started: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{apply_delta, fit_class};

    fn cfg(k: usize, metric: Metric) -> KmeansConfig {
        KmeansConfig { k, iters: 20, metric, seed: 42, ..Default::default() }
    }

    fn mixture(centers: &[u64], per: usize, spread: i64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &c in centers {
            for _ in 0..per {
                out.push(apply_delta(c, rng.range_i64(-spread, spread), WordSize::W32));
            }
        }
        out
    }

    #[test]
    fn wrapping_delta_roundtrip_w32() {
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            let v = rng.next_u32() as u64;
            let c = rng.next_u32() as u64;
            let d = wrapping_delta(v, c, WordSize::W32);
            assert_eq!(apply_delta(c, d, WordSize::W32), v);
            assert!(d.abs() <= 1 << 31);
        }
    }

    #[test]
    fn wrapping_delta_roundtrip_w64() {
        let mut rng = Rng::new(2);
        for _ in 0..5000 {
            let v = rng.next_u64();
            let c = rng.next_u64();
            let d = wrapping_delta(v, c, WordSize::W64);
            assert_eq!(apply_delta(c, d, WordSize::W64), v);
        }
    }

    #[test]
    fn fit_class_picks_smallest() {
        let classes = [0u32, 4, 8, 16];
        assert_eq!(fit_class(&classes, 0), Some(0));
        assert_eq!(fit_class(&classes, 1), Some(4)); // needs 2 bits
        assert_eq!(fit_class(&classes, 7), Some(4));
        assert_eq!(fit_class(&classes, 8), Some(8));
        assert_eq!(fit_class(&classes, -8), Some(4));
        assert_eq!(fit_class(&classes, 127), Some(8));
        assert_eq!(fit_class(&classes, 128), Some(16));
        assert_eq!(fit_class(&classes, 40_000), None);
    }

    #[test]
    fn recovers_well_separated_clusters() {
        let true_centers = [10_000u64, 5_000_000, 3_000_000_000];
        let samples = mixture(&true_centers, 500, 50, 3);
        let r = kmeans(&samples, &cfg(3, Metric::Euclidean));
        assert_eq!(r.centroids.len(), 3);
        for (&found, &truth) in r.centroids.iter().zip(&true_centers) {
            assert!(
                (found as i64 - truth as i64).abs() < 100,
                "found {found} vs true {truth}"
            );
        }
        assert_eq!(r.counts.iter().sum::<u64>(), samples.len() as u64);
    }

    #[test]
    fn bitcost_beats_euclidean_on_encoded_size() {
        // Two tight clusters plus one broad cloud: BitCost should place
        // bases to minimize delta bits, yielding lower bit inertia.
        let mut samples = mixture(&[1 << 20, 1 << 28], 800, 100, 5);
        let mut rng = Rng::new(6);
        for _ in 0..200 {
            samples.push(rng.next_u32() as u64);
        }
        let bit = kmeans(&samples, &cfg(8, Metric::BitCost));
        // Evaluate Euclidean result under the bit-cost metric.
        let euc = kmeans(&samples, &cfg(8, Metric::Euclidean));
        let classes = [0u32, 4, 8, 16, 24];
        let eval = |centers: &[u64]| -> f64 {
            samples
                .iter()
                .map(|&v| {
                    centers
                        .iter()
                        .map(|&c| match fit_class(&classes, wrapping_delta(v, c, WordSize::W32)) {
                            Some(w) => w as f64,
                            None => 40.0,
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .sum()
        };
        let bit_bits = eval(&bit.centroids);
        let euc_bits = eval(&euc.centroids);
        assert!(
            bit_bits <= euc_bits * 1.05,
            "bit-cost clustering should not lose on encoded bits: {bit_bits} vs {euc_bits}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let samples = mixture(&[7777, 999_999], 200, 20, 9);
        let a = kmeans(&samples, &cfg(4, Metric::BitCost));
        let b = kmeans(&samples, &cfg(4, Metric::BitCost));
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn handles_degenerate_inputs() {
        let r = kmeans(&[], &cfg(4, Metric::BitCost));
        assert_eq!(r.centroids, vec![0]);
        let r = kmeans(&[42], &cfg(4, Metric::BitCost));
        assert!(r.centroids.contains(&42));
        let same = vec![5u64; 100];
        let r = kmeans(&same, &cfg(4, Metric::Euclidean));
        assert!(r.centroids.contains(&5));
        assert_eq!(r.counts.iter().sum::<u64>(), 100);
    }

    #[test]
    fn k_larger_than_distinct_values() {
        let samples = vec![1u64, 2, 1, 2, 1, 2];
        let r = kmeans(&samples, &cfg(8, Metric::Euclidean));
        assert!(r.centroids.len() <= 8);
        assert!(!r.centroids.is_empty());
    }

    #[test]
    fn centroids_sorted_unique() {
        let samples = mixture(&[100, 1000, 10_000, 100_000], 100, 10, 13);
        let r = kmeans(&samples, &cfg(16, Metric::BitCost));
        assert!(r.centroids.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn inertia_decreases_with_more_bases() {
        let samples = mixture(&[1 << 10, 1 << 16, 1 << 22, 1 << 28], 400, 1000, 21);
        let small = kmeans(&samples, &cfg(2, Metric::BitCost));
        let large = kmeans(&samples, &cfg(16, Metric::BitCost));
        assert!(
            large.inertia <= small.inertia,
            "more bases should not increase inertia: {} vs {}",
            large.inertia,
            small.inertia
        );
    }
}
