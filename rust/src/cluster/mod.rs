//! Base selection — GBDI's "background data analysis" step as a
//! pluggable engine.
//!
//! The compression ratio GBDI reaches is decided here: a selector looks
//! at sampled word values and proposes the global bases the codec will
//! encode deltas against. The repo used to hard-wire one strategy (full
//! bit-cost Lloyd k-means, re-run cold every pass); this module makes the
//! strategy a first-class seam — the [`BaseSelector`] trait — with four
//! implementations:
//!
//! * [`lloyd::LloydSelector`] — full bit-cost Lloyd k-means (the paper's
//!   algorithm; the reference arm for quality).
//! * [`minibatch::MiniBatchSelector`] — streaming mini-batch k-means that
//!   **warm-starts from the incumbent table's centroids** instead of
//!   re-seeding every pass; the production arm (≈an order of magnitude
//!   cheaper per pass, within a couple percent of Lloyd's ratio).
//! * [`histogram::HistogramSelector`] — frequency top-K bucket selector;
//!   near-free, strong on pointer-heavy (Java) populations.
//! * [`artifact::ArtifactSelector`] — the AOT JAX/Pallas k-means executed
//!   through PJRT ([`crate::runtime`]), folded in as just another
//!   selector.
//!
//! Selectors receive the *incumbent* [`GlobalBaseTable`] (when one is
//! serving) so they can adapt incrementally; the analyzer layers drift
//! detection on top and skips re-clustering entirely while the incumbent
//! still scores well (see `coordinator::analyzer`). See DESIGN.md §6.
//!
//! Two assignment metrics are provided:
//!
//! * [`Metric::Euclidean`] — textbook distance (the paper's "unmodified
//!   Kmeans" ablation arm).
//! * [`Metric::BitCost`] — GBDI's *modified* metric: the distance between
//!   a value and a candidate base is the **encoded size** of their delta.

pub mod artifact;
pub mod histogram;
pub mod lloyd;
pub mod minibatch;

pub use artifact::ArtifactSelector;
pub use histogram::HistogramSelector;
pub use lloyd::{kmeans, KmeansConfig, KmeansResult, LloydSelector};
pub use minibatch::MiniBatchSelector;

use crate::gbdi::table::GlobalBaseTable;
use crate::gbdi::GbdiConfig;
use crate::util::bits::signed_width;
use crate::value::WordSize;

/// Assignment metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// |v - c| (the paper's unmodified k-means arm).
    Euclidean,
    /// Encoded bits of the delta under the codec's width classes
    /// (the paper's modified k-means).
    BitCost,
}

/// Wrapping signed delta `v - c` at word granularity: the delta the codec
/// will store, sign-extended to i64. Reconstruction is exact under
/// wrapping addition at the same width.
#[inline]
pub fn wrapping_delta(v: u64, c: u64, ws: WordSize) -> i64 {
    match ws {
        WordSize::W32 => (v as u32).wrapping_sub(c as u32) as i32 as i64,
        WordSize::W64 => v.wrapping_sub(c) as i64,
    }
}

/// Inverse of [`wrapping_delta`]: reconstruct `v` from base and delta.
#[inline]
pub fn apply_delta(c: u64, d: i64, ws: WordSize) -> u64 {
    match ws {
        WordSize::W32 => (c as u32).wrapping_add(d as u32) as u64,
        WordSize::W64 => c.wrapping_add(d as u64),
    }
}

/// Smallest width class (from sorted `classes`) that can hold signed `d`
/// in offset-binary, or `None` if `d` needs more bits than the largest
/// class. Class 0 means exact match (d == 0).
#[inline]
pub fn fit_class(classes: &[u32], d: i64) -> Option<u32> {
    let need = signed_width(d);
    classes.iter().copied().find(|&c| c >= need)
}

/// Bits charged to a value that no base can cover (full word + escape
/// slack) — the outlier cost every selector and scorer agrees on.
#[inline]
pub fn outlier_bits(ws: WordSize) -> u32 {
    ws.bits() + 8
}

/// Per-value cost of assigning `v` to base `c` under `metric`:
/// * Euclidean — |delta| as f64.
/// * BitCost — encoded delta bits, or `outlier_bits` when no class fits.
#[inline]
pub(crate) fn point_cost(
    v: u64,
    c: u64,
    metric: Metric,
    classes: &[u32],
    ws: WordSize,
    outlier_bits: u32,
) -> f64 {
    let d = wrapping_delta(v, c, ws);
    match metric {
        Metric::Euclidean => (d as f64).abs(),
        Metric::BitCost => match fit_class(classes, d) {
            Some(w) => w as f64,
            None => outlier_bits as f64,
        },
    }
}

/// Configuration every [`BaseSelector`] receives. Mirrors the analysis
/// knobs of [`GbdiConfig`] plus the mini-batch tuning parameters.
#[derive(Debug, Clone)]
pub struct SelectorConfig {
    /// Number of bases to find (the pinned zero base is extra).
    pub k: usize,
    /// Iteration / pass budget (Lloyd iterations, mini-batch passes).
    pub iters: usize,
    /// Assignment metric.
    pub metric: Metric,
    /// Sorted delta width classes (bits); must match the codec's
    /// [`GbdiConfig::width_classes`].
    pub width_classes: Vec<u32>,
    /// Word granularity (wrapping-delta semantics).
    pub word_size: WordSize,
    /// PRNG seed (seeding, batch sampling).
    pub seed: u64,
    /// Mini-batch size per pass (mini-batch selector only).
    pub batch_size: usize,
    /// Early-stop threshold: a pass improving the batch cost by less than
    /// this relative fraction ends the run (mini-batch selector only).
    pub min_improvement: f64,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig::from_gbdi(&GbdiConfig::default())
    }
}

impl SelectorConfig {
    /// Derive the selector configuration from a codec config (one slot is
    /// reserved for the pinned zero base, matching the analyzer).
    pub fn from_gbdi(cfg: &GbdiConfig) -> Self {
        SelectorConfig {
            k: cfg.num_bases.saturating_sub(1).max(1),
            iters: cfg.analysis_iters,
            metric: Metric::BitCost,
            width_classes: cfg.width_classes.clone(),
            word_size: cfg.word_size,
            seed: cfg.seed,
            batch_size: 256,
            min_improvement: 0.005,
        }
    }
}

/// A selector's proposal: candidate global bases plus bookkeeping the
/// analyzer and the benches report on.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Proposed bases (sorted ascending, deduplicated, never empty).
    pub centroids: Vec<u64>,
    /// Total metric cost of `samples` under the proposal (bits for
    /// [`Metric::BitCost`]).
    pub cost: f64,
    /// Iterations / passes the selector actually ran.
    pub iters_run: usize,
    /// Whether the selector warm-started from an incumbent table.
    pub warm_started: bool,
}

/// The pluggable base-selection seam: turn sampled word values into
/// candidate global bases. `incumbent` is the table currently serving (if
/// any) so incremental selectors can warm-start from it; stateless
/// selectors may ignore it. Implementations must be deterministic for a
/// given `(samples, incumbent, cfg)`.
pub trait BaseSelector: Send {
    /// Short name used on the CLI and in reports (e.g. `"minibatch"`).
    fn name(&self) -> &'static str;

    /// Propose bases for `samples`. Errors are reserved for external
    /// backends (PJRT artifacts); pure-Rust selectors always succeed.
    fn select(
        &mut self,
        samples: &[u64],
        incumbent: Option<&GlobalBaseTable>,
        cfg: &SelectorConfig,
    ) -> crate::Result<Selection>;
}

/// Total metric cost of `samples` under `centroids` (each sample pays its
/// cheapest centroid) — the shared scorer selectors use to fill
/// [`Selection::cost`].
pub fn selection_cost(samples: &[u64], centroids: &[u64], cfg: &SelectorConfig) -> f64 {
    let ob = outlier_bits(cfg.word_size);
    samples
        .iter()
        .map(|&v| {
            centroids
                .iter()
                .map(|&c| point_cost(v, c, cfg.metric, &cfg.width_classes, cfg.word_size, ob))
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

/// Sort + deduplicate proposed centroids; degenerate proposals collapse
/// to the single zero base so downstream table building never sees an
/// empty set.
pub(crate) fn finalize_centroids(mut centroids: Vec<u64>) -> Vec<u64> {
    centroids.sort_unstable();
    centroids.dedup();
    if centroids.is_empty() {
        centroids.push(0);
    }
    centroids
}

/// The empty-input proposal shared by all selectors.
pub(crate) fn degenerate_selection() -> Selection {
    Selection { centroids: vec![0], cost: 0.0, iters_run: 0, warm_started: false }
}

/// The pure-Rust selectors the CLI and configs can instantiate by name
/// ([`ArtifactSelector`] needs a PJRT runtime handle and is constructed
/// explicitly — see `gbdi serve --selector artifact`).
///
/// Every kind builds a [`BaseSelector`] whose proposal flows through
/// the same width fitting
/// ([`GlobalBaseTable::from_selection`](crate::gbdi::table::GlobalBaseTable::from_selection)),
/// so choosing a selector trades ratio against analysis latency but can
/// never affect decode correctness (DESIGN.md §6).
///
/// ```
/// use gbdi::cluster::{BaseSelector, SelectorConfig, SelectorKind};
///
/// let kind = SelectorKind::parse("minibatch").unwrap();
/// assert_eq!(kind.name(), "minibatch");
/// let mut selector = kind.build();
/// // a tight cluster around 50_000: one base covers everything
/// let samples: Vec<u64> = (0..512u64).map(|i| 50_000 + (i % 40)).collect();
/// let selection = selector.select(&samples, None, &SelectorConfig::default()).unwrap();
/// assert!(!selection.centroids.is_empty());
/// assert!(selection.cost.is_finite());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectorKind {
    /// Full bit-cost Lloyd k-means (reference arm).
    Lloyd,
    /// Mini-batch k-means with incumbent warm start (production arm).
    MiniBatch,
    /// Frequency top-K bucket selector (near-free).
    Histogram,
}

impl SelectorKind {
    /// All registered kinds, in report order.
    pub fn all() -> &'static [SelectorKind] {
        &[SelectorKind::Lloyd, SelectorKind::MiniBatch, SelectorKind::Histogram]
    }

    /// Parse a `--selector` value (case-insensitive, by registered name).
    pub fn parse(s: &str) -> Option<SelectorKind> {
        let s = s.to_ascii_lowercase();
        SelectorKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// The kind's name (matches [`BaseSelector::name`]).
    pub fn name(self) -> &'static str {
        match self {
            SelectorKind::Lloyd => "lloyd",
            SelectorKind::MiniBatch => "minibatch",
            SelectorKind::Histogram => "histogram",
        }
    }

    /// Instantiate the selector.
    pub fn build(self) -> Box<dyn BaseSelector> {
        match self {
            SelectorKind::Lloyd => Box::new(LloydSelector),
            SelectorKind::MiniBatch => Box::new(MiniBatchSelector),
            SelectorKind::Histogram => Box::new(HistogramSelector),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn mixture(centers: &[u64], per: usize, spread: i64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &c in centers {
            for _ in 0..per {
                out.push(apply_delta(c, rng.range_i64(-spread, spread), WordSize::W32));
            }
        }
        out
    }

    fn cfg(k: usize) -> SelectorConfig {
        SelectorConfig { k, seed: 42, ..Default::default() }
    }

    #[test]
    fn kind_parse_matches_names() {
        for &k in SelectorKind::all() {
            assert_eq!(SelectorKind::parse(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(SelectorKind::parse("LLOYD"), Some(SelectorKind::Lloyd));
        assert_eq!(SelectorKind::parse("nope"), None);
    }

    #[test]
    fn every_selector_proposes_valid_selections() {
        let samples = mixture(&[50_000, 9_000_000, 3_000_000_000], 600, 80, 7);
        for &kind in SelectorKind::all() {
            let mut sel = kind.build();
            let s = sel.select(&samples, None, &cfg(16)).unwrap();
            assert!(!s.centroids.is_empty(), "{}", kind.name());
            assert!(
                s.centroids.windows(2).all(|w| w[0] < w[1]),
                "{} centroids sorted unique",
                kind.name()
            );
            assert!(s.cost.is_finite() && s.cost >= 0.0, "{}", kind.name());
            assert!(!s.warm_started, "{} had no incumbent", kind.name());
            // raw would cost ~40 bits/word; any sane proposal beats half of it
            assert!(
                s.cost < samples.len() as f64 * 20.0,
                "{} cost {} too high",
                kind.name(),
                s.cost
            );
        }
    }

    #[test]
    fn every_selector_handles_empty_and_tiny_inputs() {
        for &kind in SelectorKind::all() {
            let mut sel = kind.build();
            let s = sel.select(&[], None, &cfg(8)).unwrap();
            assert_eq!(s.centroids, vec![0], "{} empty input", kind.name());
            let s = sel.select(&[42], None, &cfg(8)).unwrap();
            assert!(s.centroids.contains(&42), "{} single sample", kind.name());
            let s = sel.select(&[5; 100], None, &cfg(8)).unwrap();
            assert!(s.centroids.contains(&5), "{} constant input", kind.name());
        }
    }

    #[test]
    fn selectors_are_deterministic() {
        let samples = mixture(&[7777, 999_999], 300, 30, 9);
        for &kind in SelectorKind::all() {
            let a = kind.build().select(&samples, None, &cfg(8)).unwrap();
            let b = kind.build().select(&samples, None, &cfg(8)).unwrap();
            assert_eq!(a.centroids, b.centroids, "{}", kind.name());
        }
    }

    #[test]
    fn selection_cost_matches_pointwise_minimum() {
        let samples = vec![100u64, 101, 5000];
        let c = SelectorConfig { width_classes: vec![0, 4, 8], ..cfg(2) };
        // centroid 100: v=100 cost 0, v=101 cost 4, v=5000 outlier (40)
        let cost = selection_cost(&samples, &[100], &c);
        assert_eq!(cost, 0.0 + 4.0 + 40.0);
    }

    #[test]
    fn finalize_collapses_degenerate() {
        assert_eq!(finalize_centroids(vec![]), vec![0]);
        assert_eq!(finalize_centroids(vec![9, 3, 3, 9]), vec![3, 9]);
    }
}
