//! Frequency top-K bucket selector — the near-free [`BaseSelector`].
//!
//! One pass bins every sample by its high bits (bucket width matched to
//! the largest delta class, so a bucket's mean can cover its members),
//! then proposes the means of the K most populated buckets as bases. No
//! iteration, no distance computations — `O(n + B log B)` total.
//!
//! This is weak on smooth/continuous populations (it quantizes the value
//! space), but strong on pointer-heavy workloads (the paper's Java
//! group): heap references pile up in a handful of allocation regions, so
//! the occupancy histogram *is* the cluster structure.

use super::{
    degenerate_selection, finalize_centroids, selection_cost, BaseSelector, Selection,
    SelectorConfig,
};
use crate::gbdi::table::GlobalBaseTable;
use std::collections::BTreeMap;

/// Top-K occupancy-histogram selector (see module docs).
pub struct HistogramSelector;

impl BaseSelector for HistogramSelector {
    fn name(&self) -> &'static str {
        "histogram"
    }

    fn select(
        &mut self,
        samples: &[u64],
        _incumbent: Option<&GlobalBaseTable>,
        cfg: &SelectorConfig,
    ) -> crate::Result<Selection> {
        if samples.is_empty() {
            return Ok(degenerate_selection());
        }
        // Bucket width ~ the largest class's coverage (2^(w-1) either
        // side), so members of a full bucket fit a delta against its mean.
        let max_class = cfg.width_classes.last().copied().unwrap_or(cfg.word_size.bits());
        let shift = max_class.saturating_sub(1).clamp(4, cfg.word_size.bits() - 1);
        let mut buckets: BTreeMap<u64, (u64, u128)> = BTreeMap::new();
        for &v in samples {
            let e = buckets.entry(v >> shift).or_insert((0, 0));
            e.0 += 1;
            e.1 += v as u128;
        }
        // Most-populated first; ties break on the bucket key so the
        // proposal is deterministic.
        let mut ranked: Vec<(u64, u64, u128)> =
            buckets.into_iter().map(|(key, (n, sum))| (key, n, sum)).collect();
        ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let centroids: Vec<u64> = ranked
            .into_iter()
            .take(cfg.k.max(1))
            .map(|(_, n, sum)| (sum / n as u128) as u64)
            .collect();
        let centroids = finalize_centroids(centroids);
        let cost = selection_cost(samples, &centroids, cfg);
        Ok(Selection { centroids, cost, iters_run: 1, warm_started: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::apply_delta;
    use crate::util::prng::Rng;
    use crate::value::WordSize;

    #[test]
    fn finds_occupied_regions() {
        // two dense "allocation regions" plus scattered noise
        let mut rng = Rng::new(4);
        let mut samples = Vec::new();
        for _ in 0..1000 {
            samples.push(apply_delta(0x4000_0000, rng.range_i64(-500, 500), WordSize::W32));
            samples.push(apply_delta(0xC000_0000, rng.range_i64(-500, 500), WordSize::W32));
        }
        for _ in 0..50 {
            samples.push(rng.next_u32() as u64);
        }
        let cfg = SelectorConfig { k: 4, ..Default::default() };
        let s = HistogramSelector.select(&samples, None, &cfg).unwrap();
        let near = |target: u64| {
            s.centroids.iter().any(|&c| (c as i64 - target as i64).abs() < 1 << 20)
        };
        assert!(near(0x4000_0000), "centroids {:?}", s.centroids);
        assert!(near(0xC000_0000), "centroids {:?}", s.centroids);
        assert_eq!(s.iters_run, 1);
    }

    #[test]
    fn respects_k_budget() {
        let samples: Vec<u64> = (0..4096u64).map(|i| i * 1_000_003).collect();
        let cfg = SelectorConfig { k: 8, ..Default::default() };
        let s = HistogramSelector.select(&samples, None, &cfg).unwrap();
        assert!(s.centroids.len() <= 8, "{} centroids", s.centroids.len());
    }
}
