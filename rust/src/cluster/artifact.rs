//! The AOT JAX/Pallas k-means (executed through PJRT) as just another
//! [`BaseSelector`] — the analyzer no longer special-cases it.
//!
//! The artifact runs a fixed-shape f32 k-means (`crate::runtime`): the
//! selector shims arbitrary sample counts to the artifact's shape,
//! seeds the initial centroids (from the incumbent table when one is
//! serving — the warm start travels through the same seam as the native
//! selectors), executes the compiled HLO, and snaps the f32 centroids
//! back to exact word values (the f32→word precision hand-off,
//! DESIGN.md §5).
//!
//! Without the `pjrt` cargo feature (or without `artifacts/` built),
//! [`ArtifactRuntime`] construction or execution fails and callers fall
//! back to a native selector — see `gbdi serve --selector artifact`.

use super::{
    degenerate_selection, finalize_centroids, selection_cost, BaseSelector, Selection,
    SelectorConfig,
};
use crate::gbdi::table::GlobalBaseTable;
use crate::runtime::{shape_samples, ArtifactRuntime, KMEANS_KS};
use crate::util::prng::Rng;
use crate::value::WordSize;
use std::sync::Arc;

/// PJRT-artifact selector (see module docs).
pub struct ArtifactSelector {
    rt: Arc<ArtifactRuntime>,
}

impl ArtifactSelector {
    /// Selector over an already-started PJRT runtime.
    pub fn new(rt: Arc<ArtifactRuntime>) -> Self {
        ArtifactSelector { rt }
    }
}

impl BaseSelector for ArtifactSelector {
    fn name(&self) -> &'static str {
        "artifact(pjrt)"
    }

    fn select(
        &mut self,
        samples: &[u64],
        incumbent: Option<&GlobalBaseTable>,
        cfg: &SelectorConfig,
    ) -> crate::Result<Selection> {
        if samples.is_empty() {
            return Ok(degenerate_selection());
        }
        // fresh, seed-derived RNG per call: the trait promises
        // deterministic selections for a given (samples, incumbent, cfg)
        let mut rng = Rng::new(cfg.seed ^ 0xA27F_5EED);
        // choose the largest available artifact K that fits the budget
        let ak = *KMEANS_KS
            .iter()
            .filter(|&&a| a <= cfg.k.max(KMEANS_KS[0]))
            .max()
            .unwrap_or(&KMEANS_KS[0]);
        let warm = incumbent.is_some_and(|t| t.len() >= 2);
        // Warm start: seed from the incumbent's bases, skipping base 0 —
        // `GlobalBaseTable::new` pins a zero base into every table, so
        // zero stays covered downstream while a real high base is not
        // evicted from the K-capped seed list here.
        let mut init: Vec<f32> = match incumbent {
            Some(t) if t.len() >= 2 => t
                .entries()
                .iter()
                .map(|e| e.base)
                .filter(|&b| b != 0)
                .map(|b| b as f32)
                .take(ak)
                .collect(),
            _ => Vec::new(),
        };
        while init.len() < ak {
            init.push(samples[rng.below(samples.len() as u64) as usize] as f32);
        }
        let x = shape_samples(samples);
        let fit = self.rt.kmeans(&x, &init)?;
        let centroids: Vec<u64> = fit
            .centroids
            .iter()
            .zip(&fit.counts)
            .filter(|&(_, &n)| n > 0.0)
            .map(|(&c, _)| snap_word(c, cfg.word_size))
            .collect();
        let centroids = finalize_centroids(centroids);
        let cost = selection_cost(samples, &centroids, cfg);
        Ok(Selection { centroids, cost, iters_run: cfg.iters, warm_started: warm })
    }
}

/// Snap an f32 centroid back to an exact word value (clamped to the word
/// range) — the precision hand-off from the f32 analysis plane to the
/// exact codec (DESIGN.md §5).
pub fn snap_word(c: f32, ws: WordSize) -> u64 {
    let max = match ws {
        WordSize::W32 => u32::MAX as u64,
        WordSize::W64 => u64::MAX,
    };
    let c = c as f64;
    if c <= 0.0 {
        0
    } else if c >= max as f64 {
        max
    } else {
        c.round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snap_word_clamps() {
        assert_eq!(snap_word(-5.0, WordSize::W32), 0);
        assert_eq!(snap_word(5e12, WordSize::W32), u32::MAX as u64);
        assert_eq!(snap_word(1000.4, WordSize::W32), 1000);
        assert_eq!(snap_word(5e12, WordSize::W64), 5_000_000_000_000);
    }

    // Execution paths need built artifacts; they are covered by
    // rust/tests/runtime_artifacts.rs, which skips gracefully when
    // `artifacts/` is absent.
}
