//! Mini-batch k-means with incumbent warm start — the production
//! [`BaseSelector`].
//!
//! Instead of Lloyd's full assignment sweeps, each pass samples a small
//! batch, assigns it to the nearest center under the bit-cost metric, and
//! moves each center toward its batch members with a per-center learning
//! rate `1/n_j` (Sculley, WWW'10). Two things make it the cheap
//! continuous-adaptation arm the coordinator wants:
//!
//! * **Warm start.** When an incumbent [`GlobalBaseTable`] is serving,
//!   its bases seed the centers (with a count prior so the first batch
//!   refines rather than overwrites them). Steady traffic then converges
//!   in 2-3 passes instead of a full re-derivation; after a phase change
//!   the surviving bases still cover the unchanged part of the
//!   population.
//! * **Early stop.** A pass that improves the batch cost by less than
//!   `cfg.min_improvement` (relative) ends the run.
//!
//! Per pass the work is `batch_size * k` cost evaluations versus Lloyd's
//! `n * k` — with the defaults (batch 256, n 4096, 16 iterations) a full
//! run is roughly an order of magnitude cheaper even before early stop
//! (measured in `benches/kmeans_ablation.rs`).

use super::{
    apply_delta, degenerate_selection, finalize_centroids, outlier_bits, point_cost,
    selection_cost, wrapping_delta, BaseSelector, Metric, Selection, SelectorConfig,
};
use crate::gbdi::table::GlobalBaseTable;
use crate::util::prng::Rng;

/// Streaming mini-batch k-means selector (see module docs).
pub struct MiniBatchSelector;

impl BaseSelector for MiniBatchSelector {
    fn name(&self) -> &'static str {
        "minibatch"
    }

    fn select(
        &mut self,
        samples: &[u64],
        incumbent: Option<&GlobalBaseTable>,
        cfg: &SelectorConfig,
    ) -> crate::Result<Selection> {
        if samples.is_empty() {
            return Ok(degenerate_selection());
        }
        let ob = outlier_bits(cfg.word_size);
        let mut rng = Rng::new(cfg.seed ^ 0x4D42_4B4D); // domain-separate from lloyd
        let k = cfg.k.max(1);

        // Warm start from the incumbent's bases when it has real content
        // (more than just the pinned zero base); top up with random
        // samples if the table is smaller than K.
        let warm = incumbent.is_some_and(|t| t.len() >= 2);
        let mut centers: Vec<u64> = match incumbent {
            Some(t) if t.len() >= 2 => {
                // Skip base 0 when harvesting: `GlobalBaseTable::new`
                // pins a zero base into every table, so zero/small
                // immediates stay covered downstream, while harvesting it
                // here would evict a real high base at the K cap.
                let mut c: Vec<u64> =
                    t.entries().iter().map(|e| e.base).filter(|&b| b != 0).take(k).collect();
                while c.len() < k {
                    c.push(samples[rng.below(samples.len() as u64) as usize]);
                }
                c
            }
            _ => (0..k)
                .map(|_| samples[rng.below(samples.len() as u64) as usize])
                .collect(),
        };
        // A warm-started center behaves as if it had already absorbed a
        // full pass of points: the learning rate starts small, so the
        // first batch refines the incumbent instead of stomping on it.
        let prior: u64 = if warm { (samples.len() / k).max(1) as u64 } else { 0 };
        let mut counts = vec![prior; centers.len()];

        let batch = cfg.batch_size.max(16);
        let mut prev_cost = f64::INFINITY;
        let mut iters_run = 0usize;
        for _pass in 0..cfg.iters.max(1) {
            iters_run += 1;
            let mut batch_cost = 0.0;
            for _ in 0..batch {
                let v = samples[rng.below(samples.len() as u64) as usize];
                let mut best = 0usize;
                let mut best_cost = f64::INFINITY;
                let mut best_abs = i64::MAX;
                for (j, &c) in centers.iter().enumerate() {
                    let cst =
                        point_cost(v, c, cfg.metric, &cfg.width_classes, cfg.word_size, ob);
                    let abs = wrapping_delta(v, c, cfg.word_size).unsigned_abs() as i64;
                    if cst < best_cost || (cst == best_cost && abs < best_abs) {
                        best_cost = cst;
                        best_abs = abs;
                        best = j;
                    }
                }
                batch_cost += best_cost;
                // A point no center can encode marks a population shift
                // the 1/n learning rate is too slow to follow: teleport
                // the least-used center onto it (the mini-batch analog of
                // Lloyd's empty-cluster reseeding) so a warm start still
                // adapts to brand-new clusters within one pass.
                if cfg.metric == Metric::BitCost && best_cost >= ob as f64 {
                    let victim = counts
                        .iter()
                        .enumerate()
                        .min_by_key(|&(_, &n)| n)
                        .map(|(j, _)| j)
                        .unwrap_or(best);
                    centers[victim] = v;
                    counts[victim] = 1;
                    continue;
                }
                counts[best] += 1;
                let eta = 1.0 / counts[best] as f64;
                let d = wrapping_delta(v, centers[best], cfg.word_size);
                let step = (d as f64 * eta).round() as i64;
                centers[best] = apply_delta(centers[best], step, cfg.word_size);
            }
            if prev_cost.is_finite() {
                let improvement = (prev_cost - batch_cost) / prev_cost.max(1e-9);
                if improvement < cfg.min_improvement {
                    break;
                }
            }
            prev_cost = batch_cost;
        }

        let centroids = finalize_centroids(centers);
        let cost = selection_cost(samples, &centroids, cfg);
        Ok(Selection { centroids, cost, iters_run, warm_started: warm })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{LloydSelector, Metric};
    use crate::gbdi::GbdiConfig;
    use crate::value::WordSize;

    fn mixture(centers: &[u64], per: usize, spread: i64, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &c in centers {
            for _ in 0..per {
                out.push(apply_delta(c, rng.range_i64(-spread, spread), WordSize::W32));
            }
        }
        out
    }

    fn cfg(k: usize) -> SelectorConfig {
        SelectorConfig { k, seed: 11, ..Default::default() }
    }

    #[test]
    fn cold_start_quality_is_close_to_lloyd() {
        let samples = mixture(&[40_000, 9_000_000, 3_000_000_000], 800, 60, 3);
        let c = cfg(16);
        let mb = MiniBatchSelector.select(&samples, None, &c).unwrap();
        let ll = LloydSelector.select(&samples, None, &c).unwrap();
        // compare under the same scorer (lloyd's .cost is its own
        // inertia). Cold mini-batch may trail full Lloyd on raw bit
        // inertia (fewer sub-cluster splits); it must stay in the same
        // ballpark, and far below the no-clustering outlier cost.
        let ll_cost = selection_cost(&samples, &ll.centroids, &c);
        assert!(
            mb.cost <= ll_cost * 1.6 + 1.0,
            "minibatch {} vs lloyd {}",
            mb.cost,
            ll_cost
        );
        assert!(
            mb.cost < samples.len() as f64 * 20.0,
            "minibatch cost {} should be far below outlier cost",
            mb.cost
        );
    }

    #[test]
    fn warm_start_uses_incumbent_and_stops_early() {
        let samples = mixture(&[70_000, 2_000_000_000], 800, 40, 5);
        let c = cfg(8);
        // incumbent: a table built from the same population's selection
        let cold = MiniBatchSelector.select(&samples, None, &c).unwrap();
        let gcfg = GbdiConfig { num_bases: 9, ..Default::default() };
        let table = GlobalBaseTable::fit_from_centroids(&samples, &cold.centroids, &gcfg, 1);
        let warm = MiniBatchSelector.select(&samples, Some(&table), &c).unwrap();
        assert!(warm.warm_started);
        assert!(!cold.warm_started);
        // steady traffic: the warm pass converges in a few passes and the
        // quality stays in the same ballpark
        assert!(
            warm.iters_run <= c.iters,
            "warm ran {} of {} passes",
            warm.iters_run,
            c.iters
        );
        assert!(
            warm.cost <= cold.cost * 1.15 + 1.0,
            "warm {} vs cold {}",
            warm.cost,
            cold.cost
        );
    }

    #[test]
    fn trivial_incumbent_is_not_a_warm_start() {
        let samples = mixture(&[1_000_000], 200, 20, 7);
        let trivial = GlobalBaseTable::new(vec![(0, 8)], WordSize::W32, 0);
        let s = MiniBatchSelector.select(&samples, Some(&trivial), &cfg(4)).unwrap();
        assert!(!s.warm_started, "zero-base-only table carries no information");
    }

    #[test]
    fn adapts_after_phase_change() {
        // incumbent fitted on phase A; traffic is now phase B
        let phase_a = mixture(&[50_000], 600, 30, 1);
        let phase_b = mixture(&[50_000, 3_000_000_000], 600, 30, 2);
        let c = SelectorConfig { metric: Metric::BitCost, ..cfg(8) };
        let a_sel = LloydSelector.select(&phase_a, None, &c).unwrap();
        let gcfg = GbdiConfig { num_bases: 9, ..Default::default() };
        let table = GlobalBaseTable::fit_from_centroids(&phase_a, &a_sel.centroids, &gcfg, 1);
        let stale_cost = selection_cost(&phase_b, &a_sel.centroids, &c);
        let warm = MiniBatchSelector.select(&phase_b, Some(&table), &c).unwrap();
        assert!(
            warm.cost < stale_cost * 0.7,
            "warm restart must adapt: {} vs stale {}",
            warm.cost,
            stale_cost
        );
    }
}
