//! The `GBN1` client side: a blocking pipelined [`Client`] and the
//! trace-driven multi-connection load generator ([`run_loadgen`])
//! behind `gbdi client --op load` and `cargo bench --bench serving`.
//!
//! Pipelining model: responses on a `GBN1` connection arrive strictly
//! in request order, so the client keeps a FIFO of outstanding request
//! ids ([`Client::send`] / [`Client::recv`]) and the load generator
//! measures client-observed latency as *send-to-receive* time per op —
//! queueing delay under a deep pipeline is charged to the op, which is
//! what a tail-latency claim must include.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use super::protocol::{self, Reply, Request, Response, StatsReply, Status};
use crate::util::prng::Rng;
use crate::workloads;
use crate::{Error, Result};

/// How many `RetryAfter` rounds [`Client::put_pages`] tolerates before
/// giving up — generous because each round sleeps the server-suggested
/// back-off.
const MAX_PUT_RETRIES: usize = 1000;

/// A blocking, pipelineable `GBN1` connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_req_id: u64,
    inflight: VecDeque<u64>,
    max_frame_bytes: usize,
    block_bytes: usize,
}

impl Client {
    /// Connect, send the magic, and parse the server hello.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let rstream = stream.try_clone()?;
        let mut writer = BufWriter::new(stream);
        writer.write_all(&protocol::MAGIC)?;
        writer.flush()?;
        let mut reader = BufReader::new(rstream);
        let mut hello = [0u8; 8];
        reader.read_exact(&mut hello)?;
        let (_version, block_bytes) = protocol::parse_server_hello(&hello).map_err(Error::Corrupt)?;
        Ok(Client {
            reader,
            writer,
            next_req_id: 1,
            inflight: VecDeque::new(),
            max_frame_bytes: protocol::DEFAULT_MAX_FRAME_BYTES,
            block_bytes: block_bytes as usize,
        })
    }

    /// The server's block size from the hello.
    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    /// Requests sent but not yet answered.
    pub fn outstanding(&self) -> usize {
        self.inflight.len()
    }

    /// Pipelined send: frame the request into the write buffer and
    /// record its id. The bytes may sit in the buffer until the next
    /// [`Self::recv`] (which always flushes first) or an explicit flush.
    pub fn send(&mut self, req: &Request) -> Result<u64> {
        let id = self.next_req_id;
        self.next_req_id += 1;
        protocol::write_frame(&mut self.writer, &protocol::encode_request(id, req))?;
        self.inflight.push_back(id);
        Ok(id)
    }

    /// Receive the oldest outstanding response (responses are FIFO per
    /// connection). Flushes buffered requests first so a recv can never
    /// deadlock against our own write buffer.
    pub fn recv(&mut self) -> Result<Response> {
        self.writer.flush()?;
        let payload = protocol::read_frame(&mut self.reader, self.max_frame_bytes)?
            .ok_or_else(|| Error::Corrupt("server closed the connection".into()))?;
        let resp = protocol::decode_response(&payload).map_err(Error::Corrupt)?;
        match self.inflight.pop_front() {
            Some(expected) if expected == resp.req_id => Ok(resp),
            Some(expected) => Err(Error::Corrupt(format!(
                "out-of-order response: expected req {expected}, got {}",
                resp.req_id
            ))),
            None => Err(Error::Corrupt("response with no request in flight".into())),
        }
    }

    /// Synchronous round trip; requires an empty pipeline.
    pub fn request(&mut self, req: &Request) -> Result<Response> {
        if !self.inflight.is_empty() {
            return Err(Error::Config(
                "Client::request needs an empty pipeline; drain with recv() first".into(),
            ));
        }
        self.send(req)?;
        self.recv()
    }

    /// Batch-PUT pages, sleeping out `RetryAfter` shed responses with
    /// the server-suggested back-off. Returns pages accepted.
    pub fn put_pages(&mut self, pages: &[(u64, Vec<u8>)]) -> Result<u32> {
        let req = Request::PutPages(pages.to_vec());
        for _ in 0..MAX_PUT_RETRIES {
            match self.request(&req)?.body {
                Reply::PutPages { accepted } => return Ok(accepted),
                Reply::Error { status: Status::RetryAfter, retry_ms, .. } => {
                    thread::sleep(Duration::from_millis(u64::from(retry_ms.max(1))));
                }
                other => return Err(unexpected("PutPages", &other)),
            }
        }
        Err(Error::Corrupt("PutPages shed by admission control on every retry".into()))
    }

    /// Read one block.
    pub fn get_block(&mut self, page_id: u64, block: u32) -> Result<Vec<u8>> {
        match self.request(&Request::GetBlock { page_id, block })?.body {
            Reply::Block { data } => Ok(data),
            other => Err(unexpected("GetBlock", &other)),
        }
    }

    /// Write one block.
    pub fn put_block(&mut self, page_id: u64, block: u32, data: Vec<u8>) -> Result<()> {
        match self.request(&Request::PutBlock { page_id, block, data })?.body {
            Reply::PutBlock => Ok(()),
            other => Err(unexpected("PutBlock", &other)),
        }
    }

    /// Read `count` consecutive blocks starting at `first`.
    pub fn read_range(&mut self, page_id: u64, first: u32, count: u32) -> Result<Vec<u8>> {
        match self.request(&Request::ReadRange { page_id, first, count })?.body {
            Reply::Range { data } => Ok(data),
            other => Err(unexpected("ReadRange", &other)),
        }
    }

    /// Drain the server's ingest queue and flush deferred dirty cache
    /// blocks; returns how many dirty blocks were written back.
    pub fn flush(&mut self) -> Result<u64> {
        match self.request(&Request::Flush)?.body {
            Reply::Flushed { blocks } => Ok(blocks),
            other => Err(unexpected("Flush", &other)),
        }
    }

    /// Snapshot the server's STATS field vector.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.request(&Request::Stats)?.body {
            Reply::Stats(stats) => Ok(stats),
            other => Err(unexpected("Stats", &other)),
        }
    }

    /// Force a background analysis round; returns the codec version at
    /// acknowledge time (poll [`Self::stats`] to observe the swap).
    pub fn reanalyze(&mut self) -> Result<u64> {
        match self.request(&Request::Reanalyze)?.body {
            Reply::Version { version } => Ok(version),
            other => Err(unexpected("Reanalyze", &other)),
        }
    }

    /// Ask the server to begin graceful shutdown.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)?.body {
            Reply::ShutdownAck => Ok(()),
            other => Err(unexpected("Shutdown", &other)),
        }
    }
}

fn unexpected(what: &str, reply: &Reply) -> Error {
    match reply {
        Reply::Error { status, message, .. } => {
            Error::Corrupt(format!("{what}: server answered {status:?}: {message}"))
        }
        other => Error::Corrupt(format!("{what}: mismatched reply {other:?}")),
    }
}

/// Load-generator shape: a deterministic per-connection op trace driven
/// through a pipelined [`Client`].
#[derive(Debug, Clone, PartialEq)]
pub struct LoadGenConfig {
    /// Server address.
    pub addr: String,
    /// Concurrent connections, one OS thread each.
    pub conns: usize,
    /// Trace length per connection.
    pub ops_per_conn: usize,
    /// Pipeline window: requests in flight per connection.
    pub pipeline: usize,
    /// Page-id address space the trace reads/writes (must be
    /// preloaded; see `preload`).
    pub pages: u64,
    /// Logical page size for generated pages.
    pub page_bytes: usize,
    /// Fraction of trace ops that are single-block GETs; the rest are
    /// single-block PUTs (before batch/ingest mix-ins).
    pub read_fraction: f64,
    /// Every N ops, substitute an 8-block batched GET (0 = never).
    pub batch_read_every: usize,
    /// Every N ops, substitute a 4-page ingest batch with fresh page
    /// ids (0 = never) — keeps the analyzer's sample reservoir fed so
    /// codec-table swaps happen under live load.
    pub put_pages_every: usize,
    /// Zipf skew for page choice (0 = uniform).
    pub zipf_s: f64,
    /// Trace seed; each connection forks a distinct stream.
    pub seed: u64,
    /// Workload generating page/block payloads (`workloads::by_name`).
    pub workload: String,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            addr: "127.0.0.1:7070".to_string(),
            conns: 4,
            ops_per_conn: 5000,
            pipeline: 32,
            pages: 64,
            page_bytes: 4096,
            read_fraction: 0.8,
            batch_read_every: 16,
            put_pages_every: 32,
            zipf_s: 0.0,
            seed: 7,
            workload: "mcf".to_string(),
        }
    }
}

/// Client-side tallies from one load-generator run (or one
/// connection's share before [`LoadGenReport::merge`]).
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    /// OK responses received, all op kinds.
    pub ops_ok: u64,
    /// `RetryAfter` responses (admission sheds).
    pub sheds: u64,
    /// Other non-OK responses.
    pub ops_err: u64,
    /// OK single-block GETs.
    pub reads: u64,
    /// Blocks returned by OK batched GETs (found slots).
    pub batch_read_blocks: u64,
    /// OK batched-GET responses.
    pub batch_reads: u64,
    /// OK single-block PUTs.
    pub writes: u64,
    /// Pages accepted by OK ingest batches.
    pub pages_put: u64,
    /// OK ingest-batch responses.
    pub put_batches: u64,
    /// Wall time of the slowest connection, seconds.
    pub wall_s: f64,
    /// Per-op send-to-receive latency, nanoseconds (unsorted).
    pub lat_ns: Vec<u64>,
}

impl LoadGenReport {
    /// Completed ops (OK + shed + errored).
    pub fn total_ops(&self) -> u64 {
        self.ops_ok + self.sheds + self.ops_err
    }

    /// Completed ops per second over the slowest connection's wall time.
    pub fn ops_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.total_ops() as f64 / self.wall_s
        }
    }

    /// Fold another connection's tallies into this one.
    pub fn merge(&mut self, other: LoadGenReport) {
        self.ops_ok += other.ops_ok;
        self.sheds += other.sheds;
        self.ops_err += other.ops_err;
        self.reads += other.reads;
        self.batch_read_blocks += other.batch_read_blocks;
        self.batch_reads += other.batch_reads;
        self.writes += other.writes;
        self.pages_put += other.pages_put;
        self.put_batches += other.put_batches;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.lat_ns.extend(other.lat_ns);
    }
}

/// Latency percentile over an **ascending-sorted** slice (nearest-rank;
/// 0 for an empty slice).
pub fn percentile(sorted_ns: &[u64], q: f64) -> u64 {
    if sorted_ns.is_empty() {
        return 0;
    }
    let rank = (q * (sorted_ns.len() - 1) as f64).round() as usize;
    sorted_ns[rank.min(sorted_ns.len() - 1)]
}

/// Generate `pages` with ids `first_id..first_id + pages` from the
/// configured workload, deterministic in `seed`.
pub fn gen_pages(
    workload: &dyn workloads::Workload,
    first_id: u64,
    pages: u64,
    page_bytes: usize,
    seed: u64,
) -> Vec<(u64, Vec<u8>)> {
    (first_id..first_id + pages)
        .map(|id| (id, workload.generate(page_bytes, seed ^ id.wrapping_mul(0x9E37_79B9))))
        .collect()
}

/// Preload the trace's page address space over one connection in
/// batches, respecting admission back-off. Returns pages accepted.
pub fn preload(cfg: &LoadGenConfig) -> Result<u64> {
    let workload = workload_for(cfg)?;
    let mut client = Client::connect(&cfg.addr)?;
    let mut total = 0u64;
    let mut id = 0u64;
    while id < cfg.pages {
        let n = (cfg.pages - id).min(32);
        let batch = gen_pages(workload.as_ref(), id, n, cfg.page_bytes, cfg.seed);
        total += u64::from(client.put_pages(&batch)?);
        id += n;
    }
    client.flush()?;
    Ok(total)
}

fn workload_for(cfg: &LoadGenConfig) -> Result<Box<dyn workloads::Workload>> {
    workloads::by_name(&cfg.workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {:?}", cfg.workload)))
}

enum TraceOp {
    Get { page: u64, block: u32 },
    BatchGet(Vec<(u64, u32)>),
    Put { page: u64, block: u32, data: Vec<u8> },
    PutPages(Vec<(u64, Vec<u8>)>),
}

fn pick_page(rng: &mut Rng, cfg: &LoadGenConfig) -> u64 {
    if cfg.zipf_s > 0.0 {
        rng.zipf(cfg.pages.max(1), cfg.zipf_s) % cfg.pages.max(1)
    } else {
        rng.below(cfg.pages.max(1))
    }
}

/// Build one connection's deterministic trace. Fresh ingest page ids
/// live above the preloaded range and are unique per connection, so
/// concurrent traces never write the same new page.
fn build_trace(
    cfg: &LoadGenConfig,
    workload: &dyn workloads::Workload,
    conn: usize,
    blocks_per_page: u64,
    pool: &[u8],
    block_bytes: usize,
) -> Vec<TraceOp> {
    let mut rng = Rng::new(cfg.seed ^ (conn as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut fresh_id = cfg.pages + (conn as u64) * (cfg.ops_per_conn as u64) * 4;
    let mut trace = Vec::with_capacity(cfg.ops_per_conn);
    for i in 1..=cfg.ops_per_conn {
        if cfg.put_pages_every != 0 && i % cfg.put_pages_every == 0 {
            let batch = gen_pages(workload, fresh_id, 4, cfg.page_bytes, cfg.seed ^ i as u64);
            fresh_id += 4;
            trace.push(TraceOp::PutPages(batch));
        } else if cfg.batch_read_every != 0 && i % cfg.batch_read_every == 0 {
            let items = (0..8)
                .map(|_| (pick_page(&mut rng, cfg), rng.below(blocks_per_page) as u32))
                .collect();
            trace.push(TraceOp::BatchGet(items));
        } else if rng.f64() < cfg.read_fraction {
            trace.push(TraceOp::Get {
                page: pick_page(&mut rng, cfg),
                block: rng.below(blocks_per_page) as u32,
            });
        } else {
            let at = rng.below((pool.len() - block_bytes + 1) as u64) as usize;
            trace.push(TraceOp::Put {
                page: pick_page(&mut rng, cfg),
                block: rng.below(blocks_per_page) as u32,
                data: pool[at..at + block_bytes].to_vec(),
            });
        }
    }
    trace
}

fn drain_one(
    client: &mut Client,
    pending: &mut VecDeque<Instant>,
    report: &mut LoadGenReport,
) -> Result<()> {
    let resp = client.recv()?;
    let sent = pending.pop_front().ok_or_else(|| {
        Error::Corrupt("load generator received a response with nothing pending".into())
    })?;
    report.lat_ns.push(sent.elapsed().as_nanos() as u64);
    match resp.body {
        Reply::Block { .. } => {
            report.reads += 1;
            report.ops_ok += 1;
        }
        Reply::Blocks { items } => {
            report.batch_read_blocks += items.iter().flatten().count() as u64;
            report.batch_reads += 1;
            report.ops_ok += 1;
        }
        Reply::PutBlock => {
            report.writes += 1;
            report.ops_ok += 1;
        }
        Reply::PutPages { accepted } => {
            report.pages_put += u64::from(accepted);
            report.put_batches += 1;
            report.ops_ok += 1;
        }
        Reply::Error { status: Status::RetryAfter, .. } => report.sheds += 1,
        Reply::Error { .. } => report.ops_err += 1,
        _ => report.ops_ok += 1,
    }
    Ok(())
}

fn run_conn(cfg: &LoadGenConfig, conn: usize) -> Result<LoadGenReport> {
    let workload = workload_for(cfg)?;
    let mut client = Client::connect(&cfg.addr)?;
    let block_bytes = client.block_bytes().max(1);
    let blocks_per_page = (cfg.page_bytes / block_bytes).max(1) as u64;
    let pool = workload.generate(cfg.page_bytes.max(block_bytes) * 4, cfg.seed ^ 0xB10C);
    let trace = build_trace(cfg, workload.as_ref(), conn, blocks_per_page, &pool, block_bytes);

    let mut report = LoadGenReport::default();
    let mut pending: VecDeque<Instant> = VecDeque::with_capacity(cfg.pipeline.max(1));
    let t0 = Instant::now();
    for op in &trace {
        while pending.len() >= cfg.pipeline.max(1) {
            drain_one(&mut client, &mut pending, &mut report)?;
        }
        let req = match op {
            TraceOp::Get { page, block } => Request::GetBlock { page_id: *page, block: *block },
            TraceOp::BatchGet(items) => Request::GetBlocks(items.clone()),
            TraceOp::Put { page, block, data } => {
                Request::PutBlock { page_id: *page, block: *block, data: data.clone() }
            }
            TraceOp::PutPages(batch) => Request::PutPages(batch.clone()),
        };
        client.send(&req)?;
        pending.push_back(Instant::now());
    }
    while !pending.is_empty() {
        drain_one(&mut client, &mut pending, &mut report)?;
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Run the multi-connection load generator against a live server and
/// return the merged client-side tallies. Pages `0..cfg.pages` must
/// already exist (use [`preload`]).
pub fn run_loadgen(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    let results: Vec<Result<LoadGenReport>> = thread::scope(|s| {
        let handles: Vec<_> =
            (0..cfg.conns.max(1)).map(|conn| s.spawn(move || run_conn(cfg, conn))).collect();
        handles.into_iter().map(|h| h.join().expect("loadgen thread panicked")).collect()
    });
    let mut merged = LoadGenReport::default();
    for r in results {
        merged.merge(r?);
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 0.0), 1);
        assert_eq!(percentile(&xs, 0.5), 51);
        assert_eq!(percentile(&xs, 0.99), 99);
        assert_eq!(percentile(&xs, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.999), 42);
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = LoadGenReport {
            ops_ok: 10,
            sheds: 1,
            wall_s: 0.5,
            lat_ns: vec![1, 2],
            ..Default::default()
        };
        let b = LoadGenReport {
            ops_ok: 5,
            ops_err: 2,
            wall_s: 1.5,
            lat_ns: vec![3],
            ..Default::default()
        };
        a.merge(b);
        assert_eq!(a.ops_ok, 15);
        assert_eq!(a.sheds, 1);
        assert_eq!(a.ops_err, 2);
        assert_eq!(a.total_ops(), 18);
        assert_eq!(a.wall_s, 1.5);
        assert_eq!(a.lat_ns, vec![1, 2, 3]);
        assert!((a.ops_per_s() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn traces_are_deterministic_and_mixed() {
        let cfg = LoadGenConfig { ops_per_conn: 200, ..Default::default() };
        let workload = workload_for(&cfg).unwrap();
        let pool = workload.generate(4096 * 4, 1);
        let t1 = build_trace(&cfg, workload.as_ref(), 0, 64, &pool, 64);
        let t2 = build_trace(&cfg, workload.as_ref(), 0, 64, &pool, 64);
        assert_eq!(t1.len(), 200);
        let kind = |t: &TraceOp| match t {
            TraceOp::Get { .. } => 0,
            TraceOp::BatchGet(_) => 1,
            TraceOp::Put { .. } => 2,
            TraceOp::PutPages(_) => 3,
        };
        let k1: Vec<u8> = t1.iter().map(kind).collect();
        let k2: Vec<u8> = t2.iter().map(kind).collect();
        assert_eq!(k1, k2, "same seed, same trace");
        for want in 0..4u8 {
            assert!(k1.contains(&want), "trace never emitted op kind {want}");
        }
        // Distinct connections see distinct traces.
        let t3 = build_trace(&cfg, workload.as_ref(), 1, 64, &pool, 64);
        let k3: Vec<u8> = t3.iter().map(kind).collect();
        assert!(k1 != k3 || format!("{:?}", trace_pages(&t1)) != format!("{:?}", trace_pages(&t3)));
    }

    fn trace_pages(trace: &[TraceOp]) -> Vec<u64> {
        trace
            .iter()
            .map(|t| match t {
                TraceOp::Get { page, .. } | TraceOp::Put { page, .. } => *page,
                TraceOp::BatchGet(items) => items.first().map(|(p, _)| *p).unwrap_or(0),
                TraceOp::PutPages(batch) => batch.first().map(|(p, _)| *p).unwrap_or(0),
            })
            .collect()
    }
}
