//! The `GBN1` client side: a blocking pipelined [`Client`] and the
//! trace-driven multi-connection load generator ([`run_loadgen`])
//! behind `gbdi client --op load` and `cargo bench --bench serving`.
//!
//! Pipelining model: responses on a `GBN1` connection arrive strictly
//! in request order, so the client keeps a FIFO of outstanding request
//! ids ([`Client::send`] / [`Client::recv`]) and the load generator
//! measures client-observed latency as *send-to-receive* time per op —
//! queueing delay under a deep pipeline is charged to the op, which is
//! what a tail-latency claim must include.
//!
//! Resilience model: every failure the transport can produce — a
//! mid-frame disconnect, a stalled socket (bounded by the per-op
//! deadline in [`ClientConfig`]), or a desynchronized stream after
//! corruption — is retried with capped exponential back-off plus
//! jitter ([`Backoff`]) and a fresh connection, then the in-doubt
//! request is replayed. Replay is safe for every `GBN1` op the client
//! issues: reads and STATS are naturally idempotent, and both PUT
//! shapes (`PutBlock`, `PutPages`) carry *absolute* content — a
//! replayed PUT that was already applied overwrites the page with the
//! identical bytes, so double-apply cannot corrupt state (the only
//! observable effect is a possibly repeated accept count, which the
//! load generator tallies as a retry, not as new work).

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use super::protocol::{self, Reply, Request, Response, StatsReply, Status};
use crate::util::prng::Rng;
use crate::workloads;
use crate::{Error, Result};

/// How many `RetryAfter` rounds [`Client::put_pages`] tolerates before
/// giving up — generous because admission sheds are load, not failure,
/// and each round sleeps at least the server-suggested back-off.
const MAX_PUT_RETRIES: usize = 1000;

/// Capped exponential back-off schedule shared by every retry loop in
/// the client (transport reconnects, admission sheds, the load
/// generator's reconnect path).
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Transport-failure attempts before giving up. Admission sheds do
    /// **not** consume attempts — they follow the delay schedule only.
    pub max_attempts: u32,
    /// First delay, milliseconds.
    pub base_ms: u64,
    /// Delay ceiling, milliseconds; the exponential curve saturates
    /// here instead of growing without bound.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 8, base_ms: 10, cap_ms: 2_000 }
    }
}

/// Stateful back-off iterator: delay doubles from `base_ms` up to
/// `cap_ms`, and each sleep is jittered uniformly into the upper half
/// of the window (`[d/2, d]`) so a fleet of clients kicked loose by
/// the same fault does not reconnect in lockstep. Deterministic in its
/// seed, which is what lets the chaos tests replay a schedule exactly.
#[derive(Debug)]
pub struct Backoff {
    policy: RetryPolicy,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(policy: RetryPolicy, seed: u64) -> Backoff {
        Backoff { policy, attempt: 0, rng: Rng::new(seed) }
    }

    /// Attempts consumed since the last [`Self::reset`].
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Whether the transport-failure budget is spent. The delay
    /// schedule keeps working past this point (saturated at the cap)
    /// for callers like shed loops that bound rounds differently.
    pub fn exhausted(&self) -> bool {
        self.attempt >= self.policy.max_attempts
    }

    /// A successful operation ends the incident: restart the curve.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Next jittered delay; advances the attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        let exp = self
            .policy
            .base_ms
            .max(1)
            .saturating_mul(1u64.checked_shl(self.attempt).unwrap_or(u64::MAX))
            .min(self.policy.cap_ms.max(1));
        self.attempt = self.attempt.saturating_add(1);
        let half = exp / 2;
        Duration::from_millis(half + self.rng.below(exp - half + 1))
    }

    /// Next delay, floored at a server-suggested hint (RETRY_AFTER):
    /// never retry sooner than the server asked, but still grow and
    /// jitter so persistent sheds spread out instead of metronoming.
    pub fn next_delay_at_least(&mut self, floor_ms: u64) -> Duration {
        self.next_delay().max(Duration::from_millis(floor_ms))
    }
}

/// Connection-level knobs for [`Client`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClientConfig {
    /// Per-op deadline, milliseconds, enforced as the socket read and
    /// write timeout: a recv that exceeds it fails with a timeout
    /// `Error::Io` instead of hanging forever on a stalled server or a
    /// chaos-injected half-open connection. 0 disables (PR 9 behavior).
    pub op_timeout_ms: u64,
    /// Back-off schedule for transport retries and admission sheds.
    pub retry: RetryPolicy,
    /// Jitter seed; distinct clients should use distinct seeds.
    pub backoff_seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig { op_timeout_ms: 30_000, retry: RetryPolicy::default(), backoff_seed: 0x0BAC_0FF5 }
    }
}

/// A blocking, pipelineable `GBN1` connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    addr: String,
    config: ClientConfig,
    backoff: Backoff,
    next_req_id: u64,
    inflight: VecDeque<u64>,
    max_frame_bytes: usize,
    block_bytes: usize,
}

/// Dial + handshake, honoring the per-op deadline on both socket
/// directions. Returns the buffered halves and the server's block size.
fn dial(addr: &str, cfg: &ClientConfig) -> Result<(BufReader<TcpStream>, BufWriter<TcpStream>, usize)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let timeout = (cfg.op_timeout_ms > 0).then(|| Duration::from_millis(cfg.op_timeout_ms));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let rstream = stream.try_clone()?;
    let mut writer = BufWriter::new(stream);
    writer.write_all(&protocol::MAGIC)?;
    writer.flush()?;
    let mut reader = BufReader::new(rstream);
    let mut hello = [0u8; 8];
    reader.read_exact(&mut hello)?;
    let (_version, block_bytes) = protocol::parse_server_hello(&hello).map_err(Error::Corrupt)?;
    Ok((reader, writer, block_bytes as usize))
}

/// Whether an error means "the connection is dead or desynchronized" —
/// the class a reconnect can fix. I/O errors (including per-op deadline
/// timeouts) and stream corruption qualify; server-reported statuses,
/// config errors, and data loss do not.
fn is_transport(e: &Error) -> bool {
    matches!(e, Error::Io(_) | Error::Corrupt(_))
}

impl Client {
    /// Connect with default [`ClientConfig`].
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with(addr, ClientConfig::default())
    }

    /// Connect, send the magic, and parse the server hello.
    pub fn connect_with(addr: &str, config: ClientConfig) -> Result<Client> {
        let (reader, writer, block_bytes) = dial(addr, &config)?;
        let backoff = Backoff::new(config.retry.clone(), config.backoff_seed);
        Ok(Client {
            reader,
            writer,
            addr: addr.to_string(),
            config,
            backoff,
            next_req_id: 1,
            inflight: VecDeque::new(),
            max_frame_bytes: protocol::DEFAULT_MAX_FRAME_BYTES,
            block_bytes,
        })
    }

    /// Re-dial the same address and drop all in-flight state: any
    /// response the old connection owed us is gone. Callers replay what
    /// they still need (safe for every op — see the module doc).
    pub fn reconnect(&mut self) -> Result<()> {
        let (reader, writer, block_bytes) = dial(&self.addr, &self.config)?;
        self.reader = reader;
        self.writer = writer;
        self.block_bytes = block_bytes;
        self.inflight.clear();
        Ok(())
    }

    /// The server's block size from the hello.
    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    /// Requests sent but not yet answered.
    pub fn outstanding(&self) -> usize {
        self.inflight.len()
    }

    /// Pipelined send: frame the request into the write buffer and
    /// record its id. The bytes may sit in the buffer until the next
    /// [`Self::recv`] (which always flushes first) or an explicit flush.
    pub fn send(&mut self, req: &Request) -> Result<u64> {
        let id = self.next_req_id;
        self.next_req_id += 1;
        protocol::write_frame(&mut self.writer, &protocol::encode_request(id, req))?;
        self.inflight.push_back(id);
        Ok(id)
    }

    /// Receive the oldest outstanding response (responses are FIFO per
    /// connection). Flushes buffered requests first so a recv can never
    /// deadlock against our own write buffer.
    pub fn recv(&mut self) -> Result<Response> {
        self.writer.flush()?;
        let payload = protocol::read_frame(&mut self.reader, self.max_frame_bytes)?
            .ok_or_else(|| Error::Corrupt("server closed the connection".into()))?;
        let resp = protocol::decode_response(&payload).map_err(Error::Corrupt)?;
        match self.inflight.pop_front() {
            Some(expected) if expected == resp.req_id => Ok(resp),
            Some(expected) => Err(Error::Corrupt(format!(
                "out-of-order response: expected req {expected}, got {}",
                resp.req_id
            ))),
            None => Err(Error::Corrupt("response with no request in flight".into())),
        }
    }

    /// Synchronous round trip; requires an empty pipeline.
    pub fn request(&mut self, req: &Request) -> Result<Response> {
        if !self.inflight.is_empty() {
            return Err(Error::Config(
                "Client::request needs an empty pipeline; drain with recv() first".into(),
            ));
        }
        self.send(req)?;
        self.recv()
    }

    /// Synchronous round trip with reconnect-and-replay: transport
    /// failures (disconnect, deadline timeout, desynchronized stream)
    /// sleep the shared back-off, re-dial, and re-issue the request,
    /// up to the policy's attempt budget. Only called for ops where
    /// replay is safe (see the module doc: reads are idempotent, PUTs
    /// carry absolute content so double-apply is a no-op).
    fn request_replayed(&mut self, req: &Request) -> Result<Response> {
        loop {
            match self.request(req) {
                Ok(resp) => {
                    self.backoff.reset();
                    return Ok(resp);
                }
                Err(e) if is_transport(&e) && !self.backoff.exhausted() => {
                    thread::sleep(self.backoff.next_delay());
                    // A failed re-dial consumes attempts too; the loop
                    // retries the dial until the budget runs out.
                    if let Err(redial) = self.reconnect() {
                        if !is_transport(&redial) || self.backoff.exhausted() {
                            return Err(redial);
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Batch-PUT pages, riding out `RetryAfter` shed responses with
    /// capped exponential back-off + jitter floored at the
    /// server-suggested delay. Transport failures reconnect and replay
    /// (a page PUT is an absolute overwrite, so a replay that lands
    /// twice writes the same bytes twice — no double-apply hazard).
    /// Returns pages accepted.
    pub fn put_pages(&mut self, pages: &[(u64, Vec<u8>)]) -> Result<u32> {
        let req = Request::PutPages(pages.to_vec());
        let mut shed =
            Backoff::new(self.config.retry.clone(), self.config.backoff_seed ^ 0x5EED_0F_5EED);
        for _ in 0..MAX_PUT_RETRIES {
            match self.request_replayed(&req)?.body {
                Reply::PutPages { accepted } => return Ok(accepted),
                Reply::Error { status: Status::RetryAfter, retry_ms, .. } => {
                    thread::sleep(shed.next_delay_at_least(u64::from(retry_ms.max(1))));
                }
                other => return Err(unexpected("PutPages", &other)),
            }
        }
        Err(Error::Corrupt("PutPages shed by admission control on every retry".into()))
    }

    /// Read one block.
    pub fn get_block(&mut self, page_id: u64, block: u32) -> Result<Vec<u8>> {
        match self.request_replayed(&Request::GetBlock { page_id, block })?.body {
            Reply::Block { data } => Ok(data),
            other => Err(unexpected("GetBlock", &other)),
        }
    }

    /// Write one block. Replayed on transport failure: block writes are
    /// absolute (no read-modify-write on the wire), so a duplicate
    /// apply is content-idempotent.
    pub fn put_block(&mut self, page_id: u64, block: u32, data: Vec<u8>) -> Result<()> {
        match self.request_replayed(&Request::PutBlock { page_id, block, data })?.body {
            Reply::PutBlock => Ok(()),
            other => Err(unexpected("PutBlock", &other)),
        }
    }

    /// Read `count` consecutive blocks starting at `first`.
    pub fn read_range(&mut self, page_id: u64, first: u32, count: u32) -> Result<Vec<u8>> {
        match self.request_replayed(&Request::ReadRange { page_id, first, count })?.body {
            Reply::Range { data } => Ok(data),
            other => Err(unexpected("ReadRange", &other)),
        }
    }

    /// Drain the server's ingest queue and flush deferred dirty cache
    /// blocks; returns how many dirty blocks were written back.
    pub fn flush(&mut self) -> Result<u64> {
        match self.request_replayed(&Request::Flush)?.body {
            Reply::Flushed { blocks } => Ok(blocks),
            other => Err(unexpected("Flush", &other)),
        }
    }

    /// Snapshot the server's STATS field vector.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.request_replayed(&Request::Stats)?.body {
            Reply::Stats(stats) => Ok(stats),
            other => Err(unexpected("Stats", &other)),
        }
    }

    /// Force a background analysis round; returns the codec version at
    /// acknowledge time (poll [`Self::stats`] to observe the swap).
    pub fn reanalyze(&mut self) -> Result<u64> {
        match self.request(&Request::Reanalyze)?.body {
            Reply::Version { version } => Ok(version),
            other => Err(unexpected("Reanalyze", &other)),
        }
    }

    /// Ask the server to begin graceful shutdown.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)?.body {
            Reply::ShutdownAck => Ok(()),
            other => Err(unexpected("Shutdown", &other)),
        }
    }
}

fn unexpected(what: &str, reply: &Reply) -> Error {
    match reply {
        // DATA_LOSS keeps its type across the wire: retrying will not
        // help and the caller must be able to tell it from a transient.
        Reply::Error { status: Status::DataLoss, message, .. } => {
            Error::DataLoss(format!("{what}: {message}"))
        }
        Reply::Error { status, message, .. } => {
            Error::Corrupt(format!("{what}: server answered {status:?}: {message}"))
        }
        other => Error::Corrupt(format!("{what}: mismatched reply {other:?}")),
    }
}

/// Load-generator shape: a deterministic per-connection op trace driven
/// through a pipelined [`Client`].
#[derive(Debug, Clone, PartialEq)]
pub struct LoadGenConfig {
    /// Server address.
    pub addr: String,
    /// Concurrent connections, one OS thread each.
    pub conns: usize,
    /// Trace length per connection.
    pub ops_per_conn: usize,
    /// Pipeline window: requests in flight per connection.
    pub pipeline: usize,
    /// Page-id address space the trace reads/writes (must be
    /// preloaded; see `preload`).
    pub pages: u64,
    /// Logical page size for generated pages.
    pub page_bytes: usize,
    /// Fraction of trace ops that are single-block GETs; the rest are
    /// single-block PUTs (before batch/ingest mix-ins).
    pub read_fraction: f64,
    /// Every N ops, substitute an 8-block batched GET (0 = never).
    pub batch_read_every: usize,
    /// Every N ops, substitute a 4-page ingest batch with fresh page
    /// ids (0 = never) — keeps the analyzer's sample reservoir fed so
    /// codec-table swaps happen under live load.
    pub put_pages_every: usize,
    /// Zipf skew for page choice (0 = uniform).
    pub zipf_s: f64,
    /// Trace seed; each connection forks a distinct stream.
    pub seed: u64,
    /// Workload generating page/block payloads (`workloads::by_name`).
    pub workload: String,
    /// Verify every GET against the only two values a block can
    /// legally hold (its preloaded content, or the deterministic PUT
    /// payload for that slot — see [`put_payload`]); mismatches count
    /// in [`LoadGenReport::check_failures`]. The chaos CI smoke runs
    /// with this on: a corruption the server fails to fence shows up
    /// here as a silently-wrong read.
    pub check_content: bool,
    /// Transport failures each connection rides out by reconnecting
    /// and replaying its in-flight window (0 = fail fast, PR 9
    /// behavior). Outage time stays charged to the pending ops'
    /// latencies.
    pub max_reconnects: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            addr: "127.0.0.1:7070".to_string(),
            conns: 4,
            ops_per_conn: 5000,
            pipeline: 32,
            pages: 64,
            page_bytes: 4096,
            read_fraction: 0.8,
            batch_read_every: 16,
            put_pages_every: 32,
            zipf_s: 0.0,
            seed: 7,
            workload: "mcf".to_string(),
            check_content: false,
            max_reconnects: 8,
        }
    }
}

/// Client-side tallies from one load-generator run (or one
/// connection's share before [`LoadGenReport::merge`]).
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    /// OK responses received, all op kinds.
    pub ops_ok: u64,
    /// `RetryAfter` responses (admission sheds).
    pub sheds: u64,
    /// Other non-OK responses.
    pub ops_err: u64,
    /// OK single-block GETs.
    pub reads: u64,
    /// Blocks returned by OK batched GETs (found slots).
    pub batch_read_blocks: u64,
    /// OK batched-GET responses.
    pub batch_reads: u64,
    /// OK single-block PUTs.
    pub writes: u64,
    /// Pages accepted by OK ingest batches.
    pub pages_put: u64,
    /// OK ingest-batch responses.
    pub put_batches: u64,
    /// `DATA_LOSS` responses (also counted in `ops_err`).
    pub data_loss: u64,
    /// GET payloads matching neither legal value for their slot —
    /// silently-wrong reads (`check_content` mode only). The chaos
    /// smoke asserts this is exactly zero.
    pub check_failures: u64,
    /// Transport failures survived by reconnect-and-replay.
    pub reconnects: u64,
    /// Wall time of the slowest connection, seconds.
    pub wall_s: f64,
    /// Per-op send-to-receive latency, nanoseconds (unsorted).
    /// Back-off sleeps and reconnect time are **included**: an op's
    /// clock starts at first send and stops when its response (possibly
    /// of a replay) arrives, so retry cost shows up in the tail.
    pub lat_ns: Vec<u64>,
}

impl LoadGenReport {
    /// Completed ops (OK + shed + errored).
    pub fn total_ops(&self) -> u64 {
        self.ops_ok + self.sheds + self.ops_err
    }

    /// Completed ops per second over the slowest connection's wall time.
    pub fn ops_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.total_ops() as f64 / self.wall_s
        }
    }

    /// Fold another connection's tallies into this one.
    pub fn merge(&mut self, other: LoadGenReport) {
        self.ops_ok += other.ops_ok;
        self.sheds += other.sheds;
        self.ops_err += other.ops_err;
        self.reads += other.reads;
        self.batch_read_blocks += other.batch_read_blocks;
        self.batch_reads += other.batch_reads;
        self.writes += other.writes;
        self.pages_put += other.pages_put;
        self.put_batches += other.put_batches;
        self.data_loss += other.data_loss;
        self.check_failures += other.check_failures;
        self.reconnects += other.reconnects;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.lat_ns.extend(other.lat_ns);
    }
}

/// Latency percentile over an **ascending-sorted** slice (nearest-rank;
/// 0 for an empty slice).
pub fn percentile(sorted_ns: &[u64], q: f64) -> u64 {
    if sorted_ns.is_empty() {
        return 0;
    }
    let rank = (q * (sorted_ns.len() - 1) as f64).round() as usize;
    sorted_ns[rank.min(sorted_ns.len() - 1)]
}

/// Generate `pages` with ids `first_id..first_id + pages` from the
/// configured workload, deterministic in `seed`.
pub fn gen_pages(
    workload: &dyn workloads::Workload,
    first_id: u64,
    pages: u64,
    page_bytes: usize,
    seed: u64,
) -> Vec<(u64, Vec<u8>)> {
    (first_id..first_id + pages)
        .map(|id| (id, workload.generate(page_bytes, seed ^ id.wrapping_mul(0x9E37_79B9))))
        .collect()
}

/// Preload the trace's page address space over one connection in
/// batches, respecting admission back-off. Returns pages accepted.
pub fn preload(cfg: &LoadGenConfig) -> Result<u64> {
    let workload = workload_for(cfg)?;
    let mut client = Client::connect(&cfg.addr)?;
    let mut total = 0u64;
    let mut id = 0u64;
    while id < cfg.pages {
        let n = (cfg.pages - id).min(32);
        let batch = gen_pages(workload.as_ref(), id, n, cfg.page_bytes, cfg.seed);
        total += u64::from(client.put_pages(&batch)?);
        id += n;
    }
    client.flush()?;
    Ok(total)
}

fn workload_for(cfg: &LoadGenConfig) -> Result<Box<dyn workloads::Workload>> {
    workloads::by_name(&cfg.workload)
        .ok_or_else(|| Error::Config(format!("unknown workload {:?}", cfg.workload)))
}

enum TraceOp {
    Get { page: u64, block: u32 },
    BatchGet(Vec<(u64, u32)>),
    Put { page: u64, block: u32, data: Vec<u8> },
    PutPages(Vec<(u64, Vec<u8>)>),
}

fn request_of(op: &TraceOp) -> Request {
    match op {
        TraceOp::Get { page, block } => Request::GetBlock { page_id: *page, block: *block },
        TraceOp::BatchGet(items) => Request::GetBlocks(items.clone()),
        TraceOp::Put { page, block, data } => {
            Request::PutBlock { page_id: *page, block: *block, data: data.clone() }
        }
        TraceOp::PutPages(batch) => Request::PutPages(batch.clone()),
    }
}

/// The deterministic payload every `check_content` PUT writes to
/// `(page, block)` — a pure function of the slot, identical across
/// connections, so a block in the preloaded range only ever holds one
/// of **two** values: its preload bytes or this. That is what makes
/// client-side content checking sound under concurrent writers.
pub fn put_payload(seed: u64, page: u64, block: u32, block_bytes: usize) -> Vec<u8> {
    let mut rng = Rng::new(
        seed ^ page.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (u64::from(block) << 40) ^ 0x10AD_6E4,
    );
    let mut out = vec![0u8; block_bytes];
    rng.fill_bytes(&mut out);
    out
}

/// Client-side oracle for `check_content` mode: holds the preloaded
/// page images (regenerated lazily from the workload — the preload is
/// deterministic in `seed`) and validates GET payloads against the two
/// legal values per slot.
struct ContentChecker {
    workload: Box<dyn workloads::Workload>,
    preload: HashMap<u64, Vec<u8>>,
    pages: u64,
    page_bytes: usize,
    seed: u64,
}

impl ContentChecker {
    fn new(cfg: &LoadGenConfig) -> Result<ContentChecker> {
        Ok(ContentChecker {
            workload: workload_for(cfg)?,
            preload: HashMap::new(),
            pages: cfg.pages,
            page_bytes: cfg.page_bytes,
            seed: cfg.seed,
        })
    }

    /// Whether `data` is a value `(page, block)` may legally hold.
    /// Pages outside the preloaded range (fresh ingest ids) are not
    /// tracked and always pass.
    fn plausible(&mut self, page: u64, block: u32, data: &[u8]) -> bool {
        if page >= self.pages {
            return true;
        }
        if !self.preload.contains_key(&page) {
            let image =
                self.workload.generate(self.page_bytes, self.seed ^ page.wrapping_mul(0x9E37_79B9));
            self.preload.insert(page, image);
        }
        let image = &self.preload[&page];
        let off = block as usize * data.len();
        if off + data.len() <= image.len() && &image[off..off + data.len()] == data {
            return true;
        }
        data == put_payload(self.seed, page, block, data.len()).as_slice()
    }
}

fn pick_page(rng: &mut Rng, cfg: &LoadGenConfig) -> u64 {
    if cfg.zipf_s > 0.0 {
        rng.zipf(cfg.pages.max(1), cfg.zipf_s) % cfg.pages.max(1)
    } else {
        rng.below(cfg.pages.max(1))
    }
}

/// Build one connection's deterministic trace. Fresh ingest page ids
/// live above the preloaded range and are unique per connection, so
/// concurrent traces never write the same new page.
fn build_trace(
    cfg: &LoadGenConfig,
    workload: &dyn workloads::Workload,
    conn: usize,
    blocks_per_page: u64,
    pool: &[u8],
    block_bytes: usize,
) -> Vec<TraceOp> {
    let mut rng = Rng::new(cfg.seed ^ (conn as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut fresh_id = cfg.pages + (conn as u64) * (cfg.ops_per_conn as u64) * 4;
    let mut trace = Vec::with_capacity(cfg.ops_per_conn);
    for i in 1..=cfg.ops_per_conn {
        if cfg.put_pages_every != 0 && i % cfg.put_pages_every == 0 {
            let batch = gen_pages(workload, fresh_id, 4, cfg.page_bytes, cfg.seed ^ i as u64);
            fresh_id += 4;
            trace.push(TraceOp::PutPages(batch));
        } else if cfg.batch_read_every != 0 && i % cfg.batch_read_every == 0 {
            let items = (0..8)
                .map(|_| (pick_page(&mut rng, cfg), rng.below(blocks_per_page) as u32))
                .collect();
            trace.push(TraceOp::BatchGet(items));
        } else if rng.f64() < cfg.read_fraction {
            trace.push(TraceOp::Get {
                page: pick_page(&mut rng, cfg),
                block: rng.below(blocks_per_page) as u32,
            });
        } else if cfg.check_content {
            // Checked mode writes the slot's deterministic payload so
            // the oracle keeps exactly two legal values per block.
            let page = pick_page(&mut rng, cfg);
            let block = rng.below(blocks_per_page) as u32;
            let data = put_payload(cfg.seed, page, block, block_bytes);
            trace.push(TraceOp::Put { page, block, data });
        } else {
            let at = rng.below((pool.len() - block_bytes + 1) as u64) as usize;
            trace.push(TraceOp::Put {
                page: pick_page(&mut rng, cfg),
                block: rng.below(blocks_per_page) as u32,
                data: pool[at..at + block_bytes].to_vec(),
            });
        }
    }
    trace
}

fn drain_one(
    client: &mut Client,
    pending: &mut VecDeque<(Instant, usize)>,
    trace: &[TraceOp],
    checker: &mut Option<ContentChecker>,
    report: &mut LoadGenReport,
) -> Result<()> {
    let resp = client.recv()?;
    let (sent, idx) = pending.pop_front().ok_or_else(|| {
        Error::Corrupt("load generator received a response with nothing pending".into())
    })?;
    report.lat_ns.push(sent.elapsed().as_nanos() as u64);
    match resp.body {
        Reply::Block { data } => {
            if let (Some(ck), TraceOp::Get { page, block }) = (checker.as_mut(), &trace[idx]) {
                if !ck.plausible(*page, *block, &data) {
                    report.check_failures += 1;
                }
            }
            report.reads += 1;
            report.ops_ok += 1;
        }
        Reply::Blocks { items } => {
            if let (Some(ck), TraceOp::BatchGet(reqs)) = (checker.as_mut(), &trace[idx]) {
                for ((page, block), item) in reqs.iter().zip(&items) {
                    if let Some(data) = item {
                        if !ck.plausible(*page, *block, data) {
                            report.check_failures += 1;
                        }
                    }
                }
            }
            report.batch_read_blocks += items.iter().flatten().count() as u64;
            report.batch_reads += 1;
            report.ops_ok += 1;
        }
        Reply::PutBlock => {
            report.writes += 1;
            report.ops_ok += 1;
        }
        Reply::PutPages { accepted } => {
            report.pages_put += u64::from(accepted);
            report.put_batches += 1;
            report.ops_ok += 1;
        }
        Reply::Error { status: Status::RetryAfter, .. } => report.sheds += 1,
        Reply::Error { status: Status::DataLoss, .. } => {
            report.data_loss += 1;
            report.ops_err += 1;
        }
        Reply::Error { .. } => report.ops_err += 1,
        _ => report.ops_ok += 1,
    }
    Ok(())
}

/// Reconnect after a transport failure and re-send every op still in
/// the window, oldest first. Safe for every trace op (absolute-content
/// PUTs; see the module doc). The pending entries keep their original
/// `Instant`s, so the outage and back-off time land in those ops'
/// measured latencies.
fn reconnect_and_replay(
    cfg: &LoadGenConfig,
    ccfg: &ClientConfig,
    pending: &VecDeque<(Instant, usize)>,
    trace: &[TraceOp],
) -> Result<Client> {
    let mut client = Client::connect_with(&cfg.addr, ccfg.clone())?;
    for &(_, idx) in pending {
        client.send(&request_of(&trace[idx]))?;
    }
    Ok(client)
}

fn run_conn(cfg: &LoadGenConfig, conn: usize) -> Result<LoadGenReport> {
    let workload = workload_for(cfg)?;
    let ccfg = ClientConfig {
        backoff_seed: cfg.seed ^ (conn as u64).wrapping_mul(0xBACC_0FF5),
        ..ClientConfig::default()
    };
    let mut client = Client::connect_with(&cfg.addr, ccfg.clone())?;
    let block_bytes = client.block_bytes().max(1);
    let blocks_per_page = (cfg.page_bytes / block_bytes).max(1) as u64;
    let pool = workload.generate(cfg.page_bytes.max(block_bytes) * 4, cfg.seed ^ 0xB10C);
    let trace = build_trace(cfg, workload.as_ref(), conn, blocks_per_page, &pool, block_bytes);
    let mut checker = if cfg.check_content { Some(ContentChecker::new(cfg)?) } else { None };

    let mut report = LoadGenReport::default();
    let mut pending: VecDeque<(Instant, usize)> = VecDeque::with_capacity(cfg.pipeline.max(1));
    let mut backoff = Backoff::new(ccfg.retry.clone(), ccfg.backoff_seed ^ 0x10AD);
    let mut next = 0usize;
    let t0 = Instant::now();
    while next < trace.len() || !pending.is_empty() {
        let step: Result<()> = if next < trace.len() && pending.len() < cfg.pipeline.max(1) {
            client.send(&request_of(&trace[next])).map(|_| {
                pending.push_back((Instant::now(), next));
                next += 1;
            })
        } else {
            drain_one(&mut client, &mut pending, &trace, &mut checker, &mut report)
        };
        match step {
            Ok(()) => {}
            Err(e) if is_transport(&e) && report.reconnects < cfg.max_reconnects => {
                // Ride out the fault: back off, re-dial, replay the
                // window. Dial/replay failures burn reconnect budget
                // too, so a dead server still fails promptly.
                report.reconnects += 1;
                thread::sleep(backoff.next_delay());
                match reconnect_and_replay(cfg, &ccfg, &pending, &trace) {
                    Ok(c) => client = c,
                    Err(e2) if is_transport(&e2) => {}
                    Err(e2) => return Err(e2),
                }
            }
            Err(e) => return Err(e),
        }
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Run the multi-connection load generator against a live server and
/// return the merged client-side tallies. Pages `0..cfg.pages` must
/// already exist (use [`preload`]).
pub fn run_loadgen(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    let results: Vec<Result<LoadGenReport>> = thread::scope(|s| {
        let handles: Vec<_> =
            (0..cfg.conns.max(1)).map(|conn| s.spawn(move || run_conn(cfg, conn))).collect();
        handles.into_iter().map(|h| h.join().expect("loadgen thread panicked")).collect()
    });
    let mut merged = LoadGenReport::default();
    for r in results {
        merged.merge(r?);
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_caps_jitters_and_resets() {
        let policy = RetryPolicy { max_attempts: 5, base_ms: 10, cap_ms: 100 };
        let mut b = Backoff::new(policy.clone(), 42);
        let mut prev_window = 0u64;
        for attempt in 0..8u32 {
            let exp = (10u64 << attempt.min(30)).min(100);
            let d = b.next_delay().as_millis() as u64;
            assert!(d >= exp / 2 && d <= exp, "attempt {attempt}: {d} outside [{}, {exp}]", exp / 2);
            assert!(exp >= prev_window, "window must be monotone");
            prev_window = exp;
        }
        // saturated at the cap, attempts exhausted, schedule still works
        assert!(b.exhausted());
        let d = b.next_delay().as_millis() as u64;
        assert!((50..=100).contains(&d));
        b.reset();
        assert!(!b.exhausted());
        assert_eq!(b.attempts(), 0);
        // deterministic in the seed
        let s1: Vec<_> = (0..6).map(|_| Backoff::new(policy.clone(), 7).next_delay()).collect();
        let mut b2 = Backoff::new(policy.clone(), 7);
        assert_eq!(s1[0], b2.next_delay(), "same seed, same first delay");
        // server hint floors the delay
        let mut b3 = Backoff::new(policy, 9);
        assert!(b3.next_delay_at_least(500) >= Duration::from_millis(500));
    }

    #[test]
    fn put_payload_is_deterministic_per_slot() {
        let a = put_payload(7, 3, 9, 64);
        assert_eq!(a, put_payload(7, 3, 9, 64));
        assert_eq!(a.len(), 64);
        assert_ne!(a, put_payload(7, 4, 9, 64), "distinct pages, distinct payloads");
        assert_ne!(a, put_payload(7, 3, 10, 64), "distinct blocks, distinct payloads");
        assert_ne!(a, put_payload(8, 3, 9, 64), "distinct seeds, distinct payloads");
    }

    #[test]
    fn content_checker_accepts_both_legal_values_only() {
        let cfg = LoadGenConfig { check_content: true, ..Default::default() };
        let mut ck = ContentChecker::new(&cfg).unwrap();
        let workload = workload_for(&cfg).unwrap();
        let image = workload.generate(cfg.page_bytes, cfg.seed ^ 5u64.wrapping_mul(0x9E37_79B9));
        let bb = 64usize;
        // legal value 1: the preloaded bytes
        assert!(ck.plausible(5, 2, &image[2 * bb..3 * bb]));
        // legal value 2: the slot's deterministic PUT payload
        assert!(ck.plausible(5, 2, &put_payload(cfg.seed, 5, 2, bb)));
        // anything else is a silently-wrong read
        let mut bad = image[2 * bb..3 * bb].to_vec();
        bad[17] ^= 0x40;
        assert!(!ck.plausible(5, 2, &bad));
        // fresh-ingest ids above the preloaded range are not tracked
        assert!(ck.plausible(cfg.pages + 1, 0, &bad));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 0.0), 1);
        assert_eq!(percentile(&xs, 0.5), 51);
        assert_eq!(percentile(&xs, 0.99), 99);
        assert_eq!(percentile(&xs, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.999), 42);
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = LoadGenReport {
            ops_ok: 10,
            sheds: 1,
            wall_s: 0.5,
            lat_ns: vec![1, 2],
            ..Default::default()
        };
        let b = LoadGenReport {
            ops_ok: 5,
            ops_err: 2,
            wall_s: 1.5,
            lat_ns: vec![3],
            ..Default::default()
        };
        a.merge(b);
        assert_eq!(a.ops_ok, 15);
        assert_eq!(a.sheds, 1);
        assert_eq!(a.ops_err, 2);
        assert_eq!(a.total_ops(), 18);
        assert_eq!(a.wall_s, 1.5);
        assert_eq!(a.lat_ns, vec![1, 2, 3]);
        assert!((a.ops_per_s() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn traces_are_deterministic_and_mixed() {
        let cfg = LoadGenConfig { ops_per_conn: 200, ..Default::default() };
        let workload = workload_for(&cfg).unwrap();
        let pool = workload.generate(4096 * 4, 1);
        let t1 = build_trace(&cfg, workload.as_ref(), 0, 64, &pool, 64);
        let t2 = build_trace(&cfg, workload.as_ref(), 0, 64, &pool, 64);
        assert_eq!(t1.len(), 200);
        let kind = |t: &TraceOp| match t {
            TraceOp::Get { .. } => 0,
            TraceOp::BatchGet(_) => 1,
            TraceOp::Put { .. } => 2,
            TraceOp::PutPages(_) => 3,
        };
        let k1: Vec<u8> = t1.iter().map(kind).collect();
        let k2: Vec<u8> = t2.iter().map(kind).collect();
        assert_eq!(k1, k2, "same seed, same trace");
        for want in 0..4u8 {
            assert!(k1.contains(&want), "trace never emitted op kind {want}");
        }
        // Distinct connections see distinct traces.
        let t3 = build_trace(&cfg, workload.as_ref(), 1, 64, &pool, 64);
        let k3: Vec<u8> = t3.iter().map(kind).collect();
        assert!(k1 != k3 || format!("{:?}", trace_pages(&t1)) != format!("{:?}", trace_pages(&t3)));
    }

    fn trace_pages(trace: &[TraceOp]) -> Vec<u64> {
        trace
            .iter()
            .map(|t| match t {
                TraceOp::Get { page, .. } | TraceOp::Put { page, .. } => *page,
                TraceOp::BatchGet(items) => items.first().map(|(p, _)| *p).unwrap_or(0),
                TraceOp::PutPages(batch) => batch.first().map(|(p, _)| *p).unwrap_or(0),
            })
            .collect()
    }
}
