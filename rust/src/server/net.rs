//! The `GBN1` TCP server: accept loop, per-connection reader/writer
//! pairs, bounded write queues, admission control, and the
//! graceful-shutdown drain ([`Server::stop`]).
//!
//! Threading model (see the module docs in [`super`]): one nonblocking
//! accept loop, then per connection a *reader* thread that decodes and
//! executes requests against the shared
//! [`CompressionService`](crate::coordinator::CompressionService) and a
//! *writer* thread that drains that connection's bounded
//! [`WriteQueue`]. Readers poll with a short socket read timeout so
//! every thread observes the stop flag within `poll_interval_ms` even
//! while idle; a mid-frame client stall cannot wedge shutdown.

use std::collections::VecDeque;
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use super::protocol::{
    self, stats_field, Reply, Request, Response, StatsReply, Status, MIN_REQUEST_PAYLOAD,
};
use crate::coordinator::CompressionService;
use crate::{Error, Result};

/// Tuning knobs for [`Server::bind`]; `[server]` in the config file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerConfig {
    /// Address to bind (`host:port`; port 0 picks a free port).
    pub listen: String,
    /// Maximum simultaneously open connections; later accepts are
    /// dropped (counted as `rejected_conns`).
    pub max_conns: usize,
    /// Maximum frame payload size accepted or produced.
    pub max_frame_bytes: usize,
    /// Per-connection write-queue capacity in frames.
    pub write_queue_frames: usize,
    /// Per-connection write-queue capacity in bytes.
    pub write_queue_bytes: usize,
    /// Shed batch PUTs with `RetryAfter` once the service's ingest
    /// backlog would exceed this many pages. 0 = auto:
    /// `shards * ingest_batch * 4`.
    pub max_inflight_pages: u64,
    /// Suggested client back-off carried in `RetryAfter` responses.
    pub retry_after_ms: u32,
    /// Stop-flag poll granularity for idle readers and the accept loop.
    pub poll_interval_ms: u64,
    /// How long a fresh connection may take to present its 4 magic
    /// bytes before it is dropped (`gbdi serve --handshake-timeout`).
    pub handshake_timeout_ms: u64,
    /// Socket write timeout: a peer that stops reading for this long is
    /// dropped rather than allowed to wedge its writer thread forever
    /// (`gbdi serve --write-timeout`).
    pub write_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:7070".to_string(),
            max_conns: 64,
            max_frame_bytes: protocol::DEFAULT_MAX_FRAME_BYTES,
            write_queue_frames: 256,
            write_queue_bytes: 4 << 20,
            max_inflight_pages: 0,
            retry_after_ms: 50,
            poll_interval_ms: 50,
            handshake_timeout_ms: 5_000,
            write_timeout_ms: 10_000,
        }
    }
}

/// Wait-free server-wide counters, aggregated across connections. The
/// STATS op and `gbdi serve`'s periodic line both read these; the op
/// counters sum consistently with the service's `ShardMetrics` totals
/// (pinned by `tests/server_proto.rs`).
#[derive(Debug, Default)]
pub struct ServerStats {
    accepted_conns: AtomicU64,
    active_conns: AtomicU64,
    rejected_conns: AtomicU64,
    shed_ops: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    queue_full_events: AtomicU64,
    protocol_errors: AtomicU64,
    ops_ok: AtomicU64,
    ops_err: AtomicU64,
}

/// Point-in-time copy of [`ServerStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStatsSnapshot {
    /// Connections accepted since start.
    pub accepted_conns: u64,
    /// Connections currently open.
    pub active_conns: u64,
    /// Connections dropped at accept time (`max_conns` reached).
    pub rejected_conns: u64,
    /// Ops shed by admission control with `RetryAfter`.
    pub shed_ops: u64,
    /// Bytes read off sockets (magic + frame headers + payloads).
    pub bytes_in: u64,
    /// Bytes written to sockets (hello + response frames).
    pub bytes_out: u64,
    /// Request frames decoded.
    pub frames_in: u64,
    /// Response frames enqueued.
    pub frames_out: u64,
    /// Times a response had to wait for write-queue space.
    pub queue_full_events: u64,
    /// Connection-fatal protocol violations.
    pub protocol_errors: u64,
    /// OK responses sent (a STATS snapshot includes its own op).
    pub ops_ok: u64,
    /// Non-OK responses sent.
    pub ops_err: u64,
}

impl ServerStats {
    fn conn_accepted(&self) {
        self.accepted_conns.fetch_add(1, Ordering::Relaxed);
        self.active_conns.fetch_add(1, Ordering::Relaxed);
    }

    fn conn_closed(&self) {
        self.active_conns.fetch_sub(1, Ordering::Relaxed);
    }

    fn conn_rejected(&self) {
        self.rejected_conns.fetch_add(1, Ordering::Relaxed);
    }

    fn active(&self) -> u64 {
        self.active_conns.load(Ordering::Relaxed)
    }

    fn shed(&self) {
        self.shed_ops.fetch_add(1, Ordering::Relaxed);
    }

    fn add_bytes_in(&self, n: u64) {
        self.bytes_in.fetch_add(n, Ordering::Relaxed);
    }

    fn add_bytes_out(&self, n: u64) {
        self.bytes_out.fetch_add(n, Ordering::Relaxed);
    }

    fn frame_in(&self, wire_bytes: u64) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.add_bytes_in(wire_bytes);
    }

    fn frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    fn queue_full(&self) {
        self.queue_full_events.fetch_add(1, Ordering::Relaxed);
    }

    fn protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    fn op_ok(&self) {
        self.ops_ok.fetch_add(1, Ordering::Relaxed);
    }

    fn op_err(&self) {
        self.ops_err.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot every counter.
    pub fn snapshot(&self) -> ServerStatsSnapshot {
        ServerStatsSnapshot {
            accepted_conns: self.accepted_conns.load(Ordering::Relaxed),
            active_conns: self.active_conns.load(Ordering::Relaxed),
            rejected_conns: self.rejected_conns.load(Ordering::Relaxed),
            shed_ops: self.shed_ops.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            queue_full_events: self.queue_full_events.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            ops_ok: self.ops_ok.load(Ordering::Relaxed),
            ops_err: self.ops_err.load(Ordering::Relaxed),
        }
    }
}

/// Bounded MPSC byte-chunk queue between a connection's reader and
/// writer: the backpressure seam. `push` blocks while the queue is at
/// capacity (frames or bytes), so a client that stops draining
/// responses eventually stalls its own request stream instead of
/// growing server memory.
struct WriteQueue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    max_frames: usize,
    max_bytes: usize,
}

struct QueueInner {
    chunks: VecDeque<Vec<u8>>,
    bytes: usize,
    closed: bool,
}

impl WriteQueue {
    fn new(max_frames: usize, max_bytes: usize) -> Self {
        WriteQueue {
            inner: Mutex::new(QueueInner { chunks: VecDeque::new(), bytes: 0, closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            max_frames: max_frames.max(1),
            max_bytes: max_bytes.max(1),
        }
    }

    /// Enqueue, blocking while the queue is full. Returns `None` if the
    /// queue was closed (writer died), else `Some(had_to_wait)`. An
    /// oversized chunk is still admitted once the queue is empty, so a
    /// single frame larger than `max_bytes` cannot deadlock.
    fn push(&self, chunk: Vec<u8>) -> Option<bool> {
        let mut inner = self.inner.lock().unwrap();
        let mut waited = false;
        while !inner.closed
            && !inner.chunks.is_empty()
            && (inner.chunks.len() >= self.max_frames
                || inner.bytes + chunk.len() > self.max_bytes)
        {
            waited = true;
            inner = self.not_full.wait(inner).unwrap();
        }
        if inner.closed {
            return None;
        }
        inner.bytes += chunk.len();
        inner.chunks.push_back(chunk);
        self.not_empty.notify_one();
        Some(waited)
    }

    /// Dequeue without blocking; `None` when currently empty.
    fn try_pop(&self) -> Option<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let chunk = inner.chunks.pop_front();
        if let Some(c) = &chunk {
            inner.bytes -= c.len();
            self.not_full.notify_one();
        }
        chunk
    }

    /// Dequeue, blocking until a chunk arrives; `None` once the queue
    /// is closed *and* drained.
    fn pop_blocking(&self) -> Option<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(c) = inner.chunks.pop_front() {
                inner.bytes -= c.len();
                self.not_full.notify_one();
                return Some(c);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Everything a connection thread needs, shared by `Arc`.
struct ConnCtx {
    svc: Arc<CompressionService>,
    stats: Arc<ServerStats>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    max_inflight_pages: u64,
    block_bytes: usize,
}

/// A running `GBN1` server. Dropping without [`Server::stop`] leaks the
/// service into the still-running threads — always stop.
pub struct Server {
    svc: Arc<CompressionService>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    addr: SocketAddr,
}

impl Server {
    /// Bind `cfg.listen` and start serving `svc`. The service keeps its
    /// workers and analyzer; the server only adds the network front
    /// end. Fails on bind/configuration errors.
    pub fn bind(svc: CompressionService, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.listen.as_str())?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let svc = Arc::new(svc);
        let stats = Arc::new(ServerStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let max_inflight_pages = if cfg.max_inflight_pages > 0 {
            cfg.max_inflight_pages
        } else {
            (svc.config().shards.max(1) * svc.config().ingest_batch.max(1) * 4) as u64
        };
        let block_bytes = svc.config().codec.block_bytes;
        let ctx = Arc::new(ConnCtx {
            svc: Arc::clone(&svc),
            stats: Arc::clone(&stats),
            cfg,
            stop: Arc::clone(&stop),
            shutdown_requested: Arc::clone(&shutdown_requested),
            max_inflight_pages,
            block_bytes,
        });
        let aconns = Arc::clone(&conns);
        let acceptor = thread::spawn(move || accept_loop(&listener, &ctx, &aconns));
        Ok(Server { svc, stats, stop, shutdown_requested, acceptor: Some(acceptor), conns, addr })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live server counters.
    pub fn stats(&self) -> ServerStatsSnapshot {
        self.stats.snapshot()
    }

    /// The service behind the front end (metrics, shard snapshots...).
    pub fn service(&self) -> &CompressionService {
        &self.svc
    }

    /// Shared handle for sidecar threads (the serve CLI's
    /// `--chaos-corrupt` test hook). Drop every clone before
    /// [`Server::stop`], which needs sole ownership to hand the
    /// service back.
    pub fn service_shared(&self) -> Arc<CompressionService> {
        Arc::clone(&self.svc)
    }

    /// True once a client sent the SHUTDOWN op: the caller owning the
    /// server should invoke [`Server::stop`].
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::Acquire)
    }

    /// Graceful shutdown: stop accepting, wake every reader, let each
    /// writer drain the responses already enqueued, then drain the
    /// service's ingest queue and flush deferred dirty cache blocks —
    /// no acknowledged write is lost. Returns the recovered service,
    /// the final counters, and how many dirty blocks the final flush
    /// wrote back.
    pub fn stop(self) -> (CompressionService, ServerStatsSnapshot, usize) {
        let Server { svc, stats, stop, acceptor, conns, .. } = self;
        stop.store(true, Ordering::Release);
        if let Some(h) = acceptor {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        svc.flush();
        let flushed = svc.flush_cache();
        let snapshot = stats.snapshot();
        let svc = match Arc::try_unwrap(svc) {
            Ok(svc) => svc,
            Err(_) => unreachable!("connection threads joined but still hold the service"),
        };
        (svc, snapshot, flushed)
    }
}

fn accept_loop(listener: &TcpListener, ctx: &Arc<ConnCtx>, conns: &Mutex<Vec<JoinHandle<()>>>) {
    let nap = Duration::from_millis(ctx.cfg.poll_interval_ms.max(1));
    while !ctx.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let mut guard = conns.lock().unwrap();
                guard.retain(|h| !h.is_finished());
                if ctx.stats.active() >= ctx.cfg.max_conns as u64 {
                    ctx.stats.conn_rejected();
                    continue;
                }
                ctx.stats.conn_accepted();
                let cctx = Arc::clone(ctx);
                guard.push(thread::spawn(move || {
                    conn_loop(&cctx, stream);
                    cctx.stats.conn_closed();
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => thread::sleep(nap),
            Err(_) => thread::sleep(nap),
        }
    }
}

/// Outcome of a polled exact-length read.
enum ReadOutcome {
    /// Buffer filled.
    Done,
    /// Peer closed at a message boundary (nothing read).
    CleanEof,
    /// The stop flag went up mid-wait.
    Aborted,
    /// I/O error, mid-message EOF, or handshake deadline exceeded.
    Failed,
}

/// `read_exact` that polls the stop flag on every socket timeout, so a
/// reader blocked on an idle or stalled connection still observes
/// shutdown within one `poll_interval_ms`.
fn read_exact_polled(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    deadline: Option<Instant>,
) -> ReadOutcome {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Acquire) {
            return ReadOutcome::Aborted;
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 { ReadOutcome::CleanEof } else { ReadOutcome::Failed };
            }
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        return ReadOutcome::Failed;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Failed,
        }
    }
    ReadOutcome::Done
}

fn conn_loop(ctx: &ConnCtx, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(ctx.cfg.poll_interval_ms.max(1))));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(ctx.cfg.write_timeout_ms.max(1))));

    // Handshake: the client's 4 magic bytes, under a deadline so a
    // silent connection cannot hold a thread forever.
    let mut magic = [0u8; 4];
    let deadline = Instant::now() + Duration::from_millis(ctx.cfg.handshake_timeout_ms.max(1));
    match read_exact_polled(&mut stream, &mut magic, &ctx.stop, Some(deadline)) {
        ReadOutcome::Done => {}
        ReadOutcome::CleanEof | ReadOutcome::Aborted => return,
        ReadOutcome::Failed => {
            ctx.stats.protocol_error();
            return;
        }
    }
    if magic != protocol::MAGIC {
        ctx.stats.protocol_error();
        return;
    }
    ctx.stats.add_bytes_in(4);

    let queue = Arc::new(WriteQueue::new(ctx.cfg.write_queue_frames, ctx.cfg.write_queue_bytes));
    let Ok(wstream) = stream.try_clone() else {
        return;
    };
    let wqueue = Arc::clone(&queue);
    let wstats = Arc::clone(&ctx.stats);
    let writer = thread::spawn(move || writer_loop(wstream, &wqueue, &wstats));
    let hello = protocol::server_hello(ctx.block_bytes.min(u16::MAX as usize) as u16);
    queue.push(hello.to_vec());

    let mut scratch = vec![0u8; ctx.block_bytes.max(1)];
    loop {
        let mut hdr = [0u8; 4];
        match read_exact_polled(&mut stream, &mut hdr, &ctx.stop, None) {
            ReadOutcome::Done => {}
            ReadOutcome::CleanEof | ReadOutcome::Aborted => break,
            ReadOutcome::Failed => {
                ctx.stats.protocol_error();
                break;
            }
        }
        let len = u32::from_le_bytes(hdr) as usize;
        if !(MIN_REQUEST_PAYLOAD..=ctx.cfg.max_frame_bytes).contains(&len) {
            ctx.stats.protocol_error();
            break;
        }
        let mut payload = vec![0u8; len];
        match read_exact_polled(&mut stream, &mut payload, &ctx.stop, None) {
            ReadOutcome::Done => {}
            ReadOutcome::Aborted => break,
            ReadOutcome::CleanEof | ReadOutcome::Failed => {
                ctx.stats.protocol_error();
                break;
            }
        }
        ctx.stats.frame_in(4 + len as u64);

        let (resp, shutdown_op) = match protocol::decode_request(&payload) {
            Ok((req_id, req)) => {
                let shutdown_op = matches!(req, Request::Shutdown);
                // A STATS snapshot must reflect its own op, so its
                // counter tick happens before execution: after K OK
                // client ops, the K+1'th op's snapshot reads exactly
                // K+1. The CI smoke and the counter-consistency test
                // rely on this being deterministic.
                let is_stats = matches!(req, Request::Stats);
                if is_stats {
                    ctx.stats.op_ok();
                }
                let resp = execute(ctx, req_id, req, &mut scratch);
                if !is_stats {
                    if matches!(resp.body, Reply::Error { .. }) {
                        ctx.stats.op_err();
                    } else {
                        ctx.stats.op_ok();
                    }
                }
                (resp, shutdown_op)
            }
            Err(msg) => {
                // Framing was sound, the body was not: answer
                // BadRequest on the salvageable req_id and keep the
                // connection — the stream is still in sync.
                let req_id = u64::from_le_bytes(payload[..8].try_into().unwrap());
                let op = payload[8];
                ctx.stats.op_err();
                let body =
                    Reply::Error { status: Status::BadRequest, op, retry_ms: 0, message: msg };
                (Response { req_id, body }, false)
            }
        };

        let frame = protocol::frame(&protocol::encode_response(&resp));
        match queue.push(frame) {
            Some(waited) => {
                ctx.stats.frame_out();
                if waited {
                    ctx.stats.queue_full();
                }
            }
            None => break,
        }
        if shutdown_op {
            ctx.shutdown_requested.store(true, Ordering::Release);
        }
    }

    queue.close();
    let _ = writer.join();
}

fn writer_loop(stream: TcpStream, queue: &WriteQueue, stats: &ServerStats) {
    let mut w = BufWriter::new(stream);
    loop {
        let chunk = match queue.try_pop() {
            Some(c) => c,
            None => {
                // Idle: force buffered responses onto the wire before
                // blocking for the next one.
                if w.flush().is_err() {
                    break;
                }
                match queue.pop_blocking() {
                    Some(c) => c,
                    None => break,
                }
            }
        };
        if w.write_all(&chunk).is_err() {
            break;
        }
        stats.add_bytes_out(chunk.len() as u64);
    }
    // Unblock the reader if we died with the queue still open.
    queue.close();
    let _ = w.flush();
}

fn err(req_id: u64, status: Status, op: u8, retry_ms: u32, message: &str) -> Response {
    let body = Reply::Error { status, op, retry_ms, message: message.to_string() };
    Response { req_id, body }
}

/// Map a service error onto the wire: bad indices are the client's
/// fault, a missing/corrupt page is NotFound, an unhealable quarantined
/// page is DataLoss, anything else is ours.
fn err_for(req_id: u64, op: u8, e: &Error) -> Response {
    let status = match e {
        Error::Config(_) => Status::BadRequest,
        Error::Corrupt(_) => Status::NotFound,
        Error::DataLoss(_) => Status::DataLoss,
        _ => Status::ServerError,
    };
    err(req_id, status, op, 0, &e.to_string())
}

fn execute(ctx: &ConnCtx, req_id: u64, req: Request, scratch: &mut [u8]) -> Response {
    let op = req.op() as u8;
    if ctx.shutdown_requested.load(Ordering::Acquire)
        && !matches!(req, Request::Stats | Request::Shutdown)
    {
        return err(req_id, Status::ShuttingDown, op, 0, "server is draining");
    }
    let body = match req {
        Request::PutPages(pages) => {
            let n = pages.len() as u64;
            if ctx.svc.inflight() + n > ctx.max_inflight_pages {
                ctx.stats.shed();
                return err(
                    req_id,
                    Status::RetryAfter,
                    op,
                    ctx.cfg.retry_after_ms,
                    "ingest backlog full",
                );
            }
            ctx.svc.submit_batch(pages);
            Reply::PutPages { accepted: n as u32 }
        }
        Request::GetBlock { page_id, block } => {
            match ctx.svc.read_block(page_id, block as usize, scratch) {
                Ok(n) => Reply::Block { data: scratch[..n].to_vec() },
                Err(e) => return err_for(req_id, op, &e),
            }
        }
        Request::GetBlocks(items) => {
            let mut out = Vec::with_capacity(items.len());
            for (page_id, block) in items {
                match ctx.svc.read_block(page_id, block as usize, scratch) {
                    Ok(n) => out.push(Some(scratch[..n].to_vec())),
                    Err(_) => out.push(None),
                }
            }
            Reply::Blocks { items: out }
        }
        Request::PutBlock { page_id, block, data } => {
            match ctx.svc.write_block(page_id, block as usize, &data) {
                Ok(()) => Reply::PutBlock,
                Err(e) => return err_for(req_id, op, &e),
            }
        }
        Request::ReadRange { page_id, first, count } => {
            let cap = (ctx.cfg.max_frame_bytes / 2 / ctx.block_bytes.max(1)).max(1);
            if count as usize > cap {
                let msg = format!("range of {count} blocks exceeds cap of {cap}");
                return err(req_id, Status::BadRequest, op, 0, &msg);
            }
            let mut data = Vec::with_capacity(count as usize * ctx.block_bytes);
            for b in first..first.saturating_add(count) {
                match ctx.svc.read_block(page_id, b as usize, scratch) {
                    Ok(n) => data.extend_from_slice(&scratch[..n]),
                    Err(e) => return err_for(req_id, op, &e),
                }
            }
            Reply::Range { data }
        }
        Request::Flush => {
            ctx.svc.flush();
            Reply::Flushed { blocks: ctx.svc.flush_cache() as u64 }
        }
        Request::Stats => Reply::Stats(stats_reply(&ctx.svc, &ctx.stats)),
        Request::Reanalyze => {
            ctx.svc.request_analysis();
            Reply::Version { version: ctx.svc.current_version() }
        }
        Request::Shutdown => Reply::ShutdownAck,
    };
    Response { req_id, body }
}

/// Assemble the frozen STATS field vector (order: [`stats_field`]) from
/// the server counters, the service metrics, the store occupancy, and
/// the cache totals.
pub(crate) fn stats_reply(svc: &CompressionService, server: &ServerStats) -> StatsReply {
    let s = server.snapshot();
    let m = svc.metrics();
    let (logical, stored, _ratio) = svc.storage_ratio();
    let cache = svc.cache_totals();
    let integrity = svc.integrity_totals();
    let mut fields = vec![0u64; stats_field::COUNT];
    fields[stats_field::ACCEPTED_CONNS] = s.accepted_conns;
    fields[stats_field::ACTIVE_CONNS] = s.active_conns;
    fields[stats_field::REJECTED_CONNS] = s.rejected_conns;
    fields[stats_field::SHED_OPS] = s.shed_ops;
    fields[stats_field::BYTES_IN] = s.bytes_in;
    fields[stats_field::BYTES_OUT] = s.bytes_out;
    fields[stats_field::FRAMES_IN] = s.frames_in;
    fields[stats_field::FRAMES_OUT] = s.frames_out;
    fields[stats_field::QUEUE_FULL_EVENTS] = s.queue_full_events;
    fields[stats_field::PROTOCOL_ERRORS] = s.protocol_errors;
    fields[stats_field::OPS_OK] = s.ops_ok;
    fields[stats_field::OPS_ERR] = s.ops_err;
    fields[stats_field::PAGES_IN] = m.pages_in;
    fields[stats_field::BLOCK_READS] = m.block_reads;
    fields[stats_field::BLOCK_WRITES] = m.block_writes;
    fields[stats_field::READ_ERRORS] = m.read_errors;
    fields[stats_field::WRITE_ERRORS] = m.write_errors;
    fields[stats_field::LOGICAL_BYTES] = logical as u64;
    fields[stats_field::STORED_BYTES] = stored as u64;
    fields[stats_field::CODEC_VERSION] = svc.current_version();
    fields[stats_field::SHARDS] = svc.shard_count() as u64;
    fields[stats_field::TABLE_SWAPS] = m.table_swaps;
    fields[stats_field::CACHE_HITS] = cache.hits;
    fields[stats_field::CACHE_MISSES] = cache.misses;
    fields[stats_field::CACHE_ADMISSIONS] = cache.admissions;
    fields[stats_field::CACHE_EVICTIONS] = cache.evictions;
    fields[stats_field::DEFERRED_FLUSHES] = cache.deferred_flushes;
    fields[stats_field::CACHED_BLOCKS] = cache.cached_blocks;
    fields[stats_field::DIRTY_BLOCKS] = cache.dirty_blocks;
    fields[stats_field::SCRUBBED_PAGES] = integrity.scrubbed;
    fields[stats_field::CORRUPT_DETECTED] = integrity.corrupt_detected;
    fields[stats_field::HEALED] = integrity.healed;
    fields[stats_field::QUARANTINED] = integrity.quarantined;
    StatsReply { fields }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_queue_bounds_and_backpressure() {
        let q = Arc::new(WriteQueue::new(2, 1 << 20));
        assert_eq!(q.push(vec![1]), Some(false));
        assert_eq!(q.push(vec![2]), Some(false));
        // Third push must block until the consumer drains one.
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.push(vec![3]));
        thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "push past capacity should block");
        assert_eq!(q.try_pop(), Some(vec![1]));
        assert_eq!(t.join().unwrap(), Some(true));
        assert_eq!(q.pop_blocking(), Some(vec![2]));
        assert_eq!(q.pop_blocking(), Some(vec![3]));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn write_queue_byte_cap_and_oversize() {
        let q = WriteQueue::new(100, 8);
        // A chunk bigger than the byte cap still enters an empty queue.
        assert_eq!(q.push(vec![0; 64]), Some(false));
        let q = Arc::new(q);
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.push(vec![1; 4]));
        thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "byte cap should hold the second push");
        assert_eq!(q.try_pop(), Some(vec![0; 64]));
        assert_eq!(t.join().unwrap(), Some(true));
    }

    #[test]
    fn write_queue_close_unblocks_both_sides() {
        let q = Arc::new(WriteQueue::new(1, 1));
        assert_eq!(q.push(vec![9]), Some(false));
        let q2 = Arc::clone(&q);
        let pusher = thread::spawn(move || q2.push(vec![8]));
        let q3 = Arc::clone(&q);
        let closer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(30));
            q3.close();
        });
        assert_eq!(pusher.join().unwrap(), None);
        closer.join().unwrap();
        // Close drains what was queued, then reports exhaustion.
        assert_eq!(q.pop_blocking(), Some(vec![9]));
        assert_eq!(q.pop_blocking(), None);
        assert_eq!(q.push(vec![7]), None);
    }

    #[test]
    fn server_stats_snapshot_tracks_counters() {
        let s = ServerStats::default();
        s.conn_accepted();
        s.conn_accepted();
        s.conn_closed();
        s.conn_rejected();
        s.shed();
        s.frame_in(100);
        s.frame_out();
        s.add_bytes_out(60);
        s.queue_full();
        s.protocol_error();
        s.op_ok();
        s.op_ok();
        s.op_err();
        let snap = s.snapshot();
        assert_eq!(snap.accepted_conns, 2);
        assert_eq!(snap.active_conns, 1);
        assert_eq!(snap.rejected_conns, 1);
        assert_eq!(snap.shed_ops, 1);
        assert_eq!(snap.bytes_in, 100);
        assert_eq!(snap.bytes_out, 60);
        assert_eq!(snap.frames_in, 1);
        assert_eq!(snap.frames_out, 1);
        assert_eq!(snap.queue_full_events, 1);
        assert_eq!(snap.protocol_errors, 1);
        assert_eq!(snap.ops_ok, 2);
        assert_eq!(snap.ops_err, 1);
    }
}
