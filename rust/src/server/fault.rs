//! Deterministic network-fault injection: the socket analogue of
//! [`crate::persist::vfs::FaultFs`] (DESIGN.md §13).
//!
//! Three layers, all seeded and replayable:
//!
//! * [`FaultPlan`] — *where* faults land, expressed as mean byte
//!   intervals (cut the connection every ~N bytes, flip a bit every
//!   ~M bytes, stall every ~K bytes). Intervals are jittered ±50%
//!   from a seeded PRNG, so schedules are irregular but exactly
//!   reproducible.
//! * [`FaultStream`] — wraps any `Read + Write` transport and applies
//!   the plan to bytes crossing it in either direction. A *cut*
//!   delivers the scheduled prefix and then fails every later call
//!   with `ConnectionReset` — precisely a mid-frame disconnect.
//! * [`ChaosProxy`] — an in-process TCP relay that fronts a real
//!   `GBN1` server and applies an independent fault schedule to each
//!   proxied connection and direction. The chaos tests and the CI
//!   smoke point the load generator at the proxy instead of the
//!   server; the client's reconnect-and-replay path then has to earn
//!   its keep against real sockets.
//!
//! Fault *positions* are deterministic in `(seed, connection, byte
//! offset)`. What the faults hit still depends on thread interleaving
//! — that is the point of a chaos harness: schedules vary, invariants
//! must not.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::util::prng::Rng;
use crate::Result;

/// Seeded fault schedule. All intervals are mean bytes between events;
/// 0 disables that fault class. `FaultPlan::default()` injects nothing
/// — a proxy running the default plan is a transparent relay.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for every schedule derived from this plan.
    pub seed: u64,
    /// Mean bytes relayed before the connection is cut mid-stream.
    pub cut_every_bytes: u64,
    /// Mean bytes between single-bit corruptions.
    pub corrupt_every_bytes: u64,
    /// Mean bytes between injected stalls.
    pub stall_every_bytes: u64,
    /// Duration of each injected stall, milliseconds.
    pub stall_ms: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 1,
            cut_every_bytes: 0,
            corrupt_every_bytes: 0,
            stall_every_bytes: 0,
            stall_ms: 5,
        }
    }
}

impl FaultPlan {
    /// Derive the plan for one proxied connection: same fault mix,
    /// per-connection seed, so every connection sees its own schedule.
    fn for_conn(&self, conn_id: u64) -> FaultPlan {
        FaultPlan { seed: self.seed ^ conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15), ..self.clone() }
    }
}

/// Draw the next event position: `at + interval/2 + jitter(interval)`,
/// i.e. uniformly in `[at + i/2, at + 3i/2)`. `u64::MAX` when disabled.
fn next_event(rng: &mut Rng, at: u64, interval: u64) -> u64 {
    if interval == 0 {
        return u64::MAX;
    }
    at.saturating_add(interval / 2).saturating_add(rng.below(interval.max(1)))
}

/// One direction's fault state: byte position plus the pre-drawn
/// positions of the next cut/corruption/stall.
struct Injector {
    plan: FaultPlan,
    rng: Rng,
    pos: u64,
    next_cut: u64,
    next_corrupt: u64,
    next_stall: u64,
    /// Set once the cut fires: every later byte is refused.
    dead: bool,
}

impl Injector {
    fn new(plan: &FaultPlan, seed: u64) -> Injector {
        let mut rng = Rng::new(seed);
        let next_cut = next_event(&mut rng, 0, plan.cut_every_bytes);
        let next_corrupt = next_event(&mut rng, 0, plan.corrupt_every_bytes);
        let next_stall = next_event(&mut rng, 0, plan.stall_every_bytes);
        Injector { plan: plan.clone(), rng, pos: 0, next_cut, next_corrupt, next_stall, dead: false }
    }

    /// Apply the schedule to `buf` (bytes `pos..pos+len` of this
    /// direction). Corruptions mutate `buf` in place; stalls sleep
    /// here. Returns `(deliverable_prefix_len, cut_now)` — on a cut
    /// the prefix up to the cut position is still delivered, which is
    /// what makes the disconnect land *mid-frame*.
    fn process(&mut self, buf: &mut [u8]) -> (usize, bool) {
        if self.dead {
            return (0, true);
        }
        let len = buf.len() as u64;
        let mut keep = len;
        let mut cut = false;
        if self.next_cut < self.pos.saturating_add(len) {
            keep = self.next_cut.saturating_sub(self.pos).min(len);
            cut = true;
            self.dead = true;
        }
        while self.next_corrupt < self.pos.saturating_add(keep) {
            let off = (self.next_corrupt - self.pos) as usize;
            buf[off] ^= 1u8 << self.rng.below(8);
            self.next_corrupt = next_event(&mut self.rng, self.next_corrupt, self.plan.corrupt_every_bytes);
        }
        if self.next_stall < self.pos.saturating_add(keep) {
            thread::sleep(Duration::from_millis(self.plan.stall_ms));
            self.next_stall = next_event(&mut self.rng, self.next_stall, self.plan.stall_every_bytes);
        }
        self.pos = self.pos.saturating_add(keep);
        (keep as usize, cut)
    }
}

fn reset_err() -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionReset, "injected fault: connection cut")
}

/// A `Read + Write` transport with the fault plan applied to both
/// directions (independent schedules, seeds derived from the plan's).
/// Wrap a [`TcpStream`] — or anything duplex — to make it misbehave on
/// demand.
pub struct FaultStream<S> {
    inner: S,
    read_inject: Injector,
    write_inject: Injector,
}

impl<S> FaultStream<S> {
    pub fn new(inner: S, plan: &FaultPlan) -> FaultStream<S> {
        FaultStream {
            inner,
            read_inject: Injector::new(plan, plan.seed ^ 0x5EAD),
            write_inject: Injector::new(plan, plan.seed ^ 0x3717E),
        }
    }

    /// The wrapped transport (faults forgotten).
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.read_inject.dead {
            return Err(reset_err());
        }
        let n = self.inner.read(buf)?;
        if n == 0 {
            return Ok(0);
        }
        let (keep, cut) = self.read_inject.process(&mut buf[..n]);
        if cut && keep == 0 {
            return Err(reset_err());
        }
        Ok(keep)
    }
}

impl<S: Write> Write for FaultStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.write_inject.dead {
            return Err(reset_err());
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        let mut scratch = buf.to_vec();
        let (keep, cut) = self.write_inject.process(&mut scratch);
        if keep > 0 {
            self.inner.write_all(&scratch[..keep])?;
        }
        if cut && keep == 0 {
            return Err(reset_err());
        }
        // A short count on a cut makes the caller's write_all retry
        // and hit the dead check — the reset surfaces mid-frame.
        Ok(keep)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// In-process chaos TCP relay: accepts on an ephemeral local port and
/// pumps bytes to/from `upstream` through per-direction [`Injector`]s.
/// Cutting either direction tears down the whole proxied connection
/// (both sockets shut down), like a real mid-flight disconnect.
pub struct ChaosProxy {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicU64>,
    cuts: Arc<AtomicU64>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Start relaying to `upstream` under `plan`.
    pub fn start(upstream: &str, plan: FaultPlan) -> Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(AtomicU64::new(0));
        let cuts = Arc::new(AtomicU64::new(0));
        let upstream = upstream.to_string();
        let (stop2, conns2, cuts2) = (Arc::clone(&stop), Arc::clone(&conns), Arc::clone(&cuts));
        let accept_thread = thread::Builder::new()
            .name("gbdi-chaos".to_string())
            .spawn(move || {
                let mut conn_id = 0u64;
                loop {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((client, _)) => {
                            conn_id += 1;
                            conns2.fetch_add(1, Ordering::Relaxed);
                            let conn_plan = plan.for_conn(conn_id);
                            let upstream = upstream.clone();
                            let (stop3, cuts3) = (Arc::clone(&stop2), Arc::clone(&cuts2));
                            // relay threads are detached: they exit when
                            // either side closes, a cut fires, or stop is
                            // raised (polled via 50 ms read timeouts)
                            let _ = thread::Builder::new()
                                .name("gbdi-chaos-conn".to_string())
                                .spawn(move || relay_conn(client, &upstream, &conn_plan, stop3, cuts3));
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn chaos accept thread");
        Ok(ChaosProxy { local, stop, conns, cuts, accept_thread: Some(accept_thread) })
    }

    /// Address clients should dial instead of the real server.
    pub fn addr(&self) -> String {
        self.local.to_string()
    }

    /// Connections accepted so far.
    pub fn conns(&self) -> u64 {
        self.conns.load(Ordering::Relaxed)
    }

    /// Injected disconnects fired so far — chaos tests assert this is
    /// nonzero to prove the run actually exercised the fault path.
    pub fn cuts(&self) -> u64 {
        self.cuts.load(Ordering::Relaxed)
    }

    /// Stop accepting and wake the relay threads. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Pump one direction until EOF, error, cut, or stop.
fn pump(
    mut src: TcpStream,
    mut dst: TcpStream,
    mut inject: Injector,
    stop: Arc<AtomicBool>,
    cuts: Arc<AtomicU64>,
) {
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match src.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                let (keep, cut) = inject.process(&mut buf[..n]);
                if keep > 0 && dst.write_all(&buf[..keep]).and_then(|()| dst.flush()).is_err() {
                    break;
                }
                if cut {
                    cuts.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        }
    }
    // Tear down both halves: a cut (or stop) kills the connection, not
    // just one direction — mirrors how a real peer vanishes.
    let _ = src.shutdown(Shutdown::Both);
    let _ = dst.shutdown(Shutdown::Both);
}

fn relay_conn(
    client: TcpStream,
    upstream: &str,
    plan: &FaultPlan,
    stop: Arc<AtomicBool>,
    cuts: Arc<AtomicU64>,
) {
    let Ok(server) = TcpStream::connect(upstream) else {
        let _ = client.shutdown(Shutdown::Both);
        return;
    };
    let timeout = Some(Duration::from_millis(50));
    for s in [&client, &server] {
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(timeout);
    }
    let (Ok(client2), Ok(server2)) = (client.try_clone(), server.try_clone()) else {
        let _ = client.shutdown(Shutdown::Both);
        let _ = server.shutdown(Shutdown::Both);
        return;
    };
    let up = Injector::new(plan, plan.seed ^ 0xC25);
    let down = Injector::new(plan, plan.seed ^ 0x52C);
    let (stop2, cuts2) = (Arc::clone(&stop), Arc::clone(&cuts));
    let t = thread::Builder::new()
        .name("gbdi-chaos-up".to_string())
        .spawn(move || pump(client, server2, up, stop2, cuts2))
        .expect("spawn chaos pump");
    pump(server, client2, down, stop, cuts);
    let _ = t.join();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_and_jittered() {
        let plan = FaultPlan { seed: 9, corrupt_every_bytes: 64, ..Default::default() };
        let run = |p: &FaultPlan| {
            let mut inj = Injector::new(p, p.seed);
            let mut buf = vec![0u8; 4096];
            let (keep, cut) = inj.process(&mut buf);
            assert_eq!((keep, cut), (4096, false), "no cut scheduled");
            buf
        };
        let a = run(&plan);
        let b = run(&plan);
        assert_eq!(a, b, "same seed, same corruption positions");
        let flips = a.iter().filter(|&&x| x != 0).count();
        // mean interval 64 over 4 KiB: dozens of flips, not 0, not all
        assert!(flips >= 16 && flips <= 256, "{flips} flips");
        for x in a.iter().filter(|&&x| x != 0) {
            assert_eq!(x.count_ones(), 1, "exactly one bit per corruption");
        }
        let c = run(&FaultPlan { seed: 10, ..plan });
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn cut_delivers_prefix_then_refuses() {
        let plan = FaultPlan { seed: 3, cut_every_bytes: 100, ..Default::default() };
        let mut inj = Injector::new(&plan, plan.seed);
        let mut buf = vec![0u8; 1024];
        let (keep, cut) = inj.process(&mut buf);
        assert!(cut, "cut must fire inside the first KiB");
        assert!(keep >= 50 && keep < 150, "prefix near the scheduled position, got {keep}");
        let (keep2, cut2) = inj.process(&mut buf);
        assert_eq!((keep2, cut2), (0, true), "dead after the cut");
    }

    #[test]
    fn disabled_plan_is_transparent() {
        let mut inj = Injector::new(&FaultPlan::default(), 1);
        let mut buf: Vec<u8> = (0..=255u8).collect();
        let orig = buf.clone();
        for _ in 0..64 {
            let (keep, cut) = inj.process(&mut buf);
            assert_eq!((keep, cut), (256, false));
            assert_eq!(buf, orig, "no corruption without a schedule");
        }
    }

    /// In-memory duplex for exercising the stream wrapper.
    struct Duplex {
        rx: std::io::Cursor<Vec<u8>>,
        tx: Vec<u8>,
    }

    impl Read for Duplex {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.rx.read(buf)
        }
    }

    impl Write for Duplex {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.tx.write(buf)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn fault_stream_cuts_reads_mid_stream() {
        let inner = Duplex { rx: std::io::Cursor::new(vec![7u8; 4096]), tx: Vec::new() };
        let plan = FaultPlan { seed: 11, cut_every_bytes: 200, ..Default::default() };
        let mut fs = FaultStream::new(inner, &plan);
        let mut got = 0usize;
        let mut buf = [0u8; 256];
        let err = loop {
            match fs.read(&mut buf) {
                Ok(0) => panic!("EOF before the injected cut"),
                Ok(n) => got += n,
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        assert!(got < 4096, "cut must land before the stream drains, got {got}");
    }

    #[test]
    fn fault_stream_passthrough_when_disabled() {
        let inner = Duplex { rx: std::io::Cursor::new((0..100u8).collect()), tx: Vec::new() };
        let mut fs = FaultStream::new(inner, &FaultPlan::default());
        let mut out = Vec::new();
        fs.read_to_end(&mut out).unwrap();
        assert_eq!(out, (0..100u8).collect::<Vec<_>>());
        fs.write_all(&out).unwrap();
        assert_eq!(fs.into_inner().tx, (0..100u8).collect::<Vec<_>>());
    }

    #[test]
    fn proxy_relays_transparently_without_faults() {
        // echo upstream
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream = listener.local_addr().unwrap().to_string();
        let echo = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 512];
            loop {
                match s.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => {
                        if s.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                }
            }
        });
        let mut proxy = ChaosProxy::start(&upstream, FaultPlan::default()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        let msg: Vec<u8> = (0..200u8).collect();
        c.write_all(&msg).unwrap();
        let mut back = vec![0u8; msg.len()];
        c.read_exact(&mut back).unwrap();
        assert_eq!(back, msg, "default plan must be a transparent relay");
        assert_eq!(proxy.conns(), 1);
        assert_eq!(proxy.cuts(), 0);
        drop(c);
        proxy.stop();
        let _ = echo.join();
    }

    #[test]
    fn proxy_cut_tears_down_the_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream = listener.local_addr().unwrap().to_string();
        let sink = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            while matches!(s.read(&mut buf), Ok(n) if n > 0) {}
        });
        let plan = FaultPlan { seed: 5, cut_every_bytes: 512, ..Default::default() };
        let mut proxy = ChaosProxy::start(&upstream, plan).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // Keep writing until the injected cut surfaces as an error or
        // EOF on our side (reads return Ok(0) after the shutdown).
        let chunk = [0xABu8; 256];
        let mut saw_teardown = false;
        for _ in 0..1000 {
            if c.write_all(&chunk).is_err() {
                saw_teardown = true;
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        if !saw_teardown {
            let mut b = [0u8; 1];
            saw_teardown = !matches!(c.read(&mut b), Ok(n) if n > 0);
        }
        assert!(saw_teardown, "injected cut never surfaced to the client");
        assert!(proxy.cuts() >= 1, "cut counter must record the injected disconnect");
        proxy.stop();
        let _ = sink.join();
    }
}
