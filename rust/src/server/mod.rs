//! The network serving plane: a `GBN1` TCP front end over
//! [`crate::coordinator::CompressionService`], turning the in-process
//! block store into a served resource real clients can load
//! (DESIGN.md §12, `docs/PROTOCOL.md`).
//!
//! The stack is deliberately std-only — no async runtime, no epoll
//! crate — because the protocol is *pipelined*: every connection is an
//! independent, strictly ordered request stream, so one reader thread +
//! one writer thread per connection saturates the service while keeping
//! every failure mode inspectable:
//!
//! * [`protocol`] — the frozen byte format: length-prefixed frames,
//!   request/response codecs, the versioned STATS field table. Golden
//!   frames are cross-checked against the independent Python
//!   implementation in `scripts/gen_golden_fixtures.py`.
//! * [`Server`] — accept loop + per-connection reader/writer pairs.
//!   Responses travel through a **bounded write queue** per connection
//!   (frames *and* bytes): when a client stops draining responses, the
//!   reader blocks on the queue instead of buffering without bound, so
//!   backpressure propagates to the socket. Admission control sheds
//!   batch PUTs with `RetryAfter` once the service's ingest backlog
//!   passes `max_inflight_pages`. [`Server::stop`] drains connections,
//!   then the ingest queue, then flushes deferred dirty cache blocks —
//!   the graceful-shutdown path `gbdi serve` runs on SIGINT/SIGTERM.
//! * [`Client`] — blocking pipelined client (window of in-flight
//!   requests, FIFO response matching) with per-op deadlines and
//!   reconnect-and-replay under capped jittered back-off, plus the
//!   trace-driven multi-connection load generator behind
//!   `gbdi client --op load` and `cargo bench --bench serving`.
//! * [`fault`] — the deterministic network-fault seam: a seeded
//!   [`FaultStream`] wrapper (mid-frame cuts, stalls, bit corruption)
//!   and the in-process [`ChaosProxy`] TCP relay the chaos tests and
//!   CI smoke route traffic through. The socket analogue of
//!   `persist::vfs::FaultFs`.

pub mod client;
pub mod fault;
pub mod net;
pub mod protocol;

pub use client::{percentile, preload, put_payload, run_loadgen, Backoff, Client, ClientConfig,
                 LoadGenConfig, LoadGenReport, RetryPolicy};
pub use fault::{ChaosProxy, FaultPlan, FaultStream};
pub use net::{Server, ServerConfig, ServerStats, ServerStatsSnapshot};
