//! `GBN1` — the length-prefixed pipelined binary protocol spoken by the
//! network front end ([`super::Server`]) and its client
//! ([`super::Client`]).
//!
//! Byte-level layout, status codes, and the STATS field table are
//! frozen in `docs/PROTOCOL.md`; golden frames under
//! `rust/tests/golden/gbn1_*.gbn` are cross-verified against the
//! independent Python implementation in
//! `scripts/gen_golden_fixtures.py`. Everything is **little-endian**.
//!
//! A connection starts with a 4-byte client magic (`"GBN1"`) answered
//! by an 8-byte server hello, then carries framed requests and
//! responses: a `u32` payload length followed by the payload. Requests
//! on one connection are answered **in order**, which is what makes
//! pipelining trivial for clients: send a window of requests, then
//! match responses FIFO.

use crate::util::prng::Rng;

/// Connection magic: the client's first 4 bytes, echoed back as the
/// first 4 bytes of the server hello.
pub const MAGIC: [u8; 4] = *b"GBN1";

/// Protocol version carried in the server hello.
pub const PROTOCOL_VERSION: u8 = 1;

/// Smallest legal request payload: `req_id` (8) + `op` (1).
pub const MIN_REQUEST_PAYLOAD: usize = 9;

/// Smallest legal response payload: `req_id` (8) + `status` (1) + `op` (1).
pub const MIN_RESPONSE_PAYLOAD: usize = 10;

/// Default cap on a single frame's payload, requests and responses alike.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 8 << 20;

/// Version byte leading a STATS response body.
pub const STATS_VERSION: u8 = 1;

/// Hard cap on items in one `GetBlocks` request.
pub const MAX_GET_BLOCKS: usize = 4096;

/// Decode failures. The server answers a decodable `req_id` with
/// [`Status::BadRequest`] and keeps the connection; framing-level
/// violations (bad magic, bad length prefix) close it.
pub type ProtoError = String;

/// Operation codes (the `op` byte of every request, echoed in every
/// response so a response is decodable without per-connection state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Batch page ingest via `CompressionService::submit_batch`.
    PutPages = 1,
    /// Single-block read out of a compressed frame (or the cache tier).
    GetBlock = 2,
    /// Batched block reads, one found/miss slot per requested block.
    GetBlocks = 3,
    /// Single-block write (in-place recompression / cache absorb).
    PutBlock = 4,
    /// Contiguous multi-block read from one page.
    ReadRange = 5,
    /// Drain the ingest queue, then flush deferred dirty cache blocks.
    Flush = 6,
    /// Snapshot server + service + shard + cache counters.
    Stats = 7,
    /// Force a background analysis round (codec-table swap candidate).
    Reanalyze = 8,
    /// Ask the server to begin graceful shutdown after replying.
    Shutdown = 9,
}

impl Op {
    /// Decode an op byte.
    pub fn from_u8(v: u8) -> Option<Op> {
        Some(match v {
            1 => Op::PutPages,
            2 => Op::GetBlock,
            3 => Op::GetBlocks,
            4 => Op::PutBlock,
            5 => Op::ReadRange,
            6 => Op::Flush,
            7 => Op::Stats,
            8 => Op::Reanalyze,
            9 => Op::Shutdown,
            _ => return None,
        })
    }
}

/// Response status byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    /// Success; the body is op-specific.
    Ok = 0,
    /// The addressed page/block does not exist.
    NotFound = 1,
    /// The request body was malformed or out of bounds.
    BadRequest = 2,
    /// Admission control shed the op; retry after `retry_ms`.
    RetryAfter = 3,
    /// The server failed internally while executing the op.
    ServerError = 4,
    /// The server is draining; no new work is accepted.
    ShuttingDown = 5,
    /// The addressed page failed its integrity check and no durable
    /// copy could heal it: the data is gone, not merely unreadable
    /// (DESIGN.md §13). Retrying will not help; restore from backup or
    /// overwrite the page.
    DataLoss = 6,
}

impl Status {
    /// Decode a status byte.
    pub fn from_u8(v: u8) -> Option<Status> {
        Some(match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::BadRequest,
            3 => Status::RetryAfter,
            4 => Status::ServerError,
            5 => Status::ShuttingDown,
            6 => Status::DataLoss,
            _ => return None,
        })
    }
}

/// A decoded request body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Ingest pages: `(page_id, page bytes)` pairs.
    PutPages(Vec<(u64, Vec<u8>)>),
    /// Read one block of one page.
    GetBlock {
        /// Page to read from.
        page_id: u64,
        /// Block index within the page.
        block: u32,
    },
    /// Read many `(page_id, block)` pairs in one round trip.
    GetBlocks(Vec<(u64, u32)>),
    /// Overwrite one block of one page.
    PutBlock {
        /// Page to write into.
        page_id: u64,
        /// Block index within the page.
        block: u32,
        /// New block contents.
        data: Vec<u8>,
    },
    /// Read `count` consecutive blocks starting at `first`.
    ReadRange {
        /// Page to read from.
        page_id: u64,
        /// First block index.
        first: u32,
        /// Number of blocks.
        count: u32,
    },
    /// Drain ingest, then flush deferred dirty cache blocks.
    Flush,
    /// Snapshot counters.
    Stats,
    /// Force an analysis round.
    Reanalyze,
    /// Begin graceful shutdown after acknowledging.
    Shutdown,
}

impl Request {
    /// The op code this request encodes as.
    pub fn op(&self) -> Op {
        match self {
            Request::PutPages(_) => Op::PutPages,
            Request::GetBlock { .. } => Op::GetBlock,
            Request::GetBlocks(_) => Op::GetBlocks,
            Request::PutBlock { .. } => Op::PutBlock,
            Request::ReadRange { .. } => Op::ReadRange,
            Request::Flush => Op::Flush,
            Request::Stats => Op::Stats,
            Request::Reanalyze => Op::Reanalyze,
            Request::Shutdown => Op::Shutdown,
        }
    }
}

/// STATS response body: a versioned, growable vector of `u64` fields.
///
/// Field order is frozen (see [`stats_field`] and `docs/PROTOCOL.md`);
/// new fields only ever append. [`StatsReply::get`] returns 0 for
/// fields beyond what the peer sent, so old clients read new servers
/// and vice versa.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StatsReply {
    /// The raw field vector in [`stats_field`] order.
    pub fields: Vec<u64>,
}

/// Frozen indices into [`StatsReply::fields`].
pub mod stats_field {
    /// Connections accepted since start.
    pub const ACCEPTED_CONNS: usize = 0;
    /// Connections currently open.
    pub const ACTIVE_CONNS: usize = 1;
    /// Connections refused at accept time (`max_conns` reached).
    pub const REJECTED_CONNS: usize = 2;
    /// Ops shed by admission control with `RetryAfter`.
    pub const SHED_OPS: usize = 3;
    /// Frame bytes read off sockets (headers + payloads).
    pub const BYTES_IN: usize = 4;
    /// Bytes written to sockets (hello + response frames).
    pub const BYTES_OUT: usize = 5;
    /// Request frames decoded.
    pub const FRAMES_IN: usize = 6;
    /// Response frames enqueued.
    pub const FRAMES_OUT: usize = 7;
    /// Times a response had to wait for write-queue space (backpressure).
    pub const QUEUE_FULL_EVENTS: usize = 8;
    /// Connection-fatal protocol violations (bad magic, bad length).
    pub const PROTOCOL_ERRORS: usize = 9;
    /// OK responses sent (a STATS snapshot includes its own op).
    pub const OPS_OK: usize = 10;
    /// Non-OK responses sent.
    pub const OPS_ERR: usize = 11;
    /// Pages compressed by the service (`MetricsSnapshot::pages_in`).
    pub const PAGES_IN: usize = 12;
    /// Single-block reads served.
    pub const BLOCK_READS: usize = 13;
    /// Single-block writes served.
    pub const BLOCK_WRITES: usize = 14;
    /// Failed reads.
    pub const READ_ERRORS: usize = 15;
    /// Failed block writes.
    pub const WRITE_ERRORS: usize = 16;
    /// Logical bytes resident in the store.
    pub const LOGICAL_BYTES: usize = 17;
    /// Compressed bytes resident in the store.
    pub const STORED_BYTES: usize = 18;
    /// Current codec (table) version.
    pub const CODEC_VERSION: usize = 19;
    /// Page-store shard count.
    pub const SHARDS: usize = 20;
    /// Codec-table swaps published.
    pub const TABLE_SWAPS: usize = 21;
    /// Hot-block cache hits.
    pub const CACHE_HITS: usize = 22;
    /// Hot-block cache misses.
    pub const CACHE_MISSES: usize = 23;
    /// Blocks admitted into the cache.
    pub const CACHE_ADMISSIONS: usize = 24;
    /// Blocks evicted by capacity pressure.
    pub const CACHE_EVICTIONS: usize = 25;
    /// Deferred dirty blocks flushed back through frames.
    pub const DEFERRED_FLUSHES: usize = 26;
    /// Blocks resident in the cache.
    pub const CACHED_BLOCKS: usize = 27;
    /// Resident blocks carrying an unflushed write.
    pub const DIRTY_BLOCKS: usize = 28;
    /// Pages re-verified by the integrity scrubber (or explicit scrubs).
    pub const SCRUBBED_PAGES: usize = 29;
    /// Digest mismatches detected (scrub or verified read).
    pub const CORRUPT_DETECTED: usize = 30;
    /// Quarantined pages restored from durable state.
    pub const HEALED: usize = 31;
    /// Quarantine transitions (monotonic; a healed page does not
    /// decrement it).
    pub const QUARANTINED: usize = 32;
    /// Number of fields this build emits.
    pub const COUNT: usize = 33;

    /// Human-readable field names in frozen index order (`gbdi client
    /// --op stats` and the protocol docs render from this table).
    pub const NAMES: [&str; COUNT] = [
        "accepted_conns",
        "active_conns",
        "rejected_conns",
        "shed_ops",
        "bytes_in",
        "bytes_out",
        "frames_in",
        "frames_out",
        "queue_full_events",
        "protocol_errors",
        "ops_ok",
        "ops_err",
        "pages_in",
        "block_reads",
        "block_writes",
        "read_errors",
        "write_errors",
        "logical_bytes",
        "stored_bytes",
        "codec_version",
        "shards",
        "table_swaps",
        "cache_hits",
        "cache_misses",
        "cache_admissions",
        "cache_evictions",
        "deferred_flushes",
        "cached_blocks",
        "dirty_blocks",
        "scrubbed_pages",
        "corrupt_detected",
        "healed",
        "quarantined",
    ];
}

impl StatsReply {
    /// Field by frozen index; 0 when the peer sent fewer fields.
    pub fn get(&self, field: usize) -> u64 {
        self.fields.get(field).copied().unwrap_or(0)
    }
}

/// A decoded response body (the `Ok` arm of each op, or an error).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reply {
    /// `PutPages` accepted this many pages into the ingest queue.
    PutPages {
        /// Pages accepted.
        accepted: u32,
    },
    /// `GetBlock` payload.
    Block {
        /// The block bytes (tail blocks may be short).
        data: Vec<u8>,
    },
    /// `GetBlocks` payload: one slot per requested block, `None` = miss.
    Blocks {
        /// Per-request-order results.
        items: Vec<Option<Vec<u8>>>,
    },
    /// `PutBlock` acknowledged.
    PutBlock,
    /// `ReadRange` payload: the concatenated block bytes.
    Range {
        /// Concatenated blocks.
        data: Vec<u8>,
    },
    /// `Flush` completed.
    Flushed {
        /// Deferred dirty cache blocks recompressed.
        blocks: u64,
    },
    /// `Stats` snapshot.
    Stats(StatsReply),
    /// `Reanalyze` acknowledged.
    Version {
        /// Codec version at acknowledge time.
        version: u64,
    },
    /// `Shutdown` acknowledged; the server begins draining.
    ShutdownAck,
    /// Any non-OK outcome.
    Error {
        /// Why the op failed.
        status: Status,
        /// The attempted op byte (raw: it may not decode as an [`Op`]).
        op: u8,
        /// Suggested retry delay in ms (0 unless `RetryAfter`).
        retry_ms: u32,
        /// Human-readable detail (may be empty).
        message: String,
    },
}

impl Reply {
    /// The status byte this reply encodes as.
    pub fn status(&self) -> Status {
        match self {
            Reply::Error { status, .. } => *status,
            _ => Status::Ok,
        }
    }

    /// The op byte this reply encodes as.
    pub fn op_byte(&self) -> u8 {
        match self {
            Reply::PutPages { .. } => Op::PutPages as u8,
            Reply::Block { .. } => Op::GetBlock as u8,
            Reply::Blocks { .. } => Op::GetBlocks as u8,
            Reply::PutBlock => Op::PutBlock as u8,
            Reply::Range { .. } => Op::ReadRange as u8,
            Reply::Flushed { .. } => Op::Flush as u8,
            Reply::Stats(_) => Op::Stats as u8,
            Reply::Version { .. } => Op::Reanalyze as u8,
            Reply::ShutdownAck => Op::Shutdown as u8,
            Reply::Error { op, .. } => *op,
        }
    }
}

/// One framed response: the request id it answers plus the body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    /// Echoed from the request.
    pub req_id: u64,
    /// Outcome.
    pub body: Reply,
}

// ---------------------------------------------------------------------------
// Little-endian primitive writers.

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.remaining() < n {
            return Err(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Reject trailing garbage: a fully decoded payload must be spent.
    fn finish(self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "trailing garbage: {} bytes past the end of the body",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

/// Cap a claimed element count by what the remaining bytes could
/// possibly hold, so a hostile count can never drive a huge
/// pre-allocation.
fn plausible(n: usize, min_item_bytes: usize, remaining: usize) -> usize {
    n.min(remaining / min_item_bytes.max(1))
}

// ---------------------------------------------------------------------------
// Handshake.

/// The 8-byte server hello: magic, protocol version, flags (reserved,
/// 0), and the service's block size in bytes.
pub fn server_hello(block_bytes: u16) -> [u8; 8] {
    let mut out = [0u8; 8];
    out[..4].copy_from_slice(&MAGIC);
    out[4] = PROTOCOL_VERSION;
    out[5] = 0;
    out[6..8].copy_from_slice(&block_bytes.to_le_bytes());
    out
}

/// Parse a server hello into `(protocol_version, block_bytes)`.
pub fn parse_server_hello(hello: &[u8; 8]) -> Result<(u8, u16), ProtoError> {
    if hello[..4] != MAGIC {
        return Err(format!("bad server hello magic {:02x?}", &hello[..4]));
    }
    if hello[4] != PROTOCOL_VERSION {
        return Err(format!("unsupported protocol version {}", hello[4]));
    }
    Ok((hello[4], u16::from_le_bytes([hello[6], hello[7]])))
}

// ---------------------------------------------------------------------------
// Encoding.

/// Wrap a payload in its `u32` length prefix.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Encode a request payload (no length prefix).
pub fn encode_request(req_id: u64, req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    put_u64(&mut out, req_id);
    out.push(req.op() as u8);
    match req {
        Request::PutPages(pages) => {
            put_u32(&mut out, pages.len() as u32);
            for (page_id, data) in pages {
                put_u64(&mut out, *page_id);
                put_u32(&mut out, data.len() as u32);
                out.extend_from_slice(data);
            }
        }
        Request::GetBlock { page_id, block } => {
            put_u64(&mut out, *page_id);
            put_u32(&mut out, *block);
        }
        Request::GetBlocks(items) => {
            put_u32(&mut out, items.len() as u32);
            for (page_id, block) in items {
                put_u64(&mut out, *page_id);
                put_u32(&mut out, *block);
            }
        }
        Request::PutBlock { page_id, block, data } => {
            put_u64(&mut out, *page_id);
            put_u32(&mut out, *block);
            put_u32(&mut out, data.len() as u32);
            out.extend_from_slice(data);
        }
        Request::ReadRange { page_id, first, count } => {
            put_u64(&mut out, *page_id);
            put_u32(&mut out, *first);
            put_u32(&mut out, *count);
        }
        Request::Flush | Request::Stats | Request::Reanalyze | Request::Shutdown => {}
    }
    out
}

/// Encode a response payload (no length prefix).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    put_u64(&mut out, resp.req_id);
    out.push(resp.body.status() as u8);
    out.push(resp.body.op_byte());
    match &resp.body {
        Reply::PutPages { accepted } => put_u32(&mut out, *accepted),
        Reply::Block { data } | Reply::Range { data } => {
            put_u32(&mut out, data.len() as u32);
            out.extend_from_slice(data);
        }
        Reply::Blocks { items } => {
            put_u32(&mut out, items.len() as u32);
            for item in items {
                match item {
                    Some(data) => {
                        out.push(1);
                        put_u32(&mut out, data.len() as u32);
                        out.extend_from_slice(data);
                    }
                    None => out.push(0),
                }
            }
        }
        Reply::PutBlock | Reply::ShutdownAck => {}
        Reply::Flushed { blocks } => put_u64(&mut out, *blocks),
        Reply::Stats(stats) => {
            out.push(STATS_VERSION);
            put_u32(&mut out, stats.fields.len() as u32);
            for f in &stats.fields {
                put_u64(&mut out, *f);
            }
        }
        Reply::Version { version } => put_u64(&mut out, *version),
        Reply::Error { retry_ms, message, .. } => {
            put_u32(&mut out, *retry_ms);
            put_u32(&mut out, message.len() as u32);
            out.extend_from_slice(message.as_bytes());
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Decoding.

/// Decode a request payload into `(req_id, request)`.
pub fn decode_request(payload: &[u8]) -> Result<(u64, Request), ProtoError> {
    let mut rd = Rd::new(payload);
    let req_id = rd.u64()?;
    let op_byte = rd.u8()?;
    let op = Op::from_u8(op_byte).ok_or_else(|| format!("unknown op 0x{op_byte:02x}"))?;
    let req = match op {
        Op::PutPages => {
            let n = rd.u32()? as usize;
            let mut pages = Vec::with_capacity(plausible(n, 12, rd.remaining()));
            for _ in 0..n {
                let page_id = rd.u64()?;
                let len = rd.u32()? as usize;
                pages.push((page_id, rd.bytes(len)?.to_vec()));
            }
            Request::PutPages(pages)
        }
        Op::GetBlock => Request::GetBlock { page_id: rd.u64()?, block: rd.u32()? },
        Op::GetBlocks => {
            let n = rd.u32()? as usize;
            if n > MAX_GET_BLOCKS {
                return Err(format!("GetBlocks count {n} exceeds cap {MAX_GET_BLOCKS}"));
            }
            let mut items = Vec::with_capacity(plausible(n, 12, rd.remaining()));
            for _ in 0..n {
                items.push((rd.u64()?, rd.u32()?));
            }
            Request::GetBlocks(items)
        }
        Op::PutBlock => {
            let page_id = rd.u64()?;
            let block = rd.u32()?;
            let len = rd.u32()? as usize;
            Request::PutBlock { page_id, block, data: rd.bytes(len)?.to_vec() }
        }
        Op::ReadRange => {
            Request::ReadRange { page_id: rd.u64()?, first: rd.u32()?, count: rd.u32()? }
        }
        Op::Flush => Request::Flush,
        Op::Stats => Request::Stats,
        Op::Reanalyze => Request::Reanalyze,
        Op::Shutdown => Request::Shutdown,
    };
    rd.finish()?;
    Ok((req_id, req))
}

/// Decode a response payload.
pub fn decode_response(payload: &[u8]) -> Result<Response, ProtoError> {
    let mut rd = Rd::new(payload);
    let req_id = rd.u64()?;
    let status_byte = rd.u8()?;
    let status =
        Status::from_u8(status_byte).ok_or_else(|| format!("unknown status {status_byte}"))?;
    let op_byte = rd.u8()?;
    let body = if status == Status::Ok {
        let op = Op::from_u8(op_byte)
            .ok_or_else(|| format!("OK response with unknown op 0x{op_byte:02x}"))?;
        match op {
            Op::PutPages => Reply::PutPages { accepted: rd.u32()? },
            Op::GetBlock => {
                let len = rd.u32()? as usize;
                Reply::Block { data: rd.bytes(len)?.to_vec() }
            }
            Op::GetBlocks => {
                let n = rd.u32()? as usize;
                let mut items = Vec::with_capacity(plausible(n, 1, rd.remaining()));
                for _ in 0..n {
                    if rd.u8()? != 0 {
                        let len = rd.u32()? as usize;
                        items.push(Some(rd.bytes(len)?.to_vec()));
                    } else {
                        items.push(None);
                    }
                }
                Reply::Blocks { items }
            }
            Op::PutBlock => Reply::PutBlock,
            Op::ReadRange => {
                let len = rd.u32()? as usize;
                Reply::Range { data: rd.bytes(len)?.to_vec() }
            }
            Op::Flush => Reply::Flushed { blocks: rd.u64()? },
            Op::Stats => {
                let version = rd.u8()?;
                if version != STATS_VERSION {
                    return Err(format!("unsupported stats version {version}"));
                }
                let n = rd.u32()? as usize;
                let mut fields = Vec::with_capacity(plausible(n, 8, rd.remaining()));
                for _ in 0..n {
                    fields.push(rd.u64()?);
                }
                Reply::Stats(StatsReply { fields })
            }
            Op::Reanalyze => Reply::Version { version: rd.u64()? },
            Op::Shutdown => Reply::ShutdownAck,
        }
    } else {
        let retry_ms = rd.u32()?;
        let len = rd.u32()? as usize;
        let message = String::from_utf8(rd.bytes(len)?.to_vec())
            .map_err(|_| "error message is not UTF-8".to_string())?;
        Reply::Error { status, op: op_byte, retry_ms, message }
    };
    rd.finish()?;
    Ok(Response { req_id, body })
}

// ---------------------------------------------------------------------------
// Blocking frame I/O over std streams.

/// Read one frame payload. `Ok(None)` means the peer closed cleanly at
/// a frame boundary; a length prefix outside
/// `[MIN_REQUEST_PAYLOAD, max_frame_bytes]` or a mid-frame EOF is an
/// `InvalidData` error.
pub fn read_frame(
    r: &mut impl std::io::Read,
    max_frame_bytes: usize,
) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::{Error, ErrorKind, Read};
    let mut hdr = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut hdr[filled..]) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => {
                return Err(Error::new(ErrorKind::UnexpectedEof, "EOF inside a frame header"));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len < MIN_REQUEST_PAYLOAD || len > max_frame_bytes {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} outside [{MIN_REQUEST_PAYLOAD}, {max_frame_bytes}]"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

/// Generate a pseudo-random valid request — shared by the round-trip
/// property test here and the malformed-frame fuzz in
/// `tests/server_proto.rs`.
pub fn arbitrary_request(rng: &mut Rng) -> Request {
    match rng.below(9) {
        0 => {
            let n = rng.below(4) as usize;
            Request::PutPages(
                (0..n)
                    .map(|_| {
                        let mut data = vec![0u8; rng.below(256) as usize];
                        rng.fill_bytes(&mut data);
                        (rng.next_u64(), data)
                    })
                    .collect(),
            )
        }
        1 => Request::GetBlock { page_id: rng.next_u64(), block: rng.below(1 << 16) as u32 },
        2 => {
            let n = rng.below(8) as usize;
            Request::GetBlocks((0..n).map(|_| (rng.next_u64(), rng.below(256) as u32)).collect())
        }
        3 => {
            let mut data = vec![0u8; rng.below(128) as usize];
            rng.fill_bytes(&mut data);
            Request::PutBlock { page_id: rng.next_u64(), block: rng.below(64) as u32, data }
        }
        4 => Request::ReadRange {
            page_id: rng.next_u64(),
            first: rng.below(64) as u32,
            count: rng.below(16) as u32,
        },
        5 => Request::Flush,
        6 => Request::Stats,
        7 => Request::Reanalyze,
        _ => Request::Shutdown,
    }
}

/// Generate a pseudo-random valid response — the client-side twin of
/// [`arbitrary_request`], feeding the reply-decoder fuzz in
/// `tests/server_proto.rs` (mutated server output must never panic or
/// hang [`decode_response`]).
pub fn arbitrary_response(rng: &mut Rng) -> Response {
    let req_id = rng.next_u64();
    let body = match rng.below(10) {
        0 => Reply::PutPages { accepted: rng.below(1 << 16) as u32 },
        1 => {
            let mut data = vec![0u8; rng.below(256) as usize];
            rng.fill_bytes(&mut data);
            Reply::Block { data }
        }
        2 => {
            let n = rng.below(8) as usize;
            Reply::Blocks {
                items: (0..n)
                    .map(|_| {
                        if rng.chance(0.3) {
                            None
                        } else {
                            let mut data = vec![0u8; rng.below(128) as usize];
                            rng.fill_bytes(&mut data);
                            Some(data)
                        }
                    })
                    .collect(),
            }
        }
        3 => Reply::PutBlock,
        4 => {
            let mut data = vec![0u8; rng.below(512) as usize];
            rng.fill_bytes(&mut data);
            Reply::Range { data }
        }
        5 => Reply::Flushed { blocks: rng.next_u64() },
        6 => Reply::Stats(StatsReply {
            fields: (0..rng.below(2 * stats_field::COUNT as u64 + 1)).map(|_| rng.next_u64()).collect(),
        }),
        7 => Reply::Version { version: rng.next_u64() },
        8 => Reply::ShutdownAck,
        _ => {
            let status = match rng.below(6) {
                0 => Status::NotFound,
                1 => Status::BadRequest,
                2 => Status::RetryAfter,
                3 => Status::ShuttingDown,
                4 => Status::DataLoss,
                _ => Status::ServerError,
            };
            let n = rng.below(48) as usize;
            let message: String =
                (0..n).map(|_| char::from(b'a' + (rng.below(26) as u8))).collect();
            Reply::Error { status, op: rng.below(256) as u8, retry_ms: rng.next_u32(), message }
        }
    };
    Response { req_id, body }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req_id: u64, req: Request) {
        let payload = encode_request(req_id, &req);
        let (id, back) = decode_request(&payload).unwrap();
        assert_eq!(id, req_id);
        assert_eq!(back, req);
    }

    fn roundtrip_response(resp: Response) {
        let payload = encode_response(&resp);
        assert_eq!(decode_response(&payload).unwrap(), resp);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(
            1,
            Request::PutPages(vec![(42, vec![7u8; 4096]), (u64::MAX, Vec::new())]),
        );
        roundtrip_request(2, Request::GetBlock { page_id: 3, block: 9 });
        roundtrip_request(3, Request::GetBlocks(vec![(1, 2), (u64::MAX, u32::MAX)]));
        roundtrip_request(4, Request::PutBlock { page_id: 5, block: 0, data: vec![0xC3; 64] });
        roundtrip_request(5, Request::ReadRange { page_id: 9, first: 2, count: 3 });
        roundtrip_request(6, Request::Flush);
        roundtrip_request(7, Request::Stats);
        roundtrip_request(u64::MAX, Request::Reanalyze);
        roundtrip_request(0, Request::Shutdown);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_response(Response { req_id: 1, body: Reply::PutPages { accepted: 2 } });
        roundtrip_response(Response {
            req_id: 2,
            body: Reply::Block { data: (0..64).collect() },
        });
        roundtrip_response(Response {
            req_id: 3,
            body: Reply::Blocks { items: vec![Some(vec![1, 2, 3]), None, Some(Vec::new())] },
        });
        roundtrip_response(Response { req_id: 4, body: Reply::PutBlock });
        roundtrip_response(Response { req_id: 5, body: Reply::Range { data: vec![9; 192] } });
        roundtrip_response(Response { req_id: 6, body: Reply::Flushed { blocks: 7 } });
        roundtrip_response(Response {
            req_id: 7,
            body: Reply::Stats(StatsReply {
                fields: (0..stats_field::COUNT as u64).map(|i| 1000 + i).collect(),
            }),
        });
        roundtrip_response(Response { req_id: 8, body: Reply::Version { version: 3 } });
        roundtrip_response(Response { req_id: 9, body: Reply::ShutdownAck });
        for status in [
            Status::NotFound,
            Status::BadRequest,
            Status::RetryAfter,
            Status::ServerError,
            Status::ShuttingDown,
            Status::DataLoss,
        ] {
            roundtrip_response(Response {
                req_id: 10,
                body: Reply::Error {
                    status,
                    op: 0x2A,
                    retry_ms: if status == Status::RetryAfter { 50 } else { 0 },
                    message: "page 3 not found".into(),
                },
            });
        }
    }

    #[test]
    fn arbitrary_requests_roundtrip() {
        let mut rng = Rng::new(0xBEEF);
        for i in 0..500 {
            roundtrip_request(i, arbitrary_request(&mut rng));
        }
    }

    #[test]
    fn truncation_never_panics_and_always_errors() {
        let mut rng = Rng::new(0x5EED);
        for i in 0..200u64 {
            let req = arbitrary_request(&mut rng);
            let full = encode_request(i, &req);
            for cut in 0..full.len() {
                assert!(decode_request(&full[..cut]).is_err() || cut == full.len());
            }
            let resp = Response { req_id: i, body: Reply::Flushed { blocks: i } };
            let full = encode_response(&resp);
            for cut in 0..full.len() {
                assert!(decode_response(&full[..cut]).is_err());
            }
        }
    }

    #[test]
    fn garbage_never_panics() {
        let mut rng = Rng::new(0xDEAD);
        for _ in 0..2000 {
            let mut buf = vec![0u8; rng.below(96) as usize];
            rng.fill_bytes(&mut buf);
            let _ = decode_request(&buf);
            let _ = decode_response(&buf);
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut payload = encode_request(1, &Request::Flush);
        payload.push(0);
        assert!(decode_request(&payload).is_err());
        let mut payload = encode_response(&Response { req_id: 1, body: Reply::PutBlock });
        payload.push(0);
        assert!(decode_response(&payload).is_err());
    }

    #[test]
    fn hostile_counts_do_not_allocate() {
        // Claimed 4 billion pages with an 8-byte body: decode must fail
        // fast without a giant pre-allocation.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.push(Op::PutPages as u8);
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        payload.extend_from_slice(&[0u8; 8]);
        assert!(decode_request(&payload).is_err());
        // Same for a GetBlocks count past the cap.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.push(Op::GetBlocks as u8);
        payload.extend_from_slice(&(MAX_GET_BLOCKS as u32 + 1).to_le_bytes());
        assert!(decode_request(&payload).is_err());
    }

    #[test]
    fn hello_roundtrips() {
        let hello = server_hello(64);
        let (version, block_bytes) = parse_server_hello(&hello).unwrap();
        assert_eq!(version, PROTOCOL_VERSION);
        assert_eq!(block_bytes, 64);
        let mut bad = hello;
        bad[0] = b'X';
        assert!(parse_server_hello(&bad).is_err());
    }

    #[test]
    fn framed_stream_roundtrips() {
        let mut wire = Vec::new();
        let payloads: Vec<Vec<u8>> = vec![
            encode_request(1, &Request::Flush),
            encode_request(2, &Request::GetBlock { page_id: 0, block: 0 }),
        ];
        for p in &payloads {
            write_frame(&mut wire, p).unwrap();
        }
        let mut cursor = &wire[..];
        for p in &payloads {
            assert_eq!(read_frame(&mut cursor, 1 << 20).unwrap().unwrap(), *p);
        }
        assert!(read_frame(&mut cursor, 1 << 20).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(2u32 << 20).to_le_bytes());
        wire.resize(64, 0);
        let mut cursor = &wire[..];
        assert!(read_frame(&mut cursor, 1 << 20).is_err());
    }
}
