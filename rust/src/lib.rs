//! # gbdi — Global-Base Delta-Immediate memory compression
//!
//! A production-shaped reproduction of *“Implementation and Evaluation of
//! GBDI Memory Compression Algorithm Using C/C++ on a Broader Range of
//! Workloads”* (CS.DC 2025), which itself reimplements GBDI from HPCA'22
//! (Angerd et al.).
//!
//! ## The three-layer stack
//!
//! * **L1** — Pallas kernels (build-time Python): k-means assignment /
//!   centroid update / compressed-size estimation, tiled for VMEM + MXU.
//! * **L2** — JAX analysis graphs (build-time Python): the full background
//!   data-analysis loop, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** — this crate: the bit-exact compression/decompression engines,
//!   global-base-table lifecycle, workload substrate, compressed-memory
//!   simulator, and a serving-style [`coordinator`] that runs the L2
//!   artifacts through PJRT ([`runtime`]) off the hot path.
//!
//! ## The codec/frame/container layering (L3 internals)
//!
//! Everything that compresses cache-line-sized blocks sits behind one
//! seam, and everything that *serves* compressed data goes through the
//! random-access layer above it:
//!
//! * [`codec::BlockCodec`] — the crate-wide trait: per-block
//!   `compress_block` / `decompress_block` / `estimate_block_bits` over
//!   the shared bit stream ([`util::bits`]). Implemented by
//!   [`GbdiCodec`], [`baselines::bdi::Bdi`], and
//!   [`baselines::fpc::FpcBlock`]; new codecs plug in here. The `_with`
//!   variants borrow caller-owned [`Scratch`] buffers, so per-request
//!   paths never allocate.
//! * [`frame::Frame`] — the random-access handle over a compressed
//!   image: a block-offset index (prefix sums of the per-block bit
//!   lengths the wire format already carries) makes
//!   [`Frame::read_block`](frame::Frame::read_block) /
//!   [`write_block`](frame::Frame::write_block) O(1) and
//!   allocation-free; writes recompress in place and spill to a patch
//!   region when they outgrow their span. [`Compressor`] /
//!   [`Decompressor`] are the streaming sessions on top (chunked input,
//!   bounded memory). This is the surface memory-compression
//!   deployments actually need: single cache-line reads and writes out
//!   of compressed pages.
//! * [`container`] — the single framed *wire format*: codec id + config
//!   + optional global table + per-block bit lengths (u32 varints) +
//!   chunked payload. Serial ([`container::compress`]) and parallel
//!   ([`container::compress_parallel`]) pipelines work for *every*
//!   codec; parallel output decodes bit-exactly like serial, and
//!   [`Container::into_frame`] upgrades a parsed container to random
//!   access without copying the payload.
//! * Consumers — the memory simulator ([`memsim::CompressedMemory`],
//!   one sector-aligned frame per page) and the serving coordinator
//!   ([`coordinator::CompressionService`], block GET/PUT with
//!   per-request latency metrics) serve single blocks from frames; the
//!   CLI (`gbdi read --block`, `gbdi bench-access`, `compress|verify|
//!   memsim|sweep --codec gbdi|bdi|fpc`) and the benches drive any
//!   `dyn BlockCodec` through both surfaces.
//!
//! ## The sharded serving plane
//!
//! Both block-serving consumers sit on one concurrent store,
//! [`coordinator::ShardedPageStore`]: N independently locked shards
//! (page-id hash routing, per-shard [`Scratch`] and metrics) sharing a
//! single codec ring, so a table swap publishes with one O(1) insert
//! and traffic on different shards never contends. Ingest is batched —
//! [`coordinator::CompressionService::submit_batch`] groups pages per
//! shard so workers take each shard lock once per batch — and
//! recompression migration walks one shard at a time, keeping
//! maintenance off the foreground path (DESIGN.md §8, and
//! `docs/ARCHITECTURE.md` for the full dataflow). `shards = 1`
//! reproduces the old single-lock store exactly; a property test pins
//! the observational equivalence, and `cargo bench --bench
//! concurrent_serving` measures throughput and tail latency as the
//! shard count scales. An optional per-shard **hot-block cache tier**
//! ([`coordinator::ShardedPageStore::with_cache`], DESIGN.md §11)
//! serves skewed block traffic from bounded uncompressed S3-FIFO
//! caches — hits skip the decode entirely, writes to hot blocks defer
//! recompression until the block cools, and the cache-off default
//! stays bit-identical to the cacheless build.
//!
//! The serving plane is reachable over the network through [`server`]:
//! a std-only TCP front end speaking the length-prefixed pipelined
//! `GBN1` protocol (`docs/PROTOCOL.md`) with batch PUT, single/batch
//! block GET, RANGE, FLUSH, and STATS ops, bounded per-connection
//! write queues, and `RetryAfter` admission control — `gbdi serve
//! --listen` runs it, `gbdi client` and `cargo bench --bench serving`
//! drive it.
//!
//! Whole-image software comparators (LZSS, Huffman, gzip, zstd) stay
//! behind the coarser [`baselines::Codec`] trait — they have no block
//! granularity for the simulator to exploit.
//!
//! ## SIMD kernel dispatch
//!
//! The per-word hot loops — the GBDI decode apply phase, the encoder's
//! base-candidate search, BDI's feasibility scans, and the ZERO/REP
//! block classifiers — run through a runtime-dispatched kernel vtable
//! ([`simd`], DESIGN.md §10): SSE2/AVX2 on x86_64, NEON on aarch64, a
//! scalar reference everywhere. Backend choice never changes a single
//! output bit (differentially tested per backend in
//! `tests/simd_kernels.rs`); override it for ablation with the `--isa`
//! CLI flag or the `GBDI_FORCE_ISA` env var.
//!
//! ## The base-selection engine
//!
//! The background analysis that decides GBDI's global bases sits behind
//! its own seam, [`cluster::BaseSelector`] (DESIGN.md §6):
//!
//! * [`cluster::LloydSelector`] — full bit-cost Lloyd k-means (the
//!   paper's algorithm; the quality reference).
//! * [`cluster::MiniBatchSelector`] — streaming mini-batch k-means that
//!   **warm-starts from the incumbent table** (an order of magnitude
//!   cheaper per pass; the production arm).
//! * [`cluster::HistogramSelector`] — frequency top-K buckets
//!   (near-free; strong on pointer-heavy populations).
//! * [`cluster::ArtifactSelector`] — the AOT JAX/Pallas k-means through
//!   PJRT, folded in as just another selector.
//!
//! Every selector's proposal goes through
//! [`gbdi::GlobalBaseTable::from_selection`] for width fitting, so
//! selector choice affects ratio and analysis latency, never
//! correctness. The coordinator's analyzer adds **drift detection** on
//! top: it scores fresh samples under the incumbent table and skips
//! re-clustering entirely while the score stays within its
//! `drift_margin` — stable traffic pays one O(n) scoring pass instead
//! of a re-derivation. Select on the CLI via `gbdi serve --selector
//! lloyd|minibatch|histogram|artifact`, compare with `gbdi selectors`
//! or `cargo bench --bench kmeans_ablation`.
//!
//! ## Quickstart
//!
//! ```
//! use gbdi::{BlockCodec, CodecKind, Compressor, GbdiConfig, Scratch, workloads};
//! use std::sync::Arc;
//!
//! // 256 KiB of mcf-like memory content.
//! let image = workloads::by_name("mcf").unwrap().generate(1 << 18, 7);
//! // Background analysis -> codec (GBDI derives its global base table).
//! let codec: Arc<dyn BlockCodec> =
//!     Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));
//!
//! // Streaming session: feed chunks of any size, bounded memory.
//! let mut session = Compressor::new(Arc::clone(&codec));
//! for chunk in image.chunks(4096) {
//!     session.write(chunk);
//! }
//! let mut frame = session.finish();
//!
//! // Random access: O(1), allocation-free single-block reads...
//! let mut line = [0u8; 64];
//! frame.read_block(100, &mut line).unwrap();
//! assert_eq!(&line[..], &image[100 * 64..101 * 64]);
//! // ...in-place writes (spilling to a patch region when they grow)...
//! let mut scratch = Scratch::new();
//! frame.write_block(100, &[0u8; 64], &mut scratch).unwrap();
//! // ...and the canonical wire format when you need to ship it.
//! let container = frame.to_container();
//! assert!(container.ratio() > 1.0);
//! ```

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod codec;
pub mod config;
pub mod container;
pub mod coordinator;
pub mod elf;
pub mod frame;
pub mod gbdi;
pub mod memsim;
pub mod persist;
pub mod report;
pub mod runtime;
pub mod server;
pub mod simd;
pub mod util;
pub mod value;
pub mod workloads;

pub use codec::{BlockCodec, CodecId, CodecKind, Scratch};
pub use container::Container;
pub use frame::{BlockWrite, Compressor, Decompressor, Frame};
pub use gbdi::{GbdiCodec, GbdiConfig};

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Compressed stream is malformed (truncated, bad tag, bad table id...).
    #[error("corrupt compressed stream: {0}")]
    Corrupt(String),
    /// ELF parse errors from the dump substrate.
    #[error("elf: {0}")]
    Elf(String),
    /// PJRT / XLA runtime errors.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Configuration errors (bad K, bad width classes, ...).
    #[error("config: {0}")]
    Config(String),
    /// A stored page failed its integrity check and no durable copy
    /// could heal it: the data is gone, not merely unreadable. Surfaced
    /// to network clients as the GBN1 `DATA_LOSS` status (DESIGN.md
    /// §13) so operators can distinguish "retry later" from "restore
    /// from backup".
    #[error("data loss: {0}")]
    DataLoss(String),
    /// I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
