//! # gbdi — Global-Base Delta-Immediate memory compression
//!
//! A production-shaped reproduction of *“Implementation and Evaluation of
//! GBDI Memory Compression Algorithm Using C/C++ on a Broader Range of
//! Workloads”* (CS.DC 2025), which itself reimplements GBDI from HPCA'22
//! (Angerd et al.).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (build-time Python): k-means assignment /
//!   centroid update / compressed-size estimation, tiled for VMEM + MXU.
//! * **L2** — JAX analysis graphs (build-time Python): the full background
//!   data-analysis loop, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** — this crate: the bit-exact compression/decompression engines,
//!   global-base-table lifecycle, workload substrate, compressed-memory
//!   simulator, and a serving-style [`coordinator`] that runs the L2
//!   artifacts through PJRT ([`runtime`]) off the hot path.
//!
//! Quickstart:
//!
//! ```
//! use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
//! use gbdi::workloads;
//!
//! // 1 MiB of mcf-like memory content.
//! let image = workloads::by_name("mcf").unwrap().generate(1 << 20, 7);
//! // Background analysis -> global base table.
//! let cfg = GbdiConfig::default();
//! let table = analyze::analyze_image(&image, &cfg);
//! let codec = GbdiCodec::new(table, cfg);
//! let compressed = codec.compress_image(&image);
//! let restored = gbdi::gbdi::decode::decompress_image(&compressed).unwrap();
//! assert_eq!(restored, image);
//! ```

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod elf;
pub mod gbdi;
pub mod memsim;
pub mod report;
pub mod runtime;
pub mod util;
pub mod value;
pub mod workloads;

pub use gbdi::{GbdiCodec, GbdiConfig};

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Compressed stream is malformed (truncated, bad tag, bad table id...).
    #[error("corrupt compressed stream: {0}")]
    Corrupt(String),
    /// ELF parse errors from the dump substrate.
    #[error("elf: {0}")]
    Elf(String),
    /// PJRT / XLA runtime errors.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Configuration errors (bad K, bad width classes, ...).
    #[error("config: {0}")]
    Config(String),
    /// I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
