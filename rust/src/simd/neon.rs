//! aarch64 NEON kernels. NEON (ASIMD) is baseline for the
//! `aarch64-unknown-linux-gnu` target, so every entry point is safe
//! code with small `unsafe` blocks around the intrinsics; loads go
//! through `vld1q_u8` (alignment-free) and lane layouts match the
//! little-endian byte order of the wire format.

#[allow(clippy::wildcard_imports)]
use std::arch::aarch64::*;

use crate::baselines::bdi::{plan_fits, plan_fits_from};

/// NEON `all_zero`: 16-byte horizontal max per chunk.
pub fn all_zero_neon(b: &[u8]) -> bool {
    let mut i = 0;
    unsafe {
        while i + 16 <= b.len() {
            if vmaxvq_u8(vld1q_u8(b.as_ptr().add(i))) != 0 {
                return false;
            }
            i += 16;
        }
    }
    b[i..].iter().all(|&x| x == 0)
}

/// NEON `rep_words`: splat the leading pattern, compare 16 bytes at a
/// time (all-equal iff the lane-wise minimum of the compare mask is
/// saturated). Strides 2/4/8 vectorize; anything else is scalar.
pub fn rep_words_neon(b: &[u8], stride: usize) -> bool {
    debug_assert!(stride > 0 && !b.is_empty() && b.len() % stride == 0);
    let pat = unsafe {
        match stride {
            2 => vreinterpretq_u8_u16(vdupq_n_u16(u16::from_le_bytes([b[0], b[1]]))),
            4 => vreinterpretq_u8_u32(vdupq_n_u32(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))),
            8 => vreinterpretq_u8_u64(vdupq_n_u64(u64::from_le_bytes(b[..8].try_into().unwrap()))),
            _ => return crate::simd::scalar::rep_words(b, stride),
        }
    };
    let mut i = 0;
    unsafe {
        while i + 16 <= b.len() {
            let eq = vceqq_u8(vld1q_u8(b.as_ptr().add(i)), pat);
            if vminvq_u8(eq) != 0xFF {
                return false;
            }
            i += 16;
        }
    }
    b[i..].chunks_exact(stride).all(|c| c == &b[..stride])
}

/// NEON first-fit over the coverage-interval SoA. NEON compares
/// unsigned natively (`vcleq_u32`); the first fitting lane is recovered
/// by spilling the mask.
pub fn first_fit_neon(v: u32, lo: &[u32], span: &[u32]) -> Option<usize> {
    let n = lo.len().min(span.len());
    let mut i = 0;
    unsafe {
        let vv = vdupq_n_u32(v);
        while i + 4 <= n {
            let l = vld1q_u32(lo.as_ptr().add(i));
            let s = vld1q_u32(span.as_ptr().add(i));
            let fit = vcleq_u32(vsubq_u32(vv, l), s);
            if vmaxvq_u32(fit) != 0 {
                let mut m = [0u32; 4];
                vst1q_u32(m.as_mut_ptr(), fit);
                for (j, &f) in m.iter().enumerate() {
                    if f != 0 {
                        return Some(i + j);
                    }
                }
            }
            i += 4;
        }
    }
    while i < n {
        if v.wrapping_sub(lo[i]) <= span[i] {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// NEON GBDI W32 apply: scalar gather into a lane buffer, vector add,
/// byte store (little-endian lane order matches the wire).
pub fn gbdi_apply_w32_neon(adj: &[u32], ptrs: &[u32], raws: &[u32], out: &mut [u8]) {
    let n = ptrs.len().min(raws.len()).min(out.len() / 4);
    let mut i = 0;
    unsafe {
        while i + 4 <= n {
            let a = [
                adj[ptrs[i] as usize],
                adj[ptrs[i + 1] as usize],
                adj[ptrs[i + 2] as usize],
                adj[ptrs[i + 3] as usize],
            ];
            let v = vaddq_u32(vld1q_u32(a.as_ptr()), vld1q_u32(raws.as_ptr().add(i)));
            vst1q_u8(out.as_mut_ptr().add(4 * i), vreinterpretq_u8_u32(v));
            i += 4;
        }
    }
    while i < n {
        let v = adj[ptrs[i] as usize].wrapping_add(raws[i]);
        out[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        i += 1;
    }
}

/// NEON BDI feasibility. k=4 and k=2 vectorize; k=8 stays scalar (no
/// horizontal min over 64-bit lanes worth the shuffle tax at n=8).
pub fn bdi_fits_neon(block: &[u8], k: usize, d: usize) -> bool {
    match k {
        4 => bdi_fits_k4_neon(block, d),
        2 => bdi_fits_k2_neon(block, d),
        _ => plan_fits(block, k, d),
    }
}

/// Same streaming single-pass shape as the x86 kernels (see
/// `x86::bdi_fits_k4_sse2`): zero-fit lanes via `(v + bias) <u limit`,
/// latch the first miss as the block base, re-test the chunk with
/// `zero-fit OR base-fit`.
fn bdi_fits_k4_neon(block: &[u8], d: usize) -> bool {
    let n = block.len() / 4;
    let bias = 1u32 << (8 * d - 1);
    let limit = 1u32 << (8 * d);
    let mut base: Option<u32> = None;
    let mut i = 0;
    unsafe {
        let biasv = vdupq_n_u32(bias);
        let limitv = vdupq_n_u32(limit);
        while i + 4 <= n {
            let v = vreinterpretq_u32_u8(vld1q_u8(block.as_ptr().add(4 * i)));
            let zfit = vcltq_u32(vaddq_u32(v, biasv), limitv);
            if vminvq_u32(zfit) != u32::MAX {
                let b = match base {
                    Some(b) => b,
                    None => {
                        let mut m = [0u32; 4];
                        vst1q_u32(m.as_mut_ptr(), zfit);
                        let j = m.iter().position(|&f| f == 0).unwrap();
                        let o = 4 * (i + j);
                        let b = u32::from_le_bytes(block[o..o + 4].try_into().unwrap());
                        base = Some(b);
                        b
                    }
                };
                let bfit = vcltq_u32(vaddq_u32(vsubq_u32(v, vdupq_n_u32(b)), biasv), limitv);
                if vminvq_u32(vorrq_u32(zfit, bfit)) != u32::MAX {
                    return false;
                }
            }
            i += 4;
        }
    }
    plan_fits_from(block, 4, d, i, base.map(u64::from))
}

fn bdi_fits_k2_neon(block: &[u8], d: usize) -> bool {
    debug_assert_eq!(d, 1, "the BDI menu only pairs k=2 with d=1");
    let n = block.len() / 2;
    let mut base: Option<u16> = None;
    let mut i = 0;
    unsafe {
        let biasv = vdupq_n_u16(0x80);
        let limitv = vdupq_n_u16(0x100);
        while i + 8 <= n {
            let v = vreinterpretq_u16_u8(vld1q_u8(block.as_ptr().add(2 * i)));
            let zfit = vcltq_u16(vaddq_u16(v, biasv), limitv);
            if vminvq_u16(zfit) != u16::MAX {
                let b = match base {
                    Some(b) => b,
                    None => {
                        let mut m = [0u16; 8];
                        vst1q_u16(m.as_mut_ptr(), zfit);
                        let j = m.iter().position(|&f| f == 0).unwrap();
                        let o = 2 * (i + j);
                        let b = u16::from_le_bytes([block[o], block[o + 1]]);
                        base = Some(b);
                        b
                    }
                };
                let bfit = vcltq_u16(vaddq_u16(vsubq_u16(v, vdupq_n_u16(b)), biasv), limitv);
                if vminvq_u16(vorrq_u16(zfit, bfit)) != u16::MAX {
                    return false;
                }
            }
            i += 8;
        }
    }
    plan_fits_from(block, 2, d, i, base.map(u64::from))
}
