//! Portable scalar kernels — the semantics every vector backend must
//! reproduce exactly (including first-fit *index* choice, which is
//! wire-visible through GBDI base pointers). These double as the
//! fallback vtable for hosts with no vector backend and as the oracle
//! for the differential property tests in `tests/simd_kernels.rs`.

/// True iff every byte of `b` is zero.
pub fn all_zero(b: &[u8]) -> bool {
    b.iter().all(|&x| x == 0)
}

/// True iff `b` is one `stride`-byte pattern repeated. Callers
/// guarantee `stride > 0`, a non-empty slice, and `len % stride == 0`
/// (block lengths are validated against the word size at config build).
pub fn rep_words(b: &[u8], stride: usize) -> bool {
    debug_assert!(stride > 0 && !b.is_empty() && b.len() % stride == 0);
    let (first, rest) = b.split_at(stride);
    rest.chunks_exact(stride).all(|c| c == first)
}

/// BDI `(k, d)` feasibility — the scalar scan from `baselines::bdi`,
/// re-exported into the vtable shape.
pub fn bdi_fits(block: &[u8], k: usize, d: usize) -> bool {
    crate::baselines::bdi::plan_fits(block, k, d)
}

/// First index `i` with `(v - lo[i]) mod 2^32 <= span[i]` — the wrapped
/// coverage-interval test of the base-table bucket walk, in branchless
/// form.
pub fn first_fit(v: u32, lo: &[u32], span: &[u32]) -> Option<usize> {
    lo.iter().zip(span).position(|(&l, &s)| v.wrapping_sub(l) <= s)
}

/// GBDI W32 apply phase: `out[4i..4i+4] = le(adj[ptrs[i]] + raws[i])`
/// with wrapping u32 arithmetic (the offset-binary bias is already
/// folded into `adj`).
pub fn gbdi_apply_w32(adj: &[u32], ptrs: &[u32], raws: &[u32], out: &mut [u8]) {
    for ((&p, &r), o) in ptrs.iter().zip(raws).zip(out.chunks_exact_mut(4)) {
        let v = adj[p as usize].wrapping_add(r);
        o.copy_from_slice(&v.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rep_and_zero_scans() {
        assert!(all_zero(&[0; 64]));
        assert!(!all_zero(&[0, 0, 1, 0]));
        assert!(rep_words(&[7, 8, 7, 8, 7, 8], 2));
        assert!(!rep_words(&[7, 8, 7, 9, 7, 8], 2));
        assert!(rep_words(&[5; 24], 8));
    }

    #[test]
    fn first_fit_is_first() {
        // both candidates fit v=10; the first must win
        let lo = [8u32, 9];
        let span = [4u32, 4];
        assert_eq!(first_fit(10, &lo, &span), Some(0));
        assert_eq!(first_fit(14, &lo, &span), None);
        // wrapped interval: lo near u32::MAX covering small values
        assert_eq!(first_fit(1, &[u32::MAX - 1], &[3]), Some(0));
        assert_eq!(first_fit(3, &[u32::MAX - 1], &[3]), None);
        assert_eq!(first_fit(5, &[], &[]), None);
    }

    #[test]
    fn apply_writes_le_words() {
        let adj = [100u32, u32::MAX];
        let ptrs = [0u32, 1, 0];
        let raws = [1u32, 2, 0xFFFF_FFFF];
        let mut out = [0u8; 12];
        gbdi_apply_w32(&adj, &ptrs, &raws, &mut out);
        assert_eq!(&out[0..4], &101u32.to_le_bytes());
        assert_eq!(&out[4..8], &1u32.to_le_bytes()); // wraps
        assert_eq!(&out[8..12], &99u32.to_le_bytes()); // wraps
    }
}
