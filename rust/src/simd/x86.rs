//! x86_64 kernels: SSE2 (baseline for the target, callable from safe
//! code) and AVX2 (runtime-detected; every AVX2 entry point is a safe
//! wrapper whose `#[target_feature]` inner function is only reachable
//! through the AVX2 vtable, which the dispatch layer hands out only
//! after `is_x86_feature_detected!("avx2")`).
//!
//! Unsigned lane comparisons (which SSE2/AVX2 lack) use the classic
//! sign-bit-flip identity: `a <u b  <=>  (a ^ MIN) <s (b ^ MIN)`.
//! Lane layouts and the equivalence arguments are written up in
//! DESIGN.md §10.

#[allow(clippy::wildcard_imports)]
use std::arch::x86_64::*;

use crate::baselines::bdi::{plan_fits, plan_fits_from};

// ---------------------------------------------------------------- SSE2

/// SSE2 `all_zero`: 16-byte compare + movemask, scalar tail.
pub fn all_zero_sse2(b: &[u8]) -> bool {
    let mut i = 0;
    unsafe {
        let zero = _mm_setzero_si128();
        while i + 16 <= b.len() {
            let v = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            if _mm_movemask_epi8(_mm_cmpeq_epi8(v, zero)) != 0xFFFF {
                return false;
            }
            i += 16;
        }
    }
    b[i..].iter().all(|&x| x == 0)
}

/// SSE2 `rep_words`: splat the leading pattern across a register and
/// compare 16 bytes at a time. Strides 2/4/8 (the word sizes the codecs
/// use) vectorize; anything else falls back to scalar.
pub fn rep_words_sse2(b: &[u8], stride: usize) -> bool {
    debug_assert!(stride > 0 && !b.is_empty() && b.len() % stride == 0);
    let pat = match stride {
        2 => unsafe { _mm_set1_epi16(i16::from_le_bytes([b[0], b[1]])) },
        4 => unsafe { _mm_set1_epi32(i32::from_le_bytes([b[0], b[1], b[2], b[3]])) },
        8 => unsafe { _mm_set1_epi64x(i64::from_le_bytes(b[..8].try_into().unwrap())) },
        _ => return crate::simd::scalar::rep_words(b, stride),
    };
    let mut i = 0;
    unsafe {
        while i + 16 <= b.len() {
            let v = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            if _mm_movemask_epi8(_mm_cmpeq_epi8(v, pat)) != 0xFFFF {
                return false;
            }
            i += 16;
        }
    }
    // stride divides 16, so the tail is whole strides
    b[i..].chunks_exact(stride).all(|c| c == &b[..stride])
}

/// SSE2 first-fit over the coverage-interval SoA: 4 candidates per
/// compare, lowest set movemask bit = first fitting index.
pub fn first_fit_sse2(v: u32, lo: &[u32], span: &[u32]) -> Option<usize> {
    let n = lo.len().min(span.len());
    let mut i = 0;
    unsafe {
        let sign = _mm_set1_epi32(i32::MIN);
        let vv = _mm_set1_epi32(v as i32);
        while i + 4 <= n {
            let l = _mm_loadu_si128(lo.as_ptr().add(i) as *const __m128i);
            let s = _mm_loadu_si128(span.as_ptr().add(i) as *const __m128i);
            let t = _mm_sub_epi32(vv, l);
            // t <=u s  <=>  !(t >u s), via the sign-flip identity
            let gt = _mm_cmpgt_epi32(_mm_xor_si128(t, sign), _mm_xor_si128(s, sign));
            let fit = !_mm_movemask_ps(_mm_castsi128_ps(gt)) & 0xF;
            if fit != 0 {
                return Some(i + fit.trailing_zeros() as usize);
            }
            i += 4;
        }
    }
    while i < n {
        if v.wrapping_sub(lo[i]) <= span[i] {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// SSE2 GBDI W32 apply: scalar gather of `adj[ptrs[i]]` into a lane
/// buffer, vector add against the raw fields, unaligned store.
pub fn gbdi_apply_w32_sse2(adj: &[u32], ptrs: &[u32], raws: &[u32], out: &mut [u8]) {
    let n = ptrs.len().min(raws.len()).min(out.len() / 4);
    let mut i = 0;
    unsafe {
        while i + 4 <= n {
            let a = _mm_set_epi32(
                adj[ptrs[i + 3] as usize] as i32,
                adj[ptrs[i + 2] as usize] as i32,
                adj[ptrs[i + 1] as usize] as i32,
                adj[ptrs[i] as usize] as i32,
            );
            let r = _mm_loadu_si128(raws.as_ptr().add(i) as *const __m128i);
            let v = _mm_add_epi32(a, r);
            _mm_storeu_si128(out.as_mut_ptr().add(4 * i) as *mut __m128i, v);
            i += 4;
        }
    }
    while i < n {
        let v = adj[ptrs[i] as usize].wrapping_add(raws[i]);
        out[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        i += 1;
    }
}

/// SSE2 BDI feasibility. k=4 and k=2 vectorize (32-/16-bit lanes); k=8
/// needs 64-bit unsigned compares SSE2 lacks, so it stays scalar.
pub fn bdi_fits_sse2(block: &[u8], k: usize, d: usize) -> bool {
    match k {
        4 => bdi_fits_k4_sse2(block, d),
        2 => bdi_fits_k2_sse2(block, d),
        _ => plan_fits(block, k, d),
    }
}

/// The streaming one-pass shape shared by all vector BDI kernels: per
/// chunk, lane-test the zero base (`(v + bias) <u limit`, the unsigned
/// form of the sign-fit check); on the first lane that misses, latch
/// that word as the block base and re-test the chunk against
/// `zero-fit OR base-fit`. Any lane failing both kills the encoding —
/// exactly the scalar scan's accept set, word for word.
fn bdi_fits_k4_sse2(block: &[u8], d: usize) -> bool {
    let n = block.len() / 4;
    let bias = 1u32 << (8 * d - 1);
    let limit = 1u32 << (8 * d);
    let mut base: Option<u32> = None;
    let mut i = 0;
    unsafe {
        let sign = _mm_set1_epi32(i32::MIN);
        let biasv = _mm_set1_epi32(bias as i32);
        let limitx = _mm_set1_epi32((limit ^ 0x8000_0000u32) as i32);
        while i + 4 <= n {
            let v = _mm_loadu_si128(block.as_ptr().add(4 * i) as *const __m128i);
            let t = _mm_add_epi32(v, biasv);
            let zfit = _mm_cmpgt_epi32(limitx, _mm_xor_si128(t, sign));
            let zbits = _mm_movemask_ps(_mm_castsi128_ps(zfit));
            if zbits != 0xF {
                let b = match base {
                    Some(b) => b,
                    None => {
                        let j = ((!zbits & 0xF) as u32).trailing_zeros() as usize;
                        let o = 4 * (i + j);
                        let b = u32::from_le_bytes(block[o..o + 4].try_into().unwrap());
                        base = Some(b);
                        b
                    }
                };
                let t2 = _mm_add_epi32(_mm_sub_epi32(v, _mm_set1_epi32(b as i32)), biasv);
                let bfit = _mm_cmpgt_epi32(limitx, _mm_xor_si128(t2, sign));
                if _mm_movemask_ps(_mm_castsi128_ps(_mm_or_si128(zfit, bfit))) != 0xF {
                    return false;
                }
            }
            i += 4;
        }
    }
    plan_fits_from(block, 4, d, i, base.map(u64::from))
}

fn bdi_fits_k2_sse2(block: &[u8], d: usize) -> bool {
    debug_assert_eq!(d, 1, "the BDI menu only pairs k=2 with d=1");
    let n = block.len() / 2;
    let mut base: Option<u16> = None;
    let mut i = 0;
    unsafe {
        let sign = _mm_set1_epi16(i16::MIN);
        let biasv = _mm_set1_epi16(0x80);
        let limitx = _mm_set1_epi16((0x100u16 ^ 0x8000) as i16);
        while i + 8 <= n {
            let v = _mm_loadu_si128(block.as_ptr().add(2 * i) as *const __m128i);
            let t = _mm_add_epi16(v, biasv);
            let zfit = _mm_cmpgt_epi16(limitx, _mm_xor_si128(t, sign));
            let zbits = _mm_movemask_epi8(zfit); // 2 mask bits per u16 lane
            if zbits != 0xFFFF {
                let b = match base {
                    Some(b) => b,
                    None => {
                        let lane = ((!zbits & 0xFFFF) as u32).trailing_zeros() as usize / 2;
                        let o = 2 * (i + lane);
                        let b = u16::from_le_bytes([block[o], block[o + 1]]);
                        base = Some(b);
                        b
                    }
                };
                let t2 = _mm_add_epi16(_mm_sub_epi16(v, _mm_set1_epi16(b as i16)), biasv);
                let bfit = _mm_cmpgt_epi16(limitx, _mm_xor_si128(t2, sign));
                if _mm_movemask_epi8(_mm_or_si128(zfit, bfit)) != 0xFFFF {
                    return false;
                }
            }
            i += 8;
        }
    }
    plan_fits_from(block, 2, d, i, base.map(u64::from))
}

// ---------------------------------------------------------------- AVX2
//
// Safe wrappers + `#[target_feature(enable = "avx2")]` inner functions.
// The wrappers are only installed in the AVX2 vtable, which the
// dispatch layer refuses to hand out on hosts without AVX2.

/// AVX2 `all_zero` (32-byte compares).
pub fn all_zero_avx2(b: &[u8]) -> bool {
    debug_assert!(crate::simd::Isa::Avx2.supported());
    unsafe { all_zero_avx2_impl(b) }
}

#[target_feature(enable = "avx2")]
unsafe fn all_zero_avx2_impl(b: &[u8]) -> bool {
    let mut i = 0;
    let zero = _mm256_setzero_si256();
    while i + 32 <= b.len() {
        let v = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        if _mm256_movemask_epi8(_mm256_cmpeq_epi8(v, zero)) as u32 != u32::MAX {
            return false;
        }
        i += 32;
    }
    b[i..].iter().all(|&x| x == 0)
}

/// AVX2 `rep_words` (32-byte compares against the splatted pattern).
pub fn rep_words_avx2(b: &[u8], stride: usize) -> bool {
    debug_assert!(crate::simd::Isa::Avx2.supported());
    unsafe { rep_words_avx2_impl(b, stride) }
}

#[target_feature(enable = "avx2")]
unsafe fn rep_words_avx2_impl(b: &[u8], stride: usize) -> bool {
    debug_assert!(stride > 0 && !b.is_empty() && b.len() % stride == 0);
    let pat = match stride {
        2 => _mm256_set1_epi16(i16::from_le_bytes([b[0], b[1]])),
        4 => _mm256_set1_epi32(i32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        8 => _mm256_set1_epi64x(i64::from_le_bytes(b[..8].try_into().unwrap())),
        _ => return crate::simd::scalar::rep_words(b, stride),
    };
    let mut i = 0;
    while i + 32 <= b.len() {
        let v = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        if _mm256_movemask_epi8(_mm256_cmpeq_epi8(v, pat)) as u32 != u32::MAX {
            return false;
        }
        i += 32;
    }
    b[i..].chunks_exact(stride).all(|c| c == &b[..stride])
}

/// AVX2 first-fit (8 candidates per compare).
pub fn first_fit_avx2(v: u32, lo: &[u32], span: &[u32]) -> Option<usize> {
    debug_assert!(crate::simd::Isa::Avx2.supported());
    unsafe { first_fit_avx2_impl(v, lo, span) }
}

#[target_feature(enable = "avx2")]
unsafe fn first_fit_avx2_impl(v: u32, lo: &[u32], span: &[u32]) -> Option<usize> {
    let n = lo.len().min(span.len());
    let sign = _mm256_set1_epi32(i32::MIN);
    let vv = _mm256_set1_epi32(v as i32);
    let mut i = 0;
    while i + 8 <= n {
        let l = _mm256_loadu_si256(lo.as_ptr().add(i) as *const __m256i);
        let s = _mm256_loadu_si256(span.as_ptr().add(i) as *const __m256i);
        let t = _mm256_sub_epi32(vv, l);
        let gt = _mm256_cmpgt_epi32(_mm256_xor_si256(t, sign), _mm256_xor_si256(s, sign));
        let fit = !_mm256_movemask_ps(_mm256_castsi256_ps(gt)) & 0xFF;
        if fit != 0 {
            return Some(i + fit.trailing_zeros() as usize);
        }
        i += 8;
    }
    while i < n {
        if v.wrapping_sub(lo[i]) <= span[i] {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// AVX2 GBDI W32 apply (8 words per add; the gather stays scalar — the
/// LUT is small and hot in L1, where scalar loads beat the latency of
/// the hardware gather on every pre-Icelake core CI might schedule).
pub fn gbdi_apply_w32_avx2(adj: &[u32], ptrs: &[u32], raws: &[u32], out: &mut [u8]) {
    debug_assert!(crate::simd::Isa::Avx2.supported());
    unsafe { gbdi_apply_w32_avx2_impl(adj, ptrs, raws, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn gbdi_apply_w32_avx2_impl(adj: &[u32], ptrs: &[u32], raws: &[u32], out: &mut [u8]) {
    let n = ptrs.len().min(raws.len()).min(out.len() / 4);
    let mut i = 0;
    while i + 8 <= n {
        let mut a = [0u32; 8];
        for (j, slot) in a.iter_mut().enumerate() {
            *slot = adj[ptrs[i + j] as usize];
        }
        let av = _mm256_loadu_si256(a.as_ptr() as *const __m256i);
        let rv = _mm256_loadu_si256(raws.as_ptr().add(i) as *const __m256i);
        let v = _mm256_add_epi32(av, rv);
        _mm256_storeu_si256(out.as_mut_ptr().add(4 * i) as *mut __m256i, v);
        i += 8;
    }
    while i < n {
        let v = adj[ptrs[i] as usize].wrapping_add(raws[i]);
        out[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        i += 1;
    }
}

/// AVX2 BDI feasibility: every menu width vectorizes (k=8 via the
/// AVX2-only 64-bit compare).
pub fn bdi_fits_avx2(block: &[u8], k: usize, d: usize) -> bool {
    debug_assert!(crate::simd::Isa::Avx2.supported());
    unsafe {
        match k {
            8 => bdi_fits_k8_avx2(block, d),
            4 => bdi_fits_k4_avx2(block, d),
            2 => bdi_fits_k2_avx2(block, d),
            _ => plan_fits(block, k, d),
        }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn bdi_fits_k8_avx2(block: &[u8], d: usize) -> bool {
    let n = block.len() / 8;
    let bias = 1i64 << (8 * d as u32 - 1);
    let limit = 1i64 << (8 * d as u32); // d <= 4, so <= 2^32: no overflow
    let sign = _mm256_set1_epi64x(i64::MIN);
    let biasv = _mm256_set1_epi64x(bias);
    let limitx = _mm256_set1_epi64x(limit ^ i64::MIN);
    let mut base: Option<u64> = None;
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_loadu_si256(block.as_ptr().add(8 * i) as *const __m256i);
        let t = _mm256_add_epi64(v, biasv);
        let zfit = _mm256_cmpgt_epi64(limitx, _mm256_xor_si256(t, sign));
        let zbits = _mm256_movemask_pd(_mm256_castsi256_pd(zfit));
        if zbits != 0xF {
            let b = match base {
                Some(b) => b,
                None => {
                    let j = ((!zbits & 0xF) as u32).trailing_zeros() as usize;
                    let o = 8 * (i + j);
                    let b = u64::from_le_bytes(block[o..o + 8].try_into().unwrap());
                    base = Some(b);
                    b
                }
            };
            let t2 = _mm256_add_epi64(_mm256_sub_epi64(v, _mm256_set1_epi64x(b as i64)), biasv);
            let bfit = _mm256_cmpgt_epi64(limitx, _mm256_xor_si256(t2, sign));
            if _mm256_movemask_pd(_mm256_castsi256_pd(_mm256_or_si256(zfit, bfit))) != 0xF {
                return false;
            }
        }
        i += 4;
    }
    plan_fits_from(block, 8, d, i, base)
}

#[target_feature(enable = "avx2")]
unsafe fn bdi_fits_k4_avx2(block: &[u8], d: usize) -> bool {
    let n = block.len() / 4;
    let bias = 1u32 << (8 * d - 1);
    let limit = 1u32 << (8 * d);
    let sign = _mm256_set1_epi32(i32::MIN);
    let biasv = _mm256_set1_epi32(bias as i32);
    let limitx = _mm256_set1_epi32((limit ^ 0x8000_0000u32) as i32);
    let mut base: Option<u32> = None;
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_si256(block.as_ptr().add(4 * i) as *const __m256i);
        let t = _mm256_add_epi32(v, biasv);
        let zfit = _mm256_cmpgt_epi32(limitx, _mm256_xor_si256(t, sign));
        let zbits = _mm256_movemask_ps(_mm256_castsi256_ps(zfit));
        if zbits != 0xFF {
            let b = match base {
                Some(b) => b,
                None => {
                    let j = ((!zbits & 0xFF) as u32).trailing_zeros() as usize;
                    let o = 4 * (i + j);
                    let b = u32::from_le_bytes(block[o..o + 4].try_into().unwrap());
                    base = Some(b);
                    b
                }
            };
            let t2 = _mm256_add_epi32(_mm256_sub_epi32(v, _mm256_set1_epi32(b as i32)), biasv);
            let bfit = _mm256_cmpgt_epi32(limitx, _mm256_xor_si256(t2, sign));
            if _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_or_si256(zfit, bfit))) != 0xFF {
                return false;
            }
        }
        i += 8;
    }
    plan_fits_from(block, 4, d, i, base.map(u64::from))
}

#[target_feature(enable = "avx2")]
unsafe fn bdi_fits_k2_avx2(block: &[u8], d: usize) -> bool {
    debug_assert_eq!(d, 1, "the BDI menu only pairs k=2 with d=1");
    let n = block.len() / 2;
    let sign = _mm256_set1_epi16(i16::MIN);
    let biasv = _mm256_set1_epi16(0x80);
    let limitx = _mm256_set1_epi16((0x100u16 ^ 0x8000) as i16);
    let mut base: Option<u16> = None;
    let mut i = 0;
    while i + 16 <= n {
        let v = _mm256_loadu_si256(block.as_ptr().add(2 * i) as *const __m256i);
        let t = _mm256_add_epi16(v, biasv);
        let zfit = _mm256_cmpgt_epi16(limitx, _mm256_xor_si256(t, sign));
        let zbits = _mm256_movemask_epi8(zfit) as u32; // 2 bits per lane
        if zbits != u32::MAX {
            let b = match base {
                Some(b) => b,
                None => {
                    let lane = (!zbits).trailing_zeros() as usize / 2;
                    let o = 2 * (i + lane);
                    let b = u16::from_le_bytes([block[o], block[o + 1]]);
                    base = Some(b);
                    b
                }
            };
            let t2 = _mm256_add_epi16(_mm256_sub_epi16(v, _mm256_set1_epi16(b as i16)), biasv);
            let bfit = _mm256_cmpgt_epi16(limitx, _mm256_xor_si256(t2, sign));
            if _mm256_movemask_epi8(_mm256_or_si256(zfit, bfit)) as u32 != u32::MAX {
                return false;
            }
        }
        i += 16;
    }
    plan_fits_from(block, 2, d, i, base.map(u64::from))
}
