//! Runtime-dispatched SIMD kernels for the block-codec hot loops
//! (DESIGN.md §10).
//!
//! Three backends share one function-pointer vtable ([`Kernels`]):
//!
//! * **x86_64** — SSE2 (baseline, unconditionally present on the
//!   target) and AVX2 (detected at runtime via
//!   `is_x86_feature_detected!`), in [`x86`];
//! * **aarch64** — NEON (baseline on the target), in [`neon`];
//! * **portable** — the scalar reference kernels in [`scalar`], the
//!   differential-testing oracle every vector backend is property-tested
//!   against (`tests/simd_kernels.rs`).
//!
//! Dispatch resolves **once**: [`active`] returns a `'static` vtable
//! from a `OnceLock`, honoring the `GBDI_FORCE_ISA` env var (values
//! `scalar|sse2|avx2|neon`) and falling back to [`Isa::detect_best`].
//! [`force`] installs a process-wide override on top (the `--isa` CLI
//! flag and the per-ISA ablation in `benches/throughput.rs`); tests that
//! must not race on process-global state take a vtable directly via
//! [`kernels_for`] instead.
//!
//! Every kernel is observationally identical across backends — same
//! results, same first-fit *index* (base pointer indices are on the
//! wire, so the SIMD search must return the exact candidate the scalar
//! walk would). The wire format is untouched by ISA choice; only
//! throughput changes.

pub mod scalar;

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// The instruction-set backends the dispatch layer knows about.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable scalar kernels (every host; the differential reference).
    Scalar,
    /// x86_64 SSE2 — baseline for the target, no detection needed.
    Sse2,
    /// x86_64 AVX2 — requires runtime detection.
    Avx2,
    /// aarch64 NEON — baseline for the target.
    Neon,
}

impl Isa {
    /// All known backends, in ascending preference order.
    pub fn all() -> &'static [Isa] {
        &[Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon]
    }

    /// Stable lowercase name (CLI / env / bench-JSON vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a [`Self::name`] (case-insensitive; `none` aliases scalar).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "none" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Whether the current host can execute this backend.
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Sse2 => cfg!(target_arch = "x86_64"),
            Isa::Avx2 => avx2_supported(),
            Isa::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// The best backend the current host supports.
    pub fn detect_best() -> Isa {
        if Isa::Avx2.supported() {
            Isa::Avx2
        } else if Isa::Sse2.supported() {
            Isa::Sse2
        } else if Isa::Neon.supported() {
            Isa::Neon
        } else {
            Isa::Scalar
        }
    }

    fn as_index(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Sse2 => 1,
            Isa::Avx2 => 2,
            Isa::Neon => 3,
        }
    }

    fn from_index(b: u8) -> Isa {
        match b {
            1 => Isa::Sse2,
            2 => Isa::Avx2,
            3 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

/// The kernel vtable: one function pointer per vectorized hot loop. All
/// kernels are pure slice transforms — no allocation, no codec-internal
/// types — so backends stay trivially testable against [`scalar`].
pub struct Kernels {
    /// Which backend these kernels belong to.
    pub isa: Isa,
    /// True iff every byte of the slice is zero (ZERO block scans).
    pub all_zero: fn(&[u8]) -> bool,
    /// True iff the slice is one `stride`-byte pattern repeated (REP
    /// block scans). Callers guarantee `stride > 0`, a non-empty slice,
    /// and `len % stride == 0`.
    pub rep_words: fn(&[u8], usize) -> bool,
    /// BDI `(k, d)` feasibility: every k-byte word fits either the zero
    /// base or the block base (first non-zero-fitting word) in d bytes.
    /// Exact mirror of the scalar scan in `baselines::bdi`.
    pub bdi_fits: fn(&[u8], usize, usize) -> bool,
    /// First index `i` with `(v - lo[i]) mod 2^32 <= span[i]`, i.e. the
    /// first candidate whose wrapped coverage interval contains `v`.
    /// Must return the *first* fit — candidate order is wire-visible
    /// (the base pointer index is what gets emitted).
    pub first_fit: fn(u32, &[u32], &[u32]) -> Option<usize>,
    /// GBDI W32 apply phase: `out[4i..4i+4] = le(adj[ptrs[i]] + raws[i])`
    /// (wrapping u32 add) for every scanned word. `adj` is the LUT's
    /// bias-folded base array, `raws` the masked delta/outlier fields.
    pub gbdi_apply_w32: fn(&[u32], &[u32], &[u32], &mut [u8]),
}

static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    all_zero: scalar::all_zero,
    rep_words: scalar::rep_words,
    bdi_fits: scalar::bdi_fits,
    first_fit: scalar::first_fit,
    gbdi_apply_w32: scalar::gbdi_apply_w32,
};

#[cfg(target_arch = "x86_64")]
static SSE2: Kernels = Kernels {
    isa: Isa::Sse2,
    all_zero: x86::all_zero_sse2,
    rep_words: x86::rep_words_sse2,
    bdi_fits: x86::bdi_fits_sse2,
    first_fit: x86::first_fit_sse2,
    gbdi_apply_w32: x86::gbdi_apply_w32_sse2,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: Isa::Avx2,
    all_zero: x86::all_zero_avx2,
    rep_words: x86::rep_words_avx2,
    bdi_fits: x86::bdi_fits_avx2,
    first_fit: x86::first_fit_avx2,
    gbdi_apply_w32: x86::gbdi_apply_w32_avx2,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: Isa::Neon,
    all_zero: neon::all_zero_neon,
    rep_words: neon::rep_words_neon,
    bdi_fits: neon::bdi_fits_neon,
    first_fit: neon::first_fit_neon,
    gbdi_apply_w32: neon::gbdi_apply_w32_neon,
};

/// The vtable for a specific backend. Unsupported requests degrade to
/// scalar (never a crash), so differential tests can iterate
/// `Isa::all()` filtered by [`Isa::supported`] and callers that bypass
/// [`force`]'s validation still get a working vtable.
pub fn kernels_for(isa: Isa) -> &'static Kernels {
    if !isa.supported() {
        return &SCALAR;
    }
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => &SSE2,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => &NEON,
        _ => &SCALAR,
    }
}

/// Process-wide override installed by [`force`]: 0 = none, else
/// `Isa::as_index() + 1`. Reads are relaxed — every vtable computes
/// identical results, so a racing switch is observationally benign.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// The default vtable, resolved once: `GBDI_FORCE_ISA` if set and
/// supported (unsupported/unknown values warn and fall back), else the
/// best detected backend.
static DEFAULT: OnceLock<&'static Kernels> = OnceLock::new();

fn default_kernels() -> &'static Kernels {
    DEFAULT.get_or_init(|| {
        let isa = match std::env::var("GBDI_FORCE_ISA") {
            Ok(s) if !s.is_empty() => match Isa::parse(&s) {
                Some(isa) if isa.supported() => isa,
                Some(isa) => {
                    eprintln!(
                        "GBDI_FORCE_ISA={} unsupported on this host; using {}",
                        isa.name(),
                        Isa::detect_best().name()
                    );
                    Isa::detect_best()
                }
                None => {
                    eprintln!(
                        "GBDI_FORCE_ISA={s:?} unrecognized (scalar|sse2|avx2|neon); using {}",
                        Isa::detect_best().name()
                    );
                    Isa::detect_best()
                }
            },
            _ => Isa::detect_best(),
        };
        kernels_for(isa)
    })
}

/// The active kernel vtable — the one call every dispatch site makes.
/// Resolution order: [`force`] override, then the `OnceLock`'d default
/// (`GBDI_FORCE_ISA` / detection). Two relaxed atomic loads on the hot
/// path.
#[inline]
pub fn active() -> &'static Kernels {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_kernels(),
        b => kernels_for(Isa::from_index(b - 1)),
    }
}

/// Install (or with `None`, clear) a process-wide backend override —
/// the `--isa` flag and the bench ablation go through here. Errors when
/// the host cannot execute `isa`, leaving the previous selection in
/// place.
pub fn force(isa: Option<Isa>) -> std::result::Result<(), String> {
    match isa {
        None => {
            OVERRIDE.store(0, Ordering::Relaxed);
            Ok(())
        }
        Some(i) => {
            if !i.supported() {
                let names: Vec<&str> = supported().iter().map(|s| s.name()).collect();
                return Err(format!(
                    "isa '{}' is not supported on this host (supported: {})",
                    i.name(),
                    names.join(", ")
                ));
            }
            OVERRIDE.store(i.as_index() + 1, Ordering::Relaxed);
            Ok(())
        }
    }
}

/// The backends the current host can execute (always includes scalar).
pub fn supported() -> Vec<Isa> {
    Isa::all().iter().copied().filter(|i| i.supported()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_parse_roundtrip() {
        for &isa in Isa::all() {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert_eq!(Isa::parse(&isa.name().to_uppercase()), Some(isa));
            assert_eq!(Isa::from_index(isa.as_index()), isa);
        }
        assert_eq!(Isa::parse("none"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("sse9"), None);
    }

    #[test]
    fn detection_is_coherent() {
        let best = Isa::detect_best();
        assert!(best.supported());
        assert!(supported().contains(&Isa::Scalar));
        assert!(supported().contains(&best));
        // every supported backend hands out its own vtable; unsupported
        // ones degrade to scalar
        for &isa in Isa::all() {
            let k = kernels_for(isa);
            if isa.supported() {
                assert_eq!(k.isa, isa, "{}", isa.name());
            } else {
                assert_eq!(k.isa, Isa::Scalar, "{}", isa.name());
            }
        }
    }

    #[test]
    fn force_overrides_and_restores() {
        // scalar is supported everywhere, so the override path is always
        // exercisable; restore before returning so sibling tests see the
        // default dispatch
        assert!(force(Some(Isa::Scalar)).is_ok());
        assert_eq!(active().isa, Isa::Scalar);
        assert!(force(None).is_ok());
        assert_eq!(active().isa, default_kernels().isa);
        // an unsupported request errors and leaves the selection alone
        if let Some(&unsup) = Isa::all().iter().find(|i| !i.supported()) {
            let before = active().isa;
            assert!(force(Some(unsup)).is_err());
            assert_eq!(active().isa, before);
        }
    }
}
