//! Durability and elasticity: WAL, checkpoint segments, crash recovery,
//! and online shard resizing for the
//! [`ShardedPageStore`](crate::coordinator::store::ShardedPageStore).
//!
//! Layering (DESIGN.md §12):
//!
//! * [`vfs`] — the filesystem seam: [`vfs::RealFs`] for production,
//!   [`vfs::FaultFs`] for deterministic crash injection at every write,
//!   fsync, and rename boundary.
//! * [`wal`] — CRC-framed logical records (`GBW1`) with group commit.
//!   Every mutation is logged *before* it is applied; the cached write
//!   path logs at absorb time, so deferred dirty blocks are never lost.
//! * [`segment`] — per-shard checkpoint segments (`GBS1`) holding pages
//!   as frozen GBC1 containers, rooted by a manifest (`GBM1`) that also
//!   snapshots every published codec table (GBT2, wrapped in zero-image
//!   GBC1 containers).
//! * [`checkpoint`] — the atomic fold: segments, fsync, manifest
//!   rename, directory sync, *then* WAL reset.
//! * [`recover`] — manifest → segments → WAL replay; damage is counted
//!   in a [`RecoveryReport`], never silent and never a panic.
//!
//! [`Durability`] ties these together for the
//! [`CompressionService`](crate::coordinator::service::CompressionService)
//! (`gbdi serve --data-dir`), and [`DurableStore`] is the thin
//! store-plus-log facade the crash tests and `gbdi recover` drive.
//! With no `--data-dir` (the default) none of this is constructed and
//! every serving path is byte-identical to a persistence-free build.

pub mod checkpoint;
pub mod recover;
pub mod segment;
pub mod vfs;
pub mod wal;

pub use recover::RecoveryReport;
pub use vfs::{FaultFs, RealFs, Vfs, VfsFile};
pub use wal::{WalRecord, WalWriter};

use crate::codec::BlockCodec;
use crate::container;
use crate::coordinator::store::{ShardedPageStore, StoredPage};
use crate::frame::BlockWrite;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard};

/// WAL file name inside the data directory.
pub const WAL_FILE: &str = "wal.gbw";
/// Manifest file name inside the data directory.
pub const MANIFEST_FILE: &str = "MANIFEST.gbm";
/// Temp name the manifest is staged under before its atomic rename.
pub const MANIFEST_TMP: &str = "MANIFEST.tmp";

/// CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the same
/// checksum `zlib.crc32` computes, so the Python fixture generator
/// cross-checks every framed byte. One shared implementation
/// ([`crate::util::crc`]) backs both the durable framing here and the
/// in-memory integrity plane's page digests.
pub fn crc32(data: &[u8]) -> u32 {
    crate::util::crc::crc32(data)
}

/// Tunables for the durability layer (`[persist]` config section).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PersistConfig {
    /// Group-commit batch: fsync the WAL every this many appends.
    /// 1 (the default) is a strict WAL — every acknowledged mutation is
    /// durable; larger batches trade a crash window of up to
    /// `fsync_batch - 1` records for ingest throughput.
    pub fsync_batch: usize,
    /// Checkpoint once the WAL grows past this many bytes.
    pub wal_limit_bytes: u64,
}

impl Default for PersistConfig {
    fn default() -> Self {
        PersistConfig { fsync_batch: 1, wal_limit_bytes: 8 << 20 }
    }
}

/// The [`WalRecord`] that persists a whole page.
pub fn wal_put_page(page_id: u64, page: &StoredPage) -> WalRecord {
    WalRecord::PutPage { page_id, container: page.frame.to_container().to_bytes() }
}

/// The [`WalRecord`] that persists a codec-table publish: the codec
/// serialized as a zero-length-image GBC1 container (config + GBT2
/// table, no payload).
pub fn wal_publish_codec(codec: &Arc<dyn BlockCodec>) -> WalRecord {
    WalRecord::PublishCodec { container: container::compress(codec.as_ref(), &[]).to_bytes() }
}

/// The durability engine: owns the data directory, the WAL writer, the
/// checkpoint epoch, and the *apply gate* that makes `log → apply`
/// pairs atomic with respect to checkpoints.
///
/// Locking discipline: mutators hold the gate's **read** side across
/// their WAL append and store apply; [`Self::checkpoint`] takes the
/// **write** side, so it only runs when no logged-but-unapplied
/// mutation is in flight and no mutation can slip between the fold and
/// the WAL reset. The gate is never held while waiting on a shard lock
/// held by a gate holder (mutators acquire gate → wal → shard in that
/// order and checkpointing acquires gate → shard), so there is no
/// cycle.
pub struct Durability {
    vfs: Arc<dyn Vfs>,
    dir: String,
    cfg: PersistConfig,
    wal: Mutex<WalWriter>,
    gate: RwLock<()>,
    epoch: AtomicU64,
    checkpoints: AtomicU64,
    pending: Mutex<Option<ShardedPageStore>>,
}

impl std::fmt::Debug for Durability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Durability")
            .field("dir", &self.dir)
            .field("cfg", &self.cfg)
            .field("epoch", &self.epoch.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl Durability {
    /// Open (or create) a data directory: recover the store from the
    /// last good checkpoint + WAL, fold the result into a *fresh*
    /// checkpoint (so every open starts from clean segments and an
    /// empty WAL), and arm the WAL for appends. `shards` and
    /// `cache_bytes` shape the rebuilt store; a shard count differing
    /// from the manifest's triggers an online resize before the fold.
    ///
    /// The recovered store is parked inside and claimed once via
    /// [`Self::take_store`] (the service does this at start).
    pub fn open(
        vfs: Arc<dyn Vfs>,
        dir: &str,
        cfg: PersistConfig,
        shards: usize,
        cache_bytes: usize,
    ) -> Result<(Arc<Durability>, RecoveryReport)> {
        vfs.create_dir_all(dir)?;
        let (store, report) = recover::recover(vfs.as_ref(), dir, Some(shards), cache_bytes)?;
        // a placeholder writer: never appended to before the fold below
        // replaces it, and deliberately non-destructive so a crash
        // before the fold commits loses nothing
        let wal = if vfs.exists(&format!("{dir}/{WAL_FILE}")) {
            WalWriter::open_append(vfs.as_ref(), dir, report.wal_valid_bytes, cfg.fsync_batch)?
        } else {
            WalWriter::create(vfs.as_ref(), dir, cfg.fsync_batch)?
        };
        let d = Durability {
            vfs,
            dir: dir.to_string(),
            cfg,
            wal: Mutex::new(wal),
            gate: RwLock::new(()),
            epoch: AtomicU64::new(report.epoch),
            checkpoints: AtomicU64::new(0),
            pending: Mutex::new(None),
        };
        d.checkpoint(&store)?;
        *d.pending.lock().unwrap() = Some(store);
        Ok((Arc::new(d), report))
    }

    /// Claim the store recovered by [`Self::open`] (once).
    pub fn take_store(&self) -> Option<ShardedPageStore> {
        self.pending.lock().unwrap().take()
    }

    /// Enter the apply gate: hold the returned guard across a
    /// `log → apply` pair so a concurrent checkpoint cannot fold the
    /// store between the append and the store mutation.
    pub fn gate(&self) -> RwLockReadGuard<'_, ()> {
        self.gate.read().unwrap()
    }

    /// Append one record to the WAL (group commit applies).
    pub fn log(&self, rec: &WalRecord) -> Result<()> {
        self.wal.lock().unwrap().append(rec)
    }

    /// Append a batch of records under one WAL lock acquisition.
    pub fn log_all(&self, recs: &[WalRecord]) -> Result<()> {
        let mut wal = self.wal.lock().unwrap();
        for rec in recs {
            wal.append(rec)?;
        }
        Ok(())
    }

    /// Current WAL size in bytes.
    pub fn wal_bytes(&self) -> u64 {
        self.wal.lock().unwrap().bytes()
    }

    /// Whether the WAL has outgrown the configured checkpoint trigger.
    pub fn over_limit(&self) -> bool {
        self.wal_bytes() > self.cfg.wal_limit_bytes
    }

    /// Current checkpoint epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Checkpoints taken through this handle.
    pub fn checkpoints(&self) -> u64 {
        self.checkpoints.load(Ordering::Relaxed)
    }

    /// The configuration this engine runs under.
    pub fn config(&self) -> &PersistConfig {
        &self.cfg
    }

    /// Quiesce mutations (gate write side), flush the block cache, fold
    /// the store into fresh segments + manifest at the next epoch, then
    /// reset the WAL and drop old-epoch segments. Returns the new
    /// epoch.
    pub fn checkpoint(&self, store: &ShardedPageStore) -> Result<u64> {
        let _quiesce = self.gate.write().unwrap();
        store.flush_cache();
        let epoch = self.epoch.load(Ordering::Acquire) + 1;
        checkpoint::write_checkpoint(self.vfs.as_ref(), &self.dir, epoch, store)?;
        {
            let mut wal = self.wal.lock().unwrap();
            *wal = WalWriter::create(self.vfs.as_ref(), &self.dir, self.cfg.fsync_batch)?;
        }
        checkpoint::clean_stale_segments(self.vfs.as_ref(), &self.dir, epoch);
        self.epoch.store(epoch, Ordering::Release);
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        Ok(epoch)
    }

    /// [`Self::checkpoint`] only if the WAL is over its size limit.
    /// Returns whether a checkpoint ran. Racing callers are benign: the
    /// loser re-checks under the gate's serialization and folds a
    /// near-empty WAL.
    pub fn maybe_checkpoint(&self, store: &ShardedPageStore) -> Result<bool> {
        if !self.over_limit() {
            return Ok(false);
        }
        self.checkpoint(store)?;
        Ok(true)
    }

    /// Read one page's durable image — the integrity plane's self-heal
    /// source ([`recover::read_page`]): its checkpointed copy with every
    /// later WAL record for that page replayed on top. Runs under the
    /// apply gate's read side so a checkpoint cannot swap the manifest
    /// and reset the WAL mid-read; mutations logged after this call are
    /// simply not reflected, which is safe because
    /// [`ShardedPageStore::heal_page`](crate::coordinator::store::ShardedPageStore::heal_page)
    /// re-verifies the candidate before installing it.
    pub fn read_page(&self, page_id: u64) -> Result<Option<StoredPage>> {
        let _g = self.gate();
        self.wal.lock().unwrap().sync()?;
        recover::read_page(self.vfs.as_ref(), &self.dir, page_id)
    }
}

/// A [`ShardedPageStore`] whose every mutation is WAL-logged before it
/// applies: the facade `tests/durability.rs` crash-sweeps and
/// `gbdi recover --checkpoint` maintains. Reads go straight to the
/// store ([`Self::store`]).
pub struct DurableStore {
    store: ShardedPageStore,
    d: Arc<Durability>,
}

impl DurableStore {
    /// Open a data directory (see [`Durability::open`]) and wrap the
    /// recovered store.
    pub fn open(
        vfs: Arc<dyn Vfs>,
        dir: &str,
        cfg: PersistConfig,
        shards: usize,
        cache_bytes: usize,
    ) -> Result<(DurableStore, RecoveryReport)> {
        let (d, report) = Durability::open(vfs, dir, cfg, shards, cache_bytes)?;
        let store = d.take_store().expect("a fresh Durability holds the recovered store");
        Ok((DurableStore { store, d }, report))
    }

    /// The underlying store (reads and accounting).
    pub fn store(&self) -> &ShardedPageStore {
        &self.store
    }

    /// The durability engine (epoch, WAL size, metrics).
    pub fn durability(&self) -> &Arc<Durability> {
        &self.d
    }

    /// Log + publish a codec version.
    pub fn publish_codec(&self, codec: Arc<dyn BlockCodec>) -> Result<()> {
        let _g = self.d.gate();
        self.d.log(&wal_publish_codec(&codec))?;
        self.store.publish_codec(codec);
        Ok(())
    }

    /// Log + insert/overwrite a page.
    pub fn put(&self, page_id: u64, page: StoredPage) -> Result<()> {
        let _g = self.d.gate();
        self.d.log(&wal_put_page(page_id, &page))?;
        self.store.put(page_id, page);
        Ok(())
    }

    /// Log + recompress one block in place. Logged before it applies —
    /// on the cached path that is absorb time, so a deferred dirty
    /// block is durable long before eviction flushes it.
    pub fn write_block(&self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        let _g = self.d.gate();
        self.d.log(&WalRecord::WriteBlock {
            page_id,
            block: block as u32,
            data: data.to_vec(),
        })?;
        self.store.write_block(page_id, block, data)
    }

    /// Log + remove a page.
    pub fn remove(&self, page_id: u64) -> Result<Option<StoredPage>> {
        let _g = self.d.gate();
        self.d.log(&WalRecord::RemovePage { page_id })?;
        Ok(self.store.remove(page_id))
    }

    /// Log + resize the store to `shards` shards online. Returns pages
    /// rerouted.
    pub fn resize_shards(&self, shards: usize) -> Result<usize> {
        let moved = {
            let _g = self.d.gate();
            self.d.log(&WalRecord::Resize { shards: shards.max(1) as u32 })?;
            self.store.resize_shards(shards)
        };
        // rewrite segment ownership under the new topology right away,
        // so recovery cost stays proportional to the WAL, not to the
        // resize
        self.d.checkpoint(&self.store)?;
        Ok(moved)
    }

    /// Fold the WAL into a fresh checkpoint now. Returns the new epoch.
    pub fn checkpoint(&self) -> Result<u64> {
        self.d.checkpoint(&self.store)
    }

    /// Checkpoint only if the WAL outgrew its limit; returns whether it
    /// ran.
    pub fn maybe_checkpoint(&self) -> Result<bool> {
        self.d.maybe_checkpoint(&self.store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_page_rebuilds_checkpoint_plus_wal_state_for_one_page() {
        let vfs: Arc<dyn Vfs> = Arc::new(FaultFs::new());
        let (ds, _) =
            DurableStore::open(Arc::clone(&vfs), "d", PersistConfig::default(), 2, 0).unwrap();
        let codec: Arc<dyn BlockCodec> = Arc::new(crate::baselines::bdi::Bdi::default());
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        let mut scratch = crate::codec::Scratch::new();
        ds.put(
            1,
            StoredPage {
                frame: crate::frame::Frame::compress_with(Arc::clone(&codec), &data, &mut scratch),
            },
        )
        .unwrap();
        ds.checkpoint().unwrap();
        // post-checkpoint WAL mutations replay on top of the segment copy
        let line = [0xA5u8; 64];
        ds.write_block(1, 3, &line).unwrap();
        let got = ds.durability().read_page(1).unwrap().expect("durable copy exists");
        let mut expect = data.clone();
        expect[3 * 64..4 * 64].copy_from_slice(&line);
        assert_eq!(got.frame.decompress().unwrap(), expect);
        // absent pages and removed pages both come back as None
        assert!(ds.durability().read_page(99).unwrap().is_none());
        ds.remove(1).unwrap();
        assert!(ds.durability().read_page(1).unwrap().is_none());
    }

    #[test]
    fn crc32_matches_the_ieee_reference_vectors() {
        // the canonical zlib.crc32 test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"GBDI"), crc32(b"GBDI"));
        assert_ne!(crc32(b"GBDI"), crc32(b"GBDJ"));
    }
}
