//! The write-ahead log: CRC-framed logical records, group commit.
//!
//! File layout (`wal.gbw`, magic `GBW1`, little-endian throughout):
//!
//! ```text
//! "GBW1"
//! repeat: len u32 | crc u32 | payload[len]
//! payload: tag u8 | body
//!   1 PutPage     page_id u64 | GBC1 container bytes
//!   2 WriteBlock  page_id u64 | block u32 | block data
//!   3 RemovePage  page_id u64
//!   4 PublishCodec  GBC1 container bytes (zero-length image: the
//!                   codec config + GBT2 table snapshot, no payload)
//!   5 Resize      shards u32
//! ```
//!
//! `crc` covers the payload only. The log is append-only, so torn
//! writes and power loss can only damage the tail: [`scan_wal`] stops
//! at the first short or CRC-failing record and reports how many bytes
//! it abandoned, which recovery surfaces as metrics instead of
//! guessing at content past the damage.
//!
//! Durability contract: a record is durable once the writer has synced
//! past it. With `fsync_batch` = 1 every append syncs (strict WAL);
//! larger batches amortize fsync over the ingest stream and accept that
//! a crash may lose up to `fsync_batch - 1` acknowledged records — the
//! trade `benches/durability.rs` measures.

use super::vfs::{Vfs, VfsFile};
use super::{crc32, WAL_FILE};
use crate::Result;

/// WAL file magic (version byte baked into the name: a format change
/// means a new magic, never a silent re-interpretation).
pub const WAL_MAGIC: &[u8; 4] = b"GBW1";

/// One logical mutation, as logged. Containers are opaque GBC1 bytes —
/// the WAL reuses the frozen wire format instead of inventing a second
/// page serialization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    /// Insert/overwrite a whole page (its full compressed container).
    PutPage {
        /// The page id.
        page_id: u64,
        /// The page as GBC1 container bytes.
        container: Vec<u8>,
    },
    /// Recompress one block of a page in place. Logged at *absorb* time
    /// on the cached write path, so deferred dirty blocks are never
    /// lost.
    WriteBlock {
        /// The page id.
        page_id: u64,
        /// Block index within the page.
        block: u32,
        /// The block's new uncompressed content.
        data: Vec<u8>,
    },
    /// Remove a page.
    RemovePage {
        /// The page id.
        page_id: u64,
    },
    /// Publish a codec version: a zero-length-image GBC1 container
    /// carrying the codec config and GBT2 table.
    PublishCodec {
        /// The codec snapshot as GBC1 container bytes.
        container: Vec<u8>,
    },
    /// Online shard-count change.
    Resize {
        /// The new shard count.
        shards: u32,
    },
}

impl WalRecord {
    /// Append this record's payload (tag + body, no framing) to `out`.
    fn payload_into(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::PutPage { page_id, container } => {
                out.push(1);
                out.extend_from_slice(&page_id.to_le_bytes());
                out.extend_from_slice(container);
            }
            WalRecord::WriteBlock { page_id, block, data } => {
                out.push(2);
                out.extend_from_slice(&page_id.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
                out.extend_from_slice(data);
            }
            WalRecord::RemovePage { page_id } => {
                out.push(3);
                out.extend_from_slice(&page_id.to_le_bytes());
            }
            WalRecord::PublishCodec { container } => {
                out.push(4);
                out.extend_from_slice(container);
            }
            WalRecord::Resize { shards } => {
                out.push(5);
                out.extend_from_slice(&shards.to_le_bytes());
            }
        }
    }

    /// Append the framed form (`len | crc | payload`) to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut payload = Vec::new();
        self.payload_into(&mut payload);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
    }

    /// Decode a payload (tag + body). `None` on unknown tag or short
    /// body — the caller treats it like a CRC failure.
    pub fn decode_payload(p: &[u8]) -> Option<WalRecord> {
        let (&tag, body) = p.split_first()?;
        match tag {
            1 if body.len() >= 8 => Some(WalRecord::PutPage {
                page_id: u64::from_le_bytes(body[..8].try_into().ok()?),
                container: body[8..].to_vec(),
            }),
            2 if body.len() >= 12 => Some(WalRecord::WriteBlock {
                page_id: u64::from_le_bytes(body[..8].try_into().ok()?),
                block: u32::from_le_bytes(body[8..12].try_into().ok()?),
                data: body[12..].to_vec(),
            }),
            3 if body.len() == 8 => Some(WalRecord::RemovePage {
                page_id: u64::from_le_bytes(body[..8].try_into().ok()?),
            }),
            4 => Some(WalRecord::PublishCodec { container: body.to_vec() }),
            5 if body.len() == 4 => Some(WalRecord::Resize {
                shards: u32::from_le_bytes(body[..4].try_into().ok()?),
            }),
            _ => None,
        }
    }
}

/// Appending side of the WAL with group commit: every `fsync_batch`-th
/// append syncs the file (batch 1 = sync every record).
pub struct WalWriter {
    file: Box<dyn VfsFile>,
    fsync_batch: usize,
    pending: usize,
    bytes: u64,
    appends: u64,
    buf: Vec<u8>,
}

impl WalWriter {
    /// Create (truncate) the WAL at `dir/wal.gbw` and make the header
    /// durable.
    pub fn create(vfs: &dyn Vfs, dir: &str, fsync_batch: usize) -> Result<WalWriter> {
        let path = format!("{dir}/{WAL_FILE}");
        let mut file = vfs.create(&path)?;
        file.write_all(WAL_MAGIC)?;
        file.sync()?;
        Ok(WalWriter {
            file,
            fsync_batch: fsync_batch.max(1),
            pending: 0,
            bytes: WAL_MAGIC.len() as u64,
            appends: 0,
            buf: Vec::new(),
        })
    }

    /// Open the existing WAL at `dir/wal.gbw` for appending after
    /// `existing_bytes` of validated content.
    pub fn open_append(
        vfs: &dyn Vfs,
        dir: &str,
        existing_bytes: u64,
        fsync_batch: usize,
    ) -> Result<WalWriter> {
        let path = format!("{dir}/{WAL_FILE}");
        let file = vfs.open_append(&path)?;
        Ok(WalWriter {
            file,
            fsync_batch: fsync_batch.max(1),
            pending: 0,
            bytes: existing_bytes,
            appends: 0,
            buf: Vec::new(),
        })
    }

    /// Append one record; syncs when the group-commit batch fills.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        self.buf.clear();
        rec.encode_into(&mut self.buf);
        self.file.write_all(&self.buf)?;
        self.bytes += self.buf.len() as u64;
        self.appends += 1;
        self.pending += 1;
        if self.pending >= self.fsync_batch {
            self.sync()?;
        }
        Ok(())
    }

    /// Force any pending group commit to disk.
    pub fn sync(&mut self) -> Result<()> {
        if self.pending > 0 {
            self.file.sync()?;
            self.pending = 0;
        }
        Ok(())
    }

    /// Bytes written to the WAL so far (header included).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Records appended through this writer.
    pub fn appends(&self) -> u64 {
        self.appends
    }
}

/// What a WAL scan found: the valid record prefix plus damage counters.
#[derive(Debug, Default)]
pub struct WalScan {
    /// Decoded records, in append order.
    pub records: Vec<WalRecord>,
    /// Bytes of the valid prefix (header + intact records).
    pub valid_bytes: u64,
    /// Records abandoned to a CRC mismatch or undecodable payload
    /// (everything after the first is untrustworthy, so at most 1 is
    /// counted per scan).
    pub corrupt_records: u64,
    /// Trailing bytes abandoned (torn tail or post-corruption residue).
    pub truncated_bytes: u64,
    /// The file was missing its magic entirely (empty or foreign).
    pub missing_magic: bool,
}

/// Scan raw WAL bytes into the longest trustworthy record prefix.
/// Never fails: damage is reported, not propagated.
pub fn scan_wal(bytes: &[u8]) -> WalScan {
    let mut scan = WalScan::default();
    if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        scan.missing_magic = true;
        scan.truncated_bytes = bytes.len() as u64;
        return scan;
    }
    let mut at = WAL_MAGIC.len();
    loop {
        let rest = &bytes[at..];
        if rest.is_empty() {
            break;
        }
        if rest.len() < 8 {
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(rest[4..8].try_into().unwrap());
        if rest.len() < 8 + len {
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        let payload = &rest[8..8 + len];
        if crc32(payload) != crc {
            scan.corrupt_records = 1;
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        match WalRecord::decode_payload(payload) {
            Some(rec) => scan.records.push(rec),
            None => {
                scan.corrupt_records = 1;
                scan.truncated_bytes = rest.len() as u64;
                break;
            }
        }
        at += 8 + len;
    }
    scan.valid_bytes = at as u64;
    scan
}

#[cfg(test)]
mod tests {
    use super::super::vfs::FaultFs;
    use super::*;

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::PutPage { page_id: 7, container: vec![1, 2, 3, 4] },
            WalRecord::WriteBlock { page_id: 7, block: 3, data: vec![9; 64] },
            WalRecord::RemovePage { page_id: 8 },
            WalRecord::PublishCodec { container: vec![5, 6] },
            WalRecord::Resize { shards: 12 },
        ]
    }

    #[test]
    fn records_roundtrip_through_the_file_form() {
        let fs = FaultFs::new();
        let mut w = WalWriter::create(&fs, "d", 1).unwrap();
        for rec in sample_records() {
            w.append(&rec).unwrap();
        }
        let bytes = fs.read(&format!("d/{WAL_FILE}")).unwrap();
        assert_eq!(bytes.len() as u64, w.bytes());
        let scan = scan_wal(&bytes);
        assert_eq!(scan.records, sample_records());
        assert_eq!(scan.corrupt_records, 0);
        assert_eq!(scan.truncated_bytes, 0);
        assert_eq!(scan.valid_bytes, bytes.len() as u64);
    }

    #[test]
    fn group_commit_defers_fsync_to_the_batch_boundary() {
        let fs = FaultFs::new();
        let mut w = WalWriter::create(&fs, "d", 3).unwrap();
        let recs = sample_records();
        w.append(&recs[0]).unwrap();
        w.append(&recs[1]).unwrap();
        // crash mid-append of the 3rd record: at most a torn prefix of
        // it survives, so the batch can never appear fully durable
        fs.set_fuse(0);
        assert!(w.append(&recs[2]).is_err());
        fs.revive();
        let scan = scan_wal(&fs.read(&format!("d/{WAL_FILE}")).unwrap());
        assert!(scan.records.len() < 3, "unsynced batch must not be fully durable");
        assert!(!scan.missing_magic);
    }

    #[test]
    fn scan_stops_cleanly_at_a_corrupt_record() {
        let fs = FaultFs::new();
        let mut w = WalWriter::create(&fs, "d", 1).unwrap();
        for rec in sample_records() {
            w.append(&rec).unwrap();
        }
        let path = format!("d/{WAL_FILE}");
        let mut bytes = fs.read(&path).unwrap();
        // flip a payload byte in the middle record
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let scan = scan_wal(&bytes);
        assert_eq!(scan.corrupt_records, 1);
        assert!(scan.records.len() < 5);
        assert!(scan.truncated_bytes > 0);
        // torn tail: truncation mid-record is damage, not an error
        let cut = scan_wal(&bytes[..bytes.len() - 3]);
        assert!(cut.records.len() <= 5);
    }

    #[test]
    fn scan_rejects_foreign_bytes_without_panicking() {
        for junk in [&b""[..], b"GB", b"NOPE", b"GBN1xxxx"] {
            let scan = scan_wal(junk);
            assert!(scan.missing_magic);
            assert!(scan.records.is_empty());
        }
    }
}
