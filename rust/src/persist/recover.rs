//! Crash recovery: manifest → segments → WAL replay → (optional)
//! shard-count override, yielding a [`ShardedPageStore`] observationally
//! equivalent to the pre-crash one.
//!
//! Recovery never panics and never propagates *data* damage as an
//! error: torn tails, CRC failures, and missing files are counted in
//! the [`RecoveryReport`] and the store is rebuilt from everything
//! trustworthy — the last good checkpoint plus the valid WAL prefix.
//! Replay is idempotent (puts overwrite, block writes are absolute,
//! removes tolerate absence), which is what makes the checkpoint
//! protocol's crash window between manifest rename and WAL truncation
//! safe.

use super::segment::{decode_manifest, scan_segment, segment_file_name};
use super::vfs::Vfs;
use super::wal::{scan_wal, WalRecord};
use super::{MANIFEST_FILE, WAL_FILE};
use crate::coordinator::store::{ShardedPageStore, StoredPage};
use crate::frame::Frame;
use crate::{container::Container, Result};
use std::sync::Arc;

/// What recovery found and rebuilt — `gbdi recover` prints this, and
/// the corruption-fuzz tests assert damage is *counted*, never silent.
#[derive(Debug, Default, Clone)]
pub struct RecoveryReport {
    /// A manifest file existed.
    pub manifest_found: bool,
    /// ... and decoded with a valid whole-file CRC.
    pub manifest_valid: bool,
    /// Checkpoint epoch recovered from (0 = no checkpoint).
    pub epoch: u64,
    /// Final shard count of the rebuilt store.
    pub shards: usize,
    /// Segment files read.
    pub segment_files: usize,
    /// Segment files the manifest referenced but the directory lacked.
    pub segments_missing: u64,
    /// Pages rebuilt from segments.
    pub segment_pages: usize,
    /// Segment entries abandoned to CRC failures.
    pub segment_crc_failures: u64,
    /// Codec-table snapshots restored from the manifest.
    pub codecs_recovered: usize,
    /// A WAL file existed.
    pub wal_found: bool,
    /// WAL records replayed.
    pub wal_records: u64,
    /// WAL records abandoned to CRC/decode failures.
    pub wal_corrupt_records: u64,
    /// WAL bytes abandoned (torn tail or post-damage residue).
    pub wal_truncated_bytes: u64,
    /// Bytes of the valid WAL prefix (the append position for reuse).
    pub wal_valid_bytes: u64,
    /// Replay operations that failed against the rebuilt store (e.g. a
    /// block write whose page a damaged segment lost).
    pub replay_errors: u64,
    /// Pages in the rebuilt store.
    pub pages: usize,
}

impl RecoveryReport {
    /// Whether any damage was observed (CRC failures, torn bytes,
    /// missing or invalid files, failed replay ops).
    pub fn saw_damage(&self) -> bool {
        (self.manifest_found && !self.manifest_valid)
            || self.segments_missing > 0
            || self.segment_crc_failures > 0
            || self.wal_corrupt_records > 0
            || self.wal_truncated_bytes > 0
            || self.replay_errors > 0
    }
}

impl std::fmt::Display for RecoveryReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "checkpoint: epoch {} ({})",
            self.epoch,
            if !self.manifest_found {
                "no manifest"
            } else if self.manifest_valid {
                "manifest ok"
            } else {
                "manifest CORRUPT — recovered without it"
            }
        )?;
        writeln!(
            f,
            "segments:   {} file(s), {} page(s), {} missing, {} CRC failure(s)",
            self.segment_files, self.segment_pages, self.segments_missing, self.segment_crc_failures
        )?;
        writeln!(f, "codecs:     {} table snapshot(s)", self.codecs_recovered)?;
        writeln!(
            f,
            "wal:        {} record(s) replayed, {} corrupt, {} B torn, {} replay error(s)",
            self.wal_records, self.wal_corrupt_records, self.wal_truncated_bytes, self.replay_errors
        )?;
        write!(f, "store:      {} page(s) across {} shard(s)", self.pages, self.shards)
    }
}

/// Publish `frame`'s own codec into the ring if its version is not
/// there yet — segments and WAL containers carry their codec tables, so
/// a page can always re-seed the ring it was encoded under.
fn ensure_codec(store: &ShardedPageStore, frame: &Frame) {
    if store.codec(frame.codec().version()).is_none() {
        store.publish_codec(Arc::clone(frame.codec()));
    }
}

fn frame_of(container_bytes: &[u8]) -> Result<Frame> {
    Frame::from_container(Container::from_bytes(container_bytes)?)
}

/// Rebuild a store from `dir`: last good checkpoint, then WAL replay,
/// then an optional shard-count override (`gbdi recover --shards` /
/// serve config differing from the manifest). `cache_bytes` attaches
/// the hot-block cache tier to the rebuilt store (0 = off).
pub fn recover(
    vfs: &dyn Vfs,
    dir: &str,
    shards_override: Option<usize>,
    cache_bytes: usize,
) -> Result<(ShardedPageStore, RecoveryReport)> {
    let mut report = RecoveryReport::default();

    let manifest_path = format!("{dir}/{MANIFEST_FILE}");
    let manifest = if vfs.exists(&manifest_path) {
        report.manifest_found = true;
        let m = decode_manifest(&vfs.read(&manifest_path)?);
        report.manifest_valid = m.is_some();
        m
    } else {
        None
    };

    let checkpoint_shards = manifest.as_ref().map(|m| (m.shard_count as usize).max(1));
    let initial_shards = checkpoint_shards.or(shards_override).unwrap_or(1);
    let mut store = ShardedPageStore::new(initial_shards);
    if cache_bytes > 0 {
        store = store.with_cache(cache_bytes);
    }

    if let Some(m) = &manifest {
        report.epoch = m.epoch;
        for snapshot in &m.codecs {
            match frame_of(snapshot) {
                Ok(frame) => {
                    ensure_codec(&store, &frame);
                    report.codecs_recovered += 1;
                }
                Err(_) => report.replay_errors += 1,
            }
        }
        for idx in 0..m.shard_count as usize {
            let path = format!("{dir}/{}", segment_file_name(m.epoch, idx));
            if !vfs.exists(&path) {
                report.segments_missing += 1;
                continue;
            }
            let scan = scan_segment(&vfs.read(&path)?);
            report.segment_files += 1;
            report.segment_crc_failures += scan.crc_failures;
            if scan.missing_magic {
                report.segment_crc_failures += 1;
            }
            for (page_id, container) in scan.entries {
                match frame_of(&container) {
                    Ok(frame) => {
                        ensure_codec(&store, &frame);
                        store.put(page_id, StoredPage { frame });
                        report.segment_pages += 1;
                    }
                    Err(_) => report.replay_errors += 1,
                }
            }
        }
    }

    let wal_path = format!("{dir}/{WAL_FILE}");
    if vfs.exists(&wal_path) {
        report.wal_found = true;
        let scan = scan_wal(&vfs.read(&wal_path)?);
        report.wal_corrupt_records = scan.corrupt_records;
        report.wal_truncated_bytes = scan.truncated_bytes;
        report.wal_valid_bytes = scan.valid_bytes;
        if scan.missing_magic {
            report.wal_corrupt_records += 1;
        }
        for rec in scan.records {
            report.wal_records += 1;
            let outcome: Result<()> = match rec {
                WalRecord::PutPage { page_id, container } => frame_of(&container).map(|frame| {
                    ensure_codec(&store, &frame);
                    store.put(page_id, StoredPage { frame });
                }),
                WalRecord::WriteBlock { page_id, block, data } => {
                    store.write_block(page_id, block as usize, &data).map(|_| ())
                }
                WalRecord::RemovePage { page_id } => {
                    store.remove(page_id);
                    Ok(())
                }
                WalRecord::PublishCodec { container } => frame_of(&container).map(|frame| {
                    ensure_codec(&store, &frame);
                }),
                WalRecord::Resize { shards } => {
                    store.resize_shards(shards as usize);
                    Ok(())
                }
            };
            if outcome.is_err() {
                report.replay_errors += 1;
            }
        }
    }

    if let Some(n) = shards_override {
        store.resize_shards(n);
    }
    report.shards = store.shard_count();
    report.pages = store.len();
    Ok((store, report))
}

/// Rebuild **one** page from durable state alone — the integrity
/// plane's self-heal source (DESIGN.md §13). Walks the same manifest →
/// segments → WAL chain as [`recover`] but materializes only
/// `page_id`: the checkpointed copy (if any) with every later WAL
/// mutation for that page replayed on top, in log order. Returns
/// `Ok(None)` when durable state holds no trace of the page or a WAL
/// remove was the last word. Damage is tolerated exactly like full
/// recovery — a torn or CRC-failed record simply cannot contribute —
/// so the caller must re-verify the candidate before trusting it
/// ([`ShardedPageStore::heal_page`](crate::coordinator::store::ShardedPageStore::heal_page)
/// does).
pub fn read_page(vfs: &dyn Vfs, dir: &str, page_id: u64) -> Result<Option<StoredPage>> {
    let mut frame: Option<Frame> = None;
    let manifest_path = format!("{dir}/{MANIFEST_FILE}");
    if vfs.exists(&manifest_path) {
        if let Some(m) = decode_manifest(&vfs.read(&manifest_path)?) {
            // segments are routed by a shard hash we deliberately do not
            // reproduce here (the topology may have been resized since
            // the checkpoint): scan every segment of the epoch for the id
            for idx in 0..m.shard_count as usize {
                let path = format!("{dir}/{}", segment_file_name(m.epoch, idx));
                if !vfs.exists(&path) {
                    continue;
                }
                for (id, container) in scan_segment(&vfs.read(&path)?).entries {
                    if id == page_id {
                        if let Ok(f) = frame_of(&container) {
                            frame = Some(f);
                        }
                    }
                }
            }
        }
    }
    let wal_path = format!("{dir}/{WAL_FILE}");
    if vfs.exists(&wal_path) {
        let mut scratch = crate::codec::Scratch::new();
        for rec in scan_wal(&vfs.read(&wal_path)?).records {
            match rec {
                WalRecord::PutPage { page_id: id, container } if id == page_id => {
                    if let Ok(f) = frame_of(&container) {
                        frame = Some(f);
                    }
                }
                WalRecord::WriteBlock { page_id: id, block, data } if id == page_id => {
                    if let Some(f) = frame.as_mut() {
                        let _ = f.write_block(block as usize, &data, &mut scratch);
                    }
                }
                WalRecord::RemovePage { page_id: id } if id == page_id => frame = None,
                _ => {}
            }
        }
    }
    Ok(frame.map(|frame| StoredPage { frame }))
}
