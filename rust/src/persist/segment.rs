//! Checkpoint segment files and the manifest that roots them.
//!
//! A checkpoint at epoch E writes one segment per shard
//! (`seg-<E>-<shard>.gbs`) holding that shard's pages as frozen GBC1
//! containers, then atomically publishes `MANIFEST.gbm` naming the
//! epoch, the shard count, and the codec-table snapshots (zero-image
//! GBC1 containers wrapping the GBT2 tables). All little-endian:
//!
//! ```text
//! segment:  "GBS1"  repeat: page_id u64 | len u32 | crc u32 | container[len]
//! manifest: "GBM1" | version u8 | epoch u64 | shard_count u32
//!           | n_codecs u32 | repeat: len u32 | container[len]
//!           | crc u32 over every preceding byte
//! ```
//!
//! Per-entry CRCs let a bitflipped segment surface as counted damage
//! while the rest of the prefix stays readable; the manifest carries
//! one whole-file CRC because it is small and only valid as a unit.

use super::crc32;

/// Segment file magic.
pub const SEGMENT_MAGIC: &[u8; 4] = b"GBS1";
/// Manifest file magic.
pub const MANIFEST_MAGIC: &[u8; 4] = b"GBM1";
/// Frozen manifest format version byte.
pub const MANIFEST_VERSION: u8 = 1;

/// `seg-<epoch>-<shard>.gbs`.
pub fn segment_file_name(epoch: u64, shard: usize) -> String {
    format!("seg-{epoch}-{shard}.gbs")
}

/// Parse a segment file name back into `(epoch, shard)`.
pub fn parse_segment_file_name(name: &str) -> Option<(u64, usize)> {
    let rest = name.strip_prefix("seg-")?.strip_suffix(".gbs")?;
    let (epoch, shard) = rest.split_once('-')?;
    Some((epoch.parse().ok()?, shard.parse().ok()?))
}

/// Serialize one shard's pages (`(page_id, GBC1 container bytes)`,
/// caller-sorted for determinism) into a segment file image.
pub fn encode_segment(entries: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        SEGMENT_MAGIC.len() + entries.iter().map(|(_, c)| 16 + c.len()).sum::<usize>(),
    );
    out.extend_from_slice(SEGMENT_MAGIC);
    for (page_id, container) in entries {
        out.extend_from_slice(&page_id.to_le_bytes());
        out.extend_from_slice(&(container.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(container).to_le_bytes());
        out.extend_from_slice(container);
    }
    out
}

/// What a segment scan salvaged plus damage counters.
#[derive(Debug, Default)]
pub struct SegmentScan {
    /// Intact `(page_id, container bytes)` entries, in file order.
    pub entries: Vec<(u64, Vec<u8>)>,
    /// Entries abandoned to a CRC mismatch (at most 1 per scan: framing
    /// after the damage is untrustworthy).
    pub crc_failures: u64,
    /// Trailing bytes abandoned after damage or truncation.
    pub truncated_bytes: u64,
    /// The file was missing its magic entirely.
    pub missing_magic: bool,
}

/// Scan raw segment bytes into the longest trustworthy entry prefix.
/// Never fails: damage is reported, not propagated.
pub fn scan_segment(bytes: &[u8]) -> SegmentScan {
    let mut scan = SegmentScan::default();
    if bytes.len() < SEGMENT_MAGIC.len() || &bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        scan.missing_magic = true;
        scan.truncated_bytes = bytes.len() as u64;
        return scan;
    }
    let mut at = SEGMENT_MAGIC.len();
    loop {
        let rest = &bytes[at..];
        if rest.is_empty() {
            break;
        }
        if rest.len() < 16 {
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        let page_id = u64::from_le_bytes(rest[..8].try_into().unwrap());
        let len = u32::from_le_bytes(rest[8..12].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(rest[12..16].try_into().unwrap());
        if rest.len() < 16 + len {
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        let container = &rest[16..16 + len];
        if crc32(container) != crc {
            scan.crc_failures = 1;
            scan.truncated_bytes = rest.len() as u64;
            break;
        }
        scan.entries.push((page_id, container.to_vec()));
        at += 16 + len;
    }
    scan
}

/// The checkpoint root: epoch, shard topology, codec snapshots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Checkpoint epoch the current segment files belong to.
    pub epoch: u64,
    /// Shard count the segments were partitioned under.
    pub shard_count: u32,
    /// Codec-table snapshots, one zero-image GBC1 container per
    /// published codec version, sorted by version.
    pub codecs: Vec<Vec<u8>>,
}

/// Serialize a manifest (trailing whole-file CRC included).
pub fn encode_manifest(m: &Manifest) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MANIFEST_MAGIC);
    out.push(MANIFEST_VERSION);
    out.extend_from_slice(&m.epoch.to_le_bytes());
    out.extend_from_slice(&m.shard_count.to_le_bytes());
    out.extend_from_slice(&(m.codecs.len() as u32).to_le_bytes());
    for snapshot in &m.codecs {
        out.extend_from_slice(&(snapshot.len() as u32).to_le_bytes());
        out.extend_from_slice(snapshot);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse and validate a manifest. `None` on any damage — a manifest is
/// only trustworthy as a whole, so recovery treats a bad one as absent.
pub fn decode_manifest(bytes: &[u8]) -> Option<Manifest> {
    if bytes.len() < 4 + 1 + 8 + 4 + 4 + 4 {
        return None;
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    if crc32(body) != u32::from_le_bytes(crc_bytes.try_into().ok()?) {
        return None;
    }
    if &body[..4] != MANIFEST_MAGIC || body[4] != MANIFEST_VERSION {
        return None;
    }
    let epoch = u64::from_le_bytes(body[5..13].try_into().ok()?);
    let shard_count = u32::from_le_bytes(body[13..17].try_into().ok()?);
    let n_codecs = u32::from_le_bytes(body[17..21].try_into().ok()?) as usize;
    let mut at = 21;
    let mut codecs = Vec::with_capacity(n_codecs);
    for _ in 0..n_codecs {
        if body.len() < at + 4 {
            return None;
        }
        let len = u32::from_le_bytes(body[at..at + 4].try_into().ok()?) as usize;
        at += 4;
        if body.len() < at + len {
            return None;
        }
        codecs.push(body[at..at + len].to_vec());
        at += len;
    }
    if at != body.len() {
        return None;
    }
    Some(Manifest { epoch, shard_count, codecs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_roundtrip_and_file_names() {
        let entries =
            vec![(3u64, vec![1, 2, 3]), (9, Vec::new()), (u64::MAX, vec![0xAB; 100])];
        let bytes = encode_segment(&entries);
        let scan = scan_segment(&bytes);
        assert_eq!(scan.entries, entries);
        assert_eq!(scan.crc_failures, 0);
        assert_eq!(parse_segment_file_name(&segment_file_name(17, 3)), Some((17, 3)));
        assert_eq!(parse_segment_file_name("seg-x-1.gbs"), None);
        assert_eq!(parse_segment_file_name("MANIFEST.gbm"), None);
    }

    #[test]
    fn segment_scan_salvages_the_prefix_before_damage() {
        let entries = vec![(1u64, vec![7; 32]), (2, vec![8; 32]), (3, vec![9; 32])];
        let mut bytes = encode_segment(&entries);
        // flip a byte inside the second entry's container
        let off = 4 + (16 + 32) + 16 + 5;
        bytes[off] ^= 1;
        let scan = scan_segment(&bytes);
        assert_eq!(scan.entries, entries[..1]);
        assert_eq!(scan.crc_failures, 1);
        assert!(scan.truncated_bytes > 0);
        // truncation mid-entry salvages the same prefix
        let cut = scan_segment(&encode_segment(&entries)[..4 + (16 + 32) + 10]);
        assert_eq!(cut.entries, entries[..1]);
        assert_eq!(cut.crc_failures, 0);
        assert!(cut.truncated_bytes > 0);
    }

    #[test]
    fn manifest_roundtrip_rejects_any_damage() {
        let m = Manifest {
            epoch: 42,
            shard_count: 8,
            codecs: vec![vec![1, 2, 3], Vec::new(), vec![9; 50]],
        };
        let bytes = encode_manifest(&m);
        assert_eq!(decode_manifest(&bytes), Some(m));
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert_eq!(decode_manifest(&bad), None, "bitflip at {i} must invalidate");
        }
        assert_eq!(decode_manifest(&bytes[..bytes.len() - 1]), None);
        assert_eq!(decode_manifest(b""), None);
    }
}
