//! The filesystem seam the durability layer writes through.
//!
//! Two implementations:
//!
//! * [`RealFs`] — thin `std::fs` passthrough with real `fsync` /
//!   directory-sync semantics, used by `gbdi serve --data-dir` and
//!   `gbdi recover`.
//! * [`FaultFs`] — a deterministic in-memory filesystem with a crash
//!   *fuse*: the k-th mutating operation (write, fsync, create, rename,
//!   remove, dir-sync) fails mid-flight and every later operation fails
//!   too, modelling a power loss at that exact boundary. Files keep only
//!   their last-fsynced content across the crash (the crashing write
//!   itself may leave a deterministic torn prefix), which is the
//!   adversarial model `tests/durability.rs` sweeps every boundary of.
//!
//! The crash model is the standard journalled-filesystem contract the
//! checkpoint protocol relies on: file *data* is durable only after
//! `sync`, while metadata operations (`create`, `rename`, `remove`)
//! apply atomically — a crashed rename either fully happened or did not.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::{Arc, Mutex};

/// An open writable file handle.
pub trait VfsFile: Send {
    /// Append `buf` at the current end of the file.
    fn write_all(&mut self, buf: &[u8]) -> Result<()>;
    /// Make everything written so far durable (`fsync`).
    fn sync(&mut self) -> Result<()>;
}

/// A minimal filesystem surface: everything the WAL, segment, and
/// checkpoint layers need, and nothing more — small enough that
/// [`FaultFs`] can model it faithfully.
pub trait Vfs: Send + Sync {
    /// Create (or truncate) a file for writing.
    fn create(&self, path: &str) -> Result<Box<dyn VfsFile>>;
    /// Open an existing file for appending.
    fn open_append(&self, path: &str) -> Result<Box<dyn VfsFile>>;
    /// Read a whole file.
    fn read(&self, path: &str) -> Result<Vec<u8>>;
    /// Whether a file exists.
    fn exists(&self, path: &str) -> bool;
    /// Atomically rename `from` to `to` (replacing `to` if present).
    fn rename(&self, from: &str, to: &str) -> Result<()>;
    /// Delete a file.
    fn remove(&self, path: &str) -> Result<()>;
    /// File names (not paths) directly inside `dir`.
    fn list(&self, dir: &str) -> Result<Vec<String>>;
    /// Make directory metadata (renames, creates) durable.
    fn sync_dir(&self, dir: &str) -> Result<()>;
    /// Create `dir` and any missing parents.
    fn create_dir_all(&self, dir: &str) -> Result<()>;
}

// ---- real filesystem ----------------------------------------------------

/// The production [`Vfs`]: `std::fs` with real fsync semantics.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealFs;

struct RealFile(std::fs::File);

impl VfsFile for RealFile {
    fn write_all(&mut self, buf: &[u8]) -> Result<()> {
        self.0.write_all(buf)?;
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.0.sync_all()?;
        Ok(())
    }
}

impl Vfs for RealFs {
    fn create(&self, path: &str) -> Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(std::fs::File::create(path)?)))
    }

    fn open_append(&self, path: &str) -> Result<Box<dyn VfsFile>> {
        let f = std::fs::OpenOptions::new().append(true).open(path)?;
        Ok(Box::new(RealFile(f)))
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        Ok(std::fs::read(path)?)
    }

    fn exists(&self, path: &str) -> bool {
        std::path::Path::new(path).exists()
    }

    fn rename(&self, from: &str, to: &str) -> Result<()> {
        std::fs::rename(from, to)?;
        Ok(())
    }

    fn remove(&self, path: &str) -> Result<()> {
        std::fs::remove_file(path)?;
        Ok(())
    }

    fn list(&self, dir: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            if let Some(name) = entry.file_name().to_str() {
                out.push(name.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    fn sync_dir(&self, dir: &str) -> Result<()> {
        // fsync on a directory handle is how POSIX makes renames
        // durable; on platforms where opening a directory fails this
        // degrades to a no-op (renames are then only crash-atomic).
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    fn create_dir_all(&self, dir: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        Ok(())
    }
}

// ---- deterministic fault-injection filesystem ---------------------------

#[derive(Clone, Default)]
struct FileState {
    /// Content guaranteed to survive a crash (everything up to the last
    /// `sync`).
    durable: Vec<u8>,
    /// Content as the process sees it (durable + unsynced tail).
    volatile: Vec<u8>,
}

#[derive(Clone, Default)]
struct FaultState {
    files: BTreeMap<String, FileState>,
    /// `Some(k)`: k more mutating operations succeed, then the next one
    /// crashes the filesystem. `None`: unlimited.
    fuse: Option<u64>,
    crashed: bool,
    /// Mutating operations attempted so far (crash-boundary counter).
    ops: u64,
}

/// Deterministic in-memory filesystem with crash injection. Cloning is
/// shallow: clones share the same underlying state, so a [`FaultFs`]
/// can be handed to a [`Durability`](super::Durability) as
/// `Arc<dyn Vfs>` while the test keeps a handle for fuse control.
#[derive(Clone, Default)]
pub struct FaultFs {
    state: Arc<Mutex<FaultState>>,
}

impl FaultFs {
    /// Fresh empty filesystem with no fuse armed.
    pub fn new() -> FaultFs {
        FaultFs::default()
    }

    /// Arm the crash fuse: `k` more mutating operations succeed, then
    /// the next one crashes (a torn write for `write_all`, a clean
    /// no-op failure for everything else), and every operation after
    /// that fails until [`Self::revive`].
    pub fn set_fuse(&self, k: u64) {
        self.state.lock().unwrap().fuse = Some(k);
    }

    /// Total mutating operations attempted so far — the number of
    /// distinct crash boundaries a schedule exposes.
    pub fn op_count(&self) -> u64 {
        self.state.lock().unwrap().ops
    }

    /// Whether the injected crash has fired.
    pub fn crashed(&self) -> bool {
        self.state.lock().unwrap().crashed
    }

    /// Remount after a crash: every file retains only its durable
    /// content, the fuse is disarmed, and operations work again.
    pub fn revive(&self) {
        let mut st = self.state.lock().unwrap();
        st.crashed = false;
        st.fuse = None;
        for f in st.files.values_mut() {
            f.volatile = f.durable.clone();
        }
    }

    /// Deep-copy the filesystem (durable and volatile content, counters)
    /// into an independent instance — fuzz tests corrupt copies of a
    /// pristine image.
    pub fn snapshot(&self) -> FaultFs {
        let st = self.state.lock().unwrap();
        FaultFs { state: Arc::new(Mutex::new(st.clone())) }
    }

    /// Mutate a file's bytes in place (durable and volatile views both),
    /// bypassing the crash model — torn-write / bitflip fuzzing.
    pub fn corrupt(&self, path: &str, f: impl FnOnce(&mut Vec<u8>)) {
        let mut st = self.state.lock().unwrap();
        if let Some(file) = st.files.get_mut(path) {
            f(&mut file.durable);
            file.volatile = file.durable.clone();
        }
    }

    /// All file paths currently present, sorted.
    pub fn paths(&self) -> Vec<String> {
        self.state.lock().unwrap().files.keys().cloned().collect()
    }

    /// A file's current length in bytes, if it exists.
    pub fn len_of(&self, path: &str) -> Option<usize> {
        self.state.lock().unwrap().files.get(path).map(|f| f.volatile.len())
    }

    /// Record one mutating op; returns an error if the filesystem is
    /// crashed or the fuse fires on this op. `torn` is the in-flight
    /// write payload, a deterministic prefix of which survives.
    fn mutating(st: &mut FaultState, path: Option<&str>, torn: Option<&[u8]>) -> Result<()> {
        if st.crashed {
            return Err(Error::Runtime("faultfs: filesystem is crashed".into()));
        }
        st.ops += 1;
        if let Some(k) = st.fuse {
            if k == 0 {
                // crash NOW: the crashing write leaves everything the
                // process wrote to this file plus a deterministic torn
                // prefix of the new data; every other file keeps only
                // its fsynced content.
                st.crashed = true;
                let torn_survivor = match (path, torn) {
                    (Some(p), Some(data)) => {
                        let keep = (st.ops.wrapping_mul(0x9E37_79B9) as usize) % (data.len() + 1);
                        let mut kept = st.files.get(p).cloned().unwrap_or_default().volatile;
                        kept.extend_from_slice(&data[..keep]);
                        Some((p.to_string(), kept))
                    }
                    _ => None,
                };
                for f in st.files.values_mut() {
                    f.volatile = f.durable.clone();
                }
                if let Some((p, kept)) = torn_survivor {
                    let entry = st.files.entry(p).or_default();
                    entry.durable = kept.clone();
                    entry.volatile = kept;
                }
                return Err(Error::Runtime("faultfs: injected crash".into()));
            }
            st.fuse = Some(k - 1);
        }
        Ok(())
    }
}

struct FaultFile {
    state: Arc<Mutex<FaultState>>,
    path: String,
}

impl VfsFile for FaultFile {
    fn write_all(&mut self, buf: &[u8]) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        FaultFs::mutating(&mut st, Some(&self.path), Some(buf))?;
        let file = st
            .files
            .get_mut(&self.path)
            .ok_or_else(|| Error::Runtime(format!("faultfs: {} removed underfoot", self.path)))?;
        file.volatile.extend_from_slice(buf);
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        FaultFs::mutating(&mut st, None, None)?;
        let file = st
            .files
            .get_mut(&self.path)
            .ok_or_else(|| Error::Runtime(format!("faultfs: {} removed underfoot", self.path)))?;
        file.durable = file.volatile.clone();
        Ok(())
    }
}

impl Vfs for FaultFs {
    fn create(&self, path: &str) -> Result<Box<dyn VfsFile>> {
        {
            let mut st = self.state.lock().unwrap();
            FaultFs::mutating(&mut st, None, None)?;
            // creation/truncation is a journalled metadata op: durable
            // immediately, like rename
            st.files.insert(path.to_string(), FileState::default());
        }
        Ok(Box::new(FaultFile { state: Arc::clone(&self.state), path: path.to_string() }))
    }

    fn open_append(&self, path: &str) -> Result<Box<dyn VfsFile>> {
        let st = self.state.lock().unwrap();
        if st.crashed {
            return Err(Error::Runtime("faultfs: filesystem is crashed".into()));
        }
        if !st.files.contains_key(path) {
            return Err(Error::Runtime(format!("faultfs: {path} not found")));
        }
        Ok(Box::new(FaultFile { state: Arc::clone(&self.state), path: path.to_string() }))
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        let st = self.state.lock().unwrap();
        if st.crashed {
            return Err(Error::Runtime("faultfs: filesystem is crashed".into()));
        }
        st.files
            .get(path)
            .map(|f| f.volatile.clone())
            .ok_or_else(|| Error::Runtime(format!("faultfs: {path} not found")))
    }

    fn exists(&self, path: &str) -> bool {
        let st = self.state.lock().unwrap();
        !st.crashed && st.files.contains_key(path)
    }

    fn rename(&self, from: &str, to: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        FaultFs::mutating(&mut st, None, None)?;
        let file = st
            .files
            .remove(from)
            .ok_or_else(|| Error::Runtime(format!("faultfs: {from} not found")))?;
        st.files.insert(to.to_string(), file);
        Ok(())
    }

    fn remove(&self, path: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        FaultFs::mutating(&mut st, None, None)?;
        st.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| Error::Runtime(format!("faultfs: {path} not found")))
    }

    fn list(&self, dir: &str) -> Result<Vec<String>> {
        let st = self.state.lock().unwrap();
        if st.crashed {
            return Err(Error::Runtime("faultfs: filesystem is crashed".into()));
        }
        let prefix = format!("{}/", dir.trim_end_matches('/'));
        Ok(st
            .files
            .keys()
            .filter_map(|p| p.strip_prefix(&prefix))
            .filter(|rest| !rest.contains('/'))
            .map(str::to_string)
            .collect())
    }

    fn sync_dir(&self, _dir: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        FaultFs::mutating(&mut st, None, None)
    }

    fn create_dir_all(&self, _dir: &str) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_makes_writes_survive_a_crash() {
        let fs = FaultFs::new();
        let mut f = fs.create("d/a").unwrap();
        f.write_all(b"durable").unwrap();
        f.sync().unwrap();
        f.write_all(b" lost").unwrap();
        fs.set_fuse(0);
        assert!(fs.create("d/b").is_err());
        assert!(fs.crashed());
        assert!(fs.read("d/a").is_err(), "reads must fail while crashed");
        fs.revive();
        assert_eq!(fs.read("d/a").unwrap(), b"durable");
    }

    #[test]
    fn crashing_write_leaves_a_deterministic_torn_prefix() {
        let fs = FaultFs::new();
        let mut f = fs.create("d/a").unwrap();
        f.write_all(b"head.").unwrap();
        fs.set_fuse(0);
        assert!(f.write_all(b"tail-tail-tail").is_err());
        fs.revive();
        let got = fs.read("d/a").unwrap();
        assert!(got.starts_with(b"head."), "pre-crash writes to the torn file survive");
        assert!(got.len() <= b"head.tail-tail-tail".len());
        // deterministic: same schedule, same torn prefix
        let fs2 = FaultFs::new();
        let mut f2 = fs2.create("d/a").unwrap();
        f2.write_all(b"head.").unwrap();
        fs2.set_fuse(0);
        assert!(f2.write_all(b"tail-tail-tail").is_err());
        fs2.revive();
        assert_eq!(fs2.read("d/a").unwrap(), got);
    }

    #[test]
    fn rename_is_atomic_and_durable() {
        let fs = FaultFs::new();
        let mut f = fs.create("d/tmp").unwrap();
        f.write_all(b"manifest").unwrap();
        f.sync().unwrap();
        fs.rename("d/tmp", "d/final").unwrap();
        fs.set_fuse(0);
        assert!(fs.sync_dir("d").is_err());
        fs.revive();
        assert!(!fs.exists("d/tmp"));
        assert_eq!(fs.read("d/final").unwrap(), b"manifest");
    }

    #[test]
    fn fuse_counts_every_mutating_op() {
        let fs = FaultFs::new();
        let mut f = fs.create("d/a").unwrap(); // op 1
        f.write_all(b"x").unwrap(); // op 2
        f.sync().unwrap(); // op 3
        assert_eq!(fs.op_count(), 3);
        fs.set_fuse(1);
        f.write_all(b"y").unwrap(); // 1 left -> ok
        assert!(f.sync().is_err(), "fuse exhausted: this op crashes");
        assert!(f.write_all(b"z").is_err());
    }

    #[test]
    fn list_returns_direct_children_only() {
        let fs = FaultFs::new();
        fs.create("d/a").unwrap();
        fs.create("d/sub/b").unwrap();
        fs.create("e/c").unwrap();
        assert_eq!(fs.list("d").unwrap(), vec!["a".to_string()]);
    }
}
