//! Checkpoint protocol: fold the store into fresh segments, publish
//! them atomically, then let the caller reset the WAL.
//!
//! Write order is the whole crash-consistency argument (DESIGN.md §12):
//!
//! 1. per-shard segments for the new epoch are written and fsynced —
//!    under names the *current* manifest does not reference, so a crash
//!    mid-write leaves garbage recovery never reads;
//! 2. the new manifest is written to a temp name, fsynced, renamed over
//!    `MANIFEST.gbm`, and the directory is synced — the rename is the
//!    atomic commit point;
//! 3. only then may the caller truncate the WAL and delete old-epoch
//!    segments. A crash between 2 and 3 replays stale WAL records onto
//!    the new checkpoint, which is safe because every record type is
//!    idempotent.

use super::segment::{encode_manifest, encode_segment, segment_file_name, Manifest};
use super::vfs::Vfs;
use super::{MANIFEST_FILE, MANIFEST_TMP};
use crate::container;
use crate::coordinator::store::ShardedPageStore;
use crate::Result;

/// Write a full checkpoint of `store` at `epoch` into `dir` and commit
/// it as the current manifest. The caller must have quiesced mutations
/// (the durability gate) and flushed the block cache first, so the
/// frames exported here are the complete logical state.
pub fn write_checkpoint(
    vfs: &dyn Vfs,
    dir: &str,
    epoch: u64,
    store: &ShardedPageStore,
) -> Result<()> {
    let shard_count = store.shard_count();
    for idx in 0..shard_count {
        let entries = store.export_shard(idx);
        let path = format!("{dir}/{}", segment_file_name(epoch, idx));
        let mut f = vfs.create(&path)?;
        f.write_all(&encode_segment(&entries))?;
        f.sync()?;
    }
    let codecs = store
        .codecs()
        .iter()
        .map(|c| container::compress(c.as_ref(), &[]).to_bytes())
        .collect();
    let manifest = Manifest { epoch, shard_count: shard_count as u32, codecs };
    let tmp = format!("{dir}/{MANIFEST_TMP}");
    let mut f = vfs.create(&tmp)?;
    f.write_all(&encode_manifest(&manifest))?;
    f.sync()?;
    vfs.rename(&tmp, &format!("{dir}/{MANIFEST_FILE}"))?;
    vfs.sync_dir(dir)?;
    Ok(())
}

/// Best-effort removal of segment files from any epoch other than
/// `keep_epoch` (and any orphaned manifest temp). Failures are ignored:
/// stale segments are unreferenced garbage, never a correctness hazard.
pub fn clean_stale_segments(vfs: &dyn Vfs, dir: &str, keep_epoch: u64) {
    let Ok(names) = vfs.list(dir) else { return };
    for name in names {
        let stale = match super::segment::parse_segment_file_name(&name) {
            Some((epoch, _)) => epoch != keep_epoch,
            None => name == MANIFEST_TMP,
        };
        if stale {
            let _ = vfs.remove(&format!("{dir}/{name}"));
        }
    }
}
