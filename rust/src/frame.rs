//! Random-access frames: O(1) single-block reads and writes over a
//! compressed image, without a format break.
//!
//! GBDI is a *memory* compression algorithm — its deployment target
//! serves single cache-line reads and writes out of compressed pages.
//! The whole-image [`Container`](crate::container::Container) surface
//! forces every consumer to pay a full decode plus a fresh allocation
//! per access. A [`Frame`] fixes the access granularity instead of the
//! format: it materializes a **block-offset index** (prefix sums of the
//! per-block bit lengths the wire format already carries) once, then
//! serves
//!
//! * [`Frame::read_block`] — decode one block straight out of the
//!   packed payload: O(1) index lookup, zero heap allocations;
//! * [`Frame::read_range`] — arbitrary byte ranges, decoding only the
//!   touched blocks;
//! * [`Frame::write_block`] — recompress one block in place. The new
//!   encoding lands inside the block's old bit span when it fits
//!   (slack bits are don't-care; framing records the new exact length)
//!   and **spills to a patch region** when it grows — the expensive
//!   event a real memory controller must amortize, surfaced to callers
//!   via [`BlockWrite::spilled`];
//! * [`Frame::append_blocks`] — grow the image without recompressing
//!   what exists.
//!
//! All hot paths borrow caller-owned [`Scratch`] buffers instead of
//! allocating, and all bit movement rides the word-at-a-time substrate
//! in [`crate::util::bits`]: the in-place `write_block` splice is a
//! bulk [`overwrite_bits`] (64 bits per step), and compaction /
//! [`Frame::to_container`] move whole blocks between streams with
//! [`BitWriter::append_from`]'s memcpy-or-shifted-word paths — frames
//! are a runtime handle, the wire format is unchanged.
//!
//! On top of frames sit the streaming sessions: [`Compressor`] ingests
//! chunked input with bounded buffering (one partial block), and
//! [`Decompressor`] streams an image back out through a caller-sized
//! window.

use crate::codec::{build_codec, BlockCodec, Scratch};
use crate::container::{self, varint_len, Container};
use crate::util::bits::{overwrite_bits, BitReader, BitWriter};
use crate::{Error, Result};
use std::ops::Range;
use std::sync::Arc;

/// Sentinel: block lives in the base payload, not the patch region.
const IN_BASE: (u32, u32) = (u32::MAX, 0);

/// Outcome of a [`Frame::write_block`]: how large the block's new
/// encoding is and whether placing it forced a spill.
///
/// Callers branch on [`spilled`](Self::spilled) to charge re-layout
/// costs: the memory simulator counts it as a page re-layout
/// (`MemStats::relayouts`), and the page store watches the accumulated
/// patch garbage it implies to decide when to compact a frame. `bits`
/// is the framing truth — [`Frame::block_bits`] returns the same value
/// afterwards, and sector accounting derives sector counts from it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockWrite {
    /// Exact bits of the block's new encoding.
    pub bits: u32,
    /// The new encoding did not fit the block's current slot and was
    /// appended to the patch region — the "page re-layout" event a
    /// memory controller amortizes.
    pub spilled: bool,
}

/// A compressed image handle with an O(1) block index.
///
/// Built from a [`Container`] ([`Frame::from_container`]), from raw
/// image bytes ([`Frame::compress`]), or by a streaming [`Compressor`].
/// Cheap to clone the codec (shared `Arc`); the payload is owned.
///
/// ```
/// use gbdi::{BlockCodec, CodecKind, Frame, GbdiConfig, Scratch};
/// use std::sync::Arc;
///
/// let image: Vec<u8> = (0u32..4096).flat_map(|i| (7000 + (i % 50)).to_le_bytes()).collect();
/// let codec: Arc<dyn BlockCodec> =
///     Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));
/// let mut frame = Frame::compress(codec, &image);
///
/// // O(1), allocation-free single-block read
/// let mut line = [0u8; 64];
/// let n = frame.read_block(5, &mut line).unwrap();
/// assert_eq!(&line[..n], &image[5 * 64..6 * 64]);
///
/// // in-place single-block write (spills to the patch region on growth;
/// // the `BlockWrite` outcome reports both the new size and the spill)
/// let mut scratch = Scratch::new();
/// let write = frame.write_block(5, &[0u8; 64], &mut scratch).unwrap();
/// assert!(write.bits > 0);
/// frame.read_block(5, &mut line).unwrap();
/// assert_eq!(line, [0u8; 64]);
///
/// // compact back to the canonical wire format whenever needed
/// let roundtrip = frame.to_container().decompress().unwrap();
/// assert_eq!(&roundtrip[5 * 64..6 * 64], &[0u8; 64]);
/// ```
#[derive(Clone)]
pub struct Frame {
    codec: Arc<dyn BlockCodec>,
    /// The packed base payload (blocks at their original bit spans).
    payload: Vec<u8>,
    /// Spill region: byte-aligned slots for blocks that outgrew their
    /// base span, plus all appended blocks.
    patch: Vec<u8>,
    /// Bit offset of each of the first `base_blocks` blocks inside
    /// `payload`, plus one end sentinel (`base_blocks + 1` entries).
    offsets: Vec<u64>,
    /// Current exact encoding length per block (framing truth).
    bits: Vec<u32>,
    /// Per-block patch slot `(byte offset, byte capacity)`;
    /// `(u32::MAX, 0)` = block lives in the base payload. Empty until
    /// the first spill (read-only frames pay nothing).
    patches: Vec<(u32, u32)>,
    /// Blocks that have a span in `payload` (appended blocks do not).
    base_blocks: usize,
    original_len: usize,
}

impl Frame {
    // ---- construction ----------------------------------------------------

    /// Compress `image` serially into a fresh frame.
    pub fn compress(codec: Arc<dyn BlockCodec>, image: &[u8]) -> Frame {
        Self::compress_with(codec, image, &mut Scratch::new())
    }

    /// [`Self::compress`] with caller-owned scratch buffers (the
    /// allocation-conscious path for loops building many frames).
    pub fn compress_with(codec: Arc<dyn BlockCodec>, image: &[u8], scratch: &mut Scratch) -> Frame {
        Self::compress_aligned(codec, image, 0, scratch)
    }

    /// Compress with per-block **slack**: each block's bit span in the
    /// payload is rounded up to a multiple of `align_bits` (0 or 1 =
    /// tight). Slack lets [`Self::write_block`] absorb growth in place —
    /// the memory simulator aligns spans to its sector size so only
    /// sector-crossing growth triggers a spill, exactly the re-layout
    /// event the hardware model charges for.
    pub fn compress_aligned(
        codec: Arc<dyn BlockCodec>,
        image: &[u8],
        align_bits: u32,
        scratch: &mut Scratch,
    ) -> Frame {
        let bb = codec.block_bytes();
        let n = image.len().div_ceil(bb.max(1));
        let mut w = BitWriter::with_capacity(image.len() / 2 + 64);
        let mut bits = Vec::with_capacity(n);
        let mut offsets = Vec::with_capacity(n + 1);
        let mut cur: u64 = 0;
        for block in image.chunks(bb) {
            offsets.push(cur);
            let b = codec.compress_block_with(block, &mut w, scratch);
            bits.push(b);
            cur += b as u64;
            if align_bits > 1 {
                let span_end = cur.next_multiple_of(align_bits as u64);
                let mut pad = span_end - cur;
                while pad > 0 {
                    let take = pad.min(57) as u32;
                    w.put(0, take);
                    pad -= take as u64;
                }
                cur = span_end;
            }
        }
        offsets.push(cur);
        debug_assert_eq!(cur as usize, w.bit_len());
        Frame {
            codec,
            payload: w.finish(),
            patch: Vec::new(),
            offsets,
            base_blocks: bits.len(),
            bits,
            patches: Vec::new(),
            original_len: image.len(),
        }
    }

    /// Build a frame from a parsed [`Container`], rebuilding the decoder
    /// from the recorded codec id, config, and table. The payload is
    /// moved, not copied; the block-offset index is materialized here
    /// (one pass over the bit lengths, honoring the chunk realignment of
    /// parallel-compressed streams).
    pub fn from_container(c: Container) -> Result<Frame> {
        let codec = build_codec(c.codec_id, &c.config, c.table)?;
        if codec.block_bytes() != c.block_bytes {
            return Err(Error::Corrupt(format!(
                "container block size {} disagrees with codec config {}",
                c.block_bytes,
                codec.block_bytes()
            )));
        }
        Self::from_parts(Arc::from(codec), c.payload, c.block_bits, c.original_len, c.chunk_blocks)
    }

    /// [`Self::from_container`] with an already-built codec (the
    /// coordinator's codec-ring path — skips table reconstruction). The
    /// codec must match the container's identity and block size.
    pub fn with_codec(c: Container, codec: Arc<dyn BlockCodec>) -> Result<Frame> {
        container::check_codec_identity(&c, codec.as_ref())?;
        Self::from_parts(codec, c.payload, c.block_bits, c.original_len, c.chunk_blocks)
    }

    /// Assemble a frame from compressed parts, materializing the offset
    /// index and validating it against the payload (a forged bit-length
    /// table must fail here, not at read time).
    pub fn from_parts(
        codec: Arc<dyn BlockCodec>,
        payload: Vec<u8>,
        bits: Vec<u32>,
        original_len: usize,
        chunk_blocks: usize,
    ) -> Result<Frame> {
        let bb = codec.block_bytes();
        if bb == 0 {
            return Err(Error::Config("block size must be positive".into()));
        }
        let expect = original_len.div_ceil(bb);
        if bits.len() != expect {
            return Err(Error::Corrupt(format!(
                "frame: {} block lengths for an image of {expect} blocks",
                bits.len()
            )));
        }
        let mut offsets = Vec::with_capacity(bits.len() + 1);
        let mut cur: u64 = 0;
        for (i, &b) in bits.iter().enumerate() {
            // parallel streams: every chunk_blocks-th block starts
            // byte-aligned (mirrors container::decompress_parts)
            if chunk_blocks > 0 && i > 0 && i % chunk_blocks == 0 {
                cur = cur.next_multiple_of(8);
            }
            offsets.push(cur);
            cur += b as u64;
        }
        offsets.push(cur);
        if cur > (payload.len() as u64) * 8 {
            return Err(Error::Corrupt(format!(
                "frame: index claims {cur} bits, payload holds {}",
                payload.len() * 8
            )));
        }
        Ok(Frame {
            codec,
            payload,
            patch: Vec::new(),
            offsets,
            base_blocks: bits.len(),
            bits,
            patches: Vec::new(),
            original_len,
        })
    }

    // ---- geometry --------------------------------------------------------

    /// Logical (uncompressed) image length in bytes.
    pub fn len(&self) -> usize {
        self.original_len
    }

    /// Whether the image is empty.
    pub fn is_empty(&self) -> bool {
        self.original_len == 0
    }

    /// Number of blocks.
    pub fn n_blocks(&self) -> usize {
        self.bits.len()
    }

    /// Block granularity in bytes.
    pub fn block_bytes(&self) -> usize {
        self.codec.block_bytes()
    }

    /// Logical length of block `i` (shorter than
    /// [`Self::block_bytes`] only for a ragged tail).
    pub fn block_len(&self, i: usize) -> usize {
        let bb = self.block_bytes();
        bb.min(self.original_len - i * bb)
    }

    /// Current exact encoding length of block `i` in bits.
    pub fn block_bits(&self, i: usize) -> u32 {
        self.bits[i]
    }

    /// The codec this frame decodes with.
    pub fn codec(&self) -> &Arc<dyn BlockCodec> {
        &self.codec
    }

    /// Compressed footprint in bytes: base payload + patch region + the
    /// varint bit-length index + fixed header (the honest numerator for
    /// storage accounting; the shared table is charged separately by
    /// whoever owns it).
    pub fn compressed_len(&self) -> usize {
        self.payload.len()
            + self.patch.len()
            + self.bits.iter().map(|&b| varint_len(b)).sum::<usize>()
            + 16
    }

    /// Bytes currently in the patch region (spilled + appended blocks;
    /// includes slots orphaned by re-spills).
    pub fn patch_len(&self) -> usize {
        self.patch.len()
    }

    fn check_block(&self, i: usize) -> Result<usize> {
        if i >= self.bits.len() {
            return Err(Error::Config(format!(
                "block {i} out of range ({} blocks)",
                self.bits.len()
            )));
        }
        Ok(self.block_len(i))
    }

    /// Where block `i` currently lives: a byte slice holding it and the
    /// bit offset of its first bit within that slice.
    fn locate(&self, i: usize) -> (&[u8], u32) {
        if let Some(&(pos, cap)) = self.patches.get(i) {
            if pos != u32::MAX {
                return (&self.patch[pos as usize..pos as usize + cap as usize], 0);
            }
        }
        let off = self.offsets[i];
        (&self.payload[(off / 8) as usize..], (off % 8) as u32)
    }

    /// Bit capacity of block `i`'s span in the base payload (only
    /// meaningful for `i < base_blocks`). The last base block's span
    /// extends into the stream's byte padding.
    fn span_bits(&self, i: usize) -> u64 {
        let end = if i + 1 < self.base_blocks {
            self.offsets[i + 1]
        } else {
            (self.payload.len() as u64) * 8
        };
        end - self.offsets[i]
    }

    // ---- reads -----------------------------------------------------------

    /// Decode block `i` into `out[..block_len(i)]`; returns the bytes
    /// written. O(1) in the image size and allocation-free: one index
    /// lookup, one bounded bit-stream decode. `out` must hold at least
    /// [`Self::block_len`]`(i)` bytes.
    pub fn read_block(&self, i: usize, out: &mut [u8]) -> Result<usize> {
        let blen = self.check_block(i)?;
        if out.len() < blen {
            return Err(Error::Config(format!(
                "output buffer {} B short of block length {blen} B",
                out.len()
            )));
        }
        let (src, sub) = self.locate(i);
        let mut r = BitReader::new(src);
        if sub != 0 {
            r.get(sub).map_err(|_| Error::Corrupt(format!("frame: block {i} offset truncated")))?;
        }
        self.codec.decompress_block(&mut r, &mut out[..blen])?;
        let used = r.bit_pos() - sub as usize;
        if used != self.bits[i] as usize {
            return Err(Error::Corrupt(format!(
                "block {i}: consumed {used} bits, framing recorded {}",
                self.bits[i]
            )));
        }
        Ok(blen)
    }

    /// Decode the byte range `[offset, offset + out.len())` into `out`,
    /// touching only the blocks it overlaps. Partial-block edges decode
    /// through `scratch`; whole blocks decode straight into `out`, so
    /// the steady-state path is allocation-free.
    pub fn read_range(&self, offset: usize, out: &mut [u8], scratch: &mut Scratch) -> Result<()> {
        if offset + out.len() > self.original_len {
            return Err(Error::Config(format!(
                "range {offset}..{} past image end {}",
                offset + out.len(),
                self.original_len
            )));
        }
        let bb = self.block_bytes();
        let mut written = 0usize;
        while written < out.len() {
            let pos = offset + written;
            let i = pos / bb;
            let within = pos % bb;
            let blen = self.block_len(i);
            let take = (blen - within).min(out.len() - written);
            if within == 0 && take == blen {
                self.read_block(i, &mut out[written..written + blen])?;
            } else {
                scratch.block.resize(blen, 0);
                self.read_block(i, &mut scratch.block)?;
                out[written..written + take].copy_from_slice(&scratch.block[within..within + take]);
            }
            written += take;
        }
        Ok(())
    }

    /// Decode the whole image (convenience; allocates the result). The
    /// random-access equivalent of [`Container::decompress`].
    pub fn decompress(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.decompress_into(&mut out)?;
        Ok(out)
    }

    /// Decode the whole image into `out`, reusing its allocation: the
    /// vector is resized to the logical length, so a caller looping over
    /// pages with one buffer pays zero allocations once the buffer has
    /// grown to the largest page (`tests/alloc_counting.rs` pins this).
    pub fn decompress_into(&self, out: &mut Vec<u8>) -> Result<()> {
        let bb = self.block_bytes();
        out.clear();
        out.resize(self.original_len, 0);
        for (i, chunk) in out.chunks_mut(bb).enumerate() {
            self.read_block(i, chunk)?;
        }
        Ok(())
    }

    // ---- writes ----------------------------------------------------------

    /// Recompress block `i` from `data` (exactly
    /// [`Self::block_len`]`(i)` bytes) in place. The new encoding lands
    /// in the block's current slot when it fits — base-payload span or
    /// existing patch slot — and spills to a fresh patch slot otherwise.
    /// Reads see the new content immediately; [`Self::to_container`]
    /// compacts everything back to the canonical stream.
    pub fn write_block(
        &mut self,
        i: usize,
        data: &[u8],
        scratch: &mut Scratch,
    ) -> Result<BlockWrite> {
        let blen = self.check_block(i)?;
        if data.len() != blen {
            return Err(Error::Config(format!(
                "write must supply exactly {blen} B for block {i}, got {}",
                data.len()
            )));
        }
        let mut w = std::mem::take(&mut scratch.w);
        w.clear();
        let bits = self.codec.compress_block_with(data, &mut w, scratch);
        w.flush_to_byte();
        let res = self.place_block(i, w.bytes(), bits);
        scratch.w = w;
        res
    }

    /// Append a fresh byte-aligned patch slot holding `bits` bits of
    /// `bytes`, sizing the per-block slot table first. Returns the slot
    /// `(byte offset, byte capacity)`. The single definition of patch
    /// geometry — spills and appends must never disagree on it.
    fn push_patch_slot(&mut self, bytes: &[u8], bits: u32) -> Result<(u32, u32)> {
        let need = (bits as usize).div_ceil(8);
        let pos = self.patch.len();
        if pos + need > u32::MAX as usize {
            return Err(Error::Config("frame patch region exceeds 4 GiB".into()));
        }
        if self.patches.len() < self.bits.len() {
            self.patches.resize(self.bits.len(), IN_BASE);
        }
        self.patch.extend_from_slice(&bytes[..need]);
        Ok((pos as u32, need as u32))
    }

    /// Put an encoded block (packed in `bytes`, `bits` bits long) into
    /// block `i`'s slot, spilling to the patch region on overflow.
    fn place_block(&mut self, i: usize, bytes: &[u8], bits: u32) -> Result<BlockWrite> {
        let need = (bits as usize).div_ceil(8);
        let in_patch = self.patches.get(i).is_some_and(|&(pos, _)| pos != u32::MAX);
        if !in_patch && i < self.base_blocks && bits as u64 <= self.span_bits(i) {
            overwrite_bits(&mut self.payload, self.offsets[i] as usize, bytes, bits as usize);
            self.bits[i] = bits;
            return Ok(BlockWrite { bits, spilled: false });
        }
        if in_patch {
            let (pos, cap) = self.patches[i];
            if need <= cap as usize {
                let pos = pos as usize;
                self.patch[pos..pos + need].copy_from_slice(&bytes[..need]);
                self.bits[i] = bits;
                return Ok(BlockWrite { bits, spilled: false });
            }
        }
        // spill: the old slot, if any, becomes garbage until compaction
        let slot = self.push_patch_slot(bytes, bits)?;
        self.patches[i] = slot;
        self.bits[i] = bits;
        Ok(BlockWrite { bits, spilled: true })
    }

    /// Compress `data` as new blocks appended to the image (stored in
    /// the patch region; existing blocks are untouched). Returns the
    /// indices of the new blocks. Fails if the image currently ends in a
    /// ragged tail block — only whole-block images can grow.
    pub fn append_blocks(&mut self, data: &[u8], scratch: &mut Scratch) -> Result<Range<usize>> {
        let bb = self.block_bytes();
        if self.original_len % bb != 0 {
            return Err(Error::Config(format!(
                "cannot append after a ragged tail ({} B image, {bb} B blocks)",
                self.original_len
            )));
        }
        let first = self.bits.len();
        let mut w = std::mem::take(&mut scratch.w);
        for chunk in data.chunks(bb) {
            w.clear();
            let bits = self.codec.compress_block_with(chunk, &mut w, scratch);
            w.flush_to_byte();
            let slot = match self.push_patch_slot(w.bytes(), bits) {
                Ok(slot) => slot,
                Err(e) => {
                    scratch.w = w;
                    return Err(e);
                }
            };
            self.bits.push(bits);
            self.patches.push(slot);
            self.original_len += chunk.len();
        }
        scratch.w = w;
        Ok(first..self.bits.len())
    }

    /// Rebuild the base payload tight in place: every block's current
    /// encoding is bit-spliced back into one contiguous stream and the
    /// patch region (including any slots orphaned by re-spills) is
    /// dropped. Long-running write workloads call this when
    /// [`Self::patch_len`] grows past their garbage budget — the page
    /// store does so automatically.
    pub fn compact(&mut self) {
        if self.patch.is_empty() {
            return;
        }
        let mut w = BitWriter::with_capacity(self.payload.len());
        let mut offsets = Vec::with_capacity(self.bits.len() + 1);
        let mut cur: u64 = 0;
        for i in 0..self.bits.len() {
            let (src, sub) = self.locate(i);
            w.append_from(src, sub as usize, self.bits[i] as u64);
            offsets.push(cur);
            cur += self.bits[i] as u64;
        }
        offsets.push(cur);
        self.payload = w.finish();
        self.offsets = offsets;
        self.base_blocks = self.bits.len();
        self.patch.clear();
        self.patches.clear();
    }

    // ---- integrity -------------------------------------------------------

    /// CRC-32 term for block `i`'s stored encoding: a digest of the
    /// block index, its recorded bit length, and the exact bit content
    /// of its current slot (base span or patch slot), canonicalized by
    /// re-packing the bits LSB-first from offset 0 — so the term is a
    /// pure function of the block's *logical* stored bits, independent
    /// of where the slot sits or how it is byte-aligned. Slack bits
    /// beyond `block_bits(i)` are excluded: they are never read, so a
    /// flip there is harmless by construction.
    ///
    /// Deliberately total: a truncated or nonsensical span (possible
    /// under corruption of the framing metadata) hashes missing bits as
    /// zero instead of failing, so verification always produces a
    /// digest to mismatch against.
    pub fn block_crc(&self, i: usize) -> u32 {
        let (src, sub) = self.locate(i);
        let mut r = BitReader::new(src);
        if sub != 0 {
            let _ = r.get(sub);
        }
        let mut h = crate::util::crc::Crc32::new();
        h.update(&(i as u32).to_le_bytes());
        h.update(&self.bits[i].to_le_bytes());
        let mut left = u64::from(self.bits[i]);
        while left >= 64 {
            h.update_u64(r.get(64).unwrap_or(0));
            left -= 64;
        }
        if left > 0 {
            let w = r.get(left as u32).unwrap_or(0);
            h.update(&w.to_le_bytes()[..(left as usize).div_ceil(8)]);
        }
        h.finish()
    }

    /// Whole-image integrity digest: the XOR of every block's
    /// [`Self::block_crc`] term with a geometry term covering the block
    /// count and logical length. XOR composition is what makes the
    /// page store's incremental maintenance O(block): a `write_block`
    /// replaces exactly one term (`crc ^= old_term ^ new_term`), while
    /// a full recompute — what the scrubber does — folds every term
    /// (DESIGN.md §13).
    pub fn image_crc(&self) -> u32 {
        let mut crc = self.geometry_crc();
        for i in 0..self.bits.len() {
            crc ^= self.block_crc(i);
        }
        crc
    }

    /// The geometry term of [`Self::image_crc`]: block count + logical
    /// length, salted so an empty frame's digest is not zero.
    fn geometry_crc(&self) -> u32 {
        let mut h = crate::util::crc::Crc32::new();
        h.update(b"GBIC");
        h.update(&(self.bits.len() as u32).to_le_bytes());
        h.update(&(self.original_len as u64).to_le_bytes());
        h.finish()
    }

    /// Chaos-test hook: flip one bit inside block `i`'s stored encoding
    /// (bit `bit % block_bits(i)` of its slot), leaving all framing
    /// metadata intact — the in-memory analogue of FaultFs's media
    /// bitflips. Returns `false` without touching anything when the
    /// block has a zero-length encoding (nothing to flip). Not intended
    /// for production callers; the integrity plane exists to catch
    /// exactly this mutation.
    #[doc(hidden)]
    pub fn corrupt_block_bit(&mut self, i: usize, bit: u64) -> bool {
        if i >= self.bits.len() || self.bits[i] == 0 {
            return false;
        }
        let bit = bit % u64::from(self.bits[i]);
        if let Some(&(pos, _)) = self.patches.get(i) {
            if pos != u32::MAX {
                self.patch[pos as usize + (bit / 8) as usize] ^= 1 << (bit % 8);
                return true;
            }
        }
        let abs = self.offsets[i] + bit;
        self.payload[(abs / 8) as usize] ^= 1 << (abs % 8);
        true
    }

    // ---- serialization ---------------------------------------------------

    /// Compact the frame back into a canonical serial [`Container`]:
    /// every block's current encoding (base span or patch slot) is
    /// spliced tight into one stream — no re-encoding, no patch-region
    /// garbage, and the wire format is exactly what
    /// [`container::compress`] would have produced for the current
    /// content.
    pub fn to_container(&self) -> Container {
        let mut w = BitWriter::with_capacity(self.payload.len() + self.patch.len());
        for i in 0..self.bits.len() {
            let (src, sub) = self.locate(i);
            w.append_from(src, sub as usize, self.bits[i] as u64);
        }
        container::assemble(
            self.codec.as_ref(),
            self.original_len,
            0,
            w.finish(),
            self.bits.clone(),
        )
    }
}

/// Streaming compression session: feed input in chunks of any size;
/// only one partial block is ever buffered, the compressed stream grows
/// incrementally. [`Compressor::finish`] yields a random-access
/// [`Frame`]; [`Compressor::finish_container`] the serializable
/// [`Container`].
pub struct Compressor {
    codec: Arc<dyn BlockCodec>,
    w: BitWriter,
    bits: Vec<u32>,
    /// Pending partial block (never reaches `block_bytes`).
    tail: Vec<u8>,
    len: usize,
    scratch: Scratch,
}

impl Compressor {
    /// New session over `codec`.
    pub fn new(codec: Arc<dyn BlockCodec>) -> Compressor {
        Compressor {
            codec,
            w: BitWriter::new(),
            bits: Vec::new(),
            tail: Vec::new(),
            len: 0,
            scratch: Scratch::new(),
        }
    }

    /// Ingest the next chunk of the image (any size, any alignment).
    pub fn write(&mut self, data: &[u8]) {
        self.len += data.len();
        let bb = self.codec.block_bytes();
        let mut rest = data;
        if !self.tail.is_empty() {
            let take = (bb - self.tail.len()).min(rest.len());
            let mut tail = std::mem::take(&mut self.tail);
            tail.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if tail.len() == bb {
                let b = self.codec.compress_block_with(&tail, &mut self.w, &mut self.scratch);
                self.bits.push(b);
                tail.clear();
            }
            self.tail = tail;
        }
        let full = rest.len() / bb * bb;
        for block in rest[..full].chunks(bb) {
            let b = self.codec.compress_block_with(block, &mut self.w, &mut self.scratch);
            self.bits.push(b);
        }
        self.tail.extend_from_slice(&rest[full..]);
    }

    /// Bytes ingested so far.
    pub fn bytes_in(&self) -> usize {
        self.len
    }

    fn flush_tail(&mut self) {
        if !self.tail.is_empty() {
            let tail = std::mem::take(&mut self.tail);
            let b = self.codec.compress_block_with(&tail, &mut self.w, &mut self.scratch);
            self.bits.push(b);
        }
    }

    /// Close the session into a random-access [`Frame`].
    pub fn finish(mut self) -> Frame {
        self.flush_tail();
        Frame::from_parts(self.codec, self.w.finish(), self.bits, self.len, 0)
            .expect("compressor framing is self-consistent")
    }

    /// Close the session into a serializable [`Container`].
    pub fn finish_container(mut self) -> Container {
        self.flush_tail();
        container::assemble(self.codec.as_ref(), self.len, 0, self.w.finish(), self.bits)
    }
}

/// Streaming decompression session over a [`Frame`]: pull the image
/// through a caller-sized window (bounded memory — only the blocks
/// overlapping each pull are decoded).
pub struct Decompressor<'a> {
    frame: &'a Frame,
    pos: usize,
    scratch: Scratch,
}

impl<'a> Decompressor<'a> {
    /// New session at the start of `frame`'s image.
    pub fn new(frame: &'a Frame) -> Decompressor<'a> {
        Decompressor { frame, pos: 0, scratch: Scratch::new() }
    }

    /// Decode the next `out.len()`-or-fewer bytes into `out`; returns
    /// the bytes produced (0 at end of image).
    pub fn read(&mut self, out: &mut [u8]) -> Result<usize> {
        let take = out.len().min(self.frame.len() - self.pos);
        if take == 0 {
            return Ok(0);
        }
        self.frame.read_range(self.pos, &mut out[..take], &mut self.scratch)?;
        self.pos += take;
        Ok(take)
    }

    /// Bytes not yet produced.
    pub fn remaining(&self) -> usize {
        self.frame.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecKind;
    use crate::gbdi::GbdiConfig;
    use crate::util::prng::Rng;

    fn clustered_image(len_words: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..len_words)
            .flat_map(|_| {
                let v: u32 = match rng.below(4) {
                    0 => 6000u32.wrapping_add(rng.range_i64(-120, 120) as u32),
                    1 => (1u32 << 21).wrapping_add(rng.range_i64(-400, 400) as u32),
                    2 => 0,
                    _ => rng.next_u32(),
                };
                v.to_le_bytes()
            })
            .collect()
    }

    fn frame_for(kind: CodecKind, image: &[u8]) -> Frame {
        let cfg = GbdiConfig::default();
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(image, &cfg));
        Frame::compress(codec, image)
    }

    #[test]
    fn every_codec_reads_blocks_identical_to_whole_decode() {
        let mut image = clustered_image(4096, 1);
        image.extend_from_slice(&[1, 2, 3, 4, 5]); // ragged tail
        for &kind in CodecKind::all() {
            let frame = frame_for(kind, &image);
            assert_eq!(frame.decompress().unwrap(), image, "{}", kind.name());
            let mut buf = vec![0u8; frame.block_bytes()];
            for i in 0..frame.n_blocks() {
                let n = frame.read_block(i, &mut buf).unwrap();
                assert_eq!(n, frame.block_len(i));
                assert_eq!(&buf[..n], &image[i * 64..i * 64 + n], "{} block {i}", kind.name());
            }
        }
    }

    #[test]
    fn frame_from_parallel_container_realigns_chunks() {
        // 384 KiB so compress_parallel really chunks; block reads must
        // honor the byte realignment at chunk boundaries
        let image = clustered_image(96 * 1024, 2);
        let cfg = GbdiConfig::default();
        for &kind in CodecKind::all() {
            let codec = kind.build_for_image(&image, &cfg);
            let par = container::compress_parallel(codec.as_ref(), &image, 4);
            assert!(par.chunk_blocks > 0, "{} must chunk", kind.name());
            let frame = Frame::from_container(par).unwrap();
            let mut buf = [0u8; 64];
            // probe around every chunk boundary plus a spread of blocks
            let n = frame.n_blocks();
            let probes: Vec<usize> = (0..n)
                .filter(|&i| i % 997 == 0 || i % container::CHUNK_BLOCKS <= 1 || i + 1 == n)
                .collect();
            for i in probes {
                frame.read_block(i, &mut buf).unwrap();
                assert_eq!(&buf[..], &image[i * 64..(i + 1) * 64], "{} block {i}", kind.name());
            }
        }
    }

    #[test]
    fn read_range_matches_image_slices() {
        let image = clustered_image(8192, 3);
        let frame = frame_for(CodecKind::Gbdi, &image);
        let mut scratch = Scratch::new();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let off = rng.below(image.len() as u64) as usize;
            let len = rng.below((image.len() - off) as u64 + 1) as usize;
            let mut out = vec![0u8; len];
            frame.read_range(off, &mut out, &mut scratch).unwrap();
            assert_eq!(out, &image[off..off + len], "off {off} len {len}");
        }
        // degenerate ranges
        frame.read_range(0, &mut [], &mut scratch).unwrap();
        frame.read_range(image.len(), &mut [], &mut scratch).unwrap();
        assert!(frame.read_range(image.len(), &mut [0u8], &mut scratch).is_err());
    }

    #[test]
    fn write_block_in_place_and_spill_roundtrip() {
        for &kind in CodecKind::all() {
            let mut image = clustered_image(4096, 7);
            // pin the targets: block 3 compresses well (small ints), block
            // 9 is all-zero — so its base span is tiny and any real data
            // must spill
            for c in image[3 * 64..4 * 64].chunks_mut(4) {
                c.copy_from_slice(&77u32.to_le_bytes());
            }
            image[9 * 64..10 * 64].fill(0);
            let mut frame = frame_for(kind, &image);
            let mut scratch = Scratch::new();
            let mut rng = Rng::new(11);
            // shrink: overwrite a compressible block with zeros (fits the
            // old span in place)
            let zeros = [0u8; 64];
            let wr = frame.write_block(3, &zeros, &mut scratch).unwrap();
            assert!(!wr.spilled, "{}: shrink must not spill", kind.name());
            image[3 * 64..4 * 64].fill(0);
            // grow: incompressible data into the zero block spills
            let mut noisy = [0u8; 64];
            rng.fill_bytes(&mut noisy);
            let wr = frame.write_block(9, &noisy, &mut scratch).unwrap();
            assert!(wr.spilled, "{}: raw block must spill", kind.name());
            assert!(frame.patch_len() > 0);
            image[9 * 64..10 * 64].copy_from_slice(&noisy);
            // rewrite the spilled block smaller: reuses its patch slot
            let wr = frame.write_block(9, &zeros, &mut scratch).unwrap();
            assert!(!wr.spilled, "{}: patch slot reuse", kind.name());
            image[9 * 64..10 * 64].fill(0);
            assert_eq!(frame.decompress().unwrap(), image, "{}", kind.name());
            // compaction drops the patch region and still decodes
            let c = frame.to_container();
            assert_eq!(c.decompress().unwrap(), image, "{} compacted", kind.name());
            let reframed = Frame::from_container(c).unwrap();
            assert_eq!(reframed.patch_len(), 0);
            assert_eq!(reframed.decompress().unwrap(), image);
        }
    }

    #[test]
    fn compact_drops_patch_garbage_and_preserves_content() {
        let image = vec![0u8; 64 * 32];
        let cfg = GbdiConfig::default();
        let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Gbdi.build_for_image(&image, &cfg));
        let mut frame = Frame::compress(Arc::clone(&codec), &image);
        let mut scratch = Scratch::new();
        let mut rng = Rng::new(41);
        let mut expect = image.clone();
        // spill small (repeated-word) encodings first, then grow two of
        // them past their slots so the old slots are orphaned garbage
        let mut rep = [0u8; 64];
        for c in rep.chunks_mut(4) {
            c.copy_from_slice(&7u32.to_le_bytes());
        }
        for &i in &[1usize, 5, 9] {
            frame.write_block(i, &rep, &mut scratch).unwrap();
            expect[i * 64..(i + 1) * 64].copy_from_slice(&rep);
        }
        for &i in &[1usize, 5] {
            let mut noisy = [0u8; 64];
            rng.fill_bytes(&mut noisy);
            frame.write_block(i, &noisy, &mut scratch).unwrap();
            expect[i * 64..(i + 1) * 64].copy_from_slice(&noisy);
        }
        assert!(frame.patch_len() > 0);
        let before = frame.compressed_len();
        frame.compact();
        assert_eq!(frame.patch_len(), 0);
        assert!(frame.compressed_len() <= before);
        assert_eq!(frame.decompress().unwrap(), expect);
        // compacted frames keep serving reads and writes
        let mut buf = [0u8; 64];
        frame.read_block(5, &mut buf).unwrap();
        assert_eq!(&buf[..], &expect[5 * 64..6 * 64]);
        frame.write_block(9, &[0u8; 64], &mut scratch).unwrap();
        expect[9 * 64..10 * 64].fill(0);
        assert_eq!(frame.decompress().unwrap(), expect);
        // compacting a patch-free frame is a no-op
        let len = frame.compressed_len();
        frame.compact();
        assert_eq!(frame.compressed_len(), len);
    }

    #[test]
    fn ragged_tail_blocks_write_and_read() {
        let mut image = clustered_image(100, 13);
        image.truncate(image.len() - 3); // 397 B: last block is 13 B
        let mut frame = frame_for(CodecKind::Bdi, &image);
        let last = frame.n_blocks() - 1;
        assert_eq!(frame.block_len(last), 13);
        let mut scratch = Scratch::new();
        let new_tail = [0xEEu8; 13];
        frame.write_block(last, &new_tail, &mut scratch).unwrap();
        let mut buf = [0u8; 64];
        let n = frame.read_block(last, &mut buf).unwrap();
        assert_eq!(&buf[..n], &new_tail);
        // wrong-size writes are rejected
        assert!(frame.write_block(last, &[0u8; 64], &mut scratch).is_err());
        assert!(frame.write_block(0, &[0u8; 13], &mut scratch).is_err());
        // appends are blocked by the ragged tail
        assert!(frame.append_blocks(&[0u8; 64], &mut scratch).is_err());
    }

    #[test]
    fn append_blocks_grows_the_image() {
        let image = clustered_image(1024, 17);
        let mut frame = frame_for(CodecKind::Gbdi, &image);
        let mut scratch = Scratch::new();
        let extra = clustered_image(256, 18);
        let added = frame.append_blocks(&extra, &mut scratch).unwrap();
        assert_eq!(added, 64..64 + 16);
        assert_eq!(frame.len(), image.len() + extra.len());
        let mut whole = image.clone();
        whole.extend_from_slice(&extra);
        assert_eq!(frame.decompress().unwrap(), whole);
        // appended blocks are writable like any other
        let zeros = [0u8; 64];
        frame.write_block(70, &zeros, &mut scratch).unwrap();
        whole[70 * 64..71 * 64].fill(0);
        assert_eq!(frame.decompress().unwrap(), whole);
        // and the compacted container reproduces the grown image
        assert_eq!(frame.to_container().decompress().unwrap(), whole);
        // appending a ragged tail works once, then blocks further growth
        frame.append_blocks(&[7u8; 10], &mut scratch).unwrap();
        whole.extend_from_slice(&[7u8; 10]);
        assert_eq!(frame.decompress().unwrap(), whole);
        assert!(frame.append_blocks(&[7u8; 64], &mut scratch).is_err());
    }

    #[test]
    fn empty_and_zero_block_frames() {
        let frame = frame_for(CodecKind::Fpc, &[]);
        assert!(frame.is_empty());
        assert_eq!(frame.n_blocks(), 0);
        assert_eq!(frame.decompress().unwrap(), Vec::<u8>::new());
        assert!(frame.read_block(0, &mut [0u8; 64]).is_err());
        // an empty frame can still grow
        let cfg = GbdiConfig::default();
        let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Fpc.build_for_image(&[], &cfg));
        let mut frame = Frame::compress(codec, &[]);
        let mut scratch = Scratch::new();
        frame.append_blocks(&[9u8; 128], &mut scratch).unwrap();
        assert_eq!(frame.decompress().unwrap(), vec![9u8; 128]);
    }

    #[test]
    fn forged_framing_rejected_at_construction() {
        let image = clustered_image(1024, 19);
        let cfg = GbdiConfig::default();
        let codec = CodecKind::Bdi.build_for_image(&image, &cfg);
        let c = container::compress(codec.as_ref(), &image);
        // u32::MAX bit lengths must overflow the payload check, not panic
        let mut forged = c.clone();
        for b in forged.block_bits.iter_mut() {
            *b = u32::MAX;
        }
        assert!(Frame::from_container(forged).is_err());
        // wrong block count
        let mut forged = c.clone();
        forged.block_bits.pop();
        assert!(Frame::from_container(forged).is_err());
        // a single inflated entry shifts every later offset: reads fail
        // cleanly instead of decoding garbage
        let mut forged = c;
        if forged.block_bits[0] < 100 {
            forged.block_bits[0] += 8;
            forged.block_bits[1] = forged.block_bits[1].saturating_sub(8);
            let frame = Frame::from_container(forged).unwrap();
            let mut buf = [0u8; 64];
            let a = frame.read_block(0, &mut buf);
            let b = frame.read_block(1, &mut buf);
            assert!(a.is_err() || b.is_err());
        }
    }

    #[test]
    fn sessions_match_one_shot_compression() {
        let image = clustered_image(8192, 23);
        let cfg = GbdiConfig::default();
        for &kind in CodecKind::all() {
            let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&image, &cfg));
            let oneshot = container::compress(codec.as_ref(), &image);
            // feed awkward chunk sizes through the session
            let mut rng = Rng::new(29);
            let mut c = Compressor::new(Arc::clone(&codec));
            let mut off = 0;
            while off < image.len() {
                let n = (rng.below(777) as usize + 1).min(image.len() - off);
                c.write(&image[off..off + n]);
                off += n;
            }
            assert_eq!(c.bytes_in(), image.len());
            let sc = c.finish_container();
            assert_eq!(sc.block_bits, oneshot.block_bits, "{} framing", kind.name());
            assert_eq!(sc.payload, oneshot.payload, "{} payload", kind.name());
            // and the frame-yielding variant decodes bit-exactly
            let mut c = Compressor::new(Arc::clone(&codec));
            for chunk in image.chunks(1000) {
                c.write(chunk);
            }
            let frame = c.finish();
            assert_eq!(frame.decompress().unwrap(), image, "{}", kind.name());
            // streaming decode through odd window sizes
            let mut d = Decompressor::new(&frame);
            let mut out = Vec::new();
            let mut buf = [0u8; 333];
            loop {
                let n = d.read(&mut buf).unwrap();
                if n == 0 {
                    break;
                }
                out.extend_from_slice(&buf[..n]);
            }
            assert_eq!(out, image, "{} streamed", kind.name());
            assert_eq!(d.remaining(), 0);
        }
    }

    #[test]
    fn aligned_frames_absorb_growth_in_place() {
        // sector-aligned slack: growth within the padded span stays in
        // place; only span-crossing growth spills
        let image = vec![0u8; 64 * 64];
        let cfg = GbdiConfig::default();
        let codec: Arc<dyn BlockCodec> = Arc::from(CodecKind::Bdi.build_for_image(&image, &cfg));
        let mut scratch = Scratch::new();
        let mut frame = Frame::compress_aligned(codec, &image, 128, &mut scratch);
        // zero block = 4 bits, span padded to 128 bits: a rep8 rewrite
        // (4 + 64 = 68 bits) grows but still fits the slack in place
        let mut rep = [0u8; 64];
        for c in rep.chunks_mut(8) {
            c.copy_from_slice(&0xABCD_EF01_2345_6789u64.to_le_bytes());
        }
        let wr = frame.write_block(5, &rep, &mut scratch).unwrap();
        assert_eq!(wr.bits, 68);
        assert!(!wr.spilled, "growth within slack must stay in place");
        // incompressible data crosses the span: spill
        let mut noisy = [0u8; 64];
        Rng::new(31).fill_bytes(&mut noisy);
        let wr = frame.write_block(5, &noisy, &mut scratch).unwrap();
        assert!(wr.spilled);
        let mut buf = [0u8; 64];
        frame.read_block(5, &mut buf).unwrap();
        assert_eq!(buf, noisy);
        frame.read_block(4, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 64], "left neighbour untouched");
        frame.read_block(6, &mut buf).unwrap();
        assert_eq!(buf, [0u8; 64], "right neighbour untouched");
    }

    #[test]
    fn image_crc_tracks_incremental_block_terms() {
        // the page store's O(block) maintenance rule — xor out the old
        // term, xor in the new — must agree with a full recompute after
        // any sequence of in-place writes, spills, and compactions
        for &kind in CodecKind::all() {
            let image = clustered_image(4096, 43);
            let mut frame = frame_for(kind, &image);
            let mut scratch = Scratch::new();
            let mut rng = Rng::new(47);
            let mut crc = frame.image_crc();
            for round in 0..60 {
                let i = rng.below(frame.n_blocks() as u64) as usize;
                let mut data = [0u8; 64];
                match rng.below(3) {
                    0 => {}
                    1 => data.chunks_mut(4).for_each(|c| c.copy_from_slice(&9u32.to_le_bytes())),
                    _ => rng.fill_bytes(&mut data),
                }
                let old = frame.block_crc(i);
                frame.write_block(i, &data, &mut scratch).unwrap();
                crc ^= old ^ frame.block_crc(i);
                assert_eq!(crc, frame.image_crc(), "{} round {round}", kind.name());
                if round % 20 == 19 {
                    frame.compact();
                    // compaction relocates slots but never changes the
                    // logical bit content, so the digest is invariant
                    assert_eq!(crc, frame.image_crc(), "{} compact {round}", kind.name());
                }
            }
        }
    }

    #[test]
    fn corrupt_block_bit_always_breaks_the_digest() {
        let image = clustered_image(4096, 53);
        let mut frame = frame_for(CodecKind::Gbdi, &image);
        let mut scratch = Scratch::new();
        let mut rng = Rng::new(59);
        // include a spilled block so both slot kinds are exercised
        let mut noisy = [0u8; 64];
        rng.fill_bytes(&mut noisy);
        frame.write_block(2, &noisy, &mut scratch).unwrap();
        for trial in 0..200 {
            let before = frame.image_crc();
            let i = rng.below(frame.n_blocks() as u64) as usize;
            if !frame.corrupt_block_bit(i, rng.next_u64()) {
                continue;
            }
            assert_ne!(before, frame.image_crc(), "flip in block {i} (trial {trial}) undetected");
            // flip it back: the digest must return exactly
            // (corrupt_block_bit reduces the bit index modulo the block
            // length, so replaying the same argument hits the same bit)
        }
    }

    #[test]
    fn corrupting_one_bit_then_restoring_roundtrips_the_digest() {
        let image = clustered_image(1024, 61);
        let mut frame = frame_for(CodecKind::Bdi, &image);
        let before = frame.image_crc();
        assert!(frame.corrupt_block_bit(3, 5));
        assert_ne!(before, frame.image_crc());
        assert!(frame.corrupt_block_bit(3, 5));
        assert_eq!(before, frame.image_crc());
    }
}
