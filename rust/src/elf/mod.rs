//! Minimal ELF64 core-file reader/writer — the "memory dump files in the
//! ELF format" substrate from the paper's methodology (§V).
//!
//! The paper's dumps came from a course server we do not have; this module
//! supplies the same *interface*: [`write_core`] emits a valid ELF64
//! `ET_CORE` file whose `PT_LOAD` segments hold a synthetic workload's
//! memory image, and [`parse`] extracts loadable segments from any ELF64
//! file (including real core dumps), which the pipeline then compresses
//! exactly as the paper did.

use crate::{Error, Result};

/// ELF magic.
const MAGIC: [u8; 4] = [0x7F, b'E', b'L', b'F'];
/// 64-bit class, little endian, version 1.
const EHDR_SIZE: usize = 64;
const PHDR_SIZE: usize = 56;
/// Segment type: loadable.
pub const PT_LOAD: u32 = 1;
/// Segment type: note (present in real cores; skipped by the pipeline).
pub const PT_NOTE: u32 = 4;
/// Object type: core file.
pub const ET_CORE: u16 = 4;

/// One loadable memory segment.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Virtual address the segment maps at.
    pub vaddr: u64,
    /// Segment flags (PF_R=4, PF_W=2, PF_X=1).
    pub flags: u32,
    /// Segment contents. `mem_size` beyond `data.len()` is implicit zeros
    /// in the file; [`parse`] materializes them (as the paper's pipeline
    /// must compress the full mapped range).
    pub data: Vec<u8>,
}

/// A parsed memory dump: the loadable segments of an ELF file.
#[derive(Debug, Clone, Default)]
pub struct MemoryDump {
    /// Loadable segments in file order.
    pub segments: Vec<Segment>,
}

impl MemoryDump {
    /// Total loadable bytes.
    pub fn total_len(&self) -> usize {
        self.segments.iter().map(|s| s.data.len()).sum()
    }

    /// Concatenate all segments into one image (the unit the paper
    /// compresses: the dump's memory content).
    pub fn flatten(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_len());
        for s in &self.segments {
            out.extend_from_slice(&s.data);
        }
        out
    }
}

fn rd_u16(b: &[u8], o: usize) -> u16 {
    u16::from_le_bytes(b[o..o + 2].try_into().unwrap())
}
fn rd_u32(b: &[u8], o: usize) -> u32 {
    u32::from_le_bytes(b[o..o + 4].try_into().unwrap())
}
fn rd_u64(b: &[u8], o: usize) -> u64 {
    u64::from_le_bytes(b[o..o + 8].try_into().unwrap())
}

/// Parse an ELF64 little-endian file and extract its loadable segments.
///
/// Validation is strict about structure (magic, class, offsets in bounds)
/// but tolerant about content (any `e_type` is accepted — executables,
/// shared objects, and cores all carry PT_LOAD).
pub fn parse(file: &[u8]) -> Result<MemoryDump> {
    let bad = |m: &str| Error::Elf(m.to_string());
    if file.len() < EHDR_SIZE {
        return Err(bad("file shorter than ELF header"));
    }
    if file[0..4] != MAGIC {
        return Err(bad("bad magic"));
    }
    if file[4] != 2 {
        return Err(bad("not ELFCLASS64"));
    }
    if file[5] != 1 {
        return Err(bad("not little-endian"));
    }
    if file[6] != 1 {
        return Err(bad("bad ELF version"));
    }
    let e_phoff = rd_u64(file, 0x20) as usize;
    let e_phentsize = rd_u16(file, 0x36) as usize;
    let e_phnum = rd_u16(file, 0x38) as usize;
    if e_phnum == 0 {
        return Ok(MemoryDump::default());
    }
    if e_phentsize < PHDR_SIZE {
        return Err(bad("phentsize too small"));
    }
    let table_end = e_phoff
        .checked_add(e_phentsize.checked_mul(e_phnum).ok_or_else(|| bad("phdr overflow"))?)
        .ok_or_else(|| bad("phdr overflow"))?;
    if table_end > file.len() {
        return Err(bad("program header table out of bounds"));
    }
    let mut segments = Vec::new();
    for i in 0..e_phnum {
        let o = e_phoff + i * e_phentsize;
        let p_type = rd_u32(file, o);
        if p_type != PT_LOAD {
            continue;
        }
        let p_flags = rd_u32(file, o + 0x04);
        let p_offset = rd_u64(file, o + 0x08) as usize;
        let p_vaddr = rd_u64(file, o + 0x10);
        let p_filesz = rd_u64(file, o + 0x20) as usize;
        let p_memsz = rd_u64(file, o + 0x28) as usize;
        let end = p_offset.checked_add(p_filesz).ok_or_else(|| bad("segment overflow"))?;
        if end > file.len() {
            return Err(bad("segment data out of bounds"));
        }
        if p_memsz < p_filesz {
            return Err(bad("memsz < filesz"));
        }
        // cap implicit zero-fill to something sane (a dump with TB-scale
        // bss would OOM the pipeline; real cores write pages they hold)
        if p_memsz > p_filesz && p_memsz - p_filesz > (1 << 31) {
            return Err(bad("implausible zero-fill size"));
        }
        let mut data = file[p_offset..end].to_vec();
        data.resize(p_memsz, 0);
        segments.push(Segment { vaddr: p_vaddr, flags: p_flags, data });
    }
    Ok(MemoryDump { segments })
}

/// Write a minimal valid ELF64 `ET_CORE` file containing the given
/// segments as `PT_LOAD` entries (page-aligned offsets, like real cores).
pub fn write_core(segments: &[Segment]) -> Vec<u8> {
    const ALIGN: usize = 4096;
    let phnum = segments.len();
    let headers = EHDR_SIZE + phnum * PHDR_SIZE;
    // layout: headers | pad | seg0 | pad | seg1 ...
    let mut offsets = Vec::with_capacity(phnum);
    let mut cursor = (headers + ALIGN - 1) / ALIGN * ALIGN;
    for s in segments {
        offsets.push(cursor);
        cursor += (s.data.len() + ALIGN - 1) / ALIGN * ALIGN;
    }
    let mut out = vec![0u8; cursor];
    // --- ELF header ---
    out[0..4].copy_from_slice(&MAGIC);
    out[4] = 2; // ELFCLASS64
    out[5] = 1; // little endian
    out[6] = 1; // EV_CURRENT
    out[7] = 0; // SysV ABI
    out[0x10..0x12].copy_from_slice(&ET_CORE.to_le_bytes()); // e_type
    out[0x12..0x14].copy_from_slice(&62u16.to_le_bytes()); // e_machine = x86-64
    out[0x14..0x18].copy_from_slice(&1u32.to_le_bytes()); // e_version
    // e_entry = 0, e_shoff = 0
    out[0x20..0x28].copy_from_slice(&(EHDR_SIZE as u64).to_le_bytes()); // e_phoff
    out[0x34..0x36].copy_from_slice(&(EHDR_SIZE as u16).to_le_bytes()); // e_ehsize
    out[0x36..0x38].copy_from_slice(&(PHDR_SIZE as u16).to_le_bytes()); // e_phentsize
    out[0x38..0x3A].copy_from_slice(&(phnum as u16).to_le_bytes()); // e_phnum
    // --- program headers ---
    for (i, s) in segments.iter().enumerate() {
        let o = EHDR_SIZE + i * PHDR_SIZE;
        out[o..o + 4].copy_from_slice(&PT_LOAD.to_le_bytes());
        out[o + 0x04..o + 0x08].copy_from_slice(&s.flags.to_le_bytes());
        out[o + 0x08..o + 0x10].copy_from_slice(&(offsets[i] as u64).to_le_bytes());
        out[o + 0x10..o + 0x18].copy_from_slice(&s.vaddr.to_le_bytes()); // p_vaddr
        out[o + 0x18..o + 0x20].copy_from_slice(&s.vaddr.to_le_bytes()); // p_paddr
        out[o + 0x20..o + 0x28].copy_from_slice(&(s.data.len() as u64).to_le_bytes()); // filesz
        out[o + 0x28..o + 0x30].copy_from_slice(&(s.data.len() as u64).to_le_bytes()); // memsz
        out[o + 0x30..o + 0x38].copy_from_slice(&(ALIGN as u64).to_le_bytes()); // align
    }
    // --- segment data ---
    for (i, s) in segments.iter().enumerate() {
        out[offsets[i]..offsets[i] + s.data.len()].copy_from_slice(&s.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample_segments() -> Vec<Segment> {
        let mut rng = Rng::new(1);
        let mut a = vec![0u8; 8192];
        rng.fill_bytes(&mut a);
        vec![
            Segment { vaddr: 0x400000, flags: 5, data: a },
            Segment { vaddr: 0x7F00_0000_0000, flags: 6, data: vec![7u8; 4096] },
            Segment { vaddr: 0x7FFF_F000_0000, flags: 6, data: vec![1, 2, 3] }, // unaligned size
        ]
    }

    #[test]
    fn write_parse_roundtrip() {
        let segs = sample_segments();
        let file = write_core(&segs);
        let dump = parse(&file).unwrap();
        assert_eq!(dump.segments.len(), 3);
        for (a, b) in dump.segments.iter().zip(&segs) {
            assert_eq!(a.vaddr, b.vaddr);
            assert_eq!(a.flags, b.flags);
            assert_eq!(a.data, b.data);
        }
        assert_eq!(dump.total_len(), segs.iter().map(|s| s.data.len()).sum::<usize>());
    }

    #[test]
    fn flatten_concatenates() {
        let segs = vec![
            Segment { vaddr: 0, flags: 6, data: vec![1, 2] },
            Segment { vaddr: 100, flags: 6, data: vec![3] },
        ];
        let file = write_core(&segs);
        assert_eq!(parse(&file).unwrap().flatten(), vec![1, 2, 3]);
    }

    #[test]
    fn zero_fill_memsz_materialized() {
        // hand-edit memsz > filesz
        let segs = vec![Segment { vaddr: 0x1000, flags: 6, data: vec![9u8; 100] }];
        let mut file = write_core(&segs);
        let phdr = EHDR_SIZE;
        file[phdr + 0x28..phdr + 0x30].copy_from_slice(&200u64.to_le_bytes());
        let dump = parse(&file).unwrap();
        assert_eq!(dump.segments[0].data.len(), 200);
        assert_eq!(&dump.segments[0].data[..100], &[9u8; 100][..]);
        assert_eq!(&dump.segments[0].data[100..], &[0u8; 100][..]);
    }

    #[test]
    fn non_load_segments_skipped() {
        let segs = sample_segments();
        let mut file = write_core(&segs);
        // flip first phdr to PT_NOTE
        let phdr = EHDR_SIZE;
        file[phdr..phdr + 4].copy_from_slice(&PT_NOTE.to_le_bytes());
        let dump = parse(&file).unwrap();
        assert_eq!(dump.segments.len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(&[]).is_err());
        assert!(parse(&[0u8; 100]).is_err());
        let file = write_core(&sample_segments());
        // bad magic
        let mut f = file.clone();
        f[0] = 0;
        assert!(parse(&f).is_err());
        // 32-bit class
        let mut f = file.clone();
        f[4] = 1;
        assert!(parse(&f).is_err());
        // big endian
        let mut f = file.clone();
        f[5] = 2;
        assert!(parse(&f).is_err());
        // phoff out of bounds
        let mut f = file.clone();
        f[0x20..0x28].copy_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(parse(&f).is_err());
        // segment offset out of bounds
        let mut f = file.clone();
        let phdr = EHDR_SIZE;
        f[phdr + 0x08..phdr + 0x10].copy_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(parse(&f).is_err());
        // memsz < filesz
        let mut f = file;
        f[phdr + 0x28..phdr + 0x30].copy_from_slice(&1u64.to_le_bytes());
        assert!(parse(&f).is_err());
    }

    #[test]
    fn empty_dump_ok() {
        let file = write_core(&[]);
        let dump = parse(&file).unwrap();
        assert!(dump.segments.is_empty());
        assert_eq!(dump.total_len(), 0);
    }

    #[test]
    fn parse_fuzz_never_panics() {
        let mut rng = Rng::new(2);
        let base = write_core(&sample_segments());
        for _ in 0..500 {
            let mut f = base.clone();
            for _ in 0..rng.range(1, 16) {
                let i = rng.below(f.len() as u64) as usize;
                f[i] = rng.next_u32() as u8;
            }
            let _ = parse(&f); // Ok or Err, never panic
        }
    }
}
