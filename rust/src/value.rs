//! Typed word views over raw memory images.
//!
//! GBDI (like BDI) operates on fixed-width words inside fixed-size blocks.
//! The paper's dumps are little-endian x86-64/JVM memory, so words are
//! little-endian; both 32-bit (default, as in HPCA'22) and 64-bit word
//! granularities are supported.

/// Word granularity the codec operates at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WordSize {
    /// 32-bit words (GBDI default).
    W32,
    /// 64-bit words (pointer-heavy data).
    W64,
}

impl WordSize {
    /// Bytes per word.
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            WordSize::W32 => 4,
            WordSize::W64 => 8,
        }
    }

    /// Bits per word.
    #[inline]
    pub fn bits(self) -> u32 {
        self.bytes() as u32 * 8
    }

    /// Parse from a CLI string ("32"/"64").
    pub fn parse(s: &str) -> Option<WordSize> {
        match s {
            "32" | "w32" | "u32" => Some(WordSize::W32),
            "64" | "w64" | "u64" => Some(WordSize::W64),
            _ => None,
        }
    }
}

/// Read the `i`-th little-endian word of `block` as u64 (zero-extended for
/// W32). `block` must hold at least `(i+1) * ws.bytes()` bytes.
#[inline]
pub fn read_word(block: &[u8], i: usize, ws: WordSize) -> u64 {
    match ws {
        WordSize::W32 => {
            let o = i * 4;
            u32::from_le_bytes(block[o..o + 4].try_into().unwrap()) as u64
        }
        WordSize::W64 => {
            let o = i * 8;
            u64::from_le_bytes(block[o..o + 8].try_into().unwrap())
        }
    }
}

/// Write the `i`-th little-endian word of `block`.
#[inline]
pub fn write_word(block: &mut [u8], i: usize, ws: WordSize, v: u64) {
    match ws {
        WordSize::W32 => {
            let o = i * 4;
            block[o..o + 4].copy_from_slice(&(v as u32).to_le_bytes());
        }
        WordSize::W64 => {
            let o = i * 8;
            block[o..o + 8].copy_from_slice(&v.to_le_bytes());
        }
    }
}

/// Iterate all words of an image (ignoring a ragged tail shorter than one
/// word) — the sampling path of background analysis.
pub fn words<'a>(image: &'a [u8], ws: WordSize) -> impl Iterator<Item = u64> + 'a {
    let n = image.len() / ws.bytes();
    (0..n).map(move |i| read_word(image, i, ws))
}

/// Number of whole words in `len` bytes.
#[inline]
pub fn word_count(len: usize, ws: WordSize) -> usize {
    len / ws.bytes()
}

/// Iterator over fixed-size blocks of an image; the final block may be
/// short (the codec stores short tails raw).
pub fn blocks(image: &[u8], block_bytes: usize) -> impl Iterator<Item = &[u8]> {
    image.chunks(block_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_roundtrip_w32() {
        let mut b = vec![0u8; 16];
        write_word(&mut b, 0, WordSize::W32, 0xDEAD_BEEF);
        write_word(&mut b, 3, WordSize::W32, 0x1234_5678);
        assert_eq!(read_word(&b, 0, WordSize::W32), 0xDEAD_BEEF);
        assert_eq!(read_word(&b, 3, WordSize::W32), 0x1234_5678);
        assert_eq!(read_word(&b, 1, WordSize::W32), 0);
    }

    #[test]
    fn word_roundtrip_w64() {
        let mut b = vec![0u8; 16];
        write_word(&mut b, 1, WordSize::W64, u64::MAX - 7);
        assert_eq!(read_word(&b, 1, WordSize::W64), u64::MAX - 7);
    }

    #[test]
    fn words_iterator_ignores_ragged_tail() {
        let image = [1u8, 0, 0, 0, 2, 0, 0, 0, 99, 99]; // 2 words + 2 tail bytes
        let ws: Vec<u64> = words(&image, WordSize::W32).collect();
        assert_eq!(ws, vec![1, 2]);
        assert_eq!(word_count(image.len(), WordSize::W32), 2);
    }

    #[test]
    fn blocks_chunking() {
        let image = vec![7u8; 130];
        let bs: Vec<&[u8]> = blocks(&image, 64).collect();
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].len(), 64);
        assert_eq!(bs[2].len(), 2);
    }

    #[test]
    fn wordsize_parse() {
        assert_eq!(WordSize::parse("32"), Some(WordSize::W32));
        assert_eq!(WordSize::parse("u64"), Some(WordSize::W64));
        assert_eq!(WordSize::parse("16"), None);
        assert_eq!(WordSize::W32.bits(), 32);
        assert_eq!(WordSize::W64.bytes(), 8);
    }
}
