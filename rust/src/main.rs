//! `gbdi` — the leader binary: workload/dump generation, analysis,
//! compression, verification, the Figure-1 experiment, the coordinator
//! service demo, and the memsim bandwidth experiment.
//!
//! Run `gbdi --help` for the command list; every experiment in
//! EXPERIMENTS.md names the command that regenerates it.

use gbdi::baselines::{self, Codec, GbdiWholeImage};
use gbdi::cli::{App, Arg};
use gbdi::coordinator::{AnalyzerBackend, CompressionService, ServiceConfig};
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig};
use gbdi::memsim::{self, trace, CompressedMemory, DramModel};
use gbdi::report::{bar_chart, fmt_bytes, fmt_ratio, Table};
use gbdi::runtime::ArtifactRuntime;
use gbdi::util::prng::Rng;
use gbdi::{elf, workloads};
use std::sync::Arc;

fn app() -> App {
    App::new("gbdi", "GBDI memory compression — paper reproduction toolkit")
        .subcommand(
            App::new("gen", "generate a synthetic memory dump (ELF core)")
                .arg(Arg::opt("workload", "mcf", "workload name (see `list`)"))
                .arg(Arg::opt("size", "16m", "image bytes (k/m/g suffixes)"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::req("out", "output ELF path")),
        )
        .subcommand(App::new("list", "list the paper's nine workloads"))
        .subcommand(
            App::new("analyze", "background analysis: print the global base table")
                .arg(Arg::pos("input", "ELF dump or raw image"))
                .arg(Arg::opt("bases", "64", "number of global bases"))
                .arg(Arg::opt("samples", "4096", "analysis sample words")),
        )
        .subcommand(
            App::new("compress", "compress a dump/file into a .gbdi container")
                .arg(Arg::pos("input", "ELF dump or raw image"))
                .arg(Arg::req("out", "output .gbdi path"))
                .arg(Arg::opt("bases", "64", "number of global bases")),
        )
        .subcommand(
            App::new("decompress", "decompress a .gbdi container")
                .arg(Arg::pos("input", ".gbdi container"))
                .arg(Arg::req("out", "output path")),
        )
        .subcommand(
            App::new("verify", "compress + decompress + bit-exactness check")
                .arg(Arg::pos("input", "ELF dump or raw image")),
        )
        .subcommand(
            App::new("figure1", "reproduce the paper's Figure 1 (per-workload ratios)")
                .arg(Arg::opt("size", "8m", "image bytes per workload"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::opt("csv", "", "also write CSV here")),
        )
        .subcommand(
            App::new("serve", "run the coordinator service demo")
                .arg(Arg::opt("pages", "512", "pages to stream"))
                .arg(Arg::opt("workers", "4", "compression workers"))
                .arg(Arg::opt("workload", "mix", "workload or 'mix'"))
                .arg(Arg::opt("config", "", "TOML config file ([codec] + [service])"))
                .arg(Arg::flag("native", "force native k-means (skip PJRT artifacts)")),
        )
        .subcommand(
            App::new("memsim", "compressed-memory bandwidth experiment (E7)")
                .arg(Arg::opt("workload", "triangle_count", "workload name"))
                .arg(Arg::opt("size", "4m", "image bytes"))
                .arg(Arg::opt("trace", "streaming", "streaming|uniform|zipf"))
                .arg(Arg::opt("accesses", "65536", "trace length"))
                .arg(Arg::opt("burst", "16", "DRAM burst bytes")),
        )
        .subcommand(App::new("info", "platform + artifact status"))
}

fn load_image(path: &str) -> gbdi::Result<Vec<u8>> {
    let raw = std::fs::read(path)?;
    // ELF? take the loadable segments; otherwise treat as a raw image
    if raw.len() >= 4 && raw[0..4] == [0x7F, b'E', b'L', b'F'] {
        Ok(elf::parse(&raw)?.flatten())
    } else {
        Ok(raw)
    }
}

fn cmd_gen(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let name = m.get("workload");
    let w = workloads::by_name(name)
        .ok_or_else(|| gbdi::Error::Config(format!("unknown workload '{name}'")))?;
    let image = w.generate(m.get_usize("size"), m.get_u64("seed"));
    let seg = elf::Segment { vaddr: 0x10000, flags: 6, data: image };
    let file = elf::write_core(&[seg]);
    std::fs::write(m.get("out"), &file)?;
    println!("wrote {} ({}) for workload {}", m.get("out"), fmt_bytes(file.len() as u64), w.name());
    Ok(())
}

fn cmd_list() {
    let mut t = Table::new(&["name", "group", "paper dump", "memory model"]);
    for w in workloads::all() {
        t.row(&[
            w.name().to_string(),
            w.group().label().to_string(),
            w.paper_dump().to_string(),
            w.description().to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_analyze(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let image = load_image(m.get("input"))?;
    let cfg = GbdiConfig {
        num_bases: m.get_usize("bases"),
        analysis_samples: m.get_usize("samples"),
        ..Default::default()
    };
    cfg.validate().map_err(gbdi::Error::Config)?;
    let table = analyze::analyze_image(&image, &cfg);
    println!("image: {} ({})", m.get("input"), fmt_bytes(image.len() as u64));
    println!("global bases: {} (budget {})", table.len(), cfg.num_bases);
    let mut t = Table::new(&["base (hex)", "width class"]);
    for e in table.entries().iter().take(32) {
        t.row(&[format!("{:#010x}", e.base), format!("{} bits", e.width)]);
    }
    print!("{}", t.render());
    if table.len() > 32 {
        println!("... and {} more", table.len() - 32);
    }
    let codec = GbdiCodec::new(table, cfg);
    let (comp, stats) = codec.compress_image_stats(&image);
    println!(
        "ratio {}  blocks: {} gbdi / {} zero / {} rep / {} raw  outliers {:.2}%",
        fmt_ratio(comp.ratio()),
        stats.gbdi_blocks,
        stats.zero_blocks,
        stats.rep_blocks,
        stats.raw_blocks,
        stats.outlier_frac() * 100.0
    );
    Ok(())
}

fn cmd_compress(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let image = load_image(m.get("input"))?;
    let codec = GbdiWholeImage {
        config: GbdiConfig { num_bases: m.get_usize("bases"), ..Default::default() },
    };
    let comp = codec.compress(&image);
    std::fs::write(m.get("out"), &comp)?;
    println!(
        "{} -> {}: {} -> {} ({})",
        m.get("input"),
        m.get("out"),
        fmt_bytes(image.len() as u64),
        fmt_bytes(comp.len() as u64),
        fmt_ratio(image.len() as f64 / comp.len() as f64)
    );
    Ok(())
}

fn cmd_decompress(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let comp = std::fs::read(m.get("input"))?;
    let len = GbdiWholeImage::container_len(&comp)?;
    let out = GbdiWholeImage::default().decompress(&comp, len)?;
    std::fs::write(m.get("out"), &out)?;
    println!("{} -> {} ({})", m.get("input"), m.get("out"), fmt_bytes(out.len() as u64));
    Ok(())
}

fn cmd_verify(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let image = load_image(m.get("input"))?;
    let codec = GbdiWholeImage::default();
    let t0 = std::time::Instant::now();
    let comp = codec.compress(&image);
    let t_c = t0.elapsed();
    let t0 = std::time::Instant::now();
    let back = codec.decompress(&comp, image.len())?;
    let t_d = t0.elapsed();
    let ok = back == image;
    println!(
        "reconstruction: {}  ratio {}  compress {:.1} MiB/s  decompress {:.1} MiB/s",
        if ok { "BIT-EXACT" } else { "MISMATCH" },
        fmt_ratio(image.len() as f64 / comp.len() as f64),
        image.len() as f64 / (1 << 20) as f64 / t_c.as_secs_f64(),
        image.len() as f64 / (1 << 20) as f64 / t_d.as_secs_f64(),
    );
    if !ok {
        return Err(gbdi::Error::Corrupt("roundtrip mismatch".into()));
    }
    Ok(())
}

fn cmd_figure1(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let size = m.get_usize("size");
    let seed = m.get_u64("seed");
    let codec = GbdiWholeImage::default();
    let mut items = Vec::new();
    let mut c_ratios = Vec::new();
    let mut j_ratios = Vec::new();
    for w in workloads::all() {
        let img = w.generate(size, seed);
        let r = baselines::ratio_of(&codec, &img);
        items.push((w.name().to_string(), r));
        if w.group().is_c_family() {
            c_ratios.push(r);
        } else {
            j_ratios.push(r);
        }
    }
    println!("{}", bar_chart("Figure 1 — GBDI compression ratio per workload", &items, 48));
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let all: Vec<f64> = items.iter().map(|(_, r)| *r).collect();
    println!(
        "C-workloads mean {} (paper: 1.4x) | Java mean {} (paper: 1.55x) | overall {} (paper: 1.45x)",
        fmt_ratio(mean(&c_ratios)),
        fmt_ratio(mean(&j_ratios)),
        fmt_ratio(mean(&all)),
    );
    let csv_path = m.get("csv");
    if !csv_path.is_empty() {
        let mut t = Table::new(&["workload", "ratio"]);
        for (n, r) in &items {
            t.row(&[n.clone(), format!("{r:.4}")]);
        }
        std::fs::write(csv_path, t.csv())?;
        println!("csv written to {csv_path}");
    }
    Ok(())
}

fn cmd_serve(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let pages = m.get_u64("pages");
    let backend = if m.get_flag("native") {
        AnalyzerBackend::Native
    } else {
        match ArtifactRuntime::new(ArtifactRuntime::default_dir()) {
            Ok(rt) if rt.has_artifact("kmeans_k64") => {
                println!("analyzer backend: PJRT artifacts ({})", rt.platform());
                AnalyzerBackend::Artifact(Arc::new(rt))
            }
            _ => {
                println!("analyzer backend: native (artifacts not found)");
                AnalyzerBackend::Native
            }
        }
    };
    let mut cfg = match m.get("config") {
        "" => ServiceConfig { analyze_every: 64, ..Default::default() },
        path => gbdi::config::ConfigFile::load(path)
            .and_then(|f| f.service_config())
            .map_err(gbdi::Error::Config)?,
    };
    cfg.workers = m.get_usize("workers");
    let svc = CompressionService::start(cfg, backend)?;
    let names: Vec<&str> = match m.get("workload") {
        "mix" => vec!["mcf", "perlbench", "fluidanimate", "triangle_count", "svm"],
        w => vec![w],
    };
    let mut rng = Rng::new(1);
    for i in 0..pages {
        let w = workloads::by_name(names[rng.below(names.len() as u64) as usize])
            .ok_or_else(|| gbdi::Error::Config("unknown workload".into()))?;
        svc.submit(i, w.generate(4096, i));
        if i % 128 == 127 {
            svc.flush();
            let snap = svc.metrics();
            println!(
                "pages {:>6}  ratio {}  {:.0} MiB/s  analyses {} swaps {} (table v{})",
                snap.pages_in,
                fmt_ratio(snap.ratio()),
                snap.compress_mib_s(),
                snap.analyses,
                snap.table_swaps,
                svc.current_version()
            );
        }
    }
    svc.flush();
    let migrated = svc.recompress_step()?;
    let (logical, stored, ratio) = svc.storage_ratio();
    let snap = svc.shutdown();
    println!(
        "final: {} pages, {} -> {} stored ({}), {} migrated, {} swaps",
        snap.pages_in,
        fmt_bytes(logical as u64),
        fmt_bytes(stored as u64),
        fmt_ratio(ratio),
        migrated,
        snap.table_swaps
    );
    Ok(())
}

fn cmd_memsim(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let w = workloads::by_name(m.get("workload"))
        .ok_or_else(|| gbdi::Error::Config("unknown workload".into()))?;
    let image = w.generate(m.get_usize("size"), 7);
    let cfg = GbdiConfig::default();
    let table = analyze::analyze_image(&image, &cfg);
    let mut mem = CompressedMemory::new(GbdiCodec::new(table, cfg));
    mem.store_image(&image);
    let kind = trace::TraceKind::parse(m.get("trace"))
        .ok_or_else(|| gbdi::Error::Config("bad trace kind".into()))?;
    let tr = trace::generate(kind, mem.total_blocks(), m.get_usize("accesses"), 0.1, 9);
    let model = DramModel { burst_bytes: m.get_u64("burst"), meta_miss: 0.05 };
    let rep = memsim::replay(&mut mem, &tr, &model)?;
    println!(
        "workload {} trace {}: capacity {}  bandwidth amplification {:.3}x",
        w.name(),
        kind.label(),
        fmt_ratio(mem.capacity_ratio()),
        rep.amplification
    );
    let mut t = Table::new(&["memory-bound fraction", "speedup"]);
    for (f, s) in &rep.speedup_at {
        t.row(&[format!("{f:.1}"), format!("{s:.3}x")]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_info() {
    println!("gbdi {} — three-layer GBDI reproduction", env!("CARGO_PKG_VERSION"));
    let dir = ArtifactRuntime::default_dir();
    println!("artifact dir: {}", dir.display());
    match ArtifactRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for stem in ["kmeans_k16", "kmeans_k64", "sizeest_k64"] {
                println!(
                    "  {stem}: {}",
                    if rt.has_artifact(stem) { "present" } else { "MISSING (run `make artifacts`)" }
                );
            }
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse_subcommands(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let m = &parsed.matches;
    let result = match parsed.command.as_str() {
        "gen" => cmd_gen(m),
        "list" => {
            cmd_list();
            Ok(())
        }
        "analyze" => cmd_analyze(m),
        "compress" => cmd_compress(m),
        "decompress" => cmd_decompress(m),
        "verify" => cmd_verify(m),
        "figure1" => cmd_figure1(m),
        "serve" => cmd_serve(m),
        "memsim" => cmd_memsim(m),
        "info" => {
            cmd_info();
            Ok(())
        }
        _ => unreachable!("parse_subcommands validated"),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
