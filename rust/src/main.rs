//! `gbdi` — the leader binary: workload/dump generation, analysis,
//! compression, verification, the Figure-1 experiment, the coordinator
//! service demo, and the memsim bandwidth experiment.
//!
//! Run `gbdi --help` for the command list; every experiment in
//! EXPERIMENTS.md names the command that regenerates it.

use gbdi::baselines::{self, GbdiWholeImage};
use gbdi::cli::{App, Arg};
use gbdi::cluster::{ArtifactSelector, BaseSelector, SelectorConfig, SelectorKind};
use gbdi::codec::{BlockCodec, CodecKind};
use gbdi::container::{self, Container};
use gbdi::coordinator::{CompressionService, ServiceConfig};
use gbdi::frame::Frame;
use gbdi::gbdi::{analyze, GbdiCodec, GbdiConfig, GlobalBaseTable};
use gbdi::memsim::{self, trace, CompressedMemory, DramModel};
use gbdi::persist::{self, Durability, PersistConfig, RealFs};
use gbdi::report::{bar_chart, fmt_bytes, fmt_ratio, Table};
use gbdi::runtime::ArtifactRuntime;
use gbdi::server::{self, protocol::stats_field, Client, LoadGenConfig, Server, ServerConfig};
use gbdi::util::prng::Rng;
use gbdi::{elf, workloads};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn app() -> App {
    App::new("gbdi", "GBDI memory compression — paper reproduction toolkit")
        .subcommand(
            App::new("gen", "generate a synthetic memory dump (ELF core)")
                .arg(Arg::opt("workload", "mcf", "workload name (see `list`)"))
                .arg(Arg::opt("size", "16m", "image bytes (k/m/g suffixes)"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::req("out", "output ELF path")),
        )
        .subcommand(App::new("list", "list the paper's nine workloads"))
        .subcommand(
            App::new("analyze", "background analysis: print the global base table")
                .arg(Arg::pos("input", "ELF dump or raw image"))
                .arg(Arg::opt("bases", "64", "number of global bases"))
                .arg(Arg::opt("samples", "4096", "analysis sample words"))
                .arg(Arg::opt("selector", "lloyd", "base selector: lloyd|minibatch|histogram")),
        )
        .subcommand(
            App::new("compress", "compress a dump/file into a framed container")
                .arg(Arg::pos("input", "ELF dump or raw image"))
                .arg(Arg::req("out", "output container path"))
                .arg(Arg::opt("codec", "gbdi", "block codec: gbdi|bdi|fpc"))
                .arg(Arg::opt("threads", "0", "compression threads (0 = all cores)"))
                .arg(Arg::opt("bases", "64", "number of global bases (gbdi)"))
                .arg(isa_arg()),
        )
        .subcommand(
            App::new("decompress", "decompress a framed container (codec auto-detected)")
                .arg(Arg::pos("input", "compressed container"))
                .arg(Arg::req("out", "output path"))
                .arg(isa_arg()),
        )
        .subcommand(
            App::new("read", "random-access: decode single blocks (no full decode)")
                .arg(Arg::pos("input", "compressed container"))
                .arg(Arg::opt("block", "0", "first block index"))
                .arg(Arg::opt("count", "1", "blocks to read"))
                .arg(Arg::opt("out", "", "write raw bytes here instead of hex-dumping")),
        )
        .subcommand(
            App::new(
                "bench-access",
                "single-block read latency vs whole-image decode (the Frame API's reason to exist)",
            )
            .arg(Arg::opt("workload", "mcf", "workload name"))
            .arg(Arg::opt("size", "4m", "image bytes"))
            .arg(Arg::opt("codec", "gbdi", "block codec: gbdi|bdi|fpc"))
            .arg(Arg::opt("reads", "100k", "random block reads to time"))
            .arg(Arg::opt("seed", "7", "generator seed"))
            .arg(isa_arg()),
        )
        .subcommand(
            App::new("verify", "compress + decompress + bit-exactness check")
                .arg(Arg::pos("input", "ELF dump or raw image"))
                .arg(Arg::opt("codec", "gbdi", "block codec: gbdi|bdi|fpc"))
                .arg(Arg::opt("threads", "0", "parallel-path threads (0 = all cores)"))
                .arg(isa_arg()),
        )
        .subcommand(
            App::new("sweep", "compression-ratio sweep: every block codec x every workload")
                .arg(Arg::opt("size", "1m", "image bytes per workload"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::opt("threads", "0", "compression threads (0 = all cores)"))
                .arg(Arg::opt("csv", "", "also write CSV here")),
        )
        .subcommand(
            App::new("figure1", "reproduce the paper's Figure 1 (per-workload ratios)")
                .arg(Arg::opt("size", "8m", "image bytes per workload"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::opt("csv", "", "also write CSV here")),
        )
        .subcommand(
            App::new("serve", "run the coordinator service demo")
                .arg(Arg::opt("pages", "512", "pages to stream"))
                .arg(Arg::opt("workers", "4", "compression workers"))
                .arg(Arg::opt("shards", "", "page-store shards (default from config: 8)"))
                .arg(Arg::opt("batch", "", "pages per ingest batch (default from config: 32)"))
                .arg(Arg::opt(
                    "cache-bytes",
                    "",
                    "hot-block cache budget (k/m/g; default from config: 0 = off)",
                ))
                .arg(Arg::opt("workload", "mix", "workload or 'mix'"))
                .arg(Arg::opt("codec", "gbdi", "gbdi (adaptive analyzer) or bdi|fpc (static)"))
                .arg(Arg::opt(
                    "selector",
                    "",
                    "base selector: lloyd|minibatch|histogram|artifact (default from config)",
                ))
                .arg(Arg::opt("drift", "", "drift-detection margin override (e.g. 1.02)"))
                .arg(Arg::opt(
                    "config",
                    "",
                    "TOML config ([codec] + [service] + [analyzer] + [server])",
                ))
                .arg(Arg::opt(
                    "listen",
                    "",
                    "serve the GBN1 network protocol on host:port instead of the demo",
                ))
                .arg(Arg::opt(
                    "stats-every",
                    "10",
                    "network mode: seconds between stats lines (0 = quiet)",
                ))
                .arg(Arg::opt(
                    "data-dir",
                    "",
                    "durable data directory (WAL + checkpoints); recovers on start",
                ))
                .arg(Arg::opt(
                    "fsync-batch",
                    "",
                    "WAL group commit: fsync every N appends (default from config: 1)",
                ))
                .arg(Arg::opt(
                    "wal-limit",
                    "",
                    "checkpoint once the WAL outgrows this (k/m/g; default from config: 8m)",
                ))
                .arg(Arg::flag(
                    "integrity",
                    "enable page CRCs: verify on read, background scrub, quarantine + self-heal",
                ))
                .arg(Arg::opt(
                    "scrub-mib-s",
                    "",
                    "integrity: background scrub budget, MiB/s (default from config: 8)",
                ))
                .arg(Arg::opt(
                    "handshake-timeout",
                    "",
                    "ms a new connection gets to complete the hello (default from config: 5000)",
                ))
                .arg(Arg::opt(
                    "write-timeout",
                    "",
                    "ms a blocked response write gets before the connection is dropped \
                     (default from config: 10000)",
                ))
                .arg(Arg::opt(
                    "chaos-corrupt",
                    "",
                    "TEST HOOK: flip bits once pages exist; comma list of page:block:bit \
                     (requires --integrity; used by the CI chaos smoke)",
                ))
                .arg(isa_arg()),
        )
        .subcommand(
            App::new("recover", "rebuild a store from a serve data directory and report")
                .arg(Arg::req("data-dir", "data directory written by `gbdi serve --data-dir`"))
                .arg(Arg::opt("shards", "", "resize the recovered store to this many shards"))
                .arg(Arg::opt("cache-bytes", "0", "hot-block cache budget for the rebuilt store"))
                .arg(Arg::flag("verify", "decode every recovered page, fail on any corruption"))
                .arg(Arg::flag(
                    "checkpoint",
                    "fold the WAL into a fresh checkpoint (compacts the directory)",
                )),
        )
        .subcommand(
            App::new("client", "GBN1 network client: one-shot ops and the load generator")
                .arg(Arg::opt("addr", "127.0.0.1:7070", "server address"))
                .arg(Arg::opt("op", "stats", "stats|flush|reanalyze|shutdown|put|get|range|load"))
                .arg(Arg::opt("page", "0", "page id (get|range; first id for put)"))
                .arg(Arg::opt("block", "0", "block index (get; first block for range)"))
                .arg(Arg::opt("count", "8", "blocks to read (range)"))
                .arg(Arg::opt("pages", "64", "pages to ingest (put) / preload (load)"))
                .arg(Arg::opt("page-bytes", "4096", "logical page size (put|load)"))
                .arg(Arg::opt("workload", "mcf", "workload generating page payloads"))
                .arg(Arg::opt("seed", "7", "payload/trace seed"))
                .arg(Arg::opt("conns", "4", "load: concurrent connections"))
                .arg(Arg::opt("ops", "5000", "load: trace ops per connection"))
                .arg(Arg::opt("pipeline", "32", "load: requests in flight per connection"))
                .arg(Arg::opt("read-frac", "0.8", "load: fraction of single-block GETs"))
                .arg(Arg::opt("zipf", "0", "load: zipf skew for page choice (0 = uniform)"))
                .arg(Arg::flag(
                    "check-stats",
                    "load: assert server STATS deltas match client tallies \
                     (requires an otherwise idle server; incompatible with chaos — \
                     replays repeat server-side work)",
                ))
                .arg(Arg::flag(
                    "check-content",
                    "load: verify every GET against the only two legal values per block; \
                     any mismatch (a silently-wrong read) fails the run",
                ))
                .arg(Arg::opt(
                    "max-reconnects",
                    "",
                    "load: transport failures each connection rides out (default 8)",
                ))
                .arg(Arg::opt(
                    "chaos-cut",
                    "0",
                    "load: proxy traffic and cut connections every ~N bytes (0 = no proxy)",
                ))
                .arg(Arg::opt(
                    "chaos-corrupt-wire",
                    "0",
                    "load: proxy traffic and flip a bit every ~N bytes (0 = off)",
                ))
                .arg(Arg::opt(
                    "chaos-stall",
                    "0",
                    "load: proxy traffic and stall 5 ms every ~N bytes (0 = off)",
                ))
                .arg(Arg::opt("chaos-seed", "1", "load: fault-schedule seed")),
        )
        .subcommand(
            App::new("selectors", "base-selector ablation: ratio + analysis time per workload")
                .arg(Arg::opt("size", "1m", "image bytes per workload"))
                .arg(Arg::opt("seed", "7", "generator seed"))
                .arg(Arg::opt("bases", "64", "number of global bases"))
                .arg(Arg::opt("csv", "", "also write CSV here")),
        )
        .subcommand(
            App::new("memsim", "compressed-memory bandwidth experiment (E7)")
                .arg(Arg::opt("workload", "triangle_count", "workload name"))
                .arg(Arg::opt("codec", "gbdi", "block codec: gbdi|bdi|fpc"))
                .arg(Arg::opt("size", "4m", "image bytes"))
                .arg(Arg::opt("shards", "1", "page-store shards behind the memory"))
                .arg(Arg::opt(
                    "cache-bytes",
                    "0",
                    "hot-block cache budget (k/m/g; 0 = off, the exact sector model)",
                ))
                .arg(Arg::opt("trace", "streaming", "streaming|uniform|zipf"))
                .arg(Arg::opt("accesses", "65536", "trace length"))
                .arg(Arg::opt("burst", "16", "DRAM burst bytes"))
                .arg(isa_arg()),
        )
        .subcommand(App::new("info", "platform + artifact status"))
}

/// The shared `--isa` option: every command with a compression or
/// decompression hot path accepts it (DESIGN.md §10).
fn isa_arg() -> Arg {
    Arg::opt("isa", "", "force SIMD backend: scalar|sse2|avx2|neon (default: auto-detect)")
}

/// Install the `--isa` kernel override before any blocks move. An empty
/// value (the default) keeps `GBDI_FORCE_ISA` / auto-detection in charge;
/// unknown names and backends this host cannot execute are hard errors.
fn apply_isa(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let name = m.get("isa");
    if name.is_empty() {
        return Ok(());
    }
    let isa = gbdi::simd::Isa::parse(name).ok_or_else(|| {
        gbdi::Error::Config(format!("unknown --isa '{name}' (scalar|sse2|avx2|neon)"))
    })?;
    gbdi::simd::force(Some(isa)).map_err(gbdi::Error::Config)
}

fn load_image(path: &str) -> gbdi::Result<Vec<u8>> {
    let raw = std::fs::read(path)?;
    // ELF? take the loadable segments; otherwise treat as a raw image
    if raw.len() >= 4 && raw[0..4] == [0x7F, b'E', b'L', b'F'] {
        Ok(elf::parse(&raw)?.flatten())
    } else {
        Ok(raw)
    }
}

fn parse_codec(m: &gbdi::cli::Matches) -> gbdi::Result<CodecKind> {
    let name = m.get("codec");
    CodecKind::parse(name)
        .ok_or_else(|| gbdi::Error::Config(format!("unknown codec '{name}' (gbdi|bdi|fpc)")))
}

fn parse_threads(m: &gbdi::cli::Matches) -> usize {
    match m.get_usize("threads") {
        0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
        n => n,
    }
}

fn cmd_gen(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let name = m.get("workload");
    let w = workloads::by_name(name)
        .ok_or_else(|| gbdi::Error::Config(format!("unknown workload '{name}'")))?;
    let image = w.generate(m.get_usize("size"), m.get_u64("seed"));
    let seg = elf::Segment { vaddr: 0x10000, flags: 6, data: image };
    let file = elf::write_core(&[seg]);
    std::fs::write(m.get("out"), &file)?;
    println!("wrote {} ({}) for workload {}", m.get("out"), fmt_bytes(file.len() as u64), w.name());
    Ok(())
}

fn cmd_list() {
    let mut t = Table::new(&["name", "group", "paper dump", "memory model"]);
    for w in workloads::all() {
        t.row(&[
            w.name().to_string(),
            w.group().label().to_string(),
            w.paper_dump().to_string(),
            w.description().to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_analyze(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let image = load_image(m.get("input"))?;
    let cfg = GbdiConfig {
        num_bases: m.get_usize("bases"),
        analysis_samples: m.get_usize("samples"),
        ..Default::default()
    };
    cfg.validate().map_err(gbdi::Error::Config)?;
    let sel_name = m.get("selector");
    let kind = SelectorKind::parse(sel_name).ok_or_else(|| {
        gbdi::Error::Config(format!("unknown selector '{sel_name}' (lloyd|minibatch|histogram)"))
    })?;
    let samples = analyze::sample_image(&image, &cfg);
    let selection = kind.build().select(&samples, None, &SelectorConfig::from_gbdi(&cfg))?;
    let table = GlobalBaseTable::from_selection(&samples, &selection, &cfg, 0);
    println!("image: {} ({})", m.get("input"), fmt_bytes(image.len() as u64));
    println!(
        "selector: {} ({} pass{})",
        kind.name(),
        selection.iters_run,
        if selection.iters_run == 1 { "" } else { "es" }
    );
    println!("global bases: {} (budget {})", table.len(), cfg.num_bases);
    let mut t = Table::new(&["base (hex)", "width class"]);
    for e in table.entries().iter().take(32) {
        t.row(&[format!("{:#010x}", e.base), format!("{} bits", e.width)]);
    }
    print!("{}", t.render());
    if table.len() > 32 {
        println!("... and {} more", table.len() - 32);
    }
    let codec = GbdiCodec::new(table, cfg);
    let (comp, stats) = codec.compress_image_stats(&image);
    println!(
        "ratio {}  blocks: {} gbdi / {} zero / {} rep / {} raw  outliers {:.2}%",
        fmt_ratio(comp.ratio()),
        stats.gbdi_blocks,
        stats.zero_blocks,
        stats.rep_blocks,
        stats.raw_blocks,
        stats.outlier_frac() * 100.0
    );
    Ok(())
}

fn cmd_compress(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let image = load_image(m.get("input"))?;
    let kind = parse_codec(m)?;
    let cfg = GbdiConfig { num_bases: m.get_usize("bases"), ..Default::default() };
    cfg.validate().map_err(gbdi::Error::Config)?;
    let codec = kind.build_for_image(&image, &cfg);
    let comp = container::compress_parallel(codec.as_ref(), &image, parse_threads(m));
    let bytes = comp.to_bytes();
    std::fs::write(m.get("out"), &bytes)?;
    println!(
        "{} -> {} [{}]: {} -> {} ({})",
        m.get("input"),
        m.get("out"),
        kind.name(),
        fmt_bytes(image.len() as u64),
        fmt_bytes(bytes.len() as u64),
        fmt_ratio(image.len() as f64 / bytes.len() as f64)
    );
    Ok(())
}

fn cmd_decompress(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let comp = Container::from_bytes(&std::fs::read(m.get("input"))?)?;
    let out = comp.decompress()?;
    std::fs::write(m.get("out"), &out)?;
    println!(
        "{} -> {} [{}] ({})",
        m.get("input"),
        m.get("out"),
        comp.codec_id.name(),
        fmt_bytes(out.len() as u64)
    );
    Ok(())
}

fn cmd_read(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let comp = Container::from_bytes(&std::fs::read(m.get("input"))?)?;
    let codec_name = comp.codec_id.name();
    let frame = comp.into_frame()?;
    let first = m.get_usize("block");
    let count = m.get_usize("count").max(1);
    if first >= frame.n_blocks() {
        return Err(gbdi::Error::Config(format!(
            "--block {first} out of range ({} blocks)",
            frame.n_blocks()
        )));
    }
    let mut buf = vec![0u8; frame.block_bytes()];
    let mut raw = Vec::new();
    let mut read = 0usize;
    let out_path = m.get("out");
    for i in first..(first + count).min(frame.n_blocks()) {
        let n = frame.read_block(i, &mut buf)?;
        if out_path.is_empty() {
            use std::fmt::Write as _;
            let mut hex = String::with_capacity(64);
            for b in &buf[..n.min(32)] {
                let _ = write!(hex, "{b:02x}");
            }
            println!(
                "block {i:>8}  {:>5} bits  {}{}",
                frame.block_bits(i),
                hex,
                if n > 32 { "…" } else { "" }
            );
        } else {
            raw.extend_from_slice(&buf[..n]);
        }
        read += 1;
    }
    if !out_path.is_empty() {
        std::fs::write(out_path, &raw)?;
        println!(
            "wrote {} ({read} blocks, codec {codec_name}) to {out_path}",
            fmt_bytes(raw.len() as u64)
        );
    }
    Ok(())
}

fn cmd_bench_access(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let w = workloads::by_name(m.get("workload"))
        .ok_or_else(|| gbdi::Error::Config("unknown workload".into()))?;
    let image = w.generate(m.get_usize("size"), m.get_u64("seed"));
    let kind = parse_codec(m)?;
    let codec: Arc<dyn BlockCodec> =
        Arc::from(kind.build_for_image(&image, &GbdiConfig::default()));
    let comp = container::compress(codec.as_ref(), &image);
    // whole-image decode latency (the old API's only read path), then
    // hand the container to the frame without copying the payload
    let t0 = std::time::Instant::now();
    let full = comp.decompress()?;
    let t_full = t0.elapsed();
    assert_eq!(full.len(), image.len());
    let frame = Frame::with_codec(comp, Arc::clone(&codec))?;
    // random single-block reads through the frame index
    let reads = m.get_usize("reads").max(1);
    let n = frame.n_blocks() as u64;
    let mut rng = Rng::new(0xACCE55);
    let mut buf = vec![0u8; frame.block_bytes()];
    let t0 = std::time::Instant::now();
    for _ in 0..reads {
        let i = rng.below(n) as usize;
        frame.read_block(i, &mut buf)?;
    }
    let t_block = t0.elapsed();
    let per_read = t_block.as_nanos() as f64 / reads as f64;
    let speedup = t_full.as_nanos() as f64 / per_read.max(1e-9);
    println!(
        "workload {} codec {}: image {} in {} blocks",
        w.name(),
        kind.name(),
        fmt_bytes(image.len() as u64),
        frame.n_blocks()
    );
    let mut t = Table::new(&["path", "latency", "per logical byte"]);
    t.row(&[
        "whole-image decompress".into(),
        format!("{:.2} ms", t_full.as_secs_f64() * 1e3),
        format!("{:.2} ns/B", t_full.as_nanos() as f64 / image.len() as f64),
    ]);
    t.row(&[
        format!("Frame::read_block x{reads}"),
        format!("{per_read:.0} ns/read"),
        format!("{:.2} ns/B", per_read / frame.block_bytes() as f64),
    ]);
    print!("{}", t.render());
    println!("single-block read is {speedup:.0}x faster than a full decode");
    Ok(())
}

fn cmd_verify(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let image = load_image(m.get("input"))?;
    let kind = parse_codec(m)?;
    let threads = parse_threads(m);
    let codec: Arc<dyn BlockCodec> =
        Arc::from(kind.build_for_image(&image, &GbdiConfig::default()));
    let t0 = std::time::Instant::now();
    let comp = container::compress(codec.as_ref(), &image);
    let t_c = t0.elapsed();
    let t0 = std::time::Instant::now();
    let back = comp.decompress()?;
    let t_d = t0.elapsed();
    let ok = back == image;
    // the parallel pipeline must reproduce the serial framing bit-for-bit
    let par = container::compress_parallel(codec.as_ref(), &image, threads);
    let par_ok = par.block_bits == comp.block_bits && par.decompress()? == image;
    // the frame's caller-owned-buffer decode (the serving read path)
    // must agree too; `buf` is reused, not reallocated per decode
    let frame = Frame::with_codec(par, Arc::clone(&codec))?;
    let mut buf = Vec::new();
    frame.decompress_into(&mut buf)?;
    let frame_ok = buf == image;
    println!(
        "codec {}  reconstruction: {}  parallel({threads}t): {}  frame: {}  ratio {}  compress {:.1} MiB/s  decompress {:.1} MiB/s",
        kind.name(),
        if ok { "BIT-EXACT" } else { "MISMATCH" },
        if par_ok { "BIT-EXACT" } else { "MISMATCH" },
        if frame_ok { "BIT-EXACT" } else { "MISMATCH" },
        fmt_ratio(comp.ratio()),
        image.len() as f64 / (1 << 20) as f64 / t_c.as_secs_f64(),
        image.len() as f64 / (1 << 20) as f64 / t_d.as_secs_f64(),
    );
    if !ok || !par_ok || !frame_ok {
        return Err(gbdi::Error::Corrupt("roundtrip mismatch".into()));
    }
    Ok(())
}

fn cmd_sweep(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let size = m.get_usize("size");
    let seed = m.get_u64("seed");
    let threads = parse_threads(m);
    let kinds = CodecKind::all();
    let mut header: Vec<String> = vec!["workload".into()];
    header.extend(kinds.iter().map(|k| k.name().to_string()));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    let mut sums = vec![0.0f64; kinds.len()];
    let mut n = 0usize;
    for w in workloads::all() {
        let img = w.generate(size, seed);
        let mut row = vec![w.name().to_string()];
        for (i, kind) in kinds.iter().enumerate() {
            let codec = kind.build_for_image(&img, &GbdiConfig::default());
            let comp = container::compress_parallel(codec.as_ref(), &img, threads);
            let r = comp.ratio();
            sums[i] += r;
            row.push(format!("{r:.3}"));
        }
        t.row(&row);
        n += 1;
    }
    let mut mean_row = vec!["MEAN".to_string()];
    for s in &sums {
        mean_row.push(format!("{:.3}", s / n as f64));
    }
    t.row(&mean_row);
    println!(
        "== block-codec sweep: {} per workload, {threads} threads ==\n",
        fmt_bytes(size as u64)
    );
    print!("{}", t.render());
    let csv_path = m.get("csv");
    if !csv_path.is_empty() {
        std::fs::write(csv_path, t.csv())?;
        println!("csv written to {csv_path}");
    }
    Ok(())
}

fn cmd_figure1(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let size = m.get_usize("size");
    let seed = m.get_u64("seed");
    let codec = GbdiWholeImage::default();
    let mut items = Vec::new();
    let mut c_ratios = Vec::new();
    let mut j_ratios = Vec::new();
    for w in workloads::all() {
        let img = w.generate(size, seed);
        let r = baselines::ratio_of(&codec, &img);
        items.push((w.name().to_string(), r));
        if w.group().is_c_family() {
            c_ratios.push(r);
        } else {
            j_ratios.push(r);
        }
    }
    println!("{}", bar_chart("Figure 1 — GBDI compression ratio per workload", &items, 48));
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let all: Vec<f64> = items.iter().map(|(_, r)| *r).collect();
    println!(
        "C-workloads mean {} (paper: 1.4x) | Java mean {} (paper: 1.55x) | overall {} (paper: 1.45x)",
        fmt_ratio(mean(&c_ratios)),
        fmt_ratio(mean(&j_ratios)),
        fmt_ratio(mean(&all)),
    );
    let csv_path = m.get("csv");
    if !csv_path.is_empty() {
        let mut t = Table::new(&["workload", "ratio"]);
        for (n, r) in &items {
            t.row(&[n.clone(), format!("{r:.4}")]);
        }
        std::fs::write(csv_path, t.csv())?;
        println!("csv written to {csv_path}");
    }
    Ok(())
}

fn cmd_serve(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let pages = m.get_u64("pages");
    let kind = parse_codec(m)?;
    let file = match m.get("config") {
        "" => None,
        path => Some(gbdi::config::ConfigFile::load(path).map_err(gbdi::Error::Config)?),
    };
    let mut cfg = match &file {
        None => ServiceConfig { analyze_every: 64, ..Default::default() },
        Some(f) => f.service_config().map_err(gbdi::Error::Config)?,
    };
    cfg.workers = m.get_usize("workers");
    if !m.get("shards").is_empty() {
        let shards = m.get_usize("shards");
        if shards == 0 {
            return Err(gbdi::Error::Config("--shards must be >= 1".into()));
        }
        cfg.shards = shards;
    }
    if !m.get("batch").is_empty() {
        let batch = m.get_usize("batch");
        if batch == 0 {
            return Err(gbdi::Error::Config("--batch must be >= 1".into()));
        }
        cfg.ingest_batch = batch;
    }
    if !m.get("drift").is_empty() {
        let drift = m.get_f64("drift");
        if drift < 1.0 {
            return Err(gbdi::Error::Config(format!("--drift {drift} must be >= 1.0")));
        }
        cfg.drift_margin = drift;
    }
    if !m.get("cache-bytes").is_empty() {
        cfg.cache_bytes = m.get_usize("cache-bytes");
    }
    // integrity plane: [integrity] from --config, --integrity forces it on
    if m.get_flag("integrity") {
        cfg.integrity.enabled = true;
    }
    if !m.get("scrub-mib-s").is_empty() {
        let mib = m.get_u64("scrub-mib-s");
        if mib == 0 {
            return Err(gbdi::Error::Config("--scrub-mib-s must be >= 1".into()));
        }
        cfg.integrity.scrub_mib_s = mib;
    }
    if !m.get("chaos-corrupt").is_empty() && !cfg.integrity.enabled {
        return Err(gbdi::Error::Config("--chaos-corrupt requires --integrity".into()));
    }
    // durability: [persist] from --config, overridden by --data-dir/--fsync-batch/--wal-limit.
    // No data dir anywhere means persistence stays off and serving is untouched.
    let mut persist_cfg = match &file {
        None => None,
        Some(f) => f.persist_config().map_err(gbdi::Error::Config)?,
    };
    if !m.get("data-dir").is_empty() {
        let pc = persist_cfg.take().map(|(_, c)| c).unwrap_or_default();
        persist_cfg = Some((m.get("data-dir").to_string(), pc));
    }
    if let Some((_, pc)) = persist_cfg.as_mut() {
        if !m.get("fsync-batch").is_empty() {
            let batch = m.get_usize("fsync-batch");
            if batch == 0 {
                return Err(gbdi::Error::Config("--fsync-batch must be >= 1".into()));
            }
            pc.fsync_batch = batch;
        }
        if !m.get("wal-limit").is_empty() {
            let limit = m.get_u64("wal-limit");
            if limit < 4 << 10 {
                return Err(gbdi::Error::Config("--wal-limit must be >= 4k".into()));
            }
            pc.wal_limit_bytes = limit;
        }
    }
    if let Some((dir, pc)) = &persist_cfg {
        let (d, report) = Durability::open(
            Arc::new(RealFs),
            dir,
            pc.clone(),
            cfg.shards,
            cfg.cache_bytes,
        )?;
        println!(
            "persistence: '{dir}' (fsync batch {}, wal limit {})",
            pc.fsync_batch,
            fmt_bytes(pc.wal_limit_bytes)
        );
        println!("{report}");
        cfg.persist = Some(d);
    }
    let (shards, ingest_batch, cache_bytes) = (cfg.shards, cfg.ingest_batch, cfg.cache_bytes);
    let svc = if kind == CodecKind::Gbdi {
        // the --selector flag overrides [analyzer] selector from --config
        let selector: Box<dyn BaseSelector> = match m.get("selector") {
            "" => cfg.selector.build(),
            "artifact" => match ArtifactRuntime::new(ArtifactRuntime::default_dir()) {
                Ok(rt) if rt.has_artifact("kmeans_k64") => {
                    println!("artifact selector: PJRT ({})", rt.platform());
                    Box::new(ArtifactSelector::new(Arc::new(rt)))
                }
                _ => {
                    println!("artifact selector unavailable (run `make artifacts`); using lloyd");
                    Box::new(gbdi::cluster::LloydSelector)
                }
            },
            name => SelectorKind::parse(name)
                .ok_or_else(|| {
                    gbdi::Error::Config(format!(
                        "unknown selector '{name}' (lloyd|minibatch|histogram|artifact)"
                    ))
                })?
                .build(),
        };
        println!(
            "analyzer selector: {} (drift margin {:.3})",
            selector.name(),
            cfg.drift_margin
        );
        CompressionService::start_with_selector(cfg, selector)?
    } else {
        println!("static codec: {} (no background analyzer)", kind.name());
        let codec: Arc<dyn BlockCodec> = Arc::from(kind.build_for_image(&[], &cfg.codec));
        CompressionService::start_static(cfg, codec)?
    };
    println!("store: {shards} shard(s), ingest batches of {ingest_batch} page(s)");
    if cache_bytes > 0 {
        println!(
            "cache: {} hot-block tier (recompression deferred while hot)",
            fmt_bytes(cache_bytes as u64)
        );
    }
    let integrity_on = {
        let i = &svc.config().integrity;
        if i.enabled {
            println!(
                "integrity: page CRCs on ({} on reads), scrub {} MiB/s, quarantine + {}",
                if i.verify_reads { "verified" } else { "not verified" },
                i.scrub_mib_s,
                if persist_cfg.is_some() {
                    "self-heal from durable state"
                } else {
                    "DATA_LOSS (no durable copy)"
                }
            );
        }
        i.enabled
    };
    let chaos_specs = parse_chaos_specs(m.get("chaos-corrupt"))?;
    let listen = m.get("listen");
    if !listen.is_empty() {
        let mut scfg = match &file {
            None => ServerConfig::default(),
            Some(f) => f.server_config().map_err(gbdi::Error::Config)?,
        };
        scfg.listen = listen.to_string();
        if !m.get("handshake-timeout").is_empty() {
            let ms = m.get_u64("handshake-timeout");
            if ms == 0 {
                return Err(gbdi::Error::Config("--handshake-timeout must be >= 1 ms".into()));
            }
            scfg.handshake_timeout_ms = ms;
        }
        if !m.get("write-timeout").is_empty() {
            let ms = m.get_u64("write-timeout");
            if ms == 0 {
                return Err(gbdi::Error::Config("--write-timeout must be >= 1 ms".into()));
            }
            scfg.write_timeout_ms = ms;
        }
        return serve_network(m.get_u64("stats-every"), svc, scfg, integrity_on, chaos_specs);
    }
    if !chaos_specs.is_empty() {
        return Err(gbdi::Error::Config("--chaos-corrupt requires --listen".into()));
    }
    let names: Vec<&str> = match m.get("workload") {
        "mix" => vec!["mcf", "perlbench", "fluidanimate", "triangle_count", "svm"],
        w => vec![w],
    };
    let mut rng = Rng::new(1);
    let mut batch: Vec<(u64, Vec<u8>)> = Vec::with_capacity(ingest_batch);
    for i in 0..pages {
        let w = workloads::by_name(names[rng.below(names.len() as u64) as usize])
            .ok_or_else(|| gbdi::Error::Config("unknown workload".into()))?;
        batch.push((i, w.generate(4096, i)));
        if batch.len() >= ingest_batch {
            svc.submit_batch(std::mem::take(&mut batch));
        }
        if i % 128 == 127 {
            svc.submit_batch(std::mem::take(&mut batch));
            svc.flush();
            let snap = svc.metrics();
            println!(
                "pages {:>6}  ratio {}  {:.0} MiB/s  analyses {} swaps {} (table v{})",
                snap.pages_in,
                fmt_ratio(snap.ratio()),
                snap.compress_mib_s(),
                snap.analyses,
                snap.table_swaps,
                svc.current_version()
            );
        }
    }
    svc.submit_batch(batch);
    svc.flush();
    // block-granular serving: random single-line GETs and a few PUTs
    // straight out of the compressed frames (the paths a memory-expansion
    // deployment actually exercises)
    let mut line = vec![0u8; 64];
    for _ in 0..if pages > 0 { 2048 } else { 0 } {
        let pid = rng.below(pages);
        let blk = rng.below(64) as usize;
        svc.read_block(pid, blk, &mut line)?;
    }
    for pid in 0..pages.min(16) {
        svc.write_block(pid, (pid % 64) as usize, &line)?;
    }
    // page readback through the caller-owned-buffer path: one Vec is
    // reused across pages, so this loop stops allocating once the
    // buffer has grown to page size
    let mut page_buf = Vec::new();
    for pid in 0..pages.min(64) {
        svc.read_page_into(pid, &mut page_buf)?;
    }
    let migrated = svc.recompress_step()?;
    let (logical, stored, ratio) = svc.storage_ratio();
    // per-shard telemetry: occupancy, lock-hold time, block-op latency
    let mut t = Table::new(&["shard", "pages", "stored", "lock holds", "hold mean", "GET mean", "PUT mean"]);
    for s in svc.shard_metrics() {
        t.row(&[
            format!("{}", s.shard),
            format!("{}", s.pages),
            fmt_bytes(s.stored_bytes),
            format!("{}", s.lock_holds),
            format!("{:.0} ns", s.lock_hold_mean_ns()),
            format!("{:.0} ns", s.block_read_mean_ns()),
            format!("{:.0} ns", s.block_write_mean_ns()),
        ]);
    }
    print!("{}", t.render());
    let cache = svc.cache_totals();
    let snap = svc.shutdown();
    println!(
        "final: {} pages, {} -> {} stored ({}), {} migrated, {} swaps, {} analyses ({} skipped by drift detection)",
        snap.pages_in,
        fmt_bytes(logical as u64),
        fmt_bytes(stored as u64),
        fmt_ratio(ratio),
        migrated,
        snap.table_swaps,
        snap.analyses,
        snap.analyses_skipped
    );
    println!(
        "block serving: {} GETs @ {:.0} ns mean, {} PUTs @ {:.0} ns mean",
        snap.block_reads,
        snap.block_read_mean_ns(),
        snap.block_writes,
        snap.block_write_mean_ns()
    );
    if cache_bytes > 0 {
        println!(
            "cache: {:.1}% hit rate ({} hits / {} misses), {} resident ({} dirty), \
             {} evictions, {} deferred flushes",
            cache.hit_rate() * 100.0,
            cache.hits,
            cache.misses,
            fmt_bytes(cache.cached_bytes),
            fmt_bytes(cache.dirty_bytes),
            cache.evictions,
            cache.deferred_flushes
        );
    }
    Ok(())
}

/// Set from the SIGINT/SIGTERM handler; the network serve loop polls it.
static SHUTDOWN_SIGNAL: AtomicBool = AtomicBool::new(false);

/// Install a flag-setting SIGINT/SIGTERM handler through the C
/// runtime's `signal` (the libc crate is unavailable offline). The
/// handler only stores to an atomic, which is async-signal-safe; the
/// serve loop does the actual draining.
#[cfg(unix)]
fn install_shutdown_handler() {
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN_SIGNAL.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe {
        signal(2, on_signal); // SIGINT
        signal(15, on_signal); // SIGTERM
    }
}

/// Non-unix builds fall back to the process dying on Ctrl-C.
#[cfg(not(unix))]
fn install_shutdown_handler() {}

/// Parse the `--chaos-corrupt` test-hook spec: a comma list of
/// `page:block:bit` triples.
fn parse_chaos_specs(spec: &str) -> gbdi::Result<Vec<(u64, usize, u64)>> {
    if spec.is_empty() {
        return Ok(Vec::new());
    }
    let bad = |item: &str| {
        gbdi::Error::Config(format!("--chaos-corrupt: '{item}' is not page:block:bit"))
    };
    spec.split(',')
        .map(|item| {
            let parts: Vec<&str> = item.trim().split(':').collect();
            let [page, block, bit] = parts.as_slice() else { return Err(bad(item)) };
            Ok((
                page.parse::<u64>().map_err(|_| bad(item))?,
                block.parse::<usize>().map_err(|_| bad(item))?,
                bit.parse::<u64>().map_err(|_| bad(item))?,
            ))
        })
        .collect()
}

/// Network mode of `gbdi serve`: run the GBN1 front end until a signal
/// or a client SHUTDOWN op arrives, then drain connections, flush the
/// ingest queue and deferred dirty cache blocks, and report.
fn serve_network(
    stats_every: u64,
    svc: CompressionService,
    scfg: ServerConfig,
    integrity_on: bool,
    chaos_specs: Vec<(u64, usize, u64)>,
) -> gbdi::Result<()> {
    install_shutdown_handler();
    let server = Server::bind(svc, scfg)?;
    println!(
        "listening on {} (GBN1 v1) — SIGINT/SIGTERM or a SHUTDOWN op drains and exits",
        server.local_addr()
    );
    // --chaos-corrupt sidecar: poll until each targeted page exists,
    // then flip the requested bit in its stored image. Joined before
    // Server::stop so the service Arc unwraps cleanly.
    let chaos_stop = Arc::new(AtomicBool::new(false));
    let chaos_thread = if chaos_specs.is_empty() {
        None
    } else {
        let svc = server.service_shared();
        let stop = Arc::clone(&chaos_stop);
        Some(std::thread::spawn(move || {
            let mut remaining = chaos_specs;
            while !stop.load(Ordering::Acquire) && !remaining.is_empty() {
                remaining.retain(|&(page, block, bit)| {
                    let done = svc.corrupt_page_block(page, block, bit);
                    if done {
                        println!("chaos: flipped bit {bit} of page {page} block {block}");
                    }
                    !done
                });
                std::thread::sleep(Duration::from_millis(50));
            }
        }))
    };
    let mut last_stats = Instant::now();
    while !SHUTDOWN_SIGNAL.load(Ordering::SeqCst) && !server.shutdown_requested() {
        std::thread::sleep(Duration::from_millis(100));
        if stats_every > 0 && last_stats.elapsed().as_secs() >= stats_every {
            last_stats = Instant::now();
            let s = server.stats();
            let sm = server.service().metrics();
            let (_, _, ratio) = server.service().storage_ratio();
            let integrity = if integrity_on {
                let t = server.service().integrity_totals();
                format!(
                    ", scrubbed {} / corrupt {} / healed {} / quarantined {}",
                    t.scrubbed, t.corrupt_detected, t.healed, t.quarantined
                )
            } else {
                String::new()
            };
            println!(
                "stats: conns {}/{} open, ops {} ok / {} err / {} shed, {} in / {} out, \
                 pages {}, ratio {}, table v{}{integrity}",
                s.active_conns,
                s.accepted_conns,
                s.ops_ok,
                s.ops_err,
                s.shed_ops,
                fmt_bytes(s.bytes_in),
                fmt_bytes(s.bytes_out),
                sm.pages_in,
                fmt_ratio(ratio),
                server.service().current_version()
            );
        }
    }
    chaos_stop.store(true, Ordering::Release);
    if let Some(t) = chaos_thread {
        let _ = t.join();
    }
    println!("shutdown: draining connections and flushing deferred writes...");
    let (svc, s, flushed) = server.stop();
    if integrity_on {
        let t = svc.integrity_totals();
        println!(
            "integrity: {} pages scrubbed, {} corruptions detected, {} healed, {} quarantined",
            t.scrubbed, t.corrupt_detected, t.healed, t.quarantined
        );
    }
    let snap = svc.shutdown();
    println!(
        "served {} conns ({} rejected, {} protocol errors): {} ops ok / {} err / {} shed, \
         {} in / {} out, {} queue-full waits",
        s.accepted_conns,
        s.rejected_conns,
        s.protocol_errors,
        s.ops_ok,
        s.ops_err,
        s.shed_ops,
        fmt_bytes(s.bytes_in),
        fmt_bytes(s.bytes_out),
        s.queue_full_events
    );
    println!(
        "final: {} pages in, {} block reads / {} writes, {} table swaps, \
         {} deferred dirty blocks flushed on shutdown",
        snap.pages_in, snap.block_reads, snap.block_writes, snap.table_swaps, flushed
    );
    Ok(())
}

/// Hex of the first `max` bytes, with an ellipsis when truncated.
fn hex_prefix(data: &[u8], max: usize) -> String {
    use std::fmt::Write as _;
    let mut hex = String::with_capacity(2 * max + 4);
    for b in &data[..data.len().min(max)] {
        let _ = write!(hex, "{b:02x}");
    }
    if data.len() > max {
        hex.push('…');
    }
    hex
}

fn cmd_recover(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let dir = m.get("data-dir");
    let shards = if m.get("shards").is_empty() {
        None
    } else {
        Some(m.get_usize("shards").max(1))
    };
    let cache_bytes = m.get_usize("cache-bytes");
    let t0 = Instant::now();
    let (store, report) = persist::recover::recover(&RealFs, dir, shards, cache_bytes)?;
    println!("{report}");
    println!(
        "recovered {} page(s) in {:.1} ms",
        store.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    if m.get_flag("verify") {
        let mut buf = Vec::new();
        let mut bad = 0usize;
        for id in store.lagging_pages(u64::MAX) {
            if store.read_into(id, &mut buf).is_err() {
                bad += 1;
            }
        }
        if bad > 0 {
            return Err(gbdi::Error::Corrupt(format!(
                "verify: {bad} page(s) failed to decode"
            )));
        }
        println!("verify: all {} page(s) decode cleanly", store.len());
    }
    if m.get_flag("checkpoint") {
        // reopening through Durability re-runs recovery and always folds the
        // WAL into a fresh checkpoint under the atomic manifest-rename protocol
        let (d, _) = Durability::open(
            Arc::new(RealFs),
            dir,
            PersistConfig::default(),
            shards.unwrap_or_else(|| report.shards.max(1)),
            cache_bytes,
        )?;
        println!("checkpoint: WAL folded into epoch {}", d.epoch());
    }
    Ok(())
}

fn cmd_client(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let addr = m.get("addr");
    match m.get("op") {
        "stats" => {
            let mut c = Client::connect(addr)?;
            let stats = c.stats()?;
            let mut t = Table::new(&["field", "value"]);
            for (i, name) in stats_field::NAMES.iter().enumerate() {
                t.row(&[(*name).to_string(), stats.get(i).to_string()]);
            }
            print!("{}", t.render());
        }
        "flush" => {
            let mut c = Client::connect(addr)?;
            println!("flushed {} deferred dirty blocks", c.flush()?);
        }
        "reanalyze" => {
            let mut c = Client::connect(addr)?;
            let v = c.reanalyze()?;
            println!("analysis requested (table v{v} at acknowledge time)");
        }
        "shutdown" => {
            let mut c = Client::connect(addr)?;
            c.shutdown()?;
            println!("server acknowledged shutdown and is draining");
        }
        "put" => {
            let name = m.get("workload");
            let w = workloads::by_name(name)
                .ok_or_else(|| gbdi::Error::Config(format!("unknown workload '{name}'")))?;
            let mut c = Client::connect(addr)?;
            let first = m.get_u64("page");
            let pages = m.get_u64("pages");
            let page_bytes = m.get_usize("page-bytes");
            let mut put = 0u64;
            let mut id = first;
            while id < first + pages {
                let n = (first + pages - id).min(32);
                let batch = server::gen_pages(w.as_ref(), id, n, page_bytes, m.get_u64("seed"));
                put += u64::from(c.put_pages(&batch)?);
                id += n;
            }
            c.flush()?;
            println!("ingested {put} pages x {page_bytes} B starting at page {first}");
        }
        "get" => {
            let mut c = Client::connect(addr)?;
            let (page, block) = (m.get_u64("page"), m.get_u64("block") as u32);
            let data = c.get_block(page, block)?;
            println!("page {page} block {block}: {} bytes  {}", data.len(), hex_prefix(&data, 32));
        }
        "range" => {
            let mut c = Client::connect(addr)?;
            let (page, first) = (m.get_u64("page"), m.get_u64("block") as u32);
            let count = m.get_u64("count") as u32;
            let data = c.read_range(page, first, count)?;
            println!(
                "page {page} blocks {first}..{}: {}  {}",
                first.saturating_add(count),
                fmt_bytes(data.len() as u64),
                hex_prefix(&data, 32)
            );
        }
        "load" => return cmd_client_load(m),
        other => {
            return Err(gbdi::Error::Config(format!(
                "unknown --op '{other}' (stats|flush|reanalyze|shutdown|put|get|range|load)"
            )))
        }
    }
    Ok(())
}

/// `gbdi client --op load`: preload the page address space, run the
/// trace-driven multi-connection load generator, and (with
/// `--check-stats`) assert the server's STATS deltas agree with the
/// client-side tallies — the CI serving smoke runs exactly this.
fn cmd_client_load(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let mut cfg = LoadGenConfig {
        addr: m.get("addr").to_string(),
        conns: m.get_usize("conns").max(1),
        ops_per_conn: m.get_usize("ops").max(1),
        pipeline: m.get_usize("pipeline").max(1),
        pages: m.get_u64("pages").max(1),
        page_bytes: m.get_usize("page-bytes").max(64),
        read_fraction: m.get_f64("read-frac"),
        zipf_s: m.get_f64("zipf"),
        seed: m.get_u64("seed"),
        workload: m.get("workload").to_string(),
        check_content: m.get_flag("check-content"),
        ..Default::default()
    };
    if !m.get("max-reconnects").is_empty() {
        cfg.max_reconnects = m.get_u64("max-reconnects");
    }
    let check = m.get_flag("check-stats");
    // Chaos: interpose the in-process fault proxy between the load
    // generator and the server. Control connections (stats/flush) keep
    // talking to the real server directly.
    let upstream = cfg.addr.clone();
    let plan = server::FaultPlan {
        seed: m.get_u64("chaos-seed"),
        cut_every_bytes: m.get_u64("chaos-cut"),
        corrupt_every_bytes: m.get_u64("chaos-corrupt-wire"),
        stall_every_bytes: m.get_u64("chaos-stall"),
        ..Default::default()
    };
    let chaos =
        plan.cut_every_bytes > 0 || plan.corrupt_every_bytes > 0 || plan.stall_every_bytes > 0;
    let mut proxy = None;
    if chaos {
        if check {
            return Err(gbdi::Error::Config(
                "--check-stats is incompatible with chaos flags: replayed ops repeat \
                 server-side work, so deltas cannot match client tallies"
                    .into(),
            ));
        }
        let p = server::ChaosProxy::start(&upstream, plan.clone())?;
        println!(
            "chaos: proxying {} -> {upstream} (cut ~{} B, corrupt ~{} B, stall ~{} B, seed {})",
            p.addr(),
            plan.cut_every_bytes,
            plan.corrupt_every_bytes,
            plan.stall_every_bytes,
            plan.seed
        );
        cfg.addr = p.addr();
        proxy = Some(p);
    }
    let before = if check {
        let mut c = Client::connect(&upstream)?;
        Some(c.stats()?)
    } else {
        None
    };
    let preloaded = server::preload(&cfg)?;
    let preload_batches = cfg.pages.div_ceil(32);
    println!("preloaded {preloaded} pages x {} B from '{}'", cfg.page_bytes, cfg.workload);
    let rep = server::run_loadgen(&cfg)?;
    let mut c = Client::connect(&upstream)?;
    c.flush()?;
    let after = c.stats()?;

    let mut lat = rep.lat_ns.clone();
    lat.sort_unstable();
    println!(
        "{} conns x {} ops (pipeline {}): {:.0} ops/s over {:.2} s",
        cfg.conns,
        cfg.ops_per_conn,
        cfg.pipeline,
        rep.ops_per_s(),
        rep.wall_s
    );
    println!(
        "ok {} (reads {}, batch reads {} -> {} blocks, writes {}, ingest batches {} -> \
         {} pages), shed {}, err {}",
        rep.ops_ok,
        rep.reads,
        rep.batch_reads,
        rep.batch_read_blocks,
        rep.writes,
        rep.put_batches,
        rep.pages_put,
        rep.sheds,
        rep.ops_err
    );
    println!(
        "latency p50 {} ns  p99 {} ns  p999 {} ns",
        server::percentile(&lat, 0.50),
        server::percentile(&lat, 0.99),
        server::percentile(&lat, 0.999)
    );
    if chaos || rep.reconnects > 0 || rep.data_loss > 0 || cfg.check_content {
        println!(
            "resilience: {} reconnects, {} DATA_LOSS replies, {} content-check failures",
            rep.reconnects, rep.data_loss, rep.check_failures
        );
    }
    if let Some(mut p) = proxy {
        p.stop();
        println!("chaos: {} connections proxied, {} cuts injected", p.conns(), p.cuts());
    }
    if cfg.check_content && rep.check_failures > 0 {
        return Err(gbdi::Error::Corrupt(format!(
            "{} silently-wrong reads: GET payloads matched neither legal value",
            rep.check_failures
        )));
    }
    if let Some(before) = before {
        // Every OK op this process sent after the `before` snapshot:
        // the preload batches + the preload flush + the trace ops + the
        // final flush + the `after` STATS op (which counts itself).
        let expect_ok = preload_batches + 1 + rep.ops_ok + 1 + 1;
        let delta = |f: usize| after.get(f).saturating_sub(before.get(f));
        let checks = [
            ("ops_ok", delta(stats_field::OPS_OK), expect_ok),
            ("block_reads", delta(stats_field::BLOCK_READS), rep.reads + rep.batch_read_blocks),
            ("block_writes", delta(stats_field::BLOCK_WRITES), rep.writes),
            ("pages_in", delta(stats_field::PAGES_IN), preloaded + rep.pages_put),
        ];
        let mut bad = 0;
        for (name, got, want) in checks {
            let verdict = if got == want {
                "ok"
            } else {
                bad += 1;
                "MISMATCH"
            };
            println!("check {name}: server delta {got}, client tally {want} [{verdict}]");
        }
        if bad > 0 {
            return Err(gbdi::Error::Corrupt(format!("{bad} STATS consistency checks failed")));
        }
        println!("STATS deltas match client tallies");
    }
    Ok(())
}

fn cmd_selectors(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    let size = m.get_usize("size");
    let seed = m.get_u64("seed");
    let cfg = GbdiConfig { num_bases: m.get_usize("bases"), ..Default::default() };
    cfg.validate().map_err(gbdi::Error::Config)?;
    let sel_cfg = SelectorConfig::from_gbdi(&cfg);
    let kinds = SelectorKind::all();
    let mut header: Vec<String> = vec!["workload".into()];
    for k in kinds {
        header.push(format!("{} ratio", k.name()));
        header.push(format!("{} ms", k.name()));
    }
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    let mut ratio_sums = vec![0.0f64; kinds.len()];
    let mut ms_sums = vec![0.0f64; kinds.len()];
    let mut n = 0usize;
    for w in workloads::all() {
        let img = w.generate(size, seed);
        let samples = analyze::sample_image(&img, &cfg);
        let mut row = vec![w.name().to_string()];
        for (i, kind) in kinds.iter().enumerate() {
            let mut sel = kind.build();
            let t0 = std::time::Instant::now();
            let selection = sel.select(&samples, None, &sel_cfg)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let table = GlobalBaseTable::from_selection(&samples, &selection, &cfg, 0);
            let codec = GbdiCodec::new(table, cfg.clone());
            let ratio = codec.compress_image(&img).ratio();
            ratio_sums[i] += ratio;
            ms_sums[i] += ms;
            row.push(format!("{ratio:.3}"));
            row.push(format!("{ms:.2}"));
        }
        t.row(&row);
        n += 1;
    }
    let mut mean_row = vec!["MEAN".to_string()];
    for i in 0..kinds.len() {
        mean_row.push(format!("{:.3}", ratio_sums[i] / n as f64));
        mean_row.push(format!("{:.2}", ms_sums[i] / n as f64));
    }
    t.row(&mean_row);
    println!(
        "== base-selector ablation: {} per workload, K={} ==\n",
        fmt_bytes(size as u64),
        cfg.num_bases
    );
    print!("{}", t.render());
    let csv_path = m.get("csv");
    if !csv_path.is_empty() {
        std::fs::write(csv_path, t.csv())?;
        println!("csv written to {csv_path}");
    }
    Ok(())
}

fn cmd_memsim(m: &gbdi::cli::Matches) -> gbdi::Result<()> {
    apply_isa(m)?;
    let w = workloads::by_name(m.get("workload"))
        .ok_or_else(|| gbdi::Error::Config("unknown workload".into()))?;
    let image = w.generate(m.get_usize("size"), 7);
    let codec_kind = parse_codec(m)?;
    let shards = m.get_usize("shards");
    if shards == 0 {
        return Err(gbdi::Error::Config("--shards must be >= 1".into()));
    }
    let cache_bytes = m.get_usize("cache-bytes");
    let mut mem = CompressedMemory::new_with_cache(
        codec_kind.build_for_image(&image, &GbdiConfig::default()),
        shards,
        cache_bytes,
    );
    if cache_bytes > 0 {
        println!(
            "cache: {} hot-block tier on (sector accounting approximates deferred writes)",
            fmt_bytes(cache_bytes as u64)
        );
    }
    mem.store_image(&image);
    let kind = trace::TraceKind::parse(m.get("trace"))
        .ok_or_else(|| gbdi::Error::Config("bad trace kind".into()))?;
    let tr = trace::generate(kind, mem.total_blocks(), m.get_usize("accesses"), 0.1, 9);
    let model = DramModel { burst_bytes: m.get_u64("burst"), meta_miss: 0.05 };
    let rep = memsim::replay(&mut mem, &tr, &model)?;
    println!(
        "workload {} codec {} trace {}: capacity {}  bandwidth amplification {:.3}x",
        w.name(),
        codec_kind.name(),
        kind.label(),
        fmt_ratio(mem.capacity_ratio()),
        rep.amplification
    );
    let mut t = Table::new(&["memory-bound fraction", "speedup"]);
    for (f, s) in &rep.speedup_at {
        t.row(&[format!("{f:.1}"), format!("{s:.3}x")]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_info() {
    println!("gbdi {} — three-layer GBDI reproduction", env!("CARGO_PKG_VERSION"));
    let supported: Vec<&str> = gbdi::simd::supported().iter().map(|i| i.name()).collect();
    println!(
        "simd: active {} (detected best {}; supported: {})",
        gbdi::simd::active().isa.name(),
        gbdi::simd::Isa::detect_best().name(),
        supported.join(", ")
    );
    let dir = ArtifactRuntime::default_dir();
    println!("artifact dir: {}", dir.display());
    match ArtifactRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for stem in ["kmeans_k16", "kmeans_k64", "sizeest_k64"] {
                println!(
                    "  {stem}: {}",
                    if rt.has_artifact(stem) { "present" } else { "MISSING (run `make artifacts`)" }
                );
            }
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse_subcommands(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let m = &parsed.matches;
    let result = match parsed.command.as_str() {
        "gen" => cmd_gen(m),
        "list" => {
            cmd_list();
            Ok(())
        }
        "analyze" => cmd_analyze(m),
        "compress" => cmd_compress(m),
        "decompress" => cmd_decompress(m),
        "read" => cmd_read(m),
        "bench-access" => cmd_bench_access(m),
        "verify" => cmd_verify(m),
        "sweep" => cmd_sweep(m),
        "figure1" => cmd_figure1(m),
        "serve" => cmd_serve(m),
        "recover" => cmd_recover(m),
        "client" => cmd_client(m),
        "selectors" => cmd_selectors(m),
        "memsim" => cmd_memsim(m),
        "info" => {
            cmd_info();
            Ok(())
        }
        _ => unreachable!("parse_subcommands validated"),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
