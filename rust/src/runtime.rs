//! PJRT runtime: loads the AOT-compiled JAX/Pallas analysis artifacts
//! (HLO text in `artifacts/`) and executes them on the CPU PJRT client —
//! the bridge that keeps Python entirely off the request path.
//!
//! Artifacts (see `python/compile/aot.py`):
//!
//! * `kmeans_k{16,64}.hlo.txt` — `(x f32[4096], init f32[K]) ->
//!   (centroids f32[K], counts f32[K], inertia f32[1])`
//! * `sizeest_k64.hlo.txt` — `(x f32[4096], bases f32[64], widths
//!   f32[64]) -> (total f32[1], per_value f32[4096])`
//!
//! All are compiled once at startup and cached; executions are
//! synchronous (the coordinator calls them from its background analyzer
//! thread, never from compression workers).
//!
//! The PJRT bindings (the `xla` crate) are optional: build with
//! `--features pjrt` to enable them. Without the feature,
//! [`ArtifactRuntime::new`] returns a descriptive error and every caller
//! falls back to the native Rust analysis path — no native XLA toolchain
//! is required for the default build.

use crate::{Error, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// Sample count the artifacts were lowered for.
pub const N_SAMPLES: usize = 4096;
/// K variants available as k-means artifacts.
pub const KMEANS_KS: [usize; 2] = [16, 64];

/// Output of an artifact k-means run.
#[derive(Debug, Clone)]
pub struct KmeansFit {
    /// Final centroids (f32, caller snaps to words).
    pub centroids: Vec<f32>,
    /// Samples per centroid at the final assignment.
    pub counts: Vec<f32>,
    /// Final total bit-cost (inertia).
    pub inertia: f32,
}

#[cfg(feature = "pjrt")]
struct Inner {
    client: xla::PjRtClient,
    /// Compiled executables by artifact stem (e.g. "kmeans_k64").
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The artifact runtime: PJRT client + compiled executables.
#[cfg(feature = "pjrt")]
pub struct ArtifactRuntime {
    inner: Mutex<Inner>,
    dir: PathBuf,
}

/// Stub artifact runtime compiled without the `pjrt` feature:
/// construction always fails, so every caller takes its native-analysis
/// fallback path.
#[cfg(not(feature = "pjrt"))]
pub struct ArtifactRuntime {
    dir: PathBuf,
}

// SAFETY: the xla wrapper types hold `Rc`-counted opaque pointers into the
// PJRT C API (which is itself thread-compatible). Every touch of the
// client, the executables, and their transient buffers happens inside
// `self.inner`'s Mutex, so the non-atomic Rc counts are never mutated
// concurrently, and no Rc clone escapes the guarded scope (only plain
// `Literal` host data is returned).
#[cfg(feature = "pjrt")]
unsafe impl Send for ArtifactRuntime {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for ArtifactRuntime {}

#[cfg(not(feature = "pjrt"))]
impl ArtifactRuntime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir;
        Err(Error::Runtime(
            "PJRT unavailable: built without the `pjrt` feature (native analysis is used instead)"
                .into(),
        ))
    }

    /// Default artifact directory: `$GBDI_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GBDI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether the artifact file for a given stem exists.
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.dir.join(format!("{stem}.hlo.txt")).exists()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (no pjrt feature)".into()
    }

    /// Unreachable in practice ([`Self::new`] always errs), but keeps the
    /// API surface identical for callers compiled either way.
    pub fn kmeans(&self, _samples: &[f32], _init: &[f32]) -> Result<KmeansFit> {
        Err(Error::Runtime("PJRT unavailable: built without the `pjrt` feature".into()))
    }

    /// See [`Self::kmeans`].
    pub fn size_estimate(&self, _samples: &[f32], _bases: &[f32], _widths: &[f32]) -> Result<f32> {
        Err(Error::Runtime("PJRT unavailable: built without the `pjrt` feature".into()))
    }
}

#[cfg(feature = "pjrt")]
impl ArtifactRuntime {
    /// Create a runtime over the artifact directory. Fails if the PJRT
    /// client cannot start; individual artifacts are loaded lazily so a
    /// missing file only fails the call that needs it.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(ArtifactRuntime {
            inner: Mutex::new(Inner { client, executables: HashMap::new() }),
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Default artifact directory: `$GBDI_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GBDI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether the artifact file for a given stem exists.
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.dir.join(format!("{stem}.hlo.txt")).exists()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().client.platform_name()
    }

    fn execute(&self, stem: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.executables.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "artifact {} not found (run `make artifacts`)",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner.client.compile(&comp).map_err(wrap)?;
            inner.executables.insert(stem.to_string(), exe);
        }
        let exe = inner.executables.get(stem).expect("compiled above");
        let result = exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        // lowered with return_tuple=True: unpack the tuple
        out.to_tuple().map_err(wrap)
    }

    /// Run the k-means artifact for `k` (must be in [`KMEANS_KS`]).
    ///
    /// `samples` are word values as f32 (exactly [`N_SAMPLES`] of them —
    /// pad by repeating when the caller has fewer); `init` has `k`
    /// centroids (the coordinator seeds them from its sample).
    pub fn kmeans(&self, samples: &[f32], init: &[f32]) -> Result<KmeansFit> {
        let k = init.len();
        if !KMEANS_KS.contains(&k) {
            return Err(Error::Runtime(format!(
                "no kmeans artifact for K={k} (available: {KMEANS_KS:?})"
            )));
        }
        if samples.len() != N_SAMPLES {
            return Err(Error::Runtime(format!(
                "kmeans artifact expects {N_SAMPLES} samples, got {}",
                samples.len()
            )));
        }
        let x = xla::Literal::vec1(samples);
        let c = xla::Literal::vec1(init);
        let outs = self.execute(&format!("kmeans_k{k}"), &[x, c])?;
        if outs.len() != 3 {
            return Err(Error::Runtime(format!("kmeans returned {} outputs", outs.len())));
        }
        let centroids = outs[0].to_vec::<f32>().map_err(wrap)?;
        let counts = outs[1].to_vec::<f32>().map_err(wrap)?;
        let inertia = outs[2].to_vec::<f32>().map_err(wrap)?[0];
        Ok(KmeansFit { centroids, counts, inertia })
    }

    /// Run the size-estimation artifact (K = 64): total + per-value bits
    /// of encoding `samples` under a (bases, widths) table.
    pub fn size_estimate(&self, samples: &[f32], bases: &[f32], widths: &[f32]) -> Result<f32> {
        if bases.len() != 64 || widths.len() != 64 {
            return Err(Error::Runtime("sizeest artifact expects K=64".into()));
        }
        if samples.len() != N_SAMPLES {
            return Err(Error::Runtime(format!(
                "sizeest artifact expects {N_SAMPLES} samples, got {}",
                samples.len()
            )));
        }
        let outs = self.execute(
            "sizeest_k64",
            &[
                xla::Literal::vec1(samples),
                xla::Literal::vec1(bases),
                xla::Literal::vec1(widths),
            ],
        )?;
        Ok(outs[0].to_vec::<f32>().map_err(wrap)?[0])
    }
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// Pad or stride-reduce word samples to exactly [`N_SAMPLES`] f32 values —
/// the shim between arbitrary sample counts and the fixed artifact shape.
pub fn shape_samples(words: &[u64]) -> Vec<f32> {
    if words.is_empty() {
        return vec![0.0; N_SAMPLES];
    }
    let mut out = Vec::with_capacity(N_SAMPLES);
    if words.len() >= N_SAMPLES {
        let stride = words.len() as f64 / N_SAMPLES as f64;
        for i in 0..N_SAMPLES {
            out.push(words[(i as f64 * stride) as usize] as f32);
        }
    } else {
        for i in 0..N_SAMPLES {
            out.push(words[i % words.len()] as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_samples_pads_and_strides() {
        assert_eq!(shape_samples(&[]).len(), N_SAMPLES);
        let few = shape_samples(&[1, 2, 3]);
        assert_eq!(few.len(), N_SAMPLES);
        assert_eq!(&few[..4], &[1.0, 2.0, 3.0, 1.0]);
        let many: Vec<u64> = (0..100_000).collect();
        let s = shape_samples(&many);
        assert_eq!(s.len(), N_SAMPLES);
        assert_eq!(s[0], 0.0);
        assert!(s[N_SAMPLES - 1] > 90_000.0);
    }

    // Artifact-dependent tests live in rust/tests/runtime_artifacts.rs and
    // skip gracefully when artifacts/ has not been built.
}
