//! The single framed container every block codec shares — replacing the
//! three divergent formats the seed carried (GBDI's ad-hoc
//! `CompressedImage`, `GbdiWholeImage`'s u16-truncating byte container,
//! and the memory simulator's private page layout).
//!
//! A [`Container`] records:
//!
//! * the codec id + its config blob (enough to rebuild a decoder),
//! * the optional global table (GBDI's shared dictionary),
//! * per-block bit lengths (exact, for the simulator's sector layout and
//!   for framing verification) — serialized as **u32 varints**, so blocks
//!   larger than 64 B can exceed 65535 bits without truncation,
//! * chunking metadata: every `chunk_blocks`-th block starts byte-aligned
//!   (0 = unchunked serial stream), which is what makes *parallel*
//!   compression produce a stream any decoder can walk,
//! * the packed payload.
//!
//! Compression is codec-agnostic: [`compress`] walks blocks serially;
//! [`compress_parallel`] splits the image into chunks of
//! [`CHUNK_BLOCKS`] blocks, compresses each on its own thread into a
//! byte-aligned sub-stream, and concatenates — for **any**
//! [`BlockCodec`], not just GBDI. Decompression realigns at chunk
//! boundaries, so parallel output decodes bit-exactly like the serial
//! stream (ratio identical up to <1 byte padding per chunk). Both
//! directions sit on the word-at-a-time bit substrate
//! ([`crate::util::bits`], DESIGN.md §9): every codec's RAW paths are
//! bulk byte copies and per-field I/O moves up to 64 bits per shift,
//! so the container layer adds framing, not bit-loop overhead.

use crate::codec::{build_codec, BlockCodec, CodecId};
use crate::gbdi::table::GlobalBaseTable;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// Blocks per parallel-compression chunk (256 KiB of logical data at the
/// default 64-byte block).
pub const CHUNK_BLOCKS: usize = 4096;

const MAGIC: &[u8; 4] = b"GBC1";
const FLAG_TABLE: u8 = 1;

/// A compressed image: codec identity + framing + payload. This is the
/// one in-memory and on-disk compressed form for every block codec.
///
/// ```
/// use gbdi::{CodecKind, Container, GbdiConfig};
///
/// // 4 KiB of clustered little-endian words — GBDI's favorite diet
/// let image: Vec<u8> = (0u32..1024).flat_map(|i| (9000 + (i % 40)).to_le_bytes()).collect();
/// let codec = CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default());
/// let container = gbdi::container::compress(codec.as_ref(), &image);
/// assert!(container.ratio() > 1.0);
///
/// // the wire format roundtrips bit-exactly...
/// let bytes = container.to_bytes();
/// let parsed = Container::from_bytes(&bytes).unwrap();
/// assert_eq!(parsed.decompress().unwrap(), image);
///
/// // ...and upgrades to a random-access frame without copying the payload
/// let frame = parsed.into_frame().unwrap();
/// let mut line = [0u8; 64];
/// frame.read_block(0, &mut line).unwrap();
/// assert_eq!(&line[..], &image[..64]);
/// ```
#[derive(Debug, Clone)]
pub struct Container {
    /// Which codec encoded the payload.
    pub codec_id: CodecId,
    /// Codec config blob (see [`BlockCodec::config_bytes`]).
    pub config: Vec<u8>,
    /// The shared dictionary the payload references (GBDI only).
    pub table: Option<GlobalBaseTable>,
    /// Original image length in bytes.
    pub original_len: usize,
    /// Block granularity the payload was encoded at.
    pub block_bytes: usize,
    /// Parallel-compression chunking: every `chunk_blocks`-th block starts
    /// byte-aligned (0 = unchunked serial stream).
    pub chunk_blocks: usize,
    /// Per-block bit lengths; one entry per block.
    pub block_bits: Vec<u32>,
    /// The packed payload.
    pub payload: Vec<u8>,
}

impl Container {
    /// Compressed payload size in bytes (excluding table + framing).
    pub fn payload_len(&self) -> usize {
        self.payload.len()
    }

    /// Serialized framing overhead in bytes: header, config blob, table,
    /// and the varint block-length index — everything except the payload.
    pub fn header_len(&self) -> usize {
        4 + 1 + 1 + 2
            + self.config.len()
            + self.table.as_ref().map_or(0, |t| t.serialized_len())
            + 8
            + 4
            + 4
            + 4
            + self.block_bits.iter().map(|&b| varint_len(b)).sum::<usize>()
    }

    /// Total compressed size in bytes including the table and framing —
    /// the honest numerator for compression ratios.
    pub fn total_len(&self) -> usize {
        self.header_len() + self.payload.len()
    }

    /// Compression ratio original/compressed (the paper's metric).
    pub fn ratio(&self) -> f64 {
        self.original_len as f64 / self.total_len() as f64
    }

    /// Decompress self-contained: rebuilds the codec from the recorded
    /// id, config, and table. The result is byte-identical to the
    /// original image.
    pub fn decompress(&self) -> Result<Vec<u8>> {
        decompress(self)
    }

    /// Turn this container into a random-access [`crate::frame::Frame`]:
    /// the payload is moved (not copied), the codec is rebuilt from the
    /// recorded identity, and the block-offset index is materialized —
    /// after which single blocks read and write in O(1) without whole-
    /// image decodes.
    pub fn into_frame(self) -> Result<crate::frame::Frame> {
        crate::frame::Frame::from_container(self)
    }

    /// Serialize to the on-disk `.gbc` format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_len());
        out.extend_from_slice(MAGIC);
        out.push(self.codec_id as u8);
        out.push(if self.table.is_some() { FLAG_TABLE } else { 0 });
        debug_assert!(self.config.len() <= u16::MAX as usize);
        out.extend_from_slice(&(self.config.len() as u16).to_le_bytes());
        out.extend_from_slice(&self.config);
        if let Some(t) = &self.table {
            out.extend_from_slice(&t.serialize());
        }
        out.extend_from_slice(&(self.original_len as u64).to_le_bytes());
        out.extend_from_slice(&(self.block_bytes as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_blocks as u32).to_le_bytes());
        out.extend_from_slice(&(self.block_bits.len() as u32).to_le_bytes());
        for &b in &self.block_bits {
            put_varint(&mut out, b);
        }
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse the on-disk format (inverse of [`Self::to_bytes`]).
    pub fn from_bytes(data: &[u8]) -> Result<Container> {
        let corrupt = |m: &str| Error::Corrupt(format!("container: {m}"));
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > data.len() {
                return Err(Error::Corrupt("container: truncated header".into()));
            }
            let s = &data[*off..*off + n];
            *off += n;
            Ok(s)
        };
        if take(&mut off, 4)? != MAGIC {
            return Err(corrupt("bad magic"));
        }
        let id = take(&mut off, 1)?[0];
        let codec_id = CodecId::from_u8(id)
            .ok_or_else(|| corrupt(&format!("unknown codec id {id}")))?;
        let flags = take(&mut off, 1)?[0];
        let config_len = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        let config = take(&mut off, config_len)?.to_vec();
        let table = if flags & FLAG_TABLE != 0 {
            let (t, used) = GlobalBaseTable::deserialize(&data[off..])?;
            off += used;
            Some(t)
        } else {
            None
        };
        let original_len = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap()) as usize;
        let block_bytes = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let chunk_blocks = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let n_blocks = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        if block_bytes == 0 {
            return Err(corrupt("zero block size"));
        }
        // n_blocks must match the image geometry, and — since both counts
        // come from the same untrusted header — be plausible against the
        // bytes actually present (each varint is >= 1 byte), before we
        // trust it as an allocation size.
        let expect = original_len.div_ceil(block_bytes);
        if n_blocks != expect {
            return Err(corrupt(&format!(
                "block count {n_blocks} does not match image ({expect} expected)"
            )));
        }
        if n_blocks > data.len() - off {
            return Err(corrupt(&format!(
                "block count {n_blocks} exceeds remaining {} bytes",
                data.len() - off
            )));
        }
        let mut block_bits = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            block_bits.push(read_varint(data, &mut off)?);
        }
        Ok(Container {
            codec_id,
            config,
            table,
            original_len,
            block_bytes,
            chunk_blocks,
            block_bits,
            payload: data[off..].to_vec(),
        })
    }

    /// Read only the `original_len` field from a serialized container —
    /// O(header + table), without materializing the block-length index or
    /// copying the payload (a full [`Self::from_bytes`] would).
    pub fn original_len_of(data: &[u8]) -> Result<usize> {
        let corrupt = |m: &str| Error::Corrupt(format!("container: {m}"));
        if data.len() < 8 || &data[0..4] != MAGIC {
            return Err(corrupt("bad magic"));
        }
        CodecId::from_u8(data[4]).ok_or_else(|| corrupt("unknown codec id"))?;
        let flags = data[5];
        let config_len = u16::from_le_bytes(data[6..8].try_into().unwrap()) as usize;
        let mut off = 8 + config_len;
        if flags & FLAG_TABLE != 0 {
            if off > data.len() {
                return Err(corrupt("truncated header"));
            }
            let (_, used) = GlobalBaseTable::deserialize(&data[off..])?;
            off += used;
        }
        if off + 8 > data.len() {
            return Err(corrupt("truncated header"));
        }
        Ok(u64::from_le_bytes(data[off..off + 8].try_into().unwrap()) as usize)
    }
}

/// LEB128-encode a u32 (1–5 bytes; 1 byte for values < 128) — the
/// per-block bit-length encoding of the container's framing index.
pub fn put_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Encoded length of [`put_varint`]`(v)` in bytes.
pub fn varint_len(v: u32) -> usize {
    match v {
        0..=0x7F => 1,
        0x80..=0x3FFF => 2,
        0x4000..=0x1F_FFFF => 3,
        0x20_0000..=0xFFF_FFFF => 4,
        _ => 5,
    }
}

/// Decode one [`put_varint`] value at `data[*off..]`, advancing `off`.
/// Strict: a fifth byte may only carry the top four bits of a `u32` —
/// continuation past that, or payload bits above bit 31, is corruption
/// (silently truncating them would mis-frame every later block).
pub fn read_varint(data: &[u8], off: &mut usize) -> Result<u32> {
    let mut v: u32 = 0;
    for shift in 0..5u32 {
        let b = *data
            .get(*off)
            .ok_or_else(|| Error::Corrupt("container: truncated varint".into()))?;
        *off += 1;
        if shift == 4 && b & 0xF0 != 0 {
            return Err(Error::Corrupt("container: varint overflows u32".into()));
        }
        v |= ((b & 0x7F) as u32) << (7 * shift);
        if b & 0x80 == 0 {
            return Ok(v);
        }
    }
    Err(Error::Corrupt("container: varint too long".into()))
}

/// Compress every block of `image` serially into a packed payload plus
/// per-block bit lengths — the shared inner loop of [`compress`], the
/// parallel chunk workers, and the coordinator's page path.
pub fn compress_blocks(codec: &dyn BlockCodec, image: &[u8]) -> (Vec<u8>, Vec<u32>) {
    let bb = codec.block_bytes();
    let mut w = BitWriter::with_capacity(image.len() / 2 + 64);
    let mut block_bits = Vec::with_capacity(image.len() / bb + 1);
    for block in image.chunks(bb) {
        block_bits.push(codec.compress_block(block, &mut w));
    }
    (w.finish(), block_bits)
}

/// Assemble a [`Container`] from compressed parts, stamping the codec's
/// identity, config, and table.
pub fn assemble(
    codec: &dyn BlockCodec,
    original_len: usize,
    chunk_blocks: usize,
    payload: Vec<u8>,
    block_bits: Vec<u32>,
) -> Container {
    Container {
        codec_id: codec.codec_id(),
        config: codec.config_bytes(),
        table: codec.global_table().cloned(),
        original_len,
        block_bytes: codec.block_bytes(),
        chunk_blocks,
        block_bits,
        payload,
    }
}

/// Serial whole-image compression with any block codec.
pub fn compress(codec: &dyn BlockCodec, image: &[u8]) -> Container {
    let (payload, block_bits) = compress_blocks(codec, image);
    assemble(codec, image.len(), 0, payload, block_bits)
}

/// Chunked-parallel compression plumbing, generic over the per-chunk
/// worker so codec-specific statistics can ride along (GBDI's
/// `EncodeStats`). Returns `(payload, block_bits, per-chunk extras,
/// chunk_blocks)`; `chunk_blocks` is 0 when the image was small enough
/// (or `threads <= 1`) to compress serially in one piece.
pub fn compress_chunked<S, F>(
    image: &[u8],
    block_bytes: usize,
    threads: usize,
    per_chunk: F,
) -> (Vec<u8>, Vec<u32>, Vec<S>, usize)
where
    S: Send,
    F: Fn(&[u8]) -> (Vec<u8>, Vec<u32>, S) + Sync,
{
    let chunk_bytes = CHUNK_BLOCKS * block_bytes;
    if threads <= 1 || image.len() <= chunk_bytes {
        let (payload, bits, extra) = per_chunk(image);
        return (payload, bits, vec![extra], 0);
    }
    let chunks: Vec<&[u8]> = image.chunks(chunk_bytes).collect();
    let results = crate::util::pool::parallel_map_chunks(&chunks, threads, |_, piece| {
        piece.iter().map(|chunk| per_chunk(chunk)).collect::<Vec<_>>()
    });
    let mut payload = Vec::with_capacity(image.len() / 2);
    let mut block_bits = Vec::with_capacity(image.len() / block_bytes + 1);
    let mut extras = Vec::with_capacity(results.len());
    for (bytes, bits, extra) in results {
        payload.extend_from_slice(&bytes);
        block_bits.extend_from_slice(&bits);
        extras.push(extra);
    }
    (payload, block_bits, extras, CHUNK_BLOCKS)
}

/// Parallel whole-image compression with any block codec: chunks of
/// [`CHUNK_BLOCKS`] blocks are compressed on separate threads into
/// byte-aligned sub-streams and concatenated. Decompression output is
/// bit-identical to the serial path's.
pub fn compress_parallel(codec: &dyn BlockCodec, image: &[u8], threads: usize) -> Container {
    let (payload, block_bits, _, chunk_blocks) =
        compress_chunked(image, codec.block_bytes(), threads, |chunk| {
            let (p, b) = compress_blocks(codec, chunk);
            (p, b, ())
        });
    assemble(codec, image.len(), chunk_blocks, payload, block_bits)
}

/// Decode a payload back into `original_len` bytes with a caller-provided
/// codec, verifying per-block framing and chunk alignment. The low-level
/// engine under [`decompress`] and the coordinator's page store.
pub fn decompress_parts(
    codec: &dyn BlockCodec,
    payload: &[u8],
    block_bits: &[u32],
    original_len: usize,
    chunk_blocks: usize,
) -> Result<Vec<u8>> {
    let bb = codec.block_bytes();
    if bb == 0 {
        return Err(Error::Config("block size must be positive".into()));
    }
    let n_blocks = original_len.div_ceil(bb);
    if block_bits.len() != n_blocks {
        return Err(Error::Corrupt(format!(
            "block count mismatch: framing says {}, image needs {n_blocks}",
            block_bits.len()
        )));
    }
    let mut out = vec![0u8; original_len];
    let mut r = BitReader::new(payload);
    for (i, chunk) in out.chunks_mut(bb).enumerate() {
        // parallel streams: every chunk_blocks-th block starts byte-aligned
        if chunk_blocks > 0 && i > 0 && i % chunk_blocks == 0 {
            r.skip_to_byte()
                .map_err(|_| Error::Corrupt(format!("chunk realign before block {i}")))?;
        }
        let before = r.bit_pos();
        codec.decompress_block(&mut r, chunk)?;
        let used = (r.bit_pos() - before) as u32;
        if used != block_bits[i] {
            return Err(Error::Corrupt(format!(
                "block {i}: consumed {used} bits, framing recorded {}",
                block_bits[i]
            )));
        }
    }
    Ok(out)
}

/// Check that a caller-built codec matches a container's recorded
/// identity (wire id + block size) — the one definition of "this
/// decoder may decode that container", shared by [`decompress_with`]
/// and [`crate::frame::Frame::with_codec`].
pub fn check_codec_identity(c: &Container, codec: &dyn BlockCodec) -> Result<()> {
    if codec.codec_id() != c.codec_id {
        return Err(Error::Corrupt(format!(
            "codec mismatch: container is {}, decoder is {}",
            c.codec_id.name(),
            codec.name()
        )));
    }
    if codec.block_bytes() != c.block_bytes {
        return Err(Error::Corrupt(format!(
            "block size mismatch: container {}, decoder {}",
            c.block_bytes,
            codec.block_bytes()
        )));
    }
    Ok(())
}

/// Decompress with a caller-provided codec (must match the container's
/// codec id and block size — the fast path when the codec is already
/// built, e.g. the coordinator's codec ring).
pub fn decompress_with(c: &Container, codec: &dyn BlockCodec) -> Result<Vec<u8>> {
    check_codec_identity(c, codec)?;
    decompress_parts(codec, &c.payload, &c.block_bits, c.original_len, c.chunk_blocks)
}

/// Self-contained decompression: rebuild the codec from the container's
/// recorded identity, then decode.
pub fn decompress(c: &Container) -> Result<Vec<u8>> {
    let codec = build_codec(c.codec_id, &c.config, c.table.clone())?;
    decompress_with(c, codec.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecKind;
    use crate::gbdi::GbdiConfig;
    use crate::util::prng::Rng;

    fn clustered_image(len_words: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..len_words)
            .flat_map(|_| {
                let v: u32 = match rng.below(4) {
                    0 => 7000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                    1 => (1u32 << 22).wrapping_add(rng.range_i64(-500, 500) as u32),
                    2 => 0,
                    _ => rng.next_u32(),
                };
                v.to_le_bytes()
            })
            .collect()
    }

    #[test]
    fn varints_roundtrip() {
        let mut out = Vec::new();
        let vals = [0u32, 1, 127, 128, 16383, 16384, 65535, 65536, 131074, u32::MAX];
        for &v in &vals {
            out.clear();
            put_varint(&mut out, v);
            assert_eq!(out.len(), varint_len(v), "len for {v}");
            let mut off = 0;
            assert_eq!(read_varint(&out, &mut off).unwrap(), v);
            assert_eq!(off, out.len());
        }
        let mut off = 0;
        assert!(read_varint(&[0x80, 0x80], &mut off).is_err()); // truncated
        // strictness: a fifth byte carrying bits past u32 (or continuing)
        // is corruption, not silent truncation
        let mut off = 0;
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x7F], &mut off).is_err());
        let mut off = 0;
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x10], &mut off).is_err());
        let mut off = 0;
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x8F], &mut off).is_err());
        let mut off = 0;
        assert_eq!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x0F], &mut off).unwrap(), u32::MAX);
    }

    #[test]
    fn every_kind_roundtrips_serial_parallel_and_bytes() {
        // 384 KiB: past one 256 KiB chunk, so the parallel path really
        // chunks instead of falling back to serial
        let image = clustered_image(96 * 1024, 3);
        let cfg = GbdiConfig::default();
        for &kind in CodecKind::all() {
            let codec = kind.build_for_image(&image, &cfg);
            let serial = compress(codec.as_ref(), &image);
            assert_eq!(serial.decompress().unwrap(), image, "{} serial", kind.name());
            let par = compress_parallel(codec.as_ref(), &image, 4);
            assert_eq!(par.chunk_blocks, CHUNK_BLOCKS, "{} must actually chunk", kind.name());
            assert_eq!(par.block_bits, serial.block_bits, "{} framing", kind.name());
            assert_eq!(par.decompress().unwrap(), image, "{} parallel", kind.name());
            // serialized form survives and still self-decodes
            let bytes = serial.to_bytes();
            assert_eq!(bytes.len(), serial.total_len(), "{} total_len", kind.name());
            let back = Container::from_bytes(&bytes).unwrap();
            assert_eq!(back.decompress().unwrap(), image, "{} bytes", kind.name());
        }
    }

    #[test]
    fn empty_and_ragged_images_roundtrip() {
        let cfg = GbdiConfig::default();
        for image in [vec![], vec![9u8; 3], vec![7u8; 64 + 5]] {
            for &kind in CodecKind::all() {
                let codec = kind.build_for_image(&image, &cfg);
                let c = compress(codec.as_ref(), &image);
                assert_eq!(c.decompress().unwrap(), image, "{}", kind.name());
                let back = Container::from_bytes(&c.to_bytes()).unwrap();
                assert_eq!(back.decompress().unwrap(), image);
            }
        }
    }

    #[test]
    fn oversized_blocks_exceed_u16_bits_and_survive() {
        // Regression for the old GbdiWholeImage container, which wrote
        // per-block bit lengths as u16: a 16 KiB raw block is 131074 bits,
        // far past 65535, and used to truncate silently.
        let mut rng = Rng::new(11);
        let mut image = vec![0u8; 1 << 16];
        rng.fill_bytes(&mut image);
        let cfg = GbdiConfig { block_bytes: 16384, ..Default::default() };
        let codec = CodecKind::Gbdi.build_for_image(&image, &cfg);
        let c = compress(codec.as_ref(), &image);
        let max_bits = *c.block_bits.iter().max().unwrap();
        assert!(max_bits > u16::MAX as u32, "block bits {max_bits} should overflow u16");
        let back = Container::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back.block_bits, c.block_bits);
        assert_eq!(back.decompress().unwrap(), image);
    }

    #[test]
    fn corrupt_containers_rejected_not_panicking() {
        let image = clustered_image(4096, 5);
        let cfg = GbdiConfig::default();
        let codec = CodecKind::Bdi.build_for_image(&image, &cfg);
        let bytes = compress(codec.as_ref(), &image).to_bytes();
        assert!(Container::from_bytes(&bytes[..3]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::from_bytes(&bad).is_err());
        let mut bad = bytes.clone();
        bad[4] = 200; // unknown codec id
        assert!(Container::from_bytes(&bad).is_err());
        // truncating the payload must surface as Err from decompress
        let c = Container::from_bytes(&bytes).unwrap();
        let mut bad = c.clone();
        bad.payload.truncate(bad.payload.len() / 2);
        assert!(bad.decompress().is_err());
        // wrong chunking never panics
        let mut bad = c;
        bad.chunk_blocks = 3;
        let _ = bad.decompress();
    }

    #[test]
    fn huge_declared_block_count_rejected_without_allocating() {
        // a ~60-byte file claiming a multi-GB image must fail cleanly
        // instead of aborting on a giant Vec::with_capacity
        let image = vec![0u8; 4096];
        let cfg = GbdiConfig::default();
        let codec = CodecKind::Bdi.build_for_image(&image, &cfg);
        let mut bytes = compress(codec.as_ref(), &image).to_bytes();
        // header layout: magic(4) id(1) flags(1) cfg_len(2) cfg(4) —
        // original_len u64 at 12, block_bytes u32 at 20, chunk_blocks u32
        // at 24, n_blocks u32 at 28
        let huge: u64 = 1 << 37;
        bytes[12..20].copy_from_slice(&huge.to_le_bytes());
        bytes[28..32].copy_from_slice(&((huge.div_ceil(64)) as u32).to_le_bytes());
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn original_len_of_reads_header_only() {
        let image = clustered_image(4096, 9);
        let cfg = GbdiConfig::default();
        for &kind in CodecKind::all() {
            let codec = kind.build_for_image(&image, &cfg);
            let bytes = compress(codec.as_ref(), &image).to_bytes();
            assert_eq!(Container::original_len_of(&bytes).unwrap(), image.len());
        }
        assert!(Container::original_len_of(&[1, 2, 3]).is_err());
    }

    #[test]
    fn decompress_with_checks_identity() {
        let image = clustered_image(2048, 7);
        let cfg = GbdiConfig::default();
        let bdi = CodecKind::Bdi.build_for_image(&image, &cfg);
        let fpc = CodecKind::Fpc.build_for_image(&image, &cfg);
        let c = compress(bdi.as_ref(), &image);
        assert!(decompress_with(&c, fpc.as_ref()).is_err());
        let wide = crate::baselines::bdi::Bdi { block_bytes: 128 };
        assert!(decompress_with(&c, &wide).is_err());
        assert_eq!(decompress_with(&c, bdi.as_ref()).unwrap(), image);
    }
}
