//! The global base table: GBDI's shared dictionary of (base value,
//! max-delta width class) pairs, produced by background analysis and
//! consulted by both the encoder and the decoder.
//!
//! The width class of a base *is* the wire width of every delta encoded
//! against it (GBDI pairs each global base with a maximum delta, so the
//! decompressor knows each field's width from the base pointer alone —
//! no per-value width metadata).

use super::GbdiConfig;
use crate::cluster::{wrapping_delta, Selection};
use crate::util::bits::signed_width;
use crate::value::WordSize;
use crate::{Error, Result};

/// One global base: a word value paired with its maximum-delta class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BaseEntry {
    /// The base value.
    pub base: u64,
    /// Width class (bits) of the delta field for this base. A word with
    /// `signed_width(v - base) <= width` can use it; the delta is stored
    /// in exactly `width` bits (0 = exact-match base, no delta field).
    pub width: u32,
}

impl BaseEntry {
    /// Whether signed delta `d` is encodable against this base.
    #[inline]
    pub fn fits(&self, d: i64) -> bool {
        signed_width(d) <= self.width
    }
}

/// Bucket granularity for the W32 fast-path index: the 32-bit value space
/// is split into 4096 buckets of 2^20 values; each bucket lists the table
/// entries whose coverage interval intersects it, sorted by (width, base)
/// so the first fitting candidate has minimal wire cost.
const BUCKET_SHIFT: u32 = 20;
const NUM_BUCKETS: usize = 1 << (32 - BUCKET_SHIFT);

/// The global base table. Bases are kept **sorted by value**; a
/// bucket index over the 32-bit value space accelerates the encoder's
/// per-word base search (the compression hot path). Tables carry a
/// version id so the coordinator can swap them without ambiguity.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalBaseTable {
    entries: Vec<BaseEntry>,
    /// Largest width class present (scan radius for the encoder search).
    max_width: u32,
    /// W32 fast-path bucket index (SoA CSR; empty for W64 tables).
    /// Deterministic from `entries`, rebuilt on deserialize. Indices are
    /// u32 so oversized tables (> u16::MAX entries) keep the fast path
    /// instead of silently falling back to the linear scan.
    buckets: BucketIndex,
    /// Monotonic version assigned by the coordinator (0 = ad-hoc).
    pub version: u64,
    /// Word granularity the table was built for.
    pub word_size: WordSize,
}

/// The W32 bucket index in structure-of-arrays form, shaped for the
/// SIMD first-fit kernel ([`crate::simd::Kernels::first_fit`]):
/// `off[b]..off[b+1]` slices the candidate arrays for bucket `b`,
/// sorted by (width, base) so the first fit is a minimal-width fit.
/// Per candidate, `lo`/`span` hold its coverage interval as a wrapped
/// unsigned range — `v` fits candidate `i` iff
/// `(v - lo[i]) mod 2^32 <= span[i]`, the exact lane test the kernels
/// run — `cands` maps back to the entry index (wire-visible: it becomes
/// the base pointer), and `width` mirrors the entry widths for the
/// hinted search's strictly-narrower prefix cut.
#[derive(Debug, Clone, PartialEq, Default)]
struct BucketIndex {
    off: Vec<u32>,
    cands: Vec<u32>,
    lo: Vec<u32>,
    span: Vec<u32>,
    width: Vec<u32>,
}

fn build_buckets(entries: &[BaseEntry], word_size: WordSize) -> BucketIndex {
    if word_size != WordSize::W32 {
        return BucketIndex::default();
    }
    debug_assert!(entries.len() <= u32::MAX as usize);
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); NUM_BUCKETS];
    for (i, e) in entries.iter().enumerate() {
        // coverage: v in [base - 2^(w-1), base + 2^(w-1) - 1] (wrapping)
        let span: u32 = if e.width == 0 { 0 } else { 1u32 << (e.width - 1) };
        let lo = (e.base as u32).wrapping_sub(span);
        let hi = (e.base as u32).wrapping_add(span.saturating_sub(1));
        let b0 = lo >> BUCKET_SHIFT;
        let b1 = hi >> BUCKET_SHIFT;
        let count = if b1 >= b0 {
            b1 - b0 + 1
        } else {
            NUM_BUCKETS as u32 - b0 + b1 + 1 // wrapped interval
        };
        for j in 0..count {
            buckets[((b0 + j) as usize) & (NUM_BUCKETS - 1)].push(i as u32);
        }
    }
    // flatten to SoA CSR, candidates width-sorted for early exit
    let total = buckets.iter().map(|b| b.len()).sum();
    let mut idx = BucketIndex {
        off: Vec::with_capacity(NUM_BUCKETS + 1),
        cands: Vec::with_capacity(total),
        lo: Vec::with_capacity(total),
        span: Vec::with_capacity(total),
        width: Vec::with_capacity(total),
    };
    idx.off.push(0u32);
    for b in &mut buckets {
        b.sort_by_key(|&i| (entries[i as usize].width, entries[i as usize].base));
        for &i in b.iter() {
            let e = entries[i as usize];
            // the same coverage interval as above, in the wrapped-range
            // form the fit test consumes: w = 0 covers exactly the base,
            // w >= 1 covers [base - 2^(w-1), base + 2^(w-1) - 1]
            let (lo, span) = if e.width == 0 {
                (e.base as u32, 0u32)
            } else {
                let half = 1u32 << (e.width - 1);
                ((e.base as u32).wrapping_sub(half), half.wrapping_mul(2).wrapping_sub(1))
            };
            idx.cands.push(i);
            idx.lo.push(lo);
            idx.span.push(span);
            idx.width.push(e.width);
        }
        idx.off.push(idx.cands.len() as u32);
    }
    idx
}

impl GlobalBaseTable {
    /// Build a table from (base, width) pairs. Bases are sorted and
    /// deduplicated (keeping the widest class per duplicate base). A zero
    /// base with an 8-bit class is pinned if absent — HPCA'22 reserves
    /// base 0 so small immediates always encode.
    pub fn new(mut pairs: Vec<(u64, u32)>, word_size: WordSize, version: u64) -> Self {
        if !pairs.iter().any(|&(b, _)| b == 0) {
            pairs.push((0, 8));
        }
        pairs.sort_unstable();
        // dedup keeping max width
        let mut entries: Vec<BaseEntry> = Vec::with_capacity(pairs.len());
        for (base, width) in pairs {
            debug_assert!(width <= word_size.bits());
            match entries.last_mut() {
                Some(last) if last.base == base => last.width = last.width.max(width),
                _ => entries.push(BaseEntry { base, width }),
            }
        }
        let max_width = entries.iter().map(|e| e.width).max().unwrap_or(0);
        let buckets = build_buckets(&entries, word_size);
        GlobalBaseTable { entries, max_width, buckets, version, word_size }
    }

    /// Build a table from a selector's [`Selection`] — the one seam every
    /// analysis path (native selectors, PJRT artifact, CLI, benches) goes
    /// through, so the width-fitting lives here and nowhere else.
    pub fn from_selection(
        samples: &[u64],
        selection: &Selection,
        cfg: &GbdiConfig,
        version: u64,
    ) -> Self {
        Self::fit_from_centroids(samples, &selection.centroids, cfg, version)
    }

    /// Fit per-base width classes around given centroids and build the
    /// table (the paper's "establishing maximum deltas" step):
    ///
    /// 1. assign every sample to its nearest centroid (min |wrapping
    ///    delta|);
    /// 2. per centroid, take the `delta_quantile` of required delta
    ///    widths;
    /// 3. snap that up to the smallest configured width class (values
    ///    beyond it become outliers at encode time).
    pub fn fit_from_centroids(
        samples: &[u64],
        centroids: &[u64],
        cfg: &GbdiConfig,
        version: u64,
    ) -> Self {
        assert!(!centroids.is_empty());
        let mut widths_needed: Vec<Vec<u32>> = vec![Vec::new(); centroids.len()];
        for &v in samples {
            let mut best = 0usize;
            let mut best_abs = u64::MAX;
            for (j, &c) in centroids.iter().enumerate() {
                let abs = wrapping_delta(v, c, cfg.word_size).unsigned_abs();
                if abs < best_abs {
                    best_abs = abs;
                    best = j;
                }
            }
            let d = wrapping_delta(v, centroids[best], cfg.word_size);
            widths_needed[best].push(signed_width(d));
        }
        let max_class = *cfg.width_classes.last().unwrap();
        let pairs: Vec<(u64, u32)> = centroids
            .iter()
            .zip(widths_needed.iter_mut())
            .map(|(&c, widths)| {
                if widths.is_empty() {
                    return (c, 0);
                }
                widths.sort_unstable();
                let q_idx = ((cfg.delta_quantile * (widths.len() - 1) as f64).round() as usize)
                    .min(widths.len() - 1);
                let need = widths[q_idx];
                let class = cfg
                    .width_classes
                    .iter()
                    .copied()
                    .find(|&w| w >= need)
                    .unwrap_or(max_class);
                (c, class)
            })
            .collect();
        GlobalBaseTable::new(pairs, cfg.word_size, version)
    }

    /// Number of bases.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if the table has no bases (never the case after `new`).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The entries, sorted ascending by base value.
    pub fn entries(&self) -> &[BaseEntry] {
        &self.entries
    }

    /// Entry by index.
    #[inline]
    pub fn get(&self, idx: usize) -> BaseEntry {
        self.entries[idx]
    }

    /// Largest width class in the table.
    pub fn max_width(&self) -> u32 {
        self.max_width
    }

    /// Find a cheapest encodable (base index, delta, field width) for
    /// `v`. The cost of a candidate is **its entry's width** (that is
    /// what the wire pays); among equal-width fits any candidate yields
    /// an identical compressed size, so the search stops at the first
    /// one. Returns `None` when `v` is an outlier for every base.
    ///
    /// W32 tables use the bucket index (the compression hot path): the
    /// candidates for `v`'s bucket are pre-sorted by width, so the scan
    /// stops at the first width group containing a fit. W64 tables fall
    /// back to a range-bounded sorted scan. Both are exact (verified
    /// against [`Self::best_base_exhaustive`] by property tests).
    #[inline]
    pub fn best_base(&self, v: u64) -> Option<(usize, i64, u32)> {
        self.best_base_with(v, crate::simd::active())
    }

    /// [`Self::best_base`] with an explicit kernel vtable — the encode
    /// loops resolve dispatch once per block instead of once per word.
    #[inline]
    pub(crate) fn best_base_with(
        &self,
        v: u64,
        kernels: &crate::simd::Kernels,
    ) -> Option<(usize, i64, u32)> {
        if !self.buckets.off.is_empty() {
            return self.best_base_bucketed(v, kernels);
        }
        self.best_base_scan(v)
    }

    /// [`Self::best_base`] with a caller-supplied most-recently-used hint
    /// (the per-block value-locality probe of the encode hot path). When
    /// the hinted entry fits `v`, only *strictly narrower* candidates can
    /// beat it — on the W32 bucketed path that is the width-sorted prefix
    /// of `v`'s bucket, so runs of words clustered near one base skip the
    /// full bucket walk. Exact: the returned field width always equals
    /// [`Self::best_base`]'s (a width tie may resolve to a different
    /// same-width base, which encodes in identical bits — verified by the
    /// `hinted_search_matches_exhaustive_width` property test).
    ///
    /// `hint` must be an entry index previously returned by a search on
    /// **this** table (panics on an out-of-range index).
    #[inline]
    pub fn best_base_hinted(&self, v: u64, hint: Option<u32>) -> Option<(usize, i64, u32)> {
        self.best_base_hinted_with(v, hint, crate::simd::active())
    }

    /// [`Self::best_base_hinted`] with an explicit kernel vtable (see
    /// [`Self::best_base_with`]).
    #[inline]
    pub(crate) fn best_base_hinted_with(
        &self,
        v: u64,
        hint: Option<u32>,
        kernels: &crate::simd::Kernels,
    ) -> Option<(usize, i64, u32)> {
        if let Some(h) = hint {
            if !self.buckets.off.is_empty() {
                let e = self.entries[h as usize];
                let d = wrapping_delta(v, e.base, self.word_size);
                if e.fits(d) {
                    if e.width == 0 {
                        return Some((h as usize, d, 0)); // cost 0: unbeatable
                    }
                    let b = (v as u32 >> BUCKET_SHIFT) as usize;
                    let (lo, hi) = (self.buckets.off[b] as usize, self.buckets.off[b + 1] as usize);
                    // width-sorted candidates: only the strictly-narrower
                    // prefix can beat the hinted entry
                    let cut = lo + self.buckets.width[lo..hi].partition_point(|&w| w < e.width);
                    let (los, spans) = (&self.buckets.lo[lo..cut], &self.buckets.span[lo..cut]);
                    if let Some(p) = (kernels.first_fit)(v as u32, los, spans) {
                        let i = self.buckets.cands[lo + p] as usize;
                        let c = self.entries[i];
                        let cd = wrapping_delta(v, c.base, self.word_size);
                        return Some((i, cd, c.width));
                    }
                    return Some((h as usize, d, e.width));
                }
            }
        }
        self.best_base_with(v, kernels)
    }

    /// W32 fast path: first fit over the bucket's width-sorted coverage
    /// intervals (vectorized through the kernel vtable); the first fit
    /// is a minimal-width fit, and its candidate index is the base
    /// pointer that goes on the wire.
    #[inline]
    fn best_base_bucketed(
        &self,
        v: u64,
        kernels: &crate::simd::Kernels,
    ) -> Option<(usize, i64, u32)> {
        let b = (v as u32 >> BUCKET_SHIFT) as usize;
        let (lo, hi) = (self.buckets.off[b] as usize, self.buckets.off[b + 1] as usize);
        let (los, spans) = (&self.buckets.lo[lo..hi], &self.buckets.span[lo..hi]);
        let p = (kernels.first_fit)(v as u32, los, spans)?;
        let i = self.buckets.cands[lo + p] as usize;
        let e = self.entries[i];
        let d = wrapping_delta(v, e.base, self.word_size);
        debug_assert!(e.fits(d));
        Some((i, d, e.width))
    }

    /// Range-bounded sorted scan (W64 path): binary-search to the
    /// insertion point, then scan outward in both directions only while
    /// bases remain within the largest class's delta range (plus
    /// wrap-around scans from both array ends).
    ///
    /// Complete by construction: any base that can encode `v` lies within
    /// `±2^(max_width-1)` of it (mod the word ring), and all four scans
    /// stop only once they leave that range.
    fn best_base_scan(&self, v: u64) -> Option<(usize, i64, u32)> {
        let max_abs: i64 = if self.max_width == 0 { 0 } else { 1i64 << (self.max_width - 1) };
        let idx = self.entries.partition_point(|e| e.base <= v);
        let mut best: Option<(usize, i64, u32)> = None;
        let consider = |i: usize, best: &mut Option<(usize, i64, u32)>| -> i64 {
            let e = self.entries[i];
            let d = wrapping_delta(v, e.base, self.word_size);
            if e.fits(d) {
                let better = match *best {
                    None => true,
                    Some((_, _, bw)) => e.width < bw,
                };
                if better {
                    *best = Some((i, d, e.width));
                }
            }
            d
        };
        // Downward scan (bases <= v): delta grows as we go down; stop once
        // it exceeds the widest class's range (or wraps negative).
        let mut i = idx;
        while i > 0 {
            i -= 1;
            let d = consider(i, &mut best);
            if d > max_abs || d < 0 {
                break;
            }
        }
        // Upward scan (bases > v): delta is negative and shrinking.
        let mut i = idx;
        while i < self.entries.len() {
            let d = consider(i, &mut best);
            if d < -max_abs || d > 0 {
                break;
            }
            i += 1;
        }
        // Wrap-around: small v reaching the largest bases…
        let mut i = self.entries.len();
        while i > idx {
            i -= 1;
            let d = consider(i, &mut best);
            if d.abs() > max_abs {
                break;
            }
        }
        // …and large v reaching the smallest bases.
        let mut i = 0;
        while i < idx {
            let d = consider(i, &mut best);
            if d.abs() > max_abs {
                break;
            }
            i += 1;
        }
        best
    }

    /// Exhaustive variant of [`best_base`] (O(K)); used by tests to verify
    /// the indexed searches never miss a cheaper width, and by callers
    /// with tiny tables.
    pub fn best_base_exhaustive(&self, v: u64) -> Option<(usize, i64, u32)> {
        let mut best: Option<(usize, i64, u32)> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let d = wrapping_delta(v, e.base, self.word_size);
            if e.fits(d) {
                let better = match best {
                    None => true,
                    Some((_, _, bw)) => e.width < bw,
                };
                if better {
                    best = Some((i, d, e.width));
                }
            }
        }
        best
    }

    /// Serialized length in bytes (see [`GlobalBaseTable::serialize`]).
    pub fn serialized_len(&self) -> usize {
        // magic(4) + version(8) + word_size(1) + count(4) + entries * (word + 1)
        17 + self.entries.len() * (self.word_size.bytes() + 1)
    }

    /// Serialize (little-endian framing) for embedding in compressed
    /// images and for the coordinator's table ring. The entry count is a
    /// u32 ("GBT2" framing) so oversized tables serialize exactly instead
    /// of silently truncating at u16::MAX.
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_len());
        out.extend_from_slice(b"GBT2");
        out.extend_from_slice(&self.version.to_le_bytes());
        out.push(match self.word_size {
            WordSize::W32 => 4,
            WordSize::W64 => 8,
        });
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            match self.word_size {
                WordSize::W32 => out.extend_from_slice(&(e.base as u32).to_le_bytes()),
                WordSize::W64 => out.extend_from_slice(&e.base.to_le_bytes()),
            }
            out.push(e.width as u8);
        }
        out
    }

    /// Parse a serialized table; returns the table and bytes consumed.
    pub fn deserialize(data: &[u8]) -> Result<(GlobalBaseTable, usize)> {
        if data.len() < 17 || &data[0..4] != b"GBT2" {
            return Err(Error::Corrupt("bad table magic".into()));
        }
        let version = u64::from_le_bytes(data[4..12].try_into().unwrap());
        let word_size = match data[12] {
            4 => WordSize::W32,
            8 => WordSize::W64,
            b => return Err(Error::Corrupt(format!("bad word size {b}"))),
        };
        let count = u32::from_le_bytes(data[13..17].try_into().unwrap()) as usize;
        let entry_len = word_size.bytes() + 1;
        let need = 17 + count * entry_len;
        if data.len() < need {
            return Err(Error::Corrupt("truncated table".into()));
        }
        let mut entries = Vec::with_capacity(count);
        for i in 0..count {
            let o = 17 + i * entry_len;
            let base = match word_size {
                WordSize::W32 => u32::from_le_bytes(data[o..o + 4].try_into().unwrap()) as u64,
                WordSize::W64 => u64::from_le_bytes(data[o..o + 8].try_into().unwrap()),
            };
            let width = data[o + word_size.bytes()] as u32;
            if width > word_size.bits() {
                return Err(Error::Corrupt(format!("width {width} exceeds word")));
            }
            entries.push(BaseEntry { base, width });
        }
        if !entries.windows(2).all(|w| w[0].base < w[1].base) {
            return Err(Error::Corrupt("table bases not sorted/unique".into()));
        }
        let max_width = entries.iter().map(|e| e.width).max().unwrap_or(0);
        let buckets = build_buckets(&entries, word_size);
        Ok((GlobalBaseTable { entries, max_width, buckets, version, word_size }, need))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_base_pinned() {
        let t = GlobalBaseTable::new(vec![(100, 8)], WordSize::W32, 1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.entries()[0].base, 0);
    }

    #[test]
    fn dedup_keeps_widest() {
        let t = GlobalBaseTable::new(vec![(0, 4), (0, 16), (5, 8), (5, 4)], WordSize::W32, 0);
        assert_eq!(t.len(), 2);
        assert_eq!(t.entries()[0], BaseEntry { base: 0, width: 16 });
        assert_eq!(t.entries()[1], BaseEntry { base: 5, width: 8 });
        assert_eq!(t.max_width(), 16);
    }

    #[test]
    fn best_base_prefers_cheapest_field() {
        // value 1005: fits base 1000 (w=8, cost 8) and base 1004 (w=4, cost 4).
        let t = GlobalBaseTable::new(vec![(1000, 8), (1004, 4)], WordSize::W32, 0);
        let (i, d, w) = t.best_base(1005).unwrap();
        assert_eq!(t.get(i).base, 1004);
        assert_eq!(d, 1);
        assert_eq!(w, 4);
        // exact match on a zero-width base costs 0
        let t = GlobalBaseTable::new(vec![(7777, 0), (7770, 8)], WordSize::W32, 0);
        let (i, d, w) = t.best_base(7777).unwrap();
        assert_eq!(t.get(i).base, 7777);
        assert_eq!((d, w), (0, 0));
    }

    #[test]
    fn outlier_when_nothing_fits() {
        let t = GlobalBaseTable::new(vec![(1000, 4)], WordSize::W32, 0);
        assert!(t.best_base(1007).is_some());
        assert!(t.best_base(1009).is_none()); // needs 5 bits, zero base needs 11
        assert!(t.best_base(500_000_000).is_none());
    }

    #[test]
    fn fits_respects_offset_binary_asymmetry() {
        let e = BaseEntry { base: 100, width: 4 };
        assert!(e.fits(7)); // [-8, 7]
        assert!(e.fits(-8));
        assert!(!e.fits(8));
        assert!(!e.fits(-9));
        let e0 = BaseEntry { base: 5, width: 0 };
        assert!(e0.fits(0));
        assert!(!e0.fits(1));
        assert!(!e0.fits(-1));
    }

    #[test]
    fn windowed_search_matches_exhaustive() {
        let mut rng = Rng::new(77);
        for trial in 0..30 {
            let k = 1 + rng.below(96) as usize;
            let pairs: Vec<(u64, u32)> = (0..k)
                .map(|_| {
                    // mix of dense and sparse bases
                    let base = if rng.chance(0.3) {
                        rng.below(1 << 16)
                    } else {
                        rng.next_u32() as u64
                    };
                    (base, [0u32, 4, 8, 16, 24][rng.below(5) as usize])
                })
                .collect();
            let t = GlobalBaseTable::new(pairs, WordSize::W32, 0);
            for _ in 0..2000 {
                let v = if rng.chance(0.5) {
                    let e = t.get(rng.below(t.len() as u64) as usize);
                    crate::cluster::apply_delta(
                        e.base,
                        rng.range_i64(-40_000, 40_000),
                        WordSize::W32,
                    )
                } else {
                    rng.next_u32() as u64
                };
                let fast = t.best_base(v);
                let slow = t.best_base_exhaustive(v);
                // same minimal width (any same-width base costs the same
                // bits); fast result must itself be a valid encoding
                assert_eq!(fast.map(|(_, _, w)| w), slow.map(|(_, _, w)| w), "trial {trial}, v={v}");
                if let Some((i, d, w)) = fast {
                    let e = t.get(i);
                    assert_eq!(e.width, w);
                    assert!(e.fits(d));
                    assert_eq!(crate::cluster::apply_delta(e.base, d, WordSize::W32), v);
                }
            }
        }
    }

    #[test]
    fn hinted_search_matches_exhaustive_width() {
        // the MRU probe must never pick a wider (more expensive) base
        // than the exhaustive search, for any hint, and its result must
        // itself be a valid encoding
        let mut rng = Rng::new(91);
        for ws in [WordSize::W32, WordSize::W64] {
            for _ in 0..10 {
                let k = 1 + rng.below(48) as usize;
                let pairs: Vec<(u64, u32)> = (0..k)
                    .map(|_| {
                        let base = if rng.chance(0.4) {
                            rng.below(1 << 18)
                        } else if ws == WordSize::W32 {
                            rng.next_u32() as u64
                        } else {
                            rng.next_u64()
                        };
                        (base, [0u32, 4, 8, 16, 24][rng.below(5) as usize])
                    })
                    .collect();
                let t = GlobalBaseTable::new(pairs, ws, 0);
                let mut hint: Option<u32> = None;
                for _ in 0..1500 {
                    let v = if rng.chance(0.6) {
                        let e = t.get(rng.below(t.len() as u64) as usize);
                        crate::cluster::apply_delta(e.base, rng.range_i64(-5000, 5000), ws)
                    } else if ws == WordSize::W32 {
                        rng.next_u32() as u64
                    } else {
                        rng.next_u64()
                    };
                    let hinted = t.best_base_hinted(v, hint);
                    let slow = t.best_base_exhaustive(v);
                    assert_eq!(
                        hinted.map(|(_, _, w)| w),
                        slow.map(|(_, _, w)| w),
                        "ws {ws:?}, v={v}, hint={hint:?}"
                    );
                    if let Some((i, d, w)) = hinted {
                        let e = t.get(i);
                        assert_eq!(e.width, w);
                        assert!(e.fits(d));
                        assert_eq!(crate::cluster::apply_delta(e.base, d, ws), v);
                        hint = Some(i as u32);
                    }
                }
            }
        }
    }

    #[test]
    fn wraparound_candidates_work_w32() {
        // base at u32::MAX - 2 with a 4-bit class: value 1 is delta +4
        // under wrapping, cheaper (4 bits) than the pinned zero base (8).
        let t = GlobalBaseTable::new(vec![(u32::MAX as u64 - 2, 4)], WordSize::W32, 0);
        let (i, d, w) = t.best_base(1).unwrap();
        assert_eq!(t.get(i).base, u32::MAX as u64 - 2);
        assert_eq!((d, w), (4, 4));
        // and the mirror: value near MAX reaching base 0 (pinned, w=8)
        let t = GlobalBaseTable::new(vec![(1 << 20, 4)], WordSize::W32, 0);
        let (i, d, _) = t.best_base(u32::MAX as u64 - 6).unwrap();
        assert_eq!(t.get(i).base, 0);
        assert_eq!(d, -7);
    }

    #[test]
    fn oversized_table_keeps_fast_path_and_serializes() {
        // regression: tables with more than u16::MAX entries used to
        // silently drop the W32 bucket index (u16 candidate indices) and
        // silently truncate the serialized entry count (u16 framing)
        let n = u16::MAX as usize + 2;
        let pairs: Vec<(u64, u32)> = (0..n).map(|i| ((i as u64) << 12, 4)).collect();
        let t = GlobalBaseTable::new(pairs, WordSize::W32, 9);
        assert!(t.len() > u16::MAX as usize, "len {}", t.len());
        assert!(!t.buckets.off.is_empty(), "fast path must survive oversized tables");
        let mut rng = Rng::new(123);
        for _ in 0..500 {
            let v = if rng.chance(0.5) {
                let e = t.get(rng.below(t.len() as u64) as usize);
                crate::cluster::apply_delta(e.base, rng.range_i64(-10, 10), WordSize::W32)
            } else {
                rng.next_u32() as u64
            };
            assert_eq!(
                t.best_base(v).map(|(_, _, w)| w),
                t.best_base_exhaustive(v).map(|(_, _, w)| w),
                "v={v}"
            );
        }
        // entries above the old u16 boundary are reachable through the index
        let hi = t.get(t.len() - 1);
        let (i, d, _) = t.best_base(hi.base).unwrap();
        assert_eq!(t.get(i).base, hi.base);
        assert_eq!(d, 0);
        // and the wire roundtrip preserves every entry
        let bytes = t.serialize();
        assert_eq!(bytes.len(), t.serialized_len());
        let (t2, consumed) = GlobalBaseTable::deserialize(&bytes).unwrap();
        assert_eq!(consumed, bytes.len());
        assert_eq!(t2.len(), t.len());
        assert_eq!(t, t2);
    }

    #[test]
    fn from_selection_matches_fit_from_centroids() {
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let mut rng = Rng::new(31);
        let samples: Vec<u64> = (0..2000)
            .map(|_| {
                let c = [9_000u64, 70_000_000][rng.below(2) as usize];
                crate::cluster::apply_delta(c, rng.range_i64(-50, 50), WordSize::W32)
            })
            .collect();
        let sel = Selection {
            centroids: vec![9_000, 70_000_000],
            cost: 0.0,
            iters_run: 1,
            warm_started: false,
        };
        let a = GlobalBaseTable::from_selection(&samples, &sel, &cfg, 5);
        let b = GlobalBaseTable::fit_from_centroids(&samples, &sel.centroids, &cfg, 5);
        assert_eq!(a, b);
        assert_eq!(a.version, 5);
        // both clusters got a base with a sane width class
        assert!(a.entries().iter().any(|e| e.base == 9_000 && e.width <= 8));
    }

    #[test]
    fn serialize_roundtrip() {
        let mut rng = Rng::new(5);
        for ws in [WordSize::W32, WordSize::W64] {
            let pairs: Vec<(u64, u32)> = (0..37)
                .map(|_| {
                    let v = if ws == WordSize::W32 { rng.next_u32() as u64 } else { rng.next_u64() };
                    (v, rng.below(24) as u32)
                })
                .collect();
            let t = GlobalBaseTable::new(pairs, ws, 99);
            let bytes = t.serialize();
            assert_eq!(bytes.len(), t.serialized_len());
            let (t2, consumed) = GlobalBaseTable::deserialize(&bytes).unwrap();
            assert_eq!(consumed, bytes.len());
            assert_eq!(t, t2);
        }
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(GlobalBaseTable::deserialize(b"nope").is_err());
        let t = GlobalBaseTable::new(vec![(7, 8)], WordSize::W32, 0);
        let mut bytes = t.serialize();
        bytes.truncate(bytes.len() - 1);
        assert!(GlobalBaseTable::deserialize(&bytes).is_err());
        let mut bytes = t.serialize();
        bytes[12] = 3; // bad word size
        assert!(GlobalBaseTable::deserialize(&bytes).is_err());
    }
}
