//! The GBDI codec — Global-Base Delta-Immediate compression (HPCA'22,
//! reimplemented per the CS.DC'25 paper).
//!
//! Pipeline:
//!
//! 1. **Background analysis** ([`analyze`]) — sample word values from the
//!    target data, cluster them (modified k-means, bit-cost metric), and
//!    derive a [`table::GlobalBaseTable`]: K global bases, each paired
//!    with a *maximum delta* width class.
//! 2. **Compression** ([`encode`]) — per 64-byte block, encode each word
//!    as (base pointer, variable-width delta), with outlier escapes and
//!    ZERO/REP/RAW fast paths.
//! 3. **Decompression** ([`decode`]) — format decoding, global table
//!    access, bit-exact value reconstruction.
//!
//! The encodings are bit-exact and lossless; every compressed image
//! round-trips byte-identically (enforced by the `roundtrip` integration
//! suite and property tests).

pub mod analyze;
pub mod decode;
pub mod encode;
pub mod table;

pub use analyze::{analyze_image, analyze_samples};
pub use table::GlobalBaseTable;

use crate::value::WordSize;

/// Per-block encoding mode tag (2 bits on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockMode {
    /// Block stored verbatim.
    Raw = 0,
    /// All-zero block (payload-free).
    Zero = 1,
    /// Single repeated word (one word payload).
    Rep = 2,
    /// GBDI base+delta payload.
    Gbdi = 3,
}

impl BlockMode {
    /// Decode a 2-bit tag.
    pub fn from_tag(tag: u64) -> BlockMode {
        match tag & 0b11 {
            0 => BlockMode::Raw,
            1 => BlockMode::Zero,
            2 => BlockMode::Rep,
            _ => BlockMode::Gbdi,
        }
    }
}

/// Codec configuration. Defaults follow the papers: 64-byte blocks of
/// 32-bit words, 64 global bases, width classes {0,4,8,16,24}.
#[derive(Debug, Clone, PartialEq)]
pub struct GbdiConfig {
    /// Block size in bytes (a cache line in the papers).
    pub block_bytes: usize,
    /// Word granularity.
    pub word_size: WordSize,
    /// Number of global bases (table capacity). Base pointer width is
    /// `ceil(log2(num_bases + 1))` — the +1 is the outlier escape code.
    pub num_bases: usize,
    /// Sorted, strictly increasing delta width classes (bits). Class 0
    /// means "exact match with the base".
    pub width_classes: Vec<u32>,
    /// Samples fed to background analysis.
    pub analysis_samples: usize,
    /// k-means iterations during analysis.
    pub analysis_iters: usize,
    /// Quantile of |delta| within a cluster used to pick the cluster's
    /// max-delta class (values beyond it become outliers).
    pub delta_quantile: f64,
    /// Analysis PRNG seed.
    pub seed: u64,
}

impl Default for GbdiConfig {
    fn default() -> Self {
        GbdiConfig {
            block_bytes: 64,
            word_size: WordSize::W32,
            num_bases: 64,
            width_classes: vec![0, 4, 8, 12, 16, 20, 24],
            analysis_samples: 4096,
            analysis_iters: 16,
            delta_quantile: 0.95,
            seed: 0x6BD1_5EED,
        }
    }
}

impl GbdiConfig {
    /// Validate invariants; returns a human-readable complaint if invalid.
    pub fn validate(&self) -> Result<(), String> {
        if self.block_bytes == 0 || self.block_bytes % self.word_size.bytes() != 0 {
            return Err(format!(
                "block_bytes {} must be a positive multiple of the word size {}",
                self.block_bytes,
                self.word_size.bytes()
            ));
        }
        if self.num_bases < 1 || self.num_bases > 4096 {
            return Err(format!("num_bases {} out of range [1, 4096]", self.num_bases));
        }
        if self.width_classes.is_empty() {
            return Err("width_classes must be non-empty".into());
        }
        if !self.width_classes.windows(2).all(|w| w[0] < w[1]) {
            return Err("width_classes must be strictly increasing".into());
        }
        if *self.width_classes.last().unwrap() > self.word_size.bits() {
            return Err("largest width class exceeds word width".into());
        }
        if !(0.5..=1.0).contains(&self.delta_quantile) {
            return Err("delta_quantile must be in [0.5, 1.0]".into());
        }
        Ok(())
    }

    /// Words per block.
    #[inline]
    pub fn words_per_block(&self) -> usize {
        self.block_bytes / self.word_size.bytes()
    }

    /// Bits of the per-word base pointer (including the outlier escape).
    #[inline]
    pub fn base_ptr_bits(&self) -> u32 {
        // num_bases real pointers + 1 escape code
        64 - (self.num_bases as u64).leading_zeros() // ceil(log2(n+1)) for n>=1
    }

    /// The escape code marking an outlier (all base-pointer bits set would
    /// waste codes; we use exactly `num_bases`).
    #[inline]
    pub fn outlier_code(&self) -> u64 {
        self.num_bases as u64
    }
}

/// A compressed memory image: framed container written by
/// [`encode::GbdiCodec::compress_image`].
#[derive(Debug, Clone)]
pub struct CompressedImage {
    /// Serialized global base table the payload references.
    pub table: table::GlobalBaseTable,
    /// Original image length in bytes.
    pub original_len: usize,
    /// Per-block bit lengths (for the memory-simulator's sector layout);
    /// one entry per block.
    pub block_bits: Vec<u32>,
    /// The packed payload.
    pub payload: Vec<u8>,
    /// Parallel-compression chunking: every `chunk_blocks`-th block starts
    /// byte-aligned (0 = unchunked serial stream).
    pub chunk_blocks: usize,
    /// Codec config used (needed to decode).
    pub config: GbdiConfig,
}

impl CompressedImage {
    /// Compressed payload size in bytes (excluding table + framing).
    pub fn payload_len(&self) -> usize {
        self.payload.len()
    }

    /// Total compressed size in bytes including the serialized table and
    /// per-image framing — the honest numerator for compression ratios.
    pub fn total_len(&self) -> usize {
        self.payload.len() + self.table.serialized_len() + 16
    }

    /// Compression ratio original/compressed (the paper's metric).
    pub fn ratio(&self) -> f64 {
        self.original_len as f64 / self.total_len() as f64
    }
}

/// Re-export: the codec object.
pub use encode::GbdiCodec;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        GbdiConfig::default().validate().unwrap();
    }

    #[test]
    fn base_ptr_bits_counts_escape() {
        let mut c = GbdiConfig::default();
        c.num_bases = 64;
        assert_eq!(c.base_ptr_bits(), 7); // 64 bases + escape needs 7 bits
        c.num_bases = 63;
        assert_eq!(c.base_ptr_bits(), 6); // 63 + escape = 64 codes -> 6 bits
        c.num_bases = 1;
        assert_eq!(c.base_ptr_bits(), 1);
        c.num_bases = 127;
        assert_eq!(c.base_ptr_bits(), 7);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = GbdiConfig::default();
        c.block_bytes = 30;
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.width_classes = vec![4, 4];
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.width_classes = vec![0, 40];
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.num_bases = 0;
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.delta_quantile = 0.2;
        assert!(c.validate().is_err());
    }

    #[test]
    fn block_mode_tags_roundtrip() {
        for m in [BlockMode::Raw, BlockMode::Zero, BlockMode::Rep, BlockMode::Gbdi] {
            assert_eq!(BlockMode::from_tag(m as u64), m);
        }
    }
}
