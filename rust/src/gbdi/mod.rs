//! The GBDI codec — Global-Base Delta-Immediate compression (HPCA'22,
//! reimplemented per the CS.DC'25 paper).
//!
//! Pipeline:
//!
//! 1. **Background analysis** ([`analyze`]) — sample word values from the
//!    target data, cluster them (modified k-means, bit-cost metric), and
//!    derive a [`table::GlobalBaseTable`]: K global bases, each paired
//!    with a *maximum delta* width class.
//! 2. **Compression** ([`encode`]) — per 64-byte block, encode each word
//!    as (base pointer, variable-width delta), with outlier escapes and
//!    ZERO/REP/RAW fast paths.
//! 3. **Decompression** ([`decode`]) — format decoding, global table
//!    access, bit-exact value reconstruction.
//!
//! The encodings are bit-exact and lossless; every compressed image
//! round-trips byte-identically (enforced by the `roundtrip` integration
//! suite and property tests).

pub mod analyze;
pub mod decode;
pub mod encode;
pub mod table;

pub use analyze::{analyze_image, analyze_samples};
pub use table::GlobalBaseTable;

use crate::value::WordSize;

/// Per-block encoding mode tag (2 bits on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockMode {
    /// Block stored verbatim.
    Raw = 0,
    /// All-zero block (payload-free).
    Zero = 1,
    /// Single repeated word (one word payload).
    Rep = 2,
    /// GBDI base+delta payload.
    Gbdi = 3,
}

impl BlockMode {
    /// Decode a 2-bit tag.
    pub fn from_tag(tag: u64) -> BlockMode {
        match tag & 0b11 {
            0 => BlockMode::Raw,
            1 => BlockMode::Zero,
            2 => BlockMode::Rep,
            _ => BlockMode::Gbdi,
        }
    }
}

/// Codec configuration. Defaults follow the papers: 64-byte blocks of
/// 32-bit words, 64 global bases, width classes {0,4,8,16,24}.
#[derive(Debug, Clone, PartialEq)]
pub struct GbdiConfig {
    /// Block size in bytes (a cache line in the papers).
    pub block_bytes: usize,
    /// Word granularity.
    pub word_size: WordSize,
    /// Number of global bases (table capacity). Base pointer width is
    /// `ceil(log2(num_bases + 1))` — the +1 is the outlier escape code.
    pub num_bases: usize,
    /// Sorted, strictly increasing delta width classes (bits). Class 0
    /// means "exact match with the base".
    pub width_classes: Vec<u32>,
    /// Samples fed to background analysis.
    pub analysis_samples: usize,
    /// k-means iterations during analysis.
    pub analysis_iters: usize,
    /// Quantile of |delta| within a cluster used to pick the cluster's
    /// max-delta class (values beyond it become outliers).
    pub delta_quantile: f64,
    /// Analysis PRNG seed.
    pub seed: u64,
}

impl Default for GbdiConfig {
    fn default() -> Self {
        GbdiConfig {
            block_bytes: 64,
            word_size: WordSize::W32,
            num_bases: 64,
            width_classes: vec![0, 4, 8, 12, 16, 20, 24],
            analysis_samples: 4096,
            analysis_iters: 16,
            delta_quantile: 0.95,
            seed: 0x6BD1_5EED,
        }
    }
}

impl GbdiConfig {
    /// Validate invariants; returns a human-readable complaint if invalid.
    pub fn validate(&self) -> Result<(), String> {
        if self.block_bytes == 0 || self.block_bytes % self.word_size.bytes() != 0 {
            return Err(format!(
                "block_bytes {} must be a positive multiple of the word size {}",
                self.block_bytes,
                self.word_size.bytes()
            ));
        }
        if self.num_bases < 1 || self.num_bases > 4096 {
            return Err(format!("num_bases {} out of range [1, 4096]", self.num_bases));
        }
        if self.width_classes.is_empty() {
            return Err("width_classes must be non-empty".into());
        }
        if !self.width_classes.windows(2).all(|w| w[0] < w[1]) {
            return Err("width_classes must be strictly increasing".into());
        }
        if *self.width_classes.last().unwrap() > self.word_size.bits() {
            return Err("largest width class exceeds word width".into());
        }
        if !(0.5..=1.0).contains(&self.delta_quantile) {
            return Err("delta_quantile must be in [0.5, 1.0]".into());
        }
        Ok(())
    }

    /// Words per block.
    #[inline]
    pub fn words_per_block(&self) -> usize {
        self.block_bytes / self.word_size.bytes()
    }

    /// Bits of the per-word base pointer (including the outlier escape).
    #[inline]
    pub fn base_ptr_bits(&self) -> u32 {
        // num_bases real pointers + 1 escape code
        64 - (self.num_bases as u64).leading_zeros() // ceil(log2(n+1)) for n>=1
    }

    /// The escape code marking an outlier (all base-pointer bits set would
    /// waste codes; we use exactly `num_bases`).
    #[inline]
    pub fn outlier_code(&self) -> u64 {
        self.num_bases as u64
    }

    /// Serialize the wire-relevant config fields for embedding in a
    /// [`crate::container::Container`]: block size, word size, base
    /// budget, and the width-class menu. Analysis-only knobs (sample
    /// count, iterations, quantile, seed) are not needed to decode and
    /// come back as defaults from [`Self::from_bytes`].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(10 + self.width_classes.len());
        out.extend_from_slice(&(self.block_bytes as u32).to_le_bytes());
        out.push(self.word_size.bytes() as u8);
        out.extend_from_slice(&(self.num_bases as u16).to_le_bytes());
        out.push(self.width_classes.len() as u8);
        for &w in &self.width_classes {
            out.push(w as u8);
        }
        out
    }

    /// Parse a config blob written by [`Self::to_bytes`]. The result is
    /// validated.
    pub fn from_bytes(data: &[u8]) -> crate::Result<GbdiConfig> {
        let corrupt = |m: &str| crate::Error::Corrupt(format!("gbdi config: {m}"));
        if data.len() < 8 {
            return Err(corrupt("truncated"));
        }
        let block_bytes = u32::from_le_bytes(data[0..4].try_into().unwrap()) as usize;
        let word_size = match data[4] {
            4 => WordSize::W32,
            8 => WordSize::W64,
            b => return Err(corrupt(&format!("bad word size {b}"))),
        };
        let num_bases = u16::from_le_bytes(data[5..7].try_into().unwrap()) as usize;
        let n_classes = data[7] as usize;
        if data.len() < 8 + n_classes {
            return Err(corrupt("truncated width classes"));
        }
        let width_classes: Vec<u32> = data[8..8 + n_classes].iter().map(|&b| b as u32).collect();
        let cfg = GbdiConfig {
            block_bytes,
            word_size,
            num_bases,
            width_classes,
            ..Default::default()
        };
        cfg.validate().map_err(|e| corrupt(&e))?;
        Ok(cfg)
    }
}

/// Re-export: the codec object.
pub use encode::GbdiCodec;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        GbdiConfig::default().validate().unwrap();
    }

    #[test]
    fn base_ptr_bits_counts_escape() {
        let mut c = GbdiConfig::default();
        c.num_bases = 64;
        assert_eq!(c.base_ptr_bits(), 7); // 64 bases + escape needs 7 bits
        c.num_bases = 63;
        assert_eq!(c.base_ptr_bits(), 6); // 63 + escape = 64 codes -> 6 bits
        c.num_bases = 1;
        assert_eq!(c.base_ptr_bits(), 1);
        c.num_bases = 127;
        assert_eq!(c.base_ptr_bits(), 7);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = GbdiConfig::default();
        c.block_bytes = 30;
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.width_classes = vec![4, 4];
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.width_classes = vec![0, 40];
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.num_bases = 0;
        assert!(c.validate().is_err());
        let mut c = GbdiConfig::default();
        c.delta_quantile = 0.2;
        assert!(c.validate().is_err());
    }

    #[test]
    fn block_mode_tags_roundtrip() {
        for m in [BlockMode::Raw, BlockMode::Zero, BlockMode::Rep, BlockMode::Gbdi] {
            assert_eq!(BlockMode::from_tag(m as u64), m);
        }
    }

    #[test]
    fn config_wire_roundtrip() {
        let cfg = GbdiConfig {
            block_bytes: 128,
            word_size: WordSize::W64,
            num_bases: 100,
            width_classes: vec![0, 4, 8, 16, 24, 32],
            ..Default::default()
        };
        let back = GbdiConfig::from_bytes(&cfg.to_bytes()).unwrap();
        assert_eq!(back.block_bytes, 128);
        assert_eq!(back.word_size, WordSize::W64);
        assert_eq!(back.num_bases, 100);
        assert_eq!(back.width_classes, cfg.width_classes);
        assert!(GbdiConfig::from_bytes(&[1, 2]).is_err());
        let mut bad = cfg.to_bytes();
        bad[4] = 3; // bad word size
        assert!(GbdiConfig::from_bytes(&bad).is_err());
    }
}
