//! Background data analysis (paper §II.B.1-2): sample the target data,
//! cluster word values to find global bases, and pair each base with a
//! maximum-delta width class.
//!
//! Two entry points produce a [`GlobalBaseTable`]:
//!
//! * [`analyze_image`] / [`analyze_samples`] — pure-Rust clustering
//!   ([`crate::cluster`]).
//! * [`table_from_centroids`] — width-class fitting around centroids that
//!   came from elsewhere (the AOT-compiled JAX/Pallas k-means executed by
//!   [`crate::runtime`], or an ablation arm). This is the shared back half
//!   of the analysis regardless of who ran the clustering.

use super::table::GlobalBaseTable;
use super::GbdiConfig;
use crate::cluster::{kmeans, KmeansConfig, Metric};
use crate::util::stats::stride_sample;
use crate::value::words;

/// Sample word values from an image for analysis (deterministic stride
/// sampling — what a memory controller scanning traffic would do).
pub fn sample_image(image: &[u8], cfg: &GbdiConfig) -> Vec<u64> {
    let all: Vec<u64> = words(image, cfg.word_size).collect();
    stride_sample(&all, cfg.analysis_samples)
}

/// Full background analysis of an image: sample → cluster → fit widths.
pub fn analyze_image(image: &[u8], cfg: &GbdiConfig) -> GlobalBaseTable {
    analyze_samples(&sample_image(image, cfg), cfg)
}

/// Background analysis over pre-sampled word values, using the paper's
/// modified (bit-cost) k-means.
pub fn analyze_samples(samples: &[u64], cfg: &GbdiConfig) -> GlobalBaseTable {
    analyze_samples_metric(samples, cfg, Metric::BitCost)
}

/// [`analyze_samples`] with an explicit clustering metric — the ablation
/// hook for the paper's "modified vs unmodified k-means" claim (E4).
pub fn analyze_samples_metric(samples: &[u64], cfg: &GbdiConfig, metric: Metric) -> GlobalBaseTable {
    // Reserve one slot for the pinned zero base.
    let k = cfg.num_bases.saturating_sub(1).max(1);
    let kcfg = KmeansConfig {
        k,
        iters: cfg.analysis_iters,
        metric,
        width_classes: cfg.width_classes.clone(),
        word_size: cfg.word_size,
        seed: cfg.seed,
    };
    let result = kmeans(samples, &kcfg);
    table_from_centroids(samples, &result.centroids, cfg, 0)
}

/// Fit per-base width classes around given centroids and build the table
/// (the paper's "establishing maximum deltas" step). Thin alias for
/// [`GlobalBaseTable::fit_from_centroids`], where the width-fitting now
/// lives — every analysis path (native selectors, the PJRT artifact, the
/// CLI, the benches) shares that one implementation.
pub fn table_from_centroids(
    samples: &[u64],
    centroids: &[u64],
    cfg: &GbdiConfig,
    version: u64,
) -> GlobalBaseTable {
    GlobalBaseTable::fit_from_centroids(samples, centroids, cfg, version)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::apply_delta;
    use crate::gbdi::{decode, GbdiCodec};
    use crate::util::prng::Rng;
    use crate::value::WordSize;

    fn clustered_image(centers: &[u64], blocks: usize, spread: i64, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(blocks * 64);
        for _ in 0..blocks * 16 {
            let c = centers[rng.below(centers.len() as u64) as usize];
            let v = apply_delta(c, rng.range_i64(-spread, spread), WordSize::W32) as u32;
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn analysis_finds_compressive_table() {
        let image = clustered_image(&[40_000, 9_000_000, 3_100_000_000], 2000, 60, 1);
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let table = analyze_image(&image, &cfg);
        assert!(table.len() <= 8);
        let codec = GbdiCodec::new(table, cfg);
        let comp = codec.compress_image(&image);
        assert!(comp.ratio() > 2.0, "ratio {}", comp.ratio());
        assert_eq!(decode::decompress_image(&comp).unwrap(), image);
    }

    #[test]
    fn width_classes_track_spread() {
        // tight cluster -> small class; wide cluster -> big class
        let mut samples = Vec::new();
        let mut rng = Rng::new(2);
        for _ in 0..2000 {
            samples.push(apply_delta(1_000_000, rng.range_i64(-6, 7), WordSize::W32));
            samples.push(apply_delta(2_000_000_000, rng.range_i64(-30_000, 30_000), WordSize::W32));
        }
        let cfg = GbdiConfig { num_bases: 4, ..Default::default() };
        let table = analyze_samples(&samples, &cfg);
        let near = |b: u64, target: u64| (b as i64 - target as i64).abs() < 50_000;
        let tight = table.entries().iter().find(|e| near(e.base, 1_000_000)).expect("tight base");
        let wide = table.entries().iter().find(|e| near(e.base, 2_000_000_000)).expect("wide base");
        assert!(tight.width <= 8, "tight width {}", tight.width);
        assert!(wide.width >= 16, "wide width {}", wide.width);
    }

    #[test]
    fn table_within_budget_even_with_zero_pin() {
        let mut rng = Rng::new(3);
        let samples: Vec<u64> = (0..4096).map(|_| rng.next_u32() as u64).collect();
        for num_bases in [1usize, 2, 8, 64, 128] {
            let cfg = GbdiConfig { num_bases, ..Default::default() };
            let t = analyze_samples(&samples, &cfg);
            assert!(t.len() <= num_bases.max(2), "K={num_bases} -> {}", t.len());
            // codec construction must not assert
            let cfg2 = GbdiConfig { num_bases: num_bases.max(2), ..Default::default() };
            let _ = GbdiCodec::new(t, cfg2);
        }
    }

    #[test]
    fn table_from_external_centroids_matches_analysis_quality() {
        let image = clustered_image(&[123_456, 890_000_000], 800, 40, 5);
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let samples = sample_image(&image, &cfg);
        // Pretend the runtime's XLA k-means returned the true centers.
        let table = table_from_centroids(&samples, &[123_456, 890_000_000], &cfg, 7);
        assert_eq!(table.version, 7);
        let codec = GbdiCodec::new(table, cfg);
        assert!(codec.compress_image(&image).ratio() > 2.0);
    }

    #[test]
    fn empty_samples_still_yield_valid_table() {
        let cfg = GbdiConfig::default();
        let t = analyze_samples(&[], &cfg);
        assert!(!t.is_empty());
        let codec = GbdiCodec::new(t, cfg);
        let comp = codec.compress_image(&[0u8; 640]);
        assert_eq!(decode::decompress_image(&comp).unwrap(), vec![0u8; 640]);
    }

    #[test]
    fn euclidean_arm_also_roundtrips() {
        let image = clustered_image(&[777_777, 1_500_000_000], 500, 100, 9);
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let samples = sample_image(&image, &cfg);
        let t = analyze_samples_metric(&samples, &cfg, Metric::Euclidean);
        let codec = GbdiCodec::new(t, cfg);
        let comp = codec.compress_image(&image);
        assert_eq!(decode::decompress_image(&comp).unwrap(), image);
    }
}
