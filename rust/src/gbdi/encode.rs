//! The GBDI compression engine: block-level encoder + whole-image framing.
//!
//! Wire format, per block (bit-packed, LSB-first — see [`crate::util::bits`]):
//!
//! ```text
//! tag:2                       RAW | ZERO | REP | GBDI
//! RAW  -> block_bytes * 8 raw bits
//! ZERO -> (nothing)
//! REP  -> one word
//! GBDI -> per word: base_ptr:ceil(log2(K+1))
//!           base_ptr == K (escape) -> word bits raw (outlier)
//!           else                   -> delta in width(base_ptr) bits
//!                                     (offset-binary; width 0 = exact hit)
//! ```
//!
//! The encoder never expands pathological data by more than the 2-bit tag
//! per block: if the GBDI payload would be ≥ the raw block, it emits RAW.

use super::table::GlobalBaseTable;
use super::{BlockMode, GbdiConfig};
use crate::codec::{BlockCodec, CodecId};
use crate::container::{self, Container};
use crate::util::bits::{BitReader, BitWriter};
use crate::value::read_word;

/// Per-image statistics gathered while compressing (for reports and the
/// coordinator's metrics).
#[derive(Debug, Clone, Default)]
pub struct EncodeStats {
    /// Blocks by mode.
    pub raw_blocks: u64,
    /// All-zero blocks.
    pub zero_blocks: u64,
    /// Repeated-word blocks.
    pub rep_blocks: u64,
    /// GBDI-encoded blocks.
    pub gbdi_blocks: u64,
    /// Words encoded as (base, delta) pairs.
    pub encoded_words: u64,
    /// Words stored as outliers inside GBDI blocks.
    pub outlier_words: u64,
    /// Total delta bits emitted.
    pub delta_bits: u64,
}

impl EncodeStats {
    /// Accumulate another stats block (parallel chunk merge).
    pub fn merge(&mut self, o: &EncodeStats) {
        self.raw_blocks += o.raw_blocks;
        self.zero_blocks += o.zero_blocks;
        self.rep_blocks += o.rep_blocks;
        self.gbdi_blocks += o.gbdi_blocks;
        self.encoded_words += o.encoded_words;
        self.outlier_words += o.outlier_words;
        self.delta_bits += o.delta_bits;
    }

    /// Outlier fraction among words in GBDI blocks.
    pub fn outlier_frac(&self) -> f64 {
        let total = self.encoded_words + self.outlier_words;
        if total == 0 {
            0.0
        } else {
            self.outlier_words as f64 / total as f64
        }
    }
}

/// Append the fused `(base pointer, field)` emission of one word to the
/// plan: a single writer `put` when `ptr_bits + field_bits <= 64`
/// (always true for W32 tables), otherwise split into exactly two puts
/// (wide W64 delta or outlier fields). The emitted bit sequence is
/// identical to `put(ptr); put(field)` — the pointer occupies the low
/// bits, LSB-first.
#[inline]
fn push_packed(plan: &mut Vec<(u64, u32)>, ptr: u64, ptr_bits: u32, field: u64, field_bits: u32) {
    let total = ptr_bits + field_bits;
    if total <= 64 {
        plan.push((ptr | (field << ptr_bits), total));
    } else {
        // low 64 bits first; the shift drops the field's top bits, which
        // the second put re-emits
        plan.push((ptr | (field << ptr_bits), 64));
        plan.push((field >> (64 - ptr_bits), total - 64));
    }
}

/// The GBDI codec: a validated config + the global base table to encode
/// against, plus the flat decode LUT derived from both at construction
/// (see [`super::decode::DecodeLut`]). Cheap enough to clone per worker;
/// the coordinator clones one per thread.
#[derive(Debug, Clone)]
pub struct GbdiCodec {
    table: GlobalBaseTable,
    config: GbdiConfig,
    lut: super::decode::DecodeLut,
}

impl GbdiCodec {
    /// Build a codec. Panics on invalid config (use [`Self::try_new`] for
    /// a recoverable path) or table/config word-size mismatch.
    pub fn new(table: GlobalBaseTable, config: GbdiConfig) -> Self {
        assert_eq!(table.word_size, config.word_size, "table/config word size mismatch");
        Self::try_new(table, config).expect("invalid GbdiConfig")
    }

    /// Fallible [`Self::new`]: rejects invalid configs and table/config
    /// mismatches instead of panicking (the container layer builds codecs
    /// from untrusted headers through this).
    pub fn try_new(table: GlobalBaseTable, config: GbdiConfig) -> crate::Result<Self> {
        config.validate().map_err(crate::Error::Config)?;
        if table.word_size != config.word_size {
            return Err(crate::Error::Config("table/config word size mismatch".into()));
        }
        if table.len() > config.num_bases {
            // Strict: index `num_bases` is the outlier escape code, so a
            // table that large would alias real bases onto the escape.
            return Err(crate::Error::Config(format!(
                "table has {} bases, config allows {}",
                table.len(),
                config.num_bases
            )));
        }
        // Validated once here so the per-word decode loop can index the
        // LUT without bounds or validity checks.
        let lut = super::decode::DecodeLut::new(&table, &config);
        Ok(GbdiCodec { table, config, lut })
    }

    /// The table this codec encodes against.
    pub fn table(&self) -> &GlobalBaseTable {
        &self.table
    }

    /// The codec configuration.
    pub fn config(&self) -> &GbdiConfig {
        &self.config
    }

    /// Compress one block into `w`, accumulating [`EncodeStats`]. Returns
    /// the mode chosen and the payload bits written (including the tag).
    /// The stats-less [`BlockCodec::compress_block`] impl wraps this.
    pub fn compress_block_stats(
        &self,
        block: &[u8],
        w: &mut BitWriter,
        stats: &mut EncodeStats,
    ) -> (BlockMode, u32) {
        let mut plan = Vec::with_capacity(self.config.words_per_block());
        self.compress_block_into(block, w, stats, &mut plan)
    }

    /// [`Self::compress_block_stats`] with a caller-provided plan scratch
    /// buffer (the image loop and the [`crate::codec::Scratch`]-aware
    /// trait method reuse one allocation across all blocks).
    ///
    /// The plan is u64-packed: the base search runs once per word and
    /// deposits ready-to-emit `(field, bits)` pairs — base pointer and
    /// offset-binary delta fused into a single writer `put` wherever
    /// `ptr_bits + width <= 64` (always, for W32 tables). The search
    /// itself carries a per-block most-recently-used base hint
    /// ([`GlobalBaseTable::best_base_hinted`]): block-local value
    /// locality means consecutive words usually share a base, so the
    /// probe short-circuits the bucket walk without changing any field
    /// width.
    fn compress_block_into(
        &self,
        block: &[u8],
        w: &mut BitWriter,
        stats: &mut EncodeStats,
        plan: &mut Vec<(u64, u32)>,
    ) -> (BlockMode, u32) {
        let start = w.bit_len();
        let ws = self.config.word_size;
        // Ragged tail blocks (image not a multiple of block size): raw.
        if block.len() != self.config.block_bytes {
            self.emit_raw(block, w, stats);
            return (BlockMode::Raw, (w.bit_len() - start) as u32);
        }
        let n_words = self.config.words_per_block();
        // One dispatch resolution per block, shared by the ZERO/REP
        // scans and every per-word base search below.
        let kernels = crate::simd::active();

        // ZERO/REP classification through the dispatched block scans.
        // Config validation guarantees `block_bytes % word bytes == 0`,
        // the `rep_words` precondition. ZERO first: an all-zero block
        // satisfies both, and ZERO is the cheaper emission.
        if (kernels.all_zero)(block) {
            w.put(BlockMode::Zero as u64, 2);
            stats.zero_blocks += 1;
            return (BlockMode::Zero, (w.bit_len() - start) as u32);
        }
        if (kernels.rep_words)(block, ws.bytes()) {
            w.put(BlockMode::Rep as u64, 2);
            self.put_word(w, read_word(block, 0, ws));
            stats.rep_blocks += 1;
            return (BlockMode::Rep, (w.bit_len() - start) as u32);
        }

        // Load the words once (stack buffer for cache-line sized blocks).
        let mut words_buf = [0u64; 64];
        let mut words_big: Vec<u64> = Vec::new(); // oversized-block path only
        let words: &[u64] = if n_words <= 64 {
            for (i, slot) in words_buf[..n_words].iter_mut().enumerate() {
                *slot = read_word(block, i, ws);
            }
            &words_buf[..n_words]
        } else {
            words_big.extend((0..n_words).map(|i| read_word(block, i, ws)));
            &words_big[..]
        };

        // GBDI path: plan the block first (cheap), emit only if it wins.
        let ptr_bits = self.config.base_ptr_bits();
        let word_bits = ws.bits();
        let escape = self.config.outlier_code();
        plan.clear(); // packed (field, bits) puts, one or two per word
        let mut gbdi_bits: u64 = 2;
        let mut outliers = 0u64;
        let mut delta_bits = 0u64;
        let mut mru: Option<u32> = None;
        for &v in words {
            match self.table.best_base_hinted_with(v, mru, kernels) {
                Some((idx, delta, width)) => {
                    mru = Some(idx as u32);
                    gbdi_bits += (ptr_bits + width) as u64;
                    if width == 0 {
                        plan.push((idx as u64, ptr_bits));
                    } else {
                        delta_bits += width as u64;
                        let biased = (delta + (1i64 << (width - 1))) as u64;
                        push_packed(plan, idx as u64, ptr_bits, biased, width);
                    }
                }
                None => {
                    outliers += 1;
                    gbdi_bits += (ptr_bits + word_bits) as u64;
                    push_packed(plan, escape, ptr_bits, v, word_bits);
                }
            }
        }
        let raw_bits = 2 + (block.len() as u64) * 8;
        if gbdi_bits >= raw_bits {
            self.emit_raw(block, w, stats);
            return (BlockMode::Raw, (w.bit_len() - start) as u32);
        }
        w.put(BlockMode::Gbdi as u64, 2);
        for &(field, bits) in plan.iter() {
            w.put(field, bits);
        }
        stats.delta_bits += delta_bits;
        stats.gbdi_blocks += 1;
        stats.encoded_words += (n_words as u64) - outliers;
        stats.outlier_words += outliers;
        (BlockMode::Gbdi, (w.bit_len() - start) as u32)
    }

    fn emit_raw(&self, block: &[u8], w: &mut BitWriter, stats: &mut EncodeStats) {
        w.put(BlockMode::Raw as u64, 2);
        w.put_bytes(block);
        stats.raw_blocks += 1;
    }

    #[inline]
    fn put_word(&self, w: &mut BitWriter, v: u64) {
        w.put(v, self.config.word_size.bits());
    }

    /// Compress a whole image into a framed [`Container`].
    pub fn compress_image(&self, image: &[u8]) -> Container {
        self.compress_image_stats(image).0
    }

    /// [`Self::compress_image`] also returning encode statistics.
    pub fn compress_image_stats(&self, image: &[u8]) -> (Container, EncodeStats) {
        let mut w = BitWriter::with_capacity(image.len() / 2 + 64);
        let mut stats = EncodeStats::default();
        let mut block_bits = Vec::with_capacity(image.len() / self.config.block_bytes + 1);
        let mut plan = Vec::with_capacity(self.config.words_per_block());
        for block in image.chunks(self.config.block_bytes) {
            let (_, bits) = self.compress_block_into(block, &mut w, &mut stats, &mut plan);
            block_bits.push(bits);
        }
        (container::assemble(self, image.len(), 0, w.finish(), block_bits), stats)
    }

    /// Parallel whole-image compression with statistics. The chunk
    /// orchestration (byte-aligned sub-streams, realign-on-decode) lives
    /// in the codec-agnostic [`container`] layer; this wrapper only adds
    /// GBDI's per-chunk [`EncodeStats`] merge. Output decodes bit-exactly
    /// like the serial stream (ratio identical up to <1 byte padding per
    /// chunk).
    pub fn compress_image_parallel(&self, image: &[u8], threads: usize) -> (Container, EncodeStats) {
        let (payload, block_bits, chunk_stats, chunk_blocks) =
            container::compress_chunked(image, self.config.block_bytes, threads, |chunk| {
                let mut w = BitWriter::with_capacity(chunk.len() / 2 + 64);
                let mut stats = EncodeStats::default();
                let mut block_bits = Vec::with_capacity(chunk.len() / self.config.block_bytes + 1);
                let mut plan = Vec::with_capacity(self.config.words_per_block());
                for block in chunk.chunks(self.config.block_bytes) {
                    let (_, bits) = self.compress_block_into(block, &mut w, &mut stats, &mut plan);
                    block_bits.push(bits);
                }
                (w.finish(), block_bits, stats)
            });
        let mut stats = EncodeStats::default();
        for s in &chunk_stats {
            stats.merge(s);
        }
        (container::assemble(self, image.len(), chunk_blocks, payload, block_bits), stats)
    }
}

impl BlockCodec for GbdiCodec {
    fn name(&self) -> &'static str {
        "gbdi"
    }

    fn codec_id(&self) -> CodecId {
        CodecId::Gbdi
    }

    fn block_bytes(&self) -> usize {
        self.config.block_bytes
    }

    fn compress_block(&self, block: &[u8], w: &mut BitWriter) -> u32 {
        let mut stats = EncodeStats::default();
        self.compress_block_stats(block, w, &mut stats).1
    }

    fn compress_block_with(
        &self,
        block: &[u8],
        w: &mut BitWriter,
        scratch: &mut crate::codec::Scratch,
    ) -> u32 {
        let mut stats = EncodeStats::default();
        self.compress_block_into(block, w, &mut stats, &mut scratch.gbdi_plan).1
    }

    fn decompress_block(&self, r: &mut BitReader<'_>, out: &mut [u8]) -> crate::Result<()> {
        super::decode::decompress_block_lut(r, &self.lut, out)
    }

    /// Exact compressed bit size of `block` without emitting anything —
    /// the L3 mirror of the L1 `size_estimate` kernel; used by the
    /// coordinator to score candidate tables.
    fn estimate_block_bits(&self, block: &[u8]) -> u64 {
        if block.len() != self.config.block_bytes {
            return 2 + block.len() as u64 * 8;
        }
        let ws = self.config.word_size;
        let kernels = crate::simd::active();
        if (kernels.all_zero)(block) {
            return 2;
        }
        if (kernels.rep_words)(block, ws.bytes()) {
            return 2 + ws.bits() as u64;
        }
        let n_words = self.config.words_per_block();
        let ptr_bits = self.config.base_ptr_bits() as u64;
        let mut bits = 2u64;
        // same MRU hint chain as the encoder, so the estimate walks the
        // exact search the emission path would (widths always agree)
        let mut mru: Option<u32> = None;
        for i in 0..n_words {
            let v = read_word(block, i, ws);
            bits += ptr_bits
                + match self.table.best_base_hinted_with(v, mru, kernels) {
                    Some((idx, _, width)) => {
                        mru = Some(idx as u32);
                        width as u64
                    }
                    None => ws.bits() as u64,
                };
        }
        bits.min(2 + block.len() as u64 * 8)
    }

    /// The closed form above is already allocation-free; the scratch
    /// variant simply reuses it.
    fn estimate_block_bits_with(&self, block: &[u8], _scratch: &mut crate::codec::Scratch) -> u64 {
        self.estimate_block_bits(block)
    }

    fn config_bytes(&self) -> Vec<u8> {
        self.config.to_bytes()
    }

    fn global_table(&self) -> Option<&GlobalBaseTable> {
        Some(&self.table)
    }

    fn version(&self) -> u64 {
        self.table.version
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::decode;
    use crate::util::prng::Rng;

    fn codec_with_bases(bases: &[(u64, u32)]) -> GbdiCodec {
        let cfg = GbdiConfig::default();
        let table = GlobalBaseTable::new(bases.to_vec(), cfg.word_size, 1);
        GbdiCodec::new(table, cfg)
    }

    fn block_of_words(words: &[u32]) -> Vec<u8> {
        words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    #[test]
    fn zero_block_is_two_bits() {
        let codec = codec_with_bases(&[(0, 8)]);
        let mut w = BitWriter::new();
        let mut s = EncodeStats::default();
        let (mode, bits) = codec.compress_block_stats(&[0u8; 64], &mut w, &mut s);
        assert_eq!(mode, BlockMode::Zero);
        assert_eq!(bits, 2);
        assert_eq!(s.zero_blocks, 1);
    }

    #[test]
    fn rep_block_is_tag_plus_word() {
        let codec = codec_with_bases(&[(0, 8)]);
        let block = block_of_words(&[0xDEADBEEF; 16]);
        let mut w = BitWriter::new();
        let mut s = EncodeStats::default();
        let (mode, bits) = codec.compress_block_stats(&block, &mut w, &mut s);
        assert_eq!(mode, BlockMode::Rep);
        assert_eq!(bits, 2 + 32);
    }

    #[test]
    fn clustered_block_compresses_gbdi() {
        let codec = codec_with_bases(&[(1000, 8), (1 << 20, 8)]);
        let words: Vec<u32> = (0..16)
            .map(|i| if i % 2 == 0 { 1000 + i } else { (1 << 20) + i })
            .collect();
        let block = block_of_words(&words);
        let mut w = BitWriter::new();
        let mut s = EncodeStats::default();
        let (mode, bits) = codec.compress_block_stats(&block, &mut w, &mut s);
        assert_eq!(mode, BlockMode::Gbdi);
        assert!(bits < 64 * 8 / 2, "should compress >2x, got {bits} bits");
        assert_eq!(s.outlier_words, 0);
        assert_eq!(s.encoded_words, 16);
    }

    #[test]
    fn random_block_falls_back_to_raw() {
        let codec = codec_with_bases(&[(1000, 8)]);
        let mut rng = Rng::new(3);
        let mut block = vec![0u8; 64];
        rng.fill_bytes(&mut block);
        let mut w = BitWriter::new();
        let mut s = EncodeStats::default();
        let (mode, bits) = codec.compress_block_stats(&block, &mut w, &mut s);
        assert_eq!(mode, BlockMode::Raw);
        assert_eq!(bits, 2 + 64 * 8);
    }

    #[test]
    fn ragged_tail_stored_raw() {
        let codec = codec_with_bases(&[(0, 8)]);
        let mut w = BitWriter::new();
        let mut s = EncodeStats::default();
        let (mode, bits) = codec.compress_block_stats(&[7u8; 10], &mut w, &mut s);
        assert_eq!(mode, BlockMode::Raw);
        assert_eq!(bits, 2 + 80);
    }

    #[test]
    fn estimate_matches_actual_bits() {
        let mut rng = Rng::new(9);
        let codec = codec_with_bases(&[(1000, 16), (1 << 24, 8), (7_000_000, 24)]);
        for _ in 0..300 {
            let words: Vec<u32> = (0..16)
                .map(|_| match rng.below(4) {
                    0 => 1000u32.wrapping_add(rng.range_i64(-30000, 30000) as u32),
                    1 => (1u32 << 24).wrapping_add(rng.range_i64(-100, 100) as u32),
                    2 => 0,
                    _ => rng.next_u32(),
                })
                .collect();
            let block = block_of_words(&words);
            let mut w = BitWriter::new();
            let mut s = EncodeStats::default();
            let (_, bits) = codec.compress_block_stats(&block, &mut w, &mut s);
            assert_eq!(codec.estimate_block_bits(&block), bits as u64);
        }
    }

    #[test]
    fn image_roundtrip_and_ratio() {
        let mut rng = Rng::new(4);
        // words near two bases + zeros => highly compressible
        let words: Vec<u32> = (0..16 * 1024)
            .map(|_| match rng.below(3) {
                0 => 5000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                1 => (1u32 << 28).wrapping_add(rng.range_i64(-100, 100) as u32),
                _ => 0,
            })
            .collect();
        let image = block_of_words(&words);
        let codec = codec_with_bases(&[(5000, 8), (1 << 28, 8)]);
        let (comp, stats) = codec.compress_image_stats(&image);
        // ~15 bits/word payload; the container's per-block bit-length
        // index (honestly counted in total_len) costs ~2 B/block here
        assert!(comp.ratio() > 1.9, "ratio {}", comp.ratio());
        assert!(stats.gbdi_blocks + stats.zero_blocks + stats.rep_blocks > 0);
        let restored = decode::decompress_image(&comp).unwrap();
        assert_eq!(restored, image);
    }

    #[test]
    fn block_bits_sum_matches_payload() {
        let mut rng = Rng::new(8);
        let mut image = vec![0u8; 64 * 100];
        rng.fill_bytes(&mut image[..3000]);
        let codec = codec_with_bases(&[(0, 16)]);
        let comp = codec.compress_image(&image);
        let total_bits: u64 = comp.block_bits.iter().map(|&b| b as u64).sum();
        assert_eq!(comp.payload.len(), ((total_bits + 7) / 8) as usize);
        assert_eq!(comp.block_bits.len(), 100);
    }

    #[test]
    #[should_panic(expected = "word size mismatch")]
    fn word_size_mismatch_panics() {
        let cfg = GbdiConfig::default(); // W32
        let table = GlobalBaseTable::new(vec![(0, 8)], crate::value::WordSize::W64, 0);
        GbdiCodec::new(table, cfg);
    }
}
