//! The GBDI decompression engine: format decoding, global table access,
//! and bit-exact value reconstruction (paper §IV.B).

use super::table::GlobalBaseTable;
use super::{BlockMode, GbdiConfig};
use crate::cluster::apply_delta;
use crate::container::Container;
use crate::util::bits::BitReader;
use crate::value::write_word;
use crate::{Error, Result};

/// Decode one block from `r` into `out` (exactly `out.len()` bytes are
/// reconstructed; pass a short slice for ragged tail blocks).
pub fn decompress_block(
    r: &mut BitReader,
    table: &GlobalBaseTable,
    config: &GbdiConfig,
    out: &mut [u8],
) -> Result<()> {
    let corrupt = |what: &str| Error::Corrupt(format!("block: {what}"));
    let tag = r.get(2).map_err(|_| corrupt("missing tag"))?;
    let ws = config.word_size;
    match BlockMode::from_tag(tag) {
        BlockMode::Raw => {
            for b in out.iter_mut() {
                *b = r.get(8).map_err(|_| corrupt("truncated raw block"))? as u8;
            }
        }
        BlockMode::Zero => out.fill(0),
        BlockMode::Rep => {
            let v = r.get(ws.bits()).map_err(|_| corrupt("truncated rep word"))?;
            if out.len() % ws.bytes() != 0 {
                return Err(corrupt("rep block with ragged length"));
            }
            for i in 0..out.len() / ws.bytes() {
                write_word(out, i, ws, v);
            }
        }
        BlockMode::Gbdi => {
            if out.len() != config.block_bytes {
                return Err(corrupt("gbdi block with ragged length"));
            }
            let ptr_bits = config.base_ptr_bits();
            let escape = config.outlier_code();
            for i in 0..config.words_per_block() {
                let ptr = r.get(ptr_bits).map_err(|_| corrupt("truncated base ptr"))?;
                let v = if ptr == escape {
                    r.get(ws.bits()).map_err(|_| corrupt("truncated outlier"))?
                } else {
                    if ptr as usize >= table.len() {
                        return Err(corrupt("base pointer beyond table"));
                    }
                    let entry = table.get(ptr as usize);
                    // Delta width is determined by the *class that was used
                    // to encode*, which the encoder chose as the smallest
                    // class fitting the delta but capped by the entry's
                    // width. The wire does not carry the class; both sides
                    // derive it identically from the entry: the entry's
                    // width class IS the field width.
                    let w = entry.width;
                    if w == 0 {
                        entry.base
                    } else {
                        let d = r.get_signed(w).map_err(|_| corrupt("truncated delta"))?;
                        apply_delta(entry.base, d, ws)
                    }
                };
                write_word(out, i, ws, v);
            }
        }
    }
    Ok(())
}

/// Decompress a full GBDI [`Container`], verifying framing. The returned
/// buffer is byte-identical to the original image. Thin wrapper over the
/// codec-agnostic [`crate::container::decompress`], kept for the quickstart
/// API surface; it additionally insists the container really is GBDI.
pub fn decompress_image(comp: &Container) -> Result<Vec<u8>> {
    if comp.codec_id != crate::codec::CodecId::Gbdi {
        return Err(Error::Corrupt(format!(
            "not a gbdi container (codec {})",
            comp.codec_id.name()
        )));
    }
    crate::container::decompress(comp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::encode::GbdiCodec;
    use crate::util::prng::Rng;

    fn codec() -> GbdiCodec {
        let cfg = GbdiConfig::default();
        let table = GlobalBaseTable::new(
            vec![(1000, 8), (1 << 20, 16), (3_000_000_000, 8)],
            cfg.word_size,
            1,
        );
        GbdiCodec::new(table, cfg)
    }

    fn mixed_image(len_words: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..len_words)
            .flat_map(|_| {
                let v: u32 = match rng.below(5) {
                    0 => 1000u32.wrapping_add(rng.range_i64(-127, 127) as u32),
                    1 => (1u32 << 20).wrapping_add(rng.range_i64(-30_000, 30_000) as u32),
                    2 => 3_000_000_000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                    3 => 0,
                    _ => rng.next_u32(),
                };
                v.to_le_bytes()
            })
            .collect()
    }

    #[test]
    fn roundtrip_mixed_image() {
        let image = mixed_image(4096, 11);
        let c = codec();
        let comp = c.compress_image(&image);
        assert_eq!(decompress_image(&comp).unwrap(), image);
        assert!(comp.ratio() > 1.0, "ratio {}", comp.ratio());
    }

    #[test]
    fn roundtrip_ragged_image() {
        let mut image = mixed_image(100, 12);
        image.extend_from_slice(&[1, 2, 3]); // ragged tail
        let c = codec();
        let comp = c.compress_image(&image);
        assert_eq!(decompress_image(&comp).unwrap(), image);
    }

    #[test]
    fn roundtrip_empty_image() {
        let c = codec();
        let comp = c.compress_image(&[]);
        assert_eq!(decompress_image(&comp).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn truncated_payload_detected() {
        let image = mixed_image(1024, 13);
        let c = codec();
        let mut comp = c.compress_image(&image);
        comp.payload.truncate(comp.payload.len() / 2);
        assert!(decompress_image(&comp).is_err());
    }

    #[test]
    fn framing_mismatch_detected() {
        let image = mixed_image(512, 14);
        let c = codec();
        let mut comp = c.compress_image(&image);
        comp.block_bits.pop();
        assert!(decompress_image(&comp).is_err());
        let mut comp = c.compress_image(&image);
        if comp.block_bits[0] > 2 {
            comp.block_bits[0] -= 1;
            assert!(decompress_image(&comp).is_err());
        }
    }

    #[test]
    fn corrupted_payload_cannot_panic() {
        // flip bits through the payload; decode must return Ok(wrong) or
        // Err, never panic.
        let image = mixed_image(512, 15);
        let c = codec();
        let comp = c.compress_image(&image);
        let mut rng = Rng::new(16);
        for _ in 0..200 {
            let mut bad = comp.clone();
            if bad.payload.is_empty() {
                break;
            }
            let i = rng.below(bad.payload.len() as u64) as usize;
            bad.payload[i] ^= 1 << rng.below(8);
            let _ = decompress_image(&bad);
        }
    }
}
